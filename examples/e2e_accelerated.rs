//! End-to-end driver: the full three-layer system on a real workload.
//!
//! Proves all layers compose: the Rust coordinator (L3) runs GraphHP
//! global iterations whose local phases execute the AOT-compiled
//! JAX/Pallas programs (L2+L1) through PJRT — Python is never on the
//! request path. Compares four configurations on incremental PageRank
//! and one on SSSP:
//!
//!   Hama (scalar)            standard BSP baseline
//!   GraphHP (scalar)         the paper's hybrid engine
//!   GraphHP (XLA local)      hybrid engine with accelerated local phase
//!
//! and verifies every run against the sequential oracle. Results are
//! recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_accelerated
//! ```

use graphhp::algorithms::{oracle, IncrementalPageRank, Sssp};
use graphhp::engine::{EngineConfig, EngineKind, Metrics, Runner};
use graphhp::graph::{generators, DistGraph};
use graphhp::partition::{metis_partition, MetisConfig, PartitionStats};
use graphhp::runtime::{pipeline, XlaRuntime};

fn row(name: &str, m: &Metrics) {
    println!(
        "  {name:<22} I={:<6} M={:<10} T={:>8.3}s  supersteps={}",
        m.global_iterations,
        m.network_messages,
        m.elapsed.as_secs_f64(),
        m.supersteps_total
    );
}

fn main() {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let rt = XlaRuntime::new(&artifacts).expect("PJRT CPU client");
    println!("PJRT platform: {}", rt.platform());

    // ---- workload: web graph sized so metis partitions fit the 256 tile
    let n = 20_000;
    let tile = 256;
    let parts = 110; // ~182 vertices/partition average
    let g = generators::powerlaw(n, 5, 3);
    let assignment = metis_partition(
        &g,
        parts,
        &MetisConfig { balance_cap: 1.12, ..Default::default() },
    );
    let stats = PartitionStats::compute(&g, &assignment, parts);
    println!("\nworkload: {} vertices, {} edges; {stats}", g.num_vertices(), g.num_edges());
    let dg = DistGraph::new(&g, &assignment, parts);
    let max_part = dg.parts.iter().map(|p| p.num_vertices()).max().unwrap();
    assert!(max_part <= tile, "partition {max_part} exceeds tile {tile}");

    let cfg = EngineConfig::default();
    let tol = 1e-5;

    // ---- PageRank: three configurations -------------------------------
    println!("\n== incremental PageRank (tolerance {tol:e}) ==");
    let want = oracle::pagerank(&g, 1e-12);
    let err = |values: &[f64]| -> f64 {
        values.iter().zip(&want).map(|(a, b)| (a - b).abs()).sum::<f64>() / want.len() as f64
    };

    let mut runner = Runner::from_dist(&dg);
    let h = runner.run_on(EngineKind::Hama, &IncrementalPageRank { tolerance: tol });
    row("Hama (scalar)", &h.metrics);

    let hp = runner.run_on(EngineKind::GraphHP, &IncrementalPageRank { tolerance: tol });
    row("GraphHP (scalar)", &hp.metrics);

    let ax = pipeline::run_pagerank_accelerated(&rt, &dg, tol as f32, &cfg)
        .expect("accelerated pipeline");
    row("GraphHP (XLA local)", &ax.metrics);

    println!(
        "\n  oracle avg |err|: hama {:.2e} | graphhp {:.2e} | xla {:.2e}",
        err(&h.values),
        err(&hp.values),
        err(&ax.values)
    );
    assert!(err(&ax.values) < 1e-2, "accelerated run drifted from oracle");

    println!(
        "\n  headline: GraphHP reduces global iterations {:.0}x vs Hama; \
         the XLA pipeline reproduces the hybrid metrics (I={} vs {}) with \
         the local phase running as {} fused pseudo-supersteps on PJRT.",
        h.metrics.global_iterations as f64 / hp.metrics.global_iterations as f64,
        ax.metrics.global_iterations,
        hp.metrics.global_iterations,
        ax.metrics.supersteps_total
    );

    // ---- SSSP on a road network ---------------------------------------
    println!("\n== SSSP (road network) ==");
    let gr = generators::road(100, 100, 5);
    // pick k so every partition fits the AOT tile (initial partitioning
    // can overshoot the balance cap; bump k until it fits)
    let mut kr = 64;
    let (ar, dgr) = loop {
        let ar = metis_partition(&gr, kr, &MetisConfig { balance_cap: 1.1, ..Default::default() });
        let dgr = DistGraph::new(&gr, &ar, kr);
        let max_part = dgr.parts.iter().map(|p| p.num_vertices()).max().unwrap();
        if max_part <= tile {
            break (ar, dgr);
        }
        kr += 16;
    };
    let _ = ar;
    println!("  ({} partitions)", kr);
    let want_d = oracle::dijkstra(&gr, 0);

    let mut road_runner = Runner::from_dist(&dgr);
    let h = road_runner.run_on(EngineKind::Hama, &Sssp { source: 0 });
    row("Hama (scalar)", &h.metrics);
    let hp = road_runner.run_on(EngineKind::GraphHP, &Sssp { source: 0 });
    row("GraphHP (scalar)", &hp.metrics);
    let ax = pipeline::run_sssp_accelerated(&rt, &dgr, 0, &cfg).expect("sssp pipeline");
    row("GraphHP (XLA local)", &ax.metrics);

    let mut max_err = 0f32;
    for (i, &w) in want_d.iter().enumerate() {
        if w.is_finite() {
            max_err = max_err.max((ax.values[i] - w as f32).abs());
        }
    }
    println!("\n  oracle max |err| (XLA run): {max_err:.2e}");
    assert!(max_err < 1e-2);

    println!("\ne2e OK: all layers compose; all runs verified against oracles.");
}
