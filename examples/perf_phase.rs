//! L1/L2 perf probe: XLA phase invocation latency (AOT artifact on PJRT CPU).
use graphhp::runtime::{pipeline, XlaRuntime};
fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = XlaRuntime::new(&dir).unwrap();
    let ph = rt.load_phase("pagerank_local").unwrap();
    let med = pipeline::time_phase_invocation(&ph, 21).unwrap();
    let n = ph.spec.n;
    let k = ph.spec.steps;
    let flops = 2.0 * (n * n) as f64 * k as f64; // K matvecs
    println!(
        "pagerank_local (literal args): n={n} K={k} median invocation {:?} ({:.2} GFLOP/s effective)",
        med,
        flops / med.as_secs_f64() / 1e9
    );
    // cached device matrix path
    let m = vec![0.001f32; n * n];
    let m_dev = rt.upload_f32(&m, &[n, n]).unwrap();
    let r = vec![0.15f32; n];
    let d = vec![0.15f32; n];
    let mut times = Vec::new();
    for _ in 0..21 {
        let t0 = std::time::Instant::now();
        let _ = ph.run_pagerank_dev(&rt, &m_dev, &r, &d).unwrap();
        times.push(t0.elapsed());
    }
    times.sort();
    let med = times[10];
    println!(
        "pagerank_local (device-cached M): median invocation {:?} ({:.2} GFLOP/s effective)",
        med,
        flops / med.as_secs_f64() / 1e9
    );
}
