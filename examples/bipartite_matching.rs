//! Maximal bipartite matching — the paper's §6.3 / §7.4 scenario
//! (Table 3): the stateful handshake protocol on Hama, AM-Hama and
//! GraphHP, with validity and maximality checked.
//!
//! ```sh
//! cargo run --release --example bipartite_matching [n_left n_right parts]
//! ```

use graphhp::algorithms::bipartite_matching::{validate_matching, BipartiteMatching};
use graphhp::engine::{EngineKind, Runner};
use graphhp::graph::generators;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let nl: usize = args.first().map_or(20_000, |s| s.parse().unwrap());
    let nr: usize = args.get(1).map_or(20_000, |s| s.parse().unwrap());
    let parts: usize = args.get(2).map_or(18, |s| s.parse().unwrap());

    let g = generators::bipartite(nl, nr, 3, 11);
    println!(
        "bipartite graph: {}+{} vertices, {} edges, {} partitions",
        nl,
        nr,
        g.num_edges(),
        parts
    );
    let mut runner = Runner::new(&g).partitions(parts);
    let prog = BipartiteMatching { num_left: nl as u32 };

    println!("\n  engine     iterations   net messages         time     matching");
    for (kind, r) in runner.compare(
        &[EngineKind::Hama, EngineKind::AmHama, EngineKind::GraphHP],
        &prog,
    ) {
        let size = validate_matching(&g, nl as u32, &r.values)
            .expect("matching must be valid and maximal");
        println!(
            "  {:<10} {:>8} {:>14} {:>12.3}s {:>8}",
            kind.to_string(),
            r.metrics.global_iterations,
            r.metrics.network_messages,
            r.metrics.elapsed.as_secs_f64(),
            size
        );
    }
}
