//! PageRank convergence on a web-like graph — the paper's §6.2 / §7.3
//! scenario (Figure 4): iterations and time vs tolerance for Hama,
//! AM-Hama and GraphHP.
//!
//! ```sh
//! cargo run --release --example pagerank_web [n parts]
//! ```

use graphhp::algorithms::{oracle, IncrementalPageRank};
use graphhp::engine::{am_hama, graphhp as hp_engine, hama, EngineConfig};
use graphhp::graph::{generators, DistGraph};
use graphhp::partition::{metis_partition, MetisConfig};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().map_or(30_000, |s| s.parse().unwrap());
    let parts: usize = args.get(1).map_or(12, |s| s.parse().unwrap());

    let g = generators::powerlaw(n, 5, 7);
    println!(
        "web graph: {} vertices, {} edges, {} partitions",
        g.num_vertices(),
        g.num_edges(),
        parts
    );
    let assignment = metis_partition(&g, parts, &MetisConfig::default());
    let dg = DistGraph::new(&g, &assignment, parts);
    let cfg = EngineConfig::default();

    println!("\n tolerance |      Hama          |     AM-Hama        |     GraphHP");
    println!("           |   I        T       |   I        T       |   I        T");
    for exp in 2..=6 {
        let tol = 10f64.powi(-exp);
        let prog = IncrementalPageRank { tolerance: tol };
        let h = hama::run_hama(&prog, &dg, &cfg);
        let am = am_hama::run_am_hama(&prog, &dg, &cfg);
        let hp = hp_engine::run_graphhp(&prog, &dg, &cfg);
        println!(
            "   1e-{exp}    | {:>5} {:>9.3}s  | {:>5} {:>9.3}s  | {:>5} {:>9.3}s",
            h.metrics.global_iterations,
            h.metrics.elapsed.as_secs_f64(),
            am.metrics.global_iterations,
            am.metrics.elapsed.as_secs_f64(),
            hp.metrics.global_iterations,
            hp.metrics.elapsed.as_secs_f64(),
        );
    }

    // accuracy spot check at the tightest tolerance
    let want = oracle::pagerank(&g, 1e-12);
    let hp = hp_engine::run_graphhp(&IncrementalPageRank { tolerance: 1e-6 }, &dg, &cfg);
    let err: f64 =
        hp.values.iter().zip(&want).map(|(a, b)| (a - b).abs()).sum::<f64>() / want.len() as f64;
    println!("\nGraphHP@1e-6 vs power iteration: avg |err| = {err:.2e}");
}
