//! PageRank convergence on a web-like graph — the paper's §6.2 / §7.3
//! scenario (Figure 4): iterations and time vs tolerance for Hama,
//! AM-Hama and GraphHP.
//!
//! ```sh
//! cargo run --release --example pagerank_web [n parts]
//! ```

use graphhp::algorithms::{oracle, IncrementalPageRank};
use graphhp::engine::{EngineKind, Runner};
use graphhp::graph::generators;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().map_or(30_000, |s| s.parse().unwrap());
    let parts: usize = args.get(1).map_or(12, |s| s.parse().unwrap());

    let g = generators::powerlaw(n, 5, 7);
    println!(
        "web graph: {} vertices, {} edges, {} partitions",
        g.num_vertices(),
        g.num_edges(),
        parts
    );
    let mut runner = Runner::new(&g).partitions(parts);

    println!("\n tolerance |      Hama          |     AM-Hama        |     GraphHP");
    println!("           |   I        T       |   I        T       |   I        T");
    for exp in 2..=6 {
        let tol = 10f64.powi(-exp);
        let prog = IncrementalPageRank { tolerance: tol };
        let results = runner.compare(
            &[EngineKind::Hama, EngineKind::AmHama, EngineKind::GraphHP],
            &prog,
        );
        let [h, am, hp] = &results[..] else { unreachable!() };
        println!(
            "   1e-{exp}    | {:>5} {:>9.3}s  | {:>5} {:>9.3}s  | {:>5} {:>9.3}s",
            h.1.metrics.global_iterations,
            h.1.metrics.elapsed.as_secs_f64(),
            am.1.metrics.global_iterations,
            am.1.metrics.elapsed.as_secs_f64(),
            hp.1.metrics.global_iterations,
            hp.1.metrics.elapsed.as_secs_f64(),
        );
    }

    // accuracy spot check at the tightest tolerance
    let want = oracle::pagerank(&g, 1e-12);
    let hp = runner.run_on(EngineKind::GraphHP, &IncrementalPageRank { tolerance: 1e-6 });
    let err: f64 =
        hp.values.iter().zip(&want).map(|(a, b)| (a - b).abs()).sum::<f64>() / want.len() as f64;
    println!("\nGraphHP@1e-6 vs power iteration: avg |err| = {err:.2e}");
}
