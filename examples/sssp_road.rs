//! Single-source shortest paths on a road network — the paper's §6.1 /
//! §7.2 scenario. Compares Hama, AM-Hama and GraphHP on the three paper
//! metrics (iterations, network messages, time) and verifies all three
//! against Dijkstra.
//!
//! ```sh
//! cargo run --release --example sssp_road [rows cols parts]
//! ```

use graphhp::algorithms::{oracle, Sssp};
use graphhp::engine::{am_hama, graphhp as hp_engine, hama, EngineConfig, Metrics};
use graphhp::graph::{generators, DistGraph};
use graphhp::partition::{metis_partition, MetisConfig};

fn check(values: &[f32], want: &[f64]) {
    for (i, (&g, &w)) in values.iter().zip(want).enumerate() {
        if w.is_finite() {
            assert!((g - w as f32).abs() < 1e-2, "v{i}: {g} vs {w}");
        }
    }
}

fn row(name: &str, m: &Metrics) {
    println!(
        "  {name:<10} {:>8} {:>14} {:>12.3}s   (sync {:>4.1}% comm {:>4.1}%)",
        m.global_iterations,
        m.network_messages,
        m.elapsed.as_secs_f64(),
        100.0 * m.sync_fraction(),
        100.0 * m.comm_fraction()
    );
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rows: usize = args.first().map_or(120, |s| s.parse().unwrap());
    let cols: usize = args.get(1).map_or(120, |s| s.parse().unwrap());
    let parts: usize = args.get(2).map_or(12, |s| s.parse().unwrap());

    let g = generators::road(rows, cols, 1);
    println!(
        "road network: {} vertices, {} edges, {} partitions (metis)",
        g.num_vertices(),
        g.num_edges(),
        parts
    );
    let assignment = metis_partition(&g, parts, &MetisConfig::default());
    let dg = DistGraph::new(&g, &assignment, parts);
    let want = oracle::dijkstra(&g, 0);

    let cfg = EngineConfig::default();
    let prog = Sssp { source: 0 };

    println!("\n  engine     iterations   net messages         time");
    let h = hama::run_hama(&prog, &dg, &cfg);
    check(&h.values, &want);
    row("Hama", &h.metrics);

    let am = am_hama::run_am_hama(&prog, &dg, &cfg);
    check(&am.values, &want);
    row("AM-Hama", &am.metrics);

    let hp = hp_engine::run_graphhp(&prog, &dg, &cfg);
    check(&hp.values, &want);
    row("GraphHP", &hp.metrics);

    println!(
        "\nGraphHP vs Hama: {:.0}x fewer iterations, {:.0}x fewer messages, {:.1}x faster",
        h.metrics.global_iterations as f64 / hp.metrics.global_iterations as f64,
        h.metrics.network_messages as f64 / hp.metrics.network_messages.max(1) as f64,
        h.metrics.elapsed.as_secs_f64() / hp.metrics.elapsed.as_secs_f64().max(1e-9),
    );
    println!("(all three engines verified against Dijkstra)");
}
