//! Single-source shortest paths on a road network — the paper's §6.1 /
//! §7.2 scenario. Compares Hama, AM-Hama and GraphHP on the three paper
//! metrics (iterations, network messages, time) and verifies all three
//! against Dijkstra.
//!
//! ```sh
//! cargo run --release --example sssp_road [rows cols parts]
//! ```

use graphhp::algorithms::{oracle, Sssp};
use graphhp::engine::{EngineKind, Metrics, Runner};
use graphhp::graph::generators;

fn check(values: &[f32], want: &[f64]) {
    for (i, (&g, &w)) in values.iter().zip(want).enumerate() {
        if w.is_finite() {
            assert!((g - w as f32).abs() < 1e-2, "v{i}: {g} vs {w}");
        }
    }
}

fn row(name: &str, m: &Metrics) {
    println!(
        "  {name:<10} {:>8} {:>14} {:>12.3}s   (sync {:>4.1}% comm {:>4.1}%)",
        m.global_iterations,
        m.network_messages,
        m.elapsed.as_secs_f64(),
        100.0 * m.sync_fraction(),
        100.0 * m.comm_fraction()
    );
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rows: usize = args.first().map_or(120, |s| s.parse().unwrap());
    let cols: usize = args.get(1).map_or(120, |s| s.parse().unwrap());
    let parts: usize = args.get(2).map_or(12, |s| s.parse().unwrap());

    let g = generators::road(rows, cols, 1);
    println!(
        "road network: {} vertices, {} edges, {} partitions (metis)",
        g.num_vertices(),
        g.num_edges(),
        parts
    );
    let want = oracle::dijkstra(&g, 0);
    let mut runner = Runner::new(&g).partitions(parts);
    let prog = Sssp { source: 0 };

    println!("\n  engine     iterations   net messages         time");
    let results = runner.compare(
        &[EngineKind::Hama, EngineKind::AmHama, EngineKind::GraphHP],
        &prog,
    );
    for (kind, r) in &results {
        check(&r.values, &want);
        row(&kind.to_string(), &r.metrics);
    }

    let h = &results[0].1.metrics;
    let hp = &results[2].1.metrics;
    println!(
        "\nGraphHP vs Hama: {:.0}x fewer iterations, {:.0}x fewer messages, {:.1}x faster",
        h.global_iterations as f64 / hp.global_iterations as f64,
        h.network_messages as f64 / hp.network_messages.max(1) as f64,
        h.elapsed.as_secs_f64() / hp.elapsed.as_secs_f64().max(1e-9),
    );
    println!("(all three engines verified against Dijkstra)");
}
