//! Quickstart: build a graph, run PageRank on GraphHP and Hama through
//! one `Runner` session, and read the metrics — the 60-second tour of
//! the public API.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use graphhp::algorithms::IncrementalPageRank;
use graphhp::engine::{EngineKind, Runner};
use graphhp::graph::generators;

fn main() {
    // 1. a web-like graph (the stand-in for web-Google, scaled down)
    let g = generators::powerlaw(20_000, 5, 42);
    println!("graph: {} vertices, {} edges", g.num_vertices(), g.num_edges());

    // 2. one session: partitions the graph once (multilevel/metis by
    //    default) and runs any engine over the same distributed view
    let mut runner = Runner::new(&g).partitions(12);

    // 3. run incremental PageRank under the hybrid model...
    let pr = IncrementalPageRank { tolerance: 1e-4 };
    let hp = runner.run_on(EngineKind::GraphHP, &pr);

    // ...and under standard BSP for comparison
    let hm = runner.run_on(EngineKind::Hama, &pr);

    // 4. inspect results and the paper's three metrics (I, M, T)
    let dg = runner.dist();
    println!(
        "partitioning: {} partitions, edge cut {}, {} boundary vertices",
        dg.num_parts(),
        dg.edge_cut(),
        dg.num_boundary()
    );
    let mut top: Vec<(usize, f64)> = hp.values.iter().copied().enumerate().collect();
    top.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("\ntop-5 ranks: {:?}", &top[..5]);
    println!("\nGraphHP: {}", hp.metrics.summary());
    println!("Hama:    {}", hm.metrics.summary());
    println!(
        "\nGraphHP used {:.1}x fewer global iterations and {:.1}x fewer network messages",
        hm.metrics.global_iterations as f64 / hp.metrics.global_iterations as f64,
        hm.metrics.network_messages as f64 / hp.metrics.network_messages.max(1) as f64,
    );
}
