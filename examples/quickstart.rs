//! Quickstart: build a graph, partition it, run PageRank on GraphHP, and
//! read the metrics — the 60-second tour of the public API.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use graphhp::algorithms::IncrementalPageRank;
use graphhp::engine::{graphhp as hp_engine, hama, EngineConfig};
use graphhp::graph::{generators, DistGraph};
use graphhp::partition::{metis_partition, MetisConfig, PartitionStats};

fn main() {
    // 1. a web-like graph (the stand-in for web-Google, scaled down)
    let g = generators::powerlaw(20_000, 5, 42);
    println!("graph: {} vertices, {} edges", g.num_vertices(), g.num_edges());

    // 2. partition it with the built-in multilevel partitioner
    let k = 12;
    let assignment = metis_partition(&g, k, &MetisConfig::default());
    println!("partitioning: {}", PartitionStats::compute(&g, &assignment, k));
    let dg = DistGraph::new(&g, &assignment, k);

    // 3. run incremental PageRank under the hybrid model...
    let cfg = EngineConfig::default();
    let pr = IncrementalPageRank { tolerance: 1e-4 };
    let hp = hp_engine::run_graphhp(&pr, &dg, &cfg);

    // ...and under standard BSP for comparison
    let hm = hama::run_hama(&pr, &dg, &cfg);

    // 4. inspect results and the paper's three metrics (I, M, T)
    let mut top: Vec<(usize, f64)> = hp.values.iter().copied().enumerate().collect();
    top.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("\ntop-5 ranks: {:?}", &top[..5]);
    println!("\nGraphHP: {}", hp.metrics.summary());
    println!("Hama:    {}", hm.metrics.summary());
    println!(
        "\nGraphHP used {:.1}x fewer global iterations and {:.1}x fewer network messages",
        hm.metrics.global_iterations as f64 / hp.metrics.global_iterations as f64,
        hm.metrics.network_messages as f64 / hp.metrics.network_messages.max(1) as f64,
    );
}
