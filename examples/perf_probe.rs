//! L3 hot-path profiler: vertex-update throughput and per-phase costs.
use graphhp::algorithms::{IncrementalPageRank, Sssp};
use graphhp::engine::{EngineKind, Runner};
use graphhp::graph::generators;
use std::time::Instant;

fn main() {
    let g = generators::powerlaw(100_000, 6, 1);
    let mut runner = Runner::new(&g).partitions(12);
    runner.dist(); // partition outside the timed region
    for (name, kind) in
        [("hama/pagerank", EngineKind::Hama), ("graphhp/pagerank", EngineKind::GraphHP)]
    {
        let t0 = Instant::now();
        let r = runner.run_on(kind, &IncrementalPageRank { tolerance: 1e-4 });
        let dt = t0.elapsed();
        let updates = r.metrics.vertex_computations;
        let msgs = r.metrics.local_messages + r.metrics.network_messages;
        println!(
            "  {name}: {updates} updates, {msgs} msgs in {:.3}s = {:.2} M-updates/s, {:.2} M-msgs/s",
            dt.as_secs_f64(),
            updates as f64 / dt.as_secs_f64() / 1e6,
            msgs as f64 / dt.as_secs_f64() / 1e6
        );
    }
    let gr = generators::road(300, 300, 2);
    let mut road_runner = Runner::new(&gr).partitions(12);
    road_runner.dist(); // partition outside the timed region
    let t0 = Instant::now();
    let r = road_runner.run_on(EngineKind::GraphHP, &Sssp { source: 0 });
    let d = t0.elapsed();
    println!(
        "  graphhp/sssp: {} updates in {:.3}s = {:.2} M-updates/s",
        r.metrics.vertex_computations,
        d.as_secs_f64(),
        r.metrics.vertex_computations as f64 / d.as_secs_f64() / 1e6
    );
}
