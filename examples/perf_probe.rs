//! L3 hot-path profiler: vertex-update throughput and per-phase costs.
use graphhp::algorithms::{IncrementalPageRank, Sssp};
use graphhp::engine::{graphhp as hp_engine, hama, EngineConfig};
use graphhp::graph::{generators, DistGraph};
use graphhp::partition::{metis_partition, MetisConfig};
use std::time::Instant;

fn main() {
    let g = generators::powerlaw(100_000, 6, 1);
    let dg = DistGraph::new(&g, &metis_partition(&g, 12, &MetisConfig::default()), 12);
    let cfg = EngineConfig::default();
    for (name, which) in [("hama/pagerank", 0), ("graphhp/pagerank", 1), ("graphhp/sssp", 2)] {
        let t0 = Instant::now();
        let (updates, msgs) = match which {
            0 => { let r = hama::run_hama(&IncrementalPageRank{tolerance:1e-4}, &dg, &cfg); (r.metrics.vertex_computations, r.metrics.local_messages + r.metrics.network_messages) }
            1 => { let r = hp_engine::run_graphhp(&IncrementalPageRank{tolerance:1e-4}, &dg, &cfg); (r.metrics.vertex_computations, r.metrics.local_messages + r.metrics.network_messages) }
            _ => { let gr = generators::road(300,300,2); let dgr = DistGraph::new(&gr, &metis_partition(&gr, 12, &MetisConfig::default()), 12); let t=Instant::now(); let r = hp_engine::run_graphhp(&Sssp{source:0}, &dgr, &cfg); let d=t.elapsed(); println!("  graphhp/sssp: {} updates in {:.3}s = {:.2} M-updates/s", r.metrics.vertex_computations, d.as_secs_f64(), r.metrics.vertex_computations as f64/d.as_secs_f64()/1e6); continue_marker(); (0,0) }
        };
        if which == 2 { continue; }
        let dt = t0.elapsed();
        println!("  {name}: {updates} updates, {msgs} msgs in {:.3}s = {:.2} M-updates/s, {:.2} M-msgs/s",
            dt.as_secs_f64(), updates as f64/dt.as_secs_f64()/1e6, msgs as f64/dt.as_secs_f64()/1e6);
    }
}
fn continue_marker() {}
