//! Ablation study (ours, beyond the paper): which design choices carry
//! GraphHP's win?
//!
//!  1. partitioning: metis vs hash (locality is what the local phase
//!     exploits — Hama's default hash partitioning should erase much of
//!     the gain);
//!  2. boundary vertices in local phases: on vs off (paper §4.2 says ON
//!     accelerates convergence for incremental computations);
//!  3. asynchronous in-(pseudo)superstep messaging: on vs off;
//!  4. combiner: on vs off (message counts);
//!  5. XLA-accelerated vs scalar local phase (feature `xla` only:
//!     end-to-end wallclock on this host — interpret-mode CPU; see
//!     DESIGN.md §7 for the TPU estimate).

use graphhp::algorithms::{IncrementalPageRank, Sssp};
use graphhp::bench_support as bs;
use graphhp::engine::{
    EngineKind, Partitioner, Runner, SourceCombine, VertexContext, VertexProgram,
};
use graphhp::graph::{generators, VertexId};

/// SSSP without its min-combiner (ablation 4).
struct SsspNoCombiner {
    inner: Sssp,
}
impl VertexProgram for SsspNoCombiner {
    type V = f32;
    type M = f32;
    fn init(&self, v: VertexId, d: u32) -> f32 {
        self.inner.init(v, d)
    }
    fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
        // same logic, no combiner
        if ctx.superstep() == 0 {
            if ctx.vertex_id() == self.inner.source {
                ctx.send_along_edges(|e| Some(e.weight));
            }
        } else {
            let new = ctx
                .messages()
                .iter()
                .copied()
                .fold(graphhp::algorithms::sssp::INF, f32::min);
            if new < *ctx.value() {
                ctx.set_value(new);
                ctx.send_along_edges(|e| Some(new + e.weight));
            }
        }
        ctx.vote_to_halt();
    }
    fn source_combine(&self) -> SourceCombine {
        SourceCombine::KeepLatest
    }
}

fn main() {
    bs::header("Ablations: where does GraphHP's win come from?", "DESIGN.md §4 (ours)");

    // ---- 1. partitioning quality --------------------------------------
    println!("\n(1) metis vs hash partitioning — SSSP on road grid, 12 parts, GraphHP");
    let g = generators::road(160, 160, 1);
    let k = 12;
    let mut rm = bs::runner(&g, k);
    let mut rh = Runner::new(&g).partitions(k).partitioner(Partitioner::Hash);
    let resm = rm.run(&Sssp { source: 0 });
    let resh = rh.run(&Sssp { source: 0 });
    bs::row("GraphHP+metis", &resm.metrics);
    bs::row("GraphHP+hash", &resh.metrics);
    println!(
        "  metis cut={} vs hash cut={} — locality drives the local phase",
        rm.dist().edge_cut(),
        rh.dist().edge_cut()
    );
    bs::expect_less(
        "metis iters < hash iters",
        resm.metrics.global_iterations,
        resh.metrics.global_iterations,
    );

    // ---- 2. boundary vertices in local phase ---------------------------
    println!("\n(2) boundary_in_local_phase on/off — PageRank, web graph, 12 parts");
    let g = generators::powerlaw(30_000, 5, 7);
    let pr = IncrementalPageRank { tolerance: 1e-4 };
    // partition once; every A/B below runs over the same view
    let dg = bs::dist(&g, 12);
    let on = Runner::from_dist(&dg).run(&pr);
    let off = Runner::from_dist(&dg).boundary_in_local_phase(false).run(&pr);
    bs::row("boundary IN", &on.metrics);
    bs::row("boundary OUT", &off.metrics);
    bs::expect_less(
        "boundary-in iters ≤ boundary-out iters",
        on.metrics.global_iterations,
        off.metrics.global_iterations + 1,
    );

    // ---- 3. async local messaging --------------------------------------
    println!("\n(3) async in-pseudo-superstep messaging on/off — GraphHP, same workload");
    let asy = on;
    let syn = Runner::from_dist(&dg).async_local_messaging(false).run(&pr);
    bs::row("async ON", &asy.metrics);
    bs::row("async OFF", &syn.metrics);
    bs::expect_less(
        "async pseudo-supersteps ≤ sync pseudo-supersteps",
        asy.metrics.supersteps_total,
        syn.metrics.supersteps_total + 1,
    );

    // ---- 4. combiner ----------------------------------------------------
    println!("\n(4) combiner on/off — SSSP on road grid, Hama, 12 parts");
    let g = generators::road(120, 120, 2);
    let mut runner4 = bs::runner(&g, 12).engine(EngineKind::Hama);
    let with = runner4.run(&Sssp { source: 0 });
    let without = runner4.run(&SsspNoCombiner { inner: Sssp { source: 0 } });
    bs::row("combiner ON", &with.metrics);
    bs::row("combiner OFF", &without.metrics);
    bs::expect_less(
        "combined msgs < raw msgs",
        with.metrics.network_messages,
        without.metrics.network_messages,
    );

    // ---- 5. XLA local phase vs scalar ----------------------------------
    println!("\n(5) XLA-accelerated local phase vs scalar engine — PageRank");
    ablation5_xla();

    println!("\nablation done");
}

#[cfg(feature = "xla")]
fn ablation5_xla() {
    use graphhp::engine::EngineConfig;
    use graphhp::graph::DistGraph;
    use graphhp::partition::{metis_partition, MetisConfig};
    use graphhp::runtime::{pipeline, XlaRuntime};

    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.txt").exists() {
        println!("  (skipped: run `make artifacts` first)");
        return;
    }
    let cfg = EngineConfig::default();
    let rt = XlaRuntime::new(&artifacts).expect("PJRT");
    let g = generators::powerlaw(20_000, 5, 3);
    let a = metis_partition(&g, 110, &MetisConfig { balance_cap: 1.12, ..Default::default() });
    let dg5 = DistGraph::new(&g, &a, 110);
    if dg5.parts.iter().all(|p| p.num_vertices() <= 256) {
        let t0 = std::time::Instant::now();
        let sc = Runner::from_dist(&dg5).run(&IncrementalPageRank { tolerance: 1e-5 });
        let t_scalar = t0.elapsed();
        let t0 = std::time::Instant::now();
        let ac = pipeline::run_pagerank_accelerated(&rt, &dg5, 1e-5, &cfg).unwrap();
        let t_xla = t0.elapsed();
        bs::row("scalar local", &sc.metrics);
        bs::row("XLA local", &ac.metrics);
        println!(
            "  host wallclock: scalar {:.3}s, xla {:.3}s (interpret-mode CPU; \
             the XLA path is the TPU-offload demonstration, not a CPU speedup)",
            t_scalar.as_secs_f64(),
            t_xla.as_secs_f64()
        );
    } else {
        println!("  (skipped: a partition exceeds the 256 tile)");
    }
}

#[cfg(not(feature = "xla"))]
fn ablation5_xla() {
    println!("  (skipped: build with --features xla and `make artifacts` first)");
}
