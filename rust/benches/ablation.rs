//! Ablation study (ours, beyond the paper): which design choices carry
//! GraphHP's win?
//!
//!  1. partitioning: metis vs hash (locality is what the local phase
//!     exploits — Hama's default hash partitioning should erase much of
//!     the gain);
//!  2. boundary vertices in local phases: on vs off (paper §4.2 says ON
//!     accelerates convergence for incremental computations);
//!  3. asynchronous in-(pseudo)superstep messaging: on vs off;
//!  4. combiner: on vs off (message counts);
//!  5. XLA-accelerated vs scalar local phase (end-to-end wallclock on
//!     this host — interpret-mode CPU; see DESIGN.md §7 for the TPU
//!     estimate).

use graphhp::algorithms::{IncrementalPageRank, Sssp};
use graphhp::bench_support as bs;
use graphhp::engine::{graphhp as hp, hama, EngineConfig, SourceCombine, VertexContext, VertexProgram};
use graphhp::graph::{generators, DistGraph, VertexId};
use graphhp::partition::{hash_partition, metis_partition, MetisConfig};
use graphhp::runtime::{pipeline, XlaRuntime};

/// SSSP without its min-combiner (ablation 4).
struct SsspNoCombiner {
    inner: Sssp,
}
impl VertexProgram for SsspNoCombiner {
    type V = f32;
    type M = f32;
    fn init(&self, v: VertexId, d: u32) -> f32 {
        self.inner.init(v, d)
    }
    fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
        // same logic, no combiner
        if ctx.superstep() == 0 {
            if ctx.vertex_id() == self.inner.source {
                ctx.send_along_edges(|e| Some(e.weight));
            }
        } else {
            let new = ctx
                .messages()
                .iter()
                .copied()
                .fold(graphhp::algorithms::sssp::INF, f32::min);
            if new < *ctx.value() {
                ctx.set_value(new);
                ctx.send_along_edges(|e| Some(new + e.weight));
            }
        }
        ctx.vote_to_halt();
    }
    fn source_combine(&self) -> SourceCombine {
        SourceCombine::KeepLatest
    }
}

fn main() {
    bs::header("Ablations: where does GraphHP's win come from?", "DESIGN.md §4 (ours)");
    let cfg = EngineConfig::default();

    // ---- 1. partitioning quality --------------------------------------
    println!("\n(1) metis vs hash partitioning — SSSP on road grid, 12 parts, GraphHP");
    let g = generators::road(160, 160, 1);
    let k = 12;
    let dm = DistGraph::new(&g, &metis_partition(&g, k, &MetisConfig::default()), k);
    let dh = DistGraph::new(&g, &hash_partition(&g, k), k);
    let rm = hp::run_graphhp(&Sssp { source: 0 }, &dm, &cfg);
    let rh = hp::run_graphhp(&Sssp { source: 0 }, &dh, &cfg);
    bs::row("GraphHP+metis", &rm.metrics);
    bs::row("GraphHP+hash", &rh.metrics);
    println!(
        "  metis cut={} vs hash cut={} — locality drives the local phase",
        dm.edge_cut(),
        dh.edge_cut()
    );
    bs::expect_less(
        "metis iters < hash iters",
        rm.metrics.global_iterations,
        rh.metrics.global_iterations,
    );

    // ---- 2. boundary vertices in local phase ---------------------------
    println!("\n(2) boundary_in_local_phase on/off — PageRank, web graph, 12 parts");
    let g = generators::powerlaw(30_000, 5, 7);
    let dg = bs::dist(&g, 12);
    let pr = IncrementalPageRank { tolerance: 1e-4 };
    let on = hp::run_graphhp(&pr, &dg, &cfg);
    let off_cfg = EngineConfig { boundary_in_local_phase: false, ..cfg.clone() };
    let off = hp::run_graphhp(&pr, &dg, &off_cfg);
    bs::row("boundary IN", &on.metrics);
    bs::row("boundary OUT", &off.metrics);
    bs::expect_less(
        "boundary-in iters ≤ boundary-out iters",
        on.metrics.global_iterations,
        off.metrics.global_iterations + 1,
    );

    // ---- 3. async local messaging --------------------------------------
    println!("\n(3) async in-pseudo-superstep messaging on/off — GraphHP, same workload");
    let sync_cfg = EngineConfig { async_local_messaging: false, ..cfg.clone() };
    let asy = hp::run_graphhp(&pr, &dg, &cfg);
    let syn = hp::run_graphhp(&pr, &dg, &sync_cfg);
    bs::row("async ON", &asy.metrics);
    bs::row("async OFF", &syn.metrics);
    bs::expect_less(
        "async pseudo-supersteps ≤ sync pseudo-supersteps",
        asy.metrics.supersteps_total,
        syn.metrics.supersteps_total + 1,
    );

    // ---- 4. combiner ----------------------------------------------------
    println!("\n(4) combiner on/off — SSSP on road grid, Hama, 12 parts");
    let g = generators::road(120, 120, 2);
    let dg4 = bs::dist(&g, 12);
    let with = hama::run_hama(&Sssp { source: 0 }, &dg4, &cfg);
    let without = hama::run_hama(&SsspNoCombiner { inner: Sssp { source: 0 } }, &dg4, &cfg);
    bs::row("combiner ON", &with.metrics);
    bs::row("combiner OFF", &without.metrics);
    bs::expect_less(
        "combined msgs < raw msgs",
        with.metrics.network_messages,
        without.metrics.network_messages,
    );

    // ---- 5. XLA local phase vs scalar ----------------------------------
    println!("\n(5) XLA-accelerated local phase vs scalar engine — PageRank");
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("manifest.txt").exists() {
        let rt = XlaRuntime::new(&artifacts).expect("PJRT");
        let g = generators::powerlaw(20_000, 5, 3);
        let a = metis_partition(&g, 110, &MetisConfig { balance_cap: 1.12, ..Default::default() });
        let dg5 = DistGraph::new(&g, &a, 110);
        if dg5.parts.iter().all(|p| p.num_vertices() <= 256) {
            let t0 = std::time::Instant::now();
            let sc = hp::run_graphhp(&IncrementalPageRank { tolerance: 1e-5 }, &dg5, &cfg);
            let t_scalar = t0.elapsed();
            let t0 = std::time::Instant::now();
            let ac = pipeline::run_pagerank_accelerated(&rt, &dg5, 1e-5, &cfg).unwrap();
            let t_xla = t0.elapsed();
            bs::row("scalar local", &sc.metrics);
            bs::row("XLA local", &ac.metrics);
            println!(
                "  host wallclock: scalar {:.3}s, xla {:.3}s (interpret-mode CPU; \
                 the XLA path is the TPU-offload demonstration, not a CPU speedup)",
                t_scalar.as_secs_f64(),
                t_xla.as_secs_f64()
            );
        } else {
            println!("  (skipped: a partition exceeds the 256 tile)");
        }
    } else {
        println!("  (skipped: run `make artifacts` first)");
    }

    println!("\nablation done");
}
