//! Figure 4 — PageRank convergence: iterations and execution time vs
//! tolerance Δ ∈ {1e-2..1e-6} on (a,b) web-Google @12 partitions and
//! (c,d) uk-2002 @72 partitions, for Hama / AM-Hama / GraphHP.
//!
//! Paper shape: GraphHP needs considerably fewer iterations; the gap
//! WIDENS as Δ shrinks; AM-Hama sits between but much closer to Hama in
//! iterations while beating it in time.

use graphhp::algorithms::IncrementalPageRank;
use graphhp::bench_support as bs;
use graphhp::engine::EngineKind;
use graphhp::graph::generators;

fn sweep(gname: &str, g: &graphhp::graph::Graph, parts: usize) {
    println!(
        "\n-- {gname}: {} vertices, {} edges, {parts} partitions",
        g.num_vertices(),
        g.num_edges()
    );
    let mut runner = bs::runner(g, parts);
    println!("  Δ      |       Hama        |      AM-Hama      |      GraphHP");
    println!("         |    I         T    |    I         T    |    I         T");
    let tols = [1e-2f64, 1e-3, 1e-4, 1e-5, 1e-6];
    let kinds = [EngineKind::Hama, EngineKind::AmHama, EngineKind::GraphHP];
    let (mut h_iters, mut p_iters) = (vec![], vec![]);
    for (i, &tol) in tols.iter().enumerate() {
        let prog = IncrementalPageRank { tolerance: tol };
        let results = runner.compare(&kinds, &prog);
        let [h, a, p] = &results[..] else { unreachable!() };
        println!(
            "  1e-{}   | {:>5} {:>9.3}s | {:>5} {:>9.3}s | {:>5} {:>9.3}s",
            i + 2,
            h.1.metrics.global_iterations,
            h.1.metrics.elapsed.as_secs_f64(),
            a.1.metrics.global_iterations,
            a.1.metrics.elapsed.as_secs_f64(),
            p.1.metrics.global_iterations,
            p.1.metrics.elapsed.as_secs_f64(),
        );
        h_iters.push(h.1.metrics.global_iterations);
        p_iters.push(p.1.metrics.global_iterations);
    }
    let h_growth = h_iters.last().unwrap() - h_iters[0];
    let p_growth = p_iters.last().unwrap() - p_iters[0];
    println!(
        "  iteration growth 1e-2 -> 1e-6: Hama +{h_growth}, GraphHP +{p_growth}; \
         Hama/GraphHP ratio {:.1}x -> {:.1}x",
        h_iters[0] as f64 / p_iters[0].max(1) as f64,
        *h_iters.last().unwrap() as f64 / (*p_iters.last().unwrap()).max(1) as f64,
    );
    // paper: "as the tolerance threshold becomes smaller, the number of
    // required iterations increases more rapidly on Hama than on GraphHP"
    println!(
        "  paper shape (Hama iterations grow faster as Δ shrinks): {}",
        if h_growth > p_growth { "✓" } else { "✗" }
    );
}

fn main() {
    bs::header(
        "Figure 4: PageRank convergence vs tolerance",
        "paper §7.3, Figure 4 (a,b) Web-Google 12 parts, (c,d) uk-2002 72 parts",
    );
    bs::scale_note(
        "web-Google 916k vertices / uk-2002 18.5M vertices",
        "synthetic web graphs (powerlaw + host locality) at two scales",
    );
    let small = generators::powerlaw(30_000, 5, 7);
    sweep("web-Google stand-in", &small, 12);
    let large = generators::powerlaw(90_000, 6, 8);
    sweep("uk-2002 stand-in", &large, 72);
    println!("\nfig4 done");
}
