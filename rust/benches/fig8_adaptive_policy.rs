//! Figure 8 (repo-original) — static vs adaptive hybrid policy:
//! supersteps / network messages of GraphHP under the hand-tuned
//! `HybridPolicy::Static` defaults against the telemetry-driven
//! `HybridPolicy::Adaptive` scheduler, on the two workloads where the
//! local phase matters most:
//!
//! - PageRank (Δ=1e-4) on the fig5 web workload — shrinking-frontier
//!   incremental computation, the cap-growth regime;
//! - SSSP on the road-network workload (fig3 setup) — high-diameter
//!   wavefront, the regime where boundary-dominated partitions appear.
//!
//! Also reported: a tight-cap regime — static pinned to 2
//! pseudo-supersteps vs adaptive *starting* at 2 — where the static
//! policy burns a carryover iteration per barrier while the adaptive
//! controller grows its per-partition caps back out.
//!
//! Shape to expect: adaptive ≈ static on iterations/messages at the
//! defaults (the defaults are already near-optimal for these
//! workloads — the scheduler must not regress them), and adaptive
//! clearly fewer global iterations in the tight-cap regime. The trace
//! columns (pseudo-supersteps, carryovers, skipped local phases) show
//! *why* each run behaved as it did.

use graphhp::algorithms::{IncrementalPageRank, Sssp};
use graphhp::bench_support as bs;
use graphhp::engine::{
    AdaptiveConfig, EngineKind, HybridPolicy, RunResult, Runner, VertexProgram,
};
use graphhp::graph::generators;
use graphhp::graph::Graph;

fn policy_row<V>(label: &str, r: &RunResult<V>) {
    bs::row(label, &r.metrics);
    println!(
        "    trace: pseudo-supersteps={} carryovers={} skipped-local-phases={} supersteps-total={}",
        r.trace.pseudo_supersteps(),
        r.trace.carryover_events(),
        r.trace.skipped_local_phases(),
        r.metrics.supersteps_total,
    );
}

/// Element-wise agreement check with a per-workload comparator —
/// confluent programs (SSSP) demand bit equality, while PageRank's
/// tolerance-truncated f64 sums legitimately differ in the last bits
/// when the phase grouping changes.
fn assert_agree<V>(label: &str, a: &[V], b: &[V], agree: &impl Fn(&V, &V) -> bool) {
    assert_eq!(a.len(), b.len(), "{label}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(agree(x, y), "{label}: v{i} disagrees between policies");
    }
}

fn compare<P: VertexProgram>(
    workload: &str,
    g: &Graph,
    parts: usize,
    prog: &P,
    agree: impl Fn(&P::V, &P::V) -> bool,
) {
    println!("\n-- {workload}: {} vertices, {parts} partitions", g.num_vertices());
    // partition once, outside every timed region; every policy variant
    // executes over the identical distributed view
    let dg = bs::dist(g, parts);

    let s = Runner::from_dist(&dg).engine(EngineKind::GraphHP).run(prog);
    policy_row("static", &s);

    let a = Runner::from_dist(&dg)
        .engine(EngineKind::GraphHP)
        .hybrid_policy(HybridPolicy::adaptive())
        .run(prog);
    policy_row("adaptive", &a);

    // diagnostic shape checks (printed ✓/✗): the scheduler should track
    // the near-optimal static defaults within a small margin
    bs::expect_less(
        "adaptive supersteps within 1.25x of static",
        a.metrics.supersteps_total,
        s.metrics.supersteps_total * 5 / 4 + 2,
    );
    bs::expect_less(
        "adaptive messages within 1.25x of static",
        a.metrics.network_messages,
        s.metrics.network_messages * 5 / 4 + 2,
    );

    // tight-cap regime: both policies start with a pseudo-superstep cap
    // of 2, but the static one is stuck there (`Limits`) while the
    // adaptive controller grows its per-partition caps back out of the
    // carryover thrash — the re-fit the scheduler exists for
    let st = Runner::from_dist(&dg)
        .engine(EngineKind::GraphHP)
        .max_pseudo_supersteps(2)
        .run(prog);
    policy_row("static cap=2", &st);

    let at = Runner::from_dist(&dg)
        .engine(EngineKind::GraphHP)
        .hybrid_policy(HybridPolicy::Adaptive(AdaptiveConfig {
            initial_cap: 2,
            ..Default::default()
        }))
        .run(prog);
    policy_row("adaptive from cap=2", &at);

    bs::expect_less(
        "adaptive-from-2 iterations < static-2 iterations",
        at.metrics.global_iterations,
        st.metrics.global_iterations,
    );

    assert_agree(workload, &s.values, &a.values, &agree);
    assert_agree(workload, &s.values, &st.values, &agree);
    assert_agree(workload, &s.values, &at.values, &agree);
}

fn main() {
    bs::header(
        "Figure 8: static vs adaptive hybrid policy (GraphHP)",
        "repo-original experiment on the fig5 PageRank and fig3 SSSP workloads",
    );
    bs::scale_note(
        "hand-tuned HybridPolicy knobs fixed per run",
        "HybridPolicy::Adaptive re-fits cap / boundary participation / \
         local-phase skip per partition per iteration from the RunTrace",
    );

    let web = generators::powerlaw(30_000, 5, 7);
    compare(
        "PageRank Δ=1e-4, web graph",
        &web,
        12,
        &IncrementalPageRank { tolerance: 1e-4 },
        // tolerance-truncated accumulation: relative agreement
        |x, y| (x - y).abs() <= 1e-3 * x.abs().max(1.0),
    );

    let road = generators::road(120, 120, 1);
    compare(
        "SSSP, road network",
        &road,
        12,
        &Sssp { source: 0 },
        // min-fixed-point: bit-exact across every policy
        |x, y| x.to_bits() == y.to_bits(),
    );

    println!("\nfig8 done");
}
