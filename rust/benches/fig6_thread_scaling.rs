//! Figure 6 (repo-original) — worker-thread scaling: host wall-clock of
//! one engine run vs `Parallelism::Threads(n)` on the fig5 PageRank
//! workload, for Hama and GraphHP.
//!
//! What the paper could not show: its testbed pinned one worker per
//! machine, so compute parallelism was fixed. With the threaded worker
//! runtime the same partitioned run uses 1..N OS threads — measured
//! compute should drop as threads are added (until partitions/cores run
//! out) while every result stays bit-for-bit identical to sequential.
//!
//! Reported per thread count: host wall-clock of the whole run (the
//! quantity that scales) and the simulated metrics' measured-compute
//! component (per-worker average; roughly flat — per-worker work does
//! not change, only its overlap does).

use std::time::Instant;

use graphhp::algorithms::IncrementalPageRank;
use graphhp::bench_support as bs;
use graphhp::engine::{EngineKind, Parallelism};
use graphhp::graph::generators;

fn main() {
    bs::header(
        "Figure 6: worker-thread scaling (PageRank, Δ=1e-4)",
        "repo-original experiment on the fig5 web workload (paper §7.3 setup)",
    );
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    bs::scale_note(
        "one worker per machine (fixed parallelism)",
        &format!("one worker per partition on 1..{cores} OS threads, one host"),
    );

    // GRAPHHP_BENCH_SCALE=small|medium|large — CI keeps the historical
    // small workload; large is the 10M+-edge bandwidth-bound regime.
    let scale = bs::bench_scale();
    let g = scale.pick(
        generators::powerlaw(30_000, 5, 7),
        generators::web(1 << 18, 8, 7),
        generators::rmat(20, 16, 7),
    );
    let parts = 12;
    println!("scale={} ({} vertices, {} edges)", scale.name(), g.num_vertices(), g.num_edges());
    let prog = IncrementalPageRank { tolerance: 1e-4 };

    let mut threads = vec![1usize];
    while threads.last().unwrap() * 2 <= cores {
        threads.push(threads.last().unwrap() * 2);
    }

    for kind in [EngineKind::Hama, EngineKind::GraphHP] {
        println!("\n-- {kind}: {} vertices, {parts} partitions", g.num_vertices());
        let mut runner = bs::runner(&g, parts).engine(kind);
        let _ = runner.dist(); // build the view outside the timed region

        runner = runner.parallelism(Parallelism::Sequential);
        let t0 = Instant::now();
        let base = runner.run(&prog);
        let seq_wall = t0.elapsed();
        println!(
            "  sequential        wall {:>8.3}s   {}",
            seq_wall.as_secs_f64(),
            base.metrics.summary()
        );

        let (mut xs, mut walls, mut computes) = (vec![], vec![], vec![]);
        for &t in &threads {
            runner = runner.parallelism(Parallelism::Threads(t));
            let t0 = Instant::now();
            let r = runner.run(&prog);
            let wall = t0.elapsed();
            let identical = r.values == base.values
                && r.metrics.network_messages == base.metrics.network_messages
                && r.metrics.global_iterations == base.metrics.global_iterations;
            println!(
                "  threads={t:<3}       wall {:>8.3}s   compute/worker {:>8.3}s   {}",
                wall.as_secs_f64(),
                r.metrics.compute_time.as_secs_f64(),
                if identical { "≡ sequential ✓" } else { "RESULTS DIVERGED ✗" }
            );
            xs.push(t);
            walls.push(wall.as_secs_f64());
            computes.push(r.metrics.compute_time.as_secs_f64());
        }
        // opt-in work-stealing: intra-sweep chunked parallelism —
        // run-to-run deterministic, PageRank values within f64 epsilon
        // of sequential (tests/layout_equivalence.rs pins the contract)
        for &t in &threads {
            runner = runner.parallelism(Parallelism::WorkStealing(t));
            let t0 = Instant::now();
            let r = runner.run(&prog);
            let wall = t0.elapsed();
            let close = r
                .values
                .iter()
                .zip(&base.values)
                .all(|(a, b)| (a - b).abs() <= 1e-6 * b.abs().max(1.0));
            println!(
                "  steal={t:<3}         wall {:>8.3}s   compute/worker {:>8.3}s   {}",
                wall.as_secs_f64(),
                r.metrics.compute_time.as_secs_f64(),
                if close { "≈ sequential (ε) ✓" } else { "RESULTS DIVERGED ✗" }
            );
        }
        bs::series(&format!("{kind} wall(s)"), &xs, &walls);
        bs::series(&format!("{kind} compute(s)"), &xs, &computes);
        if xs.len() >= 2 {
            if let (Some(&w1), Some(&wn)) = (walls.first(), walls.last()) {
                bs::expect_less(
                    &format!("{kind}: wall at {} threads < wall at 1 thread", xs[xs.len() - 1]),
                    (wn * 1e6) as u64,
                    (w1 * 1e6) as u64,
                );
            }
        } else {
            println!("  (single core: scaling comparison skipped)");
        }
    }
    println!("\nfig6 done");
}
