//! Table 4 — GraphHP vs Giraph++ vs GraphLab (sync/async) on PageRank,
//! web-Google @12 partitions, Δ ∈ {1e-3, 1e-4}: I / M(k) / T.
//!
//! Paper values @1e-3: GraphLab(Sync) 92/—/43.0s, GraphLab(Async)
//! —/—/82.4s, Giraph++ 46/450k/13.9s, GraphHP 32/125k/11.2s.
//! Shape: GraphHP < Giraph++ < GraphLab sync on iterations; GraphHP
//! fewest messages; async GraphLab slowest (locking overhead).
//!
//! One Runner session drives all four programming models: vertex-centric
//! (GraphHP), graph-centric (Giraph++), and pull/GAS (both GraphLabs) —
//! the cross-platform comparison is exactly what the session API is for.

use graphhp::algorithms::pagerank::{GasPageRank, GiraphPPPageRank, IncrementalPageRank};
use graphhp::bench_support as bs;
use graphhp::engine::EngineKind;
use graphhp::graph::generators;

fn main() {
    bs::header(
        "Table 4: GraphHP vs Giraph++ and GraphLab (PageRank)",
        "paper §7.5, Table 4 (Web-Google, 12 partitions)",
    );
    let g = generators::powerlaw(30_000, 5, 7);
    bs::scale_note(
        "web-Google 916k vertices, 12 partitions, 12-machine cluster",
        &format!(
            "web stand-in {} vertices, {} edges, 12 partitions",
            g.num_vertices(),
            g.num_edges()
        ),
    );
    let mut runner = bs::runner(&g, 12);

    for (label, tol) in [("1e-3", 1e-3f64), ("1e-4", 1e-4f64)] {
        println!("\n-- tolerance {label}");
        let s = runner.run_gas_on(EngineKind::GraphLabSync, &GasPageRank { tolerance: tol });
        println!(
            "  GraphLab(Sync)   I={:<6} M=—           T={:>8.3}s",
            s.metrics.global_iterations,
            s.metrics.elapsed.as_secs_f64()
        );
        let a = runner.run_gas_on(EngineKind::GraphLabAsync, &GasPageRank { tolerance: tol });
        println!(
            "  GraphLab(Async)  I=—      M=—           T={:>8.3}s   (updates={})",
            a.metrics.elapsed.as_secs_f64(),
            a.metrics.vertex_computations
        );
        let gpp = runner.run_partition(&GiraphPPPageRank { tolerance: tol });
        bs::row("Giraph++", &gpp.metrics);
        let p = runner.run_on(EngineKind::GraphHP, &IncrementalPageRank { tolerance: tol });
        bs::row("GraphHP", &p.metrics);

        println!("  paper @{label}: GraphLab(Sync) 92—106 I; Giraph++ 46—54 I / 450—600k M;");
        println!("                GraphHP 32—40 I / 125—158k M — GraphHP wins every metric");
        println!("  shape checks:");
        bs::expect_less(
            "GraphHP iters < Giraph++ iters",
            p.metrics.global_iterations,
            gpp.metrics.global_iterations,
        );
        bs::expect_less(
            "Giraph++ iters < GraphLab sync iters",
            gpp.metrics.global_iterations,
            s.metrics.global_iterations,
        );
        bs::expect_less(
            "GraphHP msgs < Giraph++ msgs",
            p.metrics.network_messages,
            gpp.metrics.network_messages,
        );
        bs::expect_less(
            "GraphLab sync T < GraphLab async T",
            s.metrics.elapsed.as_micros() as u64,
            a.metrics.elapsed.as_micros() as u64,
        );
        bs::expect_less(
            "GraphHP T < GraphLab sync T",
            p.metrics.elapsed.as_micros() as u64,
            s.metrics.elapsed.as_micros() as u64,
        );
    }
    println!("\ntable4 done");
}
