//! Figure 9 (repo-local) — sweep hot-path: vertex sweeps/sec and
//! steady-state heap allocations per superstep on the Figure 5 PageRank
//! workload, sequential and threaded.
//!
//! Motivates the pooled-worklist + resolved-route + SoA-edge rebuild of
//! the per-vertex sweep loop: the distributed-graph-system surveys
//! (Ammar & Özsu 2018; McCune et al. 2015) find data-structure and
//! per-message bookkeeping costs dominating exactly this path. Before
//! the rebuild every sweep `collect()`ed a fresh node-based
//! `BTreeSet` worklist and did a random global-location lookup per
//! message; now the worklist, send buffer and message arena are all
//! pooled in worker scratch and routes ride pre-resolved on the sends.
//!
//! Steady-state cost is measured **differentially**: the same workload
//! runs at two superstep budgets and the allocation delta is divided by
//! the superstep delta, so all warmup/setup allocations (graph build,
//! arena growth to high-water, outbox batch buffers) cancel out.
//! Expect ~0 sweep-path allocations: the small residual per superstep
//! is the barrier's telemetry record (one `StepTrace` + the
//! worker-output vector per barrier), not the sweep loop; per 1k vertex
//! sweeps it rounds to zero. Threaded mode additionally pays the scoped
//! worker-thread spawns at every superstep — that is the `run_workers`
//! launch cost, also not the sweep loop.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use graphhp::algorithms::ClassicPageRank;
use graphhp::bench_support as bs;
use graphhp::engine::{EngineConfig, EngineKind, Metrics, Parallelism};
use graphhp::graph::generators;

/// System allocator wrapped with an allocation counter (no external
/// dependencies — the vendor set has no profiling crates).
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

struct Sample {
    allocs: u64,
    wall: std::time::Duration,
    metrics: Metrics,
}

/// One measured run of ClassicPageRank for `supersteps` supersteps.
fn sample(
    g: &graphhp::graph::Graph,
    parts: usize,
    kind: EngineKind,
    par: Parallelism,
    supersteps: u64,
) -> Sample {
    let prog = ClassicPageRank { supersteps };
    let mut cfg = EngineConfig::default();
    cfg.parallelism = par;
    // keep GraphHP's local phases short so the fixed-superstep workload
    // stays comparable across engines
    cfg.limits.max_pseudo_supersteps = 2;
    let mut runner = bs::runner(g, parts).config(cfg);
    runner.dist(); // build the distributed view outside the measurement
    let a0 = ALLOC_CALLS.load(Ordering::Relaxed);
    let t0 = std::time::Instant::now();
    let r = runner.run_on(kind, &prog);
    let wall = t0.elapsed();
    let a1 = ALLOC_CALLS.load(Ordering::Relaxed);
    Sample { allocs: a1 - a0, wall, metrics: r.metrics }
}

fn bench_engine(g: &graphhp::graph::Graph, parts: usize, kind: EngineKind, par: Parallelism) {
    let mode = match par {
        Parallelism::Sequential => "sequential".to_string(),
        Parallelism::Threads(n) => format!("threads={n}"),
        Parallelism::WorkStealing(n) => format!("steal={n}"),
    };
    let (short_steps, long_steps) = (10u64, 30u64);
    let short = sample(g, parts, kind, par, short_steps);
    let long = sample(g, parts, kind, par, long_steps);

    let sweeps = long.metrics.vertex_computations;
    let rate = sweeps as f64 / long.wall.as_secs_f64().max(1e-9);
    let d_steps = long.metrics.supersteps_total.saturating_sub(short.metrics.supersteps_total);
    let d_allocs = long.allocs.saturating_sub(short.allocs);
    let d_sweeps = long
        .metrics
        .vertex_computations
        .saturating_sub(short.metrics.vertex_computations);
    let per_step = d_allocs as f64 / d_steps.max(1) as f64;
    let per_1k_sweeps = d_allocs as f64 * 1000.0 / d_sweeps.max(1) as f64;
    println!(
        "  {:<16} {:<10} sweeps={:<10} {:>12.0} sweeps/s   steady allocs: {:>6.1}/superstep \
         {:>6.2}/1k sweeps  (Δallocs={} over Δsupersteps={})",
        kind, mode, sweeps, rate, per_step, per_1k_sweeps, d_allocs, d_steps,
    );
}

fn main() {
    bs::header(
        "Figure 9 (repo): sweep hot path — vertex sweeps/sec, steady-state allocations",
        "sweep-loop cost motivation (Ammar & Özsu 2018; McCune 2015 §5)",
    );
    bs::scale_note(
        "web-Google (fig5 PageRank workload)",
        "synthetic web graph at the fig5 small scale, ClassicPageRank at two \
         superstep budgets (differential steady-state measurement)",
    );
    // GRAPHHP_BENCH_SCALE=small|medium|large — CI keeps the historical
    // small workload; large is the 10M+-edge bandwidth-bound regime.
    let scale = bs::bench_scale();
    let parts = 12usize;
    let g = scale.pick(
        generators::powerlaw(20_000, 5, 7),
        generators::web(1 << 18, 8, 7),
        generators::rmat(20, 16, 7),
    );
    println!(
        "-- scale={} {} vertices, {} edges, {parts} partitions\n",
        scale.name(),
        g.num_vertices(),
        g.num_edges()
    );
    for par in
        [Parallelism::Sequential, Parallelism::Threads(4), Parallelism::WorkStealing(4)]
    {
        for kind in [EngineKind::Hama, EngineKind::AmHama, EngineKind::GraphHP] {
            bench_engine(&g, parts, kind, par);
        }
        println!();
    }
    println!(
        "note: sequential residuals are the per-barrier telemetry record \
         (StepTrace + worker-output vector), not sweep-loop work; threaded \
         residuals add the per-superstep scoped thread spawns of run_workers."
    );
    println!("\nfig9 done");
}
