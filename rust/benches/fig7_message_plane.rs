//! Figure 7 (repo-local) — message-plane throughput: messages/sec,
//! wire bytes, and heap allocations per engine on the Figure 5 PageRank
//! workload.
//!
//! Motivates the flat pooled message plane: the vertex-centric surveys
//! (McCune et al. 2015; Ammar & Özsu 2018) identify message-buffer
//! management as the dominant memory/throughput cost of BSP systems.
//! `MsgStore` recycles arena slots across sweeps and `Outbox` reuses its
//! per-destination-partition batch buffers across supersteps, so the
//! allocations-per-1k-messages column should stay in the low single
//! digits at steady state (startup structures amortize away as the
//! workload grows).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use graphhp::algorithms::IncrementalPageRank;
use graphhp::bench_support as bs;
use graphhp::engine::{EngineKind, Parallelism};
use graphhp::graph::generators;

/// System allocator wrapped with allocation counters (no external
/// dependencies — the vendor set has no profiling crates).
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn snapshot() -> (u64, u64) {
    (ALLOC_CALLS.load(Ordering::Relaxed), ALLOC_BYTES.load(Ordering::Relaxed))
}

fn bench_engine(g: &graphhp::graph::Graph, parts: usize, kind: EngineKind) {
    let prog = IncrementalPageRank { tolerance: 1e-4 };
    // sequential workers: allocation counts attribute to one engine run,
    // not to thread-pool noise (results are bit-identical either way)
    let mut runner = bs::runner(g, parts).parallelism(Parallelism::Sequential);
    runner.dist(); // build the distributed view outside the measurement
    let (a0, b0) = snapshot();
    let t0 = std::time::Instant::now();
    let r = runner.run_on(kind, &prog);
    let wall = t0.elapsed();
    let (a1, b1) = snapshot();

    let m = &r.metrics;
    let delivered = m.network_messages + m.local_messages;
    let rate = delivered as f64 / wall.as_secs_f64().max(1e-9);
    let allocs = a1 - a0;
    let alloc_kb = (b1 - b0) / 1024;
    let per_1k = allocs as f64 * 1000.0 / (delivered.max(1)) as f64;
    println!(
        "  {:<16} msgs={:<10} (net {:<9} local {:<10}) bytes={:<11} {:>10.0} msg/s  \
         allocs={:<9} ({:>7} KiB, {:>6.1}/1k msg)",
        kind,
        delivered,
        m.network_messages,
        m.local_messages,
        m.network_bytes,
        rate,
        allocs,
        alloc_kb,
        per_1k,
    );
}

fn main() {
    bs::header(
        "Figure 7 (repo): message-plane throughput — msgs/sec, bytes, allocations",
        "message-plane cost motivation (McCune 2015 §5.2; Ammar & Özsu 2018)",
    );
    bs::scale_note(
        "web-Google (fig5 PageRank workload)",
        "synthetic web graph at the fig5 small scale",
    );
    let workloads = [
        ("warmup", 5_000usize, 5usize, 7u64, 12usize),
        ("web-Google stand-in", 30_000, 5, 7, 12),
    ];
    for (label, n, deg, seed, parts) in workloads {
        let g = generators::powerlaw(n, deg, seed);
        println!(
            "\n-- {label}: {} vertices, {} edges, {parts} partitions",
            g.num_vertices(),
            g.num_edges()
        );
        for kind in [EngineKind::Hama, EngineKind::AmHama, EngineKind::GraphHP] {
            bench_engine(&g, parts, kind);
        }
    }
    println!("\nfig7 done");
}
