//! Table 3 — bipartite matching: I / M(mil) / T on cit-patents (18
//! partitions) and delaunay_n24 (48 partitions) for Hama / AM-Hama /
//! GraphHP.
//!
//! Paper values: cit-patents — Hama 23/41.5M/42.9s, AM-Hama 20/4.4M/
//! 21.6s, GraphHP 7/3.0M/13.0s; delaunay_n24 — Hama 15/126M/83.3s,
//! AM-Hama 15/0.16M/34.9s, GraphHP 5/0.10M/15.9s. Shape: ~3× fewer
//! iterations and ~3× faster for GraphHP; AM-Hama slashes messages but
//! barely iterations.
//!
//! Dataset notes: cit-patents is bipartite-ized by the two-sided random
//! generator; delaunay is bipartite-ized by vertex-id parity (keep only
//! even↔odd edges), preserving its planar local structure.

use graphhp::algorithms::bipartite_matching::{validate_matching, BipartiteMatching};
use graphhp::bench_support as bs;
use graphhp::engine::EngineKind;
use graphhp::graph::{generators, Graph, GraphBuilder};

/// Bipartite-ize a graph by id parity: left = even ids (relabeled
/// 0..nl), right = odd ids (relabeled nl..), keeping even-odd edges in
/// both directions.
fn bipartite_by_parity(g: &Graph) -> (Graph, u32) {
    let n = g.num_vertices();
    let nl = n.div_ceil(2);
    let relabel = |v: u32| -> u32 {
        if v % 2 == 0 {
            v / 2
        } else {
            nl as u32 + v / 2
        }
    };
    let mut b = GraphBuilder::with_capacity(n, g.num_edges());
    for v in 0..n as u32 {
        for &t in g.out_edges(v).0 {
            if v % 2 != t % 2 {
                b.add_edge(relabel(v), relabel(t), 1.0);
            }
        }
    }
    b.dedup();
    (b.build(), nl as u32)
}

fn run_one(gname: &str, g: &Graph, nl: u32, parts: usize, paper: [&str; 3]) {
    println!(
        "\n-- {gname}: {} vertices, {} edges, {parts} partitions",
        g.num_vertices(),
        g.num_edges()
    );
    let mut runner = bs::runner(g, parts);
    let prog = BipartiteMatching { num_left: nl };

    let h = runner.run_on(EngineKind::Hama, &prog);
    let sh = validate_matching(g, nl, &h.values).expect("hama matching");
    bs::row("Hama", &h.metrics);
    println!("{:>66}", paper[0]);
    let a = runner.run_on(EngineKind::AmHama, &prog);
    let sa = validate_matching(g, nl, &a.values).expect("am matching");
    bs::row("AM-Hama", &a.metrics);
    println!("{:>66}", paper[1]);
    let p = runner.run_on(EngineKind::GraphHP, &prog);
    let sp = validate_matching(g, nl, &p.values).expect("hp matching");
    bs::row("GraphHP", &p.metrics);
    println!("{:>66}", paper[2]);
    println!("  matching sizes: hama {sh}, am {sa}, graphhp {sp} (all valid + maximal)");

    println!("  shape checks:");
    // Our handshake adds CANCEL withdrawals (see algorithms/bipartite_
    // matching.rs), which shortens contention chains for EVERY engine —
    // so the absolute iteration counts are lower than the paper's and
    // the Hama/GraphHP ratio is smaller (the paper's deny-retry cycles
    // are what GraphHP collapsed so dramatically). Ordering still holds.
    bs::expect_less(
        "GraphHP iters < Hama iters",
        p.metrics.global_iterations,
        h.metrics.global_iterations,
    );
    bs::expect_less(
        "AM-Hama msgs < Hama msgs",
        a.metrics.network_messages,
        h.metrics.network_messages,
    );
    bs::expect_less(
        "GraphHP time < Hama time",
        p.metrics.elapsed.as_micros() as u64,
        h.metrics.elapsed.as_micros() as u64,
    );
}

fn main() {
    bs::header(
        "Table 3: Bipartite Matching",
        "paper §7.4, Table 3 (cit-patents 18 parts, delaunay_n24 48 parts)",
    );
    bs::scale_note(
        "cit-patents 3.8M vertices / delaunay_n24 16.8M vertices",
        "two-sided random graph + parity-bipartite-ized delaunay lattice",
    );

    let g1 = generators::bipartite(30_000, 30_000, 4, 5);
    run_one(
        "cit-patents stand-in",
        &g1,
        30_000,
        18,
        [
            "paper: 23 / 41.5M / 42.9s",
            "paper: 20 /  4.4M / 21.6s",
            "paper:  7 /  3.0M / 13.0s",
        ],
    );

    let (g2, nl2) = bipartite_by_parity(&generators::delaunay_like(180, 180, 6));
    run_one(
        "delaunay_n24 stand-in",
        &g2,
        nl2,
        48,
        [
            "paper: 15 / 126.6M / 83.3s",
            "paper: 15 /   0.2M / 34.9s",
            "paper:  5 /   0.1M / 15.9s",
        ],
    );
    println!("\ntable3 done");
}
