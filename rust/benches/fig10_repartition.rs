//! Figure 10 (repo-original) — online repartitioning on an
//! adversarially-skewed RMAT graph: round-robin (hash) partitioning vs
//! the built-in METIS vs METIS + telemetry-driven online migration.
//!
//! RMAT's power-law skew is the worst case for static partitioners:
//! hub vertices drag cross-partition edges wherever they land, and a
//! partition decided before the first superstep cannot react to where
//! the message traffic actually concentrates. The online repartitioner
//! folds each barrier's deterministic trace counters through the
//! `MigrationPlanner` and walks hot boundary vertices off the most
//! network-bound partition, one routing epoch at a time.
//!
//! Reported per configuration: the paper-style metric row, the edge cut
//! before/after, and — for the migrating run — the edge-cut trajectory
//! per routing epoch plus sweeps/sec per epoch. The trajectory is
//! reconstructed by replaying the planner over the run's own trace
//! (plans are pure functions of trace counters, so the replay is
//! exact — the bench asserts the replayed move counts match the trace).
//!
//! Shape to expect: hash starts ~3-4x worse on edge cut than METIS and
//! stays there; METIS+migration starts at the METIS cut and ratchets it
//! down across epochs while sweeps/sec holds or improves as network
//! traffic shifts local.

use graphhp::algorithms::IncrementalPageRank;
use graphhp::bench_support as bs;
use graphhp::engine::{
    EngineKind, MigrationPlanner, Parallelism, RepartitionConfig, RunTrace, Runner,
};
use graphhp::graph::{generators, DistGraph};
use graphhp::partition::{hash_partition, metis_partition, MetisConfig};

/// Replay the planner over a finished run's trace, recording the edge
/// cut at the end of every routing epoch. Returns (cuts, moves).
fn edge_cut_trajectory(
    dg0: &DistGraph,
    trace: &RunTrace,
    rc: RepartitionConfig,
) -> (Vec<usize>, u64) {
    let planner = MigrationPlanner::new(rc);
    let mut cuts = vec![dg0.edge_cut()];
    let mut moved = 0u64;
    let mut cur: Option<DistGraph> = None;
    for (i, step) in trace.steps.iter().enumerate() {
        let base = cur.as_ref().unwrap_or(dg0);
        let plan = planner.plan(base, step, i as u64);
        match plan {
            Some(p) => {
                assert_eq!(
                    p.len() as u64,
                    step.migrated,
                    "replayed plan at barrier {i} diverged from the trace"
                );
                let next = base.apply_migration(&p);
                moved += p.len() as u64;
                cuts.push(next.edge_cut());
                cur = Some(next);
            }
            None => assert_eq!(step.migrated, 0, "trace moved at barrier {i}, replay did not"),
        }
    }
    (cuts, moved)
}

/// Sweeps/sec per routing epoch: vertex sweeps folded over the steps of
/// each epoch, divided by their (reporting-only) compute time.
fn sweeps_per_sec_by_epoch(trace: &RunTrace) -> Vec<(u64, f64)> {
    let mut out: Vec<(u64, u64, u64)> = Vec::new(); // (epoch, sweeps, us)
    for s in &trace.steps {
        let sweeps: u64 = s.partitions.iter().map(|p| p.frontier).sum();
        let us: u64 = s.partitions.iter().map(|p| p.compute_us).sum();
        match out.last_mut() {
            Some(e) if e.0 == s.routing_epoch => {
                e.1 += sweeps;
                e.2 += us;
            }
            _ => out.push((s.routing_epoch, sweeps, us)),
        }
    }
    out.into_iter()
        .map(|(ep, sw, us)| (ep, if us == 0 { 0.0 } else { sw as f64 / (us as f64 * 1e-6) }))
        .collect()
}

fn main() {
    let scale = bs::bench_scale();
    bs::header(
        "fig10: online repartitioning on skewed RMAT (repo-original)",
        "ISSUE 8 — routing epochs + telemetry-driven migration (extends §7's partitioning study)",
    );
    let (rmat_scale, ef, parts) = scale.pick((10, 8, 4), (13, 10, 8), (16, 12, 12));
    let g = generators::rmat(rmat_scale, ef, 42);
    bs::scale_note(
        "billion-edge web graphs on a 16-node cluster",
        &format!(
            "RMAT scale {rmat_scale} ({} vertices, {} edges), {parts} partitions [{}]",
            g.num_vertices(),
            g.num_edges(),
            scale.name()
        ),
    );
    let prog = IncrementalPageRank { tolerance: 1e-4 };
    let rc = RepartitionConfig::every_barrier();

    // -- round-robin (hash): the locality-free baseline ------------------
    let hash_dg = DistGraph::new(&g, &hash_partition(&g, parts), parts);
    let r = Runner::from_dist(&hash_dg)
        .engine(EngineKind::GraphHP)
        .parallelism(Parallelism::Sequential)
        .run(&prog);
    bs::row("round-robin", &r.metrics);
    println!("    edge cut: {} (static)", hash_dg.edge_cut());

    // -- METIS static ----------------------------------------------------
    let metis_dg =
        DistGraph::new(&g, &metis_partition(&g, parts, &MetisConfig::default()), parts);
    let r = Runner::from_dist(&metis_dg)
        .engine(EngineKind::GraphHP)
        .parallelism(Parallelism::Sequential)
        .run(&prog);
    bs::row("metis", &r.metrics);
    println!("    edge cut: {} (static)", metis_dg.edge_cut());

    // -- METIS + online migration ----------------------------------------
    let r = Runner::from_dist(&metis_dg)
        .engine(EngineKind::GraphHP)
        .parallelism(Parallelism::Sequential)
        .repartition(rc)
        .run(&prog);
    bs::row("metis+migration", &r.metrics);
    let (cuts, moved) = edge_cut_trajectory(&metis_dg, &r.trace, rc);
    assert_eq!(moved, r.trace.vertices_migrated(), "replay covered every applied plan");
    println!("    vertices migrated: {moved} across {} epochs", cuts.len() - 1);
    let epochs: Vec<usize> = (0..cuts.len()).collect();
    bs::series(
        "edge-cut/epoch",
        &epochs,
        &cuts.iter().map(|&c| c as f64).collect::<Vec<_>>(),
    );
    let rates = sweeps_per_sec_by_epoch(&r.trace);
    bs::series(
        "sweeps-per-sec/epoch",
        &rates.iter().map(|&(e, _)| e as usize).collect::<Vec<_>>(),
        &rates.iter().map(|&(_, r)| r).collect::<Vec<_>>(),
    );
    if let (Some(&first), Some(&last)) = (cuts.first(), cuts.last()) {
        if last < first {
            println!("  ✓ migration reduced the edge cut: {first} -> {last}");
        } else {
            println!("  ✗ edge cut did not improve ({first} -> {last}) — planner found no gainful moves");
        }
    }
}
