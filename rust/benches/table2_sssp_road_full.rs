//! Table 2 — SSSP on USA-Road-Full at 108 partitions: I / M(mil) / T for
//! Hama, AM-Hama, GraphHP.
//!
//! Paper values:  Hama 10671 / 43,829M / 17912s; AM-Hama 10593 / 387M /
//! 5792s; GraphHP 451 / 71M / 2155s. Shape: GraphHP ~24× fewer
//! iterations than both, AM-Hama slashes messages but not iterations,
//! GraphHP fastest.

use graphhp::algorithms::{oracle, Sssp};
use graphhp::bench_support as bs;
use graphhp::engine::EngineKind;
use graphhp::graph::generators;

fn main() {
    bs::header(
        "Table 2: SSP on full road network, 108 partitions",
        "paper §7.2, Table 2 (USA-Road-Full)",
    );
    // larger, higher-diameter road graph
    let g = generators::road(420, 420, 3);
    bs::scale_note(
        "USA-Road-Full: 23.9M vertices, 58.3M edges, 108 partitions",
        &format!(
            "road grid {} vertices, {} edges, 108 partitions",
            g.num_vertices(),
            g.num_edges()
        ),
    );
    let mut runner = bs::runner(&g, 108);
    let prog = Sssp { source: 0 };
    let want = oracle::dijkstra(&g, 0);

    println!("  platform         I          M            T        (paper: I / M(mil) / T(sec))");
    let h = runner.run_on(EngineKind::Hama, &prog);
    bs::row("Hama", &h.metrics);
    println!("{:>64}", "paper: 10671 / 43829 / 17912");
    let a = runner.run_on(EngineKind::AmHama, &prog);
    bs::row("AM-Hama", &a.metrics);
    println!("{:>64}", "paper: 10593 /   387 /  5792");
    let p = runner.run_on(EngineKind::GraphHP, &prog);
    bs::row("GraphHP", &p.metrics);
    println!("{:>64}", "paper:   451 /    71 /  2155");

    for (i, &w) in want.iter().enumerate() {
        if w.is_finite() {
            assert!((p.values[i] - w as f32).abs() < 1e-2, "v{i}");
        }
    }

    println!("\nshape checks:");
    bs::expect_less(
        "GraphHP iters ≤ Hama iters / 10",
        p.metrics.global_iterations,
        h.metrics.global_iterations / 10 + 1,
    );
    bs::expect_less(
        "AM-Hama msgs < Hama msgs",
        a.metrics.network_messages,
        h.metrics.network_messages,
    );
    bs::expect_less(
        "GraphHP msgs < AM-Hama msgs",
        p.metrics.network_messages,
        a.metrics.network_messages,
    );
    bs::expect_less(
        "GraphHP time < AM-Hama time < Hama time",
        p.metrics.elapsed.as_micros() as u64,
        a.metrics.elapsed.as_micros() as u64,
    );
    println!("\ntable2 done");
}
