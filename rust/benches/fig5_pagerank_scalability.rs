//! Figure 5 — PageRank scalability: iterations / network messages (log)
//! / time vs number of partitions at Δ = 1e-4, for Hama / AM-Hama /
//! GraphHP on both web datasets.
//!
//! Paper shape: GraphHP wins every metric at every partition count; its
//! iteration and message counts grow only slightly with partitions.

use graphhp::algorithms::IncrementalPageRank;
use graphhp::bench_support as bs;
use graphhp::engine::EngineKind;
use graphhp::graph::generators;

fn sweep(gname: &str, g: &graphhp::graph::Graph, parts_sweep: &[usize]) {
    println!("\n-- {gname}: {} vertices, {} edges", g.num_vertices(), g.num_edges());
    let prog = IncrementalPageRank { tolerance: 1e-4 };
    let kinds = [EngineKind::Hama, EngineKind::AmHama, EngineKind::GraphHP];
    let (mut gi, mut gm) = (vec![], vec![]);
    for &k in parts_sweep {
        let mut runner = bs::runner(g, k);
        println!("  -- {k} partitions (cut {})", runner.dist().edge_cut());
        let results = bs::compare_rows(&mut runner, &kinds, &prog);
        let [_, a, p] = &results[..] else { unreachable!() };
        bs::expect_less(
            "GraphHP iters < AM-Hama iters",
            p.1.metrics.global_iterations,
            a.1.metrics.global_iterations,
        );
        bs::expect_less(
            "GraphHP msgs < AM-Hama msgs",
            p.1.metrics.network_messages,
            a.1.metrics.network_messages,
        );
        gi.push(p.1.metrics.global_iterations as f64);
        gm.push(p.1.metrics.network_messages as f64);
    }
    println!("  GraphHP iterations vs partitions (should grow only slightly):");
    bs::series("GraphHP I", parts_sweep, &gi);
    bs::series("GraphHP M", parts_sweep, &gm);
}

fn main() {
    bs::header(
        "Figure 5: PageRank scalability vs partitions (Δ=1e-4)",
        "paper §7.3, Figure 5 (Web-Google ≤14 parts, uk-2002 ≤108 parts)",
    );
    bs::scale_note(
        "web-Google (≤14 partitions), uk-2002 (≤108 partitions)",
        "synthetic web graphs at two scales",
    );
    let small = generators::powerlaw(30_000, 5, 7);
    sweep("web-Google stand-in", &small, &[2, 6, 10, 14]);
    let large = generators::powerlaw(90_000, 6, 8);
    sweep("uk-2002 stand-in", &large, &[12, 36, 72, 108]);
    println!("\nfig5 done");
}
