//! Figure 1 — synchronization and communication overhead of the
//! standard BSP platform (Hama), as a percentage of total processing
//! time, vs number of partitions.
//!
//! (a) SSSP on a road network; (b) PageRank on a web graph.
//! Paper's observation: sync+comm ≈ 86% at 12 partitions for SSSP, sync
//! alone ≈ 74%, sync share grows with partitions while comm share falls.

use graphhp::algorithms::{ClassicPageRank, Sssp};
use graphhp::bench_support as bs;
use graphhp::engine::EngineKind;

fn main() {
    bs::header(
        "Figure 1: Synchronization and Communication Overhead (Hama)",
        "paper §2, Figure 1 (a) SSSP on USA-Road-NE, (b) PageRank on Web-Google",
    );

    // ---- (a) SSSP on road network -------------------------------------
    let g = graphhp::graph::generators::road(160, 160, 1);
    bs::scale_note(
        "USA-Road-NE (1.5M vertices) on a 10-machine cluster",
        &format!("synthetic road grid, {} vertices, {} edges", g.num_vertices(), g.num_edges()),
    );
    println!("(a) SSSP — overhead as % of total time");
    println!("  parts   sync%   comm%   sync+comm%      I        T");
    let parts_sweep = [12, 24, 36, 48];
    let mut sync_pct = Vec::new();
    let mut comm_pct = Vec::new();
    for &k in &parts_sweep {
        let r = bs::runner(&g, k).engine(EngineKind::Hama).run(&Sssp { source: 0 });
        let m = &r.metrics;
        sync_pct.push(100.0 * m.sync_fraction());
        comm_pct.push(100.0 * m.comm_fraction());
        println!(
            "  {k:<7} {:>5.1}   {:>5.1}   {:>9.1}   {:>6} {:>8.3}s",
            100.0 * m.sync_fraction(),
            100.0 * m.comm_fraction(),
            100.0 * m.overhead_fraction(),
            m.global_iterations,
            m.elapsed.as_secs_f64()
        );
    }
    bs::series("sssp sync% vs parts", &parts_sweep, &sync_pct);
    bs::series("sssp comm% vs parts", &parts_sweep, &comm_pct);
    println!("  shape checks: paper reports sync+comm ≈ 86% @12 parts, rising with parts;");
    println!(
        "                sync dominant and rising: {}",
        if sync_pct.windows(2).all(|w| w[1] >= w[0] - 3.0) { "✓" } else { "✗" }
    );

    // ---- (b) classic PageRank on web graph ----------------------------
    let g = graphhp::graph::generators::powerlaw(40_000, 5, 2);
    println!(
        "\n(b) PageRank (straightforward Alg. 1, 30 supersteps) — {} vertices, {} edges",
        g.num_vertices(),
        g.num_edges()
    );
    println!("  parts   sync%   comm%   sync+comm%      I        T");
    let mut sync_pct = Vec::new();
    let mut comm_pct = Vec::new();
    for &k in &parts_sweep {
        let r =
            bs::runner(&g, k).engine(EngineKind::Hama).run(&ClassicPageRank { supersteps: 30 });
        let m = &r.metrics;
        sync_pct.push(100.0 * m.sync_fraction());
        comm_pct.push(100.0 * m.comm_fraction());
        println!(
            "  {k:<7} {:>5.1}   {:>5.1}   {:>9.1}   {:>6} {:>8.3}s",
            100.0 * m.sync_fraction(),
            100.0 * m.comm_fraction(),
            100.0 * m.overhead_fraction(),
            m.global_iterations,
            m.elapsed.as_secs_f64()
        );
    }
    bs::series("pr sync% vs parts", &parts_sweep, &sync_pct);
    bs::series("pr comm% vs parts", &parts_sweep, &comm_pct);
    println!("\nfig1 done");
}
