//! Figure 3 — SSSP on USA-Road-NE: (a) global iterations (log scale in
//! the paper), (b) network messages (log), (c) execution time, vs number
//! of partitions, for Hama / AM-Hama / GraphHP.
//!
//! Paper shape: Hama 3800+ iterations, AM-Hama 3700+, GraphHP ~20 (a
//! reduction of hundreds of ×); messages Hama ≫ AM-Hama ≫ GraphHP;
//! GraphHP time ~10× under AM-Hama; GraphHP iterations grow only
//! marginally with partition count.

use graphhp::algorithms::{oracle, Sssp};
use graphhp::bench_support as bs;
use graphhp::engine::EngineKind;
use graphhp::graph::generators;

fn main() {
    bs::header(
        "Figure 3: SSP on road network — iterations / messages / time vs partitions",
        "paper §7.2, Figure 3 (USA-Road-NE)",
    );
    let g = generators::road(220, 220, 1);
    bs::scale_note(
        "USA-Road-NE: 1.52M vertices, 3.9M edges, 12-48 partitions",
        &format!("road grid {} vertices, {} edges", g.num_vertices(), g.num_edges()),
    );
    let want = oracle::dijkstra(&g, 0);
    let prog = Sssp { source: 0 };
    let sweep = [12usize, 24, 36, 48];
    let kinds = [EngineKind::Hama, EngineKind::AmHama, EngineKind::GraphHP];

    let (mut hi, mut ai, mut gi) = (vec![], vec![], vec![]);
    let (mut hm, mut am, mut gm) = (vec![], vec![], vec![]);
    let (mut ht, mut at, mut gt) = (vec![], vec![], vec![]);

    for &k in &sweep {
        let mut runner = bs::runner(&g, k);
        println!("-- {k} partitions (edge cut {})", runner.dist().edge_cut());
        let results = bs::compare_rows(&mut runner, &kinds, &prog);
        let [h, a, p] = &results[..] else { unreachable!() };
        // verify
        for (i, &w) in want.iter().enumerate() {
            if w.is_finite() {
                assert!((p.1.values[i] - w as f32).abs() < 1e-2, "v{i}");
            }
        }
        hi.push(h.1.metrics.global_iterations as f64);
        ai.push(a.1.metrics.global_iterations as f64);
        gi.push(p.1.metrics.global_iterations as f64);
        hm.push(h.1.metrics.network_messages as f64);
        am.push(a.1.metrics.network_messages as f64);
        gm.push(p.1.metrics.network_messages as f64);
        ht.push(h.1.metrics.elapsed.as_secs_f64());
        at.push(a.1.metrics.elapsed.as_secs_f64());
        gt.push(p.1.metrics.elapsed.as_secs_f64());
    }

    println!("\n(a) iterations vs partitions");
    bs::series("Hama", &sweep, &hi);
    bs::series("AM-Hama", &sweep, &ai);
    bs::series("GraphHP", &sweep, &gi);
    println!("(b) network messages vs partitions");
    bs::series("Hama", &sweep, &hm);
    bs::series("AM-Hama", &sweep, &am);
    bs::series("GraphHP", &sweep, &gm);
    println!("(c) time vs partitions");
    bs::series("Hama", &sweep, &ht);
    bs::series("AM-Hama", &sweep, &at);
    bs::series("GraphHP", &sweep, &gt);

    println!("\nshape checks (paper: GraphHP ≪ AM-Hama ≈ Hama iterations; GraphHP fastest):");
    bs::expect_less("GraphHP iters ≪ Hama iters/10", gi[0] as u64, (hi[0] / 10.0) as u64);
    bs::expect_less("AM-Hama ≤ Hama iters", ai[0] as u64, hi[0] as u64 + 1);
    bs::expect_less("GraphHP msgs < AM-Hama msgs", gm[0] as u64, am[0] as u64);
    bs::expect_less("AM-Hama msgs < Hama msgs", am[0] as u64, hm[0] as u64);
    bs::expect_less(
        "GraphHP time < AM-Hama time",
        (gt[0] * 1e6) as u64,
        (at[0] * 1e6) as u64,
    );
    println!("\nfig3 done");
}
