//! Chaos stress suite: the recovery contract of every engine under
//! seeded, deterministic fault injection (see `engine/chaos.rs` and
//! `docs/architecture.md` § "Chaos & fault injection").
//!
//! The contract, asserted across all six engines × PageRank/SSSP/WCC:
//!
//! - **benign schedules** (duplicate/reorder — events the barrier
//!   absorbs by construction) leave every engine's fixpoint untouched;
//! - **lossy schedules with checkpointing** converge to the
//!   bit-identical (1e-6 for PageRank) no-chaos answer after recovery —
//!   on *every* barrier engine, through the shared rollback layer in
//!   `engine/recovery.rs`;
//! - **lossy schedules without checkpoints** fail loudly — an explicit
//!   `chaos:` error, never a silently wrong fixpoint — and an exhausted
//!   `RecoveryPolicy` budget surfaces the structured
//!   budget-exhausted error instead of retrying forever;
//! - **same seed ⇒ same `ChaosTrace`**, and `Sequential` ≡ `Threads(n)`
//!   down to the injected-event stream (graphlab-async is documented
//!   out of scope, like migration: it runs chaos-free and rejects a
//!   configured checkpoint policy loudly).

use graphhp::algorithms::{GasPageRank, GasSssp, GasWcc, IncrementalPageRank, Sssp, Wcc};
use graphhp::bench_support::runner;
use graphhp::engine::{
    ChaosEventKind, ChaosPolicy, ChaosSchedule, EngineKind, Parallelism, RecoveryPolicy, Runner,
};
use graphhp::graph::{generators, Graph};

/// Long-diameter grids keep every algorithm running well past the
/// stress preset's scheduled kill (barrier 5), so recovery always has
/// something to do.
fn grid() -> Graph {
    generators::road(20, 20, 9)
}

fn bits_f32(vs: &[f32]) -> Vec<u32> {
    vs.iter().map(|v| v.to_bits()).collect()
}

fn assert_pagerank_close(a: &[f64], b: &[f64], what: &str) {
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() < 1e-6, "{what} v{i}: {x} vs {y}");
    }
}

// ------------------------------------------------ benign: all engines

#[test]
fn benign_chaos_preserves_every_push_engine_fixpoint() {
    // duplicates are deduplicated by batch sequence and reorders are
    // reassembled into canonical order at the barrier, so the delivery
    // stream — and therefore the fixpoint — is identical to a clean run
    let g = grid();
    for kind in EngineKind::VERTEX_CENTRIC {
        let clean_sssp = runner(&g, 4).engine(kind).run(&Sssp { source: 0 });
        let chaotic = runner(&g, 4)
            .engine(kind)
            .chaos(ChaosPolicy::benign(21))
            .run(&Sssp { source: 0 });
        assert_eq!(
            bits_f32(&clean_sssp.values),
            bits_f32(&chaotic.values),
            "{kind}: benign chaos changed the SSSP fixpoint"
        );
        let trace = chaotic.chaos.expect("policy set => trace");
        assert_eq!(trace.loss_events(), 0, "{kind}: benign schedule injected loss");
        assert!(
            trace.count(ChaosEventKind::Duplicate) + trace.count(ChaosEventKind::Reorder) > 0,
            "{kind}: benign schedule never fired on a cross-partition batch"
        );

        let clean_wcc = runner(&g, 4).engine(kind).run(&Wcc);
        let chaotic_wcc =
            runner(&g, 4).engine(kind).chaos(ChaosPolicy::benign(22)).run(&Wcc);
        assert_eq!(clean_wcc.values, chaotic_wcc.values, "{kind}: WCC fixpoint");

        let prog = IncrementalPageRank { tolerance: 1e-6 };
        let clean_pr = runner(&g, 4).engine(kind).run(&prog);
        let chaotic_pr =
            runner(&g, 4).engine(kind).chaos(ChaosPolicy::benign(23)).run(&prog);
        assert_pagerank_close(&clean_pr.values, &chaotic_pr.values, &format!("{kind}"));
    }
}

#[test]
fn benign_chaos_is_vacuous_on_the_pull_engines() {
    // the GraphLab kinds have no push message plane: batch events never
    // fire (sync records an empty trace; async runs chaos-free)
    let g = grid();
    for (kind, kills_apply) in
        [(EngineKind::GraphLabSync, true), (EngineKind::GraphLabAsync, false)]
    {
        let clean = Runner::new(&g).partitions(4).engine(kind).run_gas(&GasWcc);
        let chaotic = Runner::new(&g)
            .partitions(4)
            .engine(kind)
            .chaos(ChaosPolicy::benign(31))
            .run_gas(&GasWcc);
        assert_eq!(clean.values, chaotic.values, "{kind}: WCC fixpoint");
        match (kills_apply, &chaotic.chaos) {
            (true, Some(trace)) => {
                assert!(trace.events.is_empty(), "{kind}: batch events on a pull engine")
            }
            (true, None) => panic!("{kind}: chaos policy set but no trace recorded"),
            (false, trace) => {
                assert!(trace.is_none(), "{kind}: chaos is documented out of scope")
            }
        }

        let clean_pr = Runner::new(&g)
            .partitions(4)
            .engine(kind)
            .run_gas(&GasPageRank { tolerance: 1e-6 });
        let chaotic_pr = Runner::new(&g)
            .partitions(4)
            .engine(kind)
            .chaos(ChaosPolicy::benign(32))
            .run_gas(&GasPageRank { tolerance: 1e-6 });
        assert_pagerank_close(&clean_pr.values, &chaotic_pr.values, &format!("{kind}"));

        let clean_sssp = Runner::new(&g)
            .partitions(4)
            .engine(kind)
            .run_gas(&GasSssp { source: 0 });
        let chaotic_sssp = Runner::new(&g)
            .partitions(4)
            .engine(kind)
            .chaos(ChaosPolicy::benign(33))
            .run_gas(&GasSssp { source: 0 });
        assert_eq!(
            bits_f32(&clean_sssp.values),
            bits_f32(&chaotic_sssp.values),
            "{kind}: SSSP fixpoint"
        );
    }
}

// --------------------------- lossy + checkpointing: exact recovery

#[test]
fn stress_schedule_with_checkpointing_recovers_sssp_exactly() {
    let g = grid();
    let prog = Sssp { source: 0 };
    let clean = runner(&g, 4).run(&prog);
    let stressed = runner(&g, 4)
        .checkpoint_interval(Some(2))
        .chaos(ChaosPolicy::stress(41))
        .run(&prog);
    assert!(stressed.metrics.recoveries > 0, "the scheduled kill must recover");
    assert_eq!(
        bits_f32(&clean.values),
        bits_f32(&stressed.values),
        "recovery must replay the clean trajectory bit-for-bit"
    );
    let trace = stressed.chaos.expect("trace recorded");
    assert!(trace.count(ChaosEventKind::Kill) >= 1);
    assert_eq!(
        trace.count(ChaosEventKind::Recover),
        stressed.metrics.recoveries,
        "every recovery must land in the trace"
    );
}

#[test]
fn stress_schedule_with_checkpointing_recovers_wcc_exactly() {
    let g = grid();
    let clean = runner(&g, 4).run(&Wcc);
    let stressed = runner(&g, 4)
        .checkpoint_interval(Some(2))
        .chaos(ChaosPolicy::stress(42))
        .run(&Wcc);
    assert!(stressed.metrics.recoveries > 0);
    assert_eq!(clean.values, stressed.values);
}

#[test]
fn stress_schedule_with_checkpointing_recovers_pagerank_within_tolerance() {
    let g = grid();
    let prog = IncrementalPageRank { tolerance: 1e-6 };
    let clean = runner(&g, 4).run(&prog);
    let stressed = runner(&g, 4)
        .checkpoint_interval(Some(2))
        .chaos(ChaosPolicy::stress(43))
        .run(&prog);
    assert!(stressed.metrics.recoveries > 0);
    assert_pagerank_close(&clean.values, &stressed.values, "stressed pagerank");
}

#[test]
fn partition_then_heal_window_recovers_exactly() {
    use graphhp::engine::NetSplit;
    let g = grid();
    let prog = Sssp { source: 0 };
    let clean = runner(&g, 4).run(&prog);
    let split = ChaosPolicy {
        seed: 44,
        schedule: ChaosSchedule {
            splits: vec![NetSplit { from: 1, heal_at: 6, group: vec![0, 1] }],
            ..Default::default()
        },
    };
    let stressed =
        runner(&g, 4).checkpoint_interval(Some(2)).chaos(split).run(&prog);
    assert!(stressed.metrics.recoveries > 0, "severed batches must trigger rollback");
    assert_eq!(bits_f32(&clean.values), bits_f32(&stressed.values));
    let trace = stressed.chaos.expect("trace recorded");
    assert!(trace.count(ChaosEventKind::SplitHold) > 0, "the split must sever traffic");
    assert!(trace.count(ChaosEventKind::Heal) >= 1, "the heal must be recorded");
}

// ------------------- recovery matrix: every barrier engine recovers

#[test]
fn every_barrier_engine_recovers_sssp_exactly_under_stress() {
    let g = grid();
    let prog = Sssp { source: 0 };
    for kind in EngineKind::VERTEX_CENTRIC {
        let clean = runner(&g, 4).engine(kind).run(&prog);
        let stressed = runner(&g, 4)
            .engine(kind)
            .checkpoint_interval(Some(2))
            .chaos(ChaosPolicy::stress(81))
            .run(&prog);
        assert!(stressed.metrics.recoveries > 0, "{kind}: the scheduled kill must recover");
        assert_eq!(
            bits_f32(&clean.values),
            bits_f32(&stressed.values),
            "{kind}: recovery must replay the clean trajectory bit-for-bit"
        );
        let trace = stressed.chaos.expect("trace recorded");
        assert_eq!(
            trace.count(ChaosEventKind::Recover),
            stressed.metrics.recoveries,
            "{kind}: every recovery must land in the trace"
        );
    }
}

#[test]
fn every_barrier_engine_recovers_wcc_exactly_under_stress() {
    let g = grid();
    for kind in EngineKind::VERTEX_CENTRIC {
        let clean = runner(&g, 4).engine(kind).run(&Wcc);
        let stressed = runner(&g, 4)
            .engine(kind)
            .checkpoint_interval(Some(2))
            .chaos(ChaosPolicy::stress(82))
            .run(&Wcc);
        assert!(stressed.metrics.recoveries > 0, "{kind}: recoveries");
        assert_eq!(clean.values, stressed.values, "{kind}: WCC fixpoint after recovery");
    }
}

#[test]
fn every_barrier_engine_recovers_pagerank_within_tolerance_under_stress() {
    let g = grid();
    let prog = IncrementalPageRank { tolerance: 1e-6 };
    for kind in EngineKind::VERTEX_CENTRIC {
        let clean = runner(&g, 4).engine(kind).run(&prog);
        let stressed = runner(&g, 4)
            .engine(kind)
            .checkpoint_interval(Some(2))
            .chaos(ChaosPolicy::stress(83))
            .run(&prog);
        assert!(stressed.metrics.recoveries > 0, "{kind}: recoveries");
        assert_pagerank_close(&clean.values, &stressed.values, &format!("{kind}"));
    }
}

#[test]
fn recovery_is_thread_count_independent_on_every_barrier_engine() {
    let g = grid();
    for kind in EngineKind::VERTEX_CENTRIC {
        let run = |p: Parallelism| {
            runner(&g, 4)
                .engine(kind)
                .parallelism(p)
                .checkpoint_interval(Some(2))
                .chaos(ChaosPolicy::stress(84))
                .run(&Sssp { source: 0 })
        };
        let seq = run(Parallelism::Sequential);
        let par = run(Parallelism::Threads(4));
        assert_eq!(
            seq.chaos.expect("trace"),
            par.chaos.expect("trace"),
            "{kind}: Sequential and Threads(4) must inject identically"
        );
        assert_eq!(bits_f32(&seq.values), bits_f32(&par.values), "{kind}: values");
        assert_eq!(seq.metrics.recoveries, par.metrics.recoveries, "{kind}: recoveries");
    }
}

#[test]
fn graphlab_sync_recovers_from_a_kill_with_checkpoints() {
    let g = grid();
    let clean = Runner::new(&g).partitions(4).engine(EngineKind::GraphLabSync).run_gas(&GasWcc);
    let stressed = Runner::new(&g)
        .partitions(4)
        .engine(EngineKind::GraphLabSync)
        .checkpoint_interval(Some(2))
        .chaos(ChaosPolicy {
            seed: 85,
            schedule: ChaosSchedule { kill_at: vec![1], ..Default::default() },
        })
        .run_gas(&GasWcc);
    assert!(stressed.metrics.recoveries > 0, "the kill must recover");
    assert_eq!(clean.values, stressed.values, "recovered WCC must match the clean run");
    let trace = stressed.chaos.expect("trace recorded");
    assert!(trace.count(ChaosEventKind::Kill) >= 1);
    assert_eq!(trace.count(ChaosEventKind::Recover), stressed.metrics.recoveries);
}

// ------------------- bounded retries: budget exhaustion is structured

#[test]
fn exhausted_recovery_budget_surfaces_a_structured_error() {
    // max_recoveries = 0: the very first rollback attempt must turn
    // into the budget-exhausted error — never an infinite retry loop
    let g = grid();
    let kill = |seed: u64| ChaosPolicy {
        seed,
        schedule: ChaosSchedule { kill_at: vec![1], ..Default::default() },
    };
    for kind in EngineKind::VERTEX_CENTRIC {
        let err = runner(&g, 4)
            .engine(kind)
            .checkpoint_interval(Some(2))
            .recovery(RecoveryPolicy { max_recoveries: 0, ..Default::default() })
            .chaos(kill(91))
            .try_run(&Wcc)
            .expect_err("zero budget must fail the run");
        assert!(err.starts_with("chaos:"), "{kind}: {err}");
        assert!(err.contains("recovery budget exhausted"), "{kind}: {err}");
    }
    let err = Runner::new(&g)
        .partitions(4)
        .engine(EngineKind::GraphLabSync)
        .checkpoint_interval(Some(2))
        .recovery(RecoveryPolicy { max_recoveries: 0, ..Default::default() })
        .chaos(kill(92))
        .try_run_gas(&GasWcc)
        .expect_err("zero budget must fail the run");
    assert!(err.contains("recovery budget exhausted"), "graphlab-sync: {err}");
}

#[test]
fn default_budget_covers_the_default_stress_schedule() {
    // RecoveryPolicy::default().max_recoveries == 64 ==
    // ChaosSchedule::default().max_loss_events: a default stress run can
    // spend its whole loss budget and still converge
    assert_eq!(RecoveryPolicy::default().max_recoveries, 64);
    assert_eq!(ChaosSchedule::default().max_loss_events, 64);
}

#[test]
fn graphlab_async_rejects_a_checkpoint_policy_loudly() {
    let g = grid();
    let err = Runner::new(&g)
        .partitions(4)
        .engine(EngineKind::GraphLabAsync)
        .checkpoint_interval(Some(2))
        .try_run_gas(&GasWcc)
        .expect_err("async has no barriers: the config must be rejected");
    assert!(err.starts_with("config:"), "unexpected message: {err}");
    assert!(err.contains("no barriers"), "unexpected message: {err}");
}

// ----------------------- lossy without checkpoints: loud failure

#[test]
fn loss_without_checkpoints_fails_loudly_on_every_engine() {
    let g = grid();
    // a scheduled kill is loss on every engine, independent of whether
    // the schedule's probabilistic events hit a cross-partition batch
    let kill = |seed: u64| ChaosPolicy {
        seed,
        schedule: ChaosSchedule { kill_at: vec![1], ..Default::default() },
    };
    for kind in EngineKind::VERTEX_CENTRIC {
        let err = runner(&g, 4)
            .engine(kind)
            .chaos(kill(51))
            .try_run(&Wcc)
            .expect_err("kill without checkpoints must fail loudly");
        assert!(err.starts_with("chaos:"), "{kind}: unexpected message: {err}");
    }
    let err = Runner::new(&g)
        .partitions(4)
        .engine(EngineKind::GraphLabSync)
        .chaos(kill(52))
        .try_run_gas(&GasWcc)
        .expect_err("graphlab-sync kill without checkpoints must fail loudly");
    assert!(err.starts_with("chaos:"), "graphlab-sync: unexpected message: {err}");
    // graphlab-async: documented out of scope — the run ignores chaos
    let r = Runner::new(&g)
        .partitions(4)
        .engine(EngineKind::GraphLabAsync)
        .chaos(kill(53))
        .run_gas(&GasWcc);
    assert!(r.chaos.is_none());
}

#[test]
fn certain_drop_without_checkpoints_never_converges_silently() {
    // drop_prob = 1.0: every cross-partition batch is lost. The first
    // corrupted barrier must already surface the error — on every
    // push engine and algorithm
    let g = grid();
    let lossy = |seed: u64| ChaosPolicy {
        seed,
        schedule: ChaosSchedule { drop_prob: 1.0, ..Default::default() },
    };
    for kind in EngineKind::VERTEX_CENTRIC {
        let err = runner(&g, 4)
            .engine(kind)
            .chaos(lossy(61))
            .try_run(&Sssp { source: 0 })
            .expect_err("dropped mail must not yield a silent fixpoint");
        assert!(err.starts_with("chaos:"), "{kind}: unexpected message: {err}");
        assert!(err.contains("drop"), "{kind}: loss kind missing from: {err}");
    }
}

// ------------------------------------ determinism: seed and threads

#[test]
fn same_seed_reproduces_the_exact_chaos_trace() {
    let g = grid();
    let run = || {
        runner(&g, 4)
            .checkpoint_interval(Some(2))
            .chaos(ChaosPolicy::stress(71))
            .run(&Sssp { source: 0 })
    };
    let a = run();
    let b = run();
    let (ta, tb) = (a.chaos.expect("trace"), b.chaos.expect("trace"));
    assert_eq!(ta, tb, "same seed must reproduce the injected-event stream");
    assert!(!ta.events.is_empty(), "stress schedule must inject something");
    assert_eq!(a.metrics.recoveries, b.metrics.recoveries);
    assert_eq!(bits_f32(&a.values), bits_f32(&b.values));
}

#[test]
fn sequential_and_threaded_runs_inject_identically() {
    // verdicts are drawn on the engine thread in (worker, dest) order,
    // so the chaos stream is independent of worker interleaving
    let g = grid();
    let run = |p: Parallelism| {
        runner(&g, 4)
            .parallelism(p)
            .checkpoint_interval(Some(2))
            .chaos(ChaosPolicy::stress(72))
            .run(&Sssp { source: 0 })
    };
    let seq = run(Parallelism::Sequential);
    let par = run(Parallelism::Threads(4));
    assert_eq!(
        seq.chaos.expect("trace"),
        par.chaos.expect("trace"),
        "Sequential and Threads(n) must inject the identical event stream"
    );
    assert_eq!(bits_f32(&seq.values), bits_f32(&par.values));
    assert_eq!(seq.metrics.recoveries, par.metrics.recoveries);

    // benign schedules hold the same equivalence on a checkpoint-less
    // engine (no recovery in play, pure delivery-path determinism)
    let bx = |p: Parallelism| {
        runner(&g, 4)
            .engine(EngineKind::Hama)
            .parallelism(p)
            .chaos(ChaosPolicy::benign(73))
            .run(&Wcc)
    };
    let s = bx(Parallelism::Sequential);
    let t = bx(Parallelism::Threads(4));
    assert_eq!(s.chaos.expect("trace"), t.chaos.expect("trace"));
    assert_eq!(s.values, t.values);
}

#[test]
fn chaos_trace_json_serializes_every_recorded_event() {
    let g = grid();
    let r = runner(&g, 4)
        .checkpoint_interval(Some(2))
        .chaos(ChaosPolicy::stress(74))
        .run(&Wcc);
    let trace = r.chaos.expect("trace");
    let json = trace.to_json();
    assert_eq!(json.matches("\"kind\"").count(), trace.events.len());
    for needle in ["\"seed\": 74", "\"events\": ["] {
        assert!(json.contains(needle), "missing {needle} in {json}");
    }
}
