//! Equivalence contract of telemetry-driven online repartitioning.
//!
//! Migration rewrites *where* vertices live, never *what* they compute:
//! an engine run with [`RepartitionConfig`] enabled must reach the same
//! fixed point as a static-partition run — bitwise for the min-fold
//! programs (SSSP, WCC), within 1e-6 for floating-point-sum PageRank
//! (migration reshuffles partition membership, which changes message
//! timing but not the tolerance-bounded fixed point). Checked across
//! all six engines.
//!
//! The determinism contract also survives: with migration on,
//! `Parallelism::Threads(n)` stays bit-for-bit identical to
//! `Sequential` — same values AND the same migration trajectory, since
//! every plan is a pure function of deterministic trace counters
//! (`compute_us` never feeds a decision). `check_edge_routes` +
//! `check_migration_plan` run after every applied plan in these debug
//! builds, so passing tests also certify post-migration geometry.

use graphhp::algorithms::{GasPageRank, GasSssp, GasWcc, IncrementalPageRank, Sssp, Wcc};
use graphhp::engine::{
    ChaosEventKind, ChaosPolicy, ChaosSchedule, EngineKind, Parallelism, RepartitionConfig,
    Runner,
};
use graphhp::graph::{generators, DistGraph, Graph};
use graphhp::partition::hash_partition;

/// Hash-partitioned view: poor locality by construction, so the
/// planner sees network-bound partitions and actually migrates.
fn dist(g: &Graph, k: usize) -> DistGraph {
    let a = hash_partition(g, k);
    DistGraph::new(g, &a, k)
}

fn runner(dg: &DistGraph, migrate: bool) -> Runner<'_> {
    let r = Runner::from_dist(dg).parallelism(Parallelism::Sequential);
    if migrate {
        r.repartition(RepartitionConfig::every_barrier())
    } else {
        r
    }
}

// ---- static vs migrated: same fixed point ------------------------------

#[test]
fn sssp_bitwise_equal_across_vertex_engines() {
    let g = generators::connected(300, 120, 7);
    let dg = dist(&g, 4);
    for kind in EngineKind::VERTEX_CENTRIC {
        let stat = runner(&dg, false).run_on(kind, &Sssp { source: 0 });
        let migr = runner(&dg, true).run_on(kind, &Sssp { source: 0 });
        assert_eq!(stat.values.len(), migr.values.len());
        for (i, (a, b)) in stat.values.iter().zip(&migr.values).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{kind} sssp v{i}: {a} vs {b}");
        }
        assert_eq!(stat.trace.vertices_migrated(), 0, "{kind}: static run must not move");
        assert!(migr.trace.vertices_migrated() > 0, "{kind}: hash partition should migrate");
    }
}

#[test]
fn wcc_bitwise_equal_across_vertex_engines() {
    let g = generators::connected(250, 100, 11);
    let dg = dist(&g, 4);
    for kind in EngineKind::VERTEX_CENTRIC {
        let stat = runner(&dg, false).run_on(kind, &Wcc);
        let migr = runner(&dg, true).run_on(kind, &Wcc);
        assert_eq!(stat.values, migr.values, "{kind} wcc");
        assert!(migr.trace.vertices_migrated() > 0, "{kind}: expected migrations");
    }
}

#[test]
fn pagerank_within_tolerance_across_vertex_engines() {
    let g = generators::powerlaw(300, 4, 13);
    let dg = dist(&g, 4);
    for kind in EngineKind::VERTEX_CENTRIC {
        let prog = IncrementalPageRank { tolerance: 1e-9 };
        let stat = runner(&dg, false).run_on(kind, &prog);
        let migr = runner(&dg, true).run_on(kind, &prog);
        for (i, (a, b)) in stat.values.iter().zip(&migr.values).enumerate() {
            assert!((a - b).abs() < 1e-6, "{kind} pagerank v{i}: {a} vs {b}");
        }
    }
}

#[test]
fn gas_engines_static_vs_migrated() {
    let g = generators::connected(300, 120, 7);
    let dg = dist(&g, 4);

    // sync: values are global, so migration is exactly bitwise-neutral
    let kind = EngineKind::GraphLabSync;
    let stat = runner(&dg, false).run_gas_on(kind, &GasSssp { source: 0 });
    let migr = runner(&dg, true).run_gas_on(kind, &GasSssp { source: 0 });
    for (a, b) in stat.values.iter().zip(&migr.values) {
        assert_eq!(a.to_bits(), b.to_bits(), "graphlab-sync sssp");
    }
    assert!(migr.trace.vertices_migrated() > 0, "sync engine should migrate");

    let stat = runner(&dg, false).run_gas_on(kind, &GasWcc);
    let migr = runner(&dg, true).run_gas_on(kind, &GasWcc);
    assert_eq!(stat.values, migr.values, "graphlab-sync wcc");

    let prog = GasPageRank { tolerance: 1e-9 };
    let stat = runner(&dg, false).run_gas_on(kind, &prog);
    let migr = runner(&dg, true).run_gas_on(kind, &prog);
    for (a, b) in stat.values.iter().zip(&migr.values) {
        assert!((a - b).abs() < 1e-6, "graphlab-sync pagerank: {a} vs {b}");
    }

    // async: no barriers — repartitioning is documented as ignored
    let kind = EngineKind::GraphLabAsync;
    let stat = runner(&dg, false).run_gas_on(kind, &GasWcc);
    let migr = runner(&dg, true).run_gas_on(kind, &GasWcc);
    assert_eq!(stat.values, migr.values, "graphlab-async wcc");
    assert_eq!(migr.trace.vertices_migrated(), 0, "async has no barriers to migrate at");
}

// ---- determinism: threaded ≡ sequential with migration on --------------

#[test]
fn threads_match_sequential_with_migration_enabled() {
    let g = generators::connected(300, 120, 7);
    let dg = dist(&g, 4);
    for kind in EngineKind::VERTEX_CENTRIC {
        let seq = Runner::from_dist(&dg)
            .parallelism(Parallelism::Sequential)
            .repartition(RepartitionConfig::every_barrier())
            .run_on(kind, &Sssp { source: 0 });
        let par = Runner::from_dist(&dg)
            .parallelism(Parallelism::Threads(4))
            .repartition(RepartitionConfig::every_barrier())
            .run_on(kind, &Sssp { source: 0 });
        for (i, (a, b)) in seq.values.iter().zip(&par.values).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{kind} v{i}: threaded diverged");
        }
        // the whole migration trajectory — not just the total — must be
        // identical: every plan is a function of deterministic counters
        assert_eq!(
            seq.trace.migration_trajectory(),
            par.trace.migration_trajectory(),
            "{kind}: migration trajectory diverged between modes"
        );
        assert_eq!(seq.metrics.network_messages, par.metrics.network_messages, "{kind}");
        assert!(seq.trace.vertices_migrated() > 0, "{kind}: vacuous without migrations");
    }
}

// ---- chaos in the migration window -------------------------------------

/// A kill scheduled inside the migration window (between
/// `MigrationPlanner::plan` and `apply_migration`) fires at the first
/// barrier that actually produces a plan at or after the scheduled
/// point.
fn migration_kill(seed: u64) -> ChaosPolicy {
    ChaosPolicy {
        seed,
        schedule: ChaosSchedule { migration_kill_at: vec![1], ..Default::default() },
    }
}

#[test]
fn kill_in_the_migration_window_recovers_bitwise_on_every_engine() {
    // the recovered replay re-derives the identical plan trajectory from
    // the checkpointed counters, so the final values match the clean
    // migrated run bit-for-bit
    let g = generators::connected(300, 120, 7);
    let dg = dist(&g, 4);
    for kind in EngineKind::VERTEX_CENTRIC {
        let clean = runner(&dg, true).run_on(kind, &Sssp { source: 0 });
        let killed = runner(&dg, true)
            .checkpoint_interval(Some(1))
            .chaos(migration_kill(7))
            .run_on(kind, &Sssp { source: 0 });
        assert!(killed.metrics.recoveries > 0, "{kind}: the window kill must recover");
        for (i, (a, b)) in clean.values.iter().zip(&killed.values).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{kind} v{i}: recovery diverged");
        }
        assert_eq!(
            clean.trace.vertices_migrated(),
            killed.trace.vertices_migrated(),
            "{kind}: the replay must re-apply the identical plan trajectory"
        );
        let trace = killed.chaos.expect("trace recorded");
        assert!(
            trace.count(ChaosEventKind::MigrationKill) >= 1,
            "{kind}: the kill must land inside a migration window"
        );
        assert_eq!(trace.count(ChaosEventKind::Recover), killed.metrics.recoveries);
    }
}

#[test]
fn graphlab_sync_survives_a_migration_window_kill() {
    let g = generators::connected(300, 120, 7);
    let dg = dist(&g, 4);
    let clean = runner(&dg, true).run_gas_on(EngineKind::GraphLabSync, &GasSssp { source: 0 });
    let killed = runner(&dg, true)
        .checkpoint_interval(Some(1))
        .chaos(migration_kill(8))
        .run_gas_on(EngineKind::GraphLabSync, &GasSssp { source: 0 });
    assert!(killed.metrics.recoveries > 0, "the window kill must recover");
    for (a, b) in clean.values.iter().zip(&killed.values) {
        assert_eq!(a.to_bits(), b.to_bits(), "graphlab-sync: recovery diverged");
    }
    assert_eq!(clean.trace.vertices_migrated(), killed.trace.vertices_migrated());
    let trace = killed.chaos.expect("trace recorded");
    assert!(trace.count(ChaosEventKind::MigrationKill) >= 1);
}

#[test]
fn migration_window_kill_without_checkpoints_fails_loudly() {
    let g = generators::connected(300, 120, 7);
    let dg = dist(&g, 4);
    let err = runner(&dg, true)
        .chaos(migration_kill(9))
        .try_run(&Sssp { source: 0 })
        .expect_err("a window kill without checkpoints must fail loudly");
    assert!(err.starts_with("chaos:"), "unexpected message: {err}");
    assert!(err.contains("migration window"), "unexpected message: {err}");
}

// ---- interval semantics ------------------------------------------------

#[test]
fn interval_gates_when_plans_can_fire() {
    let g = generators::connected(300, 120, 7);
    let dg = dist(&g, 4);
    let r = Runner::from_dist(&dg)
        .parallelism(Parallelism::Sequential)
        .repartition(RepartitionConfig { interval: 3, max_moves: 64 })
        .run_on(EngineKind::Hama, &Sssp { source: 0 });
    for (i, &m) in r.trace.migration_trajectory().iter().enumerate() {
        if (i as u64 + 1) % 3 != 0 {
            assert_eq!(m, 0, "barrier {i}: plan fired off-interval");
        }
    }
    // routing epoch advances exactly when a plan applied
    let mut epoch = 0u64;
    for s in &r.trace.steps {
        assert_eq!(s.routing_epoch, epoch, "iteration {}", s.iteration);
        if s.migrated > 0 {
            epoch += 1;
        }
    }
}
