//! Runner session API tests: PageRank through the [`Runner`] on every
//! [`EngineKind`], checked against the sequential oracle AND bit-for-bit
//! against the legacy free-function path; plus builder/session behavior
//! that unit tests can't cover from inside the crate.

use graphhp::algorithms::pagerank::GasPageRank;
use graphhp::algorithms::{oracle, IncrementalPageRank, Sssp};
use graphhp::engine::giraphpp::VertexSweep;
use graphhp::engine::{
    am_hama, giraphpp, graphhp as hp, graphlab, hama, EngineConfig, EngineKind, NetSimConfig,
    Partitioner, Runner,
};
use graphhp::graph::generators;
use graphhp::partition::{metis_partition, MetisConfig};

/// PageRank on every one of the six kinds through one session: values
/// must match the power-iteration oracle within the tolerance bound and
/// the legacy free-function results exactly.
#[test]
fn pagerank_via_runner_on_every_kind_matches_oracle_and_legacy() {
    let g = generators::powerlaw(600, 4, 13);
    let k = 4;
    let assignment = metis_partition(&g, k, &MetisConfig::default());
    let dg = graphhp::graph::DistGraph::new(&g, &assignment, k);
    let cfg = EngineConfig::default();
    let want = oracle::pagerank(&g, 1e-12);
    let oracle_check = |kind: EngineKind, values: &[f64]| {
        let err: f64 =
            values.iter().zip(&want).map(|(x, y)| (x - y).abs()).sum::<f64>() / want.len() as f64;
        assert!(err < 1e-4, "{kind}: avg err {err} vs oracle");
    };

    // the session partitions with the same metis config => same DistGraph
    let mut runner = Runner::new(&g)
        .partitions(k)
        .partitioner(Partitioner::Metis(MetisConfig::default()));

    let vp = IncrementalPageRank { tolerance: 1e-8 };
    let gp = GasPageRank { tolerance: 1e-9 };
    for kind in EngineKind::ALL {
        let (via, legacy) = if kind.is_gas() {
            let via = runner.run_gas_on(kind, &gp);
            let legacy = match kind {
                EngineKind::GraphLabSync => graphlab::run_graphlab_sync(&gp, &dg, &cfg),
                _ => graphlab::run_graphlab_async(&gp, &dg, &cfg),
            };
            (via, legacy)
        } else {
            let via = runner.run_on(kind, &vp);
            let legacy = match kind {
                EngineKind::Hama => hama::run_hama(&vp, &dg, &cfg),
                EngineKind::AmHama => am_hama::run_am_hama(&vp, &dg, &cfg),
                EngineKind::GraphHP => hp::run_graphhp(&vp, &dg, &cfg),
                EngineKind::GiraphPP => {
                    let sweep = VertexSweep {
                        program: IncrementalPageRank { tolerance: 1e-8 },
                        seed: cfg.seed,
                    };
                    giraphpp::run_giraphpp(&sweep, &dg, &cfg)
                }
                _ => unreachable!(),
            };
            (via, legacy)
        };
        oracle_check(kind, &via.values);
        assert_eq!(via.values, legacy.values, "{kind}: Runner != legacy free function");
        assert_eq!(
            via.metrics.global_iterations, legacy.metrics.global_iterations,
            "{kind}: iteration counts diverge"
        );
        assert_eq!(
            via.metrics.network_messages, legacy.metrics.network_messages,
            "{kind}: message counts diverge"
        );
    }
}

/// The session builds the distributed view lazily and exactly once; an
/// explicit assignment pins the placement.
#[test]
fn session_reuses_one_distributed_view() {
    let g = generators::connected(300, 120, 21);
    let mut runner = Runner::new(&g).partitions(5);
    let cut_before = runner.dist().edge_cut();
    for kind in EngineKind::VERTEX_CENTRIC {
        let r = runner.run_on(kind, &Sssp { source: 0 });
        assert_eq!(r.values.len(), g.num_vertices(), "{kind}");
    }
    assert_eq!(runner.dist().edge_cut(), cut_before, "view must not be rebuilt");
}

/// Builder knobs actually reach the engines: a 3-iteration cap stops
/// Hama early, and a custom net config changes the simulated clock.
#[test]
fn builder_knobs_are_honored_end_to_end() {
    let g = generators::road(20, 20, 3);
    let capped = Runner::new(&g)
        .partitions(4)
        .engine(EngineKind::Hama)
        .max_iterations(3)
        .run(&Sssp { source: 0 });
    assert_eq!(capped.metrics.global_iterations, 3);

    let slow_net = NetSimConfig { barrier_latency_us: 50_000.0, ..Default::default() };
    let fast = Runner::new(&g).partitions(4).engine(EngineKind::Hama).run(&Sssp { source: 0 });
    let slow = Runner::new(&g)
        .partitions(4)
        .engine(EngineKind::Hama)
        .net(slow_net)
        .run(&Sssp { source: 0 });
    assert_eq!(fast.metrics.global_iterations, slow.metrics.global_iterations);
    assert!(slow.metrics.elapsed > fast.metrics.elapsed, "barrier cost must show up");
}

/// `compare` runs every requested kind over the same view and keeps the
/// kind labels aligned with the results.
#[test]
fn compare_returns_labeled_results() {
    let g = generators::connected(150, 60, 9);
    let mut runner = Runner::new(&g).partitions(3);
    let results = runner.compare(&EngineKind::VERTEX_CENTRIC, &Sssp { source: 0 });
    assert_eq!(results.len(), EngineKind::VERTEX_CENTRIC.len());
    for ((kind, r), want_kind) in results.iter().zip(EngineKind::VERTEX_CENTRIC) {
        assert_eq!(*kind, want_kind);
        assert_eq!(r.values.len(), g.num_vertices());
    }
    // confluent program: all engines bit-identical
    let base = &results[0].1.values;
    for (kind, r) in &results[1..] {
        assert_eq!(&r.values, base, "{kind}");
    }
}
