//! Determinism contract of the parallel worker runtime:
//! `Parallelism::Threads(n)` must be **bit-for-bit** identical to
//! `Parallelism::Sequential` on every [`EngineKind`] — same final vertex
//! values (compared at the bit level for floats) and the same
//! message/iteration/computation counts. Workers are shared-nothing
//! within a superstep and the barrier folds their outputs in partition
//! order, so thread interleaving must be unobservable.
//!
//! Also proves a panicking vertex program inside a worker thread aborts
//! the run (propagates through the scoped join) instead of deadlocking
//! the barrier.

use graphhp::algorithms::{GasPageRank, GasSssp, GasWcc, IncrementalPageRank, Sssp, Wcc};
use graphhp::engine::graphlab::GasProgram;
use graphhp::engine::{
    AdaptiveConfig, EngineConfig, EngineKind, HybridPolicy, Metrics, Parallelism, RunTrace,
    Runner, VertexContext, VertexProgram,
};
use graphhp::graph::{generators, DistGraph, Graph};
use graphhp::partition::{metis_partition, MetisConfig};

fn dist(g: &Graph, k: usize) -> DistGraph {
    let a = metis_partition(g, k, &MetisConfig::default());
    DistGraph::new(g, &a, k)
}

fn cfg_with(par: Parallelism) -> EngineConfig {
    EngineConfig { parallelism: par, ..Default::default() }
}

/// All the deterministic counters two equivalent runs must share.
fn assert_counts_equal(kind: EngineKind, algo: &str, seq: &Metrics, par: &Metrics) {
    assert_eq!(seq.global_iterations, par.global_iterations, "{kind} {algo}: iterations");
    assert_eq!(seq.supersteps_total, par.supersteps_total, "{kind} {algo}: supersteps");
    assert_eq!(seq.network_messages, par.network_messages, "{kind} {algo}: messages");
    assert_eq!(seq.network_bytes, par.network_bytes, "{kind} {algo}: bytes");
    assert_eq!(seq.local_messages, par.local_messages, "{kind} {algo}: local messages");
    assert_eq!(
        seq.vertex_computations, par.vertex_computations,
        "{kind} {algo}: computations"
    );
}

/// Run a vertex program on `kind` under both modes and require bitwise
/// equality. `bits` projects a value to its bit representation.
fn check_vertex<P, B, F>(kind: EngineKind, algo: &str, dg: &DistGraph, prog: &P, bits: F)
where
    P: VertexProgram,
    B: PartialEq + std::fmt::Debug,
    F: Fn(&P::V) -> B,
{
    let seq = Runner::from_dist(dg)
        .config(cfg_with(Parallelism::Sequential))
        .run_on(kind, prog);
    let par =
        Runner::from_dist(dg).config(cfg_with(Parallelism::Threads(4))).run_on(kind, prog);
    assert_eq!(seq.values.len(), par.values.len(), "{kind} {algo}: length");
    for (i, (a, b)) in seq.values.iter().zip(&par.values).enumerate() {
        assert_eq!(bits(a), bits(b), "{kind} {algo}: v{i} differs between modes");
    }
    assert_counts_equal(kind, algo, &seq.metrics, &par.metrics);
    assert_trace_counters_equal(kind, algo, &seq.trace, &par.trace);
}

/// GAS analogue of [`check_vertex`].
fn check_gas<P, B, F>(kind: EngineKind, algo: &str, dg: &DistGraph, prog: &P, bits: F)
where
    P: GasProgram,
    B: PartialEq + std::fmt::Debug,
    F: Fn(&P::V) -> B,
{
    let seq = Runner::from_dist(dg)
        .config(cfg_with(Parallelism::Sequential))
        .run_gas_on(kind, prog);
    let par = Runner::from_dist(dg)
        .config(cfg_with(Parallelism::Threads(4)))
        .run_gas_on(kind, prog);
    assert_eq!(seq.values.len(), par.values.len(), "{kind} {algo}: length");
    for (i, (a, b)) in seq.values.iter().zip(&par.values).enumerate() {
        assert_eq!(bits(a), bits(b), "{kind} {algo}: v{i} differs between modes");
    }
    assert_counts_equal(kind, algo, &seq.metrics, &par.metrics);
    assert_trace_counters_equal(kind, algo, &seq.trace, &par.trace);
}

/// Threads(4) ≡ Sequential on all six kinds for PageRank, SSSP and WCC,
/// across several graph shapes and partition counts (including more
/// partitions than threads and an empty partition or two). Values,
/// metric counters AND every per-step trace counter must match — the
/// pooled sorted worklist and resolved-route send plane must reproduce
/// the original `BTreeSet` sweep order bit-for-bit.
#[test]
fn threads_bit_identical_to_sequential_on_all_six_kinds() {
    let cases: Vec<(Graph, usize)> = vec![
        (generators::connected(300, 150, 7), 4),
        (generators::powerlaw(400, 4, 11), 6),
        (generators::road(18, 18, 3), 9),
        (generators::erdos_renyi(120, 240, 5), 2),
    ];
    for (g, k) in &cases {
        let dg = dist(g, *k);
        for kind in EngineKind::ALL {
            if kind.is_gas() {
                check_gas(kind, "pagerank", &dg, &GasPageRank { tolerance: 1e-7 }, |v| {
                    v.to_bits()
                });
                check_gas(kind, "sssp", &dg, &GasSssp { source: 1 }, |v| v.to_bits());
                check_gas(kind, "wcc", &dg, &GasWcc, |v| *v);
            } else {
                check_vertex(
                    kind,
                    "pagerank",
                    &dg,
                    &IncrementalPageRank { tolerance: 1e-7 },
                    |v| v.to_bits(),
                );
                check_vertex(kind, "sssp", &dg, &Sssp { source: 1 }, |v| v.to_bits());
                check_vertex(kind, "wcc", &dg, &Wcc, |v| *v);
            }
        }
    }
}

/// More worker threads than partitions, and a single-partition graph,
/// must still match sequential exactly.
#[test]
fn thread_count_never_changes_results() {
    let g = generators::connected(200, 80, 13);
    let dg = dist(&g, 3);
    let base = Runner::from_dist(&dg)
        .config(cfg_with(Parallelism::Sequential))
        .run_on(EngineKind::GraphHP, &Wcc);
    for t in [1, 2, 3, 8, 32] {
        let r = Runner::from_dist(&dg)
            .config(cfg_with(Parallelism::Threads(t)))
            .run_on(EngineKind::GraphHP, &Wcc);
        assert_eq!(base.values, r.values, "Threads({t})");
        assert_counts_equal(EngineKind::GraphHP, "wcc", &base.metrics, &r.metrics);
    }
    let dg1 = DistGraph::new(&g, &vec![0; 200], 1);
    let solo_seq = Runner::from_dist(&dg1)
        .config(cfg_with(Parallelism::Sequential))
        .run_on(EngineKind::Hama, &Wcc);
    let solo_par = Runner::from_dist(&dg1)
        .config(cfg_with(Parallelism::Threads(4)))
        .run_on(EngineKind::Hama, &Wcc);
    assert_eq!(solo_seq.values, solo_par.values);
}

/// Every deterministic counter of two traces must agree; the wall-clock
/// field (`compute_us`) is explicitly excluded — it is the one
/// reporting-only field and the adaptive scheduler never reads it.
fn assert_trace_counters_equal(kind: EngineKind, algo: &str, seq: &RunTrace, par: &RunTrace) {
    assert_eq!(
        seq.partition_locality, par.partition_locality,
        "{kind} {algo}: locality seeds"
    );
    assert_eq!(seq.steps.len(), par.steps.len(), "{kind} {algo}: step count");
    for (s, p) in seq.steps.iter().zip(&par.steps) {
        assert_eq!(s.iteration, p.iteration, "{kind} {algo}: step index");
        assert_eq!(s.partitions.len(), p.partitions.len(), "{kind} {algo}: partitions");
        for (a, b) in s.partitions.iter().zip(&p.partitions) {
            let mut a = a.clone();
            let mut b = b.clone();
            a.compute_us = 0;
            b.compute_us = 0;
            assert_eq!(a, b, "{kind} {algo}: trace record step {}", s.iteration);
        }
    }
}

/// The adaptive hybrid scheduler must preserve the determinism
/// contract: its decisions are pure functions of trace counters, so
/// `Threads(n)` stays bit-for-bit identical to `Sequential` — values,
/// metric counters, AND every per-step trace counter. A tight initial
/// cap plus a hard `max_pseudo_supersteps` limit forces the whole
/// decision surface (carryover shrink, geometric growth, boundary
/// shedding, local-phase skips) to actually execute.
#[test]
fn adaptive_policy_threads_bit_identical_to_sequential() {
    let adaptive = HybridPolicy::Adaptive(AdaptiveConfig {
        initial_cap: 2,
        ..Default::default()
    });
    let cases: Vec<(Graph, usize)> = vec![
        (generators::connected(300, 150, 7), 4),
        (generators::powerlaw(400, 4, 11), 6),
        (generators::road(18, 18, 3), 9),
    ];
    for (g, k) in &cases {
        let dg = dist(g, *k);
        let mk_cfg = |par: Parallelism| {
            let mut cfg = cfg_with(par);
            cfg.hybrid = adaptive;
            cfg.limits.max_pseudo_supersteps = 6;
            cfg
        };
        macro_rules! check {
            ($algo:literal, $prog:expr, $bits:expr) => {{
                let prog = $prog;
                let seq = Runner::from_dist(&dg)
                    .config(mk_cfg(Parallelism::Sequential))
                    .run_on(EngineKind::GraphHP, &prog);
                let par = Runner::from_dist(&dg)
                    .config(mk_cfg(Parallelism::Threads(4)))
                    .run_on(EngineKind::GraphHP, &prog);
                for (i, (a, b)) in seq.values.iter().zip(&par.values).enumerate() {
                    assert_eq!($bits(a), $bits(b), "adaptive {} v{i}", $algo);
                }
                assert_counts_equal(EngineKind::GraphHP, $algo, &seq.metrics, &par.metrics);
                assert_trace_counters_equal(EngineKind::GraphHP, $algo, &seq.trace, &par.trace);
            }};
        }
        check!("pagerank", IncrementalPageRank { tolerance: 1e-7 }, |v: &f64| v.to_bits());
        check!("sssp", Sssp { source: 1 }, |v: &f32| v.to_bits());
        check!("wcc", Wcc, |v: &u32| *v);
    }
}

/// A vertex program that panics inside a worker thread: the panic must
/// propagate out of the run (scoped threads re-raise on join) rather
/// than leaving the barrier waiting forever.
#[test]
fn worker_panic_propagates_instead_of_deadlocking() {
    struct Exploder;
    impl VertexProgram for Exploder {
        type V = u32;
        type M = u32;
        fn init(&self, _v: graphhp::graph::VertexId, _d: u32) -> u32 {
            0
        }
        fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
            if ctx.vertex_id() == 17 {
                panic!("injected vertex-program panic");
            }
            ctx.vote_to_halt();
        }
    }
    let g = generators::connected(60, 30, 9);
    let dg = dist(&g, 4);
    for kind in EngineKind::VERTEX_CENTRIC {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            Runner::from_dist(&dg)
                .config(cfg_with(Parallelism::Threads(4)))
                .run_on(kind, &Exploder)
        }));
        assert!(result.is_err(), "{kind}: worker panic must propagate");
    }
}
