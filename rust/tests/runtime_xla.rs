//! Integration tests of the XLA/PJRT runtime path: load the AOT
//! artifacts produced by `make artifacts` and check the compiled
//! local-phase executables against scalar references, then prove the
//! dense-accelerated GraphHP local phase is equivalent to the scalar
//! engine path.
//!
//! Requires `artifacts/` (run `make artifacts`); tests fail with a clear
//! message otherwise.

use graphhp::algorithms::sssp::INF;
use graphhp::graph::{generators, DistGraph};
use graphhp::partition::{metis_partition, MetisConfig};
use graphhp::runtime::{DenseLocalAccel, XlaRuntime};
use graphhp::util::Rng;

fn runtime() -> XlaRuntime {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    assert!(
        dir.join("manifest.txt").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    XlaRuntime::new(dir).expect("PJRT CPU client")
}

#[test]
fn pagerank_phase_matches_scalar_matvec() {
    let rt = runtime();
    let phase = rt.load_phase("pagerank_local").expect("load pagerank_local");
    let n = phase.spec.n;
    let steps = phase.spec.steps;

    let mut rng = Rng::new(7);
    // random sparse-ish matrix with small entries (column-stochastic-ish)
    let mut m = vec![0f32; n * n];
    for v in m.iter_mut() {
        if rng.chance(0.05) {
            *v = rng.f32_range(0.0, 0.01);
        }
    }
    let rank: Vec<f32> = (0..n).map(|_| rng.f32_range(0.0, 1.0)).collect();
    let delta: Vec<f32> = (0..n).map(|_| rng.f32_range(0.0, 1.0)).collect();

    let (got_rank, got_delta, got_acc, got_linf) =
        phase.run_pagerank(&m, &rank, &delta).expect("execute");

    // scalar reference of K steps
    let mut r = rank.clone();
    let mut d = delta.clone();
    let mut acc = vec![0f32; n];
    for _ in 0..steps {
        for i in 0..n {
            acc[i] += d[i];
        }
        let mut nd = vec![0f32; n];
        for i in 0..n {
            let row = &m[i * n..(i + 1) * n];
            let mut s = 0f32;
            for j in 0..n {
                s += row[j] * d[j];
            }
            nd[i] = s;
            r[i] += s;
        }
        d = nd;
    }
    let linf = d.iter().fold(0f32, |a, &b| a.max(b.abs()));

    for i in 0..n {
        assert!((got_rank[i] - r[i]).abs() < 1e-4, "rank[{i}]: {} vs {}", got_rank[i], r[i]);
        assert!((got_delta[i] - d[i]).abs() < 1e-5, "delta[{i}]");
        assert!((got_acc[i] - acc[i]).abs() < 1e-4, "acc[{i}]");
    }
    assert!((got_linf - linf).abs() < 1e-5);
}

#[test]
fn sssp_phase_matches_scalar_minplus() {
    let rt = runtime();
    let phase = rt.load_phase("sssp_local").expect("load sssp_local");
    let n = phase.spec.n;
    let steps = phase.spec.steps;

    let mut rng = Rng::new(13);
    let mut w = vec![INF; n * n];
    for v in w.iter_mut() {
        if rng.chance(0.03) {
            *v = rng.f32_range(0.1, 10.0);
        }
    }
    let mut d0 = vec![INF; n];
    d0[0] = 0.0;
    d0[n / 2] = 5.0;

    let (got, changed) = phase.run_sssp(&w, &d0).expect("execute");

    let mut d = d0.clone();
    for _ in 0..steps {
        let mut nd = d.clone();
        for i in 0..n {
            let row = &w[i * n..(i + 1) * n];
            for j in 0..n {
                let cand = row[j] + d[j];
                if cand < nd[i] {
                    nd[i] = cand;
                }
            }
        }
        d = nd;
    }
    let want_changed = d.iter().zip(&d0).filter(|(a, b)| a < b).count() as u32;

    for i in 0..n {
        let (a, b) = (got[i], d[i]);
        if b >= INF {
            assert!(a >= INF * 0.5, "dist[{i}] should stay inf, got {a}");
        } else {
            assert!((a - b).abs() < 1e-3, "dist[{i}]: {a} vs {b}");
        }
    }
    assert_eq!(changed, want_changed);
}

#[test]
fn accelerated_pagerank_local_phase_equals_scalar() {
    let rt = runtime();
    let phase = rt.load_phase("pagerank_local").expect("load");
    let n = phase.spec.n;

    // one partition of a real graph, densified
    let g = generators::powerlaw(600, 4, 5);
    let a = metis_partition(&g, 4, &MetisConfig::default());
    let dg = DistGraph::new(&g, &a, 4);
    for part in &dg.parts {
        if part.num_vertices() > n {
            continue;
        }
        let mut accel = DenseLocalAccel::new(part, n, 0.85).unwrap();
        let live = accel.live;

        let mut rank_x: Vec<f32> = vec![0.15; live];
        let mut delta_x: Vec<f32> = vec![0.15; live];
        let (acc_x, invocations) = accel
            .pagerank_local_phase(&rt, &phase, &mut rank_x, &mut delta_x, 1e-7, 1000)
            .expect("accelerated phase");
        assert!(invocations >= 1);

        let mut rank_s: Vec<f32> = vec![0.15; live];
        let mut delta_s: Vec<f32> = vec![0.15; live];
        let acc_s = accel.pagerank_local_phase_scalar(&mut rank_s, &mut delta_s, 1e-7, 100_000);

        for i in 0..live {
            assert!(
                (rank_x[i] - rank_s[i]).abs() < 1e-3,
                "rank[{i}]: xla {} vs scalar {}",
                rank_x[i],
                rank_s[i]
            );
            // accumulated outflow mass drives remote messages: must agree
            assert!((acc_x[i] - acc_s[i]).abs() < 1e-3, "acc[{i}]");
        }
    }
}

#[test]
fn accelerated_sssp_local_phase_reaches_fixpoint() {
    let rt = runtime();
    let phase = rt.load_phase("sssp_local").expect("load");
    let n = phase.spec.n;

    let g = generators::road(14, 14, 9); // 196 vertices, one partition
    let dg = DistGraph::new(&g, &vec![0; g.num_vertices()], 1);
    let mut accel = DenseLocalAccel::new(&dg.parts[0], n, 0.85).unwrap();

    let mut dist = vec![INF; accel.live];
    dist[0] = 0.0;
    let (improved, invocations) =
        accel.sssp_local_phase(&rt, &phase, &mut dist, 1000).expect("sssp phase");
    assert!(improved > 0);
    assert!(invocations >= 2, "grid diameter needs multiple 8-step chunks");

    // must equal Dijkstra on the whole (single-partition) graph
    let want = graphhp::algorithms::oracle::dijkstra(&g, 0);
    for i in 0..accel.live {
        if want[i].is_finite() {
            assert!(
                (dist[i] - want[i] as f32).abs() < 1e-2,
                "dist[{i}]: {} vs {}",
                dist[i],
                want[i]
            );
        } else {
            assert!(dist[i] >= INF * 0.5);
        }
    }
}

#[test]
fn runtime_reports_platform() {
    let rt = runtime();
    assert!(rt.platform().to_lowercase().contains("cpu"), "{}", rt.platform());
}

#[test]
fn missing_artifact_is_clear_error() {
    let rt = runtime();
    let err = match rt.load_phase("nonexistent") {
        Ok(_) => panic!("expected an error"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("not in manifest"), "{err}");
}
