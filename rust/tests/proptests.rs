//! Property tests over coordinator invariants: message routing, message
//! stores, partitioners, generators, codec, netsim. Hand-rolled harness
//! (no proptest in the offline vendor set) over the crate RNG — each
//! property is checked on many random cases with failures reporting the
//! case seed.

use graphhp::engine::checkpoint::{Checkpoint, PolicyCheckpoint};
use graphhp::engine::messages::{MsgStore, Outbox};
use graphhp::engine::netsim::{NetSimConfig, WorkerComm};
use graphhp::engine::{SourceCombine, VertexContext, VertexProgram};
use graphhp::graph::{generators, DistGraph, Graph, MigrationPlan, VertexId};
use graphhp::partition::{hash_partition, metis_partition, MetisConfig, PartitionStats};
use graphhp::util::{Codec, Rng};

fn random_graph(rng: &mut Rng) -> Graph {
    match rng.index(3) {
        0 => generators::erdos_renyi(2 + rng.index(200), rng.index(600), rng.next_u64()),
        1 => generators::powerlaw(2 + rng.index(300), 1 + rng.index(5), rng.next_u64()),
        _ => generators::road(2 + rng.index(15), 2 + rng.index(15), rng.next_u64()),
    }
}

// ------------------------------------------------------------- routing

/// Every message sent through an engine must be delivered exactly once
/// to exactly the addressed vertex. EchoProgram: superstep 0, every
/// vertex sends its id to a pseudorandom set of targets; superstep 1,
/// receivers record what they got; engines' final states must match the
/// expected multiset.
struct EchoProgram {
    seed: u64,
}

impl VertexProgram for EchoProgram {
    type V = Vec<u32>;
    type M = u32;
    fn init(&self, _v: VertexId, _d: u32) -> Vec<u32> {
        Vec::new()
    }
    fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
        if ctx.superstep() == 0 {
            let me = ctx.vertex_id();
            let mut r = Rng::new(self.seed).derive(me as u64);
            let n = 1 + r.index(5);
            for _ in 0..n {
                // target chosen over the whole id space: exercises
                // arbitrary-id routing, not just edges
                let t = r.index(ctx.partition().num_vertices_total()) as u32;
                ctx.send(t, me);
            }
        } else {
            let mut got: Vec<u32> = ctx.messages().to_vec();
            got.sort_unstable();
            let mut v = ctx.value().clone();
            v.extend(got);
            ctx.set_value(v);
        }
        ctx.vote_to_halt();
    }
}

// the program above needs the global vertex count; extend PartGraph via
// a helper trait so the test stays self-contained
trait TotalVertices {
    fn num_vertices_total(&self) -> usize;
}
impl TotalVertices for graphhp::graph::PartGraph {
    fn num_vertices_total(&self) -> usize {
        // global ids are dense 0..n over all partitions; the max id in a
        // partition underestimates n, so tests pass the real bound via
        // the RNG modulus below. Here we fall back to a safe bound.
        (self.global_ids.iter().copied().max().unwrap_or(0) as usize) + 1
    }
}

fn expected_deliveries(n: usize, seed: u64) -> Vec<Vec<u32>> {
    let mut want: Vec<Vec<u32>> = vec![Vec::new(); n];
    for v in 0..n as u32 {
        let mut r = Rng::new(seed).derive(v as u64);
        let k = 1 + r.index(5);
        for _ in 0..k {
            let t = r.index(n);
            want[t].push(v);
        }
    }
    for w in want.iter_mut() {
        w.sort_unstable();
    }
    want
}

#[test]
fn routing_delivers_every_message_exactly_once() {
    let mut rng = Rng::new(0x51CE);
    for case in 0..20 {
        // fully-connected id space: make a graph with ZERO edges so the
        // only traffic is the arbitrary-id sends
        let n = 10 + rng.index(150);
        let g = Graph { offsets: vec![0; n + 1], targets: vec![], weights: vec![] };
        let k = 1 + rng.index(5);
        let a = hash_partition(&g, k);
        let dg = DistGraph::new(&g, &a, k);
        let seed = rng.next_u64();
        // the safe bound in num_vertices_total can underestimate n for
        // partitions missing the max id — only run when ids cover n
        // (hash partition over 0-edge graph keeps all ids, so max = n-1
        // overall; per-partition max differs, so use n from a vertex map)
        let cfg = graphhp::engine::EngineConfig::default();
        let prog = EchoProgram { seed };
        // Compare all engines against each other AND the oracle — but
        // the per-partition bound means senders in different partitions
        // use different moduli; instead verify pairwise equality of
        // engines (routing equivalence) which is the actual invariant.
        // engines may deliver a vertex's mail in several batches (e.g.
        // GraphHP splits remote vs local mail across phases): normalize
        // by sorting each mailbox before comparing
        let norm = |mut vs: Vec<Vec<u32>>| {
            for v in vs.iter_mut() {
                v.sort_unstable();
            }
            vs
        };
        let h = norm(graphhp::engine::hama::run_hama(&prog, &dg, &cfg).values);
        let am = norm(graphhp::engine::am_hama::run_am_hama(&prog, &dg, &cfg).values);
        let hp = norm(graphhp::engine::graphhp::run_graphhp(&prog, &dg, &cfg).values);
        assert_eq!(h, am, "case {case}");
        assert_eq!(h, hp, "case {case}");
        // single-partition run gives the exact oracle (modulus = n)
        let dg1 = DistGraph::new(&g, &vec![0; n], 1);
        let solo = norm(graphhp::engine::hama::run_hama(&prog, &dg1, &cfg).values);
        assert_eq!(solo, expected_deliveries(n, seed), "case {case} oracle");
    }
}

// ----------------------------------------------------------- msg store

#[test]
fn msgstore_never_loses_or_duplicates() {
    let mut rng = Rng::new(0xAB);
    for _ in 0..50 {
        let n = 1 + rng.index(40);
        let mut store: MsgStore<u64> = MsgStore::new(n);
        let mut oracle: Vec<Vec<u64>> = vec![Vec::new(); n];
        for _ in 0..rng.index(300) {
            if rng.chance(0.7) {
                let lv = rng.index(n);
                let m = rng.next_u64();
                store.push(lv, m);
                oracle[lv].push(m);
            } else {
                let lv = rng.index(n);
                let mut buf = Vec::new();
                store.take_into(lv, &mut buf);
                assert_eq!(buf, oracle[lv], "drain mismatch");
                oracle[lv].clear();
            }
        }
        let mut pending = store.pending();
        pending.sort_unstable();
        let want: Vec<u32> = (0..n as u32).filter(|&lv| !oracle[lv as usize].is_empty()).collect();
        assert_eq!(pending, want);
    }
}

#[test]
fn outbox_combining_is_min_fold() {
    let mut rng = Rng::new(0xCD);
    for _ in 0..50 {
        let mut ob: Outbox<f32> = Outbox::new(Some(|a: f32, b: f32| a.min(b)));
        let mut oracle: std::collections::HashMap<(u32, u32), f32> =
            std::collections::HashMap::new();
        for _ in 0..rng.index(200) {
            let dp = rng.index(4) as u32;
            let dl = rng.index(10) as u32;
            let m = rng.f32_range(0.0, 100.0);
            ob.push(dp, dl, 0, m);
            oracle
                .entry((dp, dl))
                .and_modify(|v| *v = v.min(m))
                .or_insert(m);
        }
        ob.seal(SourceCombine::KeepAll);
        assert_eq!(ob.len(), oracle.len());
        let mut prev_key = None;
        for (dp, dl, m) in ob.drain() {
            assert_eq!(m, oracle[&(dp, dl)]);
            // sealed drain is (dest_part, dest_local)-ordered
            assert!(prev_key < Some((dp, dl)), "unordered drain at ({dp},{dl})");
            prev_key = Some((dp, dl));
        }
    }
}

#[test]
fn outbox_source_combine_latest_only() {
    let mut rng = Rng::new(0xEF);
    for _ in 0..30 {
        let mut ob: Outbox<u64> = Outbox::new(None);
        let mut latest: std::collections::HashMap<(u32, u32, u32), u64> =
            std::collections::HashMap::new();
        for _ in 0..rng.index(150) {
            let dl = rng.index(6) as u32;
            let src = rng.index(6) as u32;
            let m = rng.next_u64();
            ob.push(0, dl, src, m);
            latest.insert((0, dl, src), m);
        }
        ob.seal(SourceCombine::KeepLatest);
        let drained: Vec<_> = ob.drain().collect();
        assert_eq!(drained.len(), latest.len());
        let vals: std::collections::HashSet<u64> = drained.iter().map(|&(_, _, m)| m).collect();
        for v in latest.values() {
            assert!(vals.contains(v));
        }
    }
}

// ---------------------------------------------------------- partitions

#[test]
fn partitioners_cover_and_bound() {
    let mut rng = Rng::new(0x9A97);
    for case in 0..20 {
        let g = random_graph(&mut rng);
        let k = 1 + rng.index(9);
        for (name, a) in [
            ("hash", hash_partition(&g, k)),
            (
                "metis",
                metis_partition(
                    &g,
                    k,
                    &MetisConfig { seed: rng.next_u64(), ..Default::default() },
                ),
            ),
        ] {
            assert_eq!(a.len(), g.num_vertices(), "{name} case {case}");
            assert!(a.iter().all(|&p| (p as usize) < k), "{name} case {case}");
            // stats are internally consistent
            let s = PartitionStats::compute(&g, &a, k);
            assert_eq!(s.sizes.iter().sum::<usize>(), g.num_vertices());
            assert!(s.edge_cut <= g.num_edges());
            // DistGraph agrees with stats
            let dg = DistGraph::new(&g, &a, k);
            assert_eq!(dg.edge_cut(), s.edge_cut);
            assert_eq!(dg.num_boundary(), s.boundary_vertices);
        }
    }
}

#[test]
fn boundary_classification_is_sound_and_complete() {
    let mut rng = Rng::new(0xB0B);
    for _ in 0..20 {
        let g = random_graph(&mut rng);
        let k = 1 + rng.index(6);
        let a = hash_partition(&g, k);
        let dg = DistGraph::new(&g, &a, k);
        // recompute from first principles
        let mut boundary = vec![false; g.num_vertices()];
        for v in 0..g.num_vertices() as u32 {
            for &t in g.out_edges(v).0 {
                if a[v as usize] != a[t as usize] {
                    boundary[t as usize] = true;
                }
            }
        }
        for part in &dg.parts {
            for (lv, &gid) in part.global_ids.iter().enumerate() {
                assert_eq!(
                    part.is_boundary[lv], boundary[gid as usize],
                    "vertex {gid} misclassified"
                );
            }
        }
    }
}

#[test]
fn distgraph_preserves_all_edges_and_weights() {
    let mut rng = Rng::new(0xED6E);
    for _ in 0..20 {
        let g = random_graph(&mut rng);
        let k = 1 + rng.index(6);
        let dg = DistGraph::new(&g, &hash_partition(&g, k), k);
        let mut got: Vec<(u32, u32, u32)> = Vec::new();
        for part in &dg.parts {
            for lv in 0..part.num_vertices() {
                let src = part.global_ids[lv];
                for e in part.out_edges(lv) {
                    got.push((src, e.target, e.weight.to_bits()));
                    // location indicator must agree with the map
                    assert_eq!(dg.routing.location[e.target as usize], (e.target_part, e.target_local));
                }
            }
        }
        let mut want: Vec<(u32, u32, u32)> = Vec::new();
        for v in 0..g.num_vertices() as u32 {
            let (ts, ws) = g.out_edges(v);
            for (&t, &w) in ts.iter().zip(ws) {
                want.push((v, t, w.to_bits()));
            }
        }
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want);
    }
}

// --------------------------------------------------------------- codec

#[test]
fn codec_roundtrips_random_values() {
    let mut rng = Rng::new(0xC0DEC);
    for _ in 0..200 {
        let v: Vec<(u32, f32)> = (0..rng.index(20))
            .map(|_| (rng.next_u64() as u32, rng.f32_range(-1e6, 1e6)))
            .collect();
        let mut buf = Vec::new();
        v.encode(&mut buf);
        assert_eq!(buf.len(), v.encoded_len());
        let mut r = &buf[..];
        assert_eq!(Vec::<(u32, f32)>::decode(&mut r), Some(v));
        assert!(r.is_empty());
    }
}

// ---------------------------------------------------- checkpoint frame

fn random_checkpoint(rng: &mut Rng) -> Checkpoint<f32, u32> {
    let np = 1 + rng.index(4);
    let mailbox = |rng: &mut Rng, n: usize| -> Vec<(u32, Vec<u32>)> {
        (0..rng.index(5))
            .map(|_| {
                let lv = rng.index(n.max(1)) as u32;
                let msgs = (0..1 + rng.index(4)).map(|_| rng.next_u64() as u32).collect();
                (lv, msgs)
            })
            .collect()
    };
    let sizes: Vec<usize> = (0..np).map(|_| rng.index(30)).collect();
    Checkpoint {
        iteration: rng.next_u64() % 1_000,
        values: sizes
            .iter()
            .map(|&n| (0..n).map(|_| rng.f32_range(-1e6, 1e6)).collect())
            .collect(),
        halted: sizes.iter().map(|&n| (0..n).map(|_| rng.chance(0.5)).collect()).collect(),
        inbox: sizes.iter().map(|&n| mailbox(rng, n)).collect(),
        local_cur: sizes.iter().map(|&n| mailbox(rng, n)).collect(),
        local_nxt: sizes.iter().map(|&n| mailbox(rng, n)).collect(),
        frontier: sizes
            .iter()
            .map(|&n| (0..rng.index(n + 1)).map(|_| rng.index(n.max(1)) as u32).collect())
            .collect(),
        policy: (0..np)
            .map(|_| PolicyCheckpoint {
                run_local: rng.chance(0.5),
                cap: 1 + rng.next_u64() % 64,
                boundary_in_local: rng.chance(0.5),
                preferred_boundary: rng.chance(0.5),
                carryover_streak: rng.index(8) as u32,
                clean_streak: rng.index(8) as u32,
            })
            .collect(),
        migrations: (0..rng.index(4))
            .map(|e| MigrationPlan {
                epoch: e as u64 + 1,
                moves: (0..rng.index(6))
                    .map(|_| (rng.index(100) as u32, rng.index(np) as u32))
                    .collect(),
            })
            .collect(),
    }
}

#[test]
fn prop_checkpoint_roundtrips_arbitrary_state() {
    let mut rng = Rng::new(0xC4E0);
    for case in 0..60 {
        let c = random_checkpoint(&mut rng);
        let d = Checkpoint::<f32, u32>::decode_bytes(&c.encode_bytes());
        assert_eq!(d.as_ref(), Some(&c), "case {case}");
    }
}

#[test]
fn prop_truncated_checkpoint_bytes_never_panic() {
    // every strict prefix must be cleanly rejected — the frame's length
    // field catches truncation before any payload decode runs
    let mut rng = Rng::new(0xC4E1);
    for case in 0..10 {
        let b = random_checkpoint(&mut rng).encode_bytes();
        for cut in 0..b.len() {
            assert!(
                Checkpoint::<f32, u32>::decode_bytes(&b[..cut]).is_none(),
                "case {case}: truncation at {cut} must be rejected"
            );
        }
    }
}

#[test]
fn prop_bit_flipped_checkpoint_bytes_are_rejected() {
    // a single bit flip anywhere — header or payload — must yield None.
    // FNV-1a's xor-then-multiply-by-odd steps are bijective in the
    // running hash, so a one-bit payload difference always changes the
    // checksum; header flips fail the magic/version/length checks.
    let mut rng = Rng::new(0xC4E2);
    for case in 0..40 {
        let mut b = random_checkpoint(&mut rng).encode_bytes();
        let byte = rng.index(b.len());
        let bit = rng.index(8) as u8;
        b[byte] ^= 1 << bit;
        assert!(
            Checkpoint::<f32, u32>::decode_bytes(&b).is_none(),
            "case {case}: flip at byte {byte} bit {bit} must be rejected"
        );
    }
}

// -------------------------------------------------------------- netsim

#[test]
fn netsim_costs_are_monotone() {
    let cfg = NetSimConfig::default();
    let mut rng = Rng::new(0x5E7);
    for _ in 0..100 {
        let base = WorkerComm {
            messages: rng.gen_range(10_000),
            bytes: rng.gen_range(1_000_000),
            peer_pairs: rng.gen_range(50),
        };
        let more_msgs = WorkerComm { messages: base.messages + 1000, ..base };
        let more_bytes = WorkerComm { bytes: base.bytes + 10_000_000, ..base };
        let t = cfg.comm_time(&base);
        assert!(cfg.comm_time(&more_msgs) > t);
        assert!(cfg.comm_time(&more_bytes) > t);
    }
}

// --------------------------------------------- compressed edge columns

/// Round-trip property of the varint-delta edge compression: for any
/// random graph, partitioning, and local-index policy, the compressed
/// columns decode to exactly the SoA columns — same `(target, route,
/// weight)` stream per vertex, agreeing route-only iteration, agreeing
/// random access — and the weights column stays directly addressable.
#[test]
fn prop_compressed_edge_columns_roundtrip() {
    use graphhp::graph::{GraphLayout, LayoutPolicy};
    let mut rng = Rng::new(0xED6E5);
    for case in 0..40u32 {
        let g = random_graph(&mut rng);
        let k = 1 + rng.index(6);
        let a = hash_partition(&g, k);
        let policy = if rng.index(2) == 0 {
            LayoutPolicy::Identity
        } else {
            LayoutPolicy::DegreeSorted
        };
        let soa =
            DistGraph::with_layout(&g, &a, k, GraphLayout { policy, compress_edges: false });
        let packed =
            DistGraph::with_layout(&g, &a, k, GraphLayout { policy, compress_edges: true });
        assert!(packed.parts.iter().all(|p| p.is_compressed() || p.num_edges() == 0));
        for (ps, pp) in soa.parts.iter().zip(&packed.parts) {
            assert_eq!(ps.num_vertices(), pp.num_vertices(), "case {case}");
            for lv in 0..ps.num_vertices() {
                let es = ps.out_edges(lv);
                let ep = pp.out_edges(lv);
                assert_eq!(es.len(), ep.len(), "case {case} lv {lv}: degree");
                let want: Vec<(u32, u64, u32)> = es
                    .iter()
                    .map(|e| {
                        (e.target, ((e.target_part as u64) << 32) | e.target_local as u64,
                         e.weight.to_bits())
                    })
                    .collect();
                let got: Vec<(u32, u64, u32)> = ep
                    .iter()
                    .map(|e| {
                        (e.target, ((e.target_part as u64) << 32) | e.target_local as u64,
                         e.weight.to_bits())
                    })
                    .collect();
                assert_eq!(got, want, "case {case} lv {lv}: edge stream");
                let r_want: Vec<(u32, u32)> =
                    es.route_iter().map(|r| r.unpack()).collect();
                let r_got: Vec<(u32, u32)> =
                    ep.route_iter().map(|r| r.unpack()).collect();
                assert_eq!(r_got, r_want, "case {case} lv {lv}: route stream");
                assert_eq!(es.weights(), ep.weights(), "case {case} lv {lv}: weights");
                if !want.is_empty() {
                    let i = rng.index(want.len());
                    let (a, b) = (es.get(i), ep.get(i));
                    assert_eq!(
                        (a.target, a.weight.to_bits()),
                        (b.target, b.weight.to_bits()),
                        "case {case} lv {lv}: random access at {i}"
                    );
                }
            }
        }
    }
}
