//! Cross-module integration tests: every algorithm on every engine on
//! real (generated) graphs, verified against sequential oracles. All
//! execution goes through the [`Runner`] session API.

use graphhp::algorithms::bipartite_matching::{validate_matching, BipartiteMatching};
use graphhp::algorithms::coloring::{is_proper_coloring, Coloring};
use graphhp::algorithms::pagerank::{GasPageRank, GiraphPPPageRank};
use graphhp::algorithms::{oracle, IncrementalPageRank, Sssp, Wcc};
use graphhp::bench_support::runner;
use graphhp::engine::{EngineKind, Partitioner, Runner};
use graphhp::graph::Graph;
use graphhp::graph::generators;

// ---------------------------------------------------------------- SSSP

fn sssp_all_engines(g: &Graph, k: usize, source: u32) {
    let mut runner = runner(g, k);
    let want = oracle::dijkstra(g, source);
    let prog = Sssp { source };
    for (kind, r) in runner.compare(&EngineKind::VERTEX_CENTRIC, &prog) {
        for (i, (&got, &w)) in r.values.iter().zip(&want).enumerate() {
            if w.is_finite() {
                assert!((got - w as f32).abs() < 1e-2, "{kind} v{i}: {got} vs {w}");
            } else {
                assert!(got >= 1e29, "{kind} v{i}: expected inf");
            }
        }
    }
}

#[test]
fn sssp_on_road_graph_all_engines() {
    sssp_all_engines(&generators::road(25, 25, 3), 5, 0);
}

#[test]
fn sssp_on_random_connected_graph_all_engines() {
    sssp_all_engines(&generators::connected(400, 200, 9), 7, 13);
}

#[test]
fn sssp_on_powerlaw_all_engines() {
    sssp_all_engines(&generators::powerlaw(500, 4, 11), 4, 2);
}

// ------------------------------------------------------------ PageRank

#[test]
fn pagerank_all_engines_agree_with_power_iteration() {
    let g = generators::powerlaw(800, 4, 17);
    let mut runner = runner(&g, 5);
    let want = oracle::pagerank(&g, 1e-12);
    let tol = 1e-8;
    let check = |name: &str, values: &[f64], bound: f64| {
        let err: f64 =
            values.iter().zip(&want).map(|(x, y)| (x - y).abs()).sum::<f64>() / want.len() as f64;
        assert!(err < bound, "{name}: avg err {err}");
    };
    for (kind, r) in runner.compare(
        &[EngineKind::Hama, EngineKind::AmHama],
        &IncrementalPageRank { tolerance: tol },
    ) {
        check(&kind.to_string(), &r.values, 1e-5);
    }
    check(
        "graphhp",
        &runner.run_on(EngineKind::GraphHP, &IncrementalPageRank { tolerance: tol }).values,
        1e-4,
    );
    check(
        "giraph++",
        &runner.run_partition(&GiraphPPPageRank { tolerance: tol }).values,
        1e-4,
    );
    check(
        "graphlab-sync",
        &runner.run_gas_on(EngineKind::GraphLabSync, &GasPageRank { tolerance: 1e-10 }).values,
        1e-5,
    );
    check(
        "graphlab-async",
        &runner.run_gas_on(EngineKind::GraphLabAsync, &GasPageRank { tolerance: 1e-10 }).values,
        1e-5,
    );
}

#[test]
fn pagerank_iteration_ordering_matches_paper() {
    // the paper's Table 4 ordering: GraphHP < Giraph++ < GraphLab sync
    let g = generators::powerlaw(5_000, 5, 23);
    let mut runner = runner(&g, 8);
    let tol = 1e-4;
    let p = runner.run_on(EngineKind::GraphHP, &IncrementalPageRank { tolerance: tol });
    let gpp = runner.run_partition(&GiraphPPPageRank { tolerance: tol });
    let s = runner.run_gas_on(EngineKind::GraphLabSync, &GasPageRank { tolerance: tol });
    assert!(
        p.metrics.global_iterations <= gpp.metrics.global_iterations,
        "graphhp {} vs giraph++ {}",
        p.metrics.global_iterations,
        gpp.metrics.global_iterations
    );
    assert!(
        gpp.metrics.global_iterations < s.metrics.global_iterations,
        "giraph++ {} vs graphlab {}",
        gpp.metrics.global_iterations,
        s.metrics.global_iterations
    );
}

// ----------------------------------------------------------------- WCC

#[test]
fn wcc_multi_component_all_engines() {
    // build several disconnected communities
    let mut b = graphhp::graph::GraphBuilder::new(600);
    let mut rng = graphhp::util::Rng::new(31);
    for c in 0..6u32 {
        let base = c * 100;
        for i in 1..100u32 {
            let parent = base + rng.gen_range(i as u64) as u32;
            b.add_undirected(base + i, parent, 1.0);
        }
    }
    let g = b.build();
    let want = oracle::wcc_labels(&g);
    let mut runner = runner(&g, 6);
    for (kind, r) in runner.compare(&EngineKind::VERTEX_CENTRIC, &Wcc) {
        assert_eq!(r.values, want, "{kind}");
    }
}

// ------------------------------------------------------------ Matching

#[test]
fn bipartite_matching_all_engines_valid_and_maximal() {
    let (nl, nr) = (150usize, 130usize);
    let g = generators::bipartite(nl, nr, 3, 41);
    let mut runner = runner(&g, 6);
    let prog = BipartiteMatching { num_left: nl as u32 };
    let greedy = oracle::greedy_matching_size(&g, nl as u32);
    for (kind, r) in
        runner.compare(&[EngineKind::Hama, EngineKind::AmHama, EngineKind::GraphHP], &prog)
    {
        let size = validate_matching(&g, nl as u32, &r.values)
            .unwrap_or_else(|e| panic!("{kind}: {e}"));
        // any maximal matching is >= half the maximum >= half of greedy
        assert!(size * 2 >= greedy, "{kind}: size {size} vs greedy {greedy}");
    }
}

// ------------------------------------------------------------ Coloring

#[test]
fn coloring_all_engines_proper() {
    let g = generators::delaunay_like(16, 16, 7);
    let mut runner = runner(&g, 4);
    for (kind, r) in
        runner.compare(&[EngineKind::Hama, EngineKind::AmHama, EngineKind::GraphHP], &Coloring)
    {
        assert!(is_proper_coloring(&g, &r.values), "{kind}");
    }
}

// ----------------------------------------------------- paper invariants

#[test]
fn graphhp_beats_hama_on_iterations_across_workloads() {
    // road SSSP
    let g = generators::road(40, 40, 1);
    let mut r = runner(&g, 8);
    let h = r.run_on(EngineKind::Hama, &Sssp { source: 0 });
    let p = r.run_on(EngineKind::GraphHP, &Sssp { source: 0 });
    assert!(p.metrics.global_iterations * 3 <= h.metrics.global_iterations);
    // web PageRank
    let g = generators::powerlaw(3_000, 5, 3);
    let mut r = runner(&g, 8);
    let h = r.run_on(EngineKind::Hama, &IncrementalPageRank { tolerance: 1e-5 });
    let p = r.run_on(EngineKind::GraphHP, &IncrementalPageRank { tolerance: 1e-5 });
    assert!(p.metrics.global_iterations < h.metrics.global_iterations);
    assert!(p.metrics.network_messages <= h.metrics.network_messages);
}

#[test]
fn hash_partitioning_erases_most_of_the_gain() {
    // the local phase exploits locality; hash partitioning should shrink
    // the iteration gap vs metis (ablation as a regression test)
    let g = generators::road(40, 40, 2);
    let k = 8;
    let pm = runner(&g, k).run(&Sssp { source: 0 });
    let ph = Runner::new(&g)
        .partitions(k)
        .partitioner(Partitioner::Hash)
        .run(&Sssp { source: 0 });
    assert!(
        pm.metrics.global_iterations < ph.metrics.global_iterations,
        "metis {} vs hash {}",
        pm.metrics.global_iterations,
        ph.metrics.global_iterations
    );
}

#[test]
fn cli_binary_smoke() {
    // generate -> partition -> run through the real binary
    let exe = env!("CARGO_BIN_EXE_graphhp");
    let dir = std::env::temp_dir().join("graphhp_cli_smoke");
    std::fs::create_dir_all(&dir).unwrap();
    let gpath = dir.join("g.bin");
    let out = std::process::Command::new(exe)
        .args(["generate", "--kind", "road", "--rows", "30", "--cols", "30", "--out"])
        .arg(&gpath)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let out = std::process::Command::new(exe)
        .args(["run", "--graph"])
        .arg(&gpath)
        .args(["--algo", "sssp", "--engine", "graphhp", "--parts", "6"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("vertices reached"), "{stdout}");
}

#[test]
fn cli_runs_every_engine_kind() {
    // the Runner-backed CLI dispatches all six kinds, GAS forms included
    let exe = env!("CARGO_BIN_EXE_graphhp");
    let dir = std::env::temp_dir().join("graphhp_cli_kinds");
    std::fs::create_dir_all(&dir).unwrap();
    let gpath = dir.join("g.bin");
    let out = std::process::Command::new(exe)
        .args(["generate", "--kind", "erdos", "--n", "200", "--m", "800", "--out"])
        .arg(&gpath)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    for engine in
        ["hama", "am-hama", "graphhp", "giraph++", "graphlab-sync", "graphlab-async"]
    {
        let out = std::process::Command::new(exe)
            .args(["run", "--graph"])
            .arg(&gpath)
            .args(["--algo", "pagerank", "--engine", engine, "--parts", "4"])
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "engine {engine}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
    }
}
