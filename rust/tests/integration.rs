//! Cross-module integration tests: every algorithm on every engine on
//! real (generated) graphs, verified against sequential oracles.

use graphhp::algorithms::bipartite_matching::{validate_matching, BipartiteMatching};
use graphhp::algorithms::coloring::{is_proper_coloring, Coloring};
use graphhp::algorithms::pagerank::{GasPageRank, GiraphPPPageRank};
use graphhp::algorithms::{oracle, IncrementalPageRank, Sssp, Wcc};
use graphhp::engine::giraphpp::VertexSweep;
use graphhp::engine::{am_hama, giraphpp, graphhp as hp, graphlab, hama, EngineConfig};
use graphhp::graph::{generators, DistGraph, Graph};
use graphhp::partition::{hash_partition, metis_partition, MetisConfig};

fn dist(g: &Graph, k: usize) -> DistGraph {
    let a = metis_partition(g, k, &MetisConfig::default());
    DistGraph::new(g, &a, k)
}

// ---------------------------------------------------------------- SSSP

fn sssp_all_engines(g: &Graph, k: usize, source: u32) {
    let dg = dist(g, k);
    let cfg = EngineConfig::default();
    let want = oracle::dijkstra(g, source);
    let prog = Sssp { source };
    for (name, values) in [
        ("hama", hama::run_hama(&prog, &dg, &cfg).values),
        ("am-hama", am_hama::run_am_hama(&prog, &dg, &cfg).values),
        ("graphhp", hp::run_graphhp(&prog, &dg, &cfg).values),
        (
            "giraph++",
            giraphpp::run_giraphpp(&VertexSweep { program: Sssp { source }, seed: 5 }, &dg, &cfg)
                .values,
        ),
    ] {
        for (i, (&got, &w)) in values.iter().zip(&want).enumerate() {
            if w.is_finite() {
                assert!((got - w as f32).abs() < 1e-2, "{name} v{i}: {got} vs {w}");
            } else {
                assert!(got >= 1e29, "{name} v{i}: expected inf");
            }
        }
    }
}

#[test]
fn sssp_on_road_graph_all_engines() {
    sssp_all_engines(&generators::road(25, 25, 3), 5, 0);
}

#[test]
fn sssp_on_random_connected_graph_all_engines() {
    sssp_all_engines(&generators::connected(400, 200, 9), 7, 13);
}

#[test]
fn sssp_on_powerlaw_all_engines() {
    sssp_all_engines(&generators::powerlaw(500, 4, 11), 4, 2);
}

// ------------------------------------------------------------ PageRank

#[test]
fn pagerank_all_engines_agree_with_power_iteration() {
    let g = generators::powerlaw(800, 4, 17);
    let k = 5;
    let a = metis_partition(&g, k, &MetisConfig::default());
    let dg = DistGraph::new(&g, &a, k);
    let cfg = EngineConfig::default();
    let want = oracle::pagerank(&g, 1e-12);
    let tol = 1e-8;
    let check = |name: &str, values: &[f64], bound: f64| {
        let err: f64 =
            values.iter().zip(&want).map(|(x, y)| (x - y).abs()).sum::<f64>() / want.len() as f64;
        assert!(err < bound, "{name}: avg err {err}");
    };
    check(
        "hama",
        &hama::run_hama(&IncrementalPageRank { tolerance: tol }, &dg, &cfg).values,
        1e-5,
    );
    check(
        "am-hama",
        &am_hama::run_am_hama(&IncrementalPageRank { tolerance: tol }, &dg, &cfg).values,
        1e-5,
    );
    check(
        "graphhp",
        &hp::run_graphhp(&IncrementalPageRank { tolerance: tol }, &dg, &cfg).values,
        1e-4,
    );
    check(
        "giraph++",
        &giraphpp::run_giraphpp(&GiraphPPPageRank { tolerance: tol }, &dg, &cfg).values,
        1e-4,
    );
    check(
        "graphlab-sync",
        &graphlab::run_graphlab_sync(
            &GasPageRank { tolerance: 1e-10 },
            &g,
            &a,
            k,
            &cfg,
            &graphlab::GraphLabCost::default(),
        )
        .values,
        1e-5,
    );
    check(
        "graphlab-async",
        &graphlab::run_graphlab_async(
            &GasPageRank { tolerance: 1e-10 },
            &g,
            &a,
            k,
            &cfg,
            &graphlab::GraphLabCost::default(),
        )
        .values,
        1e-5,
    );
}

#[test]
fn pagerank_iteration_ordering_matches_paper() {
    // the paper's Table 4 ordering: GraphHP < Giraph++ < GraphLab sync
    let g = generators::powerlaw(5_000, 5, 23);
    let k = 8;
    let a = metis_partition(&g, k, &MetisConfig::default());
    let dg = DistGraph::new(&g, &a, k);
    let cfg = EngineConfig::default();
    let tol = 1e-4;
    let p = hp::run_graphhp(&IncrementalPageRank { tolerance: tol }, &dg, &cfg);
    let gpp = giraphpp::run_giraphpp(&GiraphPPPageRank { tolerance: tol }, &dg, &cfg);
    let s = graphlab::run_graphlab_sync(
        &GasPageRank { tolerance: tol },
        &g,
        &a,
        k,
        &cfg,
        &graphlab::GraphLabCost::default(),
    );
    assert!(
        p.metrics.global_iterations <= gpp.metrics.global_iterations,
        "graphhp {} vs giraph++ {}",
        p.metrics.global_iterations,
        gpp.metrics.global_iterations
    );
    assert!(
        gpp.metrics.global_iterations < s.metrics.global_iterations,
        "giraph++ {} vs graphlab {}",
        gpp.metrics.global_iterations,
        s.metrics.global_iterations
    );
}

// ----------------------------------------------------------------- WCC

#[test]
fn wcc_multi_component_all_engines() {
    // build several disconnected communities
    let mut b = graphhp::graph::GraphBuilder::new(600);
    let mut rng = graphhp::util::Rng::new(31);
    for c in 0..6u32 {
        let base = c * 100;
        for i in 1..100u32 {
            let parent = base + rng.gen_range(i as u64) as u32;
            b.add_undirected(base + i, parent, 1.0);
        }
    }
    let g = b.build();
    let want = oracle::wcc_labels(&g);
    let dg = dist(&g, 6);
    let cfg = EngineConfig::default();
    assert_eq!(hama::run_hama(&Wcc, &dg, &cfg).values, want);
    assert_eq!(am_hama::run_am_hama(&Wcc, &dg, &cfg).values, want);
    assert_eq!(hp::run_graphhp(&Wcc, &dg, &cfg).values, want);
    assert_eq!(
        giraphpp::run_giraphpp(&VertexSweep { program: Wcc, seed: 3 }, &dg, &cfg).values,
        want
    );
}

// ------------------------------------------------------------ Matching

#[test]
fn bipartite_matching_all_engines_valid_and_maximal() {
    let (nl, nr) = (150usize, 130usize);
    let g = generators::bipartite(nl, nr, 3, 41);
    let dg = dist(&g, 6);
    let cfg = EngineConfig::default();
    let prog = BipartiteMatching { num_left: nl as u32 };
    let greedy = oracle::greedy_matching_size(&g, nl as u32);
    for (name, values) in [
        ("hama", hama::run_hama(&prog, &dg, &cfg).values),
        ("am-hama", am_hama::run_am_hama(&prog, &dg, &cfg).values),
        ("graphhp", hp::run_graphhp(&prog, &dg, &cfg).values),
    ] {
        let size =
            validate_matching(&g, nl as u32, &values).unwrap_or_else(|e| panic!("{name}: {e}"));
        // any maximal matching is >= half the maximum >= half of greedy
        assert!(size * 2 >= greedy, "{name}: size {size} vs greedy {greedy}");
    }
}

// ------------------------------------------------------------ Coloring

#[test]
fn coloring_all_engines_proper() {
    let g = generators::delaunay_like(16, 16, 7);
    let dg = dist(&g, 4);
    let cfg = EngineConfig::default();
    assert!(is_proper_coloring(&g, &hama::run_hama(&Coloring, &dg, &cfg).values));
    assert!(is_proper_coloring(&g, &am_hama::run_am_hama(&Coloring, &dg, &cfg).values));
    assert!(is_proper_coloring(&g, &hp::run_graphhp(&Coloring, &dg, &cfg).values));
}

// ----------------------------------------------------- paper invariants

#[test]
fn graphhp_beats_hama_on_iterations_across_workloads() {
    let cfg = EngineConfig::default();
    // road SSSP
    let g = generators::road(40, 40, 1);
    let dg = dist(&g, 8);
    let h = hama::run_hama(&Sssp { source: 0 }, &dg, &cfg);
    let p = hp::run_graphhp(&Sssp { source: 0 }, &dg, &cfg);
    assert!(p.metrics.global_iterations * 3 <= h.metrics.global_iterations);
    // web PageRank
    let g = generators::powerlaw(3_000, 5, 3);
    let dg = dist(&g, 8);
    let h = hama::run_hama(&IncrementalPageRank { tolerance: 1e-5 }, &dg, &cfg);
    let p = hp::run_graphhp(&IncrementalPageRank { tolerance: 1e-5 }, &dg, &cfg);
    assert!(p.metrics.global_iterations < h.metrics.global_iterations);
    assert!(p.metrics.network_messages <= h.metrics.network_messages);
}

#[test]
fn hash_partitioning_erases_most_of_the_gain() {
    // the local phase exploits locality; hash partitioning should shrink
    // the iteration gap vs metis (ablation as a regression test)
    let g = generators::road(40, 40, 2);
    let cfg = EngineConfig::default();
    let k = 8;
    let dm = DistGraph::new(&g, &metis_partition(&g, k, &MetisConfig::default()), k);
    let dh = DistGraph::new(&g, &hash_partition(&g, k), k);
    let pm = hp::run_graphhp(&Sssp { source: 0 }, &dm, &cfg);
    let ph = hp::run_graphhp(&Sssp { source: 0 }, &dh, &cfg);
    assert!(
        pm.metrics.global_iterations < ph.metrics.global_iterations,
        "metis {} vs hash {}",
        pm.metrics.global_iterations,
        ph.metrics.global_iterations
    );
}

#[test]
fn cli_binary_smoke() {
    // generate -> partition -> run through the real binary
    let exe = env!("CARGO_BIN_EXE_graphhp");
    let dir = std::env::temp_dir().join("graphhp_cli_smoke");
    std::fs::create_dir_all(&dir).unwrap();
    let gpath = dir.join("g.bin");
    let out = std::process::Command::new(exe)
        .args(["generate", "--kind", "road", "--rows", "30", "--cols", "30", "--out"])
        .arg(&gpath)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let out = std::process::Command::new(exe)
        .args(["run", "--graph"])
        .arg(&gpath)
        .args(["--algo", "sssp", "--engine", "graphhp", "--parts", "6"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("vertices reached"), "{stdout}");
}
