//! Liveness fixtures for the `detlint` determinism rules.
//!
//! Each rule R1–R6 gets one known-bad snippet proving the rule actually
//! fires — at the right line, with the right rule id — plus checks that
//! suppression annotations and path scoping behave. The final test runs
//! the linter over this crate's real `src/` tree and requires zero
//! findings: the repo must stay clean under its own contract.

use graphhp::lint::{lint_source, lint_tree, Finding, RuleId};

/// Assert exactly one finding of `rule` at `line` (ignoring none else).
fn assert_fires(findings: &[Finding], rule: RuleId, line: usize) {
    let hits: Vec<_> = findings.iter().filter(|f| f.rule == rule).collect();
    assert_eq!(
        hits.len(),
        1,
        "expected exactly one {rule} finding, got {:?}",
        findings
    );
    assert_eq!(hits[0].line, line, "wrong line for {rule}: {:?}", hits[0]);
}

// ---- R1: unordered-iter ------------------------------------------------

#[test]
fn r1_hash_container_decl_in_engine_fires() {
    let src = "use std::collections::HashMap;\n\
               struct S {\n\
                   index: HashMap<u32, u32>,\n\
               }\n";
    let f = lint_source("engine/fake.rs", src);
    assert_fires(&f, RuleId::UnorderedIter, 3);
}

#[test]
fn r1_iteration_over_tracked_container_fires() {
    let src = "fn f() {\n\
                   let mut seen: HashMap<u32, u32> = HashMap::new();\n\
                   for (k, v) in &seen {\n\
                       use_it(k, v);\n\
                   }\n\
               }\n";
    let f = lint_source("partition/fake.rs", src);
    // line 2: the declaration; line 3: the iteration — both fire, and
    // annotating the declaration alone would NOT silence the iteration
    assert_eq!(
        f.iter().filter(|x| x.rule == RuleId::UnorderedIter).count(),
        2,
        "decl and iteration are independent findings: {f:?}"
    );
    assert!(f.iter().any(|x| x.rule == RuleId::UnorderedIter && x.line == 3));
}

#[test]
fn r1_method_iteration_fires() {
    let src = "fn f(seen: &mut S) {\n\
                   let mut live: HashSet<u32> = HashSet::new();\n\
                   let v: Vec<_> = live.iter().collect();\n\
               }\n";
    let f = lint_source("engine/fake.rs", src);
    assert!(
        f.iter().any(|x| x.rule == RuleId::UnorderedIter && x.line == 3),
        "{f:?}"
    );
}

#[test]
fn r1_is_scoped_to_engine_and_partition() {
    let src = "struct S { index: HashMap<u32, u32> }\n";
    assert!(
        lint_source("util/fake.rs", src).is_empty(),
        "util/ is outside the deterministic core"
    );
    assert!(!lint_source("engine/nested/fake.rs", src).is_empty());
}

// ---- R2: wall-clock ----------------------------------------------------

#[test]
fn r2_wall_clock_read_fires() {
    let src = "fn f() {\n    let t0 = std::time::Instant::now();\n}\n";
    let f = lint_source("engine/fake.rs", src);
    assert_fires(&f, RuleId::WallClock, 2);

    let sys = "fn f() -> u64 {\n    let t = SystemTime::now();\n    0\n}\n";
    let f = lint_source("util/fake.rs", sys);
    assert_fires(&f, RuleId::WallClock, 2);
}

#[test]
fn r2_runtime_module_is_exempt() {
    let src = "fn f() {\n    let t0 = std::time::Instant::now();\n}\n";
    assert!(
        lint_source("runtime/fake.rs", src).is_empty(),
        "runtime/ is xla-gated accelerator code, outside the contract"
    );
}

// ---- R3: step-pairing --------------------------------------------------

#[test]
fn r3_unpaired_begin_step_fires() {
    let src = "fn f(rt: &mut Rt) {\n\
                   rt.begin_step();\n\
                   do_work();\n\
               }\n";
    let f = lint_source("engine/fake.rs", src);
    assert_fires(&f, RuleId::StepPairing, 2);
}

#[test]
fn r3_paired_begin_step_is_clean() {
    let commit = "fn f(rt: &mut Rt) {\n\
                      rt.begin_step();\n\
                      rt.commit_step();\n\
                  }\n";
    assert!(lint_source("engine/fake.rs", commit).is_empty());

    let abort = "fn f(rt: &mut Rt, wl: &mut Worklist) {\n\
                     rt.begin_step_into(wl);\n\
                     rt.abort_step_carryover(wl.as_slice().iter().copied());\n\
                 }\n";
    assert!(lint_source("engine/fake.rs", abort).is_empty());
}

#[test]
fn r3_closer_in_nested_block_still_pairs() {
    // the pairing is per-function, not per-block: a commit inside a
    // loop/if in the same fn satisfies the opener
    let src = "fn f(rt: &mut Rt) {\n\
                   loop {\n\
                       rt.begin_step();\n\
                       if done() {\n\
                           rt.commit_step();\n\
                           break;\n\
                       }\n\
                       rt.commit_step();\n\
                   }\n\
               }\n";
    assert!(lint_source("engine/fake.rs", src).is_empty());
}

// ---- R4: thread-confinement -------------------------------------------

#[test]
fn r4_thread_spawn_outside_worker_fires() {
    let src = "fn f() {\n    std::thread::spawn(|| {});\n}\n";
    let f = lint_source("engine/fake.rs", src);
    assert_fires(&f, RuleId::ThreadConfinement, 2);
}

#[test]
fn r4_worker_rs_is_exempt() {
    let src = "fn f() {\n    std::thread::scope(|s| {});\n}\n";
    assert!(
        lint_source("engine/worker.rs", src).is_empty(),
        "worker.rs is the one sanctioned threading site"
    );
}

// ---- R5: unwrap-hot-path ----------------------------------------------

#[test]
fn r5_unwrap_in_hot_module_fires() {
    let src = "fn f(o: Option<u32>) -> u32 {\n    o.unwrap()\n}\n";
    let f = lint_source("engine/messages.rs", src);
    assert_fires(&f, RuleId::UnwrapHotPath, 2);

    let exp = "fn f(o: Option<u32>) -> u32 {\n    o.expect(\"present\")\n}\n";
    let f = lint_source("engine/state.rs", exp);
    assert_fires(&f, RuleId::UnwrapHotPath, 2);
}

#[test]
fn r5_scoped_to_hot_files_and_test_code() {
    let src = "fn f(o: Option<u32>) -> u32 {\n    o.unwrap()\n}\n";
    assert!(
        lint_source("engine/hama.rs", src).is_empty(),
        "only worker/messages/state are hot-path files"
    );
    let test_src = "#[cfg(test)]\nmod tests {\n    fn f(o: Option<u32>) -> u32 {\n        o.unwrap()\n    }\n}\n";
    assert!(lint_source("engine/messages.rs", test_src).is_empty());
}

// ---- R6: stale-route ---------------------------------------------------

#[test]
fn r6_route_binding_before_commit_fires() {
    let src = "fn f(rt: &mut Rt, dg: &DistGraph, v: usize) {\n\
                   let (tp, tl) = dg.routing.location[v];\n\
                   rt.begin_step();\n\
                   rt.commit_step();\n\
                   send(tp, tl);\n\
               }\n";
    let f = lint_source("engine/fake.rs", src);
    assert_fires(&f, RuleId::StaleRoute, 2);
}

#[test]
fn r6_edge_route_and_route_iter_bindings_fire() {
    let src = "fn f(rt: &mut Rt, part: &PartGraph) {\n\
                   let r: EdgeRoute = part.routes[0];\n\
                   rt.begin_step();\n\
                   rt.commit_step();\n\
               }\n";
    let f = lint_source("engine/fake.rs", src);
    assert_fires(&f, RuleId::StaleRoute, 2);

    let src = "fn f(rt: &mut Rt, part: &PartGraph, lv: usize) {\n\
                   let cached: Vec<_> = part.out_edges(lv).route_iter().collect();\n\
                   rt.begin_step();\n\
                   rt.commit_step();\n\
               }\n";
    let f = lint_source("partition/fake.rs", src);
    assert_fires(&f, RuleId::StaleRoute, 2);
}

#[test]
fn r6_binding_after_commit_is_clean() {
    // re-reading the table AFTER the commit is exactly the sanctioned
    // pattern — the binding observes the post-barrier epoch
    let src = "fn f(rt: &mut Rt, dg: &DistGraph, v: usize) {\n\
                   rt.begin_step();\n\
                   rt.commit_step();\n\
                   let (tp, tl) = dg.routing.location[v];\n\
                   send(tp, tl);\n\
               }\n";
    assert!(lint_source("engine/fake.rs", src).is_empty());
}

#[test]
fn r6_no_commit_in_frame_is_clean() {
    // a pure reader (no step commit anywhere in the fn) never crosses
    // an epoch boundary
    let src = "fn resolve(dg: &DistGraph, v: usize) -> (u32, u32) {\n\
                   let (tp, tl) = dg.routing.location[v];\n\
                   (tp, tl)\n\
               }\n";
    assert!(lint_source("engine/fake.rs", src).is_empty());
}

#[test]
fn r6_scoping_and_worker_exemption() {
    let src = "fn f(rt: &mut Rt, dg: &DistGraph, v: usize) {\n\
                   let (tp, tl) = dg.routing.location[v];\n\
                   rt.begin_step();\n\
                   rt.commit_step();\n\
               }\n";
    assert!(
        lint_source("engine/worker.rs", src).is_empty(),
        "the sweep core is the sanctioned route reader"
    );
    assert!(
        lint_source("runtime/fake.rs", src).is_empty(),
        "runtime/ is outside the deterministic core"
    );
}

#[test]
fn r6_reasoned_allow_suppresses() {
    let src = "fn f(rt: &mut Rt, dg: &DistGraph, v: usize) {\n\
                   let (tp, tl) = dg.routing.location[v]; // detlint: allow(stale-route) — consumed before the commit below\n\
                   rt.begin_step();\n\
                   rt.commit_step();\n\
               }\n";
    assert!(lint_source("engine/fake.rs", src).is_empty());
}

// ---- suppression annotations ------------------------------------------

#[test]
fn reasoned_allow_suppresses_same_line() {
    let src = "fn f() {\n    let t0 = Instant::now(); // detlint: allow(wall-clock) — telemetry probe\n}\n";
    assert!(lint_source("engine/fake.rs", src).is_empty());
}

#[test]
fn reasoned_allow_on_comment_line_suppresses_next_code_line() {
    let src = "fn f() {\n\
                   // detlint: allow(wall-clock) — telemetry probe: feeds\n\
                   // metrics only, never results.\n\
                   let t0 = Instant::now();\n\
               }\n";
    assert!(lint_source("engine/fake.rs", src).is_empty());
}

#[test]
fn allow_for_a_different_rule_does_not_suppress() {
    let src = "fn f() {\n    let t0 = Instant::now(); // detlint: allow(unordered-iter) — wrong rule\n}\n";
    let f = lint_source("engine/fake.rs", src);
    assert!(f.iter().any(|x| x.rule == RuleId::WallClock), "{f:?}");
}

#[test]
fn reasonless_allow_is_inert_and_reported() {
    let src = "fn f() {\n    let t0 = Instant::now(); // detlint: allow(wall-clock)\n}\n";
    let f = lint_source("engine/fake.rs", src);
    assert!(f.iter().any(|x| x.rule == RuleId::WallClock), "inert: {f:?}");
    assert!(f.iter().any(|x| x.rule == RuleId::Annotation), "reported: {f:?}");
}

#[test]
fn unknown_rule_name_is_reported() {
    let src = "let a = 1; // detlint: allow(made-up) — reason text\n";
    let f = lint_source("engine/fake.rs", src);
    assert_fires(&f, RuleId::Annotation, 1);
}

// ---- the real tree ----------------------------------------------------

#[test]
fn repo_source_tree_is_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let findings = lint_tree(&root).expect("scan src tree");
    assert!(
        findings.is_empty(),
        "detlint found unannotated violations in src/:\n{}",
        findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}
