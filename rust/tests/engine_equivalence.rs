//! Engine-equivalence property tests: for confluent vertex programs
//! (SSSP, WCC — results independent of message timing), ALL engines must
//! produce identical final states on random graphs under random
//! partitionings; for PageRank the results must agree within
//! tolerance-bounded error. Hand-rolled property harness (the vendored
//! crate set has no proptest) over the crate's deterministic RNG.
//!
//! Also proves the [`Runner`] session dispatches every [`EngineKind`] to
//! exactly the legacy free-function path (bit-for-bit equal results) —
//! the one place the deprecated free functions are still called
//! deliberately.

use graphhp::algorithms::{oracle, GasPageRank, GasSssp, GasWcc, IncrementalPageRank, Sssp, Wcc};
use graphhp::engine::giraphpp::VertexSweep;
use graphhp::engine::{
    am_hama, giraphpp, graphhp as hp, graphlab, hama, EngineConfig, EngineKind, Runner,
};
use graphhp::graph::{generators, DistGraph, Graph};
use graphhp::partition::{hash_partition, metis_partition, MetisConfig};
use graphhp::util::Rng;

/// Random test-case source: graph + partitioning + config knobs.
struct CaseGen {
    rng: Rng,
}

impl CaseGen {
    fn new(seed: u64) -> Self {
        CaseGen { rng: Rng::new(seed) }
    }

    fn graph(&mut self) -> Graph {
        let pick = self.rng.index(4);
        let seed = self.rng.next_u64();
        match pick {
            0 => generators::connected(60 + self.rng.index(200), self.rng.index(120), seed),
            1 => generators::road(5 + self.rng.index(12), 5 + self.rng.index(12), seed),
            2 => generators::powerlaw(60 + self.rng.index(300), 2 + self.rng.index(4), seed),
            _ => generators::delaunay_like(4 + self.rng.index(10), 4 + self.rng.index(10), seed),
        }
    }

    fn dist(&mut self, g: &Graph) -> DistGraph {
        let k = 1 + self.rng.index(6);
        let a = if self.rng.chance(0.5) {
            hash_partition(g, k)
        } else {
            metis_partition(g, k, &MetisConfig { seed: self.rng.next_u64(), ..Default::default() })
        };
        DistGraph::new(g, &a, k)
    }

    fn config(&mut self) -> EngineConfig {
        let mut cfg = EngineConfig::default();
        cfg.hybrid.set_boundary_in_local_phase(self.rng.chance(0.7));
        cfg.hybrid.set_async_local_messaging(self.rng.chance(0.7));
        cfg
    }
}

const CASES: usize = 25;

#[test]
fn sssp_identical_across_engines_on_random_cases() {
    let mut gen = CaseGen::new(0xC0FFEE);
    for case in 0..CASES {
        let g = gen.graph();
        let dg = gen.dist(&g);
        let cfg = gen.config();
        let source = (gen.rng.index(g.num_vertices())) as u32;
        let prog = Sssp { source };
        let h = hama::run_hama(&prog, &dg, &cfg).values;
        let a = am_hama::run_am_hama(&prog, &dg, &cfg).values;
        let p = hp::run_graphhp(&prog, &dg, &cfg).values;
        // min-fixed-point: bitwise identical across engines
        assert_eq!(h, a, "case {case}: hama vs am-hama");
        assert_eq!(h, p, "case {case}: hama vs graphhp (cfg {cfg:?})");
        // and correct
        let want = oracle::dijkstra(&g, source);
        for (i, (&got, &w)) in h.iter().zip(&want).enumerate() {
            if w.is_finite() {
                assert!((got - w as f32).abs() < 1e-2, "case {case} v{i}");
            }
        }
    }
}

#[test]
fn wcc_identical_across_engines_on_random_cases() {
    let mut gen = CaseGen::new(0xBEEF);
    for case in 0..CASES {
        let g = gen.graph();
        let dg = gen.dist(&g);
        let cfg = gen.config();
        let h = hama::run_hama(&Wcc, &dg, &cfg).values;
        let a = am_hama::run_am_hama(&Wcc, &dg, &cfg).values;
        let p = hp::run_graphhp(&Wcc, &dg, &cfg).values;
        let gpp =
            giraphpp::run_giraphpp(&VertexSweep { program: Wcc, seed: 1 }, &dg, &cfg).values;
        assert_eq!(h, a, "case {case}");
        assert_eq!(h, p, "case {case}");
        assert_eq!(h, gpp, "case {case}");
    }
}

#[test]
fn pagerank_close_across_engines_on_random_cases() {
    let mut gen = CaseGen::new(0xFACADE);
    for case in 0..10 {
        let g = gen.graph();
        let dg = gen.dist(&g);
        let cfg = gen.config();
        let prog = IncrementalPageRank { tolerance: 1e-9 };
        let h = hama::run_hama(&prog, &dg, &cfg).values;
        let p = hp::run_graphhp(&prog, &dg, &cfg).values;
        let a = am_hama::run_am_hama(&prog, &dg, &cfg).values;
        for i in 0..h.len() {
            assert!((h[i] - p[i]).abs() < 1e-5, "case {case} v{i}: {} vs {}", h[i], p[i]);
            assert!((h[i] - a[i]).abs() < 1e-5, "case {case} v{i}");
        }
    }
}

#[test]
fn graphhp_iterations_never_exceed_hama_on_confluent_programs() {
    // the hybrid model can only collapse supersteps, never add barriers
    let mut gen = CaseGen::new(0xDA7A);
    for case in 0..15 {
        let g = gen.graph();
        let dg = gen.dist(&g);
        let cfg = EngineConfig::default();
        let source = (gen.rng.index(g.num_vertices())) as u32;
        let h = hama::run_hama(&Sssp { source }, &dg, &cfg);
        let p = hp::run_graphhp(&Sssp { source }, &dg, &cfg);
        assert!(
            p.metrics.global_iterations <= h.metrics.global_iterations,
            "case {case}: graphhp {} > hama {}",
            p.metrics.global_iterations,
            h.metrics.global_iterations
        );
    }
}

#[test]
fn all_engines_terminate_on_random_inputs() {
    // no deadlock / livelock: bounded iterations on arbitrary cases
    let mut gen = CaseGen::new(0x7E57);
    for _ in 0..15 {
        let g = gen.graph();
        let dg = gen.dist(&g);
        let mut cfg = gen.config();
        cfg.limits.max_iterations = 100_000;
        let source = (gen.rng.index(g.num_vertices())) as u32;
        for m in [
            hama::run_hama(&Sssp { source }, &dg, &cfg).metrics,
            am_hama::run_am_hama(&Sssp { source }, &dg, &cfg).metrics,
            hp::run_graphhp(&Sssp { source }, &dg, &cfg).metrics,
        ] {
            assert!(m.global_iterations < 100_000, "engine hit the cap");
        }
    }
}

// ---------------------------------------------------- Runner == legacy

/// The Runner must dispatch to exactly the code the legacy free
/// functions run: values AND iteration counts bit-for-bit equal for
/// PageRank, SSSP and WCC on every one of the six `EngineKind`s.
#[test]
fn runner_matches_legacy_free_functions_on_all_six_kinds() {
    let mut gen = CaseGen::new(0x12A55);
    for case in 0..8 {
        let g = gen.graph();
        let dg = gen.dist(&g);
        let cfg = gen.config();
        let source = (gen.rng.index(g.num_vertices())) as u32;

        // one session over the SAME distributed view + cfg as the legacy calls
        let mut runner = Runner::from_dist(&dg).config(cfg.clone());

        for kind in EngineKind::ALL {
            if kind.is_gas() {
                // pull-based kinds: GAS program forms
                let legacy_pr = match kind {
                    EngineKind::GraphLabSync => {
                        graphlab::run_graphlab_sync(&GasPageRank { tolerance: 1e-6 }, &dg, &cfg)
                    }
                    _ => {
                        graphlab::run_graphlab_async(&GasPageRank { tolerance: 1e-6 }, &dg, &cfg)
                    }
                };
                let via = runner.run_gas_on(kind, &GasPageRank { tolerance: 1e-6 });
                assert_eq!(via.values, legacy_pr.values, "case {case} {kind} pagerank");
                assert_eq!(
                    via.metrics.global_iterations, legacy_pr.metrics.global_iterations,
                    "case {case} {kind} pagerank iterations"
                );

                let legacy_sssp = match kind {
                    EngineKind::GraphLabSync => {
                        graphlab::run_graphlab_sync(&GasSssp { source }, &dg, &cfg)
                    }
                    _ => graphlab::run_graphlab_async(&GasSssp { source }, &dg, &cfg),
                };
                let via = runner.run_gas_on(kind, &GasSssp { source });
                assert_eq!(via.values, legacy_sssp.values, "case {case} {kind} sssp");

                let legacy_wcc = match kind {
                    EngineKind::GraphLabSync => graphlab::run_graphlab_sync(&GasWcc, &dg, &cfg),
                    _ => graphlab::run_graphlab_async(&GasWcc, &dg, &cfg),
                };
                let via = runner.run_gas_on(kind, &GasWcc);
                assert_eq!(via.values, legacy_wcc.values, "case {case} {kind} wcc");
                continue;
            }

            macro_rules! legacy {
                ($prog:expr) => {{
                    let prog = $prog;
                    match kind {
                        EngineKind::Hama => hama::run_hama(&prog, &dg, &cfg),
                        EngineKind::AmHama => am_hama::run_am_hama(&prog, &dg, &cfg),
                        EngineKind::GraphHP => hp::run_graphhp(&prog, &dg, &cfg),
                        EngineKind::GiraphPP => giraphpp::run_giraphpp(
                            &VertexSweep { program: prog, seed: cfg.seed },
                            &dg,
                            &cfg,
                        ),
                        _ => unreachable!(),
                    }
                }};
            }

            let legacy_pr = legacy!(IncrementalPageRank { tolerance: 1e-6 });
            let via = runner.run_on(kind, &IncrementalPageRank { tolerance: 1e-6 });
            assert_eq!(via.values, legacy_pr.values, "case {case} {kind} pagerank");
            assert_eq!(
                via.metrics.global_iterations, legacy_pr.metrics.global_iterations,
                "case {case} {kind} pagerank iterations"
            );

            let legacy_sssp = legacy!(Sssp { source });
            let via = runner.run_on(kind, &Sssp { source });
            assert_eq!(via.values, legacy_sssp.values, "case {case} {kind} sssp");

            let legacy_wcc = legacy!(Wcc);
            let via = runner.run_on(kind, &Wcc);
            assert_eq!(via.values, legacy_wcc.values, "case {case} {kind} wcc");
        }
    }
}
