//! Equivalence contract of the physical memory layouts and the
//! work-stealing mode (the bandwidth-bound sweep optimisations).
//!
//! Layouts ([`GraphLayout`]: identity/degree-sorted × SoA/compressed
//! edge columns) are *internal* renamings — user-visible vertex ids and
//! gathered results must not change:
//!
//! - min-fold programs (SSSP, WCC) are **bit-for-bit identical** across
//!   every layout — the fold result is order-free;
//! - floating-point-sum programs (PageRank) match within epsilon — the
//!   sweep visits vertices in a different local order, so same-partition
//!   f64 message folds associate differently;
//! - within any one layout, `Threads(n)` stays **bit-for-bit** identical
//!   to `Sequential` (the original determinism oracle, unchanged).
//!
//! [`Parallelism::WorkStealing`] relaxes only *thread assignment inside
//! a sweep* (chunked atomic claiming, serial ordered apply):
//!
//! - SSSP/WCC: bit-for-bit equal values vs `Sequential`;
//! - PageRank: within epsilon (chunk-local aggregator partials and the
//!   ThisSweep→next-sweep Jacobi deferral reassociate f64 sums);
//! - `WorkStealing(1)` ≡ `WorkStealing(n)` bit-for-bit, including every
//!   metric counter — thread count must be unobservable.

use graphhp::algorithms::{
    GasPageRank, GasSssp, GasWcc, IncrementalPageRank, Sssp, Wcc,
};
use graphhp::engine::{EngineConfig, EngineKind, Metrics, Parallelism, Runner};
use graphhp::graph::{generators, DistGraph, Graph, GraphLayout, LayoutPolicy};
use graphhp::partition::{metis_partition, MetisConfig};

/// Every layout configuration, named for assertion messages.
fn layouts() -> [(&'static str, GraphLayout); 4] {
    [
        ("identity", GraphLayout::default()),
        ("degree-sorted", GraphLayout::degree_sorted()),
        (
            "identity+compressed",
            GraphLayout { policy: LayoutPolicy::Identity, compress_edges: true },
        ),
        ("packed", GraphLayout::packed()),
    ]
}

fn dist(g: &Graph, k: usize, layout: GraphLayout) -> DistGraph {
    let a = metis_partition(g, k, &MetisConfig::default());
    DistGraph::with_layout(g, &a, k, layout)
}

fn cfg_with(par: Parallelism) -> EngineConfig {
    EngineConfig { parallelism: par, ..Default::default() }
}

fn graph_cases() -> Vec<(Graph, usize)> {
    vec![
        (generators::connected(300, 150, 7), 4),
        (generators::powerlaw(400, 4, 11), 6),
        (generators::road(18, 18, 3), 9),
    ]
}

/// Relative closeness for the floating-point-sum comparisons.
fn close(a: f64, b: f64, rtol: f64) -> bool {
    (a - b).abs() <= rtol * b.abs().max(1.0)
}

fn run_vertex<P: graphhp::engine::VertexProgram>(
    dg: &DistGraph,
    kind: EngineKind,
    par: Parallelism,
    prog: &P,
) -> graphhp::engine::RunResult<P::V> {
    Runner::from_dist(dg).config(cfg_with(par)).run_on(kind, prog)
}

/// The deterministic counters that must agree when two runs are claimed
/// bit-for-bit equivalent.
fn assert_counts_equal(label: &str, a: &Metrics, b: &Metrics) {
    assert_eq!(a.global_iterations, b.global_iterations, "{label}: iterations");
    assert_eq!(a.supersteps_total, b.supersteps_total, "{label}: supersteps");
    assert_eq!(a.network_messages, b.network_messages, "{label}: messages");
    assert_eq!(a.network_bytes, b.network_bytes, "{label}: bytes");
    assert_eq!(a.local_messages, b.local_messages, "{label}: local messages");
    assert_eq!(a.vertex_computations, b.vertex_computations, "{label}: computations");
}

/// Degree-sorted and compressed layouts return the same user-visible
/// results as the identity layout on all six engines: SSSP and WCC at
/// the bit level, PageRank within epsilon.
#[test]
fn layouts_preserve_results_on_all_six_kinds() {
    for (g, k) in &graph_cases() {
        let base = dist(g, *k, GraphLayout::default());
        for (lname, layout) in layouts().into_iter().skip(1) {
            let dg = dist(g, *k, layout);
            assert_eq!(dg.edge_cut(), base.edge_cut(), "{lname}: cut changed");
            for kind in EngineKind::ALL {
                let label = format!("{kind}/{lname}");
                if kind.is_gas() {
                    let s0 = Runner::from_dist(&base)
                        .config(cfg_with(Parallelism::Sequential))
                        .run_gas_on(kind, &GasSssp { source: 1 });
                    let s1 = Runner::from_dist(&dg)
                        .config(cfg_with(Parallelism::Sequential))
                        .run_gas_on(kind, &GasSssp { source: 1 });
                    assert_eq!(
                        s0.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        s1.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        "{label}: sssp"
                    );
                    let w0 = Runner::from_dist(&base)
                        .config(cfg_with(Parallelism::Sequential))
                        .run_gas_on(kind, &GasWcc);
                    let w1 = Runner::from_dist(&dg)
                        .config(cfg_with(Parallelism::Sequential))
                        .run_gas_on(kind, &GasWcc);
                    assert_eq!(w0.values, w1.values, "{label}: wcc");
                    let p0 = Runner::from_dist(&base)
                        .config(cfg_with(Parallelism::Sequential))
                        .run_gas_on(kind, &GasPageRank { tolerance: 1e-7 });
                    let p1 = Runner::from_dist(&dg)
                        .config(cfg_with(Parallelism::Sequential))
                        .run_gas_on(kind, &GasPageRank { tolerance: 1e-7 });
                    for (i, (a, b)) in p0.values.iter().zip(&p1.values).enumerate() {
                        assert!(close(*a, *b, 1e-6), "{label}: pagerank v{i} {a} vs {b}");
                    }
                } else {
                    let s0 =
                        run_vertex(&base, kind, Parallelism::Sequential, &Sssp { source: 1 });
                    let s1 =
                        run_vertex(&dg, kind, Parallelism::Sequential, &Sssp { source: 1 });
                    assert_eq!(
                        s0.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        s1.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        "{label}: sssp"
                    );
                    let w0 = run_vertex(&base, kind, Parallelism::Sequential, &Wcc);
                    let w1 = run_vertex(&dg, kind, Parallelism::Sequential, &Wcc);
                    assert_eq!(w0.values, w1.values, "{label}: wcc");
                    let pr = IncrementalPageRank { tolerance: 1e-7 };
                    let p0 = run_vertex(&base, kind, Parallelism::Sequential, &pr);
                    let p1 = run_vertex(&dg, kind, Parallelism::Sequential, &pr);
                    for (i, (a, b)) in p0.values.iter().zip(&p1.values).enumerate() {
                        assert!(close(*a, *b, 1e-6), "{label}: pagerank v{i} {a} vs {b}");
                    }
                }
            }
        }
    }
}

/// The original oracle, extended over every layout: within one layout,
/// `Threads(4)` is bit-for-bit identical to `Sequential` — values and
/// every deterministic metric counter.
#[test]
fn threads_stay_bit_identical_under_every_layout() {
    let g = generators::powerlaw(400, 4, 11);
    for (lname, layout) in layouts() {
        let dg = dist(&g, 6, layout);
        for kind in EngineKind::VERTEX_CENTRIC {
            let label = format!("{kind}/{lname}");
            let pr = IncrementalPageRank { tolerance: 1e-7 };
            let seq = run_vertex(&dg, kind, Parallelism::Sequential, &pr);
            let par = run_vertex(&dg, kind, Parallelism::Threads(4), &pr);
            assert_eq!(
                seq.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                par.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{label}: pagerank bits"
            );
            assert_counts_equal(&label, &seq.metrics, &par.metrics);
            let seq = run_vertex(&dg, kind, Parallelism::Sequential, &Wcc);
            let par = run_vertex(&dg, kind, Parallelism::Threads(4), &Wcc);
            assert_eq!(seq.values, par.values, "{label}: wcc");
            assert_counts_equal(&label, &seq.metrics, &par.metrics);
        }
    }
}

/// Work-stealing vs sequential: exact value equality for the min-fold
/// programs on every vertex-centric engine, epsilon for PageRank.
#[test]
fn work_stealing_matches_sequential() {
    for (g, k) in &graph_cases() {
        let dg = dist(g, *k, GraphLayout::default());
        for kind in EngineKind::VERTEX_CENTRIC {
            let label = format!("{kind}/steal");
            let s0 = run_vertex(&dg, kind, Parallelism::Sequential, &Sssp { source: 1 });
            let s1 =
                run_vertex(&dg, kind, Parallelism::WorkStealing(4), &Sssp { source: 1 });
            assert_eq!(
                s0.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                s1.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{label}: sssp"
            );
            let w0 = run_vertex(&dg, kind, Parallelism::Sequential, &Wcc);
            let w1 = run_vertex(&dg, kind, Parallelism::WorkStealing(4), &Wcc);
            assert_eq!(w0.values, w1.values, "{label}: wcc");
            // PageRank: chunk-local aggregator partials and the Jacobi
            // deferral reassociate f64 sums — epsilon, not bits
            let pr = IncrementalPageRank { tolerance: 1e-7 };
            let p0 = run_vertex(&dg, kind, Parallelism::Sequential, &pr);
            let p1 = run_vertex(&dg, kind, Parallelism::WorkStealing(4), &pr);
            for (i, (a, b)) in p0.values.iter().zip(&p1.values).enumerate() {
                assert!(close(*a, *b, 1e-4), "{label}: pagerank v{i} {a} vs {b}");
            }
        }
    }
}

/// The GAS engines have no intra-sweep stealing path; under
/// `WorkStealing` they run their sequential partition loop, so results
/// must equal `Sequential` at the bit level.
#[test]
fn work_stealing_on_gas_engines_is_sequential() {
    let g = generators::connected(300, 150, 7);
    let dg = dist(&g, 4, GraphLayout::default());
    for kind in [EngineKind::GraphLabSync, EngineKind::GraphLabAsync] {
        let s0 = Runner::from_dist(&dg)
            .config(cfg_with(Parallelism::Sequential))
            .run_gas_on(kind, &GasSssp { source: 1 });
        let s1 = Runner::from_dist(&dg)
            .config(cfg_with(Parallelism::WorkStealing(4)))
            .run_gas_on(kind, &GasSssp { source: 1 });
        assert_eq!(
            s0.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            s1.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "{kind}: gas sssp under WorkStealing"
        );
        assert_counts_equal(&kind.to_string(), &s0.metrics, &s1.metrics);
    }
}

/// The stealing thread count must be unobservable: `WorkStealing(1)` is
/// bit-for-bit identical to `WorkStealing(4)` — values AND every
/// deterministic counter — on every vertex-centric engine and layout.
#[test]
fn work_stealing_thread_count_is_unobservable() {
    let g = generators::powerlaw(400, 4, 11);
    for (lname, layout) in [("identity", GraphLayout::default()), ("packed", GraphLayout::packed())]
    {
        let dg = dist(&g, 6, layout);
        for kind in EngineKind::VERTEX_CENTRIC {
            let label = format!("{kind}/{lname}");
            let pr = IncrementalPageRank { tolerance: 1e-7 };
            let one = run_vertex(&dg, kind, Parallelism::WorkStealing(1), &pr);
            let many = run_vertex(&dg, kind, Parallelism::WorkStealing(4), &pr);
            assert_eq!(
                one.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                many.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{label}: pagerank bits across steal counts"
            );
            assert_counts_equal(&label, &one.metrics, &many.metrics);
            let one = run_vertex(&dg, kind, Parallelism::WorkStealing(1), &Wcc);
            let many = run_vertex(&dg, kind, Parallelism::WorkStealing(4), &Wcc);
            assert_eq!(one.values, many.values, "{label}: wcc across steal counts");
            assert_counts_equal(&label, &one.metrics, &many.metrics);
        }
    }
}

/// Run-to-run determinism of work-stealing: two identical invocations
/// produce identical bits (the claim counter races threads, but the
/// ordered apply hides it).
#[test]
fn work_stealing_is_run_to_run_deterministic() {
    let g = generators::road(18, 18, 3);
    let dg = dist(&g, 9, GraphLayout::packed());
    let pr = IncrementalPageRank { tolerance: 1e-7 };
    let a = run_vertex(&dg, EngineKind::GraphHP, Parallelism::WorkStealing(4), &pr);
    let b = run_vertex(&dg, EngineKind::GraphHP, Parallelism::WorkStealing(4), &pr);
    assert_eq!(
        a.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        b.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "two identical WorkStealing runs diverged"
    );
    assert_counts_equal("graphhp rerun", &a.metrics, &b.metrics);
}

/// The full stack composed: packed layout + work-stealing vs identity
/// layout + sequential — the two extremes of the configuration space —
/// agree exactly on WCC.
#[test]
fn packed_stealing_agrees_with_identity_sequential() {
    let g = generators::connected(300, 150, 7);
    let base = dist(&g, 4, GraphLayout::default());
    let packed = dist(&g, 4, GraphLayout::packed());
    for kind in EngineKind::VERTEX_CENTRIC {
        let b = run_vertex(&base, kind, Parallelism::Sequential, &Wcc);
        let p = run_vertex(&packed, kind, Parallelism::WorkStealing(3), &Wcc);
        assert_eq!(b.values, p.values, "{kind}: packed+steal vs identity+seq");
    }
}
