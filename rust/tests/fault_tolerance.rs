//! Fault-tolerance tests (paper §5.3): checkpointing + deterministic
//! failure injection on the GraphHP engine. A run that loses a worker
//! mid-computation must recover from the latest checkpoint and finish
//! with exactly the same result. Configured through the `Runner`
//! session's fault knobs.

use graphhp::algorithms::{IncrementalPageRank, Sssp, Wcc};
use graphhp::bench_support::runner;
use graphhp::engine::{Partitioner, RepartitionConfig, RunTrace};
use graphhp::graph::generators;

#[test]
fn recovery_reproduces_sssp_exactly() {
    let g = generators::road(30, 30, 5);
    let prog = Sssp { source: 0 };

    let clean = runner(&g, 6).run(&prog);
    assert!(clean.metrics.global_iterations > 6, "need room to inject a failure");

    let recovered = runner(&g, 6)
        .checkpoint_interval(Some(2))
        .inject_failure_at(Some(5))
        .run(&prog);
    assert_eq!(recovered.metrics.recoveries, 1);
    assert!(recovered.metrics.checkpoints >= 2);
    assert_eq!(clean.values, recovered.values, "recovery must be exact");
    // rollback re-executes iterations: the recovered run can only be longer
    assert!(recovered.metrics.global_iterations >= clean.metrics.global_iterations);
}

#[test]
fn recovery_without_checkpoint_restarts_from_scratch() {
    let g = generators::connected(200, 80, 7);
    let r = runner(&g, 4).inject_failure_at(Some(2)).run(&Wcc);
    assert_eq!(r.metrics.recoveries, 1);
    assert!(r.values.iter().all(|&l| l == 0), "still converges after restart");
}

#[test]
fn checkpoints_persist_to_disk_when_dir_configured() {
    let dir = std::env::temp_dir().join("graphhp_ft_disk");
    let _ = std::fs::remove_dir_all(&dir);
    let g = generators::road(20, 20, 9);
    let r = runner(&g, 4)
        .checkpoint_interval(Some(3))
        .checkpoint_dir(dir.clone())
        .run(&Sssp { source: 0 });
    assert!(r.metrics.checkpoints > 0);
    // the default retention keeps only the newest 4 files on disk
    let files: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
    assert_eq!(files.len() as u64, r.metrics.checkpoints.min(4));
    // and the latest checkpoint decodes
    let ck = graphhp::engine::checkpoint::Checkpoint::<f32, f32>::load_latest(&dir)
        .unwrap()
        .unwrap();
    assert_eq!(ck.values.len(), 4);
}

#[test]
fn checkpoint_retention_is_configurable_and_none_is_unbounded() {
    let g = generators::road(20, 20, 9);
    let prog = Sssp { source: 0 };

    let dir = std::env::temp_dir().join("graphhp_ft_retain2");
    let _ = std::fs::remove_dir_all(&dir);
    let r = runner(&g, 4)
        .checkpoint_interval(Some(1))
        .checkpoint_dir(dir.clone())
        .checkpoint_retain(Some(2))
        .run(&prog);
    assert!(r.metrics.checkpoints > 2, "need enough saves to trigger pruning");
    let files: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
    assert_eq!(files.len(), 2, "retain(2) must bound the directory");

    let dir_all = std::env::temp_dir().join("graphhp_ft_retain_none");
    let _ = std::fs::remove_dir_all(&dir_all);
    let r = runner(&g, 4)
        .checkpoint_interval(Some(1))
        .checkpoint_dir(dir_all.clone())
        .checkpoint_retain(None)
        .run(&prog);
    let files: Vec<_> = std::fs::read_dir(&dir_all).unwrap().collect();
    assert_eq!(files.len() as u64, r.metrics.checkpoints, "None keeps every file");
}

#[test]
fn pagerank_recovery_close_to_clean_run() {
    // PageRank combines f64 sums; rollback replays deliveries in the
    // same deterministic order so values must match exactly
    let g = generators::powerlaw(1_000, 4, 3);
    let prog = IncrementalPageRank { tolerance: 1e-6 };
    let clean = runner(&g, 5).run(&prog);
    let rec = runner(&g, 5)
        .checkpoint_interval(Some(2))
        .inject_failure_at(Some(3))
        .run(&prog);
    assert_eq!(rec.metrics.recoveries, 1);
    for (a, b) in clean.values.iter().zip(&rec.values) {
        assert!((a - b).abs() < 1e-9, "{a} vs {b}");
    }
}

#[test]
fn recovery_preserves_cap_truncated_local_phase() {
    // regression: the old Checkpoint exported only the global-phase
    // inbox, so recovering after a max_pseudo_supersteps-truncated local
    // phase dropped the carried-over frontier and in-flight cur/nxt mail
    // — the recovered run diverged from (or ran far longer than) the
    // clean one. The snapshot now includes the local-phase runtime
    // state, so rollback replays the capped run exactly.
    let g = generators::road(30, 30, 5);
    let prog = Sssp { source: 0 };

    let clean = runner(&g, 6).max_pseudo_supersteps(1).run(&prog);
    assert!(clean.metrics.global_iterations > 6, "need room to inject a failure");

    let recovered = runner(&g, 6)
        .max_pseudo_supersteps(1)
        .checkpoint_interval(Some(2))
        .inject_failure_at(Some(5))
        .run(&prog);
    assert_eq!(recovered.metrics.recoveries, 1);
    assert_eq!(clean.values, recovered.values, "carried-over state must survive recovery");
    assert!(recovered.metrics.global_iterations >= clean.metrics.global_iterations);
}

#[test]
fn adaptive_recovery_replays_clean_trajectory_exactly() {
    // The checkpoint snapshots the adaptive scheduler's per-partition
    // state (caps, streaks, skip flags) alongside the runtime state, so
    // a recovered run replays the exact schedule of a clean run.
    // PageRank's tolerance-truncated f64 values are trajectory-sensitive
    // — stale (un-rolled-back) policy state would change the phase
    // grouping and shift the values, which this test would catch at the
    // bit level. A tight initial cap keeps the policies actively
    // adapting around the failure point.
    let g = generators::powerlaw(1_000, 4, 3);
    let prog = IncrementalPageRank { tolerance: 1e-6 };
    let adaptive = graphhp::engine::HybridPolicy::Adaptive(graphhp::engine::AdaptiveConfig {
        initial_cap: 1,
        ..Default::default()
    });

    let clean = runner(&g, 5).hybrid_policy(adaptive).run(&prog);
    let rec = runner(&g, 5)
        .hybrid_policy(adaptive)
        .checkpoint_interval(Some(2))
        .inject_failure_at(Some(3))
        .run(&prog);
    assert_eq!(rec.metrics.recoveries, 1);
    let bits = |vs: &[f64]| vs.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
    assert_eq!(
        bits(&clean.values),
        bits(&rec.values),
        "adaptive recovery must replay the clean trajectory bit-for-bit"
    );
}

#[test]
fn recovery_replays_checkpointed_migration_plans_exactly() {
    // The checkpoint carries the applied MigrationPlan trajectory.
    // Recovery replays those plans onto the pristine graph FIRST — the
    // failure may strike epochs ahead of the checkpoint, and the
    // snapshotted per-partition arrays only make sense under the
    // geometry they were taken in — then restores the arrays, and the
    // planner re-derives any post-checkpoint plans from the replayed
    // deterministic counters. Values and the final routing epoch must
    // match the clean run exactly.
    let g = generators::connected(300, 120, 7);
    let prog = Sssp { source: 0 };
    let mk = || {
        runner(&g, 4)
            .partitioner(Partitioner::Hash) // poor locality => real migrations
            .repartition(RepartitionConfig::every_barrier())
    };

    let clean = mk().run(&prog);
    assert!(clean.trace.vertices_migrated() > 0, "need migrations to replay");
    assert!(clean.metrics.global_iterations > 4, "need room to inject a failure");

    let rec = mk().checkpoint_interval(Some(2)).inject_failure_at(Some(4)).run(&prog);
    assert_eq!(rec.metrics.recoveries, 1);
    let bits = |vs: &[f32]| vs.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
    assert_eq!(
        bits(&clean.values),
        bits(&rec.values),
        "recovery under migration must replay the clean trajectory exactly"
    );
    // the replayed run must end on the same routing epoch: every plan —
    // checkpointed or re-derived after rollback — matched the clean one
    let final_epoch = |t: &RunTrace| {
        t.steps.last().map_or(0, |s| s.routing_epoch + u64::from(s.migrated > 0))
    };
    assert_eq!(
        final_epoch(&clean.trace),
        final_epoch(&rec.trace),
        "recovered run diverged from the checkpointed migration trajectory"
    );
    // rollback re-plans the rolled-back barriers, so the recovered
    // trace can only record at least as many moves as the clean one
    assert!(rec.trace.vertices_migrated() >= clean.trace.vertices_migrated());
}

#[test]
fn failure_after_convergence_is_harmless() {
    let g = generators::road(15, 15, 2);
    let r = runner(&g, 3)
        .checkpoint_interval(Some(1))
        .inject_failure_at(Some(1_000_000)) // never fires
        .run(&Sssp { source: 0 });
    assert_eq!(r.metrics.recoveries, 0);
    assert!(r.metrics.checkpoints > 0);
}

#[test]
fn failure_at_iteration_zero_recovers_from_the_initial_checkpoint() {
    // the iteration-0 checkpoint is saved before the failure check, so
    // the earliest possible failure still rolls back instead of
    // restarting — and reproduces the clean run exactly
    let g = generators::road(20, 20, 5);
    let prog = Sssp { source: 0 };
    let clean = runner(&g, 4).run(&prog);
    let rec = runner(&g, 4)
        .checkpoint_interval(Some(2))
        .inject_failure_at(Some(0))
        .run(&prog);
    assert_eq!(rec.metrics.recoveries, 1);
    assert_eq!(clean.values, rec.values);
}

#[test]
fn failure_at_iteration_zero_without_checkpoint_terminates() {
    // no checkpoint exists yet: the legacy single-failure path restarts
    // from scratch; the failure is consumed, so the rerun must converge
    // rather than loop forever re-injecting at iteration 0
    let g = generators::connected(150, 60, 3);
    let r = runner(&g, 4).inject_failure_at(Some(0)).run(&Wcc);
    assert_eq!(r.metrics.recoveries, 1);
    assert!(r.values.iter().all(|&l| l == 0), "still converges after restart");
}

#[test]
fn chaos_kill_without_checkpoint_errors_loudly() {
    // the chaos harness generalizes inject_failure_at to repeated kills;
    // unlike the legacy single-failure restart, a chaos kill with
    // checkpoint_interval: None refuses to continue — an explicit error,
    // never a hang or a silently wrong fixpoint
    let g = generators::connected(150, 60, 3);
    let policy = graphhp::engine::ChaosPolicy {
        seed: 11,
        schedule: graphhp::engine::ChaosSchedule {
            kill_at: vec![1],
            ..Default::default()
        },
    };
    let err = runner(&g, 4)
        .chaos(policy)
        .try_run(&Wcc)
        .expect_err("kill without checkpoints must fail loudly");
    assert!(err.starts_with("chaos:"), "unexpected message: {err}");
    assert!(err.contains("no checkpoint"), "unexpected message: {err}");
}

#[test]
fn chaos_kill_with_checkpointing_recovers_exactly() {
    // same kill schedule, checkpointing on: rollback + replay must hit
    // the clean fixpoint exactly and record the recovery
    let g = generators::road(30, 30, 5);
    let prog = Sssp { source: 0 };
    let clean = runner(&g, 6).run(&prog);
    assert!(clean.metrics.global_iterations > 5, "need room for the kill");
    let policy = graphhp::engine::ChaosPolicy {
        seed: 11,
        schedule: graphhp::engine::ChaosSchedule {
            kill_at: vec![3, 5],
            ..Default::default()
        },
    };
    let rec = runner(&g, 6)
        .checkpoint_interval(Some(2))
        .chaos(policy)
        .run(&prog);
    assert_eq!(rec.metrics.recoveries, 2, "both scheduled kills must fire");
    assert_eq!(clean.values, rec.values, "recovery must be exact");
    let trace = rec.chaos.expect("chaos policy set => trace recorded");
    assert_eq!(trace.count(graphhp::engine::ChaosEventKind::Kill), 2);
    assert_eq!(trace.count(graphhp::engine::ChaosEventKind::Recover), 2);
}
