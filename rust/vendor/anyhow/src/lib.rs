//! Offline drop-in subset of the `anyhow` error API.
//!
//! The build environment has no access to crates.io, so this tiny crate
//! provides exactly the surface the workspace uses: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] macros, and the [`Context`]
//! extension trait for `Result` and `Option`. Error values are a single
//! message string; `context` prepends like the real crate's single-level
//! display (`"{context}: {cause}"`).

use std::fmt;

/// A string-backed error value (the offline stand-in for `anyhow::Error`).
///
/// Like the real crate, `Error` deliberately does NOT implement
/// `std::error::Error`, which is what allows the blanket
/// `From<E: std::error::Error>` conversion used by `?`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prepend a context line, matching anyhow's `{context}: {cause}`.
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result` — defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error/none case of a `Result` or `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a literal, a displayable value, or a
/// format string + args (the three shapes the real macro accepts).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($msg:literal $(,)?) => {
        return Err($crate::anyhow!($msg))
    };
    ($err:expr $(,)?) => {
        return Err($crate::anyhow!($err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        return Err($crate::anyhow!($fmt, $($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("while formatting").unwrap_err();
        assert!(e.to_string().starts_with("while formatting: "));
        let o: Option<u32> = None;
        assert_eq!(o.with_context(|| "missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn macros_build_messages() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flag was {}", flag);
            }
            Err(anyhow!("plain"))
        }
        assert_eq!(f(true).unwrap_err().to_string(), "flag was true");
        assert_eq!(f(false).unwrap_err().to_string(), "plain");
    }
}
