//! The standard BSP execution engine (paper §4.1) — the Hama/Pregel
//! baseline.
//!
//! Every superstep: each active vertex computes once on the messages from
//! superstep S-1; ALL messages go through the messaging layer (counted as
//! network messages, as in stock Hama) and are delivered at the barrier;
//! the master then synchronizes all workers. Termination: all vertices
//! inactive and no message in transit.

use crate::graph::DistGraph;

use super::aggregator::Aggregators;
use super::context::{SendBuffer, VertexContext};
use super::messages::Outbox;
use super::metrics::Metrics;
use super::netsim::{SuperstepClock, WorkerComm};
use super::program::VertexProgram;
use super::state::{init_runtimes, PartitionRuntime};
use super::{EngineConfig, RunResult};

/// Run `program` to completion under the standard BSP model.
///
/// Legacy entry point — use [`super::Runner`] with
/// [`super::EngineKind::Hama`]; kept as a delegate for one release.
#[doc(hidden)]
pub fn run_hama<P: VertexProgram>(
    program: &P,
    dg: &DistGraph,
    cfg: &EngineConfig,
) -> RunResult<P::V> {
    let mut rts: Vec<PartitionRuntime<P>> = init_runtimes(program, dg);
    let mut metrics = Metrics::default();
    let mut clock = SuperstepClock::new();
    let mut aggs = Aggregators::new(
        (0..program.num_aggregators()).map(|i| program.aggregator_op(i)).collect(),
    );
    let combiner = program.combiner();

    // superstep 0: every vertex is active
    for (p, rt) in rts.iter_mut().enumerate() {
        for lv in 0..dg.parts[p].num_vertices() {
            rt.schedule_next(lv);
        }
    }

    let mut superstep: u64 = 0;
    let mut msg_buf: Vec<P::M> = Vec::new();
    let mut send_buf: SendBuffer<P::M> = SendBuffer::new();

    loop {
        let mut outboxes: Vec<Outbox<P::M>> = Vec::with_capacity(dg.num_parts());
        let mut worker_aggs: Vec<Aggregators> = Vec::new();

        for p in 0..dg.num_parts() {
            let part = &dg.parts[p];
            let rt = &mut rts[p];
            let mut outbox: Outbox<P::M> = Outbox::new(combiner);
            let mut wagg = aggs.clone();
            let t0 = std::time::Instant::now();

            let mut frontier = rt.begin_step();
            frontier.sort_unstable();
            for &lv32 in &frontier {
                let lv = lv32 as usize;
                rt.cur.take_into(lv, &mut msg_buf);
                if rt.halted[lv] {
                    if msg_buf.is_empty() {
                        continue; // halted, no mail: stays inactive
                    }
                    rt.halted[lv] = false; // message reactivates (§4.1)
                }
                send_buf.clear();
                {
                    let mut ctx = VertexContext::<P> {
                        part,
                        lv,
                        superstep,
                        value: &mut rt.values[lv],
                        messages: &msg_buf,
                        halted: &mut rt.halted[lv],
                        out: &mut send_buf,
                        aggregators: &mut wagg,
                        seed: cfg.seed,
                    };
                    program.compute(&mut ctx);
                }
                metrics.vertex_computations += 1;
                // stock Hama: every message goes through the messaging
                // layer (sender-side combined per destination)
                for (target, m) in send_buf.sends.drain(..) {
                    let (tp, tl) = dg.location[target as usize];
                    outbox.push(tp, tl, part.global_ids[lv], m);
                }
                if !rt.halted[lv] {
                    rt.schedule_next(lv);
                }
            }

            let compute = cfg.net.scale_compute(t0.elapsed());
            let comm = WorkerComm {
                messages: outbox.len() as u64,
                bytes: outbox.wire_bytes() as u64,
                peer_pairs: outbox.peer_count(p as u32) as u64,
            };
            metrics.network_messages += comm.messages;
            metrics.network_bytes += comm.bytes;
            clock.record_worker(compute, cfg.net.comm_time(&comm));
            outboxes.push(outbox);
            worker_aggs.push(wagg);
        }

        // ---- barrier: deliver messages, merge aggregators, advance clock
        for mut outbox in outboxes {
            for (tp, tl, m) in outbox.drain() {
                let rt = &mut rts[tp as usize];
                rt.nxt.push(tl as usize, m);
                rt.schedule_next(tl as usize);
            }
        }
        for w in &worker_aggs {
            aggs.merge_current(w);
        }
        aggs.barrier();
        clock.barrier(&cfg.net, &mut metrics);
        metrics.global_iterations += 1;
        metrics.supersteps_total += 1;
        superstep += 1;

        let done = rts.iter_mut().all(|rt| rt.quiesced());
        if done || superstep >= cfg.limits.max_iterations {
            break;
        }
    }

    let values = super::gather_values(
        dg,
        &rts.iter().map(|rt| rt.values.clone()).collect::<Vec<_>>(),
    );
    RunResult { values, metrics }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, DistGraph, VertexId};
    use crate::partition::hash_partition;

    /// Propagate max vertex id through the graph (simple confluent test
    /// program with a combiner).
    struct MaxProp;
    impl VertexProgram for MaxProp {
        type V = u32;
        type M = u32;
        fn init(&self, v: VertexId, _d: u32) -> u32 {
            v
        }
        fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
            let mut best = *ctx.value();
            if ctx.superstep() == 0 {
                ctx.send_to_neighbors(best);
            } else {
                let incoming = ctx.messages().iter().copied().max();
                if let Some(m) = incoming {
                    if m > best {
                        best = m;
                        ctx.set_value(best);
                        ctx.send_to_neighbors(best);
                    }
                }
            }
            ctx.vote_to_halt();
        }
        fn combiner(&self) -> Option<fn(u32, u32) -> u32> {
            Some(|a, b| a.max(b))
        }
    }

    #[test]
    fn max_propagation_converges_on_connected_graph() {
        let g = generators::connected(100, 60, 3);
        let a = hash_partition(&g, 4);
        let dg = DistGraph::new(&g, &a, 4);
        let r = run_hama(&MaxProp, &dg, &EngineConfig::default());
        assert!(r.values.iter().all(|&v| v == 99), "all reach max id");
        assert!(r.metrics.global_iterations > 1);
        assert!(r.metrics.network_messages > 0);
    }

    #[test]
    fn terminates_immediately_when_everyone_halts() {
        struct HaltNow;
        impl VertexProgram for HaltNow {
            type V = u32;
            type M = u32;
            fn init(&self, _v: VertexId, _d: u32) -> u32 {
                0
            }
            fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
                ctx.vote_to_halt();
            }
        }
        let g = generators::erdos_renyi(10, 20, 1);
        let dg = DistGraph::new(&g, &hash_partition(&g, 2), 2);
        let r = run_hama(&HaltNow, &dg, &EngineConfig::default());
        assert_eq!(r.metrics.global_iterations, 1);
        assert_eq!(r.metrics.network_messages, 0);
    }

    #[test]
    fn max_iterations_cap_respected() {
        struct Forever;
        impl VertexProgram for Forever {
            type V = u32;
            type M = u32;
            fn init(&self, _v: VertexId, _d: u32) -> u32 {
                0
            }
            fn compute(&self, _ctx: &mut VertexContext<'_, Self>) {
                // never halts
            }
        }
        let g = generators::erdos_renyi(10, 20, 1);
        let dg = DistGraph::new(&g, &hash_partition(&g, 2), 2);
        let mut cfg = EngineConfig::default();
        cfg.limits.max_iterations = 5;
        let r = run_hama(&Forever, &dg, &cfg);
        assert_eq!(r.metrics.global_iterations, 5);
    }

    #[test]
    fn aggregator_visible_next_superstep() {
        struct CountAgg;
        impl VertexProgram for CountAgg {
            type V = f64;
            type M = u32;
            fn init(&self, _v: VertexId, _d: u32) -> f64 {
                -1.0
            }
            fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
                if ctx.superstep() == 0 {
                    ctx.aggregate(0, 1.0); // count vertices
                } else {
                    let n = ctx.aggregated(0);
                    ctx.set_value(n);
                    ctx.vote_to_halt();
                    return;
                }
                // stay active so superstep 1 happens
            }
            fn num_aggregators(&self) -> usize {
                1
            }
        }
        let g = generators::erdos_renyi(25, 50, 2);
        let dg = DistGraph::new(&g, &hash_partition(&g, 3), 3);
        let r = run_hama(&CountAgg, &dg, &EngineConfig::default());
        assert!(r.values.iter().all(|&v| v == 25.0), "{:?}", &r.values[..5]);
    }

    #[test]
    fn message_reactivates_halted_vertex() {
        // vertex 0 sends to vertex 1 at superstep 1 after 1 already halted
        struct Poke;
        impl VertexProgram for Poke {
            type V = u32;
            type M = u32;
            fn init(&self, _v: VertexId, _d: u32) -> u32 {
                0
            }
            fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
                if ctx.vertex_id() == 0 && ctx.superstep() == 1 {
                    ctx.send(1, 99);
                } else if ctx.vertex_id() == 0 && ctx.superstep() == 0 {
                    // stay active for superstep 1
                    return;
                }
                if !ctx.messages().is_empty() {
                    let m = ctx.messages()[0];
                    ctx.set_value(m);
                }
                ctx.vote_to_halt();
            }
        }
        let g = generators::erdos_renyi(4, 6, 3);
        let dg = DistGraph::new(&g, &hash_partition(&g, 2), 2);
        let r = run_hama(&Poke, &dg, &EngineConfig::default());
        assert_eq!(r.values[1], 99);
    }
}
