//! The standard BSP execution engine (paper §4.1) — the Hama/Pregel
//! baseline.
//!
//! Every superstep: each active vertex computes once on the messages from
//! superstep S-1; ALL messages go through the messaging layer (counted as
//! network messages, as in stock Hama) and are delivered at the barrier;
//! the master then synchronizes all workers. Termination: all vertices
//! inactive and no message in transit.
//!
//! Routing policy: `LocalRoute::Network` — even same-partition mail
//! crosses the (simulated) wire. The worker body itself lives in
//! `super::worker`; workers run in parallel per
//! [`super::EngineConfig::parallelism`]. With
//! `FaultPolicy::checkpoint_interval` set, the engine snapshots at the
//! superstep boundary and recovers from injected loss through the
//! shared recovery layer (`engine/recovery.rs`).

use crate::graph::{DistGraph, MigrationPlan};

use super::aggregator::Aggregators;
use super::messages::Outbox;
use super::metrics::{Metrics, PartitionStepTrace, RunTrace};
use super::migrate::{remap_runtimes, MigrationPlanner};
use super::netsim::SuperstepClock;
use super::program::{SourceCombine, VertexProgram};
use super::recovery::{persist_checkpoint, RecoveryCoordinator};
use super::worker::{
    boundary_count, close_superstep, init_worker_states, restore_worker_states, run_workers,
    snapshot_worker_states, LocalRoute, ProcessedMarks, Reschedule, Sweep, WorkerOut,
    WorkerScratch, WorkerState,
};
use super::{EngineConfig, RunResult};

/// Run `program` to completion under the standard BSP model.
///
/// Legacy entry point — use [`super::Runner`] with
/// [`super::EngineKind::Hama`]; kept as a delegate for one release.
#[doc(hidden)]
pub fn run_hama<P: VertexProgram>(
    program: &P,
    dg: &DistGraph,
    cfg: &EngineConfig,
) -> RunResult<P::V> {
    let mut workers = init_worker_states(program, dg);
    let mut metrics = Metrics::default();
    let mut trace = RunTrace::default();
    let mut clock = SuperstepClock::new();
    let mut aggs = Aggregators::new(
        (0..program.num_aggregators()).map(|i| program.aggregator_op(i)).collect(),
    );
    let combiner = program.combiner();

    // superstep 0: every vertex is active
    for ws in workers.iter_mut() {
        for lv in 0..ws.rt.num_vertices() {
            ws.rt.schedule_next(lv);
        }
    }

    let mut superstep: u64 = 0;
    let planner = cfg.repartition.map(MigrationPlanner::new);
    let mut dg_owned: Option<Box<DistGraph>> = None;
    let mut applied_plans: Vec<MigrationPlan> = Vec::new();
    let mut chaos_ctl = cfg.chaos.as_ref().map(super::chaos::ChaosController::new);
    let mut recovery = RecoveryCoordinator::new(cfg.fault.recovery);

    loop {
        // ---- fault tolerance (paper §5.3, via engine/recovery.rs):
        // snapshot the full superstep-boundary state so a chaos loss
        // event rolls back and replays instead of panicking
        if recovery.should_checkpoint(&cfg.fault, superstep) {
            let ckpt = snapshot_worker_states(superstep, &mut workers, &applied_plans);
            persist_checkpoint(&ckpt, &cfg.fault);
            recovery.install(superstep, ckpt, &mut metrics);
        }

        let dgr: &DistGraph = dg_owned.as_deref().unwrap_or(dg);
        let outs = run_workers(cfg.parallelism, &mut workers, |p, ws| {
            ws.outbox.reset();
            let mut wagg = aggs.clone();
            // detlint: allow(wall-clock) — compute_us probe: measures this
            // worker's sweep for telemetry/netsim only, never feeds results.
            let t0 = std::time::Instant::now();

            // the frontier alone seeds the worklist: every delivery into
            // `nxt` (barrier or in-sweep) is paired with a schedule, so
            // cur's pending set is always a subset of the frontier. It
            // drains into the pooled sorted worklist — same ascending
            // order a fresh BTreeSet gave, no per-sweep allocation.
            ws.rt.begin_step_into(&mut ws.scratch.worklist);
            let pt = PartitionStepTrace {
                frontier: ws.scratch.worklist.len() as u64,
                boundary_frontier: boundary_count(&dgr.parts[p], ws.scratch.worklist.as_slice()),
                ..Default::default()
            };
            let sweep = Sweep {
                program,
                dg: dgr,
                part: &dgr.parts[p],
                p,
                superstep,
                seed: cfg.seed,
                combiner,
                route: LocalRoute::Network,
                reschedule: Reschedule::Active,
                boundary_in_local: true,
                steal_threads: cfg.parallelism.steal_threads(),
            };
            let outcome = sweep.run(
                ws.rt.sweep_target(),
                None,
                &mut ws.outbox,
                &mut wagg,
                &mut ws.scratch,
                &mut ws.marks,
            );
            ws.rt.commit_step();
            ws.outbox.seal(SourceCombine::KeepAll);
            let compute = cfg.net.scale_compute(t0.elapsed());
            WorkerOut::new(std::mem::take(&mut ws.outbox), wagg, compute, p, outcome, 0, pt)
        });

        // ---- barrier: deliver messages (receiver-side combining keeps
        // inboxes at one message per vertex), merge aggregators, advance
        // the clock; drained outboxes return to their workers
        let outboxes = close_superstep(
            outs,
            &mut aggs,
            &mut clock,
            &cfg.net,
            &mut metrics,
            &mut trace,
            chaos_ctl.as_mut(),
            |tp, tl, m| {
                let rt = &mut workers[tp as usize].rt;
                rt.nxt.push_combined(tl as usize, m, combiner);
                rt.schedule_next(tl as usize);
            },
        );
        for (ws, ob) in workers.iter_mut().zip(outboxes) {
            ws.outbox = ob;
            // debug sanitizer: step closed, inboxes/frontier intact
            // after delivery (no-op in release builds)
            super::invariants::check_runtime(&ws.rt);
        }

        // ---- chaos recovery: a loss event corrupted this barrier —
        // roll every worker back to the latest checkpoint and replay
        // (the monotone chaos counter keeps advancing, so the replay
        // draws fresh RNG streams and a consumed kill never re-fires).
        // Without a checkpoint the coordinator refuses loss loudly.
        if let Some(reason) = chaos_ctl.as_mut().and_then(|c| c.take_pending()) {
            let ckpt = recovery.rollback("hama", &reason, &mut metrics);
            let (ws, at) =
                restore_worker_states(dg, ckpt, &mut dg_owned, &mut applied_plans, combiner);
            workers = ws;
            superstep = at;
            if let Some(ctl) = chaos_ctl.as_mut() {
                ctl.note_recovery();
            }
            continue;
        }

        // ---- online repartitioning: every partition is step-closed and
        // all barrier mail landed, so the plan applies atomically here
        {
            let step = trace.steps.last_mut().expect("barrier just recorded a step");
            step.routing_epoch = dgr.routing.epoch;
            let plan = planner.as_ref().and_then(|pl| pl.plan(dgr, step, superstep));
            if let Some(plan) = plan {
                // chaos: a kill scheduled inside this migration window
                // fires between plan and apply — abandon the plan and
                // roll back; the replay re-derives the identical plan
                // from the same counters and applies it cleanly
                let survive = match chaos_ctl.as_mut() {
                    Some(ctl) => ctl.judge_migration(plan.len() as u64),
                    None => true,
                };
                if !survive {
                    let reason = chaos_ctl
                        .as_mut()
                        .and_then(|c| c.take_pending())
                        .expect("migration kill raised a pending loss");
                    let ckpt = recovery.rollback("hama", &reason, &mut metrics);
                    let (ws, at) = restore_worker_states(
                        dg,
                        ckpt,
                        &mut dg_owned,
                        &mut applied_plans,
                        combiner,
                    );
                    workers = ws;
                    superstep = at;
                    if let Some(ctl) = chaos_ctl.as_mut() {
                        ctl.note_recovery();
                    }
                    continue;
                }
                step.migrated = plan.len() as u64;
                let new_dg = Box::new(dgr.apply_migration(&plan));
                let rts = remap_runtimes(
                    dgr,
                    &new_dg,
                    workers.drain(..).map(|ws| ws.rt).collect(),
                    combiner,
                );
                workers = rts
                    .into_iter()
                    .map(|rt| {
                        let n = rt.num_vertices();
                        WorkerState {
                            rt,
                            scratch: WorkerScratch::new(),
                            marks: ProcessedMarks::new(n),
                            outbox: Outbox::new(combiner),
                        }
                    })
                    .collect();
                applied_plans.push(plan);
                dg_owned = Some(new_dg);
            }
        }

        metrics.global_iterations += 1;
        metrics.supersteps_total += 1;
        superstep += 1;

        let done = workers.iter_mut().all(|ws| ws.rt.quiesced());
        if done || superstep >= cfg.limits.max_iterations {
            break;
        }
    }

    // gather under the final routing epoch — migrated vertices read back
    // from their current owners
    let dgr: &DistGraph = dg_owned.as_deref().unwrap_or(dg);
    let values =
        super::gather_values_owned(dgr, workers.into_iter().map(|ws| ws.rt.values).collect());
    RunResult { values, metrics, trace, chaos: chaos_ctl.map(|c| c.into_trace()) }
}

#[cfg(test)]
mod tests {
    use super::super::context::VertexContext;
    use super::*;
    use crate::graph::{generators, DistGraph, VertexId};
    use crate::partition::hash_partition;

    /// Propagate max vertex id through the graph (simple confluent test
    /// program with a combiner).
    struct MaxProp;
    impl VertexProgram for MaxProp {
        type V = u32;
        type M = u32;
        fn init(&self, v: VertexId, _d: u32) -> u32 {
            v
        }
        fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
            let mut best = *ctx.value();
            if ctx.superstep() == 0 {
                ctx.send_to_neighbors(best);
            } else {
                let incoming = ctx.messages().iter().copied().max();
                if let Some(m) = incoming {
                    if m > best {
                        best = m;
                        ctx.set_value(best);
                        ctx.send_to_neighbors(best);
                    }
                }
            }
            ctx.vote_to_halt();
        }
        fn combiner(&self) -> Option<fn(u32, u32) -> u32> {
            Some(|a, b| a.max(b))
        }
    }

    #[test]
    fn max_propagation_converges_on_connected_graph() {
        let g = generators::connected(100, 60, 3);
        let a = hash_partition(&g, 4);
        let dg = DistGraph::new(&g, &a, 4);
        let r = run_hama(&MaxProp, &dg, &EngineConfig::default());
        assert!(r.values.iter().all(|&v| v == 99), "all reach max id");
        assert!(r.metrics.global_iterations > 1);
        assert!(r.metrics.network_messages > 0);
    }

    #[test]
    fn terminates_immediately_when_everyone_halts() {
        struct HaltNow;
        impl VertexProgram for HaltNow {
            type V = u32;
            type M = u32;
            fn init(&self, _v: VertexId, _d: u32) -> u32 {
                0
            }
            fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
                ctx.vote_to_halt();
            }
        }
        let g = generators::erdos_renyi(10, 20, 1);
        let dg = DistGraph::new(&g, &hash_partition(&g, 2), 2);
        let r = run_hama(&HaltNow, &dg, &EngineConfig::default());
        assert_eq!(r.metrics.global_iterations, 1);
        assert_eq!(r.metrics.network_messages, 0);
    }

    #[test]
    fn max_iterations_cap_respected() {
        struct Forever;
        impl VertexProgram for Forever {
            type V = u32;
            type M = u32;
            fn init(&self, _v: VertexId, _d: u32) -> u32 {
                0
            }
            fn compute(&self, _ctx: &mut VertexContext<'_, Self>) {
                // never halts
            }
        }
        let g = generators::erdos_renyi(10, 20, 1);
        let dg = DistGraph::new(&g, &hash_partition(&g, 2), 2);
        let mut cfg = EngineConfig::default();
        cfg.limits.max_iterations = 5;
        let r = run_hama(&Forever, &dg, &cfg);
        assert_eq!(r.metrics.global_iterations, 5);
    }

    #[test]
    fn aggregator_visible_next_superstep() {
        struct CountAgg;
        impl VertexProgram for CountAgg {
            type V = f64;
            type M = u32;
            fn init(&self, _v: VertexId, _d: u32) -> f64 {
                -1.0
            }
            fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
                if ctx.superstep() == 0 {
                    ctx.aggregate(0, 1.0); // count vertices
                } else {
                    let n = ctx.aggregated(0);
                    ctx.set_value(n);
                    ctx.vote_to_halt();
                    return;
                }
                // stay active so superstep 1 happens
            }
            fn num_aggregators(&self) -> usize {
                1
            }
        }
        let g = generators::erdos_renyi(25, 50, 2);
        let dg = DistGraph::new(&g, &hash_partition(&g, 3), 3);
        let r = run_hama(&CountAgg, &dg, &EngineConfig::default());
        assert!(r.values.iter().all(|&v| v == 25.0), "{:?}", &r.values[..5]);
    }

    /// Satellite regression for the resolved-route refactor: a program
    /// flooding via `send_to_neighbors` must produce byte-for-byte the
    /// same run as the identical program using `send_along_edges` —
    /// same values, same network/local message counts, same iterations.
    #[test]
    fn send_to_neighbors_and_send_along_edges_identical_delivery() {
        struct ViaNeighbors;
        impl VertexProgram for ViaNeighbors {
            type V = u32;
            type M = u32;
            fn init(&self, v: VertexId, _d: u32) -> u32 {
                v
            }
            fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
                let mut best = *ctx.value();
                if ctx.superstep() == 0 {
                    ctx.send_to_neighbors(best);
                } else if let Some(&m) = ctx.messages().iter().min() {
                    if m < best {
                        best = m;
                        ctx.set_value(best);
                        ctx.send_to_neighbors(best);
                    }
                }
                ctx.vote_to_halt();
            }
        }
        struct ViaEdges;
        impl VertexProgram for ViaEdges {
            type V = u32;
            type M = u32;
            fn init(&self, v: VertexId, _d: u32) -> u32 {
                v
            }
            fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
                let mut best = *ctx.value();
                if ctx.superstep() == 0 {
                    ctx.send_along_edges(|_| Some(best));
                } else if let Some(&m) = ctx.messages().iter().min() {
                    if m < best {
                        best = m;
                        ctx.set_value(best);
                        ctx.send_along_edges(|_| Some(best));
                    }
                }
                ctx.vote_to_halt();
            }
        }
        let g = generators::connected(200, 80, 29);
        let dg = DistGraph::new(&g, &hash_partition(&g, 4), 4);
        let cfg = EngineConfig::default();
        let a = run_hama(&ViaNeighbors, &dg, &cfg);
        let b = run_hama(&ViaEdges, &dg, &cfg);
        assert_eq!(a.values, b.values);
        assert_eq!(a.metrics.network_messages, b.metrics.network_messages);
        assert_eq!(a.metrics.network_bytes, b.metrics.network_bytes);
        assert_eq!(a.metrics.local_messages, b.metrics.local_messages);
        assert_eq!(a.metrics.vertex_computations, b.metrics.vertex_computations);
        assert_eq!(a.metrics.global_iterations, b.metrics.global_iterations);
        assert!(a.metrics.network_messages > 0, "the flood actually sent mail");
    }

    #[test]
    fn message_reactivates_halted_vertex() {
        // vertex 0 sends to vertex 1 at superstep 1 after 1 already halted
        struct Poke;
        impl VertexProgram for Poke {
            type V = u32;
            type M = u32;
            fn init(&self, _v: VertexId, _d: u32) -> u32 {
                0
            }
            fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
                if ctx.vertex_id() == 0 && ctx.superstep() == 1 {
                    ctx.send(1, 99);
                } else if ctx.vertex_id() == 0 && ctx.superstep() == 0 {
                    // stay active for superstep 1
                    return;
                }
                if !ctx.messages().is_empty() {
                    let m = ctx.messages()[0];
                    ctx.set_value(m);
                }
                ctx.vote_to_halt();
            }
        }
        let g = generators::erdos_renyi(4, 6, 3);
        let dg = DistGraph::new(&g, &hash_partition(&g, 2), 2);
        let r = run_hama(&Poke, &dg, &EngineConfig::default());
        assert_eq!(r.values[1], 99);
    }
}
