//! Simulated cluster cost model.
//!
//! The paper runs on 13 machines over 1 Gbit Ethernet; here all
//! partitions execute in one process, so elapsed time `T` is *simulated*:
//! compute time is measured per worker, while communication and barrier
//! costs are charged by this model. Iteration and message counts — the
//! paper's other two metrics — are exact and model-independent.
//!
//! Under the threaded runtime ([`crate::engine::Parallelism`]) the
//! workers genuinely race each other: each measures its own compute span
//! on its own OS thread and the engine records them keyed by worker
//! index after the join ([`SuperstepClock::record_worker_at`]), so the
//! max-over-workers term below is a **measured** straggler, not a
//! simulated one.
//!
//! Per superstep the cluster clock advances by
//!
//! ```text
//! step = max_over_workers(compute_w + comm_w) + barrier_latency
//! comm_w = Σ_dest (msgs · per_message + bytes/bandwidth) + pairs · rpc_batch_latency
//! sync_w = step − compute_w − comm_w      (idle at the barrier)
//! ```
//!
//! and the reported compute/comm/sync times are worker averages — the
//! same accounting the paper uses for Figure 1.

use std::time::Duration;

/// Cost-model parameters. Defaults approximate the paper's testbed
/// (1 Gbit Ethernet LAN, JVM-era RPC stacks).
#[derive(Clone, Debug)]
pub struct NetSimConfig {
    /// Master round-trip + straggler skew charged at every barrier (µs).
    pub barrier_latency_us: f64,
    /// Per-message serialization/handling cost (µs).
    pub per_message_us: f64,
    /// Wire bandwidth in MB/s (1 Gbit ≈ 125 MB/s).
    pub bandwidth_mb_s: f64,
    /// Per-(src,dst)-worker-pair RPC flush latency per superstep (µs).
    pub rpc_batch_latency_us: f64,
    /// Multiplier on measured compute time (scales this host to the
    /// paper's slower per-core testbed; 1.0 = report measured time).
    pub compute_scale: f64,
}

impl Default for NetSimConfig {
    fn default() -> Self {
        NetSimConfig {
            barrier_latency_us: 2_000.0, // 2 ms: Hama/Zookeeper-style barrier
            per_message_us: 1.0,         // serialize + enqueue + deliver
            bandwidth_mb_s: 125.0,       // 1 Gbit Ethernet
            rpc_batch_latency_us: 200.0, // per-peer flush RTT share
            compute_scale: 1.0,
        }
    }
}

/// Outgoing communication of one worker during one superstep.
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerComm {
    /// Messages sent to other workers (after sender-side combining).
    pub messages: u64,
    /// Bytes in those messages.
    pub bytes: u64,
    /// Distinct destination workers.
    pub peer_pairs: u64,
}

impl NetSimConfig {
    /// Communication time for one worker's superstep output.
    pub fn comm_time(&self, c: &WorkerComm) -> Duration {
        let us = c.messages as f64 * self.per_message_us
            + c.bytes as f64 / (self.bandwidth_mb_s * 1e6) * 1e6
            + c.peer_pairs as f64 * self.rpc_batch_latency_us;
        Duration::from_secs_f64(us * 1e-6)
    }

    /// Barrier cost.
    pub fn barrier(&self) -> Duration {
        Duration::from_secs_f64(self.barrier_latency_us * 1e-6)
    }

    /// Scale a measured compute duration to the simulated testbed.
    pub fn scale_compute(&self, d: Duration) -> Duration {
        d.mul_f64(self.compute_scale)
    }
}

/// Accumulates one superstep's per-worker costs and folds them into
/// [`super::Metrics`] at the barrier.
#[derive(Debug, Default)]
pub struct SuperstepClock {
    /// (compute, comm) per worker this superstep.
    workers: Vec<(Duration, Duration)>,
}

impl SuperstepClock {
    /// A clock with no worker records yet.
    pub fn new() -> Self {
        SuperstepClock { workers: Vec::new() }
    }

    /// Append the next worker's costs in arrival order (sequential
    /// engines; the parallel runtime uses
    /// [`record_worker_at`](Self::record_worker_at) instead).
    pub fn record_worker(&mut self, compute: Duration, comm: Duration) {
        self.workers.push((compute, comm));
    }

    /// Record worker `idx`'s costs for this superstep. The parallel
    /// runtime folds worker outputs on the engine thread in partition
    /// order after the threads join, so the recording is deterministic
    /// regardless of how the workers interleaved on the hardware.
    pub fn record_worker_at(&mut self, idx: usize, compute: Duration, comm: Duration) {
        if self.workers.len() <= idx {
            self.workers.resize(idx + 1, (Duration::ZERO, Duration::ZERO));
        }
        self.workers[idx] = (compute, comm);
    }

    /// Close the superstep: advance the cluster clock, attribute averages
    /// into `m`, reset for the next superstep.
    pub fn barrier(&mut self, cfg: &NetSimConfig, m: &mut super::Metrics) {
        let n = self.workers.len().max(1) as u32;
        let slowest = self
            .workers
            .iter()
            .map(|&(c, x)| c + x)
            .max()
            .unwrap_or(Duration::ZERO);
        let step = slowest + cfg.barrier();
        let avg_compute =
            self.workers.iter().map(|&(c, _)| c).sum::<Duration>() / n;
        let avg_comm = self.workers.iter().map(|&(_, x)| x).sum::<Duration>() / n;
        m.compute_time += avg_compute;
        m.comm_time += avg_comm;
        // average idle = step - own busy time, averaged over workers
        let avg_busy = avg_compute + avg_comm;
        m.sync_time += step.saturating_sub(avg_busy);
        m.elapsed += step;
        self.workers.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Metrics;

    #[test]
    fn comm_time_scales_with_messages_and_bytes() {
        let cfg = NetSimConfig::default();
        let small = cfg.comm_time(&WorkerComm { messages: 10, bytes: 100, peer_pairs: 1 });
        let big = cfg.comm_time(&WorkerComm { messages: 10_000, bytes: 100_000, peer_pairs: 1 });
        // per-message cost dominates at scale; the fixed per-pair RPC
        // latency caps the ratio for small payloads
        assert!(big > small * 10, "big={big:?} small={small:?}");
    }

    #[test]
    fn barrier_dominates_empty_supersteps() {
        let cfg = NetSimConfig::default();
        let mut m = Metrics::default();
        let mut clock = SuperstepClock::new();
        for _ in 0..10 {
            clock.record_worker(Duration::from_micros(10), Duration::ZERO);
            clock.record_worker(Duration::from_micros(12), Duration::ZERO);
            clock.barrier(&cfg, &mut m);
        }
        // 10 barriers à 2 ms dominate ~0.1 ms compute
        assert!(m.sync_fraction() > 0.9, "sync={}", m.sync_fraction());
        assert_eq!(m.elapsed.as_millis(), 20);
    }

    #[test]
    fn record_at_index_matches_push_order() {
        let cfg = NetSimConfig::default();
        let (mut a, mut b) = (Metrics::default(), Metrics::default());
        let mut pushed = SuperstepClock::new();
        pushed.record_worker(Duration::from_millis(3), Duration::from_millis(1));
        pushed.record_worker(Duration::from_millis(5), Duration::ZERO);
        pushed.barrier(&cfg, &mut a);
        let mut indexed = SuperstepClock::new();
        // out-of-order indices must land in the same slots
        indexed.record_worker_at(1, Duration::from_millis(5), Duration::ZERO);
        indexed.record_worker_at(0, Duration::from_millis(3), Duration::from_millis(1));
        indexed.barrier(&cfg, &mut b);
        assert_eq!(a.elapsed, b.elapsed);
        assert_eq!(a.compute_time, b.compute_time);
        assert_eq!(a.sync_time, b.sync_time);
    }

    #[test]
    fn record_at_with_index_gaps_takes_straggler_over_all_slots() {
        // the parallel runtime may record a high index before the gaps
        // are filled; unfilled slots are zero-cost workers and the
        // straggler max must still come from the slowest recorded slot
        let cfg = NetSimConfig { barrier_latency_us: 0.0, ..Default::default() };
        let mut m = Metrics::default();
        let mut clock = SuperstepClock::new();
        clock.record_worker_at(3, Duration::from_millis(8), Duration::from_millis(2));
        clock.record_worker_at(0, Duration::from_millis(1), Duration::ZERO);
        clock.barrier(&cfg, &mut m);
        // slowest = slot 3: 8 + 2 = 10 ms; slots 1 and 2 idle the whole step
        assert_eq!(m.elapsed, Duration::from_millis(10));
        // averages are over all four slots: compute (8+1)/4, comm 2/4
        assert_eq!(m.compute_time, Duration::from_micros(2_250));
        assert_eq!(m.comm_time, Duration::from_micros(500));
    }

    #[test]
    fn barrier_resets_worker_records_between_supersteps() {
        let cfg = NetSimConfig { barrier_latency_us: 1_000.0, ..Default::default() };
        let mut m = Metrics::default();
        let mut clock = SuperstepClock::new();
        clock.record_worker(Duration::from_millis(7), Duration::ZERO);
        clock.barrier(&cfg, &mut m);
        assert_eq!(m.elapsed, Duration::from_millis(8));
        // the straggler from step 1 must not leak into step 2
        clock.record_worker(Duration::from_millis(2), Duration::ZERO);
        clock.barrier(&cfg, &mut m);
        assert_eq!(m.elapsed, Duration::from_millis(11), "8 + (2 + 1)");
        // an empty superstep costs exactly the barrier latency
        clock.barrier(&cfg, &mut m);
        assert_eq!(m.elapsed, Duration::from_millis(12));
    }

    #[test]
    fn sync_time_is_elapsed_minus_compute_minus_comm() {
        // the doc-comment identity sync_w = step − compute_w − comm_w
        // must hold in aggregate across heterogeneous supersteps
        let cfg = NetSimConfig::default();
        let mut m = Metrics::default();
        let mut clock = SuperstepClock::new();
        for s in 0..7u64 {
            for w in 0..5u64 {
                clock.record_worker_at(
                    w as usize,
                    Duration::from_micros(100 + 37 * ((s + w) % 5)),
                    Duration::from_micros(11 * ((s * w) % 4)),
                );
            }
            clock.barrier(&cfg, &mut m);
        }
        assert_eq!(m.elapsed, m.compute_time + m.comm_time + m.sync_time);
    }

    #[test]
    fn straggler_shows_up_as_sync_for_others() {
        let cfg = NetSimConfig { barrier_latency_us: 0.0, ..Default::default() };
        let mut m = Metrics::default();
        let mut clock = SuperstepClock::new();
        clock.record_worker(Duration::from_millis(10), Duration::ZERO); // straggler
        clock.record_worker(Duration::from_millis(1), Duration::ZERO);
        clock.record_worker(Duration::from_millis(1), Duration::ZERO);
        clock.barrier(&cfg, &mut m);
        assert_eq!(m.elapsed, Duration::from_millis(10));
        // avg compute 4ms, so 6ms is idle/sync
        assert_eq!(m.sync_time, Duration::from_millis(6));
    }
}
