//! The shared per-partition worker core and the parallel worker runtime.
//!
//! Every push-based engine executes the same per-vertex body — drain the
//! vertex's mail, reactivate on mail, run the user `Compute()`, route the
//! sends, reschedule if still active — and differs only in *routing
//! policy* (where a same-partition message goes) and *phase structure*
//! (how sweeps are sequenced between barriers). [`Sweep`] is the single
//! implementation of that body; the engine files keep only their policy
//! and phases.
//!
//! The sweep's drain order is owned by [`Worklist`] — a pooled sorted
//! worklist that reproduces the drain semantics of a fresh
//! `BTreeSet<u32>` (ascending `pop_first`, deduplicated inserts,
//! mid-sweep insertions landing in sorted position) with zero
//! steady-state allocation; it lives in [`WorkerScratch`] next to the
//! message and send buffers.
//!
//! [`run_workers`] executes one worker per partition, either on the
//! calling thread or multiplexed onto scoped OS threads
//! ([`Parallelism::Threads`]). Workers are shared-nothing within a
//! superstep: each owns its partition state — including a pooled
//! [`Outbox`] whose batch buffers are reused across supersteps — and
//! fills a private [`WorkerOut`] (outbox, aggregator partials, timings).
//! The barrier ([`close_superstep`]) folds those outputs in **partition
//! order** and hands the drained outboxes back for reuse, so a threaded
//! run is bit-for-bit identical to a sequential one — the determinism
//! contract `tests/parallel_equivalence.rs` enforces.

use std::time::Duration;

use crate::graph::{DistGraph, MigrationPlan, PartGraph};
use crate::util::Codec;

use super::aggregator::Aggregators;
use super::checkpoint::Checkpoint;
use super::context::{SendBuffer, VertexContext};
use super::messages::{MsgStore, Outbox};
use super::metrics::{Metrics, PartitionStepTrace, RunTrace, StepTrace};
use super::netsim::{NetSimConfig, SuperstepClock, WorkerComm};
use super::program::VertexProgram;
use super::state::{Frontier, PartitionRuntime};
use super::Parallelism;

/// A pooled sorted worklist: the sweep's "which vertex next" structure.
///
/// Reproduces `BTreeSet<u32>` drain semantics *exactly* — ascending-id
/// [`pop_first`](Self::pop_first) order, deduplicated
/// [`schedule`](Self::schedule), and mid-sweep insertions that land in
/// their sorted position even when they are smaller than the next seeded
/// entry — without the per-sweep node allocations of a fresh tree.
/// Seeds accumulate unsorted in a flat buffer; the first pop sorts it
/// once and drains it behind a cursor; later insertions go to a small
/// descending-sorted `pending` stack whose minimum pops from the back in
/// O(1). A membership bitmap keeps `schedule` O(1) and duplicate-free
/// across both buffers.
///
/// All three buffers are pooled in [`WorkerScratch`]:
/// [`begin`](Self::begin) re-arms the worklist for the next sweep
/// keeping every allocation, so steady-state sweeps allocate nothing.
#[derive(Default)]
pub(crate) struct Worklist {
    /// Seed entries; sorted ascending at the first pop, drained by
    /// `cursor`.
    /// (Fields are `pub(crate)` for the debug sanitizers in
    /// `engine/invariants.rs`.)
    pub(crate) items: Vec<u32>,
    pub(crate) cursor: usize,
    /// Mid-sweep insertions, sorted descending (minimum at the back).
    pub(crate) pending: Vec<u32>,
    /// `member[v]` iff `v` is queued and not yet popped.
    pub(crate) member: Vec<bool>,
    /// Set at the first pop; later schedules go through `pending`.
    pub(crate) sorted: bool,
}

impl Worklist {
    /// Re-arm for a sweep over a partition of `n` vertices: clears any
    /// leftover entries (an aborted/carried-over sweep may leave some)
    /// and their membership flags, keeping all buffer capacity.
    pub fn begin(&mut self, n: usize) {
        for &v in &self.items[self.cursor..] {
            self.member[v as usize] = false;
        }
        for &v in &self.pending {
            self.member[v as usize] = false;
        }
        self.items.clear();
        self.pending.clear();
        self.cursor = 0;
        self.sorted = false;
        if self.member.len() < n {
            self.member.resize(n, false);
        }
    }

    /// Queue local vertex `v` unless it is already queued (BTreeSet
    /// `insert` semantics). Before the first pop this seeds the sweep;
    /// afterwards the entry lands in its sorted drain position, even
    /// ahead of already-seeded larger ids.
    pub fn schedule(&mut self, v: u32) {
        if self.member[v as usize] {
            return;
        }
        self.member[v as usize] = true;
        if !self.sorted {
            self.items.push(v);
        } else {
            let pos = self.pending.partition_point(|&x| x > v);
            self.pending.insert(pos, v);
        }
    }

    /// Remove and return the smallest queued id (BTreeSet `pop_first`
    /// semantics).
    pub fn pop_first(&mut self) -> Option<u32> {
        if !self.sorted {
            self.items.sort_unstable();
            self.sorted = true;
        }
        let seeded = self.items.get(self.cursor).copied();
        let inserted = self.pending.last().copied();
        let v = match (seeded, inserted) {
            // equal heads are impossible: `member` dedups across buffers
            (Some(a), Some(b)) if b < a => {
                self.pending.pop();
                b
            }
            (Some(a), _) => {
                self.cursor += 1;
                a
            }
            (None, Some(b)) => {
                self.pending.pop();
                b
            }
            (None, None) => return None,
        };
        self.member[v as usize] = false;
        Some(v)
    }

    /// Queued entries not yet popped.
    pub fn len(&self) -> usize {
        self.items.len() - self.cursor + self.pending.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The seeded entries, only valid before the first pop (seed order,
    /// unsorted) — for frontier-composition telemetry and carryover.
    pub fn as_slice(&self) -> &[u32] {
        debug_assert!(!self.sorted, "as_slice after the sweep started draining");
        &self.items
    }
}

/// Per-worker scratch buffers reused across vertices and sweeps.
pub(crate) struct WorkerScratch<M> {
    pub msg_buf: Vec<M>,
    pub send_buf: SendBuffer<M>,
    /// The pooled sweep worklist, seeded by the engine before each
    /// [`Sweep::run`].
    pub worklist: Worklist,
}

impl<M> WorkerScratch<M> {
    pub fn new() -> Self {
        WorkerScratch {
            msg_buf: Vec::new(),
            send_buf: SendBuffer::new(),
            worklist: Worklist::default(),
        }
    }
}

/// Generation-stamped "processed this sweep" marks: O(1) reset per sweep
/// instead of an O(n) clear (hoisted from the GraphHP local phase so
/// every sweep-based engine shares it).
pub(crate) struct ProcessedMarks {
    stamps: Vec<u32>,
    stamp: u32,
}

impl ProcessedMarks {
    pub fn new(n: usize) -> Self {
        ProcessedMarks { stamps: vec![0; n], stamp: 0 }
    }

    /// Start a new sweep: previously-set marks become stale.
    pub fn begin_sweep(&mut self) {
        self.stamp = self.stamp.wrapping_add(1);
        if self.stamp == 0 {
            // u32 wrap: one O(n) clear every 2^32 sweeps
            self.stamps.iter_mut().for_each(|s| *s = 0);
            self.stamp = 1;
        }
    }

    pub fn mark(&mut self, lv: usize) {
        self.stamps[lv] = self.stamp;
    }

    pub fn processed(&self, lv: usize) -> bool {
        self.stamps[lv] == self.stamp
    }
}

/// Where a same-partition message goes — the one policy axis that
/// distinguishes the push-based engines' message semantics.
#[derive(Clone, Copy, PartialEq, Eq)]
pub(crate) enum LocalRoute {
    /// Through the network outbox like any remote message (stock Hama).
    Network,
    /// In memory, visible the *next* sweep (synchronous local messaging:
    /// GraphHP global phase and sync-mode local phases).
    NextSweep,
    /// In memory, visible *this* sweep when the receiver has not yet run
    /// (AM-Hama, Giraph++ vertex sweep, GraphHP async local phase).
    /// Sweep 0 always defers to the next sweep: programs treat the
    /// initialization superstep as message-free setup.
    ThisSweep,
}

/// Whether a vertex that stays active after computing is rescheduled
/// into the frontier for the next sweep.
#[derive(Clone, Copy, PartialEq, Eq)]
pub(crate) enum Reschedule {
    /// Always (standard BSP superstep loops, GraphHP local phase).
    Active,
    /// Only local-phase participants (GraphHP init sweep: boundary
    /// vertices sit out when `boundary_in_local_phase` is off).
    Participants,
    /// Never (the engine derives the next worklist itself).
    Never,
}

/// The mutable per-partition state a sweep runs against, as split
/// borrows so engines with extra per-partition state (GraphHP's global
/// inboxes) can lend exactly the relevant pieces.
pub(crate) struct SweepTarget<'a, V, M> {
    pub values: &'a mut [V],
    pub halted: &'a mut [bool],
    /// Inbox drained by this sweep (and receiving `ThisSweep` mail).
    pub cur: &'a mut MsgStore<M>,
    /// Inbox for the next sweep.
    pub nxt: &'a mut MsgStore<M>,
    /// Frontier receiving next-sweep schedules (None: the engine seeds
    /// the next sweep from `nxt`'s pending set instead).
    pub frontier: Option<&'a mut Frontier>,
}

/// Counters a sweep reports back to its engine.
#[derive(Clone, Copy, Default)]
pub(crate) struct SweepOutcome {
    pub computations: u64,
    pub local_messages: u64,
}

/// One in-memory sweep over a partition's worklist: the shared worker
/// body of every push-based engine.
pub(crate) struct Sweep<'a, P: VertexProgram> {
    pub program: &'a P,
    pub dg: &'a DistGraph,
    pub part: &'a PartGraph,
    pub p: usize,
    /// Superstep counter exposed to the program (global iteration for
    /// GraphHP).
    pub superstep: u64,
    pub seed: u64,
    pub combiner: Option<fn(P::M, P::M) -> P::M>,
    pub route: LocalRoute,
    pub reschedule: Reschedule,
    /// GraphHP §4.2: do boundary vertices participate in local phases?
    /// Read by `Reschedule::Participants` and the deferred-inbox routing;
    /// engines without the hybrid split pass `true` (neutral).
    pub boundary_in_local: bool,
    /// `> 0`: run this sweep through [`Sweep::run_stealing`] with that
    /// many worker threads ([`Parallelism::WorkStealing`]); `0`: the
    /// deterministic single-thread body. Engines pass
    /// `cfg.parallelism.steal_threads()`.
    pub steal_threads: usize,
}

impl<'a, P: VertexProgram> Sweep<'a, P> {
    /// Run the sweep over `scratch.worklist` (seeded by the engine).
    /// `deferred` is GraphHP's next-global-phase inbox for messages to
    /// non-participating boundary vertices (None elsewhere).
    ///
    /// Routing reads each send's pre-resolved [`crate::graph::EdgeRoute`]
    /// straight out of the [`SendBuffer`] — the location table is never
    /// consulted here (edge-directed sends copied the edge's precomputed
    /// route; arbitrary sends resolved at enqueue).
    pub fn run(
        &self,
        tgt: SweepTarget<'_, P::V, P::M>,
        mut deferred: Option<&mut MsgStore<P::M>>,
        outbox: &mut Outbox<P::M>,
        wagg: &mut Aggregators,
        scratch: &mut WorkerScratch<P::M>,
        marks: &mut ProcessedMarks,
    ) -> SweepOutcome {
        if self.steal_threads > 0 {
            return self.run_stealing(tgt, deferred, outbox, wagg, scratch, marks);
        }
        let mut out = SweepOutcome::default();
        marks.begin_sweep();
        let SweepTarget { values, halted, cur, nxt, mut frontier } = tgt;
        while let Some(lv32) = scratch.worklist.pop_first() {
            let lv = lv32 as usize;
            marks.mark(lv);
            cur.take_into(lv, &mut scratch.msg_buf);
            if halted[lv] {
                if scratch.msg_buf.is_empty() {
                    continue; // halted, no mail: stays inactive
                }
                halted[lv] = false; // a message reactivates (§4.1)
            }
            scratch.send_buf.clear();
            {
                let mut ctx = VertexContext::<P> {
                    part: self.part,
                    lv,
                    superstep: self.superstep,
                    value: &mut values[lv],
                    messages: &scratch.msg_buf,
                    halted: &mut halted[lv],
                    out: &mut scratch.send_buf,
                    aggregators: &mut *wagg,
                    seed: self.seed,
                    location: &self.dg.routing.location,
                };
                self.program.compute(&mut ctx);
            }
            out.computations += 1;
            let src_gid = self.part.global_ids[lv];
            for (route, m) in scratch.send_buf.sends.drain(..) {
                let (tp, tl) = route.unpack();
                if tp as usize != self.p || self.route == LocalRoute::Network {
                    outbox.push(tp, tl, src_gid, m);
                    continue;
                }
                let tl = tl as usize;
                out.local_messages += 1;
                if !(self.boundary_in_local || !self.part.is_boundary[tl]) {
                    if let Some(gq) = deferred.as_deref_mut() {
                        // boundary vertex sitting out the local phase:
                        // buffer for the next global phase (paper §4.2)
                        gq.push_combined(tl, m, self.combiner);
                        continue;
                    }
                }
                if self.route == LocalRoute::ThisSweep
                    && self.superstep > 0
                    && !marks.processed(tl)
                {
                    // receiver still to run this sweep: deliver now
                    cur.push_combined(tl, m, self.combiner);
                    scratch.worklist.schedule(tl as u32);
                } else {
                    nxt.push_combined(tl, m, self.combiner);
                    if let Some(f) = frontier.as_deref_mut() {
                        f.schedule(tl);
                    }
                }
            }
            if !halted[lv] {
                let resched = match self.reschedule {
                    Reschedule::Active => true,
                    Reschedule::Participants => {
                        self.boundary_in_local || !self.part.is_boundary[lv]
                    }
                    Reschedule::Never => false,
                };
                if resched {
                    if let Some(f) = frontier.as_deref_mut() {
                        f.schedule(lv);
                    }
                }
            }
        }
        out
    }

    /// The work-stealing sweep body ([`Parallelism::WorkStealing`]).
    ///
    /// Three phases:
    ///
    /// 1. **Pre-drain (serial).** Pop the whole worklist in ascending
    ///    order, move each vertex's mail into one flat buffer, and apply
    ///    the halted-skip/reactivate rule. The surviving vertices form a
    ///    fixed batch — nothing scheduled mid-sweep can join it.
    /// 2. **Compute (parallel).** The batch is cut into
    ///    [`STEAL_CHUNK`]-sized chunks claimed from an atomic counter by
    ///    scoped threads. Each chunk computes against a *copy* of its
    ///    vertex values with a fresh aggregator scratch
    ///    ([`Aggregators::fresh`]) and buffers its sends — shared state
    ///    is only ever read.
    /// 3. **Apply (serial).** Chunk outputs are sorted by chunk index —
    ///    i.e. ascending vertex order, the exact order phase 1 drained —
    ///    and applied one vertex at a time through the same routing code
    ///    path as the deterministic body.
    ///
    /// The one semantic difference from [`Sweep::run`]: a
    /// [`LocalRoute::ThisSweep`] message cannot be delivered into the
    /// running sweep (its receiver may already be computing on another
    /// thread), so it always lands in `nxt` — Gauss-Seidel relaxes to
    /// Jacobi. Convergence is unaffected; `tests/layout_equivalence.rs`
    /// pins the contract (exact for min-fold programs, epsilon for
    /// floating-point sums).
    fn run_stealing(
        &self,
        tgt: SweepTarget<'_, P::V, P::M>,
        mut deferred: Option<&mut MsgStore<P::M>>,
        outbox: &mut Outbox<P::M>,
        wagg: &mut Aggregators,
        scratch: &mut WorkerScratch<P::M>,
        marks: &mut ProcessedMarks,
    ) -> SweepOutcome {
        /// Vertices per steal unit: small enough to balance skewed
        /// degree distributions, large enough to amortize the claim.
        const STEAL_CHUNK: usize = 128;

        let mut out = SweepOutcome::default();
        marks.begin_sweep();
        let SweepTarget { values, halted, cur, nxt, mut frontier } = tgt;

        // ---- phase 1: serial pre-drain into a fixed batch ------------
        // (lv, start..end into `msgs`) per surviving vertex
        let mut batch: Vec<(u32, u32, u32)> = Vec::new();
        let mut msgs: Vec<P::M> = Vec::new();
        while let Some(lv32) = scratch.worklist.pop_first() {
            let lv = lv32 as usize;
            marks.mark(lv);
            let start = msgs.len() as u32;
            cur.take_into(lv, &mut scratch.msg_buf);
            if halted[lv] {
                if scratch.msg_buf.is_empty() {
                    continue; // halted, no mail: stays inactive
                }
                halted[lv] = false; // a message reactivates (§4.1)
            }
            msgs.append(&mut scratch.msg_buf);
            batch.push((lv32, start, msgs.len() as u32));
        }

        // ---- phase 2: parallel chunked compute -----------------------
        struct ChunkOut<V, M> {
            idx: usize,
            /// `(lv, new value, halted vote, send count)` in batch order.
            verts: Vec<(u32, V, bool, u32)>,
            /// Flat sends; each vertex owns the next `send count` pairs.
            sends: Vec<(crate::graph::EdgeRoute, M)>,
            aggs: Aggregators,
        }
        let num_chunks = batch.len().div_ceil(STEAL_CHUNK);
        let threads = self.steal_threads.min(num_chunks.max(1));
        let values_ro: &[P::V] = values;
        let batch_ro: &[(u32, u32, u32)] = &batch;
        let msgs_ro: &[P::M] = &msgs;
        let agg_template: &Aggregators = wagg;
        let claim = std::sync::atomic::AtomicUsize::new(0);
        let mut chunk_outs: Vec<ChunkOut<P::V, P::M>> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..threads)
                    .map(|_| {
                        scope.spawn(|| {
                            let mut outs: Vec<ChunkOut<P::V, P::M>> = Vec::new();
                            let mut send_buf = SendBuffer::new();
                            loop {
                                let idx = claim.fetch_add(
                                    1,
                                    std::sync::atomic::Ordering::Relaxed,
                                );
                                if idx >= num_chunks {
                                    return outs;
                                }
                                let lo = idx * STEAL_CHUNK;
                                let hi = (lo + STEAL_CHUNK).min(batch_ro.len());
                                let mut co = ChunkOut {
                                    idx,
                                    verts: Vec::with_capacity(hi - lo),
                                    sends: Vec::new(),
                                    aggs: agg_template.fresh(),
                                };
                                for &(lv32, start, end) in &batch_ro[lo..hi] {
                                    let lv = lv32 as usize;
                                    let mut value = values_ro[lv].clone();
                                    let mut vote_halt = false;
                                    send_buf.clear();
                                    let mut ctx = VertexContext::<P> {
                                        part: self.part,
                                        lv,
                                        superstep: self.superstep,
                                        value: &mut value,
                                        messages: &msgs_ro
                                            [start as usize..end as usize],
                                        halted: &mut vote_halt,
                                        out: &mut send_buf,
                                        aggregators: &mut co.aggs,
                                        seed: self.seed,
                                        location: &self.dg.routing.location,
                                    };
                                    self.program.compute(&mut ctx);
                                    let nsends = send_buf.sends.len() as u32;
                                    co.sends.extend(send_buf.sends.drain(..));
                                    co.verts.push((lv32, value, vote_halt, nsends));
                                }
                                outs.push(co);
                            }
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    // detlint: allow(unwrap-hot-path) — a stealing worker
                    // only returns by exhausting the claim counter; a
                    // panic inside it re-raises here, matching the
                    // deterministic body's abort semantics.
                    .flat_map(|h| h.join().expect("stealing worker panicked"))
                    .collect()
            });
        chunk_outs.sort_unstable_by_key(|c| c.idx);

        // ---- phase 3: serial apply in chunk (= ascending vertex) order
        for co in chunk_outs {
            wagg.merge_current(&co.aggs);
            let mut sends = co.sends.into_iter();
            for (lv32, value, vote_halt, nsends) in co.verts {
                let lv = lv32 as usize;
                values[lv] = value;
                halted[lv] = vote_halt;
                out.computations += 1;
                let src_gid = self.part.global_ids[lv];
                for (route, m) in sends.by_ref().take(nsends as usize) {
                    let (tp, tl) = route.unpack();
                    if tp as usize != self.p || self.route == LocalRoute::Network {
                        outbox.push(tp, tl, src_gid, m);
                        continue;
                    }
                    let tl = tl as usize;
                    out.local_messages += 1;
                    if !(self.boundary_in_local || !self.part.is_boundary[tl]) {
                        if let Some(gq) = deferred.as_deref_mut() {
                            // boundary vertex sitting out the local phase:
                            // buffer for the next global phase (paper §4.2)
                            gq.push_combined(tl, m, self.combiner);
                            continue;
                        }
                    }
                    // ThisSweep relaxed to next-sweep delivery (Jacobi):
                    // the receiver may have computed concurrently
                    nxt.push_combined(tl, m, self.combiner);
                    if let Some(f) = frontier.as_deref_mut() {
                        f.schedule(tl);
                    }
                }
                if !halted[lv] {
                    let resched = match self.reschedule {
                        Reschedule::Active => true,
                        Reschedule::Participants => {
                            self.boundary_in_local || !self.part.is_boundary[lv]
                        }
                        Reschedule::Never => false,
                    };
                    if resched {
                        if let Some(f) = frontier.as_deref_mut() {
                            f.schedule(lv);
                        }
                    }
                }
            }
        }
        out
    }
}

/// Everything a vertex-centric BSP worker owns for its partition:
/// runtime state, reusable scratch, and the pooled outbox.
pub(crate) struct WorkerState<V, M> {
    pub rt: PartitionRuntime<V, M>,
    pub scratch: WorkerScratch<M>,
    pub marks: ProcessedMarks,
    /// Pooled cross-partition outbox, lent to [`WorkerOut`] each
    /// superstep and returned by [`close_superstep`].
    pub outbox: Outbox<M>,
}

/// One [`WorkerState`] per partition of `dg`.
pub(crate) fn init_worker_states<P: VertexProgram>(
    program: &P,
    dg: &DistGraph,
) -> Vec<WorkerState<P::V, P::M>> {
    dg.parts
        .iter()
        .map(|part| {
            let rt = PartitionRuntime::new(program, part);
            let n = rt.num_vertices();
            WorkerState {
                rt,
                scratch: WorkerScratch::new(),
                marks: ProcessedMarks::new(n),
                outbox: Outbox::new(program.combiner()),
            }
        })
        .collect()
}

/// Snapshot every worker's partition runtime into a [`Checkpoint`] at a
/// superstep boundary, tagged with the migration trajectory applied so
/// far. The plain BSP engines have no global-phase inbox and no hybrid
/// scheduler, so those checkpoint columns stay empty; GraphHP builds its
/// richer checkpoint by hand in `engine/graphhp.rs`.
pub(crate) fn snapshot_worker_states<V: Clone, M: Clone>(
    iteration: u64,
    workers: &mut [WorkerState<V, M>],
    plans: &[MigrationPlan],
) -> Checkpoint<V, M> {
    let nparts = workers.len();
    let mut ckpt = Checkpoint {
        iteration,
        values: Vec::with_capacity(nparts),
        halted: Vec::with_capacity(nparts),
        inbox: vec![Vec::new(); nparts],
        local_cur: Vec::with_capacity(nparts),
        local_nxt: Vec::with_capacity(nparts),
        frontier: Vec::with_capacity(nparts),
        policy: Vec::new(),
        migrations: plans.to_vec(),
    };
    for w in workers {
        ckpt.values.push(w.rt.values.clone());
        ckpt.halted.push(w.rt.halted.clone());
        ckpt.local_cur.push(w.rt.cur.export());
        ckpt.local_nxt.push(w.rt.nxt.export());
        ckpt.frontier.push(w.rt.frontier.snapshot());
    }
    ckpt
}

/// Rebuild every worker from `ckpt`: replay the checkpointed migration
/// trajectory onto the pristine graph so the routing geometry matches
/// the snapshot, then restore each partition's runtime verbatim (scratch,
/// marks and outbox are rebuilt empty — they carry no cross-superstep
/// state). Returns the superstep to resume at.
pub(crate) fn restore_worker_states<V: Clone, M: Clone + Codec>(
    dg: &DistGraph,
    ckpt: &Checkpoint<V, M>,
    dg_owned: &mut Option<Box<DistGraph>>,
    applied_plans: &mut Vec<MigrationPlan>,
    combiner: Option<fn(M, M) -> M>,
) -> (Vec<WorkerState<V, M>>, u64) {
    *dg_owned = super::recovery::replay_geometry(dg, &ckpt.migrations);
    *applied_plans = ckpt.migrations.clone();
    let workers = (0..ckpt.values.len())
        .map(|p| {
            let rt = super::recovery::restore_runtime(ckpt, p);
            let n = rt.num_vertices();
            WorkerState {
                rt,
                scratch: WorkerScratch::new(),
                marks: ProcessedMarks::new(n),
                outbox: Outbox::new(combiner),
            }
        })
        .collect();
    (workers, ckpt.iteration)
}

/// What one worker hands back at the barrier.
pub(crate) struct WorkerOut<M> {
    /// The worker's (sealed) outbox, moved out of its pooled slot for
    /// the barrier drain and handed back by [`close_superstep`].
    pub outbox: Outbox<M>,
    /// This worker's aggregator partials.
    pub aggs: Aggregators,
    /// Scaled compute time measured on this worker's thread.
    pub compute: Duration,
    /// Outgoing cross-partition traffic (for the simulated network).
    pub comm: WorkerComm,
    pub computations: u64,
    pub local_messages: u64,
    /// (Pseudo-)supersteps this worker executed (GraphHP counts its
    /// phases here; plain BSP engines report 0 and count the global
    /// superstep engine-side).
    pub supersteps: u64,
    /// This turn's telemetry record. The engine fills the sweep-level
    /// fields (frontier composition, pseudo-superstep counts, carryover
    /// flags); [`WorkerOut::new`] fills the accounting fields it derives
    /// itself (partition, message split, compute time).
    pub trace: PartitionStepTrace,
}

impl<M: Clone + Codec> WorkerOut<M> {
    /// Package a finished worker turn: derive the wire accounting from
    /// the sealed outbox and complete the telemetry record.
    pub fn new(
        outbox: Outbox<M>,
        aggs: Aggregators,
        compute: Duration,
        p: usize,
        outcome: SweepOutcome,
        supersteps: u64,
        mut trace: PartitionStepTrace,
    ) -> Self {
        let comm = WorkerComm {
            messages: outbox.len() as u64,
            bytes: outbox.wire_bytes() as u64,
            peer_pairs: outbox.peer_count(p as u32) as u64,
        };
        trace.partition = p as u32;
        trace.network_messages = comm.messages;
        trace.local_messages = outcome.local_messages;
        trace.compute_us = compute.as_micros() as u64;
        WorkerOut {
            outbox,
            aggs,
            compute,
            comm,
            computations: outcome.computations,
            local_messages: outcome.local_messages,
            supersteps,
            trace,
        }
    }
}

/// Count the boundary vertices (Definition 1) in a worklist — the
/// telemetry's frontier-composition signal.
pub(crate) fn boundary_count<'a>(
    part: &PartGraph,
    worklist: impl IntoIterator<Item = &'a u32>,
) -> u64 {
    worklist.into_iter().filter(|&&lv| part.is_boundary[lv as usize]).count() as u64
}

/// Balanced work split: chunk sizes for distributing `n` items over
/// `threads` workers differ by at most one. The previous
/// `ceil(n/threads)` split could idle almost half the pool (n=17,
/// threads=16 → 9 chunks of ≤2, only 9 threads running).
pub(crate) fn chunk_sizes(n: usize, threads: usize) -> Vec<usize> {
    let t = threads.min(n).max(1);
    let base = n / t;
    let rem = n % t;
    (0..t).map(|i| base + usize::from(i < rem)).collect()
}

/// Run one worker per partition — `f(p, &mut states[p])` — sequentially
/// or multiplexed onto scoped OS threads, returning the outputs in
/// partition order. A worker panic propagates after all threads join
/// (`std::thread::scope`), so a panicking vertex program aborts the run
/// instead of deadlocking the barrier.
pub(crate) fn run_workers<T, R, F>(par: Parallelism, states: &mut [T], f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> R + Sync,
{
    let threads = match par {
        Parallelism::Sequential => 1,
        Parallelism::Threads(n) => n.max(1).min(states.len().max(1)),
        // work-stealing parallelizes *inside* each sweep
        // ([`Sweep::run_stealing`]); the partition loop stays sequential
        // so barrier folds keep their partition-order determinism.
        Parallelism::WorkStealing(_) => 1,
    };
    if threads <= 1 {
        return states.iter_mut().enumerate().map(|(p, st)| f(p, st)).collect();
    }
    let n = states.len();
    let sizes = chunk_sizes(n, threads);
    let mut results: Vec<Option<R>> = Vec::with_capacity(n);
    results.resize_with(n, || None);
    let fref = &f;
    std::thread::scope(|scope| {
        let mut st_rest: &mut [T] = states;
        let mut res_rest: &mut [Option<R>] = &mut results;
        let mut base = 0usize;
        for &size in &sizes {
            // move the remainder out before splitting so the chunk
            // borrows can outlive this loop iteration (scoped spawn)
            let (st_chunk, st_tail) = std::mem::take(&mut st_rest).split_at_mut(size);
            let (res_chunk, res_tail) = std::mem::take(&mut res_rest).split_at_mut(size);
            st_rest = st_tail;
            res_rest = res_tail;
            let start = base;
            scope.spawn(move || {
                for (i, (st, slot)) in
                    st_chunk.iter_mut().zip(res_chunk.iter_mut()).enumerate()
                {
                    *slot = Some(fref(start + i, st));
                }
            });
            base += size;
        }
    });
    // detlint: allow(unwrap-hot-path) — every chunk slot is written by
    // exactly one scoped worker; the scope joined (or propagated a
    // panic) before this line runs.
    results.into_iter().map(|r| r.expect("worker produced no output")).collect()
}

/// Fold the workers' outputs into the engine's global state in partition
/// order — the delivery order that makes a threaded run bit-for-bit
/// identical to a sequential one. `deliver` routes one cross-partition
/// message `(dest_part, dest_local, msg)` into the destination's inbox
/// (engines apply receiver-side combining here via
/// [`MsgStore::push_combined`]). Appends one [`StepTrace`] (the workers'
/// telemetry records in partition order) to `trace`. Returns the drained
/// outboxes in partition order so engines can slot them back for reuse.
///
/// When a [`super::chaos::ChaosController`] is supplied, every sealed
/// batch gets a fault verdict *here* — after `Outbox::seal` (sender-side
/// combining done), before inbox push (receiver-side combining not yet
/// run) — so injected faults act on wire batches without ever violating
/// combiner semantics. Verdicts are drawn on the engine thread in
/// partition order, which keeps sequential ≡ threaded and the same seed
/// ⇒ the same `ChaosTrace`. A lost batch is simply not delivered; the
/// engine must poll [`super::chaos::ChaosController::take_pending`]
/// right after this returns and either roll back to a checkpoint or
/// fail loudly.
pub(crate) fn close_superstep<M: Clone + Codec>(
    outs: Vec<WorkerOut<M>>,
    aggs: &mut Aggregators,
    clock: &mut SuperstepClock,
    net: &NetSimConfig,
    metrics: &mut Metrics,
    trace: &mut RunTrace,
    mut chaos: Option<&mut super::chaos::ChaosController>,
    mut deliver: impl FnMut(u32, u32, M),
) -> Vec<Outbox<M>> {
    let mut outboxes = Vec::with_capacity(outs.len());
    let mut step = StepTrace {
        iteration: trace.steps.len() as u64,
        partitions: Vec::with_capacity(outs.len()),
        // the engine stamps routing_epoch/migrated after the barrier,
        // once its migration decision for this iteration is known
        ..Default::default()
    };
    if let Some(ctl) = chaos.as_deref_mut() {
        // the monotone barrier counter keys all chaos scheduling: it
        // keeps advancing across rollbacks, so replayed iterations draw
        // fresh RNG streams and recovery always makes progress
        ctl.begin_barrier(step.iteration);
    }
    for (w, mut o) in outs.into_iter().enumerate() {
        // debug sanitizer: an outbox reaching the barrier must be sealed
        // and destination-ordered (no-op in release builds)
        super::invariants::check_outbox_sealed(&o.outbox);
        metrics.network_messages += o.comm.messages;
        metrics.network_bytes += o.comm.bytes;
        metrics.local_messages += o.local_messages;
        metrics.vertex_computations += o.computations;
        metrics.supersteps_total += o.supersteps;
        clock.record_worker_at(w, o.compute, net.comm_time(&o.comm));
        match chaos.as_deref_mut() {
            None => {
                for (tp, tl, m) in o.outbox.drain() {
                    deliver(tp, tl, m);
                }
            }
            Some(ctl) => {
                // batch-granular delivery: one verdict per sealed
                // (sender, destination) batch. A self-batch never
                // touches the wire, so it cannot be judged.
                for tp in 0..o.outbox.num_dests() {
                    let n = o.outbox.batch_size(tp);
                    if n == 0 {
                        continue;
                    }
                    if tp == w || ctl.judge(w as u32, tp as u32, n as u64) {
                        for (tl, m) in o.outbox.drain_batch(tp) {
                            deliver(tp as u32, tl, m);
                        }
                    }
                    // a lost batch stays undrained; the pending-loss
                    // flag forces the engine to roll back (or die)
                    // before the stale outbox could ever be reused
                }
            }
        }
        outboxes.push(o.outbox);
        aggs.merge_current(&o.aggs);
        step.partitions.push(std::mem::take(&mut o.trace));
    }
    trace.steps.push(step);
    aggs.barrier();
    clock.barrier(net, metrics);
    if let Some(ctl) = chaos.as_deref_mut() {
        ctl.end_barrier();
    }
    outboxes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::collections::BTreeSet;

    /// Property-style test: drive random schedule/drain/mid-sweep-insert
    /// sequences against a `BTreeSet` reference model and require the
    /// identical pop order. One pooled `Worklist` is reused across every
    /// round (including rounds abandoned mid-drain) to also prove
    /// `begin` fully re-arms leftover state.
    #[test]
    fn worklist_matches_btreeset_reference_model() {
        let mut rng = Rng::new(0xB7EE);
        let mut wl = Worklist::default();
        for round in 0..300u32 {
            let n = 1 + rng.index(96);
            let mut model: BTreeSet<u32> = BTreeSet::new();
            wl.begin(n);
            // seed phase: random schedules, duplicates included
            for _ in 0..rng.index(2 * n + 1) {
                let v = rng.index(n) as u32;
                wl.schedule(v);
                model.insert(v);
            }
            assert_eq!(wl.len(), model.len(), "round {round}: seed size");
            // drain phase with interleaved mid-sweep insertions (some
            // smaller than everything already popped, some duplicates of
            // queued entries, some re-inserts of popped ids)
            let abandon_at = if round % 7 == 3 { Some(rng.index(n)) } else { None };
            let mut pops = 0usize;
            loop {
                if rng.index(3) == 0 {
                    let v = rng.index(n) as u32;
                    wl.schedule(v);
                    model.insert(v);
                }
                if Some(pops) == abandon_at {
                    // leave the worklist mid-drain: the next begin()
                    // must clear the leftovers
                    break;
                }
                let got = wl.pop_first();
                let want = model.pop_first();
                assert_eq!(got, want, "round {round}, pop {pops}");
                if got.is_none() {
                    break;
                }
                pops += 1;
            }
        }
    }

    /// The exact mid-sweep case the ThisSweep route relies on: an id
    /// smaller than the drain cursor, never seeded, scheduled mid-sweep,
    /// must pop next — just like `BTreeSet::pop_first` would yield it.
    #[test]
    fn worklist_mid_sweep_insert_of_smaller_id_pops_next() {
        let mut wl = Worklist::default();
        wl.begin(16);
        wl.schedule(5);
        wl.schedule(10);
        assert_eq!(wl.pop_first(), Some(5));
        wl.schedule(3); // smaller than the already-popped 5
        assert_eq!(wl.pop_first(), Some(3));
        wl.schedule(7);
        wl.schedule(7); // duplicate: no-op
        assert_eq!(wl.len(), 2);
        assert_eq!(wl.pop_first(), Some(7));
        assert_eq!(wl.pop_first(), Some(10));
        assert_eq!(wl.pop_first(), None);
        assert!(wl.is_empty());
    }

    #[test]
    fn worklist_schedule_dedups_against_seeded_entries() {
        let mut wl = Worklist::default();
        wl.begin(8);
        wl.schedule(4);
        wl.schedule(1);
        wl.schedule(4); // already seeded: no-op
        assert_eq!(wl.len(), 2);
        assert_eq!(wl.as_slice(), &[4, 1], "seed order before the first pop");
        assert_eq!(wl.pop_first(), Some(1));
        wl.schedule(4); // still queued: no-op
        assert_eq!(wl.pop_first(), Some(4));
        assert_eq!(wl.pop_first(), None);
    }

    #[test]
    fn worklist_begin_clears_abandoned_entries() {
        let mut wl = Worklist::default();
        wl.begin(8);
        wl.schedule(2);
        wl.schedule(6);
        assert_eq!(wl.pop_first(), Some(2));
        wl.schedule(1); // pending entry
        // abandon with 6 seeded and 1 pending, then re-arm
        wl.begin(8);
        assert!(wl.is_empty());
        wl.schedule(6);
        wl.schedule(1);
        assert_eq!(wl.len(), 2, "abandoned membership flags must be cleared");
        assert_eq!(wl.pop_first(), Some(1));
        assert_eq!(wl.pop_first(), Some(6));
    }

    #[test]
    fn processed_marks_reset_per_sweep() {
        let mut m = ProcessedMarks::new(3);
        m.begin_sweep();
        m.mark(1);
        assert!(m.processed(1));
        assert!(!m.processed(0));
        m.begin_sweep();
        assert!(!m.processed(1));
    }

    #[test]
    fn chunk_sizes_differ_by_at_most_one() {
        for (n, t) in [(17usize, 16usize), (16, 4), (5, 16), (1, 8), (100, 7), (9, 9)] {
            let sizes = chunk_sizes(n, t);
            assert_eq!(sizes.iter().sum::<usize>(), n, "n={n} t={t}");
            assert_eq!(sizes.len(), t.min(n), "n={n} t={t}: every thread gets work");
            let (min, max) =
                (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "n={n} t={t}: {sizes:?}");
            assert!(*min >= 1, "n={n} t={t}: no empty chunk");
        }
    }

    #[test]
    fn chunking_uses_every_thread() {
        // the regression case from the old ceil split: n=17, threads=16
        // produced 9 chunks — 7 threads sat idle
        let sizes = chunk_sizes(17, 16);
        assert_eq!(sizes.len(), 16);
        assert_eq!(sizes.iter().filter(|&&s| s == 2).count(), 1);
        assert_eq!(sizes.iter().filter(|&&s| s == 1).count(), 15);
    }

    #[test]
    fn run_workers_sequential_and_threaded_agree() {
        let mut a: Vec<u64> = (0..17).collect();
        let mut b = a.clone();
        let seq = run_workers(Parallelism::Sequential, &mut a, |p, x| {
            *x += 1;
            *x * p as u64
        });
        let par = run_workers(Parallelism::Threads(4), &mut b, |p, x| {
            *x += 1;
            *x * p as u64
        });
        assert_eq!(seq, par);
        assert_eq!(a, b);
    }

    #[test]
    fn run_workers_balanced_split_keeps_index_mapping() {
        // n=17 over 16 threads: uneven chunk sizes must not scramble the
        // partition-index → result mapping
        let mut xs: Vec<u64> = (0..17).collect();
        let out = run_workers(Parallelism::Threads(16), &mut xs, |p, x| (p as u64, *x));
        for (i, &(p, v)) in out.iter().enumerate() {
            assert_eq!(p, i as u64);
            assert_eq!(v, i as u64);
        }
    }

    #[test]
    fn run_workers_more_threads_than_items() {
        let mut xs: Vec<u32> = vec![5, 6];
        let out = run_workers(Parallelism::Threads(16), &mut xs, |_, x| *x * 2);
        assert_eq!(out, vec![10, 12]);
    }

    #[test]
    fn run_workers_propagates_worker_panic() {
        let mut xs: Vec<u32> = (0..8).collect();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_workers(Parallelism::Threads(4), &mut xs, |_, x| {
                if *x == 5 {
                    panic!("worker boom");
                }
                *x
            })
        }));
        assert!(r.is_err(), "panic must propagate through the scope join");
    }
}
