//! The vertex-centric programming interface (paper §3, §5).

use crate::graph::VertexId;
use crate::util::Codec;

use super::context::VertexContext;

/// GraphHP's `SourceCombine()` policy: how messages buffered between
/// global iterations that originate from the *same source vertex* and
/// target the same destination are merged (paper §5). Only consulted when
/// [`VertexProgram::combiner`] is `None` (a full combiner subsumes it).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SourceCombine {
    /// Keep every message (no merging).
    #[default]
    KeepAll,
    /// Keep only the latest message per (source, destination) pair —
    /// the GraphHP default for value-propagation algorithms.
    KeepLatest,
}

/// A vertex-centric BSP program: the `Vertex` subclass of Pregel/Hama.
///
/// The same `compute` runs unmodified on every engine — standard BSP
/// supersteps, AM-Hama asynchronous supersteps, and GraphHP global/local
/// phases — which is the paper's central interface claim.
///
/// The trait is `Sync` and its associated types are `Send + Sync`
/// because the engines run one worker per partition on real OS threads
/// ([`crate::engine::Parallelism`]): the program is shared across
/// workers, and values/messages move between worker-owned partition
/// state at the barrier.
pub trait VertexProgram: Sync {
    /// Vertex value type (`getValue()`/`setValue()`).
    type V: Clone + Send + Sync + Codec;
    /// Message type.
    type M: Clone + Send + Sync + Codec;

    /// Initial vertex value, assigned before superstep 0.
    fn init(&self, vertex: VertexId, out_degree: u32) -> Self::V;

    /// The user-defined `Compute()` (paper §3): runs once per active
    /// vertex per (pseudo-)superstep, reading the messages delivered to
    /// the vertex and the vertex state through `ctx`.
    fn compute(&self, ctx: &mut VertexContext<'_, Self>)
    where
        Self: Sized;

    /// Optional `Combine()`: merge two messages bound for the same
    /// destination vertex into one. Must be commutative + associative.
    fn combiner(&self) -> Option<fn(Self::M, Self::M) -> Self::M> {
        None
    }

    /// GraphHP `SourceCombine()` policy (see [`SourceCombine`]).
    fn source_combine(&self) -> SourceCombine {
        SourceCombine::default()
    }

    /// Number of f64 aggregators this program uses (ids `0..n`).
    fn num_aggregators(&self) -> usize {
        0
    }

    /// Aggregator reduce ops, queried once at startup for ids
    /// `0..num_aggregators()`.
    fn aggregator_op(&self, _id: usize) -> super::AggOp {
        super::AggOp::Sum
    }
}

/// A shared reference to a vertex program is itself a vertex program.
/// This lets the [`super::Runner`] hand a borrowed program to adapters
/// that take ownership (e.g. [`super::giraphpp::VertexSweep`]).
impl<'p, P: VertexProgram> VertexProgram for &'p P {
    type V = P::V;
    type M = P::M;

    fn init(&self, vertex: VertexId, out_degree: u32) -> Self::V {
        (**self).init(vertex, out_degree)
    }

    fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
        // reborrow the context at the underlying program type; the field
        // types are identical because Self::V = P::V and Self::M = P::M
        let mut inner = VertexContext::<P> {
            part: ctx.part,
            lv: ctx.lv,
            superstep: ctx.superstep,
            value: &mut *ctx.value,
            messages: ctx.messages,
            halted: &mut *ctx.halted,
            out: &mut *ctx.out,
            aggregators: &mut *ctx.aggregators,
            seed: ctx.seed,
            location: ctx.location,
        };
        (**self).compute(&mut inner);
    }

    fn combiner(&self) -> Option<fn(Self::M, Self::M) -> Self::M> {
        (**self).combiner()
    }

    fn source_combine(&self) -> SourceCombine {
        (**self).source_combine()
    }

    fn num_aggregators(&self) -> usize {
        (**self).num_aggregators()
    }

    fn aggregator_op(&self, id: usize) -> super::AggOp {
        (**self).aggregator_op(id)
    }
}
