//! The vertex-centric programming interface (paper §3, §5).

use crate::graph::VertexId;
use crate::util::Codec;

use super::context::VertexContext;

/// GraphHP's `SourceCombine()` policy: how messages buffered between
/// global iterations that originate from the *same source vertex* and
/// target the same destination are merged (paper §5). Only consulted when
/// [`VertexProgram::combiner`] is `None` (a full combiner subsumes it).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SourceCombine {
    /// Keep every message (no merging).
    #[default]
    KeepAll,
    /// Keep only the latest message per (source, destination) pair —
    /// the GraphHP default for value-propagation algorithms.
    KeepLatest,
}

/// A vertex-centric BSP program: the `Vertex` subclass of Pregel/Hama.
///
/// The same `compute` runs unmodified on every engine — standard BSP
/// supersteps, AM-Hama asynchronous supersteps, and GraphHP global/local
/// phases — which is the paper's central interface claim.
pub trait VertexProgram: Sync {
    /// Vertex value type (`getValue()`/`setValue()`).
    type V: Clone + Send + Sync + Codec;
    /// Message type.
    type M: Clone + Send + Sync + Codec;

    /// Initial vertex value, assigned before superstep 0.
    fn init(&self, vertex: VertexId, out_degree: u32) -> Self::V;

    /// The user-defined `Compute()` (paper §3): runs once per active
    /// vertex per (pseudo-)superstep, reading the messages delivered to
    /// the vertex and the vertex state through `ctx`.
    fn compute(&self, ctx: &mut VertexContext<'_, Self>)
    where
        Self: Sized;

    /// Optional `Combine()`: merge two messages bound for the same
    /// destination vertex into one. Must be commutative + associative.
    fn combiner(&self) -> Option<fn(Self::M, Self::M) -> Self::M> {
        None
    }

    /// GraphHP `SourceCombine()` policy (see [`SourceCombine`]).
    fn source_combine(&self) -> SourceCombine {
        SourceCombine::default()
    }

    /// Number of f64 aggregators this program uses (ids `0..n`).
    fn num_aggregators(&self) -> usize {
        0
    }

    /// Aggregator reduce ops, queried once at startup for ids
    /// `0..num_aggregators()`.
    fn aggregator_op(&self, _id: usize) -> super::AggOp {
        super::AggOp::Sum
    }
}
