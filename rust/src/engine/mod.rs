//! The GraphHP execution engines and the vertex-centric BSP programming
//! interface.
//!
//! Programming interface (paper §3): users implement [`VertexProgram`]
//! (the `Vertex.Compute()` of Pregel/Hama) optionally with a message
//! combiner and a GraphHP `SourceCombine` policy, plus [`Aggregators`]
//! for global communication. The GraphLab comparator uses the pull-based
//! [`graphlab::GasProgram`], and the Giraph++ comparator the
//! graph-centric [`giraphpp::PartitionProgram`].
//!
//! # Running programs: the [`Runner`] session
//!
//! [`Runner`] is the single entry point for every engine: it owns the
//! partition → distribute plumbing once and dispatches on [`EngineKind`],
//! so the same program runs unmodified on every engine — the paper's
//! central interface claim (§3, §5).
//!
//! ```no_run
//! use graphhp::algorithms::IncrementalPageRank;
//! use graphhp::engine::{EngineKind, Runner};
//! use graphhp::graph::generators;
//!
//! let g = generators::powerlaw(20_000, 5, 42);
//! let result = Runner::new(&g)
//!     .partitions(12)
//!     .engine(EngineKind::GraphHP)
//!     .run(&IncrementalPageRank { tolerance: 1e-4 });
//! println!("{}", result.metrics.summary());
//! ```
//!
//! ## Migration from the free functions
//!
//! The per-engine free functions still exist (the `Runner` delegates to
//! them) but are no longer the public surface. The full old → new
//! mapping table lives in `docs/architecture.md` ("Migration map"),
//! together with the layer map, the six-engine matrix, and the step
//! lifecycle / message plane diagrams.
//!
//! # The message plane and step lifecycle
//!
//! [`messages::MsgStore`] (a partition's inbox) stores messages in one
//! flat slot arena threaded into per-vertex chains; drained slots are
//! recycled, so steady-state sweeps allocate nothing.
//! [`messages::Outbox`] (a worker's per-superstep output) batches by
//! destination partition; `seal` applies sender-side combining and
//! orders each batch, making barrier delivery deterministic by
//! construction, and delivery itself combines receiver-side
//! (`MsgStore::push_combined`), so inboxes hold one message per vertex
//! under a combiner no matter how many source partitions sent.
//! Engines advance per-partition state through the explicit step
//! lifecycle on [`state::PartitionRuntime`]
//! (`begin_step`/`commit_step`/`abort_step_carryover`), which is what
//! lets GraphHP's `max_pseudo_supersteps` cap truncate a local phase
//! without losing frontier entries or in-flight mail.
//!
//! # Superstep telemetry and the adaptive scheduler
//!
//! Every run returns a [`RunTrace`] on its [`RunResult`]: one record
//! per barrier per partition (frontier occupancy, boundary composition,
//! pseudo-superstep counts, local-vs-network message split, carryover
//! events, per-worker compute time). [`HybridPolicy::Adaptive`] feeds
//! the trace back into the GraphHP engine online, deciding per
//! partition and per iteration whether to run the local phase, how high
//! to cap pseudo-supersteps, and whether boundary vertices join local
//! phases — all from deterministic counters, so the parallel-equivalence
//! guarantee below is preserved.
//!
//! # Parallel execution
//!
//! Engines run one worker per partition. By default the workers execute
//! on real OS threads (`Parallelism::Threads(available_parallelism)`,
//! see [`Parallelism`]); `Parallelism::Sequential` runs them one after
//! another on the calling thread. The two modes are **bit-for-bit
//! identical** — workers share nothing within a superstep and the
//! barrier folds their outboxes, aggregator partials and clock records
//! in partition order (`engine/worker.rs`). Compute time is measured on
//! the worker threads, so the max-over-workers term of the simulated
//! superstep ([`netsim`]) reflects a *measured* straggler under real
//! parallelism. The GraphLab async comparator is the one exception: its
//! immediate-visibility updates are order-dependent, so it always
//! executes sequentially and models parallel efficiency via [`GasCost`]
//! (the paper's locking argument).
//!
//! # Execution engines (paper §4, §7)
//!
//! - [`hama`] (`run_hama`) — the standard BSP model (one superstep =
//!   one global barrier + full message exchange);
//! - [`am_hama`] (`run_am_hama`) — BSP + asynchronous in-memory
//!   messaging within a partition (Grace-style, the paper's AM-Hama
//!   baseline);
//! - [`graphhp`] (`run_graphhp`) — the paper's hybrid model: per global
//!   iteration a *global phase* over boundary vertices then a *local
//!   phase* of pseudo-supersteps until the partition quiesces;
//! - [`giraphpp`] — a graph-centric (Giraph++-style) engine;
//! - [`graphlab`] — GraphLab-style sync (pull/GAS) and async engines.
//!
//! All engines execute over a [`crate::graph::DistGraph`] and account
//! wall-clock into compute/communication/synchronization buckets under
//! the simulated cluster cost model of [`netsim`] (the stand-in for the
//! paper's 13-machine Ethernet cluster — DESIGN.md §2).

pub mod aggregator;
pub mod am_hama;
pub mod chaos;
pub mod checkpoint;
pub mod context;
pub mod giraphpp;
pub mod graphhp;
pub mod graphlab;
pub mod hama;
pub(crate) mod invariants;
pub mod messages;
pub mod metrics;
pub mod migrate;
pub mod netsim;
pub mod program;
pub mod recovery;
pub mod runner;
pub mod state;
pub(crate) mod worker;

pub use aggregator::{AggOp, Aggregators};
pub use chaos::{ChaosEvent, ChaosEventKind, ChaosPolicy, ChaosSchedule, ChaosTrace, NetSplit};
pub use context::VertexContext;
pub use graphlab::GasCost;
pub use metrics::{Metrics, PartitionStepTrace, RunTrace, StepTrace};
pub use migrate::{MigrationPlanner, RepartitionConfig};
pub use netsim::NetSimConfig;
pub use program::{SourceCombine, VertexProgram};
pub use recovery::RecoveryPolicy;
pub use runner::{Partitioner, Runner};

use crate::graph::DistGraph;

/// Which engine executes a run. The [`Runner`] dispatches on this; it is
/// also used for reporting.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EngineKind {
    /// Standard BSP (the Hama/Pregel baseline).
    Hama,
    /// BSP with asynchronous in-memory messaging within a partition.
    AmHama,
    /// The paper's hybrid global-phase / local-phase engine.
    GraphHP,
    /// Graph-centric (Giraph++-style) engine.
    GiraphPP,
    /// GraphLab-style synchronous pull (GAS) engine.
    GraphLabSync,
    /// GraphLab-style asynchronous pull (GAS) engine.
    GraphLabAsync,
}

impl EngineKind {
    /// Every engine, in the paper's presentation order.
    pub const ALL: [EngineKind; 6] = [
        EngineKind::Hama,
        EngineKind::AmHama,
        EngineKind::GraphHP,
        EngineKind::GiraphPP,
        EngineKind::GraphLabSync,
        EngineKind::GraphLabAsync,
    ];

    /// The engines that execute a [`VertexProgram`] directly (the
    /// GraphLab engines are pull-based and take a
    /// [`graphlab::GasProgram`] via [`Runner::run_gas`] instead).
    pub const VERTEX_CENTRIC: [EngineKind; 4] = [
        EngineKind::Hama,
        EngineKind::AmHama,
        EngineKind::GraphHP,
        EngineKind::GiraphPP,
    ];

    /// True for the pull-based (GAS) GraphLab engines.
    pub fn is_gas(self) -> bool {
        matches!(self, EngineKind::GraphLabSync | EngineKind::GraphLabAsync)
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            EngineKind::Hama => "Hama",
            EngineKind::AmHama => "AM-Hama",
            EngineKind::GraphHP => "GraphHP",
            EngineKind::GiraphPP => "Giraph++",
            EngineKind::GraphLabSync => "GraphLab(Sync)",
            EngineKind::GraphLabAsync => "GraphLab(Async)",
        };
        f.write_str(s)
    }
}

impl std::str::FromStr for EngineKind {
    type Err = String;

    /// Accepts the CLI spellings: `hama`, `am-hama`, `graphhp`,
    /// `giraph++`/`giraphpp`, `graphlab-sync`, `graphlab-async`.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "hama" => Ok(EngineKind::Hama),
            "am-hama" | "amhama" => Ok(EngineKind::AmHama),
            "graphhp" => Ok(EngineKind::GraphHP),
            "giraph++" | "giraphpp" => Ok(EngineKind::GiraphPP),
            "graphlab-sync" => Ok(EngineKind::GraphLabSync),
            "graphlab-async" => Ok(EngineKind::GraphLabAsync),
            other => Err(format!(
                "unknown engine {other} (hama|am-hama|graphhp|giraph++|graphlab-sync|graphlab-async)"
            )),
        }
    }
}

/// How the engines execute their per-partition workers within a
/// superstep (the barrier structure is the same either way).
///
/// Determinism guarantee: `Sequential` and `Threads(n)` produce
/// bit-for-bit identical [`RunResult`] values and identical
/// message/iteration counts for every engine — workers are
/// shared-nothing within a superstep and the barrier folds their
/// outputs in partition order. Only wall-clock changes.
///
/// `WorkStealing(n)` is the opt-in third mode for skewed or
/// few-partition runs where one straggler partition idles the pool: it
/// keeps the partition loop sequential but parallelizes *inside* each
/// sweep — the sorted worklist is pre-drained, split into fixed-size
/// chunks, and the chunks are claimed by `n` scoped threads through an
/// atomic counter. Only **thread assignment** is relaxed: results are
/// applied and messages routed in chunk (= ascending vertex) order, so
/// a WorkStealing run is deterministic run-to-run. It differs from
/// `Sequential` in exactly one semantic: same-sweep (`ThisSweep`) local
/// messages are deferred to the next sweep (Jacobi instead of
/// Gauss-Seidel), so min/max-fixpoint programs (SSSP, WCC) converge to
/// the *identical* values while floating-point-sum programs (PageRank)
/// converge within epsilon — `tests/layout_equivalence.rs` is the
/// oracle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Parallelism {
    /// One worker after another on the calling thread.
    Sequential,
    /// One worker per partition, multiplexed onto up to N scoped OS
    /// threads (`std::thread::scope`).
    Threads(usize),
    /// Sequential partition loop with N scoped threads claiming
    /// fixed-size chunks of each partition's sorted worklist through an
    /// atomic counter (deterministic apply order; see above for the
    /// exact-vs-epsilon contract).
    WorkStealing(usize),
}

impl Parallelism {
    /// `Threads(available_parallelism)` — the default.
    pub fn auto() -> Parallelism {
        Parallelism::Threads(
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        )
    }

    /// Threads stealing chunks *within* each sweep: `n` for
    /// `WorkStealing(n)`, 0 otherwise (engines pass this straight to the
    /// sweep core).
    pub fn steal_threads(&self) -> usize {
        match *self {
            Parallelism::WorkStealing(n) => n,
            _ => 0,
        }
    }
}

impl Default for Parallelism {
    fn default() -> Self {
        Parallelism::auto()
    }
}

/// Iteration caps (safety valves) shared by all engines.
#[derive(Clone, Copy, Debug)]
pub struct Limits {
    /// Hard cap on global iterations / supersteps.
    pub max_iterations: u64,
    /// Hard cap on pseudo-supersteps per GraphHP local phase. A capped
    /// phase carries its remaining work into the next iteration
    /// (`PartitionRuntime::abort_step_carryover`); 0 is treated as 1 —
    /// a phase always makes progress.
    pub max_pseudo_supersteps: u64,
}

impl Default for Limits {
    fn default() -> Self {
        Limits { max_iterations: 1_000_000, max_pseudo_supersteps: 1_000_000 }
    }
}

/// GraphHP hybrid-execution policy (paper §4.2): fixed hand-tuned knobs
/// or the telemetry-driven adaptive scheduler.
///
/// `Static` reproduces the paper's configuration exactly. `Adaptive`
/// drives the same knobs **per partition and per iteration** from the
/// run's own [`RunTrace`]: every decision is a pure function of the
/// trace's deterministic counters, so threaded runs stay bit-for-bit
/// equal to sequential (enforced by `tests/parallel_equivalence.rs`).
#[derive(Clone, Copy, Debug)]
pub enum HybridPolicy {
    /// Fixed knobs, identical for every partition and iteration.
    Static {
        /// Do boundary vertices participate in local phases?
        /// (paper §4.2 — activate for incremental computations).
        boundary_in_local_phase: bool,
        /// Asynchronous in-memory messaging within a (pseudo-)superstep
        /// (paper §4.2 last ¶; always on for AM-Hama).
        async_local_messaging: bool,
    },
    /// The adaptive scheduler: per partition, per iteration it decides
    /// whether to run the local phase at all (skipped while the
    /// partition's frontier is boundary-dominated and no local work is
    /// backlogged), how high to set the pseudo-superstep cap (grows
    /// while the local frontier shrinks geometrically, halves on a
    /// carryover whose frontier had stopped shrinking), and whether
    /// boundary vertices join local phases (seeded from the partition's
    /// static locality score, shed while the local phase thrashes).
    Adaptive(AdaptiveConfig),
}

impl Default for HybridPolicy {
    fn default() -> Self {
        HybridPolicy::Static { boundary_in_local_phase: true, async_local_messaging: true }
    }
}

impl HybridPolicy {
    /// The adaptive scheduler with default tuning.
    pub fn adaptive() -> HybridPolicy {
        HybridPolicy::Adaptive(AdaptiveConfig::default())
    }

    /// True for the [`HybridPolicy::Adaptive`] variant.
    pub fn is_adaptive(&self) -> bool {
        matches!(self, HybridPolicy::Adaptive(_))
    }

    /// Pin "boundary vertices participate in local phases". Under
    /// `Adaptive` this knob is per-partition, so pinning it falls back
    /// to `Static` with the current async-messaging setting.
    pub fn set_boundary_in_local_phase(&mut self, on: bool) {
        match self {
            HybridPolicy::Static { boundary_in_local_phase, .. } => {
                *boundary_in_local_phase = on;
            }
            HybridPolicy::Adaptive(a) => {
                let async_local_messaging = a.async_local_messaging;
                *self = HybridPolicy::Static {
                    boundary_in_local_phase: on,
                    async_local_messaging,
                };
            }
        }
    }

    /// Set asynchronous in-memory messaging (meaningful under both
    /// variants — it is a message-visibility semantic, not a scheduling
    /// decision).
    pub fn set_async_local_messaging(&mut self, on: bool) {
        match self {
            HybridPolicy::Static { async_local_messaging, .. } => *async_local_messaging = on,
            HybridPolicy::Adaptive(a) => a.async_local_messaging = on,
        }
    }
}

/// Tuning constants of the adaptive hybrid scheduler
/// ([`HybridPolicy::Adaptive`]). All thresholds compare deterministic
/// trace counters — wall-clock never feeds a decision.
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveConfig {
    /// Pseudo-superstep cap every partition starts from (the controller
    /// grows it geometrically while the local frontier keeps shrinking).
    pub initial_cap: u64,
    /// Lower bound the per-partition cap never shrinks below (floored
    /// at 1 — a local phase always makes progress).
    pub min_cap: u64,
    /// Upper bound the per-partition cap never grows beyond (also
    /// clamped by [`Limits::max_pseudo_supersteps`]).
    pub max_cap: u64,
    /// A partition's frontier counts as boundary-dominated — making its
    /// local phase skippable — when the boundary fraction reaches this.
    pub boundary_dominance: f64,
    /// Partitions whose static locality score
    /// ([`crate::partition::PartitionLocality::score`]) is below this
    /// start with boundary vertices excluded from local phases.
    pub locality_threshold: f64,
    /// Asynchronous in-memory messaging within (pseudo-)supersteps
    /// (same semantic as the `Static` knob).
    pub async_local_messaging: bool,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            initial_cap: 64,
            min_cap: 1,
            max_cap: 1 << 16,
            boundary_dominance: 0.9,
            locality_threshold: 0.5,
            async_local_messaging: true,
        }
    }
}

/// Checkpointing and deterministic fault injection (paper §5.3).
///
/// Honored by every barrier engine — GraphHP, Hama, AM-Hama, Giraph++
/// and GraphLab-sync — through the shared recovery layer
/// ([`recovery`]): with `checkpoint_interval` set, a detected chaos
/// loss event rolls the run back to the latest checkpoint and replays
/// bit-identically instead of panicking. The async GraphLab engine has
/// no barriers and rejects a configured interval loudly rather than
/// silently ignoring it. `checkpoint_dir` persistence applies to the
/// vertex-centric engines only (GAS values carry no `Codec` bound, so
/// GraphLab-sync checkpoints stay in memory).
#[derive(Clone, Debug)]
pub struct FaultPolicy {
    /// Checkpoint every N global iterations/supersteps (None = off).
    pub checkpoint_interval: Option<u64>,
    /// Directory for persisted checkpoints (None = keep in memory only).
    pub checkpoint_dir: Option<std::path::PathBuf>,
    /// Keep only the newest K checkpoint files in `checkpoint_dir`,
    /// pruning older ones after each save (None = keep all). Recovery
    /// only ever loads the newest, so the default keeps a small safety
    /// margin instead of growing the directory without bound.
    pub checkpoint_retain: Option<usize>,
    /// Simulate losing a worker at the start of the given global
    /// iteration; the engine recovers from the latest checkpoint.
    pub inject_failure_at: Option<u64>,
    /// Bounded rollback budget and post-recovery checkpoint backoff
    /// shared by all barrier engines (see [`RecoveryPolicy`]).
    pub recovery: RecoveryPolicy,
}

impl Default for FaultPolicy {
    fn default() -> Self {
        FaultPolicy {
            checkpoint_interval: None,
            checkpoint_dir: None,
            checkpoint_retain: Some(4),
            inject_failure_at: None,
            recovery: RecoveryPolicy::default(),
        }
    }
}

/// Engine configuration shared by all engines, split into the
/// builder-settable pieces the [`Runner`] exposes (fields irrelevant to
/// an engine are ignored by it).
///
/// ```
/// use graphhp::engine::{EngineConfig, HybridPolicy, Parallelism};
///
/// let mut cfg = EngineConfig::default();
/// cfg.limits.max_iterations = 500;
/// cfg.parallelism = Parallelism::Sequential;
/// cfg.hybrid = HybridPolicy::adaptive();
/// assert!(cfg.hybrid.is_adaptive());
/// assert_eq!(cfg.limits.max_iterations, 500);
/// ```
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Iteration caps.
    pub limits: Limits,
    /// GraphHP hybrid-execution policy.
    pub hybrid: HybridPolicy,
    /// Simulated cluster cost model.
    pub net: NetSimConfig,
    /// GraphLab comparator cost constants.
    pub gas: GasCost,
    /// Fault tolerance policy.
    pub fault: FaultPolicy,
    /// Worker execution mode (threads vs sequential).
    pub parallelism: Parallelism,
    /// Seed for per-vertex randomness (e.g. bipartite matching).
    pub seed: u64,
    /// Online repartitioning: fold trace counters at each barrier into a
    /// deterministic [`MigrationPlan`] and apply it before the next
    /// superstep (None = static partitioning; GraphLab-async, which has
    /// no barriers, ignores it).
    pub repartition: Option<RepartitionConfig>,
    /// Deterministic fault injection on the barrier delivery path
    /// (None = honest transport; GraphLab-async, which has no barriers,
    /// is documented out of scope like migration).
    pub chaos: Option<ChaosPolicy>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            limits: Limits::default(),
            hybrid: HybridPolicy::default(),
            net: NetSimConfig::default(),
            gas: GasCost::default(),
            fault: FaultPolicy::default(),
            parallelism: Parallelism::default(),
            seed: 42,
            repartition: None,
            chaos: None,
        }
    }
}

/// Result of an engine run: final vertex values (indexed by global
/// vertex id), execution metrics, and the per-superstep telemetry
/// trace.
pub struct RunResult<V> {
    /// Final vertex values, indexed by global vertex id.
    pub values: Vec<V>,
    /// Run totals (the paper's I / M / T plus the overhead split).
    pub metrics: Metrics,
    /// Structured per-superstep / per-partition telemetry
    /// ([`RunTrace::to_json`] dumps it; the adaptive scheduler consumes
    /// it online).
    pub trace: RunTrace,
    /// Every fault the chaos layer injected, in injection order (None
    /// when the run had no [`EngineConfig::chaos`] policy, and for
    /// GraphLab-async, where chaos is out of scope).
    pub chaos: Option<ChaosTrace>,
}

/// Gather per-partition values back into a global-id-indexed vector,
/// consuming the per-partition buffers — no per-value clone; the engines
/// hand over their runtimes' value vectors by move at the end of a run.
///
/// Panics if any global vertex id is missing from every partition (the
/// partitions must jointly cover `0..dg.num_vertices`).
pub(crate) fn gather_values_owned<V>(dg: &DistGraph, parts: Vec<Vec<V>>) -> Vec<V> {
    let mut out: Vec<Option<V>> = Vec::with_capacity(dg.num_vertices);
    out.resize_with(dg.num_vertices, || None);
    for (p, vals) in parts.into_iter().enumerate() {
        for (lv, v) in vals.into_iter().enumerate() {
            let gid = dg.parts[p].global_ids[lv];
            out[gid as usize] = Some(v);
        }
    }
    out.into_iter().map(|v| v.expect("vertex missing from every partition")).collect()
}

/// Borrowing form of [`gather_values_owned`] (clones every value; kept
/// for call sites that must retain the per-partition buffers).
pub(crate) fn gather_values<V: Clone>(dg: &DistGraph, parts: &[Vec<V>]) -> Vec<V> {
    gather_values_owned(dg, parts.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DistGraph, Graph};

    fn path2() -> Graph {
        // 0 -> 1
        let mut b = crate::graph::GraphBuilder::new(2);
        b.add_edge(0, 1, 1.0);
        b.build()
    }

    #[test]
    fn gather_handles_empty_partition() {
        let g = path2();
        // both vertices in partition 0 of 2 => partition 1 is empty
        let dg = DistGraph::new(&g, &[0, 0], 2);
        assert_eq!(dg.parts[1].num_vertices(), 0);
        let vals = gather_values(&dg, &[vec![10u32, 20], vec![]]);
        assert_eq!(vals, vec![10, 20]);
    }

    #[test]
    fn gather_single_vertex_graph() {
        let g = Graph { offsets: vec![0, 0], targets: vec![], weights: vec![] };
        let dg = DistGraph::new(&g, &[0], 1);
        let vals = gather_values(&dg, &[vec![7u64]]);
        assert_eq!(vals, vec![7]);
    }

    #[test]
    fn gather_reorders_by_global_id() {
        let g = path2();
        // vertex 1 in partition 0, vertex 0 in partition 1
        let dg = DistGraph::new(&g, &[1, 0], 2);
        let vals = gather_values(&dg, &[vec![11u32], vec![22]]);
        assert_eq!(vals, vec![22, 11]);
    }

    #[test]
    fn gather_owned_matches_borrowed() {
        let g = path2();
        let dg = DistGraph::new(&g, &[1, 0], 2);
        let by_ref = gather_values(&dg, &[vec![11u32], vec![22]]);
        let owned = gather_values_owned(&dg, vec![vec![11u32], vec![22]]);
        assert_eq!(by_ref, owned);
    }

    #[test]
    #[should_panic(expected = "vertex missing from every partition")]
    fn gather_panics_on_uncovered_vertex() {
        // tamper a consistent single-vertex DistGraph into claiming 2
        // vertices while only vertex 0 is owned by any partition
        let g = Graph { offsets: vec![0, 0], targets: vec![], weights: vec![] };
        let mut dg = DistGraph::new(&g, &[0], 1);
        dg.num_vertices = 2;
        dg.routing.location.push((0, 1));
        let _ = gather_values(&dg, &[vec![1u32]]);
    }
}
