//! The GraphHP execution engines and the vertex-centric BSP programming
//! interface.
//!
//! Programming interface (paper §3): users implement [`VertexProgram`]
//! (the `Vertex.Compute()` of Pregel/Hama) optionally with a message
//! combiner and a GraphHP `SourceCombine` policy, plus [`Aggregators`]
//! for global communication.
//!
//! Execution engines (paper §4, §7):
//! - [`hama::run_hama`] — the standard BSP model (one superstep = one
//!   global barrier + full message exchange);
//! - [`am_hama::run_am_hama`] — BSP + asynchronous in-memory messaging
//!   within a partition (Grace-style, the paper's AM-Hama baseline);
//! - [`graphhp::run_graphhp`] — the paper's hybrid model: per global
//!   iteration a *global phase* over boundary vertices then a *local
//!   phase* of pseudo-supersteps until the partition quiesces;
//! - [`giraphpp`] — a graph-centric (Giraph++-style) engine;
//! - [`graphlab`] — GraphLab-style sync (pull/GAS) and async engines.
//!
//! All engines execute over a [`crate::graph::DistGraph`] and account
//! wall-clock into compute/communication/synchronization buckets under
//! the simulated cluster cost model of [`netsim`] (the stand-in for the
//! paper's 13-machine Ethernet cluster — DESIGN.md §2).

pub mod aggregator;
pub mod am_hama;
pub mod checkpoint;
pub mod context;
pub mod giraphpp;
pub mod graphhp;
pub mod graphlab;
pub mod hama;
pub mod messages;
pub mod metrics;
pub mod netsim;
pub mod program;
pub mod state;

pub use aggregator::{AggOp, Aggregators};
pub use context::VertexContext;
pub use metrics::Metrics;
pub use netsim::NetSimConfig;
pub use program::{SourceCombine, VertexProgram};

use crate::graph::DistGraph;

/// Which engine executed a run (for reporting).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    Hama,
    AmHama,
    GraphHP,
    GiraphPP,
    GraphLabSync,
    GraphLabAsync,
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            EngineKind::Hama => "Hama",
            EngineKind::AmHama => "AM-Hama",
            EngineKind::GraphHP => "GraphHP",
            EngineKind::GiraphPP => "Giraph++",
            EngineKind::GraphLabSync => "GraphLab(Sync)",
            EngineKind::GraphLabAsync => "GraphLab(Async)",
        };
        f.write_str(s)
    }
}

/// Engine configuration shared by all engines (fields irrelevant to an
/// engine are ignored by it).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Hard cap on global iterations / supersteps (safety valve).
    pub max_iterations: u64,
    /// GraphHP: do boundary vertices participate in local phases?
    /// (paper §4.2 — activate for incremental computations).
    pub boundary_in_local_phase: bool,
    /// Asynchronous in-memory messaging within a (pseudo-)superstep
    /// (paper §4.2 last ¶; always on for AM-Hama).
    pub async_local_messaging: bool,
    /// Hard cap on pseudo-supersteps per local phase (safety valve).
    pub max_pseudo_supersteps: u64,
    /// Simulated cluster cost model.
    pub net: NetSimConfig,
    /// Seed for per-vertex randomness (e.g. bipartite matching).
    pub seed: u64,
    /// Checkpoint every N global iterations (None = off).
    pub checkpoint_interval: Option<u64>,
    /// Directory for persisted checkpoints (None = keep in memory only).
    pub checkpoint_dir: Option<std::path::PathBuf>,
    /// Deterministic fault injection: simulate losing a worker at the
    /// start of the given global iteration (GraphHP engine only). The
    /// engine recovers from the latest checkpoint, as §5.3.
    pub inject_failure_at: Option<u64>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_iterations: 1_000_000,
            boundary_in_local_phase: true,
            async_local_messaging: true,
            max_pseudo_supersteps: 1_000_000,
            net: NetSimConfig::default(),
            seed: 42,
            checkpoint_interval: None,
            checkpoint_dir: None,
            inject_failure_at: None,
        }
    }
}

/// Result of an engine run: final vertex values (indexed by global vertex
/// id) plus execution metrics.
pub struct RunResult<V> {
    pub values: Vec<V>,
    pub metrics: Metrics,
}

/// Gather per-partition values back into a global-id-indexed vector.
pub(crate) fn gather_values<V: Clone>(dg: &DistGraph, parts: &[Vec<V>]) -> Vec<V> {
    let mut out: Vec<Option<V>> = vec![None; dg.num_vertices];
    for (p, vals) in parts.iter().enumerate() {
        for (lv, v) in vals.iter().enumerate() {
            let gid = dg.parts[p].global_ids[lv];
            out[gid as usize] = Some(v.clone());
        }
    }
    out.into_iter().map(|v| v.expect("vertex missing from every partition")).collect()
}
