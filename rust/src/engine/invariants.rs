//! Debug-build invariant sanitizers for the determinism-critical state.
//!
//! Every function here validates one of the structural contracts the
//! sequential/threaded-equivalence argument rests on, and panics with a
//! message starting `invariant violated:` naming the broken invariant:
//!
//! - [`check_msgstore`] — MsgStore arena integrity: FIFO chains are
//!   acyclic and carry payloads, the free list is disjoint from live
//!   slots, the flag/index/total accounting matches the chains, and
//!   `free + live == arena_slots()`;
//! - [`check_worklist`] — the pooled worklist's sorted/dedup contract
//!   (ascending drain region, descending pending stack, membership
//!   bitmap in sync across both buffers);
//! - [`check_outbox_sealed`] — an outbox reaching the barrier is sealed
//!   and destination-ordered with correct length accounting;
//! - [`check_frontier`] / [`check_fifo`] — schedule-set dedup contracts;
//! - [`check_runtime`] — a partition runtime at a barrier: step closed,
//!   both inboxes valid, frontier valid, parallel arrays in sync;
//! - [`check_edge_routes`] — edge routes (raw columns or compressed
//!   blocks, streamed through the `Edges` view) agree with the global
//!   location table, the vertex-layout permutation is bijective
//!   ([`check_vertex_layout`]), and compressed byte blocks are
//!   well-formed (validated once at `DistGraph::new` and again after
//!   every applied migration);
//! - [`check_migration_plan`] — a `MigrationPlan` about to be applied
//!   is sorted, in-bounds, and free of duplicate or self-moves.
//!
//! The validators are compiled **only** under
//! `#[cfg(any(test, debug_assertions))]`; release builds get inline
//! no-op stubs, so the barrier hot paths carry zero cost there
//! ([`ENABLED`] tells which flavor is active). They run at every
//! engine's barrier (`close_superstep` plus each engine's
//! delivery fold) and inside the GraphHP local phase, so any test run —
//! including the `parallel_equivalence` oracle — sweeps them across all
//! six engines.

use crate::graph::DistGraph;

use super::messages::{MsgStore, Outbox, NIL};
use super::state::{FifoScheduler, Frontier, PartitionRuntime};
use super::worker::Worklist;

/// True when this build compiles the real validators (tests and debug
/// builds); false when they are no-op stubs (release).
pub(crate) const ENABLED: bool = cfg!(any(test, debug_assertions));

/// Validate a [`MsgStore`]'s arena: free-list/chain disjointness,
/// acyclicity, payload liveness, and all three accounting structures
/// (`flagged`, `nonempty`, `total`). `what` labels the store in panic
/// messages (e.g. `"cur"`, `"gq_nxt"`).
#[cfg(any(test, debug_assertions))]
pub(crate) fn check_msgstore<M>(s: &MsgStore<M>, what: &str) {
    let nslots = s.slots.len();
    let n = s.head.len();
    assert!(
        s.tail.len() == n && s.flagged.len() == n,
        "invariant violated: MsgStore({what}) parallel arrays out of sync"
    );

    // walk the free list: in-bounds, acyclic, every slot payload-free
    let mut on_free = vec![false; nslots];
    let mut free_count = 0usize;
    let mut cur = s.free;
    while cur != NIL {
        let i = cur as usize;
        assert!(
            i < nslots,
            "invariant violated: MsgStore({what}) free list points past the arena"
        );
        assert!(
            !on_free[i],
            "invariant violated: MsgStore({what}) free list cycles at slot {i}"
        );
        assert!(
            s.slots[i].0.is_none(),
            "invariant violated: MsgStore({what}) free list touches a live slot ({i})"
        );
        on_free[i] = true;
        free_count += 1;
        cur = s.slots[i].1;
    }

    // walk every chain: acyclic, disjoint from the free list and from
    // other chains, payloads present, tail terminates the chain
    let mut on_chain = vec![false; nslots];
    let mut live_count = 0usize;
    let mut in_index = vec![false; n];
    for &lv in &s.nonempty {
        if (lv as usize) < n {
            in_index[lv as usize] = true;
        }
    }
    for lv in 0..n {
        let h = s.head[lv];
        assert_eq!(
            h != NIL,
            s.flagged[lv],
            "invariant violated: MsgStore({what}) flag disagrees with chain at vertex {lv}"
        );
        if s.flagged[lv] {
            assert!(
                in_index[lv],
                "invariant violated: MsgStore({what}) nonempty index lost flagged vertex {lv}"
            );
        }
        let mut last = NIL;
        let mut cur = h;
        while cur != NIL {
            let i = cur as usize;
            assert!(
                i < nslots,
                "invariant violated: MsgStore({what}) chain of vertex {lv} points past the arena"
            );
            assert!(
                !on_chain[i] && !on_free[i],
                "invariant violated: MsgStore({what}) chain structure corrupt at vertex {lv} \
                 (cycle, shared slot, or link into the free list)"
            );
            assert!(
                s.slots[i].0.is_some(),
                "invariant violated: MsgStore({what}) live chain slot {i} has no payload"
            );
            on_chain[i] = true;
            live_count += 1;
            last = cur;
            cur = s.slots[i].1;
        }
        if h != NIL {
            assert_eq!(
                s.tail[lv], last,
                "invariant violated: MsgStore({what}) tail does not terminate the chain of vertex {lv}"
            );
        }
    }
    assert_eq!(
        live_count, s.total,
        "invariant violated: MsgStore({what}) message count out of sync with the chains"
    );
    assert_eq!(
        free_count + live_count,
        nslots,
        "invariant violated: MsgStore({what}) arena accounting broken: free + live != arena_slots"
    );
}

/// Validate the pooled [`Worklist`]'s sorted/dedup contract: the drain
/// region ascends (once sorted), the pending stack descends, and the
/// membership bitmap agrees exactly with the union of both buffers.
#[cfg(any(test, debug_assertions))]
pub(crate) fn check_worklist(wl: &Worklist) {
    assert!(
        wl.cursor <= wl.items.len(),
        "invariant violated: Worklist cursor past the seed buffer"
    );
    if !wl.sorted {
        assert!(
            wl.pending.is_empty(),
            "invariant violated: Worklist pending entries before the first pop"
        );
    } else {
        assert!(
            wl.items[wl.cursor..].windows(2).all(|w| w[0] < w[1]),
            "invariant violated: Worklist drain region not strictly ascending"
        );
    }
    assert!(
        wl.pending.windows(2).all(|w| w[0] > w[1]),
        "invariant violated: Worklist pending stack not strictly descending"
    );
    let mut queued = 0usize;
    for &v in wl.items[wl.cursor..].iter().chain(&wl.pending) {
        assert!(
            wl.member.get(v as usize).copied().unwrap_or(false),
            "invariant violated: Worklist queued entry {v} lost its membership flag"
        );
        queued += 1;
    }
    let set = wl.member.iter().filter(|&&b| b).count();
    assert_eq!(
        set, queued,
        "invariant violated: Worklist membership bitmap out of sync \
         (duplicate or ghost entries)"
    );
}

/// Validate an [`Outbox`] arriving at the barrier: it must have been
/// sealed (the seal is what orders batches and applies combining — an
/// unsealed drain would deliver in raw push order), every batch must be
/// `dest_local`-ordered, and `len` must match the batch contents.
#[cfg(any(test, debug_assertions))]
pub(crate) fn check_outbox_sealed<M>(o: &Outbox<M>) {
    assert!(
        o.sealed,
        "invariant violated: Outbox reached the barrier without seal \
         (drain order would be push order, not (dest_part, dest_local))"
    );
    let mut count = 0usize;
    for b in &o.batches {
        assert!(
            b.windows(2).all(|w| w[0].0 <= w[1].0),
            "invariant violated: Outbox batch not destination-ordered after seal"
        );
        count += b.len();
    }
    assert_eq!(
        count, o.len,
        "invariant violated: Outbox length accounting disagrees with its batches"
    );
}

/// Validate a [`Frontier`]'s dedup contract: no vertex scheduled twice,
/// flags agree with the scheduled set.
#[cfg(any(test, debug_assertions))]
pub(crate) fn check_frontier(f: &Frontier) {
    let mut seen = vec![false; f.flagged.len()];
    for &lv in &f.next {
        let i = lv as usize;
        assert!(
            i < f.flagged.len(),
            "invariant violated: Frontier entry {lv} out of range"
        );
        assert!(
            !seen[i],
            "invariant violated: Frontier vertex {lv} scheduled twice"
        );
        assert!(
            f.flagged[i],
            "invariant violated: Frontier entry {lv} lost its flag"
        );
        seen[i] = true;
    }
    let set = f.flagged.iter().filter(|&&b| b).count();
    assert_eq!(
        set,
        f.next.len(),
        "invariant violated: Frontier flags out of sync with the scheduled set"
    );
}

/// Validate a [`FifoScheduler`]'s dedup contract (GraphLab async).
#[cfg(any(test, debug_assertions))]
pub(crate) fn check_fifo(s: &FifoScheduler) {
    let mut seen = vec![false; s.queued.len()];
    for &v in &s.queue {
        let i = v as usize;
        assert!(
            i < s.queued.len(),
            "invariant violated: FifoScheduler entry {v} out of range"
        );
        assert!(
            !seen[i],
            "invariant violated: FifoScheduler vertex {v} queued twice"
        );
        assert!(
            s.queued[i],
            "invariant violated: FifoScheduler entry {v} lost its queued flag"
        );
        seen[i] = true;
    }
    let set = s.queued.iter().filter(|&&b| b).count();
    assert_eq!(
        set,
        s.queue.len(),
        "invariant violated: FifoScheduler flags out of sync with the queue"
    );
}

/// Validate a [`PartitionRuntime`] at a barrier: the step transaction is
/// closed, the parallel per-vertex arrays agree, and both inboxes and
/// the frontier hold their invariants.
#[cfg(any(test, debug_assertions))]
pub(crate) fn check_runtime<V, M>(rt: &PartitionRuntime<V, M>) {
    assert!(
        !rt.step_open,
        "invariant violated: barrier crossed with an open step \
         (begin_step without commit_step/abort_step_carryover)"
    );
    let n = rt.values.len();
    assert!(
        rt.halted.len() == n && rt.frontier.flagged.len() == n,
        "invariant violated: PartitionRuntime parallel arrays out of sync"
    );
    check_msgstore(&rt.cur, "cur");
    check_msgstore(&rt.nxt, "nxt");
    check_frontier(&rt.frontier);
}

/// Validate the [`DistGraph`]'s routing metadata once at construction:
/// every edge's route (streamed through the storage-mode-agnostic
/// [`crate::graph::Edges`] view, so compressed blocks are decode-checked
/// too) agrees with the global location table, the location table
/// round-trips through `global_ids`, the CSR offsets are monotonic, the
/// vertex-layout permutation is a bijection consistent with both, the
/// compressed byte blocks are well-formed, and the precomputed
/// boundary/internal counts match a rescan.
#[cfg(any(test, debug_assertions))]
pub(crate) fn check_edge_routes(dg: &DistGraph) {
    assert_eq!(
        dg.routing.location.len(),
        dg.num_vertices,
        "invariant violated: location table length != vertex count"
    );
    assert_eq!(
        dg.routing.cut_in.len(),
        dg.parts.len(),
        "invariant violated: cut_in table length != partition count"
    );
    let mut vertices = 0usize;
    let mut cut_in = vec![0u64; dg.parts.len()];
    for part in &dg.parts {
        let nv = part.num_vertices();
        vertices += nv;
        let ne = part.num_edges();
        if part.is_compressed() {
            assert!(
                part.targets.is_empty() && part.routes.is_empty(),
                "invariant violated: partition {} keeps raw columns alongside \
                 compressed blocks",
                part.part
            );
            assert!(
                part.packed_offsets.len() == nv + 1
                    && part.packed_offsets[0] == 0
                    && part.packed_offsets[nv] == part.packed.len()
                    && part.packed_offsets.windows(2).all(|w| w[0] <= w[1]),
                "invariant violated: partition {} packed-block offsets not monotonic \
                 over its byte stream",
                part.part
            );
        } else {
            assert!(
                part.targets.len() == ne && part.routes.len() == ne,
                "invariant violated: partition {} SoA edge columns out of sync",
                part.part
            );
            assert!(
                part.packed.is_empty() && part.packed_offsets.is_empty(),
                "invariant violated: partition {} carries packed bytes without \
                 being compressed",
                part.part
            );
        }
        assert!(
            part.weights.len() == ne,
            "invariant violated: partition {} weights column out of sync",
            part.part
        );
        assert!(
            part.offsets.len() == nv + 1
                && part.offsets[0] == 0
                && part.offsets[nv] == ne
                && part.offsets.windows(2).all(|w| w[0] <= w[1]),
            "invariant violated: partition {} CSR offsets not monotonic over its edges",
            part.part
        );
        check_vertex_layout(part);
        for (lv, &gid) in part.global_ids.iter().enumerate() {
            assert_eq!(
                dg.routing.location[gid as usize],
                (part.part, lv as u32),
                "invariant violated: location table points at the wrong vertex \
                 (partition {}, local {lv})",
                part.part
            );
        }
        let mut internal = 0usize;
        for lv in 0..nv {
            let edges = part.out_edges(lv);
            assert_eq!(
                edges.len(),
                part.out_degree[lv] as usize,
                "invariant violated: partition {} local {lv} edge view length \
                 disagrees with out_degree",
                part.part
            );
            for (i, e) in edges.iter().enumerate() {
                assert_eq!(
                    e.route().unpack(),
                    dg.routing.location[e.target as usize],
                    "invariant violated: edge route disagrees with the location \
                     table (partition {}, local {lv}, edge {i})",
                    part.part
                );
                if e.target_part == part.part {
                    internal += 1;
                } else {
                    cut_in[e.target_part as usize] += 1;
                }
            }
        }
        assert_eq!(
            internal,
            part.num_internal_edges(),
            "invariant violated: partition {} precomputed internal-edge count stale",
            part.part
        );
        assert_eq!(
            part.is_boundary.iter().filter(|&&b| b).count(),
            part.num_boundary(),
            "invariant violated: partition {} precomputed boundary count stale",
            part.part
        );
    }
    assert_eq!(
        vertices, dg.num_vertices,
        "invariant violated: partition vertex counts do not sum to the graph"
    );
    assert_eq!(
        cut_in, dg.routing.cut_in,
        "invariant violated: precomputed cut_in tallies stale against an edge rescan"
    );
}

/// Validate a [`MigrationPlan`] against the graph it is about to be
/// applied to: moves are strictly ascending by global id (sorted, no
/// duplicates), every vertex exists, every target partition exists, and
/// no move is a self-move (the planner must never emit a no-op entry —
/// it would inflate the `migrated` counter in [`super::metrics::StepTrace`]).
#[cfg(any(test, debug_assertions))]
pub(crate) fn check_migration_plan(dg: &DistGraph, plan: &crate::graph::MigrationPlan) {
    let np = dg.parts.len() as u32;
    let mut prev: Option<crate::graph::VertexId> = None;
    for &(gid, to) in &plan.moves {
        assert!(
            prev.map_or(true, |p| p < gid),
            "invariant violated: migration plan moves not strictly ascending by \
             vertex id (at vertex {gid})"
        );
        assert!(
            (gid as usize) < dg.num_vertices,
            "invariant violated: migration plan moves unknown vertex {gid}"
        );
        assert!(
            to < np,
            "invariant violated: migration plan sends vertex {gid} to \
             nonexistent partition {to}"
        );
        assert_ne!(
            to,
            dg.routing.location[gid as usize].0,
            "invariant violated: migration plan self-move for vertex {gid}"
        );
        prev = Some(gid);
    }
}

/// Validate one partition's [`crate::graph::VertexLayout`]: identity is
/// represented by empty vectors; a materialized permutation must have
/// both directions of length `nv` and be mutually inverse bijections.
#[cfg(any(test, debug_assertions))]
pub(crate) fn check_vertex_layout(part: &crate::graph::PartGraph) {
    let lay = &part.layout;
    if lay.is_identity() {
        assert!(
            lay.fwd.is_empty() && lay.inv.is_empty(),
            "invariant violated: partition {} identity layout carries a \
             half-materialized permutation",
            part.part
        );
        return;
    }
    let nv = part.num_vertices();
    assert!(
        lay.fwd.len() == nv && lay.inv.len() == nv,
        "invariant violated: partition {} layout permutation length != vertex count",
        part.part
    );
    let mut seen = vec![false; nv];
    for local in 0..nv as u32 {
        let rank = lay.to_natural(local);
        assert!(
            (rank as usize) < nv && !seen[rank as usize],
            "invariant violated: partition {} layout inv is not a permutation \
             (local {local})",
            part.part
        );
        seen[rank as usize] = true;
        assert_eq!(
            lay.to_local(rank),
            local,
            "invariant violated: partition {} layout fwd/inv are not inverse \
             (local {local})",
            part.part
        );
    }
}

/// Validate a recorded [`super::chaos::ChaosTrace`]: events are in
/// injection order (nondecreasing monotone superstep), batch-tied kinds
/// carry real endpoints, and worker/window events carry the sentinel.
#[cfg(any(test, debug_assertions))]
pub(crate) fn check_chaos_trace(t: &super::chaos::ChaosTrace) {
    use super::chaos::{ChaosEventKind, NO_PART};
    let mut prev = 0u64;
    for e in &t.events {
        assert!(
            e.superstep >= prev,
            "invariant violated: chaos trace out of injection order \
             (superstep {} after {prev})",
            e.superstep
        );
        prev = e.superstep;
        let batch_tied = matches!(
            e.kind,
            ChaosEventKind::Drop
                | ChaosEventKind::Delay
                | ChaosEventKind::Duplicate
                | ChaosEventKind::Reorder
                | ChaosEventKind::SplitHold
        );
        if batch_tied {
            assert!(
                e.from != NO_PART && e.to != NO_PART && e.from != e.to,
                "invariant violated: chaos batch event without endpoints ({e:?})"
            );
        } else {
            assert!(
                e.from == NO_PART && e.to == NO_PART && e.messages == 0,
                "invariant violated: chaos worker event carries batch fields ({e:?})"
            );
        }
    }
}

// Release builds: inline no-op stubs — the barrier paths pay nothing.
#[cfg(not(any(test, debug_assertions)))]
mod stubs {
    use super::*;

    #[inline(always)]
    pub(crate) fn check_msgstore<M>(_s: &MsgStore<M>, _what: &str) {}
    #[inline(always)]
    pub(crate) fn check_worklist(_wl: &Worklist) {}
    #[inline(always)]
    pub(crate) fn check_outbox_sealed<M>(_o: &Outbox<M>) {}
    #[inline(always)]
    pub(crate) fn check_frontier(_f: &Frontier) {}
    #[inline(always)]
    pub(crate) fn check_fifo(_s: &FifoScheduler) {}
    #[inline(always)]
    pub(crate) fn check_runtime<V, M>(_rt: &PartitionRuntime<V, M>) {}
    #[inline(always)]
    pub(crate) fn check_edge_routes(_dg: &DistGraph) {}
    #[inline(always)]
    pub(crate) fn check_migration_plan(_dg: &DistGraph, _plan: &crate::graph::MigrationPlan) {}
    #[inline(always)]
    pub(crate) fn check_vertex_layout(_part: &crate::graph::PartGraph) {}
    #[inline(always)]
    pub(crate) fn check_chaos_trace(_t: &super::chaos::ChaosTrace) {}
}
#[cfg(not(any(test, debug_assertions)))]
pub(crate) use stubs::*;

#[cfg(test)]
mod tests {
    use super::super::messages::{MsgStore, Outbox};
    use super::super::program::SourceCombine;
    use super::super::state::{FifoScheduler, Frontier};
    use super::super::worker::Worklist;
    use super::*;
    use crate::graph::{generators, EdgeRoute};
    use crate::partition::hash_partition;

    #[test]
    fn sanitizers_are_gated_to_test_and_debug_builds() {
        // under `cargo test` the `test` cfg is on, so the real
        // validators must be compiled in — including `--release` test
        // runs; plain `cargo build --release` (CI's build-test job)
        // compiles the no-op stub module instead
        assert!(ENABLED);
        assert_eq!(ENABLED, cfg!(any(test, debug_assertions)));
    }

    #[test]
    fn healthy_structures_pass() {
        let mut s: MsgStore<u32> = MsgStore::new(4);
        let mut buf = Vec::new();
        for round in 0..10 {
            s.push(1, round);
            s.push(3, round);
            s.push(1, round + 1);
            check_msgstore(&s, "healthy");
            s.take_into(1, &mut buf);
            check_msgstore(&s, "healthy");
            s.take_into(3, &mut buf);
        }
        check_msgstore(&s, "healthy");

        let mut wl = Worklist::default();
        wl.begin(8);
        wl.schedule(5);
        wl.schedule(2);
        check_worklist(&wl);
        assert_eq!(wl.pop_first(), Some(2));
        wl.schedule(1); // pending entry mid-drain
        wl.schedule(7);
        check_worklist(&wl);

        let mut o: Outbox<u32> = Outbox::new(None);
        o.push(1, 9, 0, 10);
        o.push(1, 4, 0, 11);
        o.seal(SourceCombine::KeepAll);
        check_outbox_sealed(&o);

        let mut f = Frontier::new(4);
        f.schedule(2);
        f.schedule(0);
        check_frontier(&f);

        let fifo = FifoScheduler::seeded(3);
        check_fifo(&fifo);
    }

    #[test]
    #[should_panic(expected = "chain structure corrupt")]
    fn corrupted_msgstore_chain_cycle_is_caught() {
        let mut s: MsgStore<u32> = MsgStore::new(2);
        s.push(0, 1); // slot 0
        s.push(0, 2); // slot 1, chain 0 -> 1
        s.slots[1].1 = 0; // tail links back to the head: cycle
        check_msgstore(&s, "test");
    }

    #[test]
    #[should_panic(expected = "free list touches a live slot")]
    fn free_list_overlapping_live_slot_is_caught() {
        let mut s: MsgStore<u32> = MsgStore::new(2);
        s.push(0, 1);
        let mut buf = Vec::new();
        s.take_into(0, &mut buf); // slot 0 returns to the free list
        s.slots[0].0 = Some(7); // resurrect the freed slot's payload
        check_msgstore(&s, "test");
    }

    #[test]
    #[should_panic(expected = "message count out of sync")]
    fn msgstore_total_drift_is_caught() {
        let mut s: MsgStore<u32> = MsgStore::new(2);
        s.push(1, 5);
        s.total += 1;
        check_msgstore(&s, "test");
    }

    #[test]
    #[should_panic(expected = "nonempty index lost flagged vertex")]
    fn msgstore_stale_index_losing_a_vertex_is_caught() {
        let mut s: MsgStore<u32> = MsgStore::new(3);
        s.push(2, 9);
        s.nonempty.clear(); // the lazy index forgets the flagged vertex
        check_msgstore(&s, "test");
    }

    #[test]
    #[should_panic(expected = "lost its membership flag")]
    fn worklist_membership_corruption_is_caught() {
        let mut wl = Worklist::default();
        wl.begin(8);
        wl.schedule(3);
        wl.schedule(5);
        wl.member[3] = false;
        check_worklist(&wl);
    }

    #[test]
    #[should_panic(expected = "drain region not strictly ascending")]
    fn unsorted_worklist_drain_region_is_caught() {
        let mut wl = Worklist::default();
        wl.begin(8);
        wl.schedule(5);
        wl.schedule(3); // seed buffer holds [5, 3]
        wl.sorted = true; // claim it sorted without sorting
        check_worklist(&wl);
    }

    #[test]
    #[should_panic(expected = "pending stack not strictly descending")]
    fn worklist_pending_order_corruption_is_caught() {
        let mut wl = Worklist::default();
        wl.begin(8);
        wl.schedule(6);
        assert_eq!(wl.pop_first(), Some(6));
        wl.schedule(2);
        wl.schedule(4); // pending is [4, 2] descending — now break it
        wl.pending.swap(0, 1);
        check_worklist(&wl);
    }

    #[test]
    #[should_panic(expected = "Outbox reached the barrier without seal")]
    fn unsealed_outbox_at_barrier_is_caught() {
        let mut o: Outbox<u32> = Outbox::new(None);
        o.push(1, 0, 7, 42);
        check_outbox_sealed(&o);
    }

    #[test]
    #[should_panic(expected = "batch not destination-ordered")]
    fn unordered_sealed_batch_is_caught() {
        let mut o: Outbox<u32> = Outbox::new(None);
        o.push(1, 9, 7, 1);
        o.push(1, 4, 7, 2);
        o.sealed = true; // forge the seal without the ordering pass
        check_outbox_sealed(&o);
    }

    #[test]
    #[should_panic(expected = "scheduled twice")]
    fn frontier_duplicate_entry_is_caught() {
        let mut f = Frontier::new(4);
        f.schedule(1);
        f.next.push(1); // bypass the dedup flag
        check_frontier(&f);
    }

    #[test]
    #[should_panic(expected = "lost its queued flag")]
    fn fifo_flag_corruption_is_caught() {
        let mut s = FifoScheduler::seeded(3);
        s.queued[0] = false;
        check_fifo(&s);
    }

    #[test]
    fn dist_graph_routes_validate_clean() {
        let g = generators::powerlaw(200, 4, 11);
        let a = hash_partition(&g, 4);
        let dg = crate::graph::DistGraph::new(&g, &a, 4);
        check_edge_routes(&dg); // also ran inside DistGraph::new
    }

    #[test]
    fn dist_graph_layout_and_compression_validate_clean() {
        use crate::graph::{GraphLayout, LayoutPolicy};
        let g = generators::powerlaw(200, 4, 11);
        let a = hash_partition(&g, 4);
        for layout in [
            GraphLayout::degree_sorted(),
            GraphLayout { policy: LayoutPolicy::Identity, compress_edges: true },
            GraphLayout::packed(),
        ] {
            let dg = crate::graph::DistGraph::with_layout(&g, &a, 4, layout);
            check_edge_routes(&dg); // also ran inside with_layout
        }
    }

    #[test]
    fn well_formed_migration_plan_passes() {
        use crate::graph::MigrationPlan;
        let g = generators::powerlaw(100, 3, 7);
        let a = hash_partition(&g, 3);
        let dg = crate::graph::DistGraph::new(&g, &a, 3);
        let moves: Vec<_> = (0..5u32)
            .map(|gid| (gid, (dg.routing.location[gid as usize].0 + 1) % 3))
            .collect();
        check_migration_plan(&dg, &MigrationPlan { epoch: 1, moves });
        check_migration_plan(&dg, &MigrationPlan { epoch: 1, moves: Vec::new() });
    }

    #[test]
    #[should_panic(expected = "moves not strictly ascending")]
    fn unsorted_migration_plan_is_caught() {
        use crate::graph::MigrationPlan;
        let g = generators::powerlaw(100, 3, 7);
        let a = hash_partition(&g, 3);
        let dg = crate::graph::DistGraph::new(&g, &a, 3);
        let to = |gid: u32| (dg.routing.location[gid as usize].0 + 1) % 3;
        let plan = MigrationPlan { epoch: 1, moves: vec![(4, to(4)), (2, to(2))] };
        check_migration_plan(&dg, &plan);
    }

    #[test]
    #[should_panic(expected = "self-move")]
    fn self_move_in_migration_plan_is_caught() {
        use crate::graph::MigrationPlan;
        let g = generators::powerlaw(100, 3, 7);
        let a = hash_partition(&g, 3);
        let dg = crate::graph::DistGraph::new(&g, &a, 3);
        let here = dg.routing.location[0].0;
        check_migration_plan(&dg, &MigrationPlan { epoch: 1, moves: vec![(0, here)] });
    }

    #[test]
    #[should_panic(expected = "nonexistent partition")]
    fn out_of_range_migration_target_is_caught() {
        use crate::graph::MigrationPlan;
        let g = generators::powerlaw(100, 3, 7);
        let a = hash_partition(&g, 3);
        let dg = crate::graph::DistGraph::new(&g, &a, 3);
        check_migration_plan(&dg, &MigrationPlan { epoch: 1, moves: vec![(0, 9)] });
    }

    #[test]
    #[should_panic(expected = "edge route disagrees with the location table")]
    fn tampered_edge_route_is_caught() {
        let g = generators::powerlaw(100, 3, 7);
        let a = hash_partition(&g, 3);
        let mut dg = crate::graph::DistGraph::new(&g, &a, 3);
        let part = dg.parts.iter_mut().find(|p| !p.routes.is_empty()).unwrap();
        part.routes[0] = EdgeRoute::new(u32::MAX, u32::MAX);
        check_edge_routes(&dg);
    }

    #[test]
    #[should_panic(expected = "layout fwd/inv are not inverse")]
    fn tampered_layout_permutation_is_caught() {
        let g = generators::powerlaw(100, 3, 7);
        let a = hash_partition(&g, 3);
        let mut dg =
            crate::graph::DistGraph::with_layout(&g, &a, 3, crate::graph::GraphLayout::degree_sorted());
        let part = dg.parts.iter_mut().find(|p| p.num_vertices() >= 2).unwrap();
        part.layout.fwd.swap(0, 1); // fwd no longer inverts inv
        check_vertex_layout(part);
    }

    #[test]
    #[should_panic(expected = "packed-block offsets not monotonic")]
    fn truncated_packed_stream_is_caught() {
        let g = generators::powerlaw(100, 3, 7);
        let a = hash_partition(&g, 3);
        let mut dg = crate::graph::DistGraph::with_layout(
            &g,
            &a,
            3,
            crate::graph::GraphLayout { policy: crate::graph::LayoutPolicy::Identity, compress_edges: true },
        );
        let part = dg.parts.iter_mut().find(|p| p.num_edges() > 0).unwrap();
        part.packed.pop(); // final block offset now points past the bytes
        check_edge_routes(&dg);
    }

    fn chaos_event(
        superstep: u64,
        kind: super::super::chaos::ChaosEventKind,
        from: u32,
        to: u32,
    ) -> super::super::chaos::ChaosEvent {
        super::super::chaos::ChaosEvent { superstep, kind, from, to, messages: 0, batch: 0 }
    }

    #[test]
    fn ordered_chaos_trace_passes() {
        use super::super::chaos::{ChaosEventKind, ChaosTrace, NO_PART};
        let mut t = ChaosTrace { seed: 1, events: Vec::new() };
        let mut e = chaos_event(0, ChaosEventKind::Drop, 0, 1);
        e.messages = 4;
        t.events.push(e);
        t.events.push(chaos_event(0, ChaosEventKind::Kill, NO_PART, NO_PART));
        t.events.push(chaos_event(2, ChaosEventKind::Recover, NO_PART, NO_PART));
        check_chaos_trace(&t);
    }

    #[test]
    #[should_panic(expected = "chaos trace out of injection order")]
    fn unordered_chaos_trace_is_caught() {
        use super::super::chaos::{ChaosEventKind, ChaosTrace};
        let t = ChaosTrace {
            seed: 1,
            events: vec![
                chaos_event(3, ChaosEventKind::Duplicate, 0, 1),
                chaos_event(1, ChaosEventKind::Reorder, 1, 0),
            ],
        };
        check_chaos_trace(&t);
    }

    #[test]
    #[should_panic(expected = "chaos batch event without endpoints")]
    fn chaos_batch_event_without_endpoints_is_caught() {
        use super::super::chaos::{ChaosEventKind, ChaosTrace, NO_PART};
        let t = ChaosTrace {
            seed: 1,
            events: vec![chaos_event(0, ChaosEventKind::Drop, NO_PART, NO_PART)],
        };
        check_chaos_trace(&t);
    }
}
