//! Message stores and outboxes.
//!
//! [`MsgStore`] is a partition's incoming mailbox (one queue per local
//! vertex, with a non-empty index so iteration is O(active)).
//! [`Outbox`] collects a worker's outgoing cross-partition messages for
//! one superstep, applying sender-side combining exactly like Pregel's
//! `Combine()` (one combined message per destination vertex per source
//! worker) so network-message counts match the paper's setup.

use rustc_hash::FxHashMap;

use crate::graph::VertexId;
use crate::util::Codec;

use super::program::SourceCombine;

/// Per-partition incoming message queues.
#[derive(Clone, Debug)]
pub struct MsgStore<M> {
    queues: Vec<Vec<M>>,
    nonempty: Vec<u32>,
    flagged: Vec<bool>,
}

impl<M> MsgStore<M> {
    pub fn new(n: usize) -> Self {
        let queues = (0..n).map(|_| Vec::new()).collect();
        MsgStore { queues, nonempty: Vec::new(), flagged: vec![false; n] }
    }

    /// Append a message for local vertex `lv`.
    pub fn push(&mut self, lv: usize, m: M) {
        if !self.flagged[lv] {
            self.flagged[lv] = true;
            self.nonempty.push(lv as u32);
        }
        self.queues[lv].push(m);
    }

    /// Append with combining: if a combiner is given and the queue is
    /// non-empty, fold into the single held message.
    pub fn push_combined(&mut self, lv: usize, m: M, combiner: Option<fn(M, M) -> M>) {
        match combiner {
            Some(f) if !self.queues[lv].is_empty() => {
                let prev = self.queues[lv].pop().unwrap();
                self.queues[lv].push(f(prev, m));
            }
            _ => self.push(lv, m),
        }
    }

    pub fn has_messages(&self, lv: usize) -> bool {
        self.flagged[lv]
    }

    /// Drain the queue of `lv` into `buf` (clears the flag).
    pub fn take_into(&mut self, lv: usize, buf: &mut Vec<M>) {
        buf.clear();
        if self.flagged[lv] {
            buf.append(&mut self.queues[lv]);
            self.flagged[lv] = false;
            // lazy removal from `nonempty`: entries are validated on drain
        }
    }

    /// Local vertices with pending messages (sorted, deduplicated —
    /// lazy cleanup can leave stale duplicates in the index).
    pub fn pending(&mut self) -> Vec<u32> {
        self.nonempty.retain(|&lv| self.flagged[lv as usize]);
        self.nonempty.sort_unstable();
        self.nonempty.dedup();
        self.nonempty.clone()
    }

    pub fn is_empty(&mut self) -> bool {
        self.nonempty.retain(|&lv| self.flagged[lv as usize]);
        self.nonempty.is_empty()
    }

    pub fn total_messages(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    pub fn clear(&mut self) {
        for &lv in &self.nonempty {
            self.queues[lv as usize].clear();
            self.flagged[lv as usize] = false;
        }
        self.nonempty.clear();
    }
}

impl<M: Clone> MsgStore<M> {
    /// Snapshot pending queues as (vertex, messages) pairs (checkpointing).
    pub fn export(&mut self) -> Vec<(u32, Vec<M>)> {
        self.pending()
            .into_iter()
            .map(|lv| (lv, self.queues[lv as usize].clone()))
            .collect()
    }

    /// Rebuild a store of size `n` from an [`export`](Self::export) snapshot.
    pub fn restore(n: usize, snap: &[(u32, Vec<M>)]) -> Self {
        let mut s = MsgStore::new(n);
        for (lv, msgs) in snap {
            for m in msgs {
                s.push(*lv as usize, m.clone());
            }
        }
        s
    }
}

/// Wire overhead per message on the simulated network (dest id + header).
pub const MSG_WIRE_OVERHEAD: usize = 8;

/// A worker's outgoing cross-partition traffic for one superstep.
///
/// With a combiner: one slot per destination vertex (sender-side
/// combining). Without: raw list, optionally `SourceCombine`d per
/// (source, destination) pair when the engine buffers across iterations
/// (GraphHP §5).
pub struct Outbox<M> {
    /// (dest_part, dest_local) -> combined message.
    combined: FxHashMap<(u32, u32), M>,
    /// (dest_part, dest_local, src_gid, message).
    raw: Vec<(u32, u32, VertexId, M)>,
    combiner: Option<fn(M, M) -> M>,
}

impl<M: Clone + Codec> Outbox<M> {
    pub fn new(combiner: Option<fn(M, M) -> M>) -> Self {
        Outbox { combined: FxHashMap::default(), raw: Vec::new(), combiner }
    }

    /// Queue a message from `src` to `(dest_part, dest_local)`.
    pub fn push(&mut self, dest_part: u32, dest_local: u32, src: VertexId, m: M) {
        match self.combiner {
            Some(f) => {
                self.combined
                    .entry((dest_part, dest_local))
                    .and_modify(|prev| {
                        let old = prev.clone();
                        *prev = f(old, m.clone());
                    })
                    .or_insert(m);
            }
            None => self.raw.push((dest_part, dest_local, src, m)),
        }
    }

    /// Apply GraphHP `SourceCombine` to the raw list (keep latest per
    /// (src, dest)). No-op when a combiner is active or policy is KeepAll.
    pub fn source_combine(&mut self, policy: SourceCombine) {
        if self.combiner.is_some() || policy == SourceCombine::KeepAll {
            return;
        }
        // keep the LAST message per (src, dest): iterate in order,
        // overwriting earlier entries
        let mut latest: FxHashMap<(u32, u32, VertexId), usize> = FxHashMap::default();
        let mut keep = vec![false; self.raw.len()];
        for (i, &(dp, dl, src, _)) in self.raw.iter().enumerate() {
            if let Some(&prev) = latest.get(&(dp, dl, src)) {
                keep[prev] = false;
            }
            latest.insert((dp, dl, src), i);
            keep[i] = true;
        }
        let mut i = 0;
        self.raw.retain(|_| {
            let k = keep[i];
            i += 1;
            k
        });
    }

    /// Number of messages that will cross the network.
    pub fn len(&self) -> usize {
        self.combined.len() + self.raw.len()
    }

    pub fn is_empty(&self) -> bool {
        self.combined.is_empty() && self.raw.is_empty()
    }

    /// Total bytes on the wire (payload + per-message overhead).
    pub fn wire_bytes(&self) -> usize {
        let payload: usize = self
            .combined
            .values()
            .map(|m| m.encoded_len())
            .chain(self.raw.iter().map(|(_, _, _, m)| m.encoded_len()))
            .sum();
        payload + self.len() * MSG_WIRE_OVERHEAD
    }

    /// Distinct destination partitions (for RPC-pair accounting).
    pub fn peer_count(&self, exclude_part: u32) -> usize {
        let mut peers: Vec<u32> = self
            .combined
            .keys()
            .map(|&(p, _)| p)
            .chain(self.raw.iter().map(|&(p, _, _, _)| p))
            .filter(|&p| p != exclude_part)
            .collect();
        peers.sort_unstable();
        peers.dedup();
        peers.len()
    }

    /// Drain into (dest_part, dest_local, message) triples.
    pub fn drain(&mut self) -> Vec<(u32, u32, M)> {
        let mut out: Vec<(u32, u32, M)> =
            self.combined.drain().map(|((p, l), m)| (p, l, m)).collect();
        out.extend(self.raw.drain(..).map(|(p, l, _, m)| (p, l, m)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msgstore_push_take() {
        let mut s: MsgStore<u32> = MsgStore::new(4);
        s.push(1, 10);
        s.push(1, 11);
        s.push(3, 30);
        assert!(s.has_messages(1));
        assert!(!s.has_messages(0));
        assert_eq!(s.pending(), vec![1, 3]);
        let mut buf = Vec::new();
        s.take_into(1, &mut buf);
        assert_eq!(buf, vec![10, 11]);
        assert!(!s.has_messages(1));
        assert_eq!(s.pending(), vec![3]);
    }

    #[test]
    fn msgstore_combining() {
        let mut s: MsgStore<f32> = MsgStore::new(2);
        let min = |a: f32, b: f32| a.min(b);
        s.push_combined(0, 5.0, Some(min));
        s.push_combined(0, 3.0, Some(min));
        s.push_combined(0, 9.0, Some(min));
        let mut buf = Vec::new();
        s.take_into(0, &mut buf);
        assert_eq!(buf, vec![3.0]);
    }

    #[test]
    fn outbox_sender_side_combining_counts() {
        let mut o: Outbox<f32> = Outbox::new(Some(|a: f32, b: f32| a.min(b)));
        o.push(1, 0, 100, 5.0);
        o.push(1, 0, 101, 3.0); // same destination -> combined
        o.push(2, 0, 100, 7.0);
        assert_eq!(o.len(), 2);
        assert_eq!(o.peer_count(0), 2);
        let mut d = o.drain();
        d.sort_by_key(|&(p, l, _)| (p, l));
        assert_eq!(d, vec![(1, 0, 3.0), (2, 0, 7.0)]);
    }

    #[test]
    fn outbox_source_combine_keeps_latest() {
        let mut o: Outbox<u32> = Outbox::new(None);
        o.push(1, 0, 7, 100);
        o.push(1, 0, 7, 200); // same (src, dest): keep this one
        o.push(1, 0, 8, 300); // different src: kept
        o.source_combine(SourceCombine::KeepLatest);
        let mut d = o.drain();
        d.sort_by_key(|&(_, _, m)| m);
        assert_eq!(d, vec![(1, 0, 200), (1, 0, 300)]);
    }

    #[test]
    fn outbox_keepall_preserves_everything() {
        let mut o: Outbox<u32> = Outbox::new(None);
        o.push(1, 0, 7, 100);
        o.push(1, 0, 7, 200);
        o.source_combine(SourceCombine::KeepAll);
        assert_eq!(o.len(), 2);
    }

    #[test]
    fn wire_bytes_include_overhead() {
        let mut o: Outbox<f32> = Outbox::new(None);
        o.push(1, 0, 0, 1.0);
        assert_eq!(o.wire_bytes(), 4 + MSG_WIRE_OVERHEAD);
    }
}
