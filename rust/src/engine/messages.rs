//! The message plane: partition inboxes and worker outboxes.
//!
//! [`MsgStore`] is a partition's incoming mailbox. Messages live in one
//! **flat arena** (a slot pool threaded into per-vertex chains) instead
//! of a `Vec<Vec<M>>`: drained slots go onto a free list and are reused
//! by later sweeps, so the steady-state hot path performs no heap
//! allocation — the dominant memory/throughput cost in BSP message
//! buffers (McCune et al. 2015; Ammar & Özsu 2018).
//!
//! [`Outbox`] collects a worker's outgoing cross-partition messages for
//! one superstep in **per-destination-partition batch buffers**. Pushes
//! are plain appends; [`Outbox::seal`] then applies sender-side
//! combining (Pregel's `Combine()`, one message per destination vertex
//! per source worker) or GraphHP's `SourceCombine` policy, and orders
//! every batch by destination — so [`Outbox::drain`] yields messages in
//! `(dest_part, dest_local)` order, independent of any hasher. Outboxes
//! are pooled by the worker runtime ([`Outbox::reset`]) and their batch
//! buffers are reused across supersteps.

use std::collections::HashMap;

use crate::graph::VertexId;
use crate::util::Codec;

use super::program::SourceCombine;

/// Sentinel for "no slot" in the arena chains.
pub(crate) const NIL: u32 = u32::MAX;

/// Per-partition incoming message queues backed by a flat slot arena.
///
/// Each local vertex owns a FIFO chain of arena slots; `take_into`
/// returns the slots to the free list, so the arena's high-water mark is
/// the peak number of simultaneously-buffered messages, not the total
/// message traffic.
#[derive(Clone, Debug)]
pub struct MsgStore<M> {
    /// Flat message arena: `(payload, next slot in chain / free list)`.
    /// `payload` is `None` only for slots on the free list.
    /// (`pub(crate)` for the debug sanitizers in `engine/invariants.rs`.)
    pub(crate) slots: Vec<(Option<M>, u32)>,
    /// Free-list head.
    pub(crate) free: u32,
    /// Per-vertex chain head (`NIL` = empty).
    pub(crate) head: Vec<u32>,
    /// Per-vertex chain tail, for O(1) FIFO append.
    pub(crate) tail: Vec<u32>,
    pub(crate) nonempty: Vec<u32>,
    pub(crate) flagged: Vec<bool>,
    /// Buffered message count (all vertices).
    pub(crate) total: usize,
}

impl<M> MsgStore<M> {
    /// An empty store for a partition of `n` local vertices.
    pub fn new(n: usize) -> Self {
        MsgStore {
            slots: Vec::new(),
            free: NIL,
            head: vec![NIL; n],
            tail: vec![NIL; n],
            nonempty: Vec::new(),
            flagged: vec![false; n],
            total: 0,
        }
    }

    fn alloc_slot(&mut self, m: M) -> u32 {
        if self.free != NIL {
            let s = self.free as usize;
            self.free = self.slots[s].1;
            self.slots[s] = (Some(m), NIL);
            s as u32
        } else {
            self.slots.push((Some(m), NIL));
            (self.slots.len() - 1) as u32
        }
    }

    /// Return one chain to the free list (payloads already taken or
    /// dropped by the caller).
    fn free_chain(&mut self, lv: usize) {
        let mut s = self.head[lv];
        while s != NIL {
            let idx = s as usize;
            self.slots[idx].0 = None;
            let next = self.slots[idx].1;
            self.slots[idx].1 = self.free;
            self.free = s;
            s = next;
        }
        self.head[lv] = NIL;
        self.tail[lv] = NIL;
        self.flagged[lv] = false;
    }

    /// Append a message for local vertex `lv`.
    pub fn push(&mut self, lv: usize, m: M) {
        let slot = self.alloc_slot(m);
        if self.flagged[lv] {
            let t = self.tail[lv] as usize;
            self.slots[t].1 = slot;
        } else {
            self.flagged[lv] = true;
            self.nonempty.push(lv as u32);
            self.head[lv] = slot;
        }
        self.tail[lv] = slot;
        self.total += 1;
    }

    /// Append with combining: if a combiner is given and the chain is
    /// non-empty, fold into the tail message (receiver-side combining —
    /// queues hold one message per vertex regardless of how many source
    /// partitions delivered).
    pub fn push_combined(&mut self, lv: usize, m: M, combiner: Option<fn(M, M) -> M>) {
        match combiner {
            Some(f) if self.flagged[lv] => {
                let t = self.tail[lv] as usize;
                // detlint: allow(unwrap-hot-path) — a flagged vertex's tail
                // slot is live by the arena invariant (checked by
                // invariants::check_msgstore at every barrier).
                let prev = self.slots[t].0.take().expect("tail slot occupied");
                self.slots[t].0 = Some(f(prev, m));
            }
            _ => self.push(lv, m),
        }
    }

    /// True when local vertex `lv` has pending messages.
    pub fn has_messages(&self, lv: usize) -> bool {
        self.flagged[lv]
    }

    /// Drain the chain of `lv` into `buf` in FIFO order (clears the flag
    /// and recycles the slots).
    pub fn take_into(&mut self, lv: usize, buf: &mut Vec<M>) {
        buf.clear();
        if !self.flagged[lv] {
            return;
        }
        let mut s = self.head[lv];
        while s != NIL {
            let idx = s as usize;
            // detlint: allow(unwrap-hot-path) — chain slots are live by the
            // arena invariant (checked by invariants::check_msgstore).
            buf.push(self.slots[idx].0.take().expect("chain slot occupied"));
            let next = self.slots[idx].1;
            self.slots[idx].1 = self.free;
            self.free = s;
            s = next;
        }
        self.total -= buf.len();
        self.head[lv] = NIL;
        self.tail[lv] = NIL;
        self.flagged[lv] = false;
        // lazy removal from `nonempty`: entries are validated on drain
    }

    /// Local vertices with pending messages (sorted, deduplicated —
    /// lazy cleanup can leave stale duplicates in the index).
    pub fn pending(&mut self) -> Vec<u32> {
        self.pending_sorted().to_vec()
    }

    /// [`pending`](Self::pending) without the copy: compacts the lazy
    /// index in place and returns it as a sorted, deduplicated slice —
    /// the allocation-free form the sweep-seeding hot paths use.
    pub fn pending_sorted(&mut self) -> &[u32] {
        self.nonempty.retain(|&lv| self.flagged[lv as usize]);
        self.nonempty.sort_unstable();
        self.nonempty.dedup();
        &self.nonempty
    }

    /// True when no vertex has pending messages (compacts the lazy
    /// index).
    pub fn is_empty(&mut self) -> bool {
        self.nonempty.retain(|&lv| self.flagged[lv as usize]);
        self.nonempty.is_empty()
    }

    /// Buffered message count across all vertices.
    pub fn total_messages(&self) -> usize {
        self.total
    }

    /// Arena size in slots — the store's message high-water mark.
    /// Steady-state sweeps reuse slots instead of growing this.
    pub fn arena_slots(&self) -> usize {
        self.slots.len()
    }

    /// Drop every pending message, recycling the slots (checkpoint
    /// recovery).
    pub fn clear(&mut self) {
        for lv in std::mem::take(&mut self.nonempty) {
            let lv = lv as usize;
            if self.flagged[lv] {
                self.free_chain(lv);
            }
        }
        self.total = 0;
    }
}

impl<M: Clone> MsgStore<M> {
    /// Snapshot pending queues as (vertex, messages) pairs in FIFO order
    /// (checkpointing; non-draining).
    pub fn export(&mut self) -> Vec<(u32, Vec<M>)> {
        self.pending()
            .into_iter()
            .map(|lv| {
                let mut q = Vec::new();
                let mut s = self.head[lv as usize];
                while s != NIL {
                    let (m, next) = &self.slots[s as usize];
                    // detlint: allow(unwrap-hot-path) — non-draining walk of a
                    // live chain (checkpoint path); same arena invariant.
                    q.push(m.as_ref().expect("chain slot occupied").clone());
                    s = *next;
                }
                (lv, q)
            })
            .collect()
    }

    /// Rebuild a store of size `n` from an [`export`](Self::export) snapshot.
    pub fn restore(n: usize, snap: &[(u32, Vec<M>)]) -> Self {
        let mut s = MsgStore::new(n);
        for (lv, msgs) in snap {
            for m in msgs {
                s.push(*lv as usize, m.clone());
            }
        }
        s
    }
}

/// Wire overhead per message on the simulated network (dest id + header).
pub const MSG_WIRE_OVERHEAD: usize = 8;

/// A worker's outgoing cross-partition traffic for one superstep,
/// batched per destination partition.
///
/// Lifecycle: [`push`](Self::push) during the sweep(s), one
/// [`seal`](Self::seal) when the worker's turn ends (combining + ordering),
/// then accounting ([`len`](Self::len), [`wire_bytes`](Self::wire_bytes),
/// [`peer_count`](Self::peer_count)) and [`drain`](Self::drain) at the
/// barrier. [`reset`](Self::reset) re-arms the outbox for the next
/// superstep, keeping every batch buffer's capacity.
pub struct Outbox<M> {
    /// Per-destination-partition batches, indexed by partition (grown on
    /// demand): `(dest_local, src_gid, message)` in push order.
    /// (`pub(crate)` for the debug sanitizers in `engine/invariants.rs`.)
    pub(crate) batches: Vec<Vec<(u32, VertexId, M)>>,
    combiner: Option<fn(M, M) -> M>,
    /// Entry count; collapses to the combined count at `seal`.
    pub(crate) len: usize,
    pub(crate) sealed: bool,
    /// Scratch for the KeepLatest filter, reused across seals.
    keep: Vec<bool>,
    /// Scratch: last batch index per source within one destination run
    /// (membership only — hash order never reaches the output).
    // detlint: allow(unordered-iter) — lookup-only scratch: written by
    // insert, read by key; never iterated, so hash order cannot reach
    // the sealed batch order.
    latest: HashMap<VertexId, usize>,
}

/// An empty combinerless outbox — the placeholder
/// [`std::mem::take`] leaves behind while the worker runtime lends a
/// pooled outbox out of its slot.
impl<M> Default for Outbox<M> {
    fn default() -> Self {
        Outbox {
            batches: Vec::new(),
            combiner: None,
            len: 0,
            sealed: false,
            keep: Vec::new(),
            // detlint: allow(unordered-iter) — constructing the
            // lookup-only scratch declared above.
            latest: HashMap::new(),
        }
    }
}

impl<M: Clone + Codec> Outbox<M> {
    /// An empty outbox applying `combiner` sender-side at seal.
    pub fn new(combiner: Option<fn(M, M) -> M>) -> Self {
        Outbox { combiner, ..Outbox::default() }
    }

    /// Queue a message from `src` to `(dest_part, dest_local)`: a plain
    /// append onto the destination partition's batch.
    pub fn push(&mut self, dest_part: u32, dest_local: u32, src: VertexId, m: M) {
        debug_assert!(!self.sealed, "Outbox::push after seal");
        let dp = dest_part as usize;
        if self.batches.len() <= dp {
            self.batches.resize_with(dp + 1, Vec::new);
        }
        self.batches[dp].push((dest_local, src, m));
        self.len += 1;
    }

    /// Close the outbox for this superstep: order every batch by
    /// `(destination vertex, source gid)` (stable, so a source's
    /// repeated messages to one destination keep push order) and apply
    /// sender-side combining — the full combiner when the program has
    /// one, else the GraphHP `SourceCombine` policy (keep the latest
    /// message per (source, destination) pair).
    ///
    /// Sorting by source gid as well makes the sealed order — and hence
    /// every barrier-side fold — independent of the sender partition's
    /// *vertex layout*: a degree-sorted partition pushes in a permuted
    /// sweep order, but the sealed batch is the same. (Under the
    /// identity layout the secondary key is a no-op: push order within
    /// a destination is already ascending source gid.)
    ///
    /// After sealing, [`drain`](Self::drain) yields messages in
    /// `(dest_part, dest_local)` order — deterministic by construction,
    /// with no hash-order dependence.
    pub fn seal(&mut self, policy: SourceCombine) {
        assert!(!self.sealed, "Outbox sealed twice in one superstep");
        self.sealed = true;
        for batch in &mut self.batches {
            batch.sort_by_key(|&(l, s, _)| (l, s)); // stable sort
            if let Some(f) = self.combiner {
                // fold each destination run in the sealed (source-gid)
                // order; entries past the write cursor are consumed and
                // truncated below
                let mut w = 0usize;
                let mut r = 0usize;
                while r < batch.len() {
                    batch.swap(w, r);
                    let mut j = r + 1;
                    while j < batch.len() && batch[j].0 == batch[w].0 {
                        batch[w].2 = f(batch[w].2.clone(), batch[j].2.clone());
                        j += 1;
                    }
                    r = j;
                    w += 1;
                }
                batch.truncate(w);
            } else if policy == SourceCombine::KeepLatest {
                // keep the LAST message per (destination, source),
                // preserving push order among the kept
                let n = batch.len();
                self.keep.clear();
                self.keep.resize(n, true);
                let mut s = 0usize;
                while s < n {
                    let mut e = s + 1;
                    while e < n && batch[e].0 == batch[s].0 {
                        e += 1;
                    }
                    // linear per run: record each source's last index,
                    // then keep exactly those entries
                    self.latest.clear();
                    for i in s..e {
                        self.latest.insert(batch[i].1, i);
                    }
                    for i in s..e {
                        if self.latest[&batch[i].1] != i {
                            self.keep[i] = false;
                        }
                    }
                    s = e;
                }
                let mut w = 0usize;
                for r in 0..n {
                    if self.keep[r] {
                        batch.swap(w, r);
                        w += 1;
                    }
                }
                batch.truncate(w);
            }
        }
        self.len = self.batches.iter().map(Vec::len).sum();
    }

    /// Re-arm a pooled outbox for the next superstep, keeping batch
    /// capacities.
    pub fn reset(&mut self) {
        for b in &mut self.batches {
            b.clear();
        }
        self.len = 0;
        self.sealed = false;
    }

    /// Number of messages that will cross the network (combined count
    /// once sealed).
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Total bytes on the wire (payload + per-message overhead).
    pub fn wire_bytes(&self) -> usize {
        debug_assert!(self.sealed, "wire accounting before seal");
        let payload: usize =
            self.batches.iter().flatten().map(|(_, _, m)| m.encoded_len()).sum();
        payload + self.len * MSG_WIRE_OVERHEAD
    }

    /// Distinct destination partitions (for RPC-pair accounting).
    pub fn peer_count(&self, exclude_part: u32) -> usize {
        self.batches
            .iter()
            .enumerate()
            .filter(|&(p, b)| !b.is_empty() && p as u32 != exclude_part)
            .count()
    }

    /// Drain into `(dest_part, dest_local, message)` triples, in
    /// `(dest_part, dest_local)` order. Requires [`seal`](Self::seal);
    /// batch capacities survive for [`reset`](Self::reset).
    pub fn drain(&mut self) -> impl Iterator<Item = (u32, u32, M)> + '_ {
        debug_assert!(self.sealed, "Outbox::drain before seal");
        self.len = 0;
        self.batches
            .iter_mut()
            .enumerate()
            .flat_map(|(p, b)| b.drain(..).map(move |(l, _, m)| (p as u32, l, m)))
    }

    /// Destination-partition slots the outbox has grown (some may hold
    /// empty batches). For the batch-granular barrier fold under fault
    /// injection.
    pub(crate) fn num_dests(&self) -> usize {
        self.batches.len()
    }

    /// Combined messages sealed for destination partition `dest`.
    pub(crate) fn batch_size(&self, dest: usize) -> usize {
        self.batches[dest].len()
    }

    /// Drain the sealed batch for one destination partition in its
    /// canonical `(dest_local, src)` order, yielding
    /// `(dest_local, message)`. Requires [`seal`](Self::seal); batch
    /// capacity survives for [`reset`](Self::reset). Length accounting
    /// is kept so a partially drained (chaos-dropped) outbox still
    /// reports the undelivered remainder.
    pub(crate) fn drain_batch(&mut self, dest: usize) -> impl Iterator<Item = (u32, M)> + '_ {
        debug_assert!(self.sealed, "Outbox::drain_batch before seal");
        self.len -= self.batches[dest].len();
        self.batches[dest].drain(..).map(|(l, _, m)| (l, m))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msgstore_push_take() {
        let mut s: MsgStore<u32> = MsgStore::new(4);
        s.push(1, 10);
        s.push(1, 11);
        s.push(3, 30);
        assert!(s.has_messages(1));
        assert!(!s.has_messages(0));
        assert_eq!(s.pending(), vec![1, 3]);
        let mut buf = Vec::new();
        s.take_into(1, &mut buf);
        assert_eq!(buf, vec![10, 11]);
        assert!(!s.has_messages(1));
        assert_eq!(s.pending(), vec![3]);
    }

    #[test]
    fn msgstore_combining() {
        let mut s: MsgStore<f32> = MsgStore::new(2);
        let min = |a: f32, b: f32| a.min(b);
        s.push_combined(0, 5.0, Some(min));
        s.push_combined(0, 3.0, Some(min));
        s.push_combined(0, 9.0, Some(min));
        let mut buf = Vec::new();
        s.take_into(0, &mut buf);
        assert_eq!(buf, vec![3.0]);
    }

    #[test]
    fn msgstore_arena_reused_across_sweeps() {
        let mut s: MsgStore<u64> = MsgStore::new(4);
        let mut buf = Vec::new();
        for round in 0..100u64 {
            for lv in 0..4 {
                s.push(lv, round);
                s.push(lv, round + 1);
            }
            for lv in 0..4 {
                s.take_into(lv, &mut buf);
                assert_eq!(buf.len(), 2);
            }
        }
        assert_eq!(s.total_messages(), 0);
        assert!(s.arena_slots() <= 8, "arena must be recycled, got {}", s.arena_slots());
    }

    #[test]
    fn msgstore_clear_with_stale_index_entries() {
        let mut s: MsgStore<u32> = MsgStore::new(3);
        s.push(1, 7);
        let mut buf = Vec::new();
        s.take_into(1, &mut buf); // leaves a stale `nonempty` entry for 1
        s.push(2, 8);
        s.push(1, 9); // duplicates 1 in the lazy index
        s.clear();
        assert_eq!(s.total_messages(), 0);
        assert!(s.is_empty());
        assert!(!s.has_messages(1));
        assert!(!s.has_messages(2));
        // the store still works after the clear (slots were recycled)
        s.push(1, 10);
        s.take_into(1, &mut buf);
        assert_eq!(buf, vec![10]);
    }

    #[test]
    fn msgstore_push_combined_on_drained_queue_starts_fresh() {
        let mut s: MsgStore<u32> = MsgStore::new(2);
        let min = |a: u32, b: u32| a.min(b);
        s.push_combined(0, 5, Some(min));
        let mut buf = Vec::new();
        s.take_into(0, &mut buf);
        assert_eq!(buf, vec![5]);
        // drained queue: the next combined push must NOT fold into the
        // recycled slot's ghost — it starts a fresh chain
        s.push_combined(0, 9, Some(min));
        s.take_into(0, &mut buf);
        assert_eq!(buf, vec![9]);
    }

    #[test]
    fn msgstore_export_restore_roundtrip_under_combining() {
        let min = |a: u32, b: u32| a.min(b);
        let mut s: MsgStore<u32> = MsgStore::new(4);
        s.push_combined(0, 5, Some(min));
        s.push_combined(0, 3, Some(min));
        s.push(2, 9);
        s.push(2, 1);
        let snap = s.export();
        assert_eq!(snap, vec![(0, vec![3]), (2, vec![9, 1])]);
        // export must not drain
        assert_eq!(s.total_messages(), 3);
        let mut r = MsgStore::restore(4, &snap);
        assert_eq!(r.export(), snap);
        // combining keeps working on the restored store
        r.push_combined(0, 2, Some(min));
        let mut buf = Vec::new();
        r.take_into(0, &mut buf);
        assert_eq!(buf, vec![2]);
    }

    #[test]
    fn outbox_sender_side_combining_counts() {
        let mut o: Outbox<f32> = Outbox::new(Some(|a: f32, b: f32| a.min(b)));
        o.push(1, 0, 100, 5.0);
        o.push(1, 0, 101, 3.0); // same destination -> combined at seal
        o.push(2, 0, 100, 7.0);
        o.seal(SourceCombine::KeepAll);
        assert_eq!(o.len(), 2);
        assert_eq!(o.peer_count(0), 2);
        let d: Vec<_> = o.drain().collect();
        assert_eq!(d, vec![(1, 0, 3.0), (2, 0, 7.0)]);
    }

    #[test]
    fn outbox_source_combine_keeps_latest() {
        let mut o: Outbox<u32> = Outbox::new(None);
        o.push(1, 0, 7, 100);
        o.push(1, 0, 7, 200); // same (src, dest): keep this one
        o.push(1, 0, 8, 300); // different src: kept
        o.seal(SourceCombine::KeepLatest);
        let mut d: Vec<_> = o.drain().collect();
        d.sort_by_key(|&(_, _, m)| m);
        assert_eq!(d, vec![(1, 0, 200), (1, 0, 300)]);
    }

    #[test]
    fn outbox_keepall_preserves_everything() {
        let mut o: Outbox<u32> = Outbox::new(None);
        o.push(1, 0, 7, 100);
        o.push(1, 0, 7, 200);
        o.seal(SourceCombine::KeepAll);
        assert_eq!(o.len(), 2);
        // push order preserved per destination
        let d: Vec<_> = o.drain().collect();
        assert_eq!(d, vec![(1, 0, 100), (1, 0, 200)]);
    }

    #[test]
    fn outbox_drain_is_destination_ordered() {
        // regression: the old FxHashMap-backed combined path drained in
        // hash order, so delivery order depended on hasher internals
        let mut o: Outbox<u32> = Outbox::new(Some(|a: u32, b: u32| a + b));
        o.push(2, 5, 0, 1);
        o.push(0, 9, 0, 2);
        o.push(2, 1, 0, 3);
        o.push(0, 4, 0, 4);
        o.push(1, 0, 0, 5);
        o.seal(SourceCombine::KeepAll);
        let d: Vec<_> = o.drain().collect();
        let keys: Vec<(u32, u32)> = d.iter().map(|&(p, l, _)| (p, l)).collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted, "drain must be (dest_part, dest_local)-ordered");
        assert_eq!(keys, vec![(0, 4), (0, 9), (1, 0), (2, 1), (2, 5)]);
    }

    #[test]
    fn outbox_seal_order_is_independent_of_push_order() {
        // the layout-invariance contract: a degree-sorted sender sweeps
        // (and hence pushes) in a permuted order, but the sealed batch —
        // and therefore every barrier-side fold — must be identical
        let pushes = [(1u32, 3u32, 7u32, 10u32), (1, 3, 5, 20), (1, 2, 9, 30), (2, 0, 5, 40)];
        let mut fwd: Outbox<u32> = Outbox::new(None);
        for &(p, l, s, m) in &pushes {
            fwd.push(p, l, s, m);
        }
        fwd.seal(SourceCombine::KeepAll);
        let a: Vec<_> = fwd.drain().collect();
        let mut rev: Outbox<u32> = Outbox::new(None);
        for &(p, l, s, m) in pushes.iter().rev() {
            rev.push(p, l, s, m);
        }
        rev.seal(SourceCombine::KeepAll);
        let b: Vec<_> = rev.drain().collect();
        assert_eq!(a, b);
        assert_eq!(a, vec![(1, 2, 30), (1, 3, 20), (1, 3, 10), (2, 0, 40)]);
    }

    #[test]
    fn outbox_reset_reuses_batches() {
        let mut o: Outbox<u32> = Outbox::new(None);
        for round in 0..10 {
            o.push(1, 0, 7, round);
            o.push(3, 2, 7, round);
            o.seal(SourceCombine::KeepAll);
            assert_eq!(o.len(), 2);
            assert_eq!(o.drain().count(), 2);
            o.reset();
            assert!(o.is_empty());
        }
    }

    #[test]
    fn wire_bytes_include_overhead() {
        let mut o: Outbox<f32> = Outbox::new(None);
        o.push(1, 0, 0, 1.0);
        o.seal(SourceCombine::KeepAll);
        assert_eq!(o.wire_bytes(), 4 + MSG_WIRE_OVERHEAD);
    }
}
