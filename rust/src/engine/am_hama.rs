//! AM-Hama: standard BSP with the asynchronous in-memory messaging
//! mechanism (paper §4.2 last ¶ and §7, after Grace [35] / the
//! hybrid-communication mode of Giraph++ [32]).
//!
//! Differences from stock Hama:
//! - a message to a vertex in the *same* partition is delivered directly
//!   in memory (never counted as a network message);
//! - if the receiver has not yet been processed in the current superstep,
//!   it sees the message *this* superstep (each vertex still computes at
//!   most once per superstep);
//! - only cross-partition messages go through RPC at the barrier.

use std::collections::BTreeSet;

use crate::graph::DistGraph;

use super::aggregator::Aggregators;
use super::context::{SendBuffer, VertexContext};
use super::messages::Outbox;
use super::metrics::Metrics;
use super::netsim::{SuperstepClock, WorkerComm};
use super::program::VertexProgram;
use super::state::{init_runtimes, PartitionRuntime};
use super::{EngineConfig, RunResult};

/// Run `program` under the AM-Hama (asynchronous messaging) model.
///
/// Legacy entry point — use [`super::Runner`] with
/// [`super::EngineKind::AmHama`]; kept as a delegate for one release.
#[doc(hidden)]
pub fn run_am_hama<P: VertexProgram>(
    program: &P,
    dg: &DistGraph,
    cfg: &EngineConfig,
) -> RunResult<P::V> {
    let mut rts: Vec<PartitionRuntime<P>> = init_runtimes(program, dg);
    let mut metrics = Metrics::default();
    let mut clock = SuperstepClock::new();
    let mut aggs = Aggregators::new(
        (0..program.num_aggregators()).map(|i| program.aggregator_op(i)).collect(),
    );
    let combiner = program.combiner();

    for (p, rt) in rts.iter_mut().enumerate() {
        for lv in 0..dg.parts[p].num_vertices() {
            rt.schedule_next(lv);
        }
    }

    let mut superstep: u64 = 0;
    let mut msg_buf: Vec<P::M> = Vec::new();
    let mut send_buf: SendBuffer<P::M> = SendBuffer::new();

    loop {
        let mut outboxes: Vec<Outbox<P::M>> = Vec::with_capacity(dg.num_parts());
        let mut worker_aggs: Vec<Aggregators> = Vec::new();

        for p in 0..dg.num_parts() {
            let part = &dg.parts[p];
            let rt = &mut rts[p];
            let mut outbox: Outbox<P::M> = Outbox::new(combiner);
            let mut wagg = aggs.clone();
            let t0 = std::time::Instant::now();

            // Vertices are processed in local-index order; in-memory
            // messages can still reach vertices later in the order this
            // same superstep, so the worklist is an ordered set that
            // accepts insertions ahead of the cursor.
            let frontier = rt.begin_step();
            let mut worklist: BTreeSet<u32> = frontier.into_iter().collect();
            let n = rt.num_vertices();
            let mut processed = vec![false; n];

            while let Some(lv32) = worklist.pop_first() {
                let lv = lv32 as usize;
                processed[lv] = true;
                rt.cur.take_into(lv, &mut msg_buf);
                if rt.halted[lv] {
                    if msg_buf.is_empty() {
                        continue;
                    }
                    rt.halted[lv] = false;
                }
                send_buf.clear();
                {
                    let mut ctx = VertexContext::<P> {
                        part,
                        lv,
                        superstep,
                        value: &mut rt.values[lv],
                        messages: &msg_buf,
                        halted: &mut rt.halted[lv],
                        out: &mut send_buf,
                        aggregators: &mut wagg,
                        seed: cfg.seed,
                    };
                    program.compute(&mut ctx);
                }
                metrics.vertex_computations += 1;
                for (target, m) in send_buf.sends.drain(..) {
                    let (tp, tl) = dg.location[target as usize];
                    if tp as usize == p {
                        // in-memory delivery (never network)
                        metrics.local_messages += 1;
                        let tl = tl as usize;
                        // No same-superstep delivery during the
                        // initialization superstep: programs treat
                        // superstep 0 as message-free setup, so async
                        // delivery there would silently drop messages.
                        if superstep > 0 && !processed[tl] {
                            // receiver still to run this superstep
                            rt.cur.push_combined(tl, m, combiner);
                            worklist.insert(tl as u32);
                        } else {
                            rt.nxt.push_combined(tl, m, combiner);
                            rt.schedule_next(tl);
                        }
                    } else {
                        outbox.push(tp, tl, part.global_ids[lv], m);
                    }
                }
                if !rt.halted[lv] {
                    rt.schedule_next(lv);
                }
            }

            let compute = cfg.net.scale_compute(t0.elapsed());
            let comm = WorkerComm {
                messages: outbox.len() as u64,
                bytes: outbox.wire_bytes() as u64,
                peer_pairs: outbox.peer_count(p as u32) as u64,
            };
            metrics.network_messages += comm.messages;
            metrics.network_bytes += comm.bytes;
            clock.record_worker(compute, cfg.net.comm_time(&comm));
            outboxes.push(outbox);
            worker_aggs.push(wagg);
        }

        for mut outbox in outboxes {
            for (tp, tl, m) in outbox.drain() {
                let rt = &mut rts[tp as usize];
                rt.nxt.push(tl as usize, m);
                rt.schedule_next(tl as usize);
            }
        }
        for w in &worker_aggs {
            aggs.merge_current(w);
        }
        aggs.barrier();
        clock.barrier(&cfg.net, &mut metrics);
        metrics.global_iterations += 1;
        metrics.supersteps_total += 1;
        superstep += 1;

        let done = rts.iter_mut().all(|rt| rt.quiesced());
        if done || superstep >= cfg.limits.max_iterations {
            break;
        }
    }

    let values = super::gather_values(
        dg,
        &rts.iter().map(|rt| rt.values.clone()).collect::<Vec<_>>(),
    );
    RunResult { values, metrics }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::hama::run_hama;
    use crate::graph::{generators, DistGraph, VertexId};
    use crate::partition::{metis_partition, MetisConfig};

    struct MinLabel;
    impl VertexProgram for MinLabel {
        type V = u32;
        type M = u32;
        fn init(&self, v: VertexId, _d: u32) -> u32 {
            v
        }
        fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
            let mut best = *ctx.value();
            if ctx.superstep() == 0 {
                ctx.send_to_neighbors(best);
            } else if let Some(&m) = ctx.messages().iter().min() {
                if m < best {
                    best = m;
                    ctx.set_value(best);
                    ctx.send_to_neighbors(best);
                }
            }
            ctx.vote_to_halt();
        }
        fn combiner(&self) -> Option<fn(u32, u32) -> u32> {
            Some(|a, b| a.min(b))
        }
    }

    #[test]
    fn same_result_as_hama_fewer_network_messages() {
        let g = generators::connected(300, 150, 5);
        let a = metis_partition(&g, 4, &MetisConfig::default());
        let dg = DistGraph::new(&g, &a, 4);
        let cfg = EngineConfig::default();
        let h = run_hama(&MinLabel, &dg, &cfg);
        let am = run_am_hama(&MinLabel, &dg, &cfg);
        assert_eq!(h.values, am.values);
        assert!(
            am.metrics.network_messages * 2 < h.metrics.network_messages,
            "am={} hama={}",
            am.metrics.network_messages,
            h.metrics.network_messages
        );
        assert!(am.metrics.local_messages > 0);
        // async in-memory propagation can only speed up convergence
        assert!(am.metrics.global_iterations <= h.metrics.global_iterations);
    }

    #[test]
    fn in_memory_message_seen_same_superstep() {
        // Chain 0->1->2 in ONE partition: with async messaging the label
        // of 0 reaches 2 within a single superstep after init.
        let mut b = crate::graph::GraphBuilder::new(3);
        b.add_edge(0, 1, 1.0);
        b.add_edge(1, 2, 1.0);
        let g = b.build();
        let dg = DistGraph::new(&g, &[0, 0, 0], 1);
        let r = run_am_hama(&MinLabel, &dg, &EngineConfig::default());
        assert_eq!(r.values, vec![0, 0, 0]);
        // superstep 0 init + superstep 1 full propagation + 1 to quiesce
        assert!(
            r.metrics.global_iterations <= 3,
            "iters={}",
            r.metrics.global_iterations
        );
    }
}
