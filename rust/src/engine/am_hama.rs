//! AM-Hama: standard BSP with the asynchronous in-memory messaging
//! mechanism (paper §4.2 last ¶ and §7, after Grace [35] / the
//! hybrid-communication mode of Giraph++ [32]).
//!
//! Differences from stock Hama:
//! - a message to a vertex in the *same* partition is delivered directly
//!   in memory (never counted as a network message);
//! - if the receiver has not yet been processed in the current superstep,
//!   it sees the message *this* superstep (each vertex still computes at
//!   most once per superstep);
//! - only cross-partition messages go through RPC at the barrier.
//!
//! Routing policy: `LocalRoute::ThisSweep`. The worker body lives in
//! `super::worker`; workers run in parallel per
//! [`super::EngineConfig::parallelism`]. With
//! `FaultPolicy::checkpoint_interval` set, the engine snapshots at the
//! superstep boundary and recovers from injected loss through the
//! shared recovery layer (`engine/recovery.rs`).

use crate::graph::{DistGraph, MigrationPlan};

use super::aggregator::Aggregators;
use super::messages::Outbox;
use super::metrics::{Metrics, PartitionStepTrace, RunTrace};
use super::migrate::{remap_runtimes, MigrationPlanner};
use super::netsim::SuperstepClock;
use super::program::{SourceCombine, VertexProgram};
use super::recovery::{persist_checkpoint, RecoveryCoordinator};
use super::worker::{
    boundary_count, close_superstep, init_worker_states, restore_worker_states, run_workers,
    snapshot_worker_states, LocalRoute, ProcessedMarks, Reschedule, Sweep, WorkerOut,
    WorkerScratch, WorkerState,
};
use super::{EngineConfig, RunResult};

/// Run `program` under the AM-Hama (asynchronous messaging) model.
///
/// Legacy entry point — use [`super::Runner`] with
/// [`super::EngineKind::AmHama`]; kept as a delegate for one release.
#[doc(hidden)]
pub fn run_am_hama<P: VertexProgram>(
    program: &P,
    dg: &DistGraph,
    cfg: &EngineConfig,
) -> RunResult<P::V> {
    let mut workers = init_worker_states(program, dg);
    let mut metrics = Metrics::default();
    let mut trace = RunTrace::default();
    let mut clock = SuperstepClock::new();
    let mut aggs = Aggregators::new(
        (0..program.num_aggregators()).map(|i| program.aggregator_op(i)).collect(),
    );
    let combiner = program.combiner();

    for ws in workers.iter_mut() {
        for lv in 0..ws.rt.num_vertices() {
            ws.rt.schedule_next(lv);
        }
    }

    let mut superstep: u64 = 0;
    let planner = cfg.repartition.map(MigrationPlanner::new);
    let mut dg_owned: Option<Box<DistGraph>> = None;
    let mut applied_plans: Vec<MigrationPlan> = Vec::new();
    let mut chaos_ctl = cfg.chaos.as_ref().map(super::chaos::ChaosController::new);
    let mut recovery = RecoveryCoordinator::new(cfg.fault.recovery);

    loop {
        // ---- fault tolerance (paper §5.3, via engine/recovery.rs):
        // snapshot the full superstep-boundary state so a chaos loss
        // event rolls back and replays instead of panicking
        if recovery.should_checkpoint(&cfg.fault, superstep) {
            let ckpt = snapshot_worker_states(superstep, &mut workers, &applied_plans);
            persist_checkpoint(&ckpt, &cfg.fault);
            recovery.install(superstep, ckpt, &mut metrics);
        }

        let dgr: &DistGraph = dg_owned.as_deref().unwrap_or(dg);
        let outs = run_workers(cfg.parallelism, &mut workers, |p, ws| {
            ws.outbox.reset();
            let mut wagg = aggs.clone();
            // detlint: allow(wall-clock) — compute_us probe: measures this
            // worker's sweep for telemetry/netsim only, never feeds results.
            let t0 = std::time::Instant::now();

            // Vertices are processed in local-index order; in-memory
            // messages can still reach vertices later in the order this
            // same superstep (the pooled sorted worklist accepts
            // insertions ahead of the cursor, exactly like the former
            // per-sweep BTreeSet). The frontier alone seeds it: every
            // delivery into `nxt` is paired with a schedule, so cur's
            // pending set is always a subset of the frontier.
            ws.rt.begin_step_into(&mut ws.scratch.worklist);
            let pt = PartitionStepTrace {
                frontier: ws.scratch.worklist.len() as u64,
                boundary_frontier: boundary_count(&dgr.parts[p], ws.scratch.worklist.as_slice()),
                ..Default::default()
            };
            let sweep = Sweep {
                program,
                dg: dgr,
                part: &dgr.parts[p],
                p,
                superstep,
                seed: cfg.seed,
                combiner,
                route: LocalRoute::ThisSweep,
                reschedule: Reschedule::Active,
                boundary_in_local: true,
                steal_threads: cfg.parallelism.steal_threads(),
            };
            let outcome = sweep.run(
                ws.rt.sweep_target(),
                None,
                &mut ws.outbox,
                &mut wagg,
                &mut ws.scratch,
                &mut ws.marks,
            );
            ws.rt.commit_step();
            ws.outbox.seal(SourceCombine::KeepAll);
            let compute = cfg.net.scale_compute(t0.elapsed());
            WorkerOut::new(std::mem::take(&mut ws.outbox), wagg, compute, p, outcome, 0, pt)
        });

        let outboxes = close_superstep(
            outs,
            &mut aggs,
            &mut clock,
            &cfg.net,
            &mut metrics,
            &mut trace,
            chaos_ctl.as_mut(),
            |tp, tl, m| {
                let rt = &mut workers[tp as usize].rt;
                rt.nxt.push_combined(tl as usize, m, combiner);
                rt.schedule_next(tl as usize);
            },
        );
        for (ws, ob) in workers.iter_mut().zip(outboxes) {
            ws.outbox = ob;
            // debug sanitizer: step closed, inboxes/frontier intact
            // after delivery (no-op in release builds)
            super::invariants::check_runtime(&ws.rt);
        }

        // ---- chaos recovery: a loss event corrupted this barrier —
        // roll every worker back to the latest checkpoint and replay
        // (the monotone chaos counter keeps advancing, so the replay
        // draws fresh RNG streams and a consumed kill never re-fires).
        // Without a checkpoint the coordinator refuses loss loudly.
        if let Some(reason) = chaos_ctl.as_mut().and_then(|c| c.take_pending()) {
            let ckpt = recovery.rollback("am-hama", &reason, &mut metrics);
            let (ws, at) =
                restore_worker_states(dg, ckpt, &mut dg_owned, &mut applied_plans, combiner);
            workers = ws;
            superstep = at;
            if let Some(ctl) = chaos_ctl.as_mut() {
                ctl.note_recovery();
            }
            continue;
        }

        // ---- online repartitioning: every partition is step-closed and
        // all barrier mail landed, so the plan applies atomically here
        {
            let step = trace.steps.last_mut().expect("barrier just recorded a step");
            step.routing_epoch = dgr.routing.epoch;
            let plan = planner.as_ref().and_then(|pl| pl.plan(dgr, step, superstep));
            if let Some(plan) = plan {
                // chaos: a kill scheduled inside this migration window
                // fires between plan and apply — abandon the plan and
                // roll back; the replay re-derives the identical plan
                // from the same counters and applies it cleanly
                let survive = match chaos_ctl.as_mut() {
                    Some(ctl) => ctl.judge_migration(plan.len() as u64),
                    None => true,
                };
                if !survive {
                    let reason = chaos_ctl
                        .as_mut()
                        .and_then(|c| c.take_pending())
                        .expect("migration kill raised a pending loss");
                    let ckpt = recovery.rollback("am-hama", &reason, &mut metrics);
                    let (ws, at) = restore_worker_states(
                        dg,
                        ckpt,
                        &mut dg_owned,
                        &mut applied_plans,
                        combiner,
                    );
                    workers = ws;
                    superstep = at;
                    if let Some(ctl) = chaos_ctl.as_mut() {
                        ctl.note_recovery();
                    }
                    continue;
                }
                step.migrated = plan.len() as u64;
                let new_dg = Box::new(dgr.apply_migration(&plan));
                let rts = remap_runtimes(
                    dgr,
                    &new_dg,
                    workers.drain(..).map(|ws| ws.rt).collect(),
                    combiner,
                );
                workers = rts
                    .into_iter()
                    .map(|rt| {
                        let n = rt.num_vertices();
                        WorkerState {
                            rt,
                            scratch: WorkerScratch::new(),
                            marks: ProcessedMarks::new(n),
                            outbox: Outbox::new(combiner),
                        }
                    })
                    .collect();
                applied_plans.push(plan);
                dg_owned = Some(new_dg);
            }
        }

        metrics.global_iterations += 1;
        metrics.supersteps_total += 1;
        superstep += 1;

        let done = workers.iter_mut().all(|ws| ws.rt.quiesced());
        if done || superstep >= cfg.limits.max_iterations {
            break;
        }
    }

    // gather under the final routing epoch — migrated vertices read back
    // from their current owners
    let dgr: &DistGraph = dg_owned.as_deref().unwrap_or(dg);
    let values =
        super::gather_values_owned(dgr, workers.into_iter().map(|ws| ws.rt.values).collect());
    RunResult { values, metrics, trace, chaos: chaos_ctl.map(|c| c.into_trace()) }
}

#[cfg(test)]
mod tests {
    use super::super::context::VertexContext;
    use super::*;
    use crate::engine::hama::run_hama;
    use crate::graph::{generators, DistGraph, VertexId};
    use crate::partition::{metis_partition, MetisConfig};

    struct MinLabel;
    impl VertexProgram for MinLabel {
        type V = u32;
        type M = u32;
        fn init(&self, v: VertexId, _d: u32) -> u32 {
            v
        }
        fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
            let mut best = *ctx.value();
            if ctx.superstep() == 0 {
                ctx.send_to_neighbors(best);
            } else if let Some(&m) = ctx.messages().iter().min() {
                if m < best {
                    best = m;
                    ctx.set_value(best);
                    ctx.send_to_neighbors(best);
                }
            }
            ctx.vote_to_halt();
        }
        fn combiner(&self) -> Option<fn(u32, u32) -> u32> {
            Some(|a, b| a.min(b))
        }
    }

    #[test]
    fn same_result_as_hama_fewer_network_messages() {
        let g = generators::connected(300, 150, 5);
        let a = metis_partition(&g, 4, &MetisConfig::default());
        let dg = DistGraph::new(&g, &a, 4);
        let cfg = EngineConfig::default();
        let h = run_hama(&MinLabel, &dg, &cfg);
        let am = run_am_hama(&MinLabel, &dg, &cfg);
        assert_eq!(h.values, am.values);
        assert!(
            am.metrics.network_messages * 2 < h.metrics.network_messages,
            "am={} hama={}",
            am.metrics.network_messages,
            h.metrics.network_messages
        );
        assert!(am.metrics.local_messages > 0);
        // async in-memory propagation can only speed up convergence
        assert!(am.metrics.global_iterations <= h.metrics.global_iterations);
    }

    #[test]
    fn in_memory_message_seen_same_superstep() {
        // Chain 0->1->2 in ONE partition: with async messaging the label
        // of 0 reaches 2 within a single superstep after init.
        let mut b = crate::graph::GraphBuilder::new(3);
        b.add_edge(0, 1, 1.0);
        b.add_edge(1, 2, 1.0);
        let g = b.build();
        let dg = DistGraph::new(&g, &[0, 0, 0], 1);
        let r = run_am_hama(&MinLabel, &dg, &EngineConfig::default());
        assert_eq!(r.values, vec![0, 0, 0]);
        // superstep 0 init + superstep 1 full propagation + 1 to quiesce
        assert!(
            r.metrics.global_iterations <= 3,
            "iters={}",
            r.metrics.global_iterations
        );
    }
}
