//! The [`Runner`] session: one entry point for every engine.
//!
//! The paper's central interface claim (§3, §5) is that a single
//! vertex-centric `Compute()` runs unmodified across platforms. The
//! `Runner` makes that claim executable: it owns the
//! partition → [`DistGraph`] plumbing once, dispatches on
//! [`EngineKind`], and exposes the two non-vertex-centric programming
//! models ([`GasProgram`], [`PartitionProgram`]) through the same
//! session — so an engine comparison is a loop over kinds, not six
//! differently-shaped call sites.
//!
//! ```no_run
//! use graphhp::algorithms::Sssp;
//! use graphhp::engine::{EngineKind, Runner};
//! use graphhp::graph::generators;
//!
//! let g = generators::road(120, 120, 1);
//! let mut runner = Runner::new(&g).partitions(12);
//! for (kind, r) in runner.compare(&EngineKind::VERTEX_CENTRIC, &Sssp { source: 0 }) {
//!     println!("{kind:<10} {}", r.metrics.summary());
//! }
//! ```

use crate::graph::{DistGraph, Graph, GraphLayout};
use crate::partition::{hash_partition, metis_partition, range_partition, MetisConfig};

use super::giraphpp::{run_giraphpp, PartitionProgram, VertexSweep};
use super::graphlab::{run_graphlab_async, run_graphlab_sync, GasCost, GasProgram};
use super::{
    EngineConfig, EngineKind, HybridPolicy, NetSimConfig, Parallelism, RepartitionConfig,
    RunResult, VertexProgram,
};

/// How the [`Runner`] splits the graph across simulated workers.
#[derive(Clone, Debug)]
pub enum Partitioner {
    /// `vertex_id % k` (the Hama default — destroys locality).
    Hash,
    /// Contiguous id ranges.
    Range,
    /// The built-in multilevel (METIS-like) partitioner.
    Metis(MetisConfig),
    /// A caller-supplied vertex → partition assignment.
    Explicit(Vec<u32>),
}

impl Default for Partitioner {
    fn default() -> Self {
        Partitioner::Metis(MetisConfig::default())
    }
}

/// Where the session's graph comes from.
#[derive(Clone, Copy)]
enum Source<'g> {
    /// Un-partitioned: the Runner partitions and distributes it lazily.
    Graph(&'g Graph),
    /// Pre-built distributed view (partitioning already decided).
    Dist(&'g DistGraph),
}

/// A builder-style execution session over one graph.
///
/// Construct with [`Runner::new`] (or [`Runner::from_dist`] for a
/// pre-partitioned graph), chain configuration, then call [`Runner::run`]
/// / [`Runner::run_gas`] / [`Runner::run_partition`] any number of
/// times — the distributed view is built once and reused, so comparing
/// engines never re-partitions.
///
/// ```
/// use graphhp::algorithms::Wcc;
/// use graphhp::engine::{EngineKind, Runner};
/// use graphhp::graph::generators;
///
/// let g = generators::connected(80, 40, 3);
/// let mut runner = Runner::new(&g).partitions(4).engine(EngineKind::GraphHP);
/// let r = runner.run(&Wcc);
/// assert!(r.values.iter().all(|&label| label == 0), "connected => one component");
/// assert!(r.metrics.global_iterations >= 1);
/// assert_eq!(r.trace.iterations(), r.metrics.global_iterations);
/// ```
pub struct Runner<'g> {
    source: Source<'g>,
    partitions: usize,
    partitioner: Partitioner,
    layout: GraphLayout,
    engine: EngineKind,
    cfg: EngineConfig,
    built: Option<DistGraph>,
}

impl<'g> Runner<'g> {
    /// Session over an un-partitioned graph. Defaults: 4 partitions,
    /// METIS-like partitioner, [`EngineKind::GraphHP`], default
    /// [`EngineConfig`].
    pub fn new(graph: &'g Graph) -> Self {
        Runner {
            source: Source::Graph(graph),
            partitions: 4,
            partitioner: Partitioner::default(),
            layout: GraphLayout::default(),
            engine: EngineKind::GraphHP,
            cfg: EngineConfig::default(),
            built: None,
        }
    }

    /// Session over a pre-built [`DistGraph`] (the partitioning decisions
    /// are already baked in; partition-related setters are ignored).
    pub fn from_dist(dg: &'g DistGraph) -> Self {
        Runner {
            source: Source::Dist(dg),
            partitions: dg.num_parts(),
            partitioner: Partitioner::default(),
            layout: dg.layout,
            engine: EngineKind::GraphHP,
            cfg: EngineConfig::default(),
            built: None,
        }
    }

    // ------------------------------------------------- builder setters

    /// Number of partitions (simulated workers).
    pub fn partitions(mut self, k: usize) -> Self {
        assert!(k > 0, "partitions must be > 0");
        self.partitions = k;
        self.built = None;
        self
    }

    /// Partitioning strategy.
    pub fn partitioner(mut self, p: Partitioner) -> Self {
        self.partitioner = p;
        self.built = None;
        self
    }

    /// Explicit vertex → partition assignment; sets the partition count
    /// to `max(assignment) + 1`.
    pub fn assignment(mut self, a: Vec<u32>) -> Self {
        self.partitions = a.iter().copied().max().map_or(1, |m| m as usize + 1);
        self.partitioner = Partitioner::Explicit(a);
        self.built = None;
        self
    }

    /// Physical memory layout of the distributed view: local-index
    /// naming policy plus edge-column compression (see [`GraphLayout`]).
    /// Purely internal — user-visible vertex ids and gathered results
    /// are identical across layouts. Ignored for [`Runner::from_dist`]
    /// sessions, where the layout is baked into the pre-built view.
    pub fn layout(mut self, l: GraphLayout) -> Self {
        self.layout = l;
        self.built = None;
        self
    }

    /// Shorthand for `.layout(GraphLayout::packed())`: degree-sorted
    /// vertex naming + compressed edge columns, the bandwidth-lean
    /// configuration.
    pub fn packed_layout(self) -> Self {
        self.layout(GraphLayout::packed())
    }

    /// Engine to dispatch to (default [`EngineKind::GraphHP`]).
    pub fn engine(mut self, kind: EngineKind) -> Self {
        self.engine = kind;
        self
    }

    /// Replace the whole [`EngineConfig`] at once.
    pub fn config(mut self, cfg: EngineConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Cap on global iterations / supersteps.
    pub fn max_iterations(mut self, n: u64) -> Self {
        self.cfg.limits.max_iterations = n;
        self
    }

    /// Cap on pseudo-supersteps per GraphHP local phase.
    pub fn max_pseudo_supersteps(mut self, n: u64) -> Self {
        self.cfg.limits.max_pseudo_supersteps = n;
        self
    }

    /// GraphHP: do boundary vertices participate in local phases?
    /// (Pins the knob, so an adaptive policy falls back to
    /// [`HybridPolicy::Static`] — see
    /// [`HybridPolicy::set_boundary_in_local_phase`].)
    pub fn boundary_in_local_phase(mut self, on: bool) -> Self {
        self.cfg.hybrid.set_boundary_in_local_phase(on);
        self
    }

    /// Asynchronous in-memory messaging inside (pseudo-)supersteps.
    pub fn async_local_messaging(mut self, on: bool) -> Self {
        self.cfg.hybrid.set_async_local_messaging(on);
        self
    }

    /// Replace the whole GraphHP hybrid policy — fixed knobs or the
    /// telemetry-driven adaptive scheduler.
    pub fn hybrid_policy(mut self, p: HybridPolicy) -> Self {
        self.cfg.hybrid = p;
        self
    }

    /// Shorthand for `.hybrid_policy(HybridPolicy::adaptive())`: drive
    /// the local-phase schedule per partition from the run's own
    /// telemetry (see [`HybridPolicy::Adaptive`]).
    pub fn adaptive_policy(mut self) -> Self {
        self.cfg.hybrid = HybridPolicy::adaptive();
        self
    }

    /// Telemetry-driven online repartitioning: at each barrier the
    /// engine folds the superstep's trace through the deterministic
    /// [`super::MigrationPlanner`] and may migrate vertices to a new
    /// routing epoch (see [`RepartitionConfig`]). Off by default; the
    /// async GraphLab engine has no barriers and ignores it.
    pub fn repartition(mut self, rc: RepartitionConfig) -> Self {
        self.cfg.repartition = Some(rc);
        self
    }

    /// Simulated cluster cost model.
    pub fn net(mut self, net: NetSimConfig) -> Self {
        self.cfg.net = net;
        self
    }

    /// GraphLab comparator cost constants.
    pub fn gas_cost(mut self, c: GasCost) -> Self {
        self.cfg.gas = c;
        self
    }

    /// Worker execution mode. The default is
    /// `Parallelism::Threads(available_parallelism)`; sequential and
    /// threaded runs are bit-for-bit identical (see [`Parallelism`]),
    /// only wall-clock changes.
    pub fn parallelism(mut self, p: Parallelism) -> Self {
        self.cfg.parallelism = p;
        self
    }

    /// Shorthand for `.parallelism(Parallelism::Threads(n))`.
    pub fn threads(mut self, n: usize) -> Self {
        assert!(n > 0, "threads must be > 0 (use Parallelism::Sequential)");
        self.cfg.parallelism = Parallelism::Threads(n);
        self
    }

    /// Shorthand for `.parallelism(Parallelism::WorkStealing(n))` — the
    /// opt-in intra-sweep work-stealing mode (run-to-run deterministic;
    /// see [`Parallelism::WorkStealing`] for the equivalence contract).
    pub fn steal(mut self, n: usize) -> Self {
        assert!(n > 0, "steal threads must be > 0 (use Parallelism::Sequential)");
        self.cfg.parallelism = Parallelism::WorkStealing(n);
        self
    }

    /// Seed for per-vertex randomness.
    pub fn seed(mut self, s: u64) -> Self {
        self.cfg.seed = s;
        self
    }

    /// Checkpoint every N global iterations/supersteps. Honored by every
    /// barrier engine (Hama, AM-Hama, GraphHP, Giraph++, GraphLab-sync);
    /// the barrier-less async GraphLab engine rejects it loudly (see
    /// [`super::FaultPolicy::checkpoint_interval`]).
    pub fn checkpoint_interval(mut self, n: Option<u64>) -> Self {
        self.cfg.fault.checkpoint_interval = n;
        self
    }

    /// Directory for persisted checkpoints.
    pub fn checkpoint_dir(mut self, dir: impl Into<std::path::PathBuf>) -> Self {
        self.cfg.fault.checkpoint_dir = Some(dir.into());
        self
    }

    /// Deterministic fault injection at the given global iteration.
    pub fn inject_failure_at(mut self, iteration: Option<u64>) -> Self {
        self.cfg.fault.inject_failure_at = iteration;
        self
    }

    /// How many checkpoint files to retain on disk (`None` = unbounded).
    /// The default keeps the newest 4 — see
    /// [`super::FaultPolicy::checkpoint_retain`].
    pub fn checkpoint_retain(mut self, keep: Option<usize>) -> Self {
        self.cfg.fault.checkpoint_retain = keep;
        self
    }

    /// Bounded-retry recovery policy: how many rollbacks a run may spend
    /// before a further loss event surfaces as a structured error, plus
    /// the post-rollback checkpoint backoff (see
    /// [`super::RecoveryPolicy`]).
    pub fn recovery(mut self, policy: super::RecoveryPolicy) -> Self {
        self.cfg.fault.recovery = policy;
        self
    }

    /// Seeded deterministic chaos injection on the barrier delivery path
    /// (see [`super::ChaosPolicy`]). Without
    /// [`Runner::checkpoint_interval`] set, any loss event fails loudly
    /// rather than converge on partial state — pair lossy schedules with
    /// a checkpoint interval so the engine rolls back and replays, or
    /// use [`Runner::try_run`] to observe the failure as an `Err`.
    pub fn chaos(mut self, policy: super::ChaosPolicy) -> Self {
        self.cfg.chaos = Some(policy);
        self
    }

    // ---------------------------------------------------------- access

    /// The session's engine kind.
    pub fn engine_kind(&self) -> EngineKind {
        self.engine
    }

    /// The session's engine configuration.
    pub fn cfg(&self) -> &EngineConfig {
        &self.cfg
    }

    /// The distributed view this session executes over, building it on
    /// first use (partition + distribute) and caching it for every
    /// subsequent run.
    pub fn dist(&mut self) -> &DistGraph {
        match self.source {
            Source::Dist(dg) => dg,
            Source::Graph(g) => {
                if self.built.is_none() {
                    let assignment = match &self.partitioner {
                        Partitioner::Hash => hash_partition(g, self.partitions),
                        Partitioner::Range => range_partition(g, self.partitions),
                        Partitioner::Metis(mc) => metis_partition(g, self.partitions, mc),
                        Partitioner::Explicit(a) => {
                            assert_eq!(
                                a.len(),
                                g.num_vertices(),
                                "explicit assignment length != vertex count"
                            );
                            // an explicit assignment dictates the minimum
                            // worker count; grow a stale .partitions(k)
                            // rather than panic in DistGraph::new
                            let needed =
                                a.iter().copied().max().map_or(1, |m| m as usize + 1);
                            if needed > self.partitions {
                                self.partitions = needed;
                            }
                            a.clone()
                        }
                    };
                    self.built =
                        Some(DistGraph::with_layout(g, &assignment, self.partitions, self.layout));
                }
                self.built.as_ref().expect("just built")
            }
        }
    }

    // ------------------------------------------------------------ runs

    /// Run a vertex-centric program on the session's engine.
    ///
    /// Dispatches Hama / AM-Hama / GraphHP directly and wraps the
    /// program in [`VertexSweep`] for Giraph++. Panics for the GraphLab
    /// kinds — those are pull-based; express the program as a
    /// [`GasProgram`] and call [`Runner::run_gas`].
    pub fn run<P: VertexProgram>(&mut self, program: &P) -> RunResult<P::V> {
        self.run_on(self.engine, program)
    }

    /// [`Runner::run`] with an explicit engine kind (the session default
    /// is ignored for this call).
    pub fn run_on<P: VertexProgram>(&mut self, kind: EngineKind, program: &P) -> RunResult<P::V> {
        let cfg = self.cfg.clone();
        let dg = self.dist();
        match kind {
            EngineKind::Hama => super::hama::run_hama(program, dg, &cfg),
            EngineKind::AmHama => super::am_hama::run_am_hama(program, dg, &cfg),
            EngineKind::GraphHP => super::graphhp::run_graphhp(program, dg, &cfg),
            EngineKind::GiraphPP => {
                run_giraphpp(&VertexSweep { program, seed: cfg.seed }, dg, &cfg)
            }
            EngineKind::GraphLabSync | EngineKind::GraphLabAsync => panic!(
                "{kind} is pull-based: express the program as a GasProgram and \
                 call Runner::run_gas"
            ),
        }
    }

    /// Run a pull-based (GAS) program on the session's engine, which
    /// must be one of the GraphLab kinds. Panics otherwise — the
    /// push-based engines take a [`VertexProgram`] via [`Runner::run`].
    pub fn run_gas<P: GasProgram>(&mut self, program: &P) -> RunResult<P::V> {
        self.run_gas_on(self.engine, program)
    }

    /// [`Runner::run_gas`] with an explicit engine kind.
    pub fn run_gas_on<P: GasProgram>(
        &mut self,
        kind: EngineKind,
        program: &P,
    ) -> RunResult<P::V> {
        let cfg = self.cfg.clone();
        let dg = self.dist();
        match kind {
            EngineKind::GraphLabSync => run_graphlab_sync(program, dg, &cfg),
            EngineKind::GraphLabAsync => run_graphlab_async(program, dg, &cfg),
            other => panic!(
                "{other} is push-based: GAS programs run on the GraphLab kinds; \
                 use Runner::run with a VertexProgram instead"
            ),
        }
    }

    /// [`Runner::run`], but a loud engine failure (e.g. a chaos loss
    /// event on an engine with no checkpoint to roll back to, or an
    /// exhausted [`super::RecoveryPolicy`] retry budget) is caught and
    /// returned as `Err` carrying the panic message, instead of
    /// unwinding through the caller. Used by the chaos stress suite to
    /// assert that lossy schedules *fail* rather than converge wrong.
    ///
    /// On `Err` the session's cached [`DistGraph`] is dropped: the
    /// unwound engine may have been interrupted mid-run, so the next
    /// call rebuilds the distributed view from the source graph rather
    /// than trusting state a failed run executed over.
    pub fn try_run<P: VertexProgram>(&mut self, program: &P) -> Result<RunResult<P::V>, String> {
        let kind = self.engine;
        let r = catch_run(std::panic::AssertUnwindSafe(|| self.run_on(kind, program)));
        if r.is_err() {
            self.built = None;
        }
        r
    }

    /// [`Runner::run_gas`] with the same loud-failure-to-`Err` contract
    /// (and cached-view invalidation) as [`Runner::try_run`].
    pub fn try_run_gas<P: GasProgram>(&mut self, program: &P) -> Result<RunResult<P::V>, String> {
        let kind = self.engine;
        let r = catch_run(std::panic::AssertUnwindSafe(|| self.run_gas_on(kind, program)));
        if r.is_err() {
            self.built = None;
        }
        r
    }

    /// Run a graph-centric (Giraph++-style) partition program.
    pub fn run_partition<PP: PartitionProgram>(&mut self, program: &PP) -> RunResult<PP::V> {
        let cfg = self.cfg.clone();
        let dg = self.dist();
        run_giraphpp(program, dg, &cfg)
    }

    /// Run the same program on several engines over the same partitioned
    /// graph — the shape of every fig/table bench. Kinds must be
    /// vertex-centric (see [`Runner::run`]).
    pub fn compare<P: VertexProgram>(
        &mut self,
        kinds: &[EngineKind],
        program: &P,
    ) -> Vec<(EngineKind, RunResult<P::V>)> {
        kinds.iter().map(|&k| (k, self.run_on(k, program))).collect()
    }
}

/// Run `f`, converting a panic into `Err(message)`. Engine panics carry
/// `String` or `&str` payloads (all chaos failures are `panic!("{..}")`
/// with a `"chaos: "` prefix); anything else is reported generically.
fn catch_run<T>(f: impl FnOnce() -> T + std::panic::UnwindSafe) -> Result<T, String> {
    std::panic::catch_unwind(f).map_err(|payload| {
        if let Some(s) = payload.downcast_ref::<String>() {
            s.clone()
        } else if let Some(s) = payload.downcast_ref::<&str>() {
            (*s).to_string()
        } else {
            "engine panicked with a non-string payload".to_string()
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{IncrementalPageRank, Wcc};
    use crate::engine::{graphhp, hama};
    use crate::graph::generators;
    use crate::partition::hash_partition;

    #[test]
    fn runner_matches_direct_engine_call() {
        let g = generators::connected(200, 80, 11);
        let mut runner = Runner::new(&g).partitions(4).engine(EngineKind::Hama);
        let via_runner = runner.run(&Wcc);
        let direct = hama::run_hama(&Wcc, runner.dist(), &EngineConfig::default());
        assert_eq!(via_runner.values, direct.values);
        assert_eq!(
            via_runner.metrics.global_iterations,
            direct.metrics.global_iterations
        );
    }

    #[test]
    fn dist_is_built_once_and_reused() {
        let g = generators::connected(150, 60, 7);
        let mut runner = Runner::new(&g).partitions(3);
        let cut1 = runner.dist().edge_cut();
        let _ = runner.run_on(EngineKind::Hama, &Wcc);
        let _ = runner.run_on(EngineKind::GraphHP, &Wcc);
        assert_eq!(runner.dist().edge_cut(), cut1);
    }

    #[test]
    fn explicit_assignment_respected() {
        let g = generators::erdos_renyi(10, 20, 1);
        let a = vec![0, 1, 2, 0, 1, 2, 0, 1, 2, 0];
        let mut runner = Runner::new(&g).assignment(a.clone());
        let dg = runner.dist();
        assert_eq!(dg.num_parts(), 3);
        for (v, &(p, _)) in dg.routing.location.iter().enumerate() {
            assert_eq!(p, a[v], "vertex {v}");
        }
    }

    #[test]
    fn from_dist_uses_the_given_view() {
        let g = generators::connected(100, 40, 3);
        let a = hash_partition(&g, 5);
        let dg = DistGraph::new(&g, &a, 5);
        let mut runner = Runner::from_dist(&dg).engine(EngineKind::GraphHP);
        let r = runner.run(&Wcc);
        let direct = graphhp::run_graphhp(&Wcc, &dg, &EngineConfig::default());
        assert_eq!(r.values, direct.values);
    }

    #[test]
    fn compare_covers_all_vertex_centric_kinds() {
        let g = generators::connected(120, 50, 5);
        let mut runner = Runner::new(&g).partitions(3);
        let results =
            runner.compare(&EngineKind::VERTEX_CENTRIC, &IncrementalPageRank { tolerance: 1e-6 });
        assert_eq!(results.len(), 4);
        let (_, base) = &results[0];
        for (kind, r) in &results {
            assert_eq!(r.values.len(), g.num_vertices());
            for (i, (x, y)) in base.values.iter().zip(&r.values).enumerate() {
                assert!((x - y).abs() < 1e-4, "{kind} v{i}: {x} vs {y}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "pull-based")]
    fn vertex_program_on_graphlab_kind_panics() {
        let g = generators::erdos_renyi(10, 20, 1);
        let _ = Runner::new(&g).partitions(2).engine(EngineKind::GraphLabSync).run(&Wcc);
    }

    #[test]
    #[should_panic(expected = "push-based")]
    fn gas_program_on_push_kind_panics() {
        let g = generators::erdos_renyi(10, 20, 1);
        // default session engine is GraphHP — a GAS program must not
        // silently fall back to GraphLabSync
        let _ = Runner::new(&g)
            .partitions(2)
            .run_gas(&crate::algorithms::pagerank::GasPageRank { tolerance: 1e-4 });
    }

    #[test]
    fn parallelism_knob_sequential_and_threaded_agree() {
        let g = generators::connected(150, 60, 5);
        let seq = Runner::new(&g)
            .partitions(4)
            .engine(EngineKind::Hama)
            .parallelism(Parallelism::Sequential)
            .run(&Wcc);
        let par =
            Runner::new(&g).partitions(4).engine(EngineKind::Hama).threads(4).run(&Wcc);
        assert_eq!(seq.values, par.values);
        assert_eq!(seq.metrics.network_messages, par.metrics.network_messages);
        assert_eq!(seq.metrics.global_iterations, par.metrics.global_iterations);
    }

    #[test]
    fn builder_knobs_reach_the_config() {
        let g = generators::erdos_renyi(10, 20, 1);
        let runner = Runner::new(&g)
            .max_iterations(7)
            .boundary_in_local_phase(false)
            .seed(99)
            .checkpoint_interval(Some(2))
            .repartition(RepartitionConfig { interval: 3, max_moves: 10 });
        assert_eq!(runner.cfg().limits.max_iterations, 7);
        let rc = runner.cfg().repartition.as_ref().expect("repartition set");
        assert_eq!((rc.interval, rc.max_moves), (3, 10));
        assert!(matches!(
            runner.cfg().hybrid,
            HybridPolicy::Static { boundary_in_local_phase: false, .. }
        ));
        assert_eq!(runner.cfg().seed, 99);
        assert_eq!(runner.cfg().fault.checkpoint_interval, Some(2));
    }

    #[test]
    fn try_run_ok_matches_run_and_lossy_chaos_surfaces_as_err() {
        let g = generators::connected(100, 40, 3);
        let mut runner = Runner::new(&g).partitions(3).engine(EngineKind::Hama);
        let ok = runner.try_run(&Wcc).expect("clean run succeeds");
        let direct = runner.run(&Wcc);
        assert_eq!(ok.values, direct.values);
        assert!(ok.chaos.is_none(), "no chaos policy => no trace");

        // certain loss on a checkpoint-less engine must surface as Err,
        // not unwind through the caller or converge on partial state
        let mut lossy = Runner::new(&g).partitions(3).engine(EngineKind::Hama).chaos(
            crate::engine::ChaosPolicy {
                seed: 1,
                schedule: crate::engine::ChaosSchedule {
                    drop_prob: 1.0,
                    ..Default::default()
                },
            },
        );
        let err = lossy.try_run(&Wcc).expect_err("loss without checkpoints must fail");
        assert!(err.starts_with("chaos:"), "unexpected message: {err}");
    }

    #[test]
    fn chaos_and_retention_setters_reach_the_config() {
        let g = generators::erdos_renyi(10, 20, 1);
        let runner = Runner::new(&g)
            .chaos(crate::engine::ChaosPolicy::benign(42))
            .checkpoint_retain(Some(9));
        assert_eq!(runner.cfg().chaos.as_ref().expect("chaos set").seed, 42);
        assert_eq!(runner.cfg().fault.checkpoint_retain, Some(9));
        let runner = Runner::new(&g).checkpoint_retain(None);
        assert_eq!(runner.cfg().fault.checkpoint_retain, None);
        let runner = Runner::new(&g).recovery(crate::engine::RecoveryPolicy {
            max_recoveries: 3,
            backoff_barriers: 1,
        });
        assert_eq!(runner.cfg().fault.recovery.max_recoveries, 3);
        assert_eq!(runner.cfg().fault.recovery.backoff_barriers, 1);
    }

    #[test]
    fn failed_try_run_drops_and_rebuilds_the_cached_view() {
        let g = generators::connected(100, 40, 3);
        let mut runner = Runner::new(&g).partitions(3).engine(EngineKind::Hama).chaos(
            crate::engine::ChaosPolicy {
                seed: 1,
                schedule: crate::engine::ChaosSchedule {
                    drop_prob: 1.0,
                    ..Default::default()
                },
            },
        );
        let cut = runner.dist().edge_cut();
        assert!(runner.built.is_some(), "view cached after dist()");
        let _ = runner.try_run(&Wcc).expect_err("loss without checkpoints must fail");
        assert!(runner.built.is_none(), "failed run must drop the cached view");
        // the rebuild is deterministic, so the session stays usable
        assert_eq!(runner.dist().edge_cut(), cut);
    }

    #[test]
    fn adaptive_policy_setter_and_pinning_fallback() {
        let g = generators::erdos_renyi(10, 20, 1);
        let runner = Runner::new(&g).adaptive_policy();
        assert!(runner.cfg().hybrid.is_adaptive());
        // pinning a static knob falls back to Static, keeping the
        // async-messaging setting
        let runner = Runner::new(&g)
            .adaptive_policy()
            .async_local_messaging(false)
            .boundary_in_local_phase(true);
        assert!(matches!(
            runner.cfg().hybrid,
            HybridPolicy::Static {
                boundary_in_local_phase: true,
                async_local_messaging: false
            }
        ));
    }

    #[test]
    fn adaptive_runner_run_matches_static_on_confluent_program() {
        let g = generators::connected(180, 70, 13);
        let mut stat = Runner::new(&g).partitions(4).engine(EngineKind::GraphHP);
        let s = stat.run(&Wcc);
        let adp = Runner::from_dist(stat.dist())
            .engine(EngineKind::GraphHP)
            .adaptive_policy()
            .run(&Wcc);
        assert_eq!(s.values, adp.values);
    }
}
