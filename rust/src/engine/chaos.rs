//! Deterministic fault injection for the barrier delivery path.
//!
//! The simulated cluster is polite by default: `netsim.rs` *prices* the
//! network but never misbehaves, and [`super::FaultPolicy::inject_failure_at`]
//! kills exactly one worker at a clean iteration boundary. This module
//! makes the transport hostile — and keeps every run bit-for-bit
//! reproducible.
//!
//! # Model
//!
//! A [`ChaosPolicy`] (a seed plus a [`ChaosSchedule`]) is attached to
//! [`super::EngineConfig::chaos`]. The engine builds one
//! [`ChaosController`] per run and hands it to the shared barrier fold
//! ([`super::worker::close_superstep`]), which consults it **between
//! `Outbox::seal` and inbox push**: sender-side combining has already
//! run, receiver-side combining has not, so injected events act on
//! sealed per-destination batches exactly like a real transport acting
//! on wire packets — combiner semantics are never violated.
//!
//! Every sealed batch (one sender partition → one destination partition,
//! one barrier) gets a monotone sequence number and a verdict drawn from
//! a per-barrier RNG stream (`Rng::new(seed).derive(superstep)`):
//!
//! - **benign events** — `Duplicate` (the receiver discards the second
//!   copy by sequence number; delivered once) and `Reorder` (the
//!   receiver reassembles the batch into its canonical
//!   `(dest_local, src)` order before inbox push, which the sealed
//!   batch already carries — delivery is order-insensitive by
//!   construction). These are recorded in the trace and must not change
//!   the fixpoint.
//! - **loss events** — `Drop` (batch destroyed), `Delay` (batch held
//!   past its barrier), `SplitHold` (batch held by an active network
//!   partition window), `Kill` (worker killed at the barrier). A BSP
//!   barrier cannot complete while acknowledged mail is missing, so the
//!   transport detects every loss event *at the barrier it corrupts*
//!   (sequence-number gap) and raises a pending-recovery flag. The
//!   engine must then either roll every partition back to the last
//!   checkpoint (any barrier engine with `checkpoint_interval` set —
//!   the shared rollback lives in `engine/recovery.rs`) or fail loudly
//!   (checkpointing disabled) — never continue on partially-delivered
//!   state. `MigrationKill` extends the kill family into migration
//!   windows: the worker dies between `MigrationPlanner::plan` and
//!   `apply_migration`, the planned epoch is abandoned, and recovery
//!   replays the checkpointed plan trajectory exactly.
//!   Held mail is **never delivered late**: the rolled-back timeline
//!   regenerates it, which is what keeps recovery bit-identical to the
//!   clean run.
//!
//! # Determinism contract
//!
//! All verdicts are drawn on the engine thread, during the barrier
//! fold, in partition order — so `Parallelism::Sequential` and
//! `Parallelism::Threads(n)` consume the RNG identically and the same
//! seed always yields the same [`ChaosTrace`] (asserted by
//! `tests/chaos_suite.rs`). Scheduling is keyed on the **monotone
//! barrier counter** (`RunTrace::steps.len()`), which keeps advancing
//! across rollbacks: a replayed iteration draws a *fresh* RNG stream
//! and a consumed kill never re-fires, so recovery always makes
//! progress. detlint rule R2 applies here: no wall-clock, ever — the
//! only entropy source is the seeded [`Rng`].
//!
//! `max_loss_events` (default 64) bounds the total number of loss
//! verdicts per run, so even a `drop_prob = 1.0` schedule with an
//! unbounded window terminates: once the budget is spent the transport
//! turns honest and the final replay runs clean from the last
//! checkpoint.

use crate::util::Rng;

/// Sentinel partition id for events that are not tied to a single
/// sender/receiver pair (kills, heals). Serialized as `null`.
pub const NO_PART: u32 = u32::MAX;

/// What the transport did to one sealed batch (or to a worker).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChaosEventKind {
    /// Batch destroyed in flight (loss).
    Drop,
    /// Batch held past its barrier; discarded on rollback (loss).
    Delay,
    /// Batch transmitted twice; receiver deduplicates by sequence
    /// number, so exactly one copy is delivered (benign).
    Duplicate,
    /// Batch permuted in flight; receiver reassembles the canonical
    /// `(dest_local, src)` order before inbox push (benign).
    Reorder,
    /// Batch held by an active network-partition window (loss).
    SplitHold,
    /// A network-partition window closed.
    Heal,
    /// Worker killed at the barrier (loss; generalizes
    /// `inject_failure_at` to repeated failures).
    Kill,
    /// Worker killed inside a migration window — between
    /// `MigrationPlanner::plan` returning a plan and `apply_migration`
    /// (loss; the planned epoch is abandoned and recovery replays the
    /// checkpointed migration trajectory).
    MigrationKill,
    /// The engine rolled back to a checkpoint in response to a loss
    /// event.
    Recover,
}

impl ChaosEventKind {
    /// Stable lowercase name used in the JSON trace.
    pub fn name(self) -> &'static str {
        match self {
            ChaosEventKind::Drop => "drop",
            ChaosEventKind::Delay => "delay",
            ChaosEventKind::Duplicate => "duplicate",
            ChaosEventKind::Reorder => "reorder",
            ChaosEventKind::SplitHold => "split_hold",
            ChaosEventKind::Heal => "heal",
            ChaosEventKind::Kill => "kill",
            ChaosEventKind::MigrationKill => "migration_kill",
            ChaosEventKind::Recover => "recover",
        }
    }

    /// Loss events corrupt the barrier and demand recovery; benign
    /// events must leave the fixpoint untouched.
    pub fn is_loss(self) -> bool {
        matches!(
            self,
            ChaosEventKind::Drop
                | ChaosEventKind::Delay
                | ChaosEventKind::SplitHold
                | ChaosEventKind::Kill
                | ChaosEventKind::MigrationKill
        )
    }
}

/// One injected event, recorded for replay.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ChaosEvent {
    /// Monotone barrier counter at injection time (counts barriers
    /// actually run, including replayed iterations — see
    /// [`super::StepTrace::iteration`]).
    pub superstep: u64,
    /// What happened.
    pub kind: ChaosEventKind,
    /// Sender partition, or [`NO_PART`] for kills/heals/recoveries.
    pub from: u32,
    /// Destination partition, or [`NO_PART`].
    pub to: u32,
    /// Messages in the affected batch (0 for kills/heals/recoveries).
    pub messages: u64,
    /// Monotone batch sequence number (0 for kills/heals/recoveries —
    /// they are not tied to a batch).
    pub batch: u64,
}

/// A network-partition window: from barrier `from` (inclusive) to
/// barrier `heal_at` (exclusive), every batch crossing between `group`
/// and its complement is held.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct NetSplit {
    /// First monotone barrier the split is active at.
    pub from: u64,
    /// Monotone barrier the split heals at (exclusive); a `Heal` event
    /// is recorded once this barrier is reached.
    pub heal_at: u64,
    /// One side of the split (partition ids); the other side is the
    /// complement. Batches within a side are unaffected.
    pub group: Vec<u32>,
}

impl NetSplit {
    fn active_at(&self, s: u64) -> bool {
        self.from <= s && s < self.heal_at
    }

    fn severs(&self, from: u32, to: u32) -> bool {
        self.group.contains(&from) != self.group.contains(&to)
    }
}

/// What faults to inject, when, and between whom. All probabilities are
/// per sealed batch; an empty `senders`/`receivers` group means "all
/// partitions".
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosSchedule {
    /// Probability a batch is destroyed in flight.
    pub drop_prob: f64,
    /// Probability a batch is held past its barrier.
    pub delay_prob: f64,
    /// How many barriers a delayed batch would arrive late by
    /// (taxonomy only: synchronous recovery discards held mail and the
    /// rolled-back timeline regenerates it).
    pub delay_supersteps: u64,
    /// Probability a batch is transmitted twice.
    pub duplicate_prob: f64,
    /// Probability a batch is permuted in flight.
    pub reorder_prob: f64,
    /// First monotone barrier events may fire at (inclusive).
    pub from_superstep: u64,
    /// Last monotone barrier events may fire at (exclusive).
    pub until_superstep: u64,
    /// Restrict probabilistic events to batches *from* these partitions
    /// (empty = all).
    pub senders: Vec<u32>,
    /// Restrict probabilistic events to batches *to* these partitions
    /// (empty = all).
    pub receivers: Vec<u32>,
    /// Monotone barriers at which a worker is killed (each entry fires
    /// once; generalizes `inject_failure_at` to repeated failures).
    pub kill_at: Vec<u64>,
    /// Monotone barriers at whose *migration window* a worker is killed:
    /// the kill fires between `MigrationPlanner::plan` returning a plan
    /// and `apply_migration`, at the first open window at or after the
    /// scheduled barrier (each entry fires once). Vacuous unless online
    /// repartitioning is enabled and the planner emits a plan.
    pub migration_kill_at: Vec<u64>,
    /// Partition-then-heal windows.
    pub splits: Vec<NetSplit>,
    /// Hard cap on loss events per run — the termination backstop that
    /// keeps even `drop_prob = 1.0` schedules convergent.
    pub max_loss_events: u64,
}

impl Default for ChaosSchedule {
    fn default() -> Self {
        ChaosSchedule {
            drop_prob: 0.0,
            delay_prob: 0.0,
            delay_supersteps: 1,
            duplicate_prob: 0.0,
            reorder_prob: 0.0,
            from_superstep: 0,
            until_superstep: u64::MAX,
            senders: Vec::new(),
            receivers: Vec::new(),
            kill_at: Vec::new(),
            migration_kill_at: Vec::new(),
            splits: Vec::new(),
            max_loss_events: 64,
        }
    }
}

/// Seed + schedule: everything needed to replay a chaos run.
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosPolicy {
    /// Root of the per-barrier RNG streams.
    pub seed: u64,
    /// What to inject.
    pub schedule: ChaosSchedule,
}

impl ChaosPolicy {
    /// A benign-only schedule: duplicates and reorders, no loss. Safe
    /// on every engine (with or without checkpoints) — the fixpoint
    /// must not move.
    pub fn benign(seed: u64) -> Self {
        ChaosPolicy {
            seed,
            schedule: ChaosSchedule {
                duplicate_prob: 0.3,
                reorder_prob: 0.3,
                ..ChaosSchedule::default()
            },
        }
    }

    /// A lossy stress schedule: drops, delays, duplicates, reorders and
    /// one mid-run kill inside a bounded window. Needs checkpointing to
    /// survive.
    pub fn stress(seed: u64) -> Self {
        ChaosPolicy {
            seed,
            schedule: ChaosSchedule {
                drop_prob: 0.10,
                delay_prob: 0.05,
                duplicate_prob: 0.10,
                reorder_prob: 0.10,
                from_superstep: 1,
                until_superstep: 12,
                kill_at: vec![5],
                max_loss_events: 16,
                ..ChaosSchedule::default()
            },
        }
    }
}

/// Every injected event of one run, in injection order, keyed by the
/// seed that reproduces it.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ChaosTrace {
    /// The policy seed (replay key).
    pub seed: u64,
    /// Injected events in injection order (nondecreasing `superstep`).
    pub events: Vec<ChaosEvent>,
}

impl ChaosTrace {
    /// Events of a given kind.
    pub fn count(&self, kind: ChaosEventKind) -> u64 {
        self.events.iter().filter(|e| e.kind == kind).count() as u64
    }

    /// Loss events injected (each one forced a recovery or a loud
    /// failure).
    pub fn loss_events(&self) -> u64 {
        self.events.iter().filter(|e| e.kind.is_loss()).count() as u64
    }

    /// Serialize as JSON (hand-rolled — the offline vendor set has no
    /// serde). Schema: `{"seed": n, "events": [{"superstep": n,
    /// "kind": "drop", "from": 0|null, "to": 1|null, "messages": n,
    /// "batch": n}]}`.
    pub fn to_json(&self) -> String {
        fn part(p: u32) -> String {
            if p == NO_PART { "null".to_string() } else { p.to_string() }
        }
        let mut out = String::with_capacity(64 + self.events.len() * 96);
        out.push_str(&format!("{{\n  \"seed\": {},\n  \"events\": [", self.seed));
        for (i, e) in self.events.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n    {{\"superstep\": {}, \"kind\": \"{}\", \"from\": {}, \"to\": {}, \
                 \"messages\": {}, \"batch\": {}}}",
                e.superstep,
                e.kind.name(),
                part(e.from),
                part(e.to),
                e.messages,
                e.batch
            ));
        }
        if self.events.is_empty() {
            out.push_str("]\n}\n");
        } else {
            out.push_str("\n  ]\n}\n");
        }
        out
    }
}

/// Per-run fault-injection state machine. Built by the engine from
/// [`super::EngineConfig::chaos`], consulted by the barrier fold for a
/// per-batch verdict, polled by the engine after each barrier for a
/// pending loss that demands recovery.
#[derive(Clone, Debug)]
pub struct ChaosController {
    seed: u64,
    sched: ChaosSchedule,
    /// Current barrier's RNG stream (`Rng::new(seed).derive(superstep)`).
    rng: Rng,
    /// Monotone barrier counter of the barrier being folded.
    superstep: u64,
    /// Next unconsumed entry of the (sorted) kill list.
    kill_cursor: usize,
    /// Next unconsumed entry of the (sorted) migration-kill list.
    mig_kill_cursor: usize,
    /// Which splits have had their `Heal` event recorded.
    healed: Vec<bool>,
    /// Loss verdicts issued so far (bounded by `max_loss_events`).
    loss_events: u64,
    /// Monotone batch sequence counter.
    batch_seq: u64,
    /// Human-readable reason of the first unhandled loss event of the
    /// current barrier; the engine must take it and recover (or die).
    pending: Option<String>,
    trace: ChaosTrace,
}

impl ChaosController {
    /// Build a controller for one run.
    pub fn new(policy: &ChaosPolicy) -> Self {
        let mut sched = policy.schedule.clone();
        sched.kill_at.sort_unstable();
        sched.migration_kill_at.sort_unstable();
        let healed = vec![false; sched.splits.len()];
        ChaosController {
            seed: policy.seed,
            rng: Rng::new(policy.seed),
            sched,
            superstep: 0,
            kill_cursor: 0,
            mig_kill_cursor: 0,
            healed,
            loss_events: 0,
            batch_seq: 0,
            pending: None,
            trace: ChaosTrace { seed: policy.seed, events: Vec::new() },
        }
    }

    /// Enter the barrier with monotone counter `superstep`: derive the
    /// barrier's RNG stream and record `Heal` events for any split
    /// whose window just closed.
    pub(crate) fn begin_barrier(&mut self, superstep: u64) {
        self.superstep = superstep;
        self.rng = Rng::new(self.seed).derive(superstep);
        for i in 0..self.sched.splits.len() {
            if !self.healed[i] && self.sched.splits[i].heal_at <= superstep {
                self.healed[i] = true;
                self.record(ChaosEventKind::Heal, NO_PART, NO_PART, 0, 0);
            }
        }
    }

    /// Verdict for one sealed batch (`messages` combined messages from
    /// partition `from` to partition `to`): `true` = deliver now,
    /// `false` = the batch is lost (destroyed or held) and a recovery
    /// is pending. Benign verdicts (duplicate/reorder) are recorded and
    /// still deliver exactly one canonical copy.
    pub(crate) fn judge(&mut self, from: u32, to: u32, messages: u64) -> bool {
        let seq = self.batch_seq;
        self.batch_seq += 1;
        let s = self.superstep;
        if !(self.sched.from_superstep <= s && s < self.sched.until_superstep) {
            return true;
        }
        // an active split severs the link unconditionally (no RNG)
        for i in 0..self.sched.splits.len() {
            let sp = &self.sched.splits[i];
            if sp.active_at(s) && sp.severs(from, to) && self.loss_budget_left() {
                self.lose(ChaosEventKind::SplitHold, from, to, messages, seq);
                return false;
            }
        }
        if !group_has(&self.sched.senders, from) || !group_has(&self.sched.receivers, to) {
            return true;
        }
        // fixed draw order per batch keeps the stream replayable
        if self.rng.chance(self.sched.drop_prob) {
            if self.loss_budget_left() {
                self.lose(ChaosEventKind::Drop, from, to, messages, seq);
                return false;
            }
        } else if self.rng.chance(self.sched.delay_prob) {
            if self.loss_budget_left() {
                self.lose(ChaosEventKind::Delay, from, to, messages, seq);
                return false;
            }
        } else if self.rng.chance(self.sched.duplicate_prob) {
            self.record(ChaosEventKind::Duplicate, from, to, messages, seq);
        } else if self.rng.chance(self.sched.reorder_prob) {
            self.record(ChaosEventKind::Reorder, from, to, messages, seq);
        }
        true
    }

    /// Leave the barrier: fire any kill scheduled at (or overtaken by)
    /// the current counter. Each kill entry fires exactly once.
    pub(crate) fn end_barrier(&mut self) {
        while self.kill_cursor < self.sched.kill_at.len()
            && self.sched.kill_at[self.kill_cursor] <= self.superstep
        {
            self.kill_cursor += 1;
            self.loss_events += 1;
            self.record(ChaosEventKind::Kill, NO_PART, NO_PART, 0, 0);
            let s = self.superstep;
            self.raise(format!("worker killed at barrier {s}"));
        }
    }

    /// Verdict for one open migration window (`moves` planned moves) at
    /// the current barrier: `true` = apply the plan, `false` = a worker
    /// was killed between plan and apply, the plan must be abandoned,
    /// and a recovery is pending. Each scheduled entry fires exactly
    /// once, at the first *open* window at or after its barrier —
    /// windows only open when the planner actually emits a plan, so an
    /// entry can fire later than scheduled (or never, without a
    /// planner). Recovery replays the checkpointed plan trajectory; the
    /// abandoned plan is re-derived identically from the same counters
    /// and applies cleanly on the retry.
    pub(crate) fn judge_migration(&mut self, moves: u64) -> bool {
        if self.mig_kill_cursor < self.sched.migration_kill_at.len()
            && self.sched.migration_kill_at[self.mig_kill_cursor] <= self.superstep
        {
            self.mig_kill_cursor += 1;
            self.loss_events += 1;
            self.record(ChaosEventKind::MigrationKill, NO_PART, NO_PART, 0, 0);
            let s = self.superstep;
            self.raise(format!(
                "worker killed in the migration window at barrier {s} \
                 ({moves} planned moves abandoned)"
            ));
            return false;
        }
        true
    }

    /// Take the pending loss reason, if any. The engine MUST respond:
    /// roll back to the latest checkpoint (recording the rollback via
    /// [`Self::note_recovery`]) or fail loudly. Continuing past a
    /// pending loss silently corrupts the fixpoint.
    pub(crate) fn take_pending(&mut self) -> Option<String> {
        self.pending.take()
    }

    /// Record that the engine rolled back to a checkpoint in response
    /// to a loss event.
    pub(crate) fn note_recovery(&mut self) {
        self.record(ChaosEventKind::Recover, NO_PART, NO_PART, 0, 0);
    }

    /// Finish the run and surrender the recorded trace.
    pub fn into_trace(self) -> ChaosTrace {
        super::invariants::check_chaos_trace(&self.trace);
        self.trace
    }

    fn loss_budget_left(&self) -> bool {
        self.loss_events < self.sched.max_loss_events
    }

    fn lose(&mut self, kind: ChaosEventKind, from: u32, to: u32, messages: u64, seq: u64) {
        self.loss_events += 1;
        self.record(kind, from, to, messages, seq);
        let s = self.superstep;
        let name = kind.name();
        self.raise(format!(
            "{name} of batch {seq} ({messages} messages, partition {from} -> {to}) \
             detected at barrier {s}"
        ));
    }

    fn raise(&mut self, reason: String) {
        if self.pending.is_none() {
            self.pending = Some(reason);
        }
    }

    fn record(&mut self, kind: ChaosEventKind, from: u32, to: u32, messages: u64, batch: u64) {
        self.trace.events.push(ChaosEvent {
            superstep: self.superstep,
            kind,
            from,
            to,
            messages,
            batch,
        });
    }
}

fn group_has(group: &[u32], p: u32) -> bool {
    group.is_empty() || group.contains(&p)
}

/// The loud-failure message for engines that hit a loss event with no
/// checkpoint to roll back to. Prefixed `chaos:` so tests can match it.
pub(crate) fn no_checkpoint_panic(engine: &str, reason: &str) -> String {
    format!(
        "chaos: {reason} — the {engine} engine has no checkpoint to roll back to; \
         refusing to converge to a silently wrong fixpoint \
         (enable checkpointing or remove the lossy chaos schedule)"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drive(policy: &ChaosPolicy, barriers: u64, parts: u32) -> (ChaosTrace, Vec<Option<String>>) {
        let mut ctl = ChaosController::new(policy);
        let mut pendings = Vec::new();
        for s in 0..barriers {
            ctl.begin_barrier(s);
            for from in 0..parts {
                for to in 0..parts {
                    if from != to {
                        ctl.judge(from, to, 10);
                    }
                }
            }
            ctl.end_barrier();
            pendings.push(ctl.take_pending());
        }
        (ctl.into_trace(), pendings)
    }

    #[test]
    fn same_seed_same_trace() {
        let p = ChaosPolicy::stress(42);
        let (a, _) = drive(&p, 20, 4);
        let (b, _) = drive(&p, 20, 4);
        assert_eq!(a, b);
        assert!(!a.events.is_empty(), "stress schedule injected nothing");
    }

    #[test]
    fn different_seeds_differ() {
        let (a, _) = drive(&ChaosPolicy::stress(1), 20, 4);
        let (b, _) = drive(&ChaosPolicy::stress(2), 20, 4);
        assert_ne!(a.events, b.events);
    }

    #[test]
    fn window_confines_events() {
        let mut p = ChaosPolicy::stress(7);
        p.schedule.from_superstep = 3;
        p.schedule.until_superstep = 6;
        p.schedule.kill_at.clear();
        p.schedule.drop_prob = 0.9;
        p.schedule.max_loss_events = 1000;
        let (t, _) = drive(&p, 20, 4);
        assert!(!t.events.is_empty());
        for e in &t.events {
            assert!((3..6).contains(&e.superstep), "event outside window: {e:?}");
        }
    }

    #[test]
    fn loss_raises_pending_and_benign_does_not() {
        let mut p = ChaosPolicy::benign(9);
        let (t, pendings) = drive(&p, 10, 3);
        assert!(t.count(ChaosEventKind::Duplicate) + t.count(ChaosEventKind::Reorder) > 0);
        assert_eq!(t.loss_events(), 0);
        assert!(pendings.iter().all(|x| x.is_none()));

        p.schedule.drop_prob = 1.0;
        p.schedule.max_loss_events = 3;
        let (t, pendings) = drive(&p, 10, 3);
        assert_eq!(t.count(ChaosEventKind::Drop), 3, "budget not honored: {t:?}");
        assert!(pendings.iter().filter(|x| x.is_some()).count() >= 1);
        let reason = pendings.iter().flatten().next().expect("pending reason");
        assert!(reason.contains("drop"), "{reason}");
    }

    #[test]
    fn kill_fires_once_per_entry() {
        let mut p = ChaosPolicy { seed: 5, schedule: ChaosSchedule::default() };
        p.schedule.kill_at = vec![2, 2, 5];
        let (t, pendings) = drive(&p, 10, 2);
        assert_eq!(t.count(ChaosEventKind::Kill), 3);
        assert!(pendings[2].is_some() && pendings[5].is_some());
        assert!(pendings[3].is_none() && pendings[6].is_none());
    }

    #[test]
    fn split_holds_cross_batches_then_heals() {
        let p = ChaosPolicy {
            seed: 11,
            schedule: ChaosSchedule {
                splits: vec![NetSplit { from: 1, heal_at: 3, group: vec![0] }],
                max_loss_events: 1000,
                ..ChaosSchedule::default()
            },
        };
        let (t, pendings) = drive(&p, 6, 3);
        // barriers 1 and 2: partition 0 <-> {1,2} severed both ways
        assert_eq!(t.count(ChaosEventKind::SplitHold), 2 * 4);
        assert_eq!(t.count(ChaosEventKind::Heal), 1);
        for e in &t.events {
            if e.kind == ChaosEventKind::SplitHold {
                assert!((e.from == 0) != (e.to == 0), "not a crossing batch: {e:?}");
                assert!((1..3).contains(&e.superstep));
            }
        }
        assert!(pendings[0].is_none() && pendings[3].is_none());
        assert!(pendings[1].is_some() && pendings[2].is_some());
    }

    #[test]
    fn group_restriction_filters_senders_and_receivers() {
        let p = ChaosPolicy {
            seed: 13,
            schedule: ChaosSchedule {
                drop_prob: 1.0,
                senders: vec![0],
                receivers: vec![2],
                max_loss_events: 1000,
                ..ChaosSchedule::default()
            },
        };
        let (t, _) = drive(&p, 5, 3);
        assert_eq!(t.count(ChaosEventKind::Drop), 5);
        for e in &t.events {
            assert_eq!((e.from, e.to), (0, 2), "event outside group: {e:?}");
        }
    }

    #[test]
    fn trace_json_is_balanced_and_complete() {
        let (t, _) = drive(&ChaosPolicy::stress(3), 20, 4);
        let j = t.to_json();
        assert!(j.contains("\"seed\": 3"), "{j}");
        for e in &t.events {
            assert!(j.contains(&format!("\"{}\"", e.kind.name())), "{j}");
        }
        assert!(j.contains("\"from\": null"), "kill should serialize null parts: {j}");
        for (open, close) in [('{', '}'), ('[', ']')] {
            assert_eq!(j.matches(open).count(), j.matches(close).count(), "{j}");
        }
    }

    #[test]
    fn empty_trace_serializes() {
        let t = ChaosTrace { seed: 0, events: Vec::new() };
        assert!(t.to_json().contains("\"events\": []"));
    }

    #[test]
    fn migration_kill_fires_once_at_first_open_window() {
        let mut ctl = ChaosController::new(&ChaosPolicy {
            seed: 2,
            schedule: ChaosSchedule { migration_kill_at: vec![3], ..ChaosSchedule::default() },
        });
        for s in 0..8 {
            ctl.begin_barrier(s);
            ctl.end_barrier();
            assert!(ctl.take_pending().is_none(), "barrier events leaked a pending");
            // windows only open at even barriers in this synthetic run
            if s % 2 == 0 {
                let applied = ctl.judge_migration(7);
                if s < 3 {
                    assert!(applied, "entry must wait for its barrier");
                    assert!(ctl.take_pending().is_none());
                } else if s == 4 {
                    assert!(!applied, "first open window at/after barrier 3 must kill");
                    let reason = ctl.take_pending().expect("migration kill raises a pending");
                    assert!(reason.contains("migration window"), "{reason}");
                    ctl.note_recovery();
                } else {
                    assert!(applied, "a consumed entry must never re-fire");
                    assert!(ctl.take_pending().is_none());
                }
            }
        }
        let t = ctl.into_trace();
        assert_eq!(t.count(ChaosEventKind::MigrationKill), 1);
        assert_eq!(t.count(ChaosEventKind::Recover), 1);
        assert_eq!(t.loss_events(), 1, "a migration kill is a loss event");
    }

    #[test]
    fn recovery_note_lands_in_trace() {
        let mut ctl = ChaosController::new(&ChaosPolicy {
            seed: 1,
            schedule: ChaosSchedule { kill_at: vec![0], ..ChaosSchedule::default() },
        });
        ctl.begin_barrier(0);
        ctl.end_barrier();
        assert!(ctl.take_pending().is_some());
        ctl.note_recovery();
        let t = ctl.into_trace();
        assert_eq!(t.count(ChaosEventKind::Recover), 1);
    }
}
