//! The shared checkpoint/rollback layer every barrier engine recovers
//! through (paper §5.3: master-coordinated checkpoint + all-worker
//! rollback).
//!
//! Through PR 9 only the hybrid engine could *recover* from an injected
//! loss event — Hama, AM-Hama, Giraph++ and GraphLab-sync refused loss
//! outright via `no_checkpoint_panic` (engine/chaos.rs). This module
//! extracts the machinery GraphHP used (snapshot at the iteration
//! boundary, rollback to the latest snapshot, bit-exact replay driven by
//! the monotone chaos counter, migration-trajectory restoration) into
//! one coordinator so `FaultPolicy::checkpoint_interval` means the same
//! thing on every engine with barriers:
//!
//! 1. **Checkpoint** — at each barrier whose iteration hits the
//!    configured interval, the engine snapshots its full resumable state
//!    (vertex values, halt flags, in-flight mail, frontier, applied
//!    migration plans — plus scheduler policy for GraphHP) and hands it
//!    to the coordinator (`RecoveryCoordinator::install`).
//!    Vertex-centric engines optionally persist the snapshot to
//!    `checkpoint_dir` (`persist_checkpoint`); GraphLab-sync checkpoints
//!    stay in memory because [`GasProgram`](super::graphlab::GasProgram)
//!    values carry no `Codec` bound.
//! 2. **Rollback** — when the chaos controller raises a pending loss
//!    event at a barrier (or inside a migration window), the engine calls
//!    `RecoveryCoordinator::rollback`: the coordinator charges the
//!    bounded retry budget and returns the latest snapshot; the engine
//!    rebuilds partition runtimes from it and replays the checkpointed
//!    migration trajectory (`replay_geometry`) so the routing geometry
//!    matches the snapshot exactly.
//! 3. **Replay** — the superstep counter rewinds but the chaos counter
//!    (`trace.steps.len()`) never does, so the replayed barriers draw
//!    fresh RNG streams and a consumed kill entry never re-fires: every
//!    recovery makes progress, and the replayed run converges to the
//!    bit-identical fixpoint the clean run reaches (the contract
//!    `tests/chaos_suite.rs` and `tests/migration_equivalence.rs`
//!    enforce).
//!
//! Without a checkpoint the panic path is unchanged: loss is refused
//! loudly rather than converging to a silently wrong fixpoint. The
//! async GraphLab engine has no barriers, hence no consistent snapshot
//! boundary, and stays documented out of scope — it rejects a configured
//! interval loudly instead of ignoring it (see `run_graphlab_async`).

use crate::graph::{DistGraph, MigrationPlan};
use crate::util::Codec;

use super::checkpoint::{prune_checkpoints, Checkpoint};
use super::metrics::Metrics;
use super::state::{Frontier, MsgStore, PartitionRuntime};
use super::FaultPolicy;

/// Bounded, deterministic recovery budget shared by all barrier engines.
///
/// Chaos recovery replays from the latest checkpoint; this policy bounds
/// how many times an engine may do so before surfacing a structured
/// error through [`Runner::try_run`](super::Runner::try_run) instead of
/// retrying forever, and optionally backs checkpointing off after a
/// rollback so a kill landing right on the checkpoint barrier cannot
/// re-checkpoint corrupt-adjacent state immediately.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RecoveryPolicy {
    /// Maximum rollbacks one run may take. The next loss event after the
    /// budget is spent panics with a `"chaos: recovery budget exhausted"`
    /// message (caught by `try_run` as an `Err`). The default (64)
    /// matches the default
    /// [`ChaosSchedule::max_loss_events`](super::ChaosSchedule::max_loss_events),
    /// so a default schedule can never exhaust it: every loss event
    /// charges at most one rollback.
    pub max_recoveries: u64,
    /// After a rollback to checkpoint iteration `c`, suppress new
    /// checkpoints until iteration `c + backoff_barriers`. Zero (the
    /// default) re-checkpoints on the normal interval; a positive value
    /// widens the replay window after each recovery, which is
    /// deterministic but trades replay work for fewer snapshot clones
    /// under sustained fault pressure.
    pub backoff_barriers: u64,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy { max_recoveries: 64, backoff_barriers: 0 }
    }
}

/// Per-run recovery state machine: owns the latest snapshot, charges the
/// retry budget, and applies checkpoint backoff. `S` is whatever the
/// engine can resume from — [`Checkpoint<V, M>`] for the vertex-centric
/// engines, [`GasSnapshot`] for GraphLab-sync.
pub(crate) struct RecoveryCoordinator<S> {
    policy: RecoveryPolicy,
    /// `(checkpoint iteration, snapshot)` — latest wins.
    last: Option<(u64, S)>,
    recoveries: u64,
    /// Checkpoints are suppressed below this iteration (backoff).
    resume_at: u64,
}

impl<S> RecoveryCoordinator<S> {
    pub(crate) fn new(policy: RecoveryPolicy) -> Self {
        RecoveryCoordinator { policy, last: None, recoveries: 0, resume_at: 0 }
    }

    /// Should the engine snapshot at this barrier? True on the
    /// configured interval, unless post-rollback backoff suppresses it.
    pub(crate) fn should_checkpoint(&self, fault: &FaultPolicy, iteration: u64) -> bool {
        fault.checkpoint_interval.is_some_and(|n| n > 0 && iteration % n == 0)
            && iteration >= self.resume_at
    }

    /// Install `snap` as the rollback target for every later loss event.
    pub(crate) fn install(&mut self, iteration: u64, snap: S, metrics: &mut Metrics) {
        self.last = Some((iteration, snap));
        metrics.checkpoints += 1;
    }

    /// The latest snapshot, if any (the legacy `inject_failure_at` drill
    /// reads this directly: injected-failure restarts are budget-exempt,
    /// only chaos-detected loss charges [`rollback`](Self::rollback)).
    pub(crate) fn last(&self) -> Option<&S> {
        self.last.as_ref().map(|(_, s)| s)
    }

    /// Charge one rollback against the budget and return the snapshot to
    /// resume from. Panics (structured, `try_run`-catchable) when no
    /// checkpoint exists or the budget is exhausted — never loops
    /// forever.
    pub(crate) fn rollback(
        &mut self,
        engine: &str,
        reason: &str,
        metrics: &mut Metrics,
    ) -> &S {
        let (at, snap) = match &self.last {
            Some(pair) => pair,
            None => panic!("{}", super::chaos::no_checkpoint_panic(engine, reason)),
        };
        if self.recoveries >= self.policy.max_recoveries {
            panic!(
                "chaos: recovery budget exhausted — the {engine} engine already rolled back \
                 {} times (RecoveryPolicy::max_recoveries = {}) and another loss event \
                 arrived ({reason}); surfacing a structured error instead of retrying forever \
                 (raise max_recoveries or tame the chaos schedule)",
                self.recoveries, self.policy.max_recoveries,
            );
        }
        self.recoveries += 1;
        metrics.recoveries += 1;
        self.resume_at = at + self.policy.backoff_barriers;
        snap
    }
}

/// Persist `ckpt` under the policy's checkpoint directory (when one is
/// configured) and apply the retention policy. Write errors are
/// deliberately swallowed — matching the pre-existing GraphHP behavior —
/// because the in-memory snapshot already guarantees recovery within
/// this run; the on-disk copy only serves post-mortem `load_latest`.
pub(crate) fn persist_checkpoint<V, M>(ckpt: &Checkpoint<V, M>, fault: &FaultPolicy)
where
    V: Codec + Clone,
    M: Codec + Clone,
{
    if let Some(dir) = &fault.checkpoint_dir {
        let _ = ckpt.save(dir);
        if let Some(keep) = fault.checkpoint_retain {
            let _ = prune_checkpoints(dir, keep);
        }
    }
}

/// Replay a checkpointed migration trajectory onto the pristine graph:
/// the snapshot's partition runtimes are only meaningful under the
/// routing geometry that existed when it was taken, so rollback rebuilds
/// that geometry by re-applying every checkpointed plan in order.
/// Returns `None` when no migrations had been applied (resume on the
/// caller's original `DistGraph`).
pub(crate) fn replay_geometry(base: &DistGraph, plans: &[MigrationPlan]) -> Option<Box<DistGraph>> {
    let mut rebuilt: Option<Box<DistGraph>> = None;
    for plan in plans {
        let cur: &DistGraph = rebuilt.as_deref().unwrap_or(base);
        rebuilt = Some(Box::new(cur.apply_migration(plan)));
    }
    rebuilt
}

/// Rebuild one partition's runtime verbatim from checkpoint column `p`:
/// values, halt flags, both message stores, and the frontier (in its
/// checkpointed schedule order, preserving drain determinism).
pub(crate) fn restore_runtime<V: Clone, M: Clone>(
    ckpt: &Checkpoint<V, M>,
    p: usize,
) -> PartitionRuntime<V, M> {
    let n = ckpt.values[p].len();
    let mut rt = PartitionRuntime::from_values(ckpt.values[p].clone());
    rt.halted = ckpt.halted[p].clone();
    rt.cur = MsgStore::restore(n, &ckpt.local_cur[p]);
    rt.nxt = MsgStore::restore(n, &ckpt.local_nxt[p]);
    rt.frontier = Frontier::restore(n, &ckpt.frontier[p]);
    rt
}

/// GraphLab-sync's in-memory snapshot. GAS vertex values carry no
/// [`Codec`] bound, so there is no on-disk format — the snapshot lives
/// only inside the run's [`RecoveryCoordinator`], which is exactly what
/// chaos recovery needs (a crashed *process* is out of scope for the
/// pull engine; a killed *worker* is not).
pub(crate) struct GasSnapshot<V> {
    /// The round the snapshot was taken at (resume point).
    pub(crate) round: u64,
    /// Vertex values by global id.
    pub(crate) values: Vec<V>,
    /// Scheduled-vertex frontier, in schedule order.
    pub(crate) frontier: Vec<u32>,
    /// Migration plans applied before the snapshot (geometry replay).
    pub(crate) plans: Vec<MigrationPlan>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metrics() -> Metrics {
        Metrics::default()
    }

    #[test]
    fn rollback_without_checkpoint_panics_loudly() {
        let mut m = metrics();
        let mut rc: RecoveryCoordinator<u64> = RecoveryCoordinator::new(RecoveryPolicy::default());
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            rc.rollback("hama", "worker killed at barrier 1", &mut m);
        }))
        .expect_err("no checkpoint must refuse");
        let msg = err.downcast_ref::<String>().expect("string payload");
        assert!(msg.starts_with("chaos:"), "{msg}");
        assert!(msg.contains("no checkpoint"), "{msg}");
    }

    #[test]
    fn budget_exhaustion_is_a_structured_panic_not_a_loop() {
        let mut m = metrics();
        let mut rc: RecoveryCoordinator<u64> =
            RecoveryCoordinator::new(RecoveryPolicy { max_recoveries: 2, backoff_barriers: 0 });
        rc.install(4, 0xC0FFEE, &mut m);
        assert_eq!(*rc.rollback("hama", "loss", &mut m), 0xC0FFEE);
        assert_eq!(*rc.rollback("hama", "loss", &mut m), 0xC0FFEE);
        assert_eq!(m.recoveries, 2);
        assert_eq!(m.checkpoints, 1);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            rc.rollback("hama", "loss", &mut m);
        }))
        .expect_err("third rollback must exhaust the budget");
        let msg = err.downcast_ref::<String>().expect("string payload");
        assert!(msg.starts_with("chaos: recovery budget exhausted"), "{msg}");
        assert!(msg.contains("max_recoveries = 2"), "{msg}");
    }

    #[test]
    fn backoff_suppresses_checkpoints_below_the_resume_point() {
        let fault = FaultPolicy { checkpoint_interval: Some(2), ..Default::default() };
        let mut m = metrics();
        let mut rc: RecoveryCoordinator<u64> =
            RecoveryCoordinator::new(RecoveryPolicy { max_recoveries: 8, backoff_barriers: 3 });
        assert!(rc.should_checkpoint(&fault, 0));
        assert!(!rc.should_checkpoint(&fault, 1), "off-interval barrier");
        rc.install(4, 7, &mut m);
        rc.rollback("graphhp", "loss", &mut m);
        // resume_at = 4 + 3 = 7: the interval hit at 6 is suppressed,
        // the one at 8 is live again
        assert!(!rc.should_checkpoint(&fault, 6));
        assert!(rc.should_checkpoint(&fault, 8));
    }

    #[test]
    fn zero_interval_never_checkpoints() {
        let rc: RecoveryCoordinator<u64> = RecoveryCoordinator::new(RecoveryPolicy::default());
        let fault = FaultPolicy { checkpoint_interval: Some(0), ..Default::default() };
        assert!(!rc.should_checkpoint(&fault, 0));
        assert!(rc.last().is_none());
    }
}
