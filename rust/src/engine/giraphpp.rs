//! A Giraph++-style graph-centric engine (the paper's §7.5 comparator).
//!
//! Giraph++ ("think like a graph", Tian et al. [32]) exposes whole
//! partitions to user code: per superstep the user-defined sequential
//! algorithm scans its partition once, directly reading/writing any
//! vertex state inside the partition and messaging remote vertices.
//! Cross-partition messages still flow at superstep barriers.
//!
//! The paper benchmarks an improvised Hama `bsp()` implementation of this
//! model: "sequentially update each vertex once and immediately propagate
//! its update to its neighboring vertices within a same partition" per
//! superstep. [`run_giraphpp`] executes a [`PartitionProgram`] — one
//! parallel worker per partition, like every other engine, each turn an
//! explicit step on the shared [`PartitionRuntime`] lifecycle — and the
//! [`VertexSweep`] adapter runs any [`VertexProgram`] under those
//! single-sweep semantics via the shared `super::worker::Sweep` body.

use crate::graph::{DistGraph, MigrationPlan, PartGraph, VertexId};
use crate::util::Codec;

use super::messages::{MsgStore, Outbox};
use super::metrics::{Metrics, PartitionStepTrace, RunTrace};
use super::migrate::{remap_runtimes, MigrationPlanner};
use super::netsim::SuperstepClock;
use super::program::{SourceCombine, VertexProgram};
use super::recovery::{persist_checkpoint, RecoveryCoordinator};
use super::state::{Frontier, PartitionRuntime};
use super::worker::{
    boundary_count, close_superstep, restore_worker_states, run_workers, snapshot_worker_states,
    LocalRoute, ProcessedMarks, Reschedule, Sweep, SweepTarget, WorkerOut, WorkerScratch,
    WorkerState,
};
use super::{Aggregators, EngineConfig, RunResult};

/// The graph-centric programming interface: a sequential algorithm over
/// one partition per superstep.
pub trait PartitionProgram: Sync {
    /// Vertex value type.
    type V: Clone + Send + Sync + Codec;
    /// Message type.
    type M: Clone + Send + Sync + Codec;

    /// Initial vertex value, assigned before superstep 0.
    fn init(&self, vertex: VertexId, out_degree: u32) -> Self::V;

    /// One superstep of the sequential partition algorithm. Drain
    /// messages with [`PartitionContext::take_messages`], mutate vertex
    /// state freely, message remote vertices with
    /// [`PartitionContext::send`].
    fn compute_partition(&self, ctx: &mut PartitionContext<'_, Self>)
    where
        Self: Sized;

    /// Optional message combiner, applied sender-side in the outbox and
    /// receiver-side at barrier delivery (like the vertex-centric
    /// engines). [`VertexSweep`] forwards the wrapped program's.
    fn combiner(&self) -> Option<fn(Self::M, Self::M) -> Self::M> {
        None
    }
}

/// Full-partition access handed to a [`PartitionProgram`].
pub struct PartitionContext<'a, PP: PartitionProgram> {
    /// This partition's topology + metadata.
    pub part: &'a PartGraph,
    /// Current superstep counter.
    pub superstep: u64,
    /// Vertex values by local index — mutate freely.
    pub values: &'a mut [PP::V],
    /// voteToHalt flags by local index.
    pub halted: &'a mut [bool],
    cur: &'a mut MsgStore<PP::M>,
    nxt: &'a mut MsgStore<PP::M>,
    /// Next-superstep schedules ([`VertexSweep`]'s frontier bookkeeping;
    /// plain partition programs leave it untouched and re-derive their
    /// worklist from pending messages).
    frontier: &'a mut Frontier,
    /// Vertices the previous superstep scheduled (the frontier drained
    /// by this turn's `begin_step`).
    scheduled: &'a [u32],
    outbox: &'a mut Outbox<PP::M>,
    scratch: &'a mut WorkerScratch<PP::M>,
    marks: &'a mut ProcessedMarks,
    combiner: Option<fn(PP::M, PP::M) -> PP::M>,
    dg: &'a DistGraph,
    p: usize,
    /// [`Parallelism::WorkStealing`] thread count (0 = deterministic
    /// sweep body), forwarded to [`VertexSweep`]'s inner [`Sweep`].
    steal_threads: usize,
    computations: u64,
    local_messages: u64,
}

impl<'a, PP: PartitionProgram> PartitionContext<'a, PP> {
    /// Local vertices with pending messages this superstep.
    pub fn pending_vertices(&mut self) -> Vec<u32> {
        self.cur.pending()
    }

    /// Vertices scheduled by the previous superstep (insertion order).
    pub fn scheduled_vertices(&self) -> &[u32] {
        self.scheduled
    }

    /// Drain the incoming messages of local vertex `lv` into `buf`.
    pub fn take_messages(&mut self, lv: usize, buf: &mut Vec<PP::M>) {
        self.cur.take_into(lv, buf);
    }

    /// Send a message to any vertex. Same-partition destinations are
    /// queued in memory for the next superstep (combined on arrival when
    /// the program has a combiner); remote destinations go through RPC
    /// at the barrier.
    pub fn send(&mut self, target: VertexId, m: PP::M) {
        let (tp, tl) = self.dg.routing.location[target as usize];
        if tp as usize == self.p {
            self.local_messages += 1;
            self.nxt.push_combined(tl as usize, m, self.combiner);
        } else {
            let src = self.part.global_ids[0]; // graph-centric: partition-level source
            self.outbox.push(tp, tl, src, m);
        }
    }

    /// Record `n` vertex updates (for the metrics report).
    pub fn count_computations(&mut self, n: u64) {
        self.computations += n;
    }
}

/// Run a [`PartitionProgram`] to completion. Workers own the shared
/// `WorkerState` (runtime + pooled outbox + sweep scratch), which is
/// also what lets this engine share the universal checkpoint/rollback
/// helpers in `engine/worker.rs`/`engine/recovery.rs`.
///
/// Legacy entry point — use [`super::Runner::run_partition`] (or
/// [`super::Runner::run`] with [`super::EngineKind::GiraphPP`] for a
/// vertex program); kept as a delegate for one release.
#[doc(hidden)]
pub fn run_giraphpp<PP: PartitionProgram>(
    program: &PP,
    dg: &DistGraph,
    cfg: &EngineConfig,
) -> RunResult<PP::V> {
    let combiner = program.combiner();
    let mut workers: Vec<WorkerState<PP::V, PP::M>> = dg
        .parts
        .iter()
        .map(|pg| {
            let rt = PartitionRuntime::from_values(
                (0..pg.num_vertices())
                    .map(|lv| program.init(pg.global_ids[lv], pg.out_degree[lv]))
                    .collect(),
            );
            let n = rt.num_vertices();
            WorkerState {
                rt,
                scratch: WorkerScratch::new(),
                marks: ProcessedMarks::new(n),
                outbox: Outbox::new(combiner),
            }
        })
        .collect();

    let mut metrics = Metrics::default();
    let mut trace = RunTrace::default();
    let mut clock = SuperstepClock::new();
    // the graph-centric interface has no aggregators; keep an empty
    // master set so the shared barrier fold applies unchanged
    let mut aggs = Aggregators::new(Vec::new());
    let mut superstep: u64 = 0;
    let planner = cfg.repartition.map(MigrationPlanner::new);
    let mut dg_owned: Option<Box<DistGraph>> = None;
    let mut applied_plans: Vec<MigrationPlan> = Vec::new();
    let mut chaos_ctl = cfg.chaos.as_ref().map(super::chaos::ChaosController::new);
    let mut recovery = RecoveryCoordinator::new(cfg.fault.recovery);

    loop {
        // ---- fault tolerance (paper §5.3, via engine/recovery.rs):
        // snapshot the full superstep-boundary state so a chaos loss
        // event rolls back and replays instead of panicking
        if recovery.should_checkpoint(&cfg.fault, superstep) {
            let ckpt = snapshot_worker_states(superstep, &mut workers, &applied_plans);
            persist_checkpoint(&ckpt, &cfg.fault);
            recovery.install(superstep, ckpt, &mut metrics);
        }

        let dgr: &DistGraph = dg_owned.as_deref().unwrap_or(dg);
        let outs = run_workers(cfg.parallelism, &mut workers, |p, w| {
            let WorkerState { rt, scratch, marks, outbox } = w;
            outbox.reset();
            let scheduled = rt.begin_step();
            let pt = PartitionStepTrace {
                frontier: scheduled.len() as u64,
                boundary_frontier: boundary_count(&dgr.parts[p], &scheduled),
                ..Default::default()
            };
            // detlint: allow(wall-clock) — compute_us probe: measures this
            // worker's sweep for telemetry/netsim only, never feeds results.
            let t0 = std::time::Instant::now();
            let (computations, local_messages);
            {
                let mut ctx = PartitionContext::<PP> {
                    part: &dgr.parts[p],
                    superstep,
                    values: &mut rt.values,
                    halted: &mut rt.halted,
                    cur: &mut rt.cur,
                    nxt: &mut rt.nxt,
                    frontier: &mut rt.frontier,
                    scheduled: &scheduled,
                    outbox: &mut *outbox,
                    scratch: &mut *scratch,
                    marks: &mut *marks,
                    combiner,
                    dg: dgr,
                    p,
                    steal_threads: cfg.parallelism.steal_threads(),
                    computations: 0,
                    local_messages: 0,
                };
                program.compute_partition(&mut ctx);
                computations = ctx.computations;
                local_messages = ctx.local_messages;
            }
            rt.commit_step();
            outbox.seal(SourceCombine::KeepAll);
            let compute = cfg.net.scale_compute(t0.elapsed());
            let outcome = super::worker::SweepOutcome { computations, local_messages };
            WorkerOut::new(
                std::mem::take(outbox),
                Aggregators::new(Vec::new()),
                compute,
                p,
                outcome,
                0,
                pt,
            )
        });

        let outboxes = close_superstep(
            outs,
            &mut aggs,
            &mut clock,
            &cfg.net,
            &mut metrics,
            &mut trace,
            chaos_ctl.as_mut(),
            |tp, tl, m| {
                workers[tp as usize].rt.nxt.push_combined(tl as usize, m, combiner);
            },
        );
        for (w, ob) in workers.iter_mut().zip(outboxes) {
            w.outbox = ob;
            // debug sanitizer: step closed, inboxes/frontier intact
            // after delivery (no-op in release builds)
            super::invariants::check_runtime(&w.rt);
        }

        // ---- chaos recovery: a loss event corrupted this barrier —
        // roll every worker back to the latest checkpoint and replay
        // (the monotone chaos counter keeps advancing, so the replay
        // draws fresh RNG streams and a consumed kill never re-fires).
        // Without a checkpoint the coordinator refuses loss loudly.
        if let Some(reason) = chaos_ctl.as_mut().and_then(|c| c.take_pending()) {
            let ckpt = recovery.rollback("giraph++", &reason, &mut metrics);
            let (ws, at) =
                restore_worker_states(dg, ckpt, &mut dg_owned, &mut applied_plans, combiner);
            workers = ws;
            superstep = at;
            if let Some(ctl) = chaos_ctl.as_mut() {
                ctl.note_recovery();
            }
            continue;
        }

        // ---- online repartitioning: every partition is step-closed and
        // all barrier mail landed, so the plan applies atomically here
        {
            let step = trace.steps.last_mut().expect("barrier just recorded a step");
            step.routing_epoch = dgr.routing.epoch;
            let plan = planner.as_ref().and_then(|pl| pl.plan(dgr, step, superstep));
            if let Some(plan) = plan {
                // chaos: a kill scheduled inside this migration window
                // fires between plan and apply — abandon the plan and
                // roll back; the replay re-derives the identical plan
                // from the same counters and applies it cleanly
                let survive = match chaos_ctl.as_mut() {
                    Some(ctl) => ctl.judge_migration(plan.len() as u64),
                    None => true,
                };
                if !survive {
                    let reason = chaos_ctl
                        .as_mut()
                        .and_then(|c| c.take_pending())
                        .expect("migration kill raised a pending loss");
                    let ckpt = recovery.rollback("giraph++", &reason, &mut metrics);
                    let (ws, at) = restore_worker_states(
                        dg,
                        ckpt,
                        &mut dg_owned,
                        &mut applied_plans,
                        combiner,
                    );
                    workers = ws;
                    superstep = at;
                    if let Some(ctl) = chaos_ctl.as_mut() {
                        ctl.note_recovery();
                    }
                    continue;
                }
                step.migrated = plan.len() as u64;
                let new_dg = Box::new(dgr.apply_migration(&plan));
                let rts = remap_runtimes(
                    dgr,
                    &new_dg,
                    workers.drain(..).map(|w| w.rt).collect(),
                    combiner,
                );
                workers = rts
                    .into_iter()
                    .map(|rt| {
                        let n = rt.num_vertices();
                        WorkerState {
                            rt,
                            scratch: WorkerScratch::new(),
                            marks: ProcessedMarks::new(n),
                            outbox: Outbox::new(combiner),
                        }
                    })
                    .collect();
                applied_plans.push(plan);
                dg_owned = Some(new_dg);
            }
        }

        metrics.global_iterations += 1;
        metrics.supersteps_total += 1;
        superstep += 1;

        // barrier deliveries land in `nxt`; the next turn's `begin_step`
        // swaps them in, so quiescence checks both stores
        let done = workers
            .iter_mut()
            .all(|w| w.rt.halted.iter().all(|&h| h) && w.rt.quiesced());
        if done || superstep >= cfg.limits.max_iterations {
            break;
        }
    }

    // gather under the final routing epoch — migrated vertices read back
    // from their current owners
    let dgr: &DistGraph = dg_owned.as_deref().unwrap_or(dg);
    let values =
        super::gather_values_owned(dgr, workers.into_iter().map(|w| w.rt.values).collect());
    RunResult { values, metrics, trace, chaos: chaos_ctl.map(|c| c.into_trace()) }
}

/// Adapter: run a vertex-centric [`VertexProgram`] under Giraph++
/// single-sweep semantics — each active vertex computes at most once per
/// superstep, in-partition messages reach vertices later in the sweep
/// within the same superstep. The sweep itself is the shared worker body
/// (`super::worker::Sweep` with `LocalRoute::ThisSweep`).
pub struct VertexSweep<P: VertexProgram> {
    /// The wrapped vertex-centric program.
    pub program: P,
    /// Seed for per-vertex randomness.
    pub seed: u64,
}

impl<P: VertexProgram> PartitionProgram for VertexSweep<P> {
    type V = P::V;
    type M = P::M;

    fn init(&self, vertex: VertexId, out_degree: u32) -> P::V {
        self.program.init(vertex, out_degree)
    }

    fn combiner(&self) -> Option<fn(P::M, P::M) -> P::M> {
        self.program.combiner()
    }

    fn compute_partition(&self, ctx: &mut PartitionContext<'_, Self>) {
        let n = ctx.part.num_vertices();
        // worklist: scheduled vertices + vertices with mail (plus every
        // vertex at the initialization superstep), seeded into the
        // pooled sorted worklist — same ascending drain as the former
        // per-superstep BTreeSet, no allocation at steady state
        ctx.scratch.worklist.begin(n);
        for &lv in ctx.scheduled {
            ctx.scratch.worklist.schedule(lv);
        }
        for &lv in ctx.cur.pending_sorted() {
            ctx.scratch.worklist.schedule(lv);
        }
        if ctx.superstep == 0 {
            for lv in 0..n as u32 {
                ctx.scratch.worklist.schedule(lv);
            }
        }
        let sweep = Sweep {
            program: &self.program,
            dg: ctx.dg,
            part: ctx.part,
            p: ctx.p,
            superstep: ctx.superstep,
            seed: self.seed,
            combiner: self.program.combiner(),
            route: LocalRoute::ThisSweep,
            reschedule: Reschedule::Active,
            boundary_in_local: true,
            steal_threads: ctx.steal_threads,
        };
        // the vertex-centric aggregator mechanism is not part of the
        // graph-centric interface
        let mut wagg = Aggregators::new(Vec::new());
        let outcome = sweep.run(
            SweepTarget {
                values: &mut *ctx.values,
                halted: &mut *ctx.halted,
                cur: &mut *ctx.cur,
                nxt: &mut *ctx.nxt,
                frontier: Some(&mut *ctx.frontier),
            },
            None,
            &mut *ctx.outbox,
            &mut wagg,
            &mut *ctx.scratch,
            &mut *ctx.marks,
        );
        ctx.computations += outcome.computations;
        ctx.local_messages += outcome.local_messages;
    }
}

#[cfg(test)]
mod tests {
    use super::super::context::VertexContext;
    use super::*;
    use crate::engine::hama::run_hama;
    use crate::graph::generators;
    use crate::partition::hash_partition;

    struct MinLabel;
    impl VertexProgram for MinLabel {
        type V = u32;
        type M = u32;
        fn init(&self, v: VertexId, _d: u32) -> u32 {
            v
        }
        fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
            let mut best = *ctx.value();
            if ctx.superstep() == 0 {
                ctx.send_to_neighbors(best);
            } else if let Some(&m) = ctx.messages().iter().min() {
                if m < best {
                    best = m;
                    ctx.set_value(best);
                    ctx.send_to_neighbors(best);
                }
            }
            ctx.vote_to_halt();
        }
        fn combiner(&self) -> Option<fn(u32, u32) -> u32> {
            Some(|a, b| a.min(b))
        }
    }

    #[test]
    fn vertex_sweep_matches_hama_result() {
        let g = generators::connected(200, 80, 21);
        let a = hash_partition(&g, 4);
        let dg = DistGraph::new(&g, &a, 4);
        let cfg = EngineConfig::default();
        let h = run_hama(&MinLabel, &dg, &cfg);
        let gp = run_giraphpp(&VertexSweep { program: MinLabel, seed: 1 }, &dg, &cfg);
        assert_eq!(h.values, gp.values);
        // in-partition propagation converges in fewer supersteps
        assert!(gp.metrics.global_iterations <= h.metrics.global_iterations);
    }

    #[test]
    fn vertex_sweep_combiner_reduces_network_messages() {
        // VertexSweep now forwards the program's combiner to the outbox:
        // many same-destination deltas collapse to one wire message
        let g = generators::connected(200, 80, 25);
        let a = hash_partition(&g, 4);
        let dg = DistGraph::new(&g, &a, 4);
        let cfg = EngineConfig::default();
        struct NoCombine;
        impl VertexProgram for NoCombine {
            type V = u32;
            type M = u32;
            fn init(&self, v: VertexId, _d: u32) -> u32 {
                v
            }
            fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
                let mut best = *ctx.value();
                if ctx.superstep() == 0 {
                    ctx.send_to_neighbors(best);
                } else if let Some(&m) = ctx.messages().iter().min() {
                    if m < best {
                        best = m;
                        ctx.set_value(best);
                        ctx.send_to_neighbors(best);
                    }
                }
                ctx.vote_to_halt();
            }
        }
        let with = run_giraphpp(&VertexSweep { program: MinLabel, seed: 1 }, &dg, &cfg);
        let without = run_giraphpp(&VertexSweep { program: NoCombine, seed: 1 }, &dg, &cfg);
        assert_eq!(with.values, without.values, "combining must not change results");
        assert!(
            with.metrics.network_messages <= without.metrics.network_messages,
            "combined {} > raw {}",
            with.metrics.network_messages,
            without.metrics.network_messages
        );
    }
}
