//! A Giraph++-style graph-centric engine (the paper's §7.5 comparator).
//!
//! Giraph++ ("think like a graph", Tian et al. [32]) exposes whole
//! partitions to user code: per superstep the user-defined sequential
//! algorithm scans its partition once, directly reading/writing any
//! vertex state inside the partition and messaging remote vertices.
//! Cross-partition messages still flow at superstep barriers.
//!
//! The paper benchmarks an improvised Hama `bsp()` implementation of this
//! model: "sequentially update each vertex once and immediately propagate
//! its update to its neighboring vertices within a same partition" per
//! superstep. [`run_giraphpp`] executes a [`PartitionProgram`]; the
//! [`VertexSweep`] adapter runs any [`VertexProgram`] under those
//! single-sweep semantics.

use std::collections::BTreeSet;

use crate::graph::{DistGraph, PartGraph, VertexId};
use crate::util::Codec;

use super::context::{SendBuffer, VertexContext};
use super::messages::{MsgStore, Outbox};
use super::metrics::Metrics;
use super::netsim::{SuperstepClock, WorkerComm};
use super::program::VertexProgram;
use super::{Aggregators, EngineConfig, RunResult};

/// The graph-centric programming interface: a sequential algorithm over
/// one partition per superstep.
pub trait PartitionProgram: Sync {
    type V: Clone + Send + Sync + Codec;
    type M: Clone + Send + Sync + Codec;

    fn init(&self, vertex: VertexId, out_degree: u32) -> Self::V;

    /// One superstep of the sequential partition algorithm. Drain
    /// messages with [`PartitionContext::take_messages`], mutate vertex
    /// state freely, message remote vertices with
    /// [`PartitionContext::send`].
    fn compute_partition(&self, ctx: &mut PartitionContext<'_, Self>)
    where
        Self: Sized;
}

/// Full-partition access handed to a [`PartitionProgram`].
pub struct PartitionContext<'a, PP: PartitionProgram> {
    pub part: &'a PartGraph,
    pub superstep: u64,
    pub values: &'a mut [PP::V],
    pub halted: &'a mut [bool],
    cur: &'a mut MsgStore<PP::M>,
    nxt: &'a mut MsgStore<PP::M>,
    outbox: &'a mut Outbox<PP::M>,
    dg: &'a DistGraph,
    p: usize,
    computations: u64,
    local_messages: u64,
}

impl<'a, PP: PartitionProgram> PartitionContext<'a, PP> {
    /// Local vertices with pending messages this superstep.
    pub fn pending_vertices(&mut self) -> Vec<u32> {
        self.cur.pending()
    }

    /// Drain the incoming messages of local vertex `lv` into `buf`.
    pub fn take_messages(&mut self, lv: usize, buf: &mut Vec<PP::M>) {
        self.cur.take_into(lv, buf);
    }

    /// Send a message to any vertex. Same-partition destinations are
    /// queued in memory for the next superstep; remote destinations go
    /// through RPC at the barrier.
    pub fn send(&mut self, target: VertexId, m: PP::M) {
        let (tp, tl) = self.dg.location[target as usize];
        if tp as usize == self.p {
            self.local_messages += 1;
            self.nxt.push(tl as usize, m);
        } else {
            let src = self.part.global_ids[0]; // graph-centric: partition-level source
            self.outbox.push(tp, tl, src, m);
        }
    }

    /// Record `n` vertex updates (for the metrics report).
    pub fn count_computations(&mut self, n: u64) {
        self.computations += n;
    }
}

/// Run a [`PartitionProgram`] to completion.
///
/// Legacy entry point — use [`super::Runner::run_partition`] (or
/// [`super::Runner::run`] with [`super::EngineKind::GiraphPP`] for a
/// vertex program); kept as a delegate for one release.
#[doc(hidden)]
pub fn run_giraphpp<PP: PartitionProgram>(
    program: &PP,
    dg: &DistGraph,
    cfg: &EngineConfig,
) -> RunResult<PP::V> {
    let np = dg.num_parts();
    let mut values: Vec<Vec<PP::V>> = dg
        .parts
        .iter()
        .map(|pg| {
            (0..pg.num_vertices())
                .map(|lv| program.init(pg.global_ids[lv], pg.out_degree[lv]))
                .collect()
        })
        .collect();
    let mut halted: Vec<Vec<bool>> =
        dg.parts.iter().map(|pg| vec![false; pg.num_vertices()]).collect();
    let mut cur: Vec<MsgStore<PP::M>> =
        dg.parts.iter().map(|pg| MsgStore::new(pg.num_vertices())).collect();
    let mut nxt: Vec<MsgStore<PP::M>> =
        dg.parts.iter().map(|pg| MsgStore::new(pg.num_vertices())).collect();

    let mut metrics = Metrics::default();
    let mut clock = SuperstepClock::new();
    let mut superstep: u64 = 0;

    loop {
        let mut outboxes: Vec<Outbox<PP::M>> = Vec::with_capacity(np);
        for p in 0..np {
            let mut outbox: Outbox<PP::M> = Outbox::new(None);
            let t0 = std::time::Instant::now();
            {
                let mut ctx = PartitionContext::<PP> {
                    part: &dg.parts[p],
                    superstep,
                    values: &mut values[p],
                    halted: &mut halted[p],
                    cur: &mut cur[p],
                    nxt: &mut nxt[p],
                    outbox: &mut outbox,
                    dg,
                    p,
                    computations: 0,
                    local_messages: 0,
                };
                program.compute_partition(&mut ctx);
                metrics.vertex_computations += ctx.computations;
                metrics.local_messages += ctx.local_messages;
            }
            let compute = cfg.net.scale_compute(t0.elapsed());
            let comm = WorkerComm {
                messages: outbox.len() as u64,
                bytes: outbox.wire_bytes() as u64,
                peer_pairs: outbox.peer_count(p as u32) as u64,
            };
            metrics.network_messages += comm.messages;
            metrics.network_bytes += comm.bytes;
            clock.record_worker(compute, cfg.net.comm_time(&comm));
            outboxes.push(outbox);
        }
        for (_p, mut outbox) in outboxes.into_iter().enumerate() {
            for (tp, tl, m) in outbox.drain() {
                nxt[tp as usize].push(tl as usize, m);
            }
        }
        clock.barrier(&cfg.net, &mut metrics);
        metrics.global_iterations += 1;
        metrics.supersteps_total += 1;
        superstep += 1;

        for p in 0..np {
            std::mem::swap(&mut cur[p], &mut nxt[p]);
        }
        let done = (0..np).all(|p| {
            halted[p].iter().all(|&h| h) && cur[p].is_empty() && nxt[p].is_empty()
        });
        if done || superstep >= cfg.limits.max_iterations {
            break;
        }
    }

    let values = super::gather_values(dg, &values);
    RunResult { values, metrics }
}

/// Adapter: run a vertex-centric [`VertexProgram`] under Giraph++
/// single-sweep semantics — each active vertex computes at most once per
/// superstep, in-partition messages reach vertices later in the sweep
/// within the same superstep.
pub struct VertexSweep<P: VertexProgram> {
    pub program: P,
    pub seed: u64,
}

impl<P: VertexProgram> PartitionProgram for VertexSweep<P> {
    type V = P::V;
    type M = P::M;

    fn init(&self, vertex: VertexId, out_degree: u32) -> P::V {
        self.program.init(vertex, out_degree)
    }

    fn compute_partition(&self, ctx: &mut PartitionContext<'_, Self>) {
        let n = ctx.part.num_vertices();
        let combiner = self.program.combiner();
        // worklist: vertices with messages + unhalted vertices
        let mut worklist: BTreeSet<u32> = ctx.pending_vertices().into_iter().collect();
        for lv in 0..n {
            if !ctx.halted[lv] {
                worklist.insert(lv as u32);
            }
        }
        let mut processed = vec![false; n];
        let mut msg_buf: Vec<P::M> = Vec::new();
        let mut send_buf: SendBuffer<P::M> = SendBuffer::new();
        let mut aggs = Aggregators::new(Vec::new());
        let mut computations = 0u64;
        while let Some(lv32) = worklist.pop_first() {
            let lv = lv32 as usize;
            processed[lv] = true;
            ctx.take_messages(lv, &mut msg_buf);
            if ctx.halted[lv] {
                if msg_buf.is_empty() {
                    continue;
                }
                ctx.halted[lv] = false;
            }
            send_buf.clear();
            {
                let mut vctx = VertexContext::<P> {
                    part: ctx.part,
                    lv,
                    superstep: ctx.superstep,
                    value: &mut ctx.values[lv],
                    messages: &msg_buf,
                    halted: &mut ctx.halted[lv],
                    out: &mut send_buf,
                    aggregators: &mut aggs,
                    seed: self.seed,
                };
                self.program.compute(&mut vctx);
            }
            computations += 1;
            for (target, m) in send_buf.sends.drain(..) {
                let (tp, tl) = ctx.dg.location[target as usize];
                if tp as usize == ctx.p {
                    let tl = tl as usize;
                    ctx.local_messages += 1;
                    // no same-sweep delivery during the initialization
                    // superstep (programs treat superstep 0 as
                    // message-free setup; async delivery there would
                    // silently drop messages)
                    if ctx.superstep > 0 && !processed[tl] {
                        // visible within this sweep
                        ctx.cur.push_combined(tl, m, combiner);
                        worklist.insert(tl as u32);
                    } else {
                        ctx.nxt.push_combined(tl, m, combiner);
                    }
                } else {
                    ctx.outbox.push(tp, tl, ctx.part.global_ids[lv], m);
                }
            }
        }
        ctx.count_computations(computations);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::hama::run_hama;
    use crate::graph::generators;
    use crate::partition::hash_partition;

    struct MinLabel;
    impl VertexProgram for MinLabel {
        type V = u32;
        type M = u32;
        fn init(&self, v: VertexId, _d: u32) -> u32 {
            v
        }
        fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
            let mut best = *ctx.value();
            if ctx.superstep() == 0 {
                ctx.send_to_neighbors(best);
            } else if let Some(&m) = ctx.messages().iter().min() {
                if m < best {
                    best = m;
                    ctx.set_value(best);
                    ctx.send_to_neighbors(best);
                }
            }
            ctx.vote_to_halt();
        }
        fn combiner(&self) -> Option<fn(u32, u32) -> u32> {
            Some(|a, b| a.min(b))
        }
    }

    #[test]
    fn vertex_sweep_matches_hama_result() {
        let g = generators::connected(200, 80, 21);
        let a = hash_partition(&g, 4);
        let dg = DistGraph::new(&g, &a, 4);
        let cfg = EngineConfig::default();
        let h = run_hama(&MinLabel, &dg, &cfg);
        let gp = run_giraphpp(&VertexSweep { program: MinLabel, seed: 1 }, &dg, &cfg);
        assert_eq!(h.values, gp.values);
        // in-partition propagation converges in fewer supersteps
        assert!(gp.metrics.global_iterations <= h.metrics.global_iterations);
    }
}
