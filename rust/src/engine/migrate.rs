//! Online repartitioning: the telemetry-driven migration planner and
//! the barrier-side state remapper.
//!
//! GraphHP's whole advantage is locality, yet the partition assignment
//! is frozen at build time while the run's own [`RunTrace`] counters
//! say, per barrier and per partition, exactly which partitions are
//! boundary-dominated and network-bound. This module closes that loop
//! (the Mizan-style dynamic-migration answer to runtime skew):
//!
//! 1. [`MigrationPlanner::plan`] folds the just-recorded
//!    [`StepTrace`] at the barrier into a [`MigrationPlan`] — a pure
//!    function of **deterministic counters only** (boundary occupancy
//!    and the local/network message split; `compute_us` is wall-clock
//!    and must never be read), so sequential and threaded runs plan
//!    identical migrations and the sequential ≡ threaded bit-for-bit
//!    guarantee survives.
//! 2. The engine applies the plan atomically at the barrier:
//!    [`DistGraph::apply_migration`] rebuilds every partition and the
//!    routing epoch through the write-through construction path, and
//!    [`remap_runtimes`] forwards all live per-partition state —
//!    vertex values, halt flags, in-flight [`MsgStore`] mail (FIFO
//!    order preserved) and carryover frontier entries — to each
//!    vertex's new owner.
//!
//! Plans are [`Codec`](crate::util::Codec)-encodable pure data, so
//! every barrier engine checkpoints the applied-plan trajectory and
//! replays it bit-for-bit on recovery (`engine/recovery.rs` replays
//! the plans over the base graph to rebuild the checkpointed
//! geometry). The window between [`MigrationPlanner::plan`] and
//! [`DistGraph::apply_migration`] is itself a chaos target
//! (`ChaosSchedule::migration_kill_at`): a kill there abandons the
//! planned moves and recovery re-derives the identical plan from the
//! checkpointed counters.
//!
//! [`RunTrace`]: super::RunTrace

use crate::graph::{DistGraph, MigrationPlan, VertexId};

use super::messages::MsgStore;
use super::metrics::StepTrace;
use super::state::PartitionRuntime;

/// Tuning of the online repartitioner (`EngineConfig::repartition`).
///
/// Every knob feeds the deterministic planner only — there is no
/// wall-clock input anywhere in the migration pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RepartitionConfig {
    /// Plan a migration every N barriers (the first candidate barrier
    /// is iteration N-1). 0 disables planning outright.
    pub interval: u64,
    /// Upper bound on vertices moved per plan (also capped so the donor
    /// partition always keeps at least one vertex).
    pub max_moves: usize,
}

impl Default for RepartitionConfig {
    fn default() -> Self {
        RepartitionConfig { interval: 4, max_moves: 64 }
    }
}

impl RepartitionConfig {
    /// Plan at every barrier — the aggressive setting the equivalence
    /// tests use so short runs still migrate.
    pub fn every_barrier() -> Self {
        RepartitionConfig { interval: 1, ..Default::default() }
    }
}

/// Deterministic migration planner: folds one barrier's [`StepTrace`]
/// counters plus the current routing epoch's topology into a
/// [`MigrationPlan`].
///
/// Donor selection reads only counter fields (`network_messages`,
/// `local_messages`, `boundary_frontier`); candidate scoring reads only
/// the donor partition's route columns. Both are identical between
/// sequential and threaded runs, so so is every plan.
#[derive(Clone, Copy, Debug)]
pub struct MigrationPlanner {
    /// The planner's tuning.
    pub config: RepartitionConfig,
}

impl MigrationPlanner {
    /// A planner with the given tuning.
    pub fn new(config: RepartitionConfig) -> Self {
        MigrationPlanner { config }
    }

    /// Fold the barrier's trace into a plan, or None when this barrier
    /// is off-interval, no partition qualifies as a donor, or no vertex
    /// move would reduce the donor's share of the cut.
    ///
    /// Donor: among partitions whose turn was network-dominated
    /// (`network_messages > local_messages`) with a non-empty boundary
    /// frontier, the one with the most network messages (ties broken by
    /// the smaller partition index). Candidates: the donor's vertices
    /// whose out-edges favor one remote partition over staying
    /// (`edges to best remote part > internal edges` — an out-edge-only
    /// gain heuristic; in-edges would need a reverse scan). The
    /// highest-gain candidates move, ties broken by ascending global
    /// id, capped at [`RepartitionConfig::max_moves`] and at donor
    /// size - 1 so no partition is emptied.
    pub fn plan(
        &self,
        dg: &DistGraph,
        step: &StepTrace,
        iteration: u64,
    ) -> Option<MigrationPlan> {
        let np = dg.num_parts();
        if np < 2 || self.config.interval == 0 || self.config.max_moves == 0 {
            return None;
        }
        if (iteration + 1) % self.config.interval != 0 {
            return None;
        }

        let mut donor: Option<(u64, usize)> = None;
        for pt in &step.partitions {
            if pt.network_messages > pt.local_messages && pt.boundary_frontier > 0 {
                let p = pt.partition as usize;
                let better = match donor {
                    None => true,
                    Some((best, bp)) => {
                        pt.network_messages > best
                            || (pt.network_messages == best && p < bp)
                    }
                };
                if better {
                    donor = Some((pt.network_messages, p));
                }
            }
        }
        let (_, donor) = donor?;
        let part = &dg.parts[donor];
        let n = part.num_vertices();
        if n < 2 {
            return None;
        }

        // Score every donor vertex: external out-edge counts per remote
        // partition vs internal out-edges, via the route stream (works
        // over raw and packed columns alike). `ext` is reset through the
        // `touched` list so the scan is O(edges), not O(n * parts).
        let mut ext = vec![0u64; np];
        let mut touched: Vec<u32> = Vec::new();
        let mut cands: Vec<(u64, VertexId, u32)> = Vec::new(); // (gain, gid, to)
        for lv in 0..n {
            let mut internal = 0u64;
            for r in part.out_edges(lv).route_iter() {
                let tp = r.part() as usize;
                if tp == donor {
                    internal += 1;
                } else {
                    if ext[tp] == 0 {
                        touched.push(tp as u32);
                    }
                    ext[tp] += 1;
                }
            }
            touched.sort_unstable();
            let mut best: Option<(u64, u32)> = None;
            for &q in &touched {
                let c = ext[q as usize];
                if best.map_or(true, |(bc, _)| c > bc) {
                    best = Some((c, q));
                }
            }
            if let Some((c, q)) = best {
                if c > internal {
                    cands.push((c - internal, part.global_ids[lv], q));
                }
            }
            for q in touched.drain(..) {
                ext[q as usize] = 0;
            }
        }
        if cands.is_empty() {
            return None;
        }
        cands.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        cands.truncate(self.config.max_moves.min(n - 1));
        let mut moves: Vec<(VertexId, u32)> =
            cands.into_iter().map(|(_, gid, q)| (gid, q)).collect();
        moves.sort_unstable_by_key(|&(gid, _)| gid);
        Some(MigrationPlan { epoch: dg.routing.epoch + 1, moves })
    }
}

/// Forward every pending message of `stores` (one [`MsgStore`] per old
/// partition, indexed by partition id) to the owners under the `new`
/// epoch. Per-vertex FIFO order is preserved — a vertex's mail lives in
/// exactly one old partition, and `export` walks it in queue order —
/// and receiver-side combining is re-applied so a combined store stays
/// one-message-per-vertex.
pub(crate) fn remap_stores<M: Clone>(
    old: &DistGraph,
    new: &DistGraph,
    mut stores: Vec<MsgStore<M>>,
    combiner: Option<fn(M, M) -> M>,
) -> Vec<MsgStore<M>> {
    let mut out: Vec<MsgStore<M>> =
        new.parts.iter().map(|p| MsgStore::new(p.num_vertices())).collect();
    for (op, store) in stores.iter_mut().enumerate() {
        for (lv, msgs) in store.export() {
            let gid = old.parts[op].global_ids[lv as usize];
            let (np, nl) = new.routing.location[gid as usize];
            for m in msgs {
                out[np as usize].push_combined(nl as usize, m, combiner);
            }
        }
    }
    out
}

/// Remap per-partition runtimes from the `old` geometry onto the `new`
/// one at a barrier: vertex values and halt flags follow their global
/// id to the new (partition, local) slot, in-flight `cur`/`nxt` mail is
/// forwarded through [`remap_stores`], and carryover frontier entries
/// are re-scheduled at each vertex's new owner (in ascending global-id
/// order; sweeps sort their worklists, so this ordering is a
/// determinism discipline, not a semantic requirement).
///
/// Callers must be at a barrier (no step open); the remapped runtimes
/// come back with `step_open == false`.
pub(crate) fn remap_runtimes<V: Clone, M: Clone>(
    old: &DistGraph,
    new: &DistGraph,
    rts: Vec<PartitionRuntime<V, M>>,
    combiner: Option<fn(M, M) -> M>,
) -> Vec<PartitionRuntime<V, M>> {
    let mut values_old = Vec::with_capacity(rts.len());
    let mut halted_old = Vec::with_capacity(rts.len());
    let mut cur_old = Vec::with_capacity(rts.len());
    let mut nxt_old = Vec::with_capacity(rts.len());
    let mut frontiers_old = Vec::with_capacity(rts.len());
    for rt in rts {
        values_old.push(rt.values);
        halted_old.push(rt.halted);
        cur_old.push(rt.cur);
        nxt_old.push(rt.nxt);
        frontiers_old.push(rt.frontier);
    }

    let mut out: Vec<PartitionRuntime<V, M>> = new
        .parts
        .iter()
        .map(|part| {
            let n = part.num_vertices();
            let mut vals = Vec::with_capacity(n);
            let mut halts = Vec::with_capacity(n);
            for lv in 0..n {
                let gid = part.global_ids[lv] as usize;
                let (op, ol) = old.routing.location[gid];
                vals.push(values_old[op as usize][ol as usize].clone());
                halts.push(halted_old[op as usize][ol as usize]);
            }
            let mut rt = PartitionRuntime::from_values(vals);
            rt.halted = halts;
            rt
        })
        .collect();

    let cur_new = remap_stores(old, new, cur_old, combiner);
    let nxt_new = remap_stores(old, new, nxt_old, combiner);
    for (p, (c, x)) in cur_new.into_iter().zip(nxt_new).enumerate() {
        out[p].cur = c;
        out[p].nxt = x;
    }

    let mut scheduled: Vec<VertexId> = Vec::new();
    for (op, f) in frontiers_old.iter().enumerate() {
        for &lv in &f.snapshot() {
            scheduled.push(old.parts[op].global_ids[lv as usize]);
        }
    }
    scheduled.sort_unstable();
    for gid in scheduled {
        let (np, nl) = new.routing.location[gid as usize];
        out[np as usize].frontier.schedule(nl as usize);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::metrics::PartitionStepTrace;
    use crate::graph::{DistGraph, Graph, GraphBuilder};

    /// Two partitions, vertex 1 lives in p0 but all three of its edges
    /// point into p1 — the canonical migration candidate.
    fn misplaced() -> (Graph, DistGraph) {
        let mut b = GraphBuilder::new(6);
        b.add_edge(0, 1, 1.0);
        b.add_edge(1, 3, 1.0);
        b.add_edge(1, 4, 1.0);
        b.add_edge(1, 5, 1.0);
        b.add_edge(3, 4, 1.0);
        let g = b.build();
        let dg = DistGraph::new(&g, &[0, 0, 0, 1, 1, 1], 2);
        (g, dg)
    }

    fn network_bound_step(parts: usize, donor: u32) -> StepTrace {
        StepTrace {
            iteration: 0,
            partitions: (0..parts as u32)
                .map(|p| PartitionStepTrace {
                    partition: p,
                    boundary_frontier: u64::from(p == donor),
                    network_messages: if p == donor { 10 } else { 0 },
                    local_messages: 1,
                    ..Default::default()
                })
                .collect(),
            ..Default::default()
        }
    }

    #[test]
    fn planner_moves_the_misplaced_vertex() {
        let (_, dg) = misplaced();
        let planner = MigrationPlanner::new(RepartitionConfig::every_barrier());
        let plan = planner.plan(&dg, &network_bound_step(2, 0), 0).expect("plan");
        assert_eq!(plan.epoch, 1);
        assert!(plan.moves.contains(&(1, 1)), "vertex 1 should move to p1: {:?}", plan.moves);
        let m = dg.apply_migration(&plan);
        assert!(m.edge_cut() < dg.edge_cut(), "migration must reduce the cut");
    }

    #[test]
    fn planner_respects_interval_and_caps() {
        let (_, dg) = misplaced();
        let step = network_bound_step(2, 0);
        let planner = MigrationPlanner::new(RepartitionConfig { interval: 4, max_moves: 64 });
        assert!(planner.plan(&dg, &step, 0).is_none(), "iteration 0 is off-interval");
        assert!(planner.plan(&dg, &step, 3).is_some(), "iteration 3 is the 4th barrier");
        let capped = MigrationPlanner::new(RepartitionConfig { interval: 1, max_moves: 1 });
        let plan = capped.plan(&dg, &step, 0).expect("plan");
        assert_eq!(plan.len(), 1);
        let off = MigrationPlanner::new(RepartitionConfig { interval: 0, max_moves: 64 });
        assert!(off.plan(&dg, &step, 0).is_none(), "interval 0 disables planning");
    }

    #[test]
    fn planner_is_a_pure_function_of_counters() {
        let (_, dg) = misplaced();
        let planner = MigrationPlanner::new(RepartitionConfig::every_barrier());
        let a = planner.plan(&dg, &network_bound_step(2, 0), 0);
        let b = planner.plan(&dg, &network_bound_step(2, 0), 0);
        assert_eq!(a, b);
        // a quiet step (no network dominance) plans nothing
        let quiet = StepTrace {
            partitions: vec![PartitionStepTrace::default(), PartitionStepTrace::default()],
            ..Default::default()
        };
        assert!(planner.plan(&dg, &quiet, 0).is_none());
    }

    #[test]
    fn remap_forwards_values_mail_and_frontier() {
        let (_, dg) = misplaced();
        let mut rts: Vec<PartitionRuntime<u32, u32>> = dg
            .parts
            .iter()
            .map(|p| PartitionRuntime::from_values(p.global_ids.iter().map(|&g| g * 10).collect()))
            .collect();
        // vertex 1 (p0, local 1): halted, two FIFO messages, scheduled
        rts[0].halted[1] = true;
        rts[0].nxt.push(1, 7);
        rts[0].nxt.push(1, 8);
        rts[0].frontier.schedule(1);
        // vertex 4 (p1, local 1): cur-mail that must stay in place
        rts[1].cur.push(1, 9);

        let plan = MigrationPlan { epoch: 1, moves: vec![(1, 1)] };
        let new_dg = dg.apply_migration(&plan);
        let mut out = remap_runtimes(&dg, &new_dg, rts, None);

        let (np, nl) = new_dg.routing.location[1];
        assert_eq!(np, 1);
        assert_eq!(out[np as usize].values[nl as usize], 10);
        assert!(out[np as usize].halted[nl as usize]);
        let mut buf = Vec::new();
        out[np as usize].nxt.take_into(nl as usize, &mut buf);
        assert_eq!(buf, vec![7, 8], "FIFO mail order preserved across migration");
        assert_eq!(out[np as usize].frontier.take(), vec![nl]);
        // unmoved vertex 4 keeps its mail under the new epoch
        let (p4, l4) = new_dg.routing.location[4];
        out[p4 as usize].cur.take_into(l4 as usize, &mut buf);
        assert_eq!(buf, vec![9]);
        // every value still reachable at its new location
        for v in 0..new_dg.num_vertices {
            let (p, l) = new_dg.routing.location[v];
            assert_eq!(out[p as usize].values[l as usize], v as u32 * 10);
        }
    }

    #[test]
    fn remap_applies_receiver_side_combining() {
        let (_, dg) = misplaced();
        let mut rts: Vec<PartitionRuntime<u32, u32>> = dg
            .parts
            .iter()
            .map(|p| PartitionRuntime::from_values(vec![0; p.num_vertices()]))
            .collect();
        rts[0].nxt.push(1, 5);
        rts[0].nxt.push(1, 3);
        let plan = MigrationPlan { epoch: 1, moves: vec![(1, 1)] };
        let new_dg = dg.apply_migration(&plan);
        let min = |a: u32, b: u32| a.min(b);
        let mut out = remap_runtimes(&dg, &new_dg, rts, Some(min));
        let (np, nl) = new_dg.routing.location[1];
        let mut buf = Vec::new();
        out[np as usize].nxt.take_into(nl as usize, &mut buf);
        assert_eq!(buf, vec![3], "combiner folds forwarded mail");
    }
}
