//! The per-vertex compute context: what a `Compute()` invocation can see
//! and do (paper §3).

use crate::graph::{Edge, EdgeRoute, Edges, PartGraph, VertexId};
use crate::util::Rng;

use super::aggregator::Aggregators;
use super::program::VertexProgram;

/// Sends collected during one `compute` invocation; the engine routes
/// them afterwards (destination may be any vertex id, not only a
/// neighbor, as in Pregel).
///
/// Entries are **pre-resolved routes**: edge-directed sends
/// ([`VertexContext::send_to_neighbors`] /
/// [`VertexContext::send_along_edges`]) copy the edge's precomputed
/// [`EdgeRoute`] location indicator (§5.1) and never consult the global
/// location table; only the arbitrary-destination
/// [`VertexContext::send`] resolves its route — once, at enqueue. The
/// sweep loop then routes each entry with no per-message lookup.
pub struct SendBuffer<M> {
    /// `(resolved destination route, message)` pairs in send order.
    pub sends: Vec<(EdgeRoute, M)>,
}

impl<M> SendBuffer<M> {
    /// An empty buffer.
    pub fn new() -> Self {
        SendBuffer { sends: Vec::new() }
    }

    /// Drop all queued sends, keeping the allocation.
    pub fn clear(&mut self) {
        self.sends.clear();
    }
}

impl<M> Default for SendBuffer<M> {
    fn default() -> Self {
        Self::new()
    }
}

/// The context handed to [`VertexProgram::compute`].
pub struct VertexContext<'a, P: VertexProgram> {
    pub(crate) part: &'a PartGraph,
    /// Local index of the vertex within the partition.
    pub(crate) lv: usize,
    /// Superstep counter exposed to the program. Engines map their notion
    /// of progress onto it (global iteration index for GraphHP, as §5.3).
    pub(crate) superstep: u64,
    pub(crate) value: &'a mut P::V,
    pub(crate) messages: &'a [P::M],
    pub(crate) halted: &'a mut bool,
    pub(crate) out: &'a mut SendBuffer<P::M>,
    pub(crate) aggregators: &'a mut Aggregators,
    pub(crate) seed: u64,
    /// Global vertex id -> (partition, local index) — consulted only by
    /// the arbitrary-destination [`send`](Self::send); edge-directed
    /// sends use the edges' precomputed routes instead.
    pub(crate) location: &'a [(u32, u32)],
}

impl<'a, P: VertexProgram> VertexContext<'a, P> {
    /// Global id of this vertex.
    pub fn vertex_id(&self) -> VertexId {
        self.part.global_ids[self.lv]
    }

    /// The partition this vertex lives in (topology + metadata).
    pub fn partition(&self) -> &PartGraph {
        self.part
    }

    /// Superstep (Hama) / global iteration (GraphHP) counter —
    /// `getSuperstepCount()`.
    pub fn superstep(&self) -> u64 {
        self.superstep
    }

    /// `getValue()`.
    pub fn value(&self) -> &P::V {
        self.value
    }

    /// `setValue()`.
    pub fn set_value(&mut self, v: P::V) {
        *self.value = v;
    }

    /// Mutable access to the value (ergonomic alternative to get+set).
    pub fn value_mut(&mut self) -> &mut P::V {
        self.value
    }

    /// Messages delivered to this vertex for this (pseudo-)superstep.
    pub fn messages(&self) -> &[P::M] {
        self.messages
    }

    /// Out-edges of this vertex (targets + weights + location hints).
    pub fn edges(&self) -> Edges<'a> {
        self.part.out_edges(self.lv)
    }

    /// Out-degree.
    pub fn out_degree(&self) -> u32 {
        self.part.out_degree[self.lv]
    }

    /// Whether this vertex is a boundary vertex (Definition 1). Exposed
    /// for diagnostics; correct programs don't need it.
    pub fn is_boundary(&self) -> bool {
        self.part.is_boundary[self.lv]
    }

    /// `sendMessage(dest, msg)` — dest may be any vertex. The route is
    /// resolved here, once, so the sweep loop pays no per-message
    /// location lookup.
    pub fn send(&mut self, dest: VertexId, msg: P::M) {
        let (tp, tl) = self.location[dest as usize];
        self.out.sends.push((EdgeRoute::new(tp, tl), msg));
    }

    /// Send `msg` along every out-edge: streams the partition's
    /// precomputed routes directly (the raw column on SoA storage, a
    /// route-only decode on compressed storage) — no location lookup,
    /// no intermediate allocation.
    pub fn send_to_neighbors(&mut self, msg: P::M) {
        let part = self.part;
        for route in part.out_edges(self.lv).route_iter() {
            self.out.sends.push((route, msg.clone()));
        }
    }

    /// Send one message per out-edge, computed from the edge (no
    /// intermediate allocation — the hot path of SSSP/PageRank). The
    /// edge's precomputed route is copied into the send, so delivery
    /// needs no location lookup either.
    pub fn send_along_edges(&mut self, f: impl Fn(&Edge) -> Option<P::M>) {
        let part = self.part;
        for e in part.out_edges(self.lv) {
            if let Some(m) = f(&e) {
                self.out.sends.push((e.route(), m));
            }
        }
    }

    /// `voteToHalt()`.
    pub fn vote_to_halt(&mut self) {
        *self.halted = true;
    }

    /// Submit to aggregator `id` (visible at the next superstep).
    pub fn aggregate(&mut self, id: usize, v: f64) {
        self.aggregators.submit(id, v);
    }

    /// Reduced aggregator value from the previous superstep.
    pub fn aggregated(&self, id: usize) -> f64 {
        self.aggregators.previous(id)
    }

    /// Deterministic per-(vertex, superstep) RNG — for randomized
    /// programs like bipartite matching.
    pub fn rng(&self) -> Rng {
        Rng::new(self.seed)
            .derive(self.vertex_id() as u64)
            .derive(self.superstep.wrapping_add(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DistGraph, GraphBuilder};

    struct Probe;
    impl VertexProgram for Probe {
        type V = u32;
        type M = u64;
        fn init(&self, _v: VertexId, _d: u32) -> u32 {
            0
        }
        fn compute(&self, _ctx: &mut VertexContext<'_, Self>) {}
    }

    /// 0 -> 1 (same partition), 0 -> 2 and 0 -> 3 (remote partition).
    fn two_part_graph() -> DistGraph {
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1, 1.0);
        b.add_edge(0, 2, 2.0);
        b.add_edge(0, 3, 3.0);
        let g = b.build();
        DistGraph::new(&g, &[0, 0, 1, 1], 2)
    }

    /// Drive `f` against a context for local vertex 0 of partition 0
    /// with the given location table, returning the resolved sends as
    /// `(dest_part, dest_local, msg)`.
    fn collect_sends(
        dg: &DistGraph,
        location: &[(u32, u32)],
        f: impl FnOnce(&mut VertexContext<'_, Probe>),
    ) -> Vec<(u32, u32, u64)> {
        let mut value = 0u32;
        let mut halted = false;
        let mut out = SendBuffer::new();
        let mut aggs = Aggregators::new(Vec::new());
        let mut ctx = VertexContext::<Probe> {
            part: &dg.parts[0],
            lv: 0,
            superstep: 1,
            value: &mut value,
            messages: &[],
            halted: &mut halted,
            out: &mut out,
            aggregators: &mut aggs,
            seed: 1,
            location,
        };
        f(&mut ctx);
        out.sends.iter().map(|&(r, m)| (r.part(), r.local(), m)).collect()
    }

    /// The acceptance contract of the resolved-route send plane: the
    /// location table handed to the context is EMPTY, so any
    /// `dg.routing.location` consultation would panic — edge-directed sends must
    /// resolve purely from the edges' precomputed routes, and the buffer
    /// must contain the fully-resolved `(part, local)` destinations.
    #[test]
    fn edge_directed_sends_resolve_without_location_lookup() {
        let dg = two_part_graph();
        let sends = collect_sends(&dg, &[], |ctx| ctx.send_to_neighbors(7));
        assert_eq!(sends, vec![(0, 1, 7), (1, 0, 7), (1, 1, 7)]);
        let sends =
            collect_sends(&dg, &[], |ctx| ctx.send_along_edges(|e| Some(e.weight as u64)));
        assert_eq!(sends, vec![(0, 1, 1), (1, 0, 2), (1, 1, 3)]);
    }

    /// `send_to_neighbors` must deliver exactly what the equivalent
    /// `send_along_edges` delivers: same routes, same order, same count
    /// (the former per-call `Vec<VertexId>` collection is gone).
    #[test]
    fn send_to_neighbors_matches_send_along_edges_delivery() {
        let dg = two_part_graph();
        let a = collect_sends(&dg, &[], |ctx| ctx.send_to_neighbors(9));
        let b = collect_sends(&dg, &[], |ctx| ctx.send_along_edges(|_| Some(9)));
        assert_eq!(a, b);
        assert_eq!(a.len(), dg.parts[0].out_degree[0] as usize);
    }

    /// Arbitrary-destination `send` resolves through the location table
    /// once, at enqueue.
    #[test]
    fn arbitrary_send_resolves_once_at_enqueue() {
        let dg = two_part_graph();
        let sends = collect_sends(&dg, &dg.routing.location, |ctx| ctx.send(3, 42));
        assert_eq!(sends, vec![(1, 1, 42)]);
    }
}
