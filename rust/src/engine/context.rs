//! The per-vertex compute context: what a `Compute()` invocation can see
//! and do (paper §3).

use crate::graph::{Edge, PartGraph, VertexId};
use crate::util::Rng;

use super::aggregator::Aggregators;
use super::program::VertexProgram;

/// Sends collected during one `compute` invocation; the engine routes
/// them afterwards (destination may be any vertex id, not only a
/// neighbor, as in Pregel).
pub struct SendBuffer<M> {
    /// (destination, message) pairs in send order.
    pub sends: Vec<(VertexId, M)>,
}

impl<M> SendBuffer<M> {
    /// An empty buffer.
    pub fn new() -> Self {
        SendBuffer { sends: Vec::new() }
    }

    /// Drop all queued sends, keeping the allocation.
    pub fn clear(&mut self) {
        self.sends.clear();
    }
}

impl<M> Default for SendBuffer<M> {
    fn default() -> Self {
        Self::new()
    }
}

/// The context handed to [`VertexProgram::compute`].
pub struct VertexContext<'a, P: VertexProgram> {
    pub(crate) part: &'a PartGraph,
    /// Local index of the vertex within the partition.
    pub(crate) lv: usize,
    /// Superstep counter exposed to the program. Engines map their notion
    /// of progress onto it (global iteration index for GraphHP, as §5.3).
    pub(crate) superstep: u64,
    pub(crate) value: &'a mut P::V,
    pub(crate) messages: &'a [P::M],
    pub(crate) halted: &'a mut bool,
    pub(crate) out: &'a mut SendBuffer<P::M>,
    pub(crate) aggregators: &'a mut Aggregators,
    pub(crate) seed: u64,
}

impl<'a, P: VertexProgram> VertexContext<'a, P> {
    /// Global id of this vertex.
    pub fn vertex_id(&self) -> VertexId {
        self.part.global_ids[self.lv]
    }

    /// The partition this vertex lives in (topology + metadata).
    pub fn partition(&self) -> &PartGraph {
        self.part
    }

    /// Superstep (Hama) / global iteration (GraphHP) counter —
    /// `getSuperstepCount()`.
    pub fn superstep(&self) -> u64 {
        self.superstep
    }

    /// `getValue()`.
    pub fn value(&self) -> &P::V {
        self.value
    }

    /// `setValue()`.
    pub fn set_value(&mut self, v: P::V) {
        *self.value = v;
    }

    /// Mutable access to the value (ergonomic alternative to get+set).
    pub fn value_mut(&mut self) -> &mut P::V {
        self.value
    }

    /// Messages delivered to this vertex for this (pseudo-)superstep.
    pub fn messages(&self) -> &[P::M] {
        self.messages
    }

    /// Out-edges of this vertex (targets + weights + location hints).
    pub fn edges(&self) -> &[Edge] {
        self.part.out_edges(self.lv)
    }

    /// Out-degree.
    pub fn out_degree(&self) -> u32 {
        self.part.out_degree[self.lv]
    }

    /// Whether this vertex is a boundary vertex (Definition 1). Exposed
    /// for diagnostics; correct programs don't need it.
    pub fn is_boundary(&self) -> bool {
        self.part.is_boundary[self.lv]
    }

    /// `sendMessage(dest, msg)` — dest may be any vertex.
    pub fn send(&mut self, dest: VertexId, msg: P::M) {
        self.out.sends.push((dest, msg));
    }

    /// Send `msg` along every out-edge.
    pub fn send_to_neighbors(&mut self, msg: P::M) {
        // routed by the engine; we just record (target, msg) pairs
        let targets: Vec<VertexId> =
            self.part.out_edges(self.lv).iter().map(|e| e.target).collect();
        for t in targets {
            self.out.sends.push((t, msg.clone()));
        }
    }

    /// Send one message per out-edge, computed from the edge (no
    /// intermediate allocation — the hot path of SSSP/PageRank).
    pub fn send_along_edges(&mut self, f: impl Fn(&Edge) -> Option<P::M>) {
        let (s, e) = (self.part.offsets[self.lv], self.part.offsets[self.lv + 1]);
        for i in s..e {
            let edge = self.part.edges[i];
            if let Some(m) = f(&edge) {
                self.out.sends.push((edge.target, m));
            }
        }
    }

    /// `voteToHalt()`.
    pub fn vote_to_halt(&mut self) {
        *self.halted = true;
    }

    /// Submit to aggregator `id` (visible at the next superstep).
    pub fn aggregate(&mut self, id: usize, v: f64) {
        self.aggregators.submit(id, v);
    }

    /// Reduced aggregator value from the previous superstep.
    pub fn aggregated(&self, id: usize) -> f64 {
        self.aggregators.previous(id)
    }

    /// Deterministic per-(vertex, superstep) RNG — for randomized
    /// programs like bipartite matching.
    pub fn rng(&self) -> Rng {
        Rng::new(self.seed)
            .derive(self.vertex_id() as u64)
            .derive(self.superstep.wrapping_add(1))
    }
}
