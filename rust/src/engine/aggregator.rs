//! Aggregators: the global communication/monitoring mechanism of the BSP
//! interface (paper §3). Vertices submit values during superstep S; the
//! reduced value is visible to every vertex at superstep S+1.

/// Reduce operation of an aggregator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggOp {
    /// Sum of all submissions.
    Sum,
    /// Minimum submission.
    Min,
    /// Maximum submission.
    Max,
}

impl AggOp {
    /// The fold's neutral element (0, +∞, −∞ respectively).
    pub fn identity(self) -> f64 {
        match self {
            AggOp::Sum => 0.0,
            AggOp::Min => f64::INFINITY,
            AggOp::Max => f64::NEG_INFINITY,
        }
    }

    /// Reduce two values under this operation.
    pub fn fold(self, a: f64, b: f64) -> f64 {
        match self {
            AggOp::Sum => a + b,
            AggOp::Min => a.min(b),
            AggOp::Max => a.max(b),
        }
    }
}

/// A set of named-by-index f64 aggregators with double buffering:
/// `current` accumulates this superstep's submissions, `previous` holds
/// the reduced values from the last superstep.
#[derive(Clone, Debug)]
pub struct Aggregators {
    ops: Vec<AggOp>,
    current: Vec<f64>,
    previous: Vec<f64>,
}

impl Aggregators {
    /// A fresh set with one aggregator per op, both buffers at identity.
    pub fn new(ops: Vec<AggOp>) -> Self {
        let current = ops.iter().map(|o| o.identity()).collect();
        let previous = ops.iter().map(|o| o.identity()).collect();
        Aggregators { ops, current, previous }
    }

    /// Number of aggregators.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// True when no aggregator is registered.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Submit a value to aggregator `id` (called from vertex compute).
    pub fn submit(&mut self, id: usize, v: f64) {
        self.current[id] = self.ops[id].fold(self.current[id], v);
    }

    /// Value reduced during the previous superstep.
    pub fn previous(&self, id: usize) -> f64 {
        self.previous[id]
    }

    /// Barrier: flip current -> previous, reset current to identities.
    pub fn barrier(&mut self) {
        for i in 0..self.ops.len() {
            self.previous[i] = self.current[i];
            self.current[i] = self.ops[i].identity();
        }
    }

    /// Merge another worker's partial accumulations into this (master)
    /// set's current buffer.
    pub fn merge_current(&mut self, other: &Aggregators) {
        for i in 0..self.ops.len() {
            self.current[i] = self.ops[i].fold(self.current[i], other.current[i]);
        }
    }

    /// A scratch copy for a parallel worker: same ops, same visible
    /// `previous` values (so `aggregated()` reads are unchanged), but
    /// `current` reset to identities — its partials fold back into the
    /// master with [`merge_current`](Self::merge_current).
    pub fn fresh(&self) -> Aggregators {
        Aggregators {
            ops: self.ops.clone(),
            current: self.ops.iter().map(|o| o.identity()).collect(),
            previous: self.previous.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sum_min_max_fold() {
        let mut a = Aggregators::new(vec![AggOp::Sum, AggOp::Min, AggOp::Max]);
        a.submit(0, 1.0);
        a.submit(0, 2.0);
        a.submit(1, 5.0);
        a.submit(1, 3.0);
        a.submit(2, 5.0);
        a.submit(2, 7.0);
        a.barrier();
        assert_eq!(a.previous(0), 3.0);
        assert_eq!(a.previous(1), 3.0);
        assert_eq!(a.previous(2), 7.0);
        // fresh accumulation after barrier
        a.barrier();
        assert_eq!(a.previous(0), 0.0);
        assert_eq!(a.previous(1), f64::INFINITY);
    }

    #[test]
    fn fresh_keeps_previous_but_resets_current() {
        let mut master = Aggregators::new(vec![AggOp::Sum]);
        master.submit(0, 2.0);
        master.barrier(); // previous = 2.0
        master.submit(0, 5.0); // pending in current
        let f = master.fresh();
        assert_eq!(f.previous(0), 2.0, "scratch copy sees the reduced value");
        // merging the untouched scratch back must not duplicate the 5.0
        master.merge_current(&f);
        master.barrier();
        assert_eq!(master.previous(0), 5.0);
    }

    #[test]
    fn merge_across_workers() {
        let mut master = Aggregators::new(vec![AggOp::Sum, AggOp::Min]);
        let mut w1 = Aggregators::new(vec![AggOp::Sum, AggOp::Min]);
        let mut w2 = Aggregators::new(vec![AggOp::Sum, AggOp::Min]);
        w1.submit(0, 2.0);
        w1.submit(1, 9.0);
        w2.submit(0, 3.0);
        w2.submit(1, 4.0);
        master.merge_current(&w1);
        master.merge_current(&w2);
        master.barrier();
        assert_eq!(master.previous(0), 5.0);
        assert_eq!(master.previous(1), 4.0);
    }
}
