//! Fault tolerance via checkpointing (paper §5.3).
//!
//! At a checkpoint the master instructs workers to persist their
//! partition state; when a worker fails (detected by missed pings in the
//! paper; injected deterministically here), its partitions are reassigned
//! and ALL workers reload the most recent checkpoint, rolling the
//! computation back to a consistent global iteration.
//!
//! A checkpoint of the hybrid engine is taken at an iteration boundary.
//! Each partition's state there is: vertex values, halt flags, the
//! global-phase inbox, **the local-phase runtime state** — the
//! `cur`/`nxt` inboxes and the scheduled frontier — and the
//! hybrid-scheduler state ([`PolicyCheckpoint`]). The local-phase
//! queues are empty between iterations when the local phase runs to
//! quiescence, but a `max_pseudo_supersteps`-truncated phase carries
//! its remaining frontier and in-flight mail across the boundary
//! (`PartitionRuntime::abort_step_carryover`); a snapshot that dropped
//! them would recover into a state the clean run never visits. The same
//! holds for the adaptive scheduler's per-partition caps/streaks/skip
//! flags: without them, rolled-back iterations would replay under a
//! schedule the clean run never executed.

use std::path::Path;

use anyhow::{Context, Result};

use crate::graph::MigrationPlan;
use crate::util::Codec;

/// One partition's hybrid-scheduler state. This is the GraphHP engine's
/// live per-partition policy (static policies hold their constant
/// knobs, adaptive ones their evolved state), persisted verbatim in
/// checkpoints so a recovered run replays exactly the schedule the
/// checkpointed run would have executed — without it, rolled-back
/// iterations would replay under policy state adapted by the aborted
/// timeline and the recovered trajectory could diverge from a clean
/// run. The controller's update rules live in `engine/graphhp.rs`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct PolicyCheckpoint {
    /// Run the local phase next iteration?
    pub run_local: bool,
    /// Pseudo-superstep cap of the partition.
    pub cap: u64,
    /// Do the partition's boundary vertices join its local phases?
    pub boundary_in_local: bool,
    /// Locality-derived default to restore after clean iterations.
    pub preferred_boundary: bool,
    /// Consecutive thrashing carryovers observed.
    pub carryover_streak: u32,
    /// Consecutive carryover-free iterations observed.
    pub clean_streak: u32,
}

impl Codec for PolicyCheckpoint {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.run_local.encode(buf);
        self.cap.encode(buf);
        self.boundary_in_local.encode(buf);
        self.preferred_boundary.encode(buf);
        self.carryover_streak.encode(buf);
        self.clean_streak.encode(buf);
    }
    fn decode(r: &mut &[u8]) -> Option<Self> {
        Some(PolicyCheckpoint {
            run_local: bool::decode(r)?,
            cap: u64::decode(r)?,
            boundary_in_local: bool::decode(r)?,
            preferred_boundary: bool::decode(r)?,
            carryover_streak: u32::decode(r)?,
            clean_streak: u32::decode(r)?,
        })
    }
}

/// A consistent snapshot of an engine run at an iteration boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint<V, M> {
    /// Global iteration the snapshot was taken at.
    pub iteration: u64,
    /// Per partition: vertex values.
    pub values: Vec<Vec<V>>,
    /// Per partition: halt flags.
    pub halted: Vec<Vec<bool>>,
    /// Per partition: pending global-phase messages as
    /// (local vertex, queue) pairs.
    pub inbox: Vec<Vec<(u32, Vec<M>)>>,
    /// Per partition: the local-phase `cur` inbox (normally empty at a
    /// boundary; live after a cap-truncated local phase).
    pub local_cur: Vec<Vec<(u32, Vec<M>)>>,
    /// Per partition: the local-phase `nxt` inbox (ditto — this is
    /// where carryover mail waits for the next phase's swap).
    pub local_nxt: Vec<Vec<(u32, Vec<M>)>>,
    /// Per partition: the scheduled local-phase frontier, in insertion
    /// order.
    pub frontier: Vec<Vec<u32>>,
    /// Per partition: the hybrid-scheduler state (see
    /// [`PolicyCheckpoint`]).
    pub policy: Vec<PolicyCheckpoint>,
    /// Every [`MigrationPlan`] applied before this snapshot, in epoch
    /// order. Recovery replays the trajectory onto the pristine graph to
    /// rebuild the exact routing geometry the per-partition arrays were
    /// snapshotted under — the failure may have happened epochs ahead of
    /// the checkpoint, and without the trajectory the array shapes would
    /// not even line up.
    pub migrations: Vec<MigrationPlan>,
}

impl<V: Codec + Clone, M: Codec + Clone> Checkpoint<V, M> {
    /// Serialize with the crate's little-endian [`Codec`].
    pub fn encode_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        self.iteration.encode(&mut buf);
        (self.values.len() as u64).encode(&mut buf);
        for p in 0..self.values.len() {
            self.values[p].encode(&mut buf);
            self.halted[p].encode(&mut buf);
            self.inbox[p].encode(&mut buf);
            self.local_cur[p].encode(&mut buf);
            self.local_nxt[p].encode(&mut buf);
            self.frontier[p].encode(&mut buf);
            self.policy[p].encode(&mut buf);
        }
        self.migrations.encode(&mut buf);
        buf
    }

    /// Inverse of [`encode_bytes`](Self::encode_bytes); `None` on
    /// truncated or malformed input.
    pub fn decode_bytes(mut r: &[u8]) -> Option<Self> {
        let r = &mut r;
        let iteration = u64::decode(r)?;
        let np = u64::decode(r)? as usize;
        let mut values = Vec::with_capacity(np);
        let mut halted = Vec::with_capacity(np);
        let mut inbox = Vec::with_capacity(np);
        let mut local_cur = Vec::with_capacity(np);
        let mut local_nxt = Vec::with_capacity(np);
        let mut frontier = Vec::with_capacity(np);
        let mut policy = Vec::with_capacity(np);
        for _ in 0..np {
            values.push(Vec::<V>::decode(r)?);
            halted.push(Vec::<bool>::decode(r)?);
            inbox.push(Vec::<(u32, Vec<M>)>::decode(r)?);
            local_cur.push(Vec::<(u32, Vec<M>)>::decode(r)?);
            local_nxt.push(Vec::<(u32, Vec<M>)>::decode(r)?);
            frontier.push(Vec::<u32>::decode(r)?);
            policy.push(PolicyCheckpoint::decode(r)?);
        }
        let migrations = Vec::<MigrationPlan>::decode(r)?;
        Some(Checkpoint {
            iteration,
            values,
            halted,
            inbox,
            local_cur,
            local_nxt,
            frontier,
            policy,
            migrations,
        })
    }

    /// Persist to `dir/ckpt_<iteration>.bin`.
    pub fn save(&self, dir: &Path) -> Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("ckpt_{:08}.bin", self.iteration));
        std::fs::write(&path, self.encode_bytes()).with_context(|| format!("write {path:?}"))?;
        Ok(path)
    }

    /// Load the latest checkpoint in `dir`, if any.
    pub fn load_latest(dir: &Path) -> Result<Option<Self>> {
        if !dir.exists() {
            return Ok(None);
        }
        let mut ckpts: Vec<std::path::PathBuf> = std::fs::read_dir(dir)?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("ckpt_") && n.ends_with(".bin"))
            })
            .collect();
        ckpts.sort();
        let Some(path) = ckpts.pop() else {
            return Ok(None);
        };
        let bytes = std::fs::read(&path)?;
        Ok(Some(
            Self::decode_bytes(&bytes)
                .with_context(|| format!("corrupt checkpoint {path:?}"))?,
        ))
    }
}

impl<A: Codec, B: Codec, C: Codec> Codec for (A, B, C) {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.0.encode(buf);
        self.1.encode(buf);
        self.2.encode(buf);
    }
    fn decode(r: &mut &[u8]) -> Option<Self> {
        Some((A::decode(r)?, B::decode(r)?, C::decode(r)?))
    }
    fn encoded_len(&self) -> usize {
        self.0.encoded_len() + self.1.encoded_len() + self.2.encoded_len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint<f32, u32> {
        Checkpoint {
            iteration: 7,
            values: vec![vec![1.0, 2.0], vec![3.0]],
            halted: vec![vec![true, false], vec![true]],
            inbox: vec![vec![(0, vec![9, 8])], vec![]],
            local_cur: vec![vec![], vec![(0, vec![5])]],
            local_nxt: vec![vec![(1, vec![6, 7])], vec![]],
            frontier: vec![vec![1, 0], vec![]],
            policy: vec![
                PolicyCheckpoint {
                    run_local: true,
                    cap: 16,
                    boundary_in_local: true,
                    preferred_boundary: true,
                    carryover_streak: 1,
                    clean_streak: 0,
                },
                PolicyCheckpoint { run_local: false, cap: 1, ..Default::default() },
            ],
            migrations: vec![
                MigrationPlan { epoch: 1, moves: vec![(2, 1), (5, 0)] },
                MigrationPlan { epoch: 2, moves: vec![(3, 1)] },
            ],
        }
    }

    #[test]
    fn bytes_roundtrip() {
        let c = sample();
        let b = c.encode_bytes();
        let d = Checkpoint::<f32, u32>::decode_bytes(&b).unwrap();
        assert_eq!(c, d);
    }

    #[test]
    fn roundtrip_preserves_local_phase_state() {
        // the carryover fields must survive encode/decode untouched —
        // they are exactly what a cap-truncated local phase leaves live
        let c = sample();
        let d = Checkpoint::<f32, u32>::decode_bytes(&c.encode_bytes()).unwrap();
        assert_eq!(d.local_cur, vec![vec![], vec![(0, vec![5])]]);
        assert_eq!(d.local_nxt, vec![vec![(1, vec![6, 7])], vec![]]);
        assert_eq!(d.frontier, vec![vec![1, 0], vec![]], "insertion order kept");
        assert_eq!(d.policy, c.policy, "scheduler state survives the roundtrip");
        assert_eq!(d.policy[0].cap, 16);
        assert!(!d.policy[1].run_local);
        assert_eq!(
            d.migrations, c.migrations,
            "the applied-plan trajectory survives the roundtrip"
        );
        assert_eq!(d.migrations[1].epoch, 2);
    }

    #[test]
    fn file_roundtrip_and_latest() {
        let dir = std::env::temp_dir().join("graphhp_ckpt_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut c = sample();
        c.iteration = 3;
        c.save(&dir).unwrap();
        let mut c2 = sample();
        c2.iteration = 12;
        c2.values[0][0] = 42.0;
        c2.save(&dir).unwrap();
        let latest = Checkpoint::<f32, u32>::load_latest(&dir).unwrap().unwrap();
        assert_eq!(latest.iteration, 12);
        assert_eq!(latest.values[0][0], 42.0);
    }

    #[test]
    fn empty_dir_gives_none() {
        let dir = std::env::temp_dir().join("graphhp_ckpt_none");
        let _ = std::fs::remove_dir_all(&dir);
        assert!(Checkpoint::<f32, u32>::load_latest(&dir).unwrap().is_none());
    }

    #[test]
    fn corrupt_file_is_an_error() {
        let dir = std::env::temp_dir().join("graphhp_ckpt_corrupt");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("ckpt_00000001.bin"), b"garbage").unwrap();
        assert!(Checkpoint::<f32, u32>::load_latest(&dir).is_err());
    }
}
