//! Fault tolerance via checkpointing (paper §5.3).
//!
//! At a checkpoint the master instructs workers to persist their
//! partition state; when a worker fails (detected by missed pings in the
//! paper; injected deterministically here), its partitions are reassigned
//! and ALL workers reload the most recent checkpoint, rolling the
//! computation back to a consistent global iteration.
//!
//! A checkpoint of the hybrid engine is taken at an iteration boundary.
//! Each partition's state there is: vertex values, halt flags, the
//! global-phase inbox, **the local-phase runtime state** — the
//! `cur`/`nxt` inboxes and the scheduled frontier — and the
//! hybrid-scheduler state ([`PolicyCheckpoint`]). The local-phase
//! queues are empty between iterations when the local phase runs to
//! quiescence, but a `max_pseudo_supersteps`-truncated phase carries
//! its remaining frontier and in-flight mail across the boundary
//! (`PartitionRuntime::abort_step_carryover`); a snapshot that dropped
//! them would recover into a state the clean run never visits. The same
//! holds for the adaptive scheduler's per-partition caps/streaks/skip
//! flags: without them, rolled-back iterations would replay under a
//! schedule the clean run never executed.
//!
//! The [`Checkpoint`] container is shared by **every** barrier engine,
//! not just GraphHP: the push engines (Hama, AM-Hama, Giraph++)
//! snapshot their generalized worker state into the same structure via
//! `engine/recovery.rs` (the GraphHP-specific `PolicyCheckpoint` slots
//! simply stay at their defaults there), and the rollback/replay
//! lifecycle is driven by the shared `RecoveryCoordinator`.
//! GraphLab-sync checkpoints in memory only (its GAS value types carry
//! no [`Codec`] bound) — see `engine/recovery.rs`.

use std::path::Path;

use anyhow::{Context, Result};

use crate::graph::MigrationPlan;
use crate::util::Codec;

/// One partition's hybrid-scheduler state. This is the GraphHP engine's
/// live per-partition policy (static policies hold their constant
/// knobs, adaptive ones their evolved state), persisted verbatim in
/// checkpoints so a recovered run replays exactly the schedule the
/// checkpointed run would have executed — without it, rolled-back
/// iterations would replay under policy state adapted by the aborted
/// timeline and the recovered trajectory could diverge from a clean
/// run. The controller's update rules live in `engine/graphhp.rs`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct PolicyCheckpoint {
    /// Run the local phase next iteration?
    pub run_local: bool,
    /// Pseudo-superstep cap of the partition.
    pub cap: u64,
    /// Do the partition's boundary vertices join its local phases?
    pub boundary_in_local: bool,
    /// Locality-derived default to restore after clean iterations.
    pub preferred_boundary: bool,
    /// Consecutive thrashing carryovers observed.
    pub carryover_streak: u32,
    /// Consecutive carryover-free iterations observed.
    pub clean_streak: u32,
}

impl Codec for PolicyCheckpoint {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.run_local.encode(buf);
        self.cap.encode(buf);
        self.boundary_in_local.encode(buf);
        self.preferred_boundary.encode(buf);
        self.carryover_streak.encode(buf);
        self.clean_streak.encode(buf);
    }
    fn decode(r: &mut &[u8]) -> Option<Self> {
        Some(PolicyCheckpoint {
            run_local: bool::decode(r)?,
            cap: u64::decode(r)?,
            boundary_in_local: bool::decode(r)?,
            preferred_boundary: bool::decode(r)?,
            carryover_streak: u32::decode(r)?,
            clean_streak: u32::decode(r)?,
        })
    }
}

/// A consistent snapshot of an engine run at an iteration boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint<V, M> {
    /// Global iteration the snapshot was taken at.
    pub iteration: u64,
    /// Per partition: vertex values.
    pub values: Vec<Vec<V>>,
    /// Per partition: halt flags.
    pub halted: Vec<Vec<bool>>,
    /// Per partition: pending global-phase messages as
    /// (local vertex, queue) pairs.
    pub inbox: Vec<Vec<(u32, Vec<M>)>>,
    /// Per partition: the local-phase `cur` inbox (normally empty at a
    /// boundary; live after a cap-truncated local phase).
    pub local_cur: Vec<Vec<(u32, Vec<M>)>>,
    /// Per partition: the local-phase `nxt` inbox (ditto — this is
    /// where carryover mail waits for the next phase's swap).
    pub local_nxt: Vec<Vec<(u32, Vec<M>)>>,
    /// Per partition: the scheduled local-phase frontier, in insertion
    /// order.
    pub frontier: Vec<Vec<u32>>,
    /// Per partition: the hybrid-scheduler state (see
    /// [`PolicyCheckpoint`]).
    pub policy: Vec<PolicyCheckpoint>,
    /// Every [`MigrationPlan`] applied before this snapshot, in epoch
    /// order. Recovery replays the trajectory onto the pristine graph to
    /// rebuild the exact routing geometry the per-partition arrays were
    /// snapshotted under — the failure may have happened epochs ahead of
    /// the checkpoint, and without the trajectory the array shapes would
    /// not even line up.
    pub migrations: Vec<MigrationPlan>,
}

/// Header magic of the on-disk checkpoint format ("GHCK").
const CKPT_MAGIC: u32 = 0x4748_434B;
/// On-disk format version; bumped on any layout change.
const CKPT_VERSION: u32 = 1;

/// FNV-1a 64 over the payload — the integrity check that turns any
/// truncation or bit flip into a clean `None` instead of a decode of
/// garbage that happens to parse. Not cryptographic; it only has to
/// catch accidental corruption (the chaos suite's corrupt-checkpoint
/// schedule flips random bits and expects loud rejection).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h = (h ^ u64::from(b)).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

impl<V: Codec + Clone, M: Codec + Clone> Checkpoint<V, M> {
    /// Serialize with the crate's little-endian [`Codec`], framed by an
    /// integrity header: magic, version, payload length, FNV-1a 64
    /// payload checksum. [`decode_bytes`](Self::decode_bytes) verifies
    /// the frame before touching the payload, so corrupt bytes are
    /// rejected instead of decoded.
    pub fn encode_bytes(&self) -> Vec<u8> {
        let mut payload = Vec::new();
        self.iteration.encode(&mut payload);
        (self.values.len() as u64).encode(&mut payload);
        for p in 0..self.values.len() {
            self.values[p].encode(&mut payload);
            self.halted[p].encode(&mut payload);
            self.inbox[p].encode(&mut payload);
            self.local_cur[p].encode(&mut payload);
            self.local_nxt[p].encode(&mut payload);
            self.frontier[p].encode(&mut payload);
            self.policy[p].encode(&mut payload);
        }
        self.migrations.encode(&mut payload);
        let mut buf = Vec::with_capacity(payload.len() + 24);
        CKPT_MAGIC.encode(&mut buf);
        CKPT_VERSION.encode(&mut buf);
        (payload.len() as u64).encode(&mut buf);
        fnv1a64(&payload).encode(&mut buf);
        buf.extend_from_slice(&payload);
        buf
    }

    /// Inverse of [`encode_bytes`](Self::encode_bytes); `None` on
    /// truncated, bit-flipped or otherwise malformed input — never a
    /// panic. The header (magic, version, exact payload length, FNV-1a
    /// checksum) is verified before any payload field is decoded.
    pub fn decode_bytes(mut r: &[u8]) -> Option<Self> {
        let r = &mut r;
        if u32::decode(r)? != CKPT_MAGIC || u32::decode(r)? != CKPT_VERSION {
            return None;
        }
        let len = u64::decode(r)? as usize;
        let sum = u64::decode(r)?;
        if r.len() != len || fnv1a64(r) != sum {
            return None;
        }
        let iteration = u64::decode(r)?;
        let np = u64::decode(r)? as usize;
        let mut values = Vec::with_capacity(np);
        let mut halted = Vec::with_capacity(np);
        let mut inbox = Vec::with_capacity(np);
        let mut local_cur = Vec::with_capacity(np);
        let mut local_nxt = Vec::with_capacity(np);
        let mut frontier = Vec::with_capacity(np);
        let mut policy = Vec::with_capacity(np);
        for _ in 0..np {
            values.push(Vec::<V>::decode(r)?);
            halted.push(Vec::<bool>::decode(r)?);
            inbox.push(Vec::<(u32, Vec<M>)>::decode(r)?);
            local_cur.push(Vec::<(u32, Vec<M>)>::decode(r)?);
            local_nxt.push(Vec::<(u32, Vec<M>)>::decode(r)?);
            frontier.push(Vec::<u32>::decode(r)?);
            policy.push(PolicyCheckpoint::decode(r)?);
        }
        let migrations = Vec::<MigrationPlan>::decode(r)?;
        Some(Checkpoint {
            iteration,
            values,
            halted,
            inbox,
            local_cur,
            local_nxt,
            frontier,
            policy,
            migrations,
        })
    }

    /// Persist to `dir/ckpt_<iteration>.bin`.
    pub fn save(&self, dir: &Path) -> Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("ckpt_{:08}.bin", self.iteration));
        std::fs::write(&path, self.encode_bytes()).with_context(|| format!("write {path:?}"))?;
        Ok(path)
    }

    /// Load the latest checkpoint in `dir`, if any.
    pub fn load_latest(dir: &Path) -> Result<Option<Self>> {
        if !dir.exists() {
            return Ok(None);
        }
        let mut ckpts = checkpoint_files(dir)?;
        let Some(path) = ckpts.pop() else {
            return Ok(None);
        };
        let bytes = std::fs::read(&path)?;
        Ok(Some(
            Self::decode_bytes(&bytes)
                .with_context(|| format!("corrupt checkpoint {path:?}"))?,
        ))
    }
}

/// List `dir`'s checkpoint files (`ckpt_*.bin`) in ascending iteration
/// order — the zero-padded filenames make lexicographic order iteration
/// order.
fn checkpoint_files(dir: &Path) -> Result<Vec<std::path::PathBuf>> {
    let mut ckpts: Vec<std::path::PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("ckpt_") && n.ends_with(".bin"))
        })
        .collect();
    ckpts.sort();
    Ok(ckpts)
}

/// Retention: delete all but the newest `keep` checkpoint files in
/// `dir`. Recovery only ever loads the newest, so older files are pure
/// disk growth; `keep` is floored at 1 so the newest always survives.
/// Returns how many files were removed. A missing directory is a no-op.
pub fn prune_checkpoints(dir: &Path, keep: usize) -> Result<usize> {
    if !dir.exists() {
        return Ok(0);
    }
    let mut ckpts = checkpoint_files(dir)?;
    let keep = keep.max(1);
    if ckpts.len() <= keep {
        return Ok(0);
    }
    let drop_n = ckpts.len() - keep;
    let mut removed = 0usize;
    for path in ckpts.drain(..drop_n) {
        std::fs::remove_file(&path).with_context(|| format!("prune {path:?}"))?;
        removed += 1;
    }
    Ok(removed)
}

impl<A: Codec, B: Codec, C: Codec> Codec for (A, B, C) {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.0.encode(buf);
        self.1.encode(buf);
        self.2.encode(buf);
    }
    fn decode(r: &mut &[u8]) -> Option<Self> {
        Some((A::decode(r)?, B::decode(r)?, C::decode(r)?))
    }
    fn encoded_len(&self) -> usize {
        self.0.encoded_len() + self.1.encoded_len() + self.2.encoded_len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint<f32, u32> {
        Checkpoint {
            iteration: 7,
            values: vec![vec![1.0, 2.0], vec![3.0]],
            halted: vec![vec![true, false], vec![true]],
            inbox: vec![vec![(0, vec![9, 8])], vec![]],
            local_cur: vec![vec![], vec![(0, vec![5])]],
            local_nxt: vec![vec![(1, vec![6, 7])], vec![]],
            frontier: vec![vec![1, 0], vec![]],
            policy: vec![
                PolicyCheckpoint {
                    run_local: true,
                    cap: 16,
                    boundary_in_local: true,
                    preferred_boundary: true,
                    carryover_streak: 1,
                    clean_streak: 0,
                },
                PolicyCheckpoint { run_local: false, cap: 1, ..Default::default() },
            ],
            migrations: vec![
                MigrationPlan { epoch: 1, moves: vec![(2, 1), (5, 0)] },
                MigrationPlan { epoch: 2, moves: vec![(3, 1)] },
            ],
        }
    }

    #[test]
    fn bytes_roundtrip() {
        let c = sample();
        let b = c.encode_bytes();
        let d = Checkpoint::<f32, u32>::decode_bytes(&b).unwrap();
        assert_eq!(c, d);
    }

    #[test]
    fn roundtrip_preserves_local_phase_state() {
        // the carryover fields must survive encode/decode untouched —
        // they are exactly what a cap-truncated local phase leaves live
        let c = sample();
        let d = Checkpoint::<f32, u32>::decode_bytes(&c.encode_bytes()).unwrap();
        assert_eq!(d.local_cur, vec![vec![], vec![(0, vec![5])]]);
        assert_eq!(d.local_nxt, vec![vec![(1, vec![6, 7])], vec![]]);
        assert_eq!(d.frontier, vec![vec![1, 0], vec![]], "insertion order kept");
        assert_eq!(d.policy, c.policy, "scheduler state survives the roundtrip");
        assert_eq!(d.policy[0].cap, 16);
        assert!(!d.policy[1].run_local);
        assert_eq!(
            d.migrations, c.migrations,
            "the applied-plan trajectory survives the roundtrip"
        );
        assert_eq!(d.migrations[1].epoch, 2);
    }

    #[test]
    fn file_roundtrip_and_latest() {
        let dir = std::env::temp_dir().join("graphhp_ckpt_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut c = sample();
        c.iteration = 3;
        c.save(&dir).unwrap();
        let mut c2 = sample();
        c2.iteration = 12;
        c2.values[0][0] = 42.0;
        c2.save(&dir).unwrap();
        let latest = Checkpoint::<f32, u32>::load_latest(&dir).unwrap().unwrap();
        assert_eq!(latest.iteration, 12);
        assert_eq!(latest.values[0][0], 42.0);
    }

    #[test]
    fn empty_dir_gives_none() {
        let dir = std::env::temp_dir().join("graphhp_ckpt_none");
        let _ = std::fs::remove_dir_all(&dir);
        assert!(Checkpoint::<f32, u32>::load_latest(&dir).unwrap().is_none());
    }

    #[test]
    fn corrupt_file_is_an_error() {
        let dir = std::env::temp_dir().join("graphhp_ckpt_corrupt");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("ckpt_00000001.bin"), b"garbage").unwrap();
        assert!(Checkpoint::<f32, u32>::load_latest(&dir).is_err());
    }

    #[test]
    fn header_rejects_wrong_magic_or_version() {
        let c = sample();
        let mut b = c.encode_bytes();
        b[0] ^= 0xFF; // magic
        assert!(Checkpoint::<f32, u32>::decode_bytes(&b).is_none());
        let mut b = c.encode_bytes();
        b[4] ^= 0x01; // version
        assert!(Checkpoint::<f32, u32>::decode_bytes(&b).is_none());
    }

    #[test]
    fn every_truncation_is_rejected_without_panic() {
        let b = sample().encode_bytes();
        for cut in 0..b.len() {
            assert!(
                Checkpoint::<f32, u32>::decode_bytes(&b[..cut]).is_none(),
                "truncation at {cut} must be rejected"
            );
        }
    }

    #[test]
    fn prune_keeps_newest_and_load_latest_still_finds_it() {
        let dir = std::env::temp_dir().join("graphhp_ckpt_prune");
        let _ = std::fs::remove_dir_all(&dir);
        for it in [1u64, 4, 9, 12, 20] {
            let mut c = sample();
            c.iteration = it;
            c.values[0][0] = it as f32;
            c.save(&dir).unwrap();
        }
        assert_eq!(prune_checkpoints(&dir, 2).unwrap(), 3);
        let left = checkpoint_files(&dir).unwrap();
        assert_eq!(left.len(), 2);
        assert!(left[0].to_string_lossy().contains("ckpt_00000012"), "{left:?}");
        assert!(left[1].to_string_lossy().contains("ckpt_00000020"), "{left:?}");
        let latest = Checkpoint::<f32, u32>::load_latest(&dir).unwrap().unwrap();
        assert_eq!(latest.iteration, 20);
        assert_eq!(latest.values[0][0], 20.0);
        // already within budget: nothing more to remove
        assert_eq!(prune_checkpoints(&dir, 2).unwrap(), 0);
        // keep is floored at 1 — the newest always survives
        assert_eq!(prune_checkpoints(&dir, 0).unwrap(), 1);
        let latest = Checkpoint::<f32, u32>::load_latest(&dir).unwrap().unwrap();
        assert_eq!(latest.iteration, 20);
    }

    #[test]
    fn prune_missing_dir_is_a_noop() {
        let dir = std::env::temp_dir().join("graphhp_ckpt_prune_missing");
        let _ = std::fs::remove_dir_all(&dir);
        assert_eq!(prune_checkpoints(&dir, 3).unwrap(), 0);
    }
}
