//! GraphLab-style engines (the paper's §7.5 comparator).
//!
//! GraphLab's abstraction is pull-based: an update function reads the
//! values of adjacent vertices directly (no messages). We implement the
//! gather-apply-scatter form:
//!
//! - [`run_graphlab_sync`] — synchronous mode: rounds; every scheduled
//!   vertex gathers over its in-edges, applies, and (if its change is
//!   significant) schedules its out-neighbors for the next round. One
//!   barrier per round, like BSP.
//! - [`run_graphlab_async`] — asynchronous mode: a FIFO scheduler
//!   processes one vertex at a time with immediate visibility. Fewer
//!   updates to converge, but each update pays locking/scheduling
//!   overhead and parallel efficiency is reduced — reproducing the
//!   trade-off in Table 4 (the paper: "Async ... reduces the degree of
//!   parallelism due to the locking mechanism").
//!
//! Cross-partition gathers are charged as network reads in the simulated
//! cluster clock; the paper leaves `M` blank for GraphLab, and so do our
//! reports.

use std::collections::VecDeque;
use std::time::Duration;

use crate::graph::{Graph, VertexId};

use super::metrics::Metrics;
use super::netsim::SuperstepClock;
use super::{EngineConfig, RunResult};

/// The GraphLab-style update program (gather over in-edges, apply).
pub trait GasProgram: Sync {
    type V: Clone + Send + Sync;
    /// Gather accumulator.
    type G: Clone;

    fn init(&self, vertex: VertexId, out_degree: u32) -> Self::V;

    /// Contribution of in-neighbor `src` along an edge of weight `w`.
    fn gather(&self, src_value: &Self::V, src_out_degree: u32, w: f32) -> Self::G;

    fn merge(&self, a: Self::G, b: Self::G) -> Self::G;

    /// Apply the accumulated gather; return `true` when the change is
    /// significant enough to (re)schedule the out-neighbors.
    fn apply(&self, value: &mut Self::V, acc: Option<Self::G>) -> bool;
}

/// Cost constants of the GraphLab comparator (see module docs).
#[derive(Clone, Debug)]
pub struct GraphLabCost {
    /// Per-update lock acquisition/scheduling overhead in async mode (µs).
    pub async_lock_us: f64,
    /// Parallel efficiency of the async engine (0..1]: effective workers
    /// = parts × efficiency (lock contention on a shared graph).
    pub async_efficiency: f64,
    /// Per-remote-gather cost (µs) — reading a neighbor value across
    /// workers.
    pub remote_gather_us: f64,
}

impl Default for GraphLabCost {
    fn default() -> Self {
        GraphLabCost { async_lock_us: 6.0, async_efficiency: 0.5, remote_gather_us: 0.5 }
    }
}

/// In-edge CSR: for each vertex, (source, source_out_degree, weight).
struct InEdges {
    offsets: Vec<usize>,
    src: Vec<VertexId>,
    src_deg: Vec<u32>,
    w: Vec<f32>,
}

fn in_edges(g: &Graph) -> InEdges {
    let rev = g.reversed();
    let deg: Vec<u32> = (0..g.num_vertices() as VertexId).map(|v| g.out_degree(v) as u32).collect();
    let src_deg = rev.targets.iter().map(|&s| deg[s as usize]).collect();
    InEdges { offsets: rev.offsets.clone(), src: rev.targets.clone(), src_deg, w: rev.weights.clone() }
}

/// Synchronous GraphLab: rounds with a barrier each, pull-based updates.
pub fn run_graphlab_sync<P: GasProgram>(
    program: &P,
    g: &Graph,
    assignment: &[u32],
    num_parts: usize,
    cfg: &EngineConfig,
    cost: &GraphLabCost,
) -> RunResult<P::V> {
    let nv = g.num_vertices();
    let ie = in_edges(g);
    let mut values: Vec<P::V> =
        (0..nv).map(|v| program.init(v as VertexId, g.out_degree(v as VertexId) as u32)).collect();
    let mut metrics = Metrics::default();
    let mut clock = SuperstepClock::new();

    let mut active: Vec<VertexId> = (0..nv as VertexId).collect();
    let mut in_next = vec![false; nv];
    let mut rounds = 0u64;

    while !active.is_empty() && rounds < cfg.max_iterations {
        // per-worker accounting
        let mut worker_compute = vec![Duration::ZERO; num_parts];
        let mut worker_remote_gathers = vec![0u64; num_parts];
        let mut next: Vec<VertexId> = Vec::new();
        // snapshot semantics: sync mode reads round-start values
        let snapshot = values.clone();
        for &v in &active {
            let p = assignment[v as usize] as usize;
            let t0 = std::time::Instant::now();
            let (s, e) = (ie.offsets[v as usize], ie.offsets[v as usize + 1]);
            let mut acc: Option<P::G> = None;
            for i in s..e {
                let srcv = ie.src[i];
                if assignment[srcv as usize] != assignment[v as usize] {
                    worker_remote_gathers[p] += 1;
                }
                let gth = program.gather(&snapshot[srcv as usize], ie.src_deg[i], ie.w[i]);
                acc = Some(match acc {
                    None => gth,
                    Some(a) => program.merge(a, gth),
                });
            }
            let significant = program.apply(&mut values[v as usize], acc);
            metrics.vertex_computations += 1;
            worker_compute[p] += t0.elapsed();
            if significant {
                for &t in g.out_edges(v).0 {
                    if !in_next[t as usize] {
                        in_next[t as usize] = true;
                        next.push(t);
                    }
                }
            }
        }
        for p in 0..num_parts {
            let comm = Duration::from_secs_f64(
                worker_remote_gathers[p] as f64 * cost.remote_gather_us * 1e-6,
            );
            clock.record_worker(cfg.net.scale_compute(worker_compute[p]), comm);
        }
        clock.barrier(&cfg.net, &mut metrics);
        metrics.global_iterations += 1;
        metrics.supersteps_total += 1;
        rounds += 1;
        for &t in &next {
            in_next[t as usize] = false;
        }
        active = next;
    }

    RunResult { values, metrics }
}

/// Asynchronous GraphLab: FIFO vertex scheduler, immediate visibility,
/// per-update locking overhead, reduced parallel efficiency.
pub fn run_graphlab_async<P: GasProgram>(
    program: &P,
    g: &Graph,
    _assignment: &[u32],
    num_parts: usize,
    cfg: &EngineConfig,
    cost: &GraphLabCost,
) -> RunResult<P::V> {
    let nv = g.num_vertices();
    let ie = in_edges(g);
    let mut values: Vec<P::V> =
        (0..nv).map(|v| program.init(v as VertexId, g.out_degree(v as VertexId) as u32)).collect();
    let mut metrics = Metrics::default();

    let mut queue: VecDeque<VertexId> = (0..nv as VertexId).collect();
    let mut queued = vec![true; nv];
    let mut updates = 0u64;
    let t0 = std::time::Instant::now();
    let max_updates = cfg.max_iterations.saturating_mul(nv as u64);

    while let Some(v) = queue.pop_front() {
        queued[v as usize] = false;
        let (s, e) = (ie.offsets[v as usize], ie.offsets[v as usize + 1]);
        let mut acc: Option<P::G> = None;
        for i in s..e {
            let srcv = ie.src[i] as usize;
            let gth = program.gather(&values[srcv], ie.src_deg[i], ie.w[i]);
            acc = Some(match acc {
                None => gth,
                Some(a) => program.merge(a, gth),
            });
        }
        let significant = program.apply(&mut values[v as usize], acc);
        updates += 1;
        if significant {
            for &t in g.out_edges(v).0 {
                if !queued[t as usize] {
                    queued[t as usize] = true;
                    queue.push_back(t);
                }
            }
        }
        if updates >= max_updates {
            break;
        }
    }

    // simulated parallel time: sequential work / effective workers, plus
    // per-update lock+scheduling overhead
    let seq = cfg.net.scale_compute(t0.elapsed());
    let eff_workers = (num_parts as f64 * cost.async_efficiency).max(1.0);
    let lock = Duration::from_secs_f64(updates as f64 * cost.async_lock_us * 1e-6 / eff_workers);
    metrics.vertex_computations = updates;
    metrics.compute_time = seq.div_f64(eff_workers);
    metrics.sync_time = lock; // lock/scheduling overhead reported as sync
    metrics.elapsed = seq.div_f64(eff_workers) + lock;
    // async has no superstep counter; report updates/nv as a pseudo count
    metrics.global_iterations = 0;

    RunResult { values, metrics }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::partition::hash_partition;

    /// GAS PageRank with tolerance-based scheduling.
    struct GasPr {
        tol: f64,
    }
    impl GasProgram for GasPr {
        type V = f64;
        type G = f64;
        fn init(&self, _v: VertexId, _d: u32) -> f64 {
            0.15
        }
        fn gather(&self, src: &f64, src_deg: u32, _w: f32) -> f64 {
            if src_deg == 0 {
                0.0
            } else {
                src / src_deg as f64
            }
        }
        fn merge(&self, a: f64, b: f64) -> f64 {
            a + b
        }
        fn apply(&self, v: &mut f64, acc: Option<f64>) -> bool {
            let new = 0.15 + 0.85 * acc.unwrap_or(0.0);
            let change = (new - *v).abs();
            *v = new;
            change > self.tol
        }
    }

    #[test]
    fn sync_and_async_agree_on_pagerank() {
        let g = generators::powerlaw(400, 4, 17);
        let a = hash_partition(&g, 4);
        let cfg = EngineConfig::default();
        let cost = GraphLabCost::default();
        let s = run_graphlab_sync(&GasPr { tol: 1e-7 }, &g, &a, 4, &cfg, &cost);
        let asy = run_graphlab_async(&GasPr { tol: 1e-7 }, &g, &a, 4, &cfg, &cost);
        for (x, y) in s.values.iter().zip(&asy.values) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
        assert!(s.metrics.global_iterations > 3);
        // async converges in fewer updates than sync total updates
        assert!(asy.metrics.vertex_computations < s.metrics.vertex_computations);
    }

    #[test]
    fn sync_terminates_on_inactive() {
        let g = generators::erdos_renyi(50, 100, 3);
        let a = hash_partition(&g, 2);
        let cfg = EngineConfig::default();
        let r = run_graphlab_sync(
            &GasPr { tol: 1e-3 },
            &g,
            &a,
            2,
            &cfg,
            &GraphLabCost::default(),
        );
        assert!(r.metrics.global_iterations < cfg.max_iterations);
    }
}
