//! GraphLab-style engines (the paper's §7.5 comparator).
//!
//! GraphLab's abstraction is pull-based: an update function reads the
//! values of adjacent vertices directly (no messages). We implement the
//! gather-apply-scatter form:
//!
//! - [`run_graphlab_sync`] — synchronous mode: rounds; every scheduled
//!   vertex gathers over its in-edges, applies, and (if its change is
//!   significant) schedules its out-neighbors for the next round. One
//!   barrier per round, like BSP. Rounds execute with one worker per
//!   partition (snapshot reads, disjoint writes), threaded per
//!   [`super::EngineConfig::parallelism`] and bit-for-bit identical to
//!   sequential execution.
//! - [`run_graphlab_async`] — asynchronous mode: a FIFO scheduler
//!   processes one vertex at a time with immediate visibility. Fewer
//!   updates to converge, but each update pays locking/scheduling
//!   overhead and parallel efficiency is reduced — reproducing the
//!   trade-off in Table 4 (the paper: "Async ... reduces the degree of
//!   parallelism due to the locking mechanism"). Because immediate
//!   visibility makes results depend on update order, this engine
//!   ignores `parallelism` and always executes sequentially; its reduced
//!   parallel efficiency is *modeled* via [`GasCost`] instead.
//!
//! Both engines consume the same [`DistGraph`] every other engine runs
//! on (the worker-partition structure doubles as the GraphLab vertex
//! placement), so the [`super::Runner`] can dispatch to them with no
//! extra plumbing. Cross-partition gathers are charged as network reads
//! in the simulated cluster clock; the paper leaves `M` blank for
//! GraphLab, and so do our reports.
//!
//! # Online repartitioning
//!
//! The sync engine honors [`super::EngineConfig::repartition`]: at each
//! round barrier the [`MigrationPlanner`] folds the round's trace (remote
//! gathers play the network-message role) and an applied plan rebuilds
//! the [`DistGraph`] and the pull-mode [`GasView`] for the next round.
//! Values and the round scheduler are global-id indexed, so nothing else
//! moves — results are bitwise identical to a static-partition run; only
//! the simulated remote-gather accounting shifts. The async engine has
//! no barriers and ignores `cfg.repartition` entirely.
//!
//! # Fault tolerance
//!
//! With `FaultPolicy::checkpoint_interval` set, the sync engine takes
//! in-memory `GasSnapshot` checkpoints at round boundaries (GAS values
//! carry no `Codec` bound, so nothing is persisted to disk) and rolls
//! back + replays through the shared recovery layer when chaos kills a
//! worker — including kills landing inside a migration window. The
//! async engine has no barriers, hence no consistent cut to checkpoint:
//! a configured `checkpoint_interval` is rejected with a loud
//! `config:` error rather than being silently ignored.

use std::time::Duration;

use crate::graph::{DistGraph, MigrationPlan, VertexId};

use super::metrics::{Metrics, PartitionStepTrace, RunTrace, StepTrace};
use super::migrate::MigrationPlanner;
use super::netsim::SuperstepClock;
use super::recovery::{replay_geometry, GasSnapshot, RecoveryCoordinator};
use super::state::{FifoScheduler, Frontier};
use super::worker::run_workers;
use super::{EngineConfig, RunResult};

/// The GraphLab-style update program (gather over in-edges, apply).
///
/// The `Send + Sync` bounds on the associated types let rounds execute
/// on parallel worker threads (values are read from a shared snapshot;
/// accumulators stay worker-local).
pub trait GasProgram: Sync {
    /// Vertex value type.
    type V: Clone + Send + Sync;
    /// Gather accumulator.
    type G: Clone + Send;

    /// Initial vertex value.
    fn init(&self, vertex: VertexId, out_degree: u32) -> Self::V;

    /// Contribution of in-neighbor `src` along an edge of weight `w`.
    fn gather(&self, src_value: &Self::V, src_out_degree: u32, w: f32) -> Self::G;

    /// Combine two gather contributions (commutative + associative).
    fn merge(&self, a: Self::G, b: Self::G) -> Self::G;

    /// Apply the accumulated gather; return `true` when the change is
    /// significant enough to (re)schedule the out-neighbors.
    fn apply(&self, value: &mut Self::V, acc: Option<Self::G>) -> bool;
}

/// Cost constants of the GraphLab comparator (see module docs). Part of
/// [`EngineConfig`] (`cfg.gas`) since the Runner redesign; previously a
/// separate `GraphLabCost` argument.
#[derive(Clone, Debug)]
pub struct GasCost {
    /// Per-update lock acquisition/scheduling overhead in async mode (µs).
    pub async_lock_us: f64,
    /// Parallel efficiency of the async engine (0..1]: effective workers
    /// = parts × efficiency (lock contention on a shared graph).
    pub async_efficiency: f64,
    /// Per-remote-gather cost (µs) — reading a neighbor value across
    /// workers.
    pub remote_gather_us: f64,
}

impl Default for GasCost {
    fn default() -> Self {
        GasCost { async_lock_us: 6.0, async_efficiency: 0.5, remote_gather_us: 0.5 }
    }
}

/// Pre-Runner name for [`GasCost`], kept for source compatibility.
#[doc(hidden)]
pub type GraphLabCost = GasCost;

/// Global pull-mode view derived from a [`DistGraph`]: in-edge CSR for
/// gathers, out-neighbor CSR for scatter scheduling, and the vertex →
/// worker placement for remote-read accounting. Edge enumeration follows
/// global vertex order, so results are bit-identical to the old
/// `&Graph`-based implementation.
///
/// Built per engine call: construction is one O(V+E) pass, small next
/// to the multi-round engine run it precedes, so it is deliberately not
/// cached in the Runner session (revisit if GAS runs become hot).
struct GasView {
    /// In-edge CSR: for each vertex, (source, source out-degree, weight).
    in_offsets: Vec<usize>,
    in_src: Vec<VertexId>,
    in_src_deg: Vec<u32>,
    in_w: Vec<f32>,
    /// Out-neighbor CSR (scatter targets).
    out_offsets: Vec<usize>,
    out_targets: Vec<VertexId>,
    /// Global out-degree per vertex.
    out_deg: Vec<u32>,
    /// Vertex → owning partition.
    part_of: Vec<u32>,
}

impl GasView {
    fn new(dg: &DistGraph) -> GasView {
        let nv = dg.num_vertices;
        let mut out_deg = vec![0u32; nv];
        let mut in_count = vec![0usize; nv];
        let part_of: Vec<u32> = dg.routing.location.iter().map(|&(p, _)| p).collect();
        for v in 0..nv {
            let (p, lv) = dg.routing.location[v];
            let part = &dg.parts[p as usize];
            out_deg[v] = part.out_degree[lv as usize];
            // counting pass: stream targets only (raw column on SoA
            // storage, streaming decode on compressed storage)
            for e in part.out_edges(lv as usize) {
                in_count[e.target as usize] += 1;
            }
        }
        let mut in_offsets = vec![0usize; nv + 1];
        let mut out_offsets = vec![0usize; nv + 1];
        for v in 0..nv {
            in_offsets[v + 1] = in_offsets[v] + in_count[v];
            out_offsets[v + 1] = out_offsets[v] + out_deg[v] as usize;
        }
        let mut in_src = vec![0 as VertexId; in_offsets[nv]];
        let mut in_w = vec![0f32; in_offsets[nv]];
        let mut out_targets = vec![0 as VertexId; out_offsets[nv]];
        let mut in_cursor = in_offsets.clone();
        // walk sources in global id order: in-edges of every vertex end
        // up sorted by source, matching Graph::reversed()
        for v in 0..nv {
            let (p, lv) = dg.routing.location[v];
            let part = &dg.parts[p as usize];
            let mut oc = out_offsets[v];
            // pull-view build needs targets + weights only; the edge
            // iterator works over both storage modes
            for e in part.out_edges(lv as usize) {
                let t = e.target as usize;
                in_src[in_cursor[t]] = v as VertexId;
                in_w[in_cursor[t]] = e.weight;
                in_cursor[t] += 1;
                out_targets[oc] = e.target;
                oc += 1;
            }
        }
        let in_src_deg = in_src.iter().map(|&s| out_deg[s as usize]).collect();
        GasView { in_offsets, in_src, in_src_deg, in_w, out_offsets, out_targets, out_deg, part_of }
    }

    fn out_neighbors(&self, v: VertexId) -> &[VertexId] {
        &self.out_targets[self.out_offsets[v as usize]..self.out_offsets[v as usize + 1]]
    }
}

/// Synchronous GraphLab: rounds with a barrier each, pull-based updates.
///
/// Legacy entry point — use [`super::Runner::run_gas`] with
/// [`super::EngineKind::GraphLabSync`]; kept as a delegate for one
/// release.
#[doc(hidden)]
pub fn run_graphlab_sync<P: GasProgram>(
    program: &P,
    dg: &DistGraph,
    cfg: &EngineConfig,
) -> RunResult<P::V> {
    let nv = dg.num_vertices;
    let num_parts = dg.num_parts();
    let mut view = GasView::new(dg);
    let mut values: Vec<P::V> =
        (0..nv).map(|v| program.init(v as VertexId, view.out_deg[v])).collect();
    let mut metrics = Metrics::default();
    let mut trace = RunTrace::default();
    let mut clock = SuperstepClock::new();
    let planner = cfg.repartition.map(MigrationPlanner::new);
    let mut dg_owned: Option<Box<DistGraph>> = None;
    // chaos: the pull model has no message plane — batch events
    // (drop/delay/duplicate/reorder/splits) are vacuous here and never
    // fire, but scheduled worker kills still apply at every round
    // barrier; with `FaultPolicy::checkpoint_interval` set the engine
    // survives them through in-memory `GasSnapshot` checkpoints
    let mut chaos_ctl = cfg.chaos.as_ref().map(super::chaos::ChaosController::new);
    // GAS values carry no Codec bound, so sync-GraphLab checkpoints stay
    // in memory (checkpoint_dir is a push-engine affordance)
    let mut recovery: RecoveryCoordinator<GasSnapshot<P::V>> =
        RecoveryCoordinator::new(cfg.fault.recovery);
    let mut applied_plans: Vec<MigrationPlan> = Vec::new();

    // the shared scheduling structure of the push engines doubles as
    // GraphLab's round scheduler: rounds begin by draining it (the step
    // lifecycle's frontier take) and scatter re-schedules into it
    let mut frontier = Frontier::new(nv);
    for v in 0..nv {
        frontier.schedule(v);
    }
    let mut rounds = 0u64;

    /// One worker's round output: the applied values plus accounting.
    struct RoundOut<V> {
        updates: Vec<(VertexId, V, bool)>,
        compute: Duration,
        remote_gathers: u64,
    }

    loop {
        if rounds >= cfg.limits.max_iterations {
            break;
        }
        // ---- fault tolerance (via engine/recovery.rs): snapshot the
        // round-start state BEFORE frontier.take() drains the scheduler
        if recovery.should_checkpoint(&cfg.fault, rounds) {
            let snap = GasSnapshot {
                round: rounds,
                values: values.clone(),
                frontier: frontier.snapshot(),
                plans: applied_plans.clone(),
            };
            recovery.install(rounds, snap, &mut metrics);
        }
        let dgr: &DistGraph = dg_owned.as_deref().unwrap_or(dg);
        let active = frontier.take();
        if active.is_empty() {
            break;
        }
        // group the active list by owning partition (preserving relative
        // order): the per-worker work lists, identical in sequential and
        // threaded mode
        let mut by_part: Vec<Vec<VertexId>> = vec![Vec::new(); num_parts];
        for &v in &active {
            by_part[view.part_of[v as usize] as usize].push(v);
        }
        // snapshot semantics: sync mode reads round-start values, so
        // workers only read the snapshot and write disjoint updates
        let snapshot = values.clone();
        let view_ref = &view;
        let snap = &snapshot;
        let outs = run_workers(cfg.parallelism, &mut by_part, |p, list| {
            // detlint: allow(wall-clock) — compute_us probe: measures this
            // worker's sweep for telemetry/netsim only, never feeds results.
            let t0 = std::time::Instant::now();
            let mut updates = Vec::with_capacity(list.len());
            let mut remote_gathers = 0u64;
            for &v in list.iter() {
                let (s, e) =
                    (view_ref.in_offsets[v as usize], view_ref.in_offsets[v as usize + 1]);
                let mut acc: Option<P::G> = None;
                for i in s..e {
                    let srcv = view_ref.in_src[i];
                    if view_ref.part_of[srcv as usize] != p as u32 {
                        remote_gathers += 1;
                    }
                    let gth = program.gather(
                        &snap[srcv as usize],
                        view_ref.in_src_deg[i],
                        view_ref.in_w[i],
                    );
                    acc = Some(match acc {
                        None => gth,
                        Some(a) => program.merge(a, gth),
                    });
                }
                // apply against the round-start value (values[v] is
                // untouched until the fold below, so this equals the
                // in-place apply of the sequential implementation)
                let mut newv = snap[v as usize].clone();
                let significant = program.apply(&mut newv, acc);
                updates.push((v, newv, significant));
            }
            RoundOut {
                updates,
                compute: cfg.net.scale_compute(t0.elapsed()),
                remote_gathers,
            }
        });

        // fold in partition order: disjoint value writes + deterministic
        // next-round scheduling
        let mut step = StepTrace {
            iteration: trace.steps.len() as u64,
            partitions: Vec::with_capacity(num_parts),
            // routing_epoch/migrated are stamped below, once this
            // round's migration decision is known
            ..Default::default()
        };
        for (p, out) in outs.into_iter().enumerate() {
            let comm = Duration::from_secs_f64(
                out.remote_gathers as f64 * cfg.gas.remote_gather_us * 1e-6,
            );
            clock.record_worker_at(p, out.compute, comm);
            let boundary = by_part[p]
                .iter()
                .filter(|&&v| {
                    let (pp, lv) = dgr.routing.location[v as usize];
                    dgr.parts[pp as usize].is_boundary[lv as usize]
                })
                .count() as u64;
            step.partitions.push(PartitionStepTrace {
                partition: p as u32,
                frontier: by_part[p].len() as u64,
                boundary_frontier: boundary,
                // remote gathers are the pull model's cross-partition
                // traffic analogue (the paper leaves M blank here)
                network_messages: out.remote_gathers,
                compute_us: out.compute.as_micros() as u64,
                ..Default::default()
            });
            for (v, newv, significant) in out.updates {
                values[v as usize] = newv;
                metrics.vertex_computations += 1;
                if significant {
                    for &t in view.out_neighbors(v) {
                        frontier.schedule(t as usize);
                    }
                }
            }
        }
        trace.steps.push(step);
        // debug sanitizer: round scheduler membership flags consistent
        // after scatter re-scheduling (no-op in release builds)
        super::invariants::check_frontier(&frontier);

        // ---- chaos: poll scheduled worker kills at this round's
        // barrier (monotone counter = rounds recorded so far)
        if let Some(ctl) = chaos_ctl.as_mut() {
            ctl.begin_barrier(trace.steps.len() as u64 - 1);
            ctl.end_barrier();
        }
        // a loss event corrupted this round — roll back to the latest
        // in-memory snapshot and replay (the monotone counter keeps the
        // consumed kill from re-firing); without a checkpoint the
        // coordinator refuses loss loudly
        if let Some(reason) = chaos_ctl.as_mut().and_then(|c| c.take_pending()) {
            let snap = recovery.rollback("graphlab-sync", &reason, &mut metrics);
            values = snap.values.clone();
            frontier = Frontier::restore(nv, &snap.frontier);
            applied_plans = snap.plans.clone();
            rounds = snap.round;
            dg_owned = replay_geometry(dg, &snap.plans);
            view = GasView::new(dg_owned.as_deref().unwrap_or(dg));
            if let Some(ctl) = chaos_ctl.as_mut() {
                ctl.note_recovery();
            }
            continue;
        }

        // ---- online repartitioning: values and the round scheduler are
        // global-id indexed, so only the graph and the pull-mode view
        // change hands — results stay bitwise identical
        {
            let step = trace.steps.last_mut().expect("round just recorded a step");
            step.routing_epoch = dgr.routing.epoch;
            let plan = planner.as_ref().and_then(|pl| pl.plan(dgr, step, rounds));
            if let Some(plan) = plan {
                // chaos: a kill scheduled inside this migration window
                // fires between plan and apply — abandon the plan, roll
                // back, and let the replay re-derive it deterministically
                let survive = match chaos_ctl.as_mut() {
                    Some(ctl) => ctl.judge_migration(plan.len() as u64),
                    None => true,
                };
                if !survive {
                    let reason = chaos_ctl
                        .as_mut()
                        .and_then(|c| c.take_pending())
                        .expect("migration kill raised a pending loss");
                    let snap = recovery.rollback("graphlab-sync", &reason, &mut metrics);
                    values = snap.values.clone();
                    frontier = Frontier::restore(nv, &snap.frontier);
                    applied_plans = snap.plans.clone();
                    rounds = snap.round;
                    dg_owned = replay_geometry(dg, &snap.plans);
                    view = GasView::new(dg_owned.as_deref().unwrap_or(dg));
                    if let Some(ctl) = chaos_ctl.as_mut() {
                        ctl.note_recovery();
                    }
                    continue;
                }
                step.migrated = plan.len() as u64;
                let new_dg = Box::new(dgr.apply_migration(&plan));
                view = GasView::new(&new_dg);
                applied_plans.push(plan);
                dg_owned = Some(new_dg);
            }
        }

        clock.barrier(&cfg.net, &mut metrics);
        metrics.global_iterations += 1;
        metrics.supersteps_total += 1;
        rounds += 1;
    }

    RunResult { values, metrics, trace, chaos: chaos_ctl.map(|c| c.into_trace()) }
}

/// Asynchronous GraphLab: FIFO vertex scheduler, immediate visibility,
/// per-update locking overhead, reduced parallel efficiency.
///
/// Always executes sequentially regardless of
/// [`super::EngineConfig::parallelism`]: immediate visibility makes the
/// result depend on update interleaving, so any real threading would
/// break the determinism guarantee the other engines honor. The engine
/// *models* the paper's reduced async parallelism through [`GasCost`].
///
/// Checkpoint/recovery is documented out of scope: with no barriers
/// there is no consistent cut to snapshot at, so a configured
/// `FaultPolicy::checkpoint_interval` is rejected loudly (observe it as
/// a structured error through [`super::Runner::try_run_gas`]) instead
/// of being silently dropped.
///
/// Legacy entry point — use [`super::Runner::run_gas`] with
/// [`super::EngineKind::GraphLabAsync`]; kept as a delegate for one
/// release.
#[doc(hidden)]
pub fn run_graphlab_async<P: GasProgram>(
    program: &P,
    dg: &DistGraph,
    cfg: &EngineConfig,
) -> RunResult<P::V> {
    if cfg.fault.checkpoint_interval.is_some() {
        panic!(
            "config: FaultPolicy::checkpoint_interval is set but the graphlab-async \
             engine has no barriers to checkpoint at; run GraphLabSync or clear the \
             checkpoint policy (use Runner::try_run_gas to observe this error)"
        );
    }
    let nv = dg.num_vertices;
    let num_parts = dg.num_parts();
    let view = GasView::new(dg);
    let mut values: Vec<P::V> =
        (0..nv).map(|v| program.init(v as VertexId, view.out_deg[v])).collect();
    let mut metrics = Metrics::default();

    let mut sched = FifoScheduler::seeded(nv);
    // debug sanitizer: seeded FIFO queue/flag consistency (no-op in
    // release builds)
    super::invariants::check_fifo(&sched);
    let mut updates = 0u64;
    // detlint: allow(wall-clock) — compute_us probe: measures the whole
    // sequential async run for the parallel-time model, never feeds
    // results.
    let t0 = std::time::Instant::now();
    let max_updates = cfg.limits.max_iterations.saturating_mul(nv as u64);

    while let Some(v) = sched.pop() {
        let (s, e) = (view.in_offsets[v as usize], view.in_offsets[v as usize + 1]);
        let mut acc: Option<P::G> = None;
        for i in s..e {
            let srcv = view.in_src[i] as usize;
            let gth = program.gather(&values[srcv], view.in_src_deg[i], view.in_w[i]);
            acc = Some(match acc {
                None => gth,
                Some(a) => program.merge(a, gth),
            });
        }
        let significant = program.apply(&mut values[v as usize], acc);
        updates += 1;
        if significant {
            for &t in view.out_neighbors(v) {
                sched.schedule(t);
            }
        }
        if updates >= max_updates {
            break;
        }
    }
    // debug sanitizer: drained scheduler left no stale queued flags
    // (no-op in release builds)
    super::invariants::check_fifo(&sched);

    // simulated parallel time: sequential work / effective workers, plus
    // per-update lock+scheduling overhead
    let seq = cfg.net.scale_compute(t0.elapsed());
    let eff_workers = (num_parts as f64 * cfg.gas.async_efficiency).max(1.0);
    let lock =
        Duration::from_secs_f64(updates as f64 * cfg.gas.async_lock_us * 1e-6 / eff_workers);
    metrics.vertex_computations = updates;
    metrics.compute_time = seq.div_f64(eff_workers);
    metrics.sync_time = lock; // lock/scheduling overhead reported as sync
    metrics.elapsed = seq.div_f64(eff_workers) + lock;
    // async has no superstep counter; report updates/nv as a pseudo count
    metrics.global_iterations = 0;

    // async has no barriers either, so there is nothing to trace per
    // superstep — the trace stays empty by design, and chaos injection
    // (like migration) is documented out of scope: without barriers
    // there is no delivery fold to inject into and no synchronous
    // recovery point to roll back to
    RunResult { values, metrics, trace: RunTrace::default(), chaos: None }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::partition::hash_partition;

    /// GAS PageRank with tolerance-based scheduling.
    struct GasPr {
        tol: f64,
    }
    impl GasProgram for GasPr {
        type V = f64;
        type G = f64;
        fn init(&self, _v: VertexId, _d: u32) -> f64 {
            0.15
        }
        fn gather(&self, src: &f64, src_deg: u32, _w: f32) -> f64 {
            if src_deg == 0 {
                0.0
            } else {
                src / src_deg as f64
            }
        }
        fn merge(&self, a: f64, b: f64) -> f64 {
            a + b
        }
        fn apply(&self, v: &mut f64, acc: Option<f64>) -> bool {
            let new = 0.15 + 0.85 * acc.unwrap_or(0.0);
            let change = (new - *v).abs();
            *v = new;
            change > self.tol
        }
    }

    #[test]
    fn sync_and_async_agree_on_pagerank() {
        let g = generators::powerlaw(400, 4, 17);
        let a = hash_partition(&g, 4);
        let dg = crate::graph::DistGraph::new(&g, &a, 4);
        let cfg = EngineConfig::default();
        let s = run_graphlab_sync(&GasPr { tol: 1e-7 }, &dg, &cfg);
        let asy = run_graphlab_async(&GasPr { tol: 1e-7 }, &dg, &cfg);
        for (x, y) in s.values.iter().zip(&asy.values) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
        assert!(s.metrics.global_iterations > 3);
        // async converges in fewer updates than sync total updates
        assert!(asy.metrics.vertex_computations < s.metrics.vertex_computations);
    }

    #[test]
    fn sync_migration_is_bitwise_neutral() {
        // values are global in GAS mode, so online repartitioning may
        // only shift remote-gather accounting — never the fixed point
        let g = generators::powerlaw(400, 4, 17);
        let a = hash_partition(&g, 4);
        let dg = crate::graph::DistGraph::new(&g, &a, 4);
        let cfg = EngineConfig::default();
        let mut mcfg = cfg.clone();
        mcfg.repartition = Some(crate::engine::migrate::RepartitionConfig::every_barrier());
        let stat = run_graphlab_sync(&GasPr { tol: 1e-7 }, &dg, &cfg);
        let migr = run_graphlab_sync(&GasPr { tol: 1e-7 }, &dg, &mcfg);
        assert_eq!(stat.values, migr.values);
        assert!(migr.trace.vertices_migrated() > 0, "hash partition should trigger moves");
        assert_eq!(stat.trace.vertices_migrated(), 0);
    }

    #[test]
    fn sync_terminates_on_inactive() {
        let g = generators::erdos_renyi(50, 100, 3);
        let a = hash_partition(&g, 2);
        let dg = crate::graph::DistGraph::new(&g, &a, 2);
        let cfg = EngineConfig::default();
        let r = run_graphlab_sync(&GasPr { tol: 1e-3 }, &dg, &cfg);
        assert!(r.metrics.global_iterations < cfg.limits.max_iterations);
    }

    #[test]
    fn gas_view_matches_reversed_graph() {
        let g = generators::powerlaw(200, 3, 9);
        let a = hash_partition(&g, 3);
        let dg = crate::graph::DistGraph::new(&g, &a, 3);
        let view = GasView::new(&dg);
        let rev = g.reversed();
        assert_eq!(view.in_offsets, rev.offsets);
        assert_eq!(view.in_src, rev.targets);
        assert_eq!(view.in_w, rev.weights);
        for v in 0..g.num_vertices() as VertexId {
            assert_eq!(view.out_neighbors(v), g.out_edges(v).0);
            assert_eq!(view.out_deg[v as usize] as usize, g.out_degree(v));
        }
    }
}
