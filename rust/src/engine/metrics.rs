//! Execution metrics and superstep telemetry.
//!
//! Two layers of observability come out of every engine run:
//!
//! - [`Metrics`] — the run totals: the three quantities the paper
//!   reports for every experiment (global iterations I, network
//!   messages M, time T) plus the compute/communication/synchronization
//!   decomposition of Figure 1.
//! - [`RunTrace`] — the structured per-superstep / per-partition trace:
//!   one [`StepTrace`] per barrier, one [`PartitionStepTrace`] per
//!   worker turn, recording frontier occupancy, boundary composition,
//!   pseudo-superstep counts, local-vs-network message split, carryover
//!   events and per-worker compute time. The trace is what the adaptive
//!   hybrid scheduler ([`super::HybridPolicy::Adaptive`]) consumes
//!   online, and what `graphhp run --trace out.json` dumps for offline
//!   tuning.
//!
//! Determinism contract: every **counter** field of the trace is a pure
//! function of the computation (identical between sequential and
//! threaded runs); the **timing** field (`compute_us`) is measured
//! wall-clock and is reporting-only — the adaptive scheduler must never
//! read it.

use std::time::Duration;

/// Metrics of one engine run.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Global iterations = barrier synchronizations (supersteps for
    /// Hama/AM-Hama; hybrid iterations for GraphHP). Paper column `I`.
    pub global_iterations: u64,
    /// Total (pseudo-)supersteps executed across all partitions,
    /// including GraphHP's in-memory pseudo-supersteps.
    pub supersteps_total: u64,
    /// Messages that crossed the simulated network. Paper column `M`.
    pub network_messages: u64,
    /// Bytes that crossed the simulated network.
    pub network_bytes: u64,
    /// Messages delivered in memory within a partition.
    pub local_messages: u64,
    /// `Compute()` invocations.
    pub vertex_computations: u64,
    /// Measured compute time, averaged over workers per superstep and
    /// summed (the "computation" slice of Fig. 1).
    pub compute_time: Duration,
    /// Simulated communication time (serialization + wire), averaged
    /// over workers per superstep and summed.
    pub comm_time: Duration,
    /// Synchronization time: barrier latency + idle waiting for the
    /// slowest worker, averaged over workers per superstep and summed.
    pub sync_time: Duration,
    /// Simulated cluster wall-clock: sum over supersteps of
    /// (slowest worker + barrier). Paper column `T`.
    pub elapsed: Duration,
    /// Checkpoints taken.
    pub checkpoints: u64,
    /// Simulated worker failures recovered from.
    pub recoveries: u64,
}

impl Metrics {
    /// Fraction of elapsed spent in synchronization (Fig. 1 y-axis).
    pub fn sync_fraction(&self) -> f64 {
        let e = self.elapsed.as_secs_f64();
        if e == 0.0 {
            0.0
        } else {
            self.sync_time.as_secs_f64() / e
        }
    }

    /// Fraction of elapsed spent in communication.
    pub fn comm_fraction(&self) -> f64 {
        let e = self.elapsed.as_secs_f64();
        if e == 0.0 {
            0.0
        } else {
            self.comm_time.as_secs_f64() / e
        }
    }

    /// Combined sync+comm overhead fraction (Fig. 1 headline number).
    pub fn overhead_fraction(&self) -> f64 {
        self.sync_fraction() + self.comm_fraction()
    }

    /// Paper-style one-liner: `I=.. M=.. T=..`.
    pub fn summary(&self) -> String {
        format!(
            "I={} M={} T={:.3}s (compute {:.1}% comm {:.1}% sync {:.1}%)",
            self.global_iterations,
            self.network_messages,
            self.elapsed.as_secs_f64(),
            100.0 * (1.0 - self.overhead_fraction()),
            100.0 * self.comm_fraction(),
            100.0 * self.sync_fraction(),
        )
    }
}

/// One partition's telemetry for one barrier-delimited worker turn.
///
/// All counter fields are deterministic (threaded ≡ sequential);
/// `compute_us` is measured wall-clock and is **reporting-only** — no
/// scheduling decision may depend on it.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PartitionStepTrace {
    /// Partition (= worker) index.
    pub partition: u32,
    /// Worklist size of the barrier-level sweep (the global phase for
    /// GraphHP, the whole superstep for the BSP engines, the scheduled
    /// set for Giraph++, the active set for GraphLab-sync rounds).
    pub frontier: u64,
    /// Boundary vertices (Definition 1) in that worklist.
    pub boundary_frontier: u64,
    /// Local-phase pseudo-supersteps executed this turn (GraphHP only;
    /// 0 for the single-sweep engines).
    pub pseudo_supersteps: u64,
    /// Worklist size of the first local pseudo-superstep (0 when the
    /// local phase did not run).
    pub local_frontier_first: u64,
    /// Final local frontier sample: the last executed pseudo-superstep's
    /// worklist, or — after a carryover — the size of the rolled-back
    /// worklist (so shrinkage is measurable even when only one sweep
    /// ran before the cap hit).
    pub local_frontier_last: u64,
    /// Messages delivered in memory within the partition this turn.
    pub local_messages: u64,
    /// Messages this worker sent across the (simulated) network this
    /// turn, after sender-side combining. (GraphLab-sync reports remote
    /// gathers here — its cross-partition traffic analogue.)
    pub network_messages: u64,
    /// Local work left when the turn ended: scheduled frontier entries
    /// plus buffered in-partition mail. Non-zero after a cap-truncated
    /// (carryover) local phase; the adaptive scheduler only skips a
    /// partition's local phase while this is 0.
    pub local_backlog: u64,
    /// The local phase hit the pseudo-superstep cap and was rolled back
    /// with carryover (`PartitionRuntime::abort_step_carryover`).
    pub carryover: bool,
    /// The adaptive scheduler decided not to run the local phase at all
    /// this iteration.
    pub local_phase_skipped: bool,
    /// Scaled compute time of this worker's turn in microseconds.
    /// Wall-clock: varies run to run, never a policy input.
    pub compute_us: u64,
}

/// Telemetry of one barrier synchronization across all partitions.
#[derive(Clone, Debug, Default)]
pub struct StepTrace {
    /// Execution-order index of the barrier (0-based). After a simulated
    /// failure recovery the re-executed iterations appear as additional
    /// entries, so this counts barriers actually run, not logical
    /// iteration numbers.
    pub iteration: u64,
    /// The routing epoch this superstep executed under (0 unless online
    /// repartitioning has applied a `MigrationPlan`).
    pub routing_epoch: u64,
    /// Vertices migrated by the plan applied at this barrier's close
    /// (0 when no migration happened — deterministic counter, identical
    /// between sequential and threaded runs).
    pub migrated: u64,
    /// Per-partition records, in partition order.
    pub partitions: Vec<PartitionStepTrace>,
}

/// Structured per-superstep / per-partition trace of one engine run.
///
/// Returned on every [`super::RunResult`]; dump it as JSON with
/// [`RunTrace::to_json`] (the CLI's `--trace out.json`). The GraphHP
/// engine also fills [`partition_locality`](Self::partition_locality)
/// from [`crate::partition::stats::partition_localities`] — the static
/// score that seeds the adaptive scheduler's initial per-partition
/// state.
///
/// ```
/// use graphhp::algorithms::Wcc;
/// use graphhp::engine::{EngineKind, Runner};
/// use graphhp::graph::generators;
///
/// let g = generators::connected(60, 30, 7);
/// let r = Runner::new(&g).partitions(3).engine(EngineKind::GraphHP).run(&Wcc);
/// assert_eq!(r.trace.iterations(), r.metrics.global_iterations);
/// assert!(r.trace.to_json().contains("\"steps\""));
/// ```
///
/// Memory: the trace keeps one [`PartitionStepTrace`] (~100 bytes) per
/// partition per barrier for the whole run, so a run's trace footprint
/// is `O(iterations × partitions)`. That is negligible for converging
/// workloads; for deliberately huge iteration counts (the
/// `max_iterations` safety valve defaults to 10⁶) bound the run or drop
/// the trace early.
#[derive(Clone, Debug, Default)]
pub struct RunTrace {
    /// Static locality score per partition (internal edges over total
    /// incident edges, 1.0 = no cross-partition edge). Filled by the
    /// GraphHP engine; empty for engines that don't consume it.
    pub partition_locality: Vec<f64>,
    /// One entry per barrier synchronization, in execution order.
    pub steps: Vec<StepTrace>,
}

impl RunTrace {
    /// Barriers recorded (equals `Metrics::global_iterations` for runs
    /// without failure recovery).
    pub fn iterations(&self) -> u64 {
        self.steps.len() as u64
    }

    /// Total local-phase pseudo-supersteps across all steps/partitions.
    pub fn pseudo_supersteps(&self) -> u64 {
        self.per_partition_sum(|p| p.pseudo_supersteps)
    }

    /// Cap-truncated (carryover) local phases observed.
    pub fn carryover_events(&self) -> u64 {
        self.per_partition_sum(|p| u64::from(p.carryover))
    }

    /// Local phases the adaptive scheduler skipped.
    pub fn skipped_local_phases(&self) -> u64 {
        self.per_partition_sum(|p| u64::from(p.local_phase_skipped))
    }

    /// Total vertices migrated by online repartitioning across the run.
    pub fn vertices_migrated(&self) -> u64 {
        self.steps.iter().map(|s| s.migrated).sum()
    }

    /// The `migrated` counter of every barrier, in execution order — the
    /// migration trajectory the equivalence/replay tests compare.
    pub fn migration_trajectory(&self) -> Vec<u64> {
        self.steps.iter().map(|s| s.migrated).collect()
    }

    fn per_partition_sum(&self, f: impl Fn(&PartitionStepTrace) -> u64) -> u64 {
        self.steps.iter().flat_map(|s| s.partitions.iter().map(&f)).sum()
    }

    /// Serialize the whole trace as JSON (hand-rolled — the offline
    /// vendor set has no serde). Schema: `{"partition_locality": [..],
    /// "steps": [{"iteration": n, "partitions": [{..counters..}]}]}`.
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(256 + self.steps.len() * 128);
        out.push_str("{\n  \"partition_locality\": [");
        for (i, l) in self.partition_locality.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!("{l}"));
        }
        out.push_str("],\n  \"steps\": [");
        for (si, s) in self.steps.iter().enumerate() {
            if si > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n    {{\"iteration\": {}, \"routing_epoch\": {}, \"migrated\": {}, \
                 \"partitions\": [",
                s.iteration, s.routing_epoch, s.migrated
            ));
            for (pi, p) in s.partitions.iter().enumerate() {
                if pi > 0 {
                    out.push(',');
                }
                out.push_str(&format!(
                    "\n      {{\"partition\": {}, \"frontier\": {}, \"boundary_frontier\": {}, \
                     \"pseudo_supersteps\": {}, \"local_frontier_first\": {}, \
                     \"local_frontier_last\": {}, \"local_messages\": {}, \
                     \"network_messages\": {}, \"local_backlog\": {}, \"carryover\": {}, \
                     \"local_phase_skipped\": {}, \"compute_us\": {}}}",
                    p.partition,
                    p.frontier,
                    p.boundary_frontier,
                    p.pseudo_supersteps,
                    p.local_frontier_first,
                    p.local_frontier_last,
                    p.local_messages,
                    p.network_messages,
                    p.local_backlog,
                    p.carryover,
                    p.local_phase_skipped,
                    p.compute_us,
                ));
            }
            out.push_str("\n    ]}");
        }
        if self.steps.is_empty() {
            out.push_str("]\n}\n");
        } else {
            out.push_str("\n  ]\n}\n");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_sum_sanely() {
        let m = Metrics {
            elapsed: Duration::from_secs(10),
            sync_time: Duration::from_secs(6),
            comm_time: Duration::from_secs(2),
            compute_time: Duration::from_secs(2),
            ..Default::default()
        };
        assert!((m.sync_fraction() - 0.6).abs() < 1e-9);
        assert!((m.comm_fraction() - 0.2).abs() < 1e-9);
        assert!((m.overhead_fraction() - 0.8).abs() < 1e-9);
    }

    #[test]
    fn zero_elapsed_is_safe() {
        let m = Metrics::default();
        assert_eq!(m.sync_fraction(), 0.0);
        assert_eq!(m.overhead_fraction(), 0.0);
    }

    fn sample_trace() -> RunTrace {
        RunTrace {
            partition_locality: vec![0.75, 1.0],
            steps: vec![
                StepTrace {
                    iteration: 0,
                    partitions: vec![
                        PartitionStepTrace {
                            partition: 0,
                            frontier: 5,
                            boundary_frontier: 2,
                            pseudo_supersteps: 3,
                            carryover: true,
                            ..Default::default()
                        },
                        PartitionStepTrace {
                            partition: 1,
                            frontier: 4,
                            local_phase_skipped: true,
                            ..Default::default()
                        },
                    ],
                    ..Default::default()
                },
                StepTrace {
                    iteration: 1,
                    routing_epoch: 1,
                    migrated: 3,
                    partitions: vec![PartitionStepTrace {
                        partition: 0,
                        pseudo_supersteps: 2,
                        ..Default::default()
                    }],
                },
            ],
        }
    }

    #[test]
    fn trace_summaries_count_across_steps_and_partitions() {
        let t = sample_trace();
        assert_eq!(t.iterations(), 2);
        assert_eq!(t.pseudo_supersteps(), 5);
        assert_eq!(t.carryover_events(), 1);
        assert_eq!(t.skipped_local_phases(), 1);
        assert_eq!(t.vertices_migrated(), 3);
        assert_eq!(t.migration_trajectory(), vec![0, 3]);
    }

    #[test]
    fn trace_json_contains_every_record() {
        let j = sample_trace().to_json();
        assert!(j.contains("\"partition_locality\": [0.75, 1]"), "{j}");
        assert!(j.contains("\"iteration\": 1"), "{j}");
        assert!(j.contains("\"routing_epoch\": 1"), "{j}");
        assert!(j.contains("\"migrated\": 3"), "{j}");
        assert!(j.contains("\"carryover\": true"), "{j}");
        assert!(j.contains("\"local_phase_skipped\": true"), "{j}");
        // crude structural check: balanced braces/brackets
        for (open, close) in [('{', '}'), ('[', ']')] {
            assert_eq!(
                j.matches(open).count(),
                j.matches(close).count(),
                "unbalanced {open}{close} in {j}"
            );
        }
    }

    #[test]
    fn empty_trace_serializes() {
        let j = RunTrace::default().to_json();
        assert!(j.contains("\"steps\": []"), "{j}");
    }
}
