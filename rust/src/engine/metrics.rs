//! Execution metrics: the three quantities the paper reports for every
//! experiment (global iterations I, network messages M, time T) plus the
//! compute/communication/synchronization decomposition of Figure 1.

use std::time::Duration;

/// Metrics of one engine run.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Global iterations = barrier synchronizations (supersteps for
    /// Hama/AM-Hama; hybrid iterations for GraphHP). Paper column `I`.
    pub global_iterations: u64,
    /// Total (pseudo-)supersteps executed across all partitions,
    /// including GraphHP's in-memory pseudo-supersteps.
    pub supersteps_total: u64,
    /// Messages that crossed the simulated network. Paper column `M`.
    pub network_messages: u64,
    /// Bytes that crossed the simulated network.
    pub network_bytes: u64,
    /// Messages delivered in memory within a partition.
    pub local_messages: u64,
    /// `Compute()` invocations.
    pub vertex_computations: u64,
    /// Measured compute time, averaged over workers per superstep and
    /// summed (the "computation" slice of Fig. 1).
    pub compute_time: Duration,
    /// Simulated communication time (serialization + wire), averaged
    /// over workers per superstep and summed.
    pub comm_time: Duration,
    /// Synchronization time: barrier latency + idle waiting for the
    /// slowest worker, averaged over workers per superstep and summed.
    pub sync_time: Duration,
    /// Simulated cluster wall-clock: sum over supersteps of
    /// (slowest worker + barrier). Paper column `T`.
    pub elapsed: Duration,
    /// Checkpoints taken.
    pub checkpoints: u64,
    /// Simulated worker failures recovered from.
    pub recoveries: u64,
}

impl Metrics {
    /// Fraction of elapsed spent in synchronization (Fig. 1 y-axis).
    pub fn sync_fraction(&self) -> f64 {
        let e = self.elapsed.as_secs_f64();
        if e == 0.0 {
            0.0
        } else {
            self.sync_time.as_secs_f64() / e
        }
    }

    /// Fraction of elapsed spent in communication.
    pub fn comm_fraction(&self) -> f64 {
        let e = self.elapsed.as_secs_f64();
        if e == 0.0 {
            0.0
        } else {
            self.comm_time.as_secs_f64() / e
        }
    }

    /// Combined sync+comm overhead fraction (Fig. 1 headline number).
    pub fn overhead_fraction(&self) -> f64 {
        self.sync_fraction() + self.comm_fraction()
    }

    /// Paper-style one-liner: `I=.. M=.. T=..`.
    pub fn summary(&self) -> String {
        format!(
            "I={} M={} T={:.3}s (compute {:.1}% comm {:.1}% sync {:.1}%)",
            self.global_iterations,
            self.network_messages,
            self.elapsed.as_secs_f64(),
            100.0 * (1.0 - self.overhead_fraction()),
            100.0 * self.comm_fraction(),
            100.0 * self.sync_fraction(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_sum_sanely() {
        let m = Metrics {
            elapsed: Duration::from_secs(10),
            sync_time: Duration::from_secs(6),
            comm_time: Duration::from_secs(2),
            compute_time: Duration::from_secs(2),
            ..Default::default()
        };
        assert!((m.sync_fraction() - 0.6).abs() < 1e-9);
        assert!((m.comm_fraction() - 0.2).abs() < 1e-9);
        assert!((m.overhead_fraction() - 0.8).abs() < 1e-9);
    }

    #[test]
    fn zero_elapsed_is_safe() {
        let m = Metrics::default();
        assert_eq!(m.sync_fraction(), 0.0);
        assert_eq!(m.overhead_fraction(), 0.0);
    }
}
