//! The GraphHP hybrid execution engine (paper §4.2, §5) — the system
//! contribution of the paper.
//!
//! Execution is a sequence of **global iterations**. Iteration 0 is the
//! initialization superstep (identical to standard BSP). Every iteration
//! ≥ 1 is:
//!
//! 1. **Global phase** (`globalSuperstep()` of Alg. 2): each active
//!    boundary vertex computes once on the messages buffered for it
//!    during the previous iteration. Messages it sends to *local-class*
//!    vertices of its own partition go to the immediate local phase;
//!    messages to boundary vertices of its own partition are buffered for
//!    the next iteration's global phase (unless boundary vertices
//!    participate in local phases); messages to remote vertices are
//!    buffered for RPC delivery at the next barrier.
//! 2. **Local phase** (`pseudoSuperstep()` of Alg. 2): pseudo-supersteps
//!    over the partition's participating vertices, entirely in memory,
//!    repeated until every participant is inactive and no message is in
//!    transit inside the partition — or until
//!    [`super::Limits::max_pseudo_supersteps`], in which case the
//!    in-flight work **carries over**: the truncated step is rolled back
//!    with [`PartitionRuntime::abort_step_carryover`], so the remaining
//!    frontier and mail resume in the next iteration's local phase
//!    instead of being dropped (the pre-lifecycle code lost both and
//!    livelocked until `max_iterations`).
//!
//! Distributed synchronization + communication happen once per global
//! iteration — the whole point of the hybrid model.
//!
//! # The adaptive scheduler
//!
//! Under [`super::HybridPolicy::Adaptive`] the phase structure above is
//! driven per partition and per iteration by the run's own telemetry
//! ([`super::RunTrace`]). At every barrier the engine thread folds the
//! workers' trace records in partition order and updates one
//! per-partition policy (`PartitionPolicy`):
//!
//! - **pseudo-superstep cap** — doubles while the local frontier is
//!   shrinking geometrically (the phase is converging: give it room to
//!   finish in-memory, even when the cap truncated it), halves on a
//!   carryover whose frontier had stopped shrinking (the phase is
//!   burning sweeps without quiescing, stalling the barrier for every
//!   other partition);
//! - **boundary participation** — seeded from the partition's static
//!   locality score; shed after two consecutive carryovers (boundary
//!   work is thrashing the local phase), restored after two clean
//!   iterations;
//! - **local-phase skip** — the next iteration's local phase is skipped
//!   entirely while the partition's frontier is boundary-dominated and
//!   it ended the turn with zero local backlog (nothing scheduled, no
//!   buffered in-partition mail), so a pure boundary relay partition
//!   stops paying the per-iteration step transaction.
//!
//! Every decision is a pure function of the trace's deterministic
//! counters — never of measured time — so threaded runs remain
//! bit-for-bit identical to sequential ones
//! (`tests/parallel_equivalence.rs` covers the adaptive policy too).
//!
//! # Online repartitioning
//!
//! Under [`super::EngineConfig::repartition`] the engine additionally
//! runs the deterministic [`MigrationPlanner`] at each barrier: the
//! just-recorded [`super::metrics::StepTrace`] counters pick a
//! network-bound donor partition and a set of vertices whose out-edges
//! favor a remote partition, and the plan is applied atomically while
//! every partition is step-closed — `DistGraph::apply_migration`
//! rebuilds the routing epoch, [`remap_runtimes`]/[`remap_stores`]
//! forward values, halt flags, in-flight mail (both the local-phase
//! inbox pair and the global-phase `gq` pair) and carryover frontier
//! entries to each vertex's new owner. The applied-plan trajectory is
//! part of every checkpoint, and recovery replays it onto the pristine
//! graph to rebuild the checkpoint's geometry before restoring the
//! per-partition arrays — so a recovered run is bit-for-bit the clean
//! run, migrations included.
//!
//! The per-vertex body of all three sweeps (init / global / local) is
//! the shared `super::worker::Sweep`; this file keeps only the phase
//! structure and the hybrid routing policy. Partitions run as parallel
//! workers per [`super::EngineConfig::parallelism`].

use crate::graph::{DistGraph, MigrationPlan, PartGraph};
use crate::partition::stats::partition_localities;

use super::aggregator::Aggregators;
use super::checkpoint::PolicyCheckpoint;
use super::messages::{MsgStore, Outbox};
use super::metrics::{Metrics, PartitionStepTrace, RunTrace};
use super::migrate::{remap_runtimes, remap_stores, MigrationPlanner};
use super::netsim::SuperstepClock;
use super::program::VertexProgram;
use super::state::{Frontier, PartitionRuntime};
use super::worker::{
    boundary_count, close_superstep, run_workers, LocalRoute, ProcessedMarks, Reschedule, Sweep,
    SweepOutcome, SweepTarget, WorkerOut, WorkerScratch,
};
use super::{AdaptiveConfig, EngineConfig, HybridPolicy, RunResult};

/// Per-partition scheduling state: what the worker reads for its next
/// turn (`run_local` / `cap` / `boundary_in_local`) plus the counters
/// the adaptive controller folds at each barrier. Static policies build
/// one fixed instance per partition and never touch it again.
///
/// This IS the checkpoint type: checkpoints persist the policies
/// verbatim, so there is no field-by-field conversion to drift out of
/// sync — adding controller state automatically makes it recoverable
/// (the `Codec` impl in `checkpoint.rs` is the one thing to extend).
type PartitionPolicy = PolicyCheckpoint;

impl PolicyCheckpoint {
    /// Fixed policy (the `Static` variant): the paper's hand-tuned knobs.
    fn fixed(boundary_in_local: bool, cap: u64) -> Self {
        PartitionPolicy {
            run_local: true,
            cap,
            boundary_in_local,
            preferred_boundary: boundary_in_local,
            carryover_streak: 0,
            clean_streak: 0,
        }
    }

    /// Initial adaptive state, seeded from the partition's static
    /// locality score (`partition/stats.rs`). Degenerate configs
    /// (`max_cap < min_cap`, zeros) are sanitized rather than panicking:
    /// the floor wins, and `Limits::max_pseudo_supersteps` always
    /// dominates.
    fn initial(acfg: &AdaptiveConfig, locality: f64, limit_cap: u64) -> Self {
        let boundary = locality >= acfg.locality_threshold;
        let floor = acfg.min_cap.max(1);
        let ceil = acfg.max_cap.max(floor);
        PartitionPolicy {
            run_local: true,
            cap: acfg.initial_cap.clamp(floor, ceil).min(limit_cap),
            boundary_in_local: boundary,
            preferred_boundary: boundary,
            carryover_streak: 0,
            clean_streak: 0,
        }
    }

    /// Fold one iteration's telemetry record into the policy — a pure
    /// function of the deterministic counter fields (`compute_us` is
    /// wall-clock and must never be read here).
    fn adapt(&mut self, acfg: &AdaptiveConfig, t: &PartitionStepTrace, limit_cap: u64) {
        // every path keeps the cap within [1, limit_cap]: the engine-level
        // Limits::max_pseudo_supersteps always dominates the adaptive range
        let grow = |cap: u64| cap.saturating_mul(2).min(acfg.max_cap).min(limit_cap).max(1);
        if t.carryover {
            // after a carryover `local_frontier_last` is the ROLLED-BACK
            // worklist, so shrinkage is measurable from a single executed
            // sweep — without this, cap 1 would be an absorbing state
            // (one sweep can never satisfy a two-sweep shrink test and
            // the cap could never grow back out)
            let shrinking = t.pseudo_supersteps >= 1
                && t.local_frontier_last * 2 <= t.local_frontier_first;
            if shrinking {
                // truncated while still converging: the cap was the only
                // thing standing between this phase and quiescence —
                // give it room instead of punishing it
                self.cap = grow(self.cap);
                self.carryover_streak = 0;
            } else {
                // truncated with a flat frontier: the phase is burning
                // sweeps without converging and stalling the barrier for
                // every other partition — halve the cap
                self.cap = (self.cap / 2).max(acfg.min_cap.max(1)).min(limit_cap).max(1);
                self.carryover_streak += 1;
            }
            self.clean_streak = 0;
        } else {
            // clean completion: grow the cap while the local frontier
            // kept shrinking geometrically across the executed sweeps —
            // more headroom converts future global iterations into
            // in-memory pseudo-supersteps
            let shrinking = t.pseudo_supersteps >= 2
                && t.local_frontier_last * 2 <= t.local_frontier_first;
            if shrinking {
                self.cap = grow(self.cap);
            }
            self.carryover_streak = 0;
            self.clean_streak = self.clean_streak.saturating_add(1);
        }
        if self.carryover_streak >= 2 {
            self.boundary_in_local = false;
        } else if self.clean_streak >= 2 {
            self.boundary_in_local = self.preferred_boundary;
        }
        // skip the next local phase only when this turn proved there is
        // nothing local to do (zero backlog) and the frontier is
        // boundary-dominated; any backlog forces the phase back on, so a
        // skipped partition can never strand carried-over work
        self.run_local = !(t.local_backlog == 0
            && t.frontier > 0
            && t.boundary_frontier as f64 >= acfg.boundary_dominance * t.frontier as f64);
    }
}

/// One policy per partition from the configured [`HybridPolicy`]:
/// constant knobs for `Static`, locality-seeded initial state for
/// `Adaptive`. Also used to rebuild policies on a restart-from-scratch
/// recovery, so a restarted run begins from exactly the same state as a
/// fresh one.
fn build_policies(
    hybrid: &HybridPolicy,
    locality: &[f64],
    limit_cap: u64,
) -> Vec<PartitionPolicy> {
    locality
        .iter()
        .map(|&score| match hybrid {
            HybridPolicy::Static { boundary_in_local_phase, .. } => {
                PartitionPolicy::fixed(*boundary_in_local_phase, limit_cap)
            }
            HybridPolicy::Adaptive(a) => PartitionPolicy::initial(a, score, limit_cap),
        })
        .collect()
}

/// Per-partition state of the hybrid engine: the shared
/// [`PartitionRuntime`] carries the local-phase inboxes/frontier, plus
/// the global-phase inbox pair the hybrid model adds on top and the
/// pooled outbox.
struct HpPart<P: VertexProgram> {
    rt: PartitionRuntime<P::V, P::M>,
    /// Global-phase inbox for the CURRENT iteration.
    gq_cur: MsgStore<P::M>,
    /// Global-phase inbox for the NEXT iteration (remote deliveries +
    /// same-partition messages to non-participating boundary vertices).
    gq_nxt: MsgStore<P::M>,
    outbox: Outbox<P::M>,
    scratch: WorkerScratch<P::M>,
    marks: ProcessedMarks,
}

impl<P: VertexProgram> HpPart<P> {
    fn new(program: &P, part: &PartGraph) -> Self {
        let rt = PartitionRuntime::new(program, part);
        let n = rt.num_vertices();
        HpPart {
            rt,
            gq_cur: MsgStore::new(n),
            gq_nxt: MsgStore::new(n),
            outbox: Outbox::new(program.combiner()),
            scratch: WorkerScratch::new(),
            marks: ProcessedMarks::new(n),
        }
    }
}

/// Run `program` under the GraphHP hybrid execution model.
///
/// Legacy entry point — use [`super::Runner`] with
/// [`super::EngineKind::GraphHP`]; kept as a delegate for one release.
#[doc(hidden)]
pub fn run_graphhp<P: VertexProgram>(
    program: &P,
    dg: &DistGraph,
    cfg: &EngineConfig,
) -> RunResult<P::V> {
    let mut parts: Vec<HpPart<P>> =
        dg.parts.iter().map(|pg| HpPart::new(program, pg)).collect();
    let mut metrics = Metrics::default();
    let mut trace = RunTrace::default();
    let mut clock = SuperstepClock::new();
    let mut aggs = Aggregators::new(
        (0..program.num_aggregators()).map(|i| program.aggregator_op(i)).collect(),
    );
    let combiner = program.combiner();
    let source_combine = program.source_combine();

    // ---- hybrid policy: fixed knobs or the adaptive controller ------
    trace.partition_locality = partition_localities(dg).iter().map(|l| l.score()).collect();
    let limit_cap = cfg.limits.max_pseudo_supersteps.max(1);
    let (adaptive, async_local) = match &cfg.hybrid {
        HybridPolicy::Static { async_local_messaging, .. } => (None, *async_local_messaging),
        HybridPolicy::Adaptive(a) => (Some(a), a.async_local_messaging),
    };
    let mut policies = build_policies(&cfg.hybrid, &trace.partition_locality, limit_cap);

    let mut iteration: u64 = 0;
    let mut recovery: super::recovery::RecoveryCoordinator<
        super::checkpoint::Checkpoint<P::V, P::M>,
    > = super::recovery::RecoveryCoordinator::new(cfg.fault.recovery);
    let mut failure_pending = cfg.fault.inject_failure_at;
    let mut chaos_ctl = cfg.chaos.as_ref().map(super::chaos::ChaosController::new);

    // ---- online repartitioning state: the migrated graph (None while
    // still at epoch 0) and the applied-plan trajectory checkpoints
    // persist so recovery can rebuild the geometry
    let planner = cfg.repartition.map(MigrationPlanner::new);
    let mut dg_owned: Option<Box<DistGraph>> = None;
    let mut applied_plans: Vec<MigrationPlan> = Vec::new();

    loop {
        // ---- fault tolerance (paper §5.3) --------------------------
        if recovery.should_checkpoint(&cfg.fault, iteration) {
            // the snapshot covers the local-phase runtime state too:
            // after a cap-truncated local phase the carryover frontier
            // and in-flight mail are live state at the boundary
            let ckpt = super::checkpoint::Checkpoint {
                iteration,
                values: parts.iter().map(|hp| hp.rt.values.clone()).collect(),
                halted: parts.iter().map(|hp| hp.rt.halted.clone()).collect(),
                inbox: parts.iter_mut().map(|hp| hp.gq_cur.export()).collect(),
                local_cur: parts.iter_mut().map(|hp| hp.rt.cur.export()).collect(),
                local_nxt: parts.iter_mut().map(|hp| hp.rt.nxt.export()).collect(),
                frontier: parts.iter().map(|hp| hp.rt.frontier.snapshot()).collect(),
                policy: policies.clone(),
                migrations: applied_plans.clone(),
            };
            super::recovery::persist_checkpoint(&ckpt, &cfg.fault);
            recovery.install(iteration, ckpt, &mut metrics);
        }
        if failure_pending == Some(iteration) {
            // legacy single-failure drill: budget-exempt by design (it
            // models one planned loss, not chaos pressure), so it reads
            // the snapshot directly instead of charging `rollback`
            failure_pending = None;
            metrics.recoveries += 1;
            match recovery.last() {
                Some(ckpt) => {
                    // worker lost: reassign its partitions and roll every
                    // worker back to the latest consistent checkpoint —
                    // including the scheduler state, so the replay runs
                    // under exactly the policies the checkpointed run
                    // had (not ones adapted by the aborted timeline)
                    iteration = restore_from_checkpoint(
                        program,
                        dg,
                        ckpt,
                        &mut dg_owned,
                        &mut applied_plans,
                        &mut parts,
                        &mut policies,
                    );
                }
                None => {
                    // no checkpoint yet: restart from scratch — scheduler
                    // state and routing geometry included, so the rerun
                    // re-plans its migrations from iteration 0
                    dg_owned = None;
                    applied_plans.clear();
                    parts = dg.parts.iter().map(|pg| HpPart::new(program, pg)).collect();
                    policies =
                        build_policies(&cfg.hybrid, &trace.partition_locality, limit_cap);
                    iteration = 0;
                }
            }
        }

        // the current routing epoch's graph: pristine until the first
        // applied migration, then the latest rebuilt geometry
        let dgr: &DistGraph = dg_owned.as_deref().unwrap_or(dg);
        let policies_ref = &policies;
        let outs = run_workers(cfg.parallelism, &mut parts, |p, hp| {
            let HpPart { rt, gq_cur, gq_nxt, outbox, scratch, marks } = hp;
            let part = &dgr.parts[p];
            let policy = &policies_ref[p];
            let boundary_in_local = policy.boundary_in_local;
            outbox.reset();
            let mut wagg = aggs.clone();
            // detlint: allow(wall-clock) — compute_us probe: measures this
            // worker's sweep for telemetry/netsim only, never feeds results.
            let t0 = std::time::Instant::now();
            let mut outcome = SweepOutcome::default();
            let mut steps: u64 = 0;
            let mut pt = PartitionStepTrace::default();

            let local_route = if async_local {
                LocalRoute::ThisSweep
            } else {
                LocalRoute::NextSweep
            };
            let mk_sweep = |route: LocalRoute, reschedule: Reschedule| Sweep {
                program,
                dg: dgr,
                part,
                p,
                superstep: iteration,
                seed: cfg.seed,
                combiner,
                route,
                reschedule,
                boundary_in_local,
                steal_threads: cfg.parallelism.steal_threads(),
            };
            let merge = |outcome: &mut SweepOutcome, oc: SweepOutcome| {
                outcome.computations += oc.computations;
                outcome.local_messages += oc.local_messages;
            };

            if iteration == 0 {
                // ---- initialization iteration: identical to a standard
                // first superstep over every vertex (paper §4.2).
                // Unhalted vertices keep computing afterwards: boundary
                // ones in the next global phase (picked up by the
                // boundary && !halted rule), participants in the next
                // local phase (Reschedule::Participants).
                scratch.worklist.begin(part.num_vertices());
                for lv in 0..part.num_vertices() as u32 {
                    scratch.worklist.schedule(lv);
                }
                pt.frontier = scratch.worklist.len() as u64;
                pt.boundary_frontier = part.num_boundary() as u64;
                let oc = mk_sweep(LocalRoute::NextSweep, Reschedule::Participants).run(
                    SweepTarget {
                        values: &mut rt.values,
                        halted: &mut rt.halted,
                        cur: &mut *gq_cur,
                        nxt: &mut rt.nxt,
                        frontier: Some(&mut rt.frontier),
                    },
                    Some(&mut *gq_nxt),
                    outbox,
                    &mut wagg,
                    scratch,
                    marks,
                );
                merge(&mut outcome, oc);
                steps += 1;
            } else {
                // ---- global phase -----------------------------------
                // participants: any vertex with buffered global messages,
                // plus unhalted boundary vertices; an unhalted boundary
                // participant continues in the local phase iff boundary
                // vertices take part in it
                scratch.worklist.begin(part.num_vertices());
                for &lv in gq_cur.pending_sorted() {
                    scratch.worklist.schedule(lv);
                }
                for lv in 0..part.num_vertices() {
                    if part.is_boundary[lv] && !rt.halted[lv] {
                        scratch.worklist.schedule(lv as u32);
                    }
                }
                pt.frontier = scratch.worklist.len() as u64;
                pt.boundary_frontier = boundary_count(part, scratch.worklist.as_slice());
                let resched =
                    if boundary_in_local { Reschedule::Active } else { Reschedule::Never };
                let oc = mk_sweep(LocalRoute::NextSweep, resched).run(
                    SweepTarget {
                        values: &mut rt.values,
                        halted: &mut rt.halted,
                        cur: &mut *gq_cur,
                        nxt: &mut rt.nxt,
                        frontier: Some(&mut rt.frontier),
                    },
                    Some(&mut *gq_nxt),
                    outbox,
                    &mut wagg,
                    scratch,
                    marks,
                );
                merge(&mut outcome, oc);
                steps += 1;

                // ---- local phase: pseudo-supersteps until quiescence --
                // (or skipped wholesale by the adaptive scheduler when
                // this partition proved boundary-dominated and backlog-
                // free last iteration)
                if policy.run_local {
                    // a cap of 0 would abort every phase before its first
                    // sweep (zero progress, spin to max_iterations):
                    // PartitionPolicy keeps its cap floored at 1
                    let cap = policy.cap;
                    let mut pseudo_steps: u64 = 0;
                    loop {
                        rt.begin_step_into(&mut scratch.worklist);
                        for &lv in rt.cur.pending_sorted() {
                            scratch.worklist.schedule(lv);
                        }
                        // debug sanitizer: seeded pseudo-superstep worklist
                        // sorted/deduped before the sweep drains it (no-op
                        // in release builds)
                        super::invariants::check_worklist(&scratch.worklist);
                        if scratch.worklist.is_empty() {
                            rt.commit_step();
                            break;
                        }
                        if pseudo_steps >= cap {
                            // cap hit with work remaining: roll the step
                            // back so the frontier and in-flight mail
                            // carry over to the next iteration's local
                            // phase — nothing is dropped, nothing strands
                            // in the wrong inbox. Record the rolled-back
                            // worklist as the final frontier sample so
                            // the controller can tell a converging
                            // truncation from thrash even at cap 1.
                            pt.local_frontier_last = scratch.worklist.len() as u64;
                            rt.abort_step_carryover(
                                scratch.worklist.as_slice().iter().copied(),
                            );
                            pt.carryover = true;
                            break;
                        }
                        pseudo_steps += 1;
                        if pseudo_steps == 1 {
                            pt.local_frontier_first = scratch.worklist.len() as u64;
                        }
                        pt.local_frontier_last = scratch.worklist.len() as u64;
                        let oc = mk_sweep(local_route, Reschedule::Active).run(
                            rt.sweep_target(),
                            Some(&mut *gq_nxt),
                            outbox,
                            &mut wagg,
                            scratch,
                            marks,
                        );
                        rt.commit_step();
                        merge(&mut outcome, oc);
                        steps += 1;
                    }
                    pt.pseudo_supersteps = pseudo_steps;
                } else {
                    pt.local_phase_skipped = true;
                }
            }

            // local work left at the end of the turn: the signal that
            // gates the adaptive local-phase skip (and a carryover probe)
            pt.local_backlog = rt.frontier.len() as u64
                + rt.cur.total_messages() as u64
                + rt.nxt.total_messages() as u64;

            // GraphHP's SourceCombine applies to messages buffered across
            // the iteration boundary (subsumed by a full combiner)
            outbox.seal(source_combine);

            let compute = cfg.net.scale_compute(t0.elapsed());
            WorkerOut::new(std::mem::take(outbox), wagg, compute, p, outcome, steps, pt)
        });

        // ---- barrier: one distributed synchronization per iteration;
        // remote mail lands with receiver-side combining
        let outboxes = close_superstep(
            outs,
            &mut aggs,
            &mut clock,
            &cfg.net,
            &mut metrics,
            &mut trace,
            chaos_ctl.as_mut(),
            |tp, tl, m| {
                parts[tp as usize].gq_nxt.push_combined(tl as usize, m, combiner);
            },
        );
        for (hp, ob) in parts.iter_mut().zip(outboxes) {
            hp.outbox = ob;
            // debug sanitizer: after the iteration barrier the local
            // runtime must be step-closed and every inbox arena — the
            // per-partition pair plus both global-phase stores that
            // buffer cross-partition mail — internally consistent
            // (no-op in release builds)
            super::invariants::check_runtime(&hp.rt);
            super::invariants::check_msgstore(&hp.gq_cur, "gq_cur");
            super::invariants::check_msgstore(&hp.gq_nxt, "gq_nxt");
        }

        // ---- chaos recovery: a loss event (dropped/held mail or a
        // scheduled worker kill) corrupted this barrier. It must be
        // handled HERE, at the point of detection — before the adaptive
        // fold, the migration planner or the next loop-top checkpoint
        // could consume state derived from a lossy barrier — by rolling
        // every partition back to the latest checkpoint and replaying.
        // The chaos clock (the monotone barrier counter) keeps advancing
        // across rollbacks, so the replay draws fresh RNG streams and
        // recovery always makes progress. Held/dropped mail is never
        // delivered late: the rolled-back timeline regenerates it, which
        // is what keeps the recovered run bit-identical to a clean one.
        if let Some(reason) = chaos_ctl.as_mut().and_then(|c| c.take_pending()) {
            let ckpt = recovery.rollback("graphhp", &reason, &mut metrics);
            iteration = restore_from_checkpoint(
                program,
                dg,
                ckpt,
                &mut dg_owned,
                &mut applied_plans,
                &mut parts,
                &mut policies,
            );
            if let Some(ctl) = chaos_ctl.as_mut() {
                ctl.note_recovery();
            }
            continue;
        }

        // ---- adaptive barrier update: fold the just-recorded counters
        // into each partition's policy, in partition order on the engine
        // thread — deterministic regardless of worker interleaving
        if let Some(acfg) = adaptive {
            let step = trace.steps.last().expect("barrier just recorded a step");
            for (policy, ptrace) in policies.iter_mut().zip(&step.partitions) {
                policy.adapt(acfg, ptrace, limit_cap);
            }
        }

        // ---- online repartitioning: stamp this step's epoch, then fold
        // its counters into a migration plan and apply it atomically —
        // every partition is step-closed and all cross-partition mail
        // already landed, so the whole live state is remappable
        {
            let step = trace.steps.last_mut().expect("barrier just recorded a step");
            step.routing_epoch = dgr.routing.epoch;
            let plan = planner.as_ref().and_then(|pl| pl.plan(dgr, step, iteration));
            if let Some(plan) = plan {
                // chaos: a kill scheduled inside this migration window
                // fires between plan and apply — the planned epoch is
                // abandoned and the engine rolls back; the replay
                // re-derives the identical plan from the same counters
                // and the consumed entry never re-fires, so the retried
                // window applies cleanly
                let survive = match chaos_ctl.as_mut() {
                    Some(ctl) => ctl.judge_migration(plan.len() as u64),
                    None => true,
                };
                if !survive {
                    let reason = chaos_ctl
                        .as_mut()
                        .and_then(|c| c.take_pending())
                        .expect("migration kill raised a pending loss");
                    let ckpt = recovery.rollback("graphhp", &reason, &mut metrics);
                    iteration = restore_from_checkpoint(
                        program,
                        dg,
                        ckpt,
                        &mut dg_owned,
                        &mut applied_plans,
                        &mut parts,
                        &mut policies,
                    );
                    if let Some(ctl) = chaos_ctl.as_mut() {
                        ctl.note_recovery();
                    }
                    continue;
                }
                step.migrated = plan.len() as u64;
                let new_dg = Box::new(dgr.apply_migration(&plan));
                let mut rts = Vec::with_capacity(parts.len());
                let mut gqc = Vec::with_capacity(parts.len());
                let mut gqn = Vec::with_capacity(parts.len());
                for hp in std::mem::take(&mut parts) {
                    rts.push(hp.rt);
                    gqc.push(hp.gq_cur);
                    gqn.push(hp.gq_nxt);
                }
                let rts = remap_runtimes(dgr, &new_dg, rts, combiner);
                let gqc = remap_stores(dgr, &new_dg, gqc, combiner);
                let gqn = remap_stores(dgr, &new_dg, gqn, combiner);
                parts = rts
                    .into_iter()
                    .zip(gqc.into_iter().zip(gqn))
                    .map(|(rt, (gq_cur, gq_nxt))| {
                        let n = rt.num_vertices();
                        HpPart {
                            rt,
                            gq_cur,
                            gq_nxt,
                            outbox: Outbox::new(combiner),
                            scratch: WorkerScratch::new(),
                            marks: ProcessedMarks::new(n),
                        }
                    })
                    .collect();
                applied_plans.push(plan);
                dg_owned = Some(new_dg);
            }
        }

        metrics.global_iterations += 1;
        iteration += 1;

        // swap global inboxes for the next iteration
        for hp in parts.iter_mut() {
            std::mem::swap(&mut hp.gq_cur, &mut hp.gq_nxt);
        }

        // termination: every vertex inactive, nothing in transit
        let done = parts.iter_mut().all(|hp| {
            hp.rt.halted.iter().all(|&h| h) && hp.gq_cur.is_empty() && hp.rt.quiesced()
        });
        if done || iteration >= cfg.limits.max_iterations {
            break;
        }
    }

    // gather under the FINAL routing epoch — migrated vertices are read
    // back from their current owners
    let dgr: &DistGraph = dg_owned.as_deref().unwrap_or(dg);
    let values =
        super::gather_values_owned(dgr, parts.into_iter().map(|hp| hp.rt.values).collect());
    RunResult { values, metrics, trace, chaos: chaos_ctl.map(|c| c.into_trace()) }
}

/// Roll every partition back to `ckpt` — the shared body of legacy
/// `inject_failure_at` recovery and chaos-driven recovery. Geometry
/// first: the failure may have happened epochs ahead of the checkpoint,
/// so the checkpointed migration trajectory is replayed onto the
/// pristine graph to rebuild the exact geometry the per-partition
/// arrays were snapshotted under; then values, halt flags, in-flight
/// mail (local inbox pair + global-phase inbox) and scheduler policies
/// are restored verbatim. Returns the checkpoint's iteration.
fn restore_from_checkpoint<P: VertexProgram>(
    program: &P,
    dg: &DistGraph,
    ckpt: &super::checkpoint::Checkpoint<P::V, P::M>,
    dg_owned: &mut Option<Box<DistGraph>>,
    applied_plans: &mut Vec<MigrationPlan>,
    parts: &mut Vec<HpPart<P>>,
    policies: &mut Vec<PartitionPolicy>,
) -> u64 {
    *dg_owned = super::recovery::replay_geometry(dg, &ckpt.migrations);
    *applied_plans = ckpt.migrations.clone();
    let dgc: &DistGraph = dg_owned.as_deref().unwrap_or(dg);
    *parts = dgc.parts.iter().map(|pg| HpPart::new(program, pg)).collect();
    for (p, hp) in parts.iter_mut().enumerate() {
        let n = hp.rt.num_vertices();
        hp.rt.values = ckpt.values[p].clone();
        hp.rt.halted = ckpt.halted[p].clone();
        hp.rt.cur = MsgStore::restore(n, &ckpt.local_cur[p]);
        hp.rt.nxt = MsgStore::restore(n, &ckpt.local_nxt[p]);
        hp.rt.frontier = Frontier::restore(n, &ckpt.frontier[p]);
        hp.gq_cur = MsgStore::restore(n, &ckpt.inbox[p]);
        hp.gq_nxt = MsgStore::new(n);
    }
    // cap floored at 1 defensively: a hand-edited on-disk checkpoint
    // with cap 0 would abort every local step
    *policies = ckpt
        .policy
        .iter()
        .map(|pol| PolicyCheckpoint { cap: pol.cap.max(1), ..*pol })
        .collect();
    ckpt.iteration
}

#[cfg(test)]
mod tests {
    use super::super::context::VertexContext;
    use super::*;
    use crate::engine::hama::run_hama;
    use crate::graph::{generators, DistGraph, VertexId};
    use crate::partition::{hash_partition, metis_partition, MetisConfig};

    struct MinLabel;
    impl VertexProgram for MinLabel {
        type V = u32;
        type M = u32;
        fn init(&self, v: VertexId, _d: u32) -> u32 {
            v
        }
        fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
            let mut best = *ctx.value();
            if ctx.superstep() == 0 {
                ctx.send_to_neighbors(best);
            } else if let Some(&m) = ctx.messages().iter().min() {
                if m < best {
                    best = m;
                    ctx.set_value(best);
                    ctx.send_to_neighbors(best);
                }
            }
            ctx.vote_to_halt();
        }
        fn combiner(&self) -> Option<fn(u32, u32) -> u32> {
            Some(|a, b| a.min(b))
        }
    }

    #[test]
    fn matches_hama_with_far_fewer_iterations() {
        let g = generators::connected(400, 100, 11);
        let a = metis_partition(&g, 4, &MetisConfig::default());
        let dg = DistGraph::new(&g, &a, 4);
        let cfg = EngineConfig::default();
        let h = run_hama(&MinLabel, &dg, &cfg);
        let hp = run_graphhp(&MinLabel, &dg, &cfg);
        assert_eq!(h.values, hp.values);
        assert!(
            hp.metrics.global_iterations * 2 <= h.metrics.global_iterations,
            "graphhp={} hama={}",
            hp.metrics.global_iterations,
            h.metrics.global_iterations
        );
        assert!(hp.metrics.network_messages <= h.metrics.network_messages);
    }

    #[test]
    fn single_partition_converges_in_two_iterations() {
        // one partition => everything is local: iteration 0 (init) +
        // iteration 1 (local fixpoint) + possibly 1 empty to quiesce
        let g = generators::connected(200, 80, 3);
        let dg = DistGraph::new(&g, &vec![0; 200], 1);
        let r = run_graphhp(&MinLabel, &dg, &EngineConfig::default());
        assert!(r.values.iter().all(|&v| v == 0));
        assert!(r.metrics.global_iterations <= 3, "{}", r.metrics.global_iterations);
        assert_eq!(r.metrics.network_messages, 0);
    }

    #[test]
    fn boundary_not_in_local_phase_still_correct() {
        let g = generators::connected(150, 60, 7);
        let a = hash_partition(&g, 3);
        let dg = DistGraph::new(&g, &a, 3);
        let mut cfg = EngineConfig::default();
        cfg.hybrid.set_boundary_in_local_phase(false);
        let r = run_graphhp(&MinLabel, &dg, &cfg);
        assert!(r.values.iter().all(|&v| v == 0), "label must reach all");
    }

    #[test]
    fn sync_local_messaging_still_correct() {
        let g = generators::connected(150, 60, 9);
        let a = hash_partition(&g, 3);
        let dg = DistGraph::new(&g, &a, 3);
        let mut cfg = EngineConfig::default();
        cfg.hybrid.set_async_local_messaging(false);
        let r = run_graphhp(&MinLabel, &dg, &cfg);
        assert!(r.values.iter().all(|&v| v == 0));
    }

    #[test]
    fn pseudo_supersteps_counted() {
        let g = generators::connected(100, 40, 13);
        let dg = DistGraph::new(&g, &vec![0; 100], 1);
        let r = run_graphhp(&MinLabel, &dg, &EngineConfig::default());
        // pseudo-supersteps make supersteps_total exceed global iterations
        assert!(r.metrics.supersteps_total > r.metrics.global_iterations);
    }

    // ------------------------------------------ cap-truncation regression

    /// Regression for the pseudo-superstep cap bug: the pre-lifecycle
    /// code broke out of the local loop AFTER `begin_step()` had drained
    /// the frontier and swapped the inboxes, silently dropping scheduled
    /// vertices and stranding mail in `nxt` — the run livelocked until
    /// `max_iterations`. A truncated local phase must lose nothing.
    #[test]
    fn pseudo_superstep_cap_converges_without_livelock() {
        let g = generators::connected(200, 80, 17);
        let a = hash_partition(&g, 3);
        let dg = DistGraph::new(&g, &a, 3);
        let mut cfg = EngineConfig::default();
        cfg.limits.max_pseudo_supersteps = 1;
        cfg.limits.max_iterations = 500;
        let r = run_graphhp(&MinLabel, &dg, &cfg);
        assert!(r.values.iter().all(|&v| v == 0), "capped run must still converge");
        assert!(
            r.metrics.global_iterations < 500,
            "cap must not livelock until max_iterations (took {})",
            r.metrics.global_iterations
        );
        // and the result is exactly the uncapped fixed point
        let full = run_graphhp(&MinLabel, &dg, &EngineConfig::default());
        assert_eq!(r.values, full.values);
    }

    /// A program that stays active WITHOUT mail: every vertex must
    /// compute exactly `target` times before halting. Under the old bug
    /// the cap dropped the drained frontier, so non-boundary vertices
    /// stopped being scheduled, never reached the target, and never
    /// halted — proof that carryover preserves frontier entries (not
    /// just messages).
    struct CountTo {
        target: u32,
    }
    impl VertexProgram for CountTo {
        type V = u32;
        type M = u32;
        fn init(&self, _v: VertexId, _d: u32) -> u32 {
            0
        }
        fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
            let v = *ctx.value() + 1;
            ctx.set_value(v);
            if v >= self.target {
                ctx.vote_to_halt();
            }
        }
    }

    #[test]
    fn cap_carryover_preserves_frontier_entries() {
        let g = generators::connected(120, 50, 19);
        let a = hash_partition(&g, 3);
        let dg = DistGraph::new(&g, &a, 3);
        let mut cfg = EngineConfig::default();
        cfg.limits.max_pseudo_supersteps = 1;
        cfg.limits.max_iterations = 200;
        let r = run_graphhp(&CountTo { target: 12 }, &dg, &cfg);
        assert!(
            r.values.iter().all(|&v| v == 12),
            "every vertex computes to the target exactly (lost frontier entries \
             would leave some below it): {:?}",
            r.values.iter().filter(|&&v| v != 12).take(5).collect::<Vec<_>>()
        );
        assert!(
            r.metrics.global_iterations < 200,
            "carryover must converge, not livelock ({})",
            r.metrics.global_iterations
        );
    }

    // ------------------------------------------------- adaptive policy

    #[test]
    fn adaptive_matches_static_fixed_point() {
        let g = generators::connected(300, 120, 29);
        let a = metis_partition(&g, 4, &MetisConfig::default());
        let dg = DistGraph::new(&g, &a, 4);
        let stat = run_graphhp(&MinLabel, &dg, &EngineConfig::default());
        let mut cfg = EngineConfig::default();
        cfg.hybrid = super::super::HybridPolicy::adaptive();
        let adp = run_graphhp(&MinLabel, &dg, &cfg);
        assert_eq!(stat.values, adp.values, "confluent program: same fixed point");
        assert!(adp.values.iter().all(|&v| v == 0));
    }

    #[test]
    fn adaptive_trace_records_locality_and_steps() {
        let g = generators::connected(200, 80, 31);
        let a = hash_partition(&g, 4);
        let dg = DistGraph::new(&g, &a, 4);
        let mut cfg = EngineConfig::default();
        cfg.hybrid = super::super::HybridPolicy::adaptive();
        let r = run_graphhp(&MinLabel, &dg, &cfg);
        assert_eq!(r.trace.partition_locality.len(), 4);
        assert!(r.trace.partition_locality.iter().all(|&s| (0.0..=1.0).contains(&s)));
        assert_eq!(r.trace.iterations(), r.metrics.global_iterations);
        for s in &r.trace.steps {
            assert_eq!(s.partitions.len(), 4, "one record per partition per step");
        }
    }

    /// CountTo needs `target` pseudo-supersteps per vertex; a tiny
    /// initial cap forces carryovers, the controller halves/doubles
    /// around them, and the run must still reach the exact fixed point.
    #[test]
    fn adaptive_cap_carryover_converges_exactly() {
        let g = generators::connected(120, 50, 37);
        let a = hash_partition(&g, 3);
        let dg = DistGraph::new(&g, &a, 3);
        let mut cfg = EngineConfig::default();
        cfg.hybrid = super::super::HybridPolicy::Adaptive(super::super::AdaptiveConfig {
            initial_cap: 1,
            ..Default::default()
        });
        cfg.limits.max_iterations = 300;
        let r = run_graphhp(&CountTo { target: 12 }, &dg, &cfg);
        assert!(r.values.iter().all(|&v| v == 12), "carryover must lose nothing");
        assert!(r.metrics.global_iterations < 300, "no livelock");
        assert!(
            r.trace.carryover_events() > 0,
            "a cap of 1 against 12 required sweeps must carry over at least once"
        );
    }

    /// The controller's rules, exercised directly: grow the cap while
    /// the local frontier shrinks geometrically (even across a
    /// carryover), halve it on a flat-frontier carryover, shed boundary
    /// participation after two consecutive thrashing carryovers and
    /// restore it after two clean iterations, skip the local phase on a
    /// backlog-free boundary-dominated frontier.
    #[test]
    fn adaptive_controller_rules() {
        let acfg = super::super::AdaptiveConfig::default();
        let mut pol = PartitionPolicy::initial(&acfg, 0.9, 1 << 20);
        assert!(pol.boundary_in_local, "high locality starts boundary-in-local");
        assert_eq!(pol.cap, 64);

        // shrinking local frontier (100 -> 10 over 3 sweeps): cap doubles
        let shrinking = PartitionStepTrace {
            frontier: 10,
            boundary_frontier: 1,
            pseudo_supersteps: 3,
            local_frontier_first: 100,
            local_frontier_last: 10,
            local_backlog: 5,
            ..Default::default()
        };
        pol.adapt(&acfg, &shrinking, 1 << 20);
        assert_eq!(pol.cap, 128);
        assert!(pol.run_local);

        // a carryover that was still shrinking: the cap grows instead of
        // shrinking — the phase only needed more room
        let converging_carry =
            PartitionStepTrace { carryover: true, local_backlog: 50, ..shrinking.clone() };
        pol.adapt(&acfg, &converging_carry, 1 << 20);
        assert_eq!(pol.cap, 256, "shrinking carryover grows the cap");
        assert!(pol.boundary_in_local);

        // flat-frontier (thrashing) carryovers: cap halves each time,
        // boundary participation sheds after two in a row
        let thrash = PartitionStepTrace {
            carryover: true,
            pseudo_supersteps: 3,
            local_frontier_first: 100,
            local_frontier_last: 100,
            local_backlog: 50,
            frontier: 10,
            boundary_frontier: 1,
            ..Default::default()
        };
        pol.adapt(&acfg, &thrash, 1 << 20);
        assert_eq!(pol.cap, 128);
        assert!(pol.boundary_in_local, "one thrash is not yet a streak");
        pol.adapt(&acfg, &thrash, 1 << 20);
        assert_eq!(pol.cap, 64);
        assert!(!pol.boundary_in_local, "two consecutive thrashes shed boundary work");

        // two clean iterations: the locality-preferred setting returns
        let clean = PartitionStepTrace { pseudo_supersteps: 1, ..Default::default() };
        pol.adapt(&acfg, &clean, 1 << 20);
        pol.adapt(&acfg, &clean, 1 << 20);
        assert!(pol.boundary_in_local, "clean streak restores the preference");

        // boundary-dominated frontier with zero backlog: skip the phase
        let dominated = PartitionStepTrace {
            frontier: 10,
            boundary_frontier: 10,
            local_backlog: 0,
            ..Default::default()
        };
        pol.adapt(&acfg, &dominated, 1 << 20);
        assert!(!pol.run_local, "boundary-dominated + no backlog => skip");
        // any backlog re-enables it — carried-over work can never strand
        let backlogged = PartitionStepTrace { local_backlog: 1, ..dominated.clone() };
        pol.adapt(&acfg, &backlogged, 1 << 20);
        assert!(pol.run_local, "backlog forces the local phase back on");

        // cap 1 must not be absorbing: a single executed sweep whose
        // rolled-back worklist halved still reads as converging, so the
        // cap grows back out (regression: the old two-sweep shrink test
        // could never pass at cap 1)
        let mut stuck = PartitionPolicy::initial(&acfg, 0.9, 1 << 20);
        stuck.cap = 1;
        let one_sweep_converging = PartitionStepTrace {
            carryover: true,
            pseudo_supersteps: 1,
            local_frontier_first: 100,
            local_frontier_last: 40,
            local_backlog: 40,
            ..Default::default()
        };
        stuck.adapt(&acfg, &one_sweep_converging, 1 << 20);
        assert_eq!(stuck.cap, 2, "cap 1 escapes via the rolled-back worklist sample");
        stuck.adapt(&acfg, &one_sweep_converging, 1 << 20);
        assert_eq!(stuck.cap, 4);

        // the cap never leaves [min_cap, min(max_cap, limit)]
        let mut low = PartitionPolicy::initial(&acfg, 0.0, 4);
        assert_eq!(low.cap, 4, "limit clamps the initial cap");
        assert!(!low.boundary_in_local, "low locality starts boundary-out");
        for _ in 0..10 {
            low.adapt(&acfg, &thrash, 4);
        }
        assert_eq!(low.cap, 1, "floored at min_cap");
        for _ in 0..10 {
            low.adapt(&acfg, &shrinking, 4);
        }
        assert_eq!(low.cap, 4, "clamped by the limits cap");
    }

    /// A fully boundary-dominated partition (alternating 2-partition
    /// split of a path: every vertex has a remote in-edge) with zero
    /// local backlog must get its local phase skipped by the scheduler.
    #[test]
    fn adaptive_skips_local_phase_when_boundary_dominated() {
        let mut b = crate::graph::GraphBuilder::new(12);
        for v in 0..11u32 {
            b.add_undirected(v, v + 1, 1.0);
        }
        let g = b.build();
        let assignment: Vec<u32> = (0..12).map(|v| v % 2).collect();
        let dg = DistGraph::new(&g, &assignment, 2);
        assert_eq!(dg.num_boundary(), 12, "alternating split: all boundary");
        let mut cfg = EngineConfig::default();
        cfg.hybrid = super::super::HybridPolicy::adaptive();
        let r = run_graphhp(&MinLabel, &dg, &cfg);
        assert!(r.values.iter().all(|&v| v == 0), "still correct");
        assert!(
            r.trace.skipped_local_phases() > 0,
            "all-boundary partitions must skip local phases: {}",
            r.trace.to_json()
        );
        // and the low locality seeds boundary_in_local = false
        assert!(r.trace.partition_locality.iter().all(|&s| s < 0.5));
    }

    // ------------------------------------------- online repartitioning

    /// Migration on a hash-partitioned run (lots of cross-partition
    /// traffic): the planner must fire, every applied plan must leave
    /// the fixed point untouched, and the trace must record the epoch
    /// trajectory.
    #[test]
    fn online_repartitioning_reaches_the_same_fixed_point() {
        let g = generators::connected(300, 120, 41);
        let a = hash_partition(&g, 4);
        let dg = DistGraph::new(&g, &a, 4);
        let stat = run_graphhp(&MinLabel, &dg, &EngineConfig::default());
        let mut cfg = EngineConfig::default();
        cfg.repartition = Some(super::super::RepartitionConfig::every_barrier());
        let mig = run_graphhp(&MinLabel, &dg, &cfg);
        assert_eq!(stat.values, mig.values, "migration must not change the fixed point");
        assert!(
            mig.trace.vertices_migrated() > 0,
            "hash partitioning under every-barrier planning must move vertices"
        );
        // the epoch trajectory is monotone and advances exactly when a
        // step migrated
        let mut epoch = 0u64;
        for s in &mig.trace.steps {
            assert_eq!(s.routing_epoch, epoch, "iteration {}", s.iteration);
            if s.migrated > 0 {
                epoch += 1;
            }
        }
        assert!(epoch > 0);
        // the static run never leaves epoch 0 and never migrates
        assert!(stat.trace.steps.iter().all(|s| s.routing_epoch == 0 && s.migrated == 0));
    }

    /// Sync-mode local messaging takes the NextSweep route, which is the
    /// path that parks mail in `nxt` — exactly what the old cap break
    /// stranded. Cover it too.
    #[test]
    fn cap_carryover_sync_local_messaging() {
        let g = generators::connected(150, 60, 23);
        let a = hash_partition(&g, 3);
        let dg = DistGraph::new(&g, &a, 3);
        let mut cfg = EngineConfig::default();
        cfg.hybrid.set_async_local_messaging(false);
        cfg.limits.max_pseudo_supersteps = 1;
        cfg.limits.max_iterations = 500;
        let r = run_graphhp(&MinLabel, &dg, &cfg);
        assert!(r.values.iter().all(|&v| v == 0));
        assert!(r.metrics.global_iterations < 500, "{}", r.metrics.global_iterations);
    }
}
