//! The GraphHP hybrid execution engine (paper §4.2, §5) — the system
//! contribution of the paper.
//!
//! Execution is a sequence of **global iterations**. Iteration 0 is the
//! initialization superstep (identical to standard BSP). Every iteration
//! ≥ 1 is:
//!
//! 1. **Global phase** (`globalSuperstep()` of Alg. 2): each active
//!    boundary vertex computes once on the messages buffered for it
//!    during the previous iteration. Messages it sends to *local-class*
//!    vertices of its own partition go to the immediate local phase;
//!    messages to boundary vertices of its own partition are buffered for
//!    the next iteration's global phase (unless boundary vertices
//!    participate in local phases); messages to remote vertices are
//!    buffered for RPC delivery at the next barrier.
//! 2. **Local phase** (`pseudoSuperstep()` of Alg. 2): pseudo-supersteps
//!    over the partition's participating vertices, entirely in memory,
//!    repeated until every participant is inactive and no message is in
//!    transit inside the partition.
//!
//! Distributed synchronization + communication happen once per global
//! iteration — the whole point of the hybrid model.

use std::collections::BTreeSet;

use crate::graph::DistGraph;

use super::aggregator::Aggregators;
use super::context::{SendBuffer, VertexContext};
use super::messages::{MsgStore, Outbox};
use super::metrics::Metrics;
use super::netsim::{SuperstepClock, WorkerComm};
use super::program::VertexProgram;
use super::{EngineConfig, RunResult};

/// Per-partition state of the hybrid engine.
struct HpPart<P: VertexProgram> {
    values: Vec<P::V>,
    halted: Vec<bool>,
    /// Global-phase inbox for the CURRENT iteration.
    gq_cur: MsgStore<P::M>,
    /// Global-phase inbox for the NEXT iteration (remote deliveries +
    /// same-partition messages to non-participating boundary vertices).
    gq_nxt: MsgStore<P::M>,
    /// Local-phase pseudo-superstep inboxes.
    lq_cur: MsgStore<P::M>,
    lq_nxt: MsgStore<P::M>,
    /// Local-phase frontier for the next pseudo-superstep.
    l_frontier: Vec<u32>,
    in_l_frontier: Vec<bool>,
}

impl<P: VertexProgram> HpPart<P> {
    fn new(program: &P, part: &crate::graph::PartGraph) -> Self {
        let n = part.num_vertices();
        HpPart {
            values: (0..n)
                .map(|lv| program.init(part.global_ids[lv], part.out_degree[lv]))
                .collect(),
            halted: vec![false; n],
            gq_cur: MsgStore::new(n),
            gq_nxt: MsgStore::new(n),
            lq_cur: MsgStore::new(n),
            lq_nxt: MsgStore::new(n),
            l_frontier: Vec::new(),
            in_l_frontier: vec![false; n],
        }
    }

    fn schedule_local(&mut self, lv: usize) {
        if !self.in_l_frontier[lv] {
            self.in_l_frontier[lv] = true;
            self.l_frontier.push(lv as u32);
        }
    }

    fn take_local_frontier(&mut self) -> Vec<u32> {
        for &lv in &self.l_frontier {
            self.in_l_frontier[lv as usize] = false;
        }
        std::mem::take(&mut self.l_frontier)
    }
}

/// Route one send originating in partition `p`.
///
/// `in_local_phase` selects the local-phase routing rules; during the
/// global phase, same-partition messages go to the local phase inbox
/// (participants) or the next global inbox (non-participating boundary).
#[allow(clippy::too_many_arguments)]
fn route_send<P: VertexProgram>(
    hp: &mut HpPart<P>,
    outbox: &mut Outbox<P::M>,
    dg: &DistGraph,
    p: usize,
    src_gid: crate::graph::VertexId,
    target: crate::graph::VertexId,
    m: P::M,
    boundary_in_local: bool,
    combiner: Option<fn(P::M, P::M) -> P::M>,
    metrics: &mut Metrics,
    // async local delivery: Some((processed stamps, current stamp,
    // worklist)) while a pseudo-superstep sweep is in progress and async
    // messaging is on
    async_ctx: Option<(&[u32], u32, &mut BTreeSet<u32>)>,
) {
    let (tp, tl) = dg.location[target as usize];
    if tp as usize != p {
        outbox.push(tp, tl, src_gid, m);
        return;
    }
    let tl = tl as usize;
    metrics.local_messages += 1;
    let target_is_boundary = dg.parts[p].is_boundary[tl];
    let participates = boundary_in_local || !target_is_boundary;
    if !participates {
        // boundary vertex not in local phase: buffer for the next
        // iteration's global phase (paper §4.2)
        hp.gq_nxt.push_combined(tl, m, combiner);
        return;
    }
    // participant: in-memory local-phase delivery
    if let Some((stamps, stamp, worklist)) = async_ctx {
        if stamps[tl] != stamp {
            hp.lq_cur.push_combined(tl, m, combiner);
            worklist.insert(tl as u32);
            return;
        }
    }
    hp.lq_nxt.push_combined(tl, m, combiner);
    hp.schedule_local(tl);
}

/// Run `program` under the GraphHP hybrid execution model.
///
/// Legacy entry point — use [`super::Runner`] with
/// [`super::EngineKind::GraphHP`]; kept as a delegate for one release.
#[doc(hidden)]
pub fn run_graphhp<P: VertexProgram>(
    program: &P,
    dg: &DistGraph,
    cfg: &EngineConfig,
) -> RunResult<P::V> {
    let mut parts: Vec<HpPart<P>> =
        dg.parts.iter().map(|pg| HpPart::new(program, pg)).collect();
    let mut metrics = Metrics::default();
    let mut clock = SuperstepClock::new();
    let mut aggs = Aggregators::new(
        (0..program.num_aggregators()).map(|i| program.aggregator_op(i)).collect(),
    );
    let combiner = program.combiner();
    let source_combine = program.source_combine();
    let boundary_in_local = cfg.hybrid.boundary_in_local_phase;

    let mut iteration: u64 = 0;
    let mut msg_buf: Vec<P::M> = Vec::new();
    let mut send_buf: SendBuffer<P::M> = SendBuffer::new();
    let mut last_ckpt: Option<super::checkpoint::Checkpoint<P::V, P::M>> = None;
    let mut failure_pending = cfg.fault.inject_failure_at;

    loop {
        // ---- fault tolerance (paper §5.3) --------------------------
        if cfg.fault.checkpoint_interval.is_some_and(|n| n > 0 && iteration % n == 0) {
            let ckpt = super::checkpoint::Checkpoint {
                iteration,
                values: parts.iter().map(|hp| hp.values.clone()).collect(),
                halted: parts.iter().map(|hp| hp.halted.clone()).collect(),
                inbox: parts.iter_mut().map(|hp| hp.gq_cur.export()).collect(),
            };
            if let Some(dir) = &cfg.fault.checkpoint_dir {
                let _ = ckpt.save(dir);
            }
            last_ckpt = Some(ckpt);
            metrics.checkpoints += 1;
        }
        if failure_pending == Some(iteration) {
            failure_pending = None;
            metrics.recoveries += 1;
            match &last_ckpt {
                Some(ckpt) => {
                    // worker lost: reassign its partitions and roll every
                    // worker back to the latest consistent checkpoint
                    for (p, hp) in parts.iter_mut().enumerate() {
                        let n = hp.values.len();
                        hp.values = ckpt.values[p].clone();
                        hp.halted = ckpt.halted[p].clone();
                        hp.gq_cur = super::messages::MsgStore::restore(n, &ckpt.inbox[p]);
                        hp.gq_nxt = super::messages::MsgStore::new(n);
                        hp.lq_cur = super::messages::MsgStore::new(n);
                        hp.lq_nxt = super::messages::MsgStore::new(n);
                        hp.l_frontier.clear();
                        hp.in_l_frontier = vec![false; n];
                    }
                    iteration = ckpt.iteration;
                }
                None => {
                    // no checkpoint yet: restart from scratch
                    parts = dg.parts.iter().map(|pg| HpPart::new(program, pg)).collect();
                    iteration = 0;
                }
            }
        }

        let mut outboxes: Vec<Outbox<P::M>> = Vec::with_capacity(dg.num_parts());
        let mut worker_aggs: Vec<Aggregators> = Vec::new();

        for p in 0..dg.num_parts() {
            let part = &dg.parts[p];
            let hp = &mut parts[p];
            let mut outbox: Outbox<P::M> = Outbox::new(combiner);
            let mut wagg = aggs.clone();
            let t0 = std::time::Instant::now();
            let mut pseudo_steps: u64 = 0;

            if iteration == 0 {
                // ---- initialization iteration: identical to a standard
                // first superstep over every vertex (paper §4.2)
                for lv in 0..part.num_vertices() {
                    msg_buf.clear();
                    send_buf.clear();
                    {
                        let mut ctx = VertexContext::<P> {
                            part,
                            lv,
                            superstep: 0,
                            value: &mut hp.values[lv],
                            messages: &msg_buf,
                            halted: &mut hp.halted[lv],
                            out: &mut send_buf,
                            aggregators: &mut wagg,
                            seed: cfg.seed,
                        };
                        program.compute(&mut ctx);
                    }
                    metrics.vertex_computations += 1;
                    let src_gid = part.global_ids[lv];
                    for (target, m) in send_buf.sends.drain(..) {
                        route_send(
                            hp, &mut outbox, dg, p, src_gid, target, m,
                            boundary_in_local, combiner, &mut metrics, None,
                        );
                    }
                    if !hp.halted[lv] {
                        // unhalted vertices keep computing: boundary ones
                        // in the next global phase, participants in the
                        // next local phase
                        if part.is_boundary[lv] && !boundary_in_local {
                            // picked up by the global-phase participant
                            // rule (boundary && !halted)
                        } else {
                            hp.schedule_local(lv);
                        }
                    }
                }
                metrics.supersteps_total += 1;
            } else {
                // ---- global phase -----------------------------------
                // participants: any vertex with buffered global messages,
                // plus unhalted boundary vertices
                let mut gfrontier: Vec<u32> = hp.gq_cur.pending();
                for lv in 0..part.num_vertices() {
                    if part.is_boundary[lv] && !hp.halted[lv] && !hp.gq_cur.has_messages(lv) {
                        gfrontier.push(lv as u32);
                    }
                }
                gfrontier.sort_unstable();
                gfrontier.dedup();
                for &lv32 in &gfrontier {
                    let lv = lv32 as usize;
                    hp.gq_cur.take_into(lv, &mut msg_buf);
                    if hp.halted[lv] {
                        if msg_buf.is_empty() {
                            continue;
                        }
                        hp.halted[lv] = false;
                    }
                    send_buf.clear();
                    {
                        let mut ctx = VertexContext::<P> {
                            part,
                            lv,
                            superstep: iteration,
                            value: &mut hp.values[lv],
                            messages: &msg_buf,
                            halted: &mut hp.halted[lv],
                            out: &mut send_buf,
                            aggregators: &mut wagg,
                            seed: cfg.seed,
                        };
                        program.compute(&mut ctx);
                    }
                    metrics.vertex_computations += 1;
                    let src_gid = part.global_ids[lv];
                    for (target, m) in send_buf.sends.drain(..) {
                        route_send(
                            hp, &mut outbox, dg, p, src_gid, target, m,
                            boundary_in_local, combiner, &mut metrics, None,
                        );
                    }
                    if !hp.halted[lv] && boundary_in_local {
                        // unhalted boundary participant continues in the
                        // local phase
                        hp.schedule_local(lv);
                    }
                }
                metrics.supersteps_total += 1;

                // ---- local phase: pseudo-supersteps until quiescence --
                // generation-stamped "processed" marks: avoids an O(n)
                // allocation + clear per pseudo-superstep (perf log in
                // EXPERIMENTS.md §Perf)
                let mut stamps: Vec<u32> = vec![0; part.num_vertices()];
                let mut stamp: u32 = 0;
                loop {
                    std::mem::swap(&mut hp.lq_cur, &mut hp.lq_nxt);
                    let frontier = hp.take_local_frontier();
                    if frontier.is_empty() && hp.lq_cur.is_empty() {
                        break;
                    }
                    pseudo_steps += 1;
                    if pseudo_steps > cfg.limits.max_pseudo_supersteps {
                        break;
                    }
                    let mut worklist: BTreeSet<u32> = frontier.into_iter().collect();
                    for lv in hp.lq_cur.pending() {
                        worklist.insert(lv);
                    }
                    stamp += 1;
                    while let Some(lv32) = worklist.pop_first() {
                        let lv = lv32 as usize;
                        stamps[lv] = stamp;
                        hp.lq_cur.take_into(lv, &mut msg_buf);
                        if hp.halted[lv] {
                            if msg_buf.is_empty() {
                                continue;
                            }
                            hp.halted[lv] = false;
                        }
                        send_buf.clear();
                        {
                            let mut ctx = VertexContext::<P> {
                                part,
                                lv,
                                superstep: iteration,
                                value: &mut hp.values[lv],
                                messages: &msg_buf,
                                halted: &mut hp.halted[lv],
                                out: &mut send_buf,
                                aggregators: &mut wagg,
                                seed: cfg.seed,
                            };
                            program.compute(&mut ctx);
                        }
                        metrics.vertex_computations += 1;
                        let src_gid = part.global_ids[lv];
                        for (target, m) in send_buf.sends.drain(..) {
                            let async_ctx = if cfg.hybrid.async_local_messaging {
                                Some((&stamps[..], stamp, &mut worklist))
                            } else {
                                None
                            };
                            route_send(
                                hp, &mut outbox, dg, p, src_gid, target, m,
                                boundary_in_local, combiner, &mut metrics, async_ctx,
                            );
                        }
                        if !hp.halted[lv] {
                            hp.schedule_local(lv);
                        }
                    }
                    metrics.supersteps_total += 1;
                }
            }

            // GraphHP's SourceCombine applies to messages buffered across
            // the iteration boundary (no-op when a combiner exists)
            outbox.source_combine(source_combine);

            let compute = cfg.net.scale_compute(t0.elapsed());
            let comm = WorkerComm {
                messages: outbox.len() as u64,
                bytes: outbox.wire_bytes() as u64,
                peer_pairs: outbox.peer_count(p as u32) as u64,
            };
            metrics.network_messages += comm.messages;
            metrics.network_bytes += comm.bytes;
            clock.record_worker(compute, cfg.net.comm_time(&comm));
            outboxes.push(outbox);
            worker_aggs.push(wagg);
        }

        // ---- barrier: one distributed synchronization per iteration ---
        for mut outbox in outboxes {
            for (tp, tl, m) in outbox.drain() {
                parts[tp as usize].gq_nxt.push(tl as usize, m);
            }
        }
        for w in &worker_aggs {
            aggs.merge_current(w);
        }
        aggs.barrier();
        clock.barrier(&cfg.net, &mut metrics);
        metrics.global_iterations += 1;
        iteration += 1;

        // swap global inboxes for the next iteration
        for hp in parts.iter_mut() {
            std::mem::swap(&mut hp.gq_cur, &mut hp.gq_nxt);
        }

        // termination: every vertex inactive, nothing in transit
        let done = parts.iter_mut().all(|hp| {
            hp.halted.iter().all(|&h| h)
                && hp.gq_cur.is_empty()
                && hp.lq_cur.is_empty()
                && hp.lq_nxt.is_empty()
                && hp.l_frontier.is_empty()
        });
        if done || iteration >= cfg.limits.max_iterations {
            break;
        }
    }

    let values = super::gather_values(
        dg,
        &parts.iter().map(|hp| hp.values.clone()).collect::<Vec<_>>(),
    );
    RunResult { values, metrics }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::hama::run_hama;
    use crate::graph::{generators, DistGraph, VertexId};
    use crate::partition::{hash_partition, metis_partition, MetisConfig};

    struct MinLabel;
    impl VertexProgram for MinLabel {
        type V = u32;
        type M = u32;
        fn init(&self, v: VertexId, _d: u32) -> u32 {
            v
        }
        fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
            let mut best = *ctx.value();
            if ctx.superstep() == 0 {
                ctx.send_to_neighbors(best);
            } else if let Some(&m) = ctx.messages().iter().min() {
                if m < best {
                    best = m;
                    ctx.set_value(best);
                    ctx.send_to_neighbors(best);
                }
            }
            ctx.vote_to_halt();
        }
        fn combiner(&self) -> Option<fn(u32, u32) -> u32> {
            Some(|a, b| a.min(b))
        }
    }

    #[test]
    fn matches_hama_with_far_fewer_iterations() {
        let g = generators::connected(400, 100, 11);
        let a = metis_partition(&g, 4, &MetisConfig::default());
        let dg = DistGraph::new(&g, &a, 4);
        let cfg = EngineConfig::default();
        let h = run_hama(&MinLabel, &dg, &cfg);
        let hp = run_graphhp(&MinLabel, &dg, &cfg);
        assert_eq!(h.values, hp.values);
        assert!(
            hp.metrics.global_iterations * 2 <= h.metrics.global_iterations,
            "graphhp={} hama={}",
            hp.metrics.global_iterations,
            h.metrics.global_iterations
        );
        assert!(hp.metrics.network_messages <= h.metrics.network_messages);
    }

    #[test]
    fn single_partition_converges_in_two_iterations() {
        // one partition => everything is local: iteration 0 (init) +
        // iteration 1 (local fixpoint) + possibly 1 empty to quiesce
        let g = generators::connected(200, 80, 3);
        let dg = DistGraph::new(&g, &vec![0; 200], 1);
        let r = run_graphhp(&MinLabel, &dg, &EngineConfig::default());
        assert!(r.values.iter().all(|&v| v == 0));
        assert!(r.metrics.global_iterations <= 3, "{}", r.metrics.global_iterations);
        assert_eq!(r.metrics.network_messages, 0);
    }

    #[test]
    fn boundary_not_in_local_phase_still_correct() {
        let g = generators::connected(150, 60, 7);
        let a = hash_partition(&g, 3);
        let dg = DistGraph::new(&g, &a, 3);
        let mut cfg = EngineConfig::default();
        cfg.hybrid.boundary_in_local_phase = false;
        let r = run_graphhp(&MinLabel, &dg, &cfg);
        assert!(r.values.iter().all(|&v| v == 0), "label must reach all");
    }

    #[test]
    fn sync_local_messaging_still_correct() {
        let g = generators::connected(150, 60, 9);
        let a = hash_partition(&g, 3);
        let dg = DistGraph::new(&g, &a, 3);
        let mut cfg = EngineConfig::default();
        cfg.hybrid.async_local_messaging = false;
        let r = run_graphhp(&MinLabel, &dg, &cfg);
        assert!(r.values.iter().all(|&v| v == 0));
    }

    #[test]
    fn pseudo_supersteps_counted() {
        let g = generators::connected(100, 40, 13);
        let dg = DistGraph::new(&g, &vec![0; 100], 1);
        let r = run_graphhp(&MinLabel, &dg, &EngineConfig::default());
        // pseudo-supersteps make supersteps_total exceed global iterations
        assert!(r.metrics.supersteps_total > r.metrics.global_iterations);
    }
}
