//! The GraphHP hybrid execution engine (paper §4.2, §5) — the system
//! contribution of the paper.
//!
//! Execution is a sequence of **global iterations**. Iteration 0 is the
//! initialization superstep (identical to standard BSP). Every iteration
//! ≥ 1 is:
//!
//! 1. **Global phase** (`globalSuperstep()` of Alg. 2): each active
//!    boundary vertex computes once on the messages buffered for it
//!    during the previous iteration. Messages it sends to *local-class*
//!    vertices of its own partition go to the immediate local phase;
//!    messages to boundary vertices of its own partition are buffered for
//!    the next iteration's global phase (unless boundary vertices
//!    participate in local phases); messages to remote vertices are
//!    buffered for RPC delivery at the next barrier.
//! 2. **Local phase** (`pseudoSuperstep()` of Alg. 2): pseudo-supersteps
//!    over the partition's participating vertices, entirely in memory,
//!    repeated until every participant is inactive and no message is in
//!    transit inside the partition — or until
//!    [`super::Limits::max_pseudo_supersteps`], in which case the
//!    in-flight work **carries over**: the truncated step is rolled back
//!    with [`PartitionRuntime::abort_step_carryover`], so the remaining
//!    frontier and mail resume in the next iteration's local phase
//!    instead of being dropped (the pre-lifecycle code lost both and
//!    livelocked until `max_iterations`).
//!
//! Distributed synchronization + communication happen once per global
//! iteration — the whole point of the hybrid model.
//!
//! The per-vertex body of all three sweeps (init / global / local) is
//! the shared `super::worker::Sweep`; this file keeps only the phase
//! structure and the hybrid routing policy. Partitions run as parallel
//! workers per [`super::EngineConfig::parallelism`].

use std::collections::BTreeSet;

use crate::graph::{DistGraph, PartGraph};

use super::aggregator::Aggregators;
use super::messages::{MsgStore, Outbox};
use super::metrics::Metrics;
use super::netsim::SuperstepClock;
use super::program::VertexProgram;
use super::state::{Frontier, PartitionRuntime};
use super::worker::{
    close_superstep, run_workers, LocalRoute, ProcessedMarks, Reschedule, Sweep, SweepOutcome,
    SweepTarget, WorkerOut, WorkerScratch,
};
use super::{EngineConfig, RunResult};

/// Per-partition state of the hybrid engine: the shared
/// [`PartitionRuntime`] carries the local-phase inboxes/frontier, plus
/// the global-phase inbox pair the hybrid model adds on top and the
/// pooled outbox.
struct HpPart<P: VertexProgram> {
    rt: PartitionRuntime<P::V, P::M>,
    /// Global-phase inbox for the CURRENT iteration.
    gq_cur: MsgStore<P::M>,
    /// Global-phase inbox for the NEXT iteration (remote deliveries +
    /// same-partition messages to non-participating boundary vertices).
    gq_nxt: MsgStore<P::M>,
    outbox: Outbox<P::M>,
    scratch: WorkerScratch<P::M>,
    marks: ProcessedMarks,
}

impl<P: VertexProgram> HpPart<P> {
    fn new(program: &P, part: &PartGraph) -> Self {
        let rt = PartitionRuntime::new(program, part);
        let n = rt.num_vertices();
        HpPart {
            rt,
            gq_cur: MsgStore::new(n),
            gq_nxt: MsgStore::new(n),
            outbox: Outbox::new(program.combiner()),
            scratch: WorkerScratch::new(),
            marks: ProcessedMarks::new(n),
        }
    }
}

/// Run `program` under the GraphHP hybrid execution model.
///
/// Legacy entry point — use [`super::Runner`] with
/// [`super::EngineKind::GraphHP`]; kept as a delegate for one release.
#[doc(hidden)]
pub fn run_graphhp<P: VertexProgram>(
    program: &P,
    dg: &DistGraph,
    cfg: &EngineConfig,
) -> RunResult<P::V> {
    let mut parts: Vec<HpPart<P>> =
        dg.parts.iter().map(|pg| HpPart::new(program, pg)).collect();
    let mut metrics = Metrics::default();
    let mut clock = SuperstepClock::new();
    let mut aggs = Aggregators::new(
        (0..program.num_aggregators()).map(|i| program.aggregator_op(i)).collect(),
    );
    let combiner = program.combiner();
    let source_combine = program.source_combine();
    let boundary_in_local = cfg.hybrid.boundary_in_local_phase;

    let mut iteration: u64 = 0;
    let mut last_ckpt: Option<super::checkpoint::Checkpoint<P::V, P::M>> = None;
    let mut failure_pending = cfg.fault.inject_failure_at;

    loop {
        // ---- fault tolerance (paper §5.3) --------------------------
        if cfg.fault.checkpoint_interval.is_some_and(|n| n > 0 && iteration % n == 0) {
            // the snapshot covers the local-phase runtime state too:
            // after a cap-truncated local phase the carryover frontier
            // and in-flight mail are live state at the boundary
            let ckpt = super::checkpoint::Checkpoint {
                iteration,
                values: parts.iter().map(|hp| hp.rt.values.clone()).collect(),
                halted: parts.iter().map(|hp| hp.rt.halted.clone()).collect(),
                inbox: parts.iter_mut().map(|hp| hp.gq_cur.export()).collect(),
                local_cur: parts.iter_mut().map(|hp| hp.rt.cur.export()).collect(),
                local_nxt: parts.iter_mut().map(|hp| hp.rt.nxt.export()).collect(),
                frontier: parts.iter().map(|hp| hp.rt.frontier.snapshot()).collect(),
            };
            if let Some(dir) = &cfg.fault.checkpoint_dir {
                let _ = ckpt.save(dir);
            }
            last_ckpt = Some(ckpt);
            metrics.checkpoints += 1;
        }
        if failure_pending == Some(iteration) {
            failure_pending = None;
            metrics.recoveries += 1;
            match &last_ckpt {
                Some(ckpt) => {
                    // worker lost: reassign its partitions and roll every
                    // worker back to the latest consistent checkpoint
                    for (p, hp) in parts.iter_mut().enumerate() {
                        let n = hp.rt.num_vertices();
                        hp.rt.values = ckpt.values[p].clone();
                        hp.rt.halted = ckpt.halted[p].clone();
                        hp.rt.cur = MsgStore::restore(n, &ckpt.local_cur[p]);
                        hp.rt.nxt = MsgStore::restore(n, &ckpt.local_nxt[p]);
                        hp.rt.frontier = Frontier::restore(n, &ckpt.frontier[p]);
                        hp.gq_cur = MsgStore::restore(n, &ckpt.inbox[p]);
                        hp.gq_nxt = MsgStore::new(n);
                    }
                    iteration = ckpt.iteration;
                }
                None => {
                    // no checkpoint yet: restart from scratch
                    parts = dg.parts.iter().map(|pg| HpPart::new(program, pg)).collect();
                    iteration = 0;
                }
            }
        }

        let outs = run_workers(cfg.parallelism, &mut parts, |p, hp| {
            let HpPart { rt, gq_cur, gq_nxt, outbox, scratch, marks } = hp;
            let part = &dg.parts[p];
            outbox.reset();
            let mut wagg = aggs.clone();
            let t0 = std::time::Instant::now();
            let mut outcome = SweepOutcome::default();
            let mut steps: u64 = 0;

            let local_route = if cfg.hybrid.async_local_messaging {
                LocalRoute::ThisSweep
            } else {
                LocalRoute::NextSweep
            };
            let mk_sweep = |route: LocalRoute, reschedule: Reschedule| Sweep {
                program,
                dg,
                part,
                p,
                superstep: iteration,
                seed: cfg.seed,
                combiner,
                route,
                reschedule,
                boundary_in_local,
            };
            let merge = |outcome: &mut SweepOutcome, oc: SweepOutcome| {
                outcome.computations += oc.computations;
                outcome.local_messages += oc.local_messages;
            };

            if iteration == 0 {
                // ---- initialization iteration: identical to a standard
                // first superstep over every vertex (paper §4.2).
                // Unhalted vertices keep computing afterwards: boundary
                // ones in the next global phase (picked up by the
                // boundary && !halted rule), participants in the next
                // local phase (Reschedule::Participants).
                let worklist: BTreeSet<u32> = (0..part.num_vertices() as u32).collect();
                let oc = mk_sweep(LocalRoute::NextSweep, Reschedule::Participants).run(
                    worklist,
                    SweepTarget {
                        values: &mut rt.values,
                        halted: &mut rt.halted,
                        cur: &mut *gq_cur,
                        nxt: &mut rt.nxt,
                        frontier: Some(&mut rt.frontier),
                    },
                    Some(&mut *gq_nxt),
                    outbox,
                    &mut wagg,
                    scratch,
                    marks,
                );
                merge(&mut outcome, oc);
                steps += 1;
            } else {
                // ---- global phase -----------------------------------
                // participants: any vertex with buffered global messages,
                // plus unhalted boundary vertices; an unhalted boundary
                // participant continues in the local phase iff boundary
                // vertices take part in it
                let mut worklist: BTreeSet<u32> = gq_cur.pending().into_iter().collect();
                for lv in 0..part.num_vertices() {
                    if part.is_boundary[lv] && !rt.halted[lv] {
                        worklist.insert(lv as u32);
                    }
                }
                let resched =
                    if boundary_in_local { Reschedule::Active } else { Reschedule::Never };
                let oc = mk_sweep(LocalRoute::NextSweep, resched).run(
                    worklist,
                    SweepTarget {
                        values: &mut rt.values,
                        halted: &mut rt.halted,
                        cur: &mut *gq_cur,
                        nxt: &mut rt.nxt,
                        frontier: Some(&mut rt.frontier),
                    },
                    Some(&mut *gq_nxt),
                    outbox,
                    &mut wagg,
                    scratch,
                    marks,
                );
                merge(&mut outcome, oc);
                steps += 1;

                // ---- local phase: pseudo-supersteps until quiescence --
                // a cap of 0 would abort every phase before its first
                // sweep (zero progress, spin to max_iterations): floor 1
                let cap = cfg.limits.max_pseudo_supersteps.max(1);
                let mut pseudo_steps: u64 = 0;
                loop {
                    let taken = rt.begin_step();
                    let mut worklist: BTreeSet<u32> = taken.into_iter().collect();
                    for lv in rt.cur.pending() {
                        worklist.insert(lv);
                    }
                    if worklist.is_empty() {
                        rt.commit_step();
                        break;
                    }
                    if pseudo_steps >= cap {
                        // cap hit with work remaining: roll the step back
                        // so the frontier and in-flight mail carry over
                        // to the next iteration's local phase — nothing
                        // is dropped, nothing strands in the wrong inbox
                        rt.abort_step_carryover(worklist);
                        break;
                    }
                    pseudo_steps += 1;
                    let oc = mk_sweep(local_route, Reschedule::Active).run(
                        worklist,
                        rt.sweep_target(),
                        Some(&mut *gq_nxt),
                        outbox,
                        &mut wagg,
                        scratch,
                        marks,
                    );
                    rt.commit_step();
                    merge(&mut outcome, oc);
                    steps += 1;
                }
            }

            // GraphHP's SourceCombine applies to messages buffered across
            // the iteration boundary (subsumed by a full combiner)
            outbox.seal(source_combine);

            let compute = cfg.net.scale_compute(t0.elapsed());
            WorkerOut::new(std::mem::take(outbox), wagg, compute, p, outcome, steps)
        });

        // ---- barrier: one distributed synchronization per iteration;
        // remote mail lands with receiver-side combining
        let outboxes =
            close_superstep(outs, &mut aggs, &mut clock, &cfg.net, &mut metrics, |tp, tl, m| {
                parts[tp as usize].gq_nxt.push_combined(tl as usize, m, combiner);
            });
        for (hp, ob) in parts.iter_mut().zip(outboxes) {
            hp.outbox = ob;
        }
        metrics.global_iterations += 1;
        iteration += 1;

        // swap global inboxes for the next iteration
        for hp in parts.iter_mut() {
            std::mem::swap(&mut hp.gq_cur, &mut hp.gq_nxt);
        }

        // termination: every vertex inactive, nothing in transit
        let done = parts.iter_mut().all(|hp| {
            hp.rt.halted.iter().all(|&h| h) && hp.gq_cur.is_empty() && hp.rt.quiesced()
        });
        if done || iteration >= cfg.limits.max_iterations {
            break;
        }
    }

    let values =
        super::gather_values_owned(dg, parts.into_iter().map(|hp| hp.rt.values).collect());
    RunResult { values, metrics }
}

#[cfg(test)]
mod tests {
    use super::super::context::VertexContext;
    use super::*;
    use crate::engine::hama::run_hama;
    use crate::graph::{generators, DistGraph, VertexId};
    use crate::partition::{hash_partition, metis_partition, MetisConfig};

    struct MinLabel;
    impl VertexProgram for MinLabel {
        type V = u32;
        type M = u32;
        fn init(&self, v: VertexId, _d: u32) -> u32 {
            v
        }
        fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
            let mut best = *ctx.value();
            if ctx.superstep() == 0 {
                ctx.send_to_neighbors(best);
            } else if let Some(&m) = ctx.messages().iter().min() {
                if m < best {
                    best = m;
                    ctx.set_value(best);
                    ctx.send_to_neighbors(best);
                }
            }
            ctx.vote_to_halt();
        }
        fn combiner(&self) -> Option<fn(u32, u32) -> u32> {
            Some(|a, b| a.min(b))
        }
    }

    #[test]
    fn matches_hama_with_far_fewer_iterations() {
        let g = generators::connected(400, 100, 11);
        let a = metis_partition(&g, 4, &MetisConfig::default());
        let dg = DistGraph::new(&g, &a, 4);
        let cfg = EngineConfig::default();
        let h = run_hama(&MinLabel, &dg, &cfg);
        let hp = run_graphhp(&MinLabel, &dg, &cfg);
        assert_eq!(h.values, hp.values);
        assert!(
            hp.metrics.global_iterations * 2 <= h.metrics.global_iterations,
            "graphhp={} hama={}",
            hp.metrics.global_iterations,
            h.metrics.global_iterations
        );
        assert!(hp.metrics.network_messages <= h.metrics.network_messages);
    }

    #[test]
    fn single_partition_converges_in_two_iterations() {
        // one partition => everything is local: iteration 0 (init) +
        // iteration 1 (local fixpoint) + possibly 1 empty to quiesce
        let g = generators::connected(200, 80, 3);
        let dg = DistGraph::new(&g, &vec![0; 200], 1);
        let r = run_graphhp(&MinLabel, &dg, &EngineConfig::default());
        assert!(r.values.iter().all(|&v| v == 0));
        assert!(r.metrics.global_iterations <= 3, "{}", r.metrics.global_iterations);
        assert_eq!(r.metrics.network_messages, 0);
    }

    #[test]
    fn boundary_not_in_local_phase_still_correct() {
        let g = generators::connected(150, 60, 7);
        let a = hash_partition(&g, 3);
        let dg = DistGraph::new(&g, &a, 3);
        let mut cfg = EngineConfig::default();
        cfg.hybrid.boundary_in_local_phase = false;
        let r = run_graphhp(&MinLabel, &dg, &cfg);
        assert!(r.values.iter().all(|&v| v == 0), "label must reach all");
    }

    #[test]
    fn sync_local_messaging_still_correct() {
        let g = generators::connected(150, 60, 9);
        let a = hash_partition(&g, 3);
        let dg = DistGraph::new(&g, &a, 3);
        let mut cfg = EngineConfig::default();
        cfg.hybrid.async_local_messaging = false;
        let r = run_graphhp(&MinLabel, &dg, &cfg);
        assert!(r.values.iter().all(|&v| v == 0));
    }

    #[test]
    fn pseudo_supersteps_counted() {
        let g = generators::connected(100, 40, 13);
        let dg = DistGraph::new(&g, &vec![0; 100], 1);
        let r = run_graphhp(&MinLabel, &dg, &EngineConfig::default());
        // pseudo-supersteps make supersteps_total exceed global iterations
        assert!(r.metrics.supersteps_total > r.metrics.global_iterations);
    }

    // ------------------------------------------ cap-truncation regression

    /// Regression for the pseudo-superstep cap bug: the pre-lifecycle
    /// code broke out of the local loop AFTER `begin_step()` had drained
    /// the frontier and swapped the inboxes, silently dropping scheduled
    /// vertices and stranding mail in `nxt` — the run livelocked until
    /// `max_iterations`. A truncated local phase must lose nothing.
    #[test]
    fn pseudo_superstep_cap_converges_without_livelock() {
        let g = generators::connected(200, 80, 17);
        let a = hash_partition(&g, 3);
        let dg = DistGraph::new(&g, &a, 3);
        let mut cfg = EngineConfig::default();
        cfg.limits.max_pseudo_supersteps = 1;
        cfg.limits.max_iterations = 500;
        let r = run_graphhp(&MinLabel, &dg, &cfg);
        assert!(r.values.iter().all(|&v| v == 0), "capped run must still converge");
        assert!(
            r.metrics.global_iterations < 500,
            "cap must not livelock until max_iterations (took {})",
            r.metrics.global_iterations
        );
        // and the result is exactly the uncapped fixed point
        let full = run_graphhp(&MinLabel, &dg, &EngineConfig::default());
        assert_eq!(r.values, full.values);
    }

    /// A program that stays active WITHOUT mail: every vertex must
    /// compute exactly `target` times before halting. Under the old bug
    /// the cap dropped the drained frontier, so non-boundary vertices
    /// stopped being scheduled, never reached the target, and never
    /// halted — proof that carryover preserves frontier entries (not
    /// just messages).
    struct CountTo {
        target: u32,
    }
    impl VertexProgram for CountTo {
        type V = u32;
        type M = u32;
        fn init(&self, _v: VertexId, _d: u32) -> u32 {
            0
        }
        fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
            let v = *ctx.value() + 1;
            ctx.set_value(v);
            if v >= self.target {
                ctx.vote_to_halt();
            }
        }
    }

    #[test]
    fn cap_carryover_preserves_frontier_entries() {
        let g = generators::connected(120, 50, 19);
        let a = hash_partition(&g, 3);
        let dg = DistGraph::new(&g, &a, 3);
        let mut cfg = EngineConfig::default();
        cfg.limits.max_pseudo_supersteps = 1;
        cfg.limits.max_iterations = 200;
        let r = run_graphhp(&CountTo { target: 12 }, &dg, &cfg);
        assert!(
            r.values.iter().all(|&v| v == 12),
            "every vertex computes to the target exactly (lost frontier entries \
             would leave some below it): {:?}",
            r.values.iter().filter(|&&v| v != 12).take(5).collect::<Vec<_>>()
        );
        assert!(
            r.metrics.global_iterations < 200,
            "carryover must converge, not livelock ({})",
            r.metrics.global_iterations
        );
    }

    /// Sync-mode local messaging takes the NextSweep route, which is the
    /// path that parks mail in `nxt` — exactly what the old cap break
    /// stranded. Cover it too.
    #[test]
    fn cap_carryover_sync_local_messaging() {
        let g = generators::connected(150, 60, 23);
        let a = hash_partition(&g, 3);
        let dg = DistGraph::new(&g, &a, 3);
        let mut cfg = EngineConfig::default();
        cfg.hybrid.async_local_messaging = false;
        cfg.limits.max_pseudo_supersteps = 1;
        cfg.limits.max_iterations = 500;
        let r = run_graphhp(&MinLabel, &dg, &cfg);
        assert!(r.values.iter().all(|&v| v == 0));
        assert!(r.metrics.global_iterations < 500, "{}", r.metrics.global_iterations);
    }
}
