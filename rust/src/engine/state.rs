//! Per-partition runtime state shared by all push-based engines.

use crate::graph::{DistGraph, PartGraph};

use super::messages::MsgStore;
use super::program::VertexProgram;

/// Mutable state a worker keeps for one partition.
pub struct PartitionRuntime<P: VertexProgram> {
    /// Vertex values (by local index).
    pub values: Vec<P::V>,
    /// voteToHalt flags.
    pub halted: Vec<bool>,
    /// Incoming messages for the current (pseudo-)superstep.
    pub cur: MsgStore<P::M>,
    /// Incoming messages for the next (pseudo-)superstep.
    pub nxt: MsgStore<P::M>,
    /// Frontier for the next (pseudo-)superstep: vertices that must
    /// compute (not halted, or received a message).
    pub next_frontier: Vec<u32>,
    in_next_frontier: Vec<bool>,
}

impl<P: VertexProgram> PartitionRuntime<P> {
    /// Initialize values via `program.init` for every owned vertex; all
    /// vertices start active (standard BSP).
    pub fn new(program: &P, part: &PartGraph) -> Self {
        let n = part.num_vertices();
        let values = (0..n)
            .map(|lv| program.init(part.global_ids[lv], part.out_degree[lv]))
            .collect();
        PartitionRuntime {
            values,
            halted: vec![false; n],
            cur: MsgStore::new(n),
            nxt: MsgStore::new(n),
            next_frontier: Vec::new(),
            in_next_frontier: vec![false; n],
        }
    }

    pub fn num_vertices(&self) -> usize {
        self.values.len()
    }

    /// Mark `lv` to compute next (pseudo-)superstep.
    pub fn schedule_next(&mut self, lv: usize) {
        if !self.in_next_frontier[lv] {
            self.in_next_frontier[lv] = true;
            self.next_frontier.push(lv as u32);
        }
    }

    /// Swap message stores and take the next frontier for this step.
    pub fn begin_step(&mut self) -> Vec<u32> {
        std::mem::swap(&mut self.cur, &mut self.nxt);
        for &lv in &self.next_frontier {
            self.in_next_frontier[lv as usize] = false;
        }
        std::mem::take(&mut self.next_frontier)
    }

    /// A vertex is live if it has not halted or has pending messages.
    pub fn is_live(&self, lv: usize) -> bool {
        !self.halted[lv] || self.cur.has_messages(lv)
    }

    /// True when nothing remains to do in this partition:
    /// all halted and no undelivered messages.
    pub fn quiesced(&mut self) -> bool {
        self.next_frontier.is_empty() && self.nxt.is_empty() && self.cur.is_empty()
    }
}

/// Build the runtime state for every partition of `dg`.
pub fn init_runtimes<P: VertexProgram>(program: &P, dg: &DistGraph) -> Vec<PartitionRuntime<P>> {
    dg.parts.iter().map(|part| PartitionRuntime::new(program, part)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::context::VertexContext;
    use crate::graph::{generators, DistGraph};
    use crate::partition::hash_partition;

    struct Noop;
    impl VertexProgram for Noop {
        type V = u32;
        type M = u32;
        fn init(&self, v: crate::graph::VertexId, _d: u32) -> u32 {
            v * 2
        }
        fn compute(&self, _ctx: &mut VertexContext<'_, Self>) {}
    }

    #[test]
    fn init_assigns_program_values() {
        let g = generators::erdos_renyi(20, 40, 1);
        let a = hash_partition(&g, 3);
        let dg = DistGraph::new(&g, &a, 3);
        let rts = init_runtimes(&Noop, &dg);
        for (p, rt) in rts.iter().enumerate() {
            for (lv, &v) in rt.values.iter().enumerate() {
                assert_eq!(v, dg.parts[p].global_ids[lv] * 2);
            }
            assert!(rt.halted.iter().all(|&h| !h));
        }
    }

    #[test]
    fn frontier_dedup_and_swap() {
        let g = generators::erdos_renyi(5, 8, 2);
        let dg = DistGraph::new(&g, &vec![0; 5], 1);
        let mut rt = PartitionRuntime::new(&Noop, &dg.parts[0]);
        rt.schedule_next(2);
        rt.schedule_next(2);
        rt.schedule_next(4);
        let f = rt.begin_step();
        assert_eq!(f, vec![2, 4]);
        assert!(rt.next_frontier.is_empty());
        // messages pushed to nxt become cur after swap
        rt.nxt.push(1, 9);
        let _ = rt.begin_step();
        assert!(rt.cur.has_messages(1));
    }
}
