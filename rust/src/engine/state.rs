//! Per-partition runtime state shared by all push-based engines.
//!
//! [`PartitionRuntime`] is generic over the value/message types rather
//! than over a program trait so both the vertex-centric engines
//! ([`VertexProgram`]) and the graph-centric one
//! ([`super::giraphpp::PartitionProgram`]) execute over the same state —
//! one runtime per partition is exactly what a worker thread owns in the
//! parallel runtime (`super::worker`).

use crate::graph::{DistGraph, PartGraph};

use super::messages::MsgStore;
use super::program::VertexProgram;
use super::worker::SweepTarget;

/// A deduplicated "compute next (pseudo-)superstep" set: O(1) schedule
/// via a membership bitmap, drained in insertion order.
#[derive(Clone, Debug, Default)]
pub struct Frontier {
    next: Vec<u32>,
    flagged: Vec<bool>,
}

impl Frontier {
    pub fn new(n: usize) -> Self {
        Frontier { next: Vec::new(), flagged: vec![false; n] }
    }

    /// Mark `lv` to compute in the next (pseudo-)superstep.
    pub fn schedule(&mut self, lv: usize) {
        if !self.flagged[lv] {
            self.flagged[lv] = true;
            self.next.push(lv as u32);
        }
    }

    /// Take the scheduled set, leaving the frontier empty.
    pub fn take(&mut self) -> Vec<u32> {
        for &lv in &self.next {
            self.flagged[lv as usize] = false;
        }
        std::mem::take(&mut self.next)
    }

    pub fn is_empty(&self) -> bool {
        self.next.is_empty()
    }

    /// Drop everything scheduled (checkpoint recovery).
    pub fn clear(&mut self) {
        for &lv in &self.next {
            self.flagged[lv as usize] = false;
        }
        self.next.clear();
    }
}

/// Mutable state a worker keeps for one partition.
pub struct PartitionRuntime<V, M> {
    /// Vertex values (by local index).
    pub values: Vec<V>,
    /// voteToHalt flags.
    pub halted: Vec<bool>,
    /// Incoming messages for the current (pseudo-)superstep.
    pub cur: MsgStore<M>,
    /// Incoming messages for the next (pseudo-)superstep.
    pub nxt: MsgStore<M>,
    /// Vertices that must compute next (pseudo-)superstep (not halted,
    /// or received a message).
    pub frontier: Frontier,
}

impl<V, M> PartitionRuntime<V, M> {
    /// Build from per-local-vertex initial values; all vertices start
    /// active (standard BSP).
    pub fn from_values(values: Vec<V>) -> Self {
        let n = values.len();
        PartitionRuntime {
            values,
            halted: vec![false; n],
            cur: MsgStore::new(n),
            nxt: MsgStore::new(n),
            frontier: Frontier::new(n),
        }
    }

    /// Initialize values via `program.init` for every owned vertex.
    pub fn new<P>(program: &P, part: &PartGraph) -> Self
    where
        P: VertexProgram<V = V, M = M>,
    {
        Self::from_values(
            (0..part.num_vertices())
                .map(|lv| program.init(part.global_ids[lv], part.out_degree[lv]))
                .collect(),
        )
    }

    pub fn num_vertices(&self) -> usize {
        self.values.len()
    }

    /// Mark `lv` to compute next (pseudo-)superstep.
    pub fn schedule_next(&mut self, lv: usize) {
        self.frontier.schedule(lv);
    }

    /// Swap message stores and take the next frontier for this step.
    pub fn begin_step(&mut self) -> Vec<u32> {
        std::mem::swap(&mut self.cur, &mut self.nxt);
        self.frontier.take()
    }

    /// A vertex is live if it has not halted or has pending messages.
    pub fn is_live(&self, lv: usize) -> bool {
        !self.halted[lv] || self.cur.has_messages(lv)
    }

    /// True when nothing remains to do in this partition:
    /// all halted and no undelivered messages.
    pub fn quiesced(&mut self) -> bool {
        self.frontier.is_empty() && self.nxt.is_empty() && self.cur.is_empty()
    }

    /// The split borrow a `super::worker::Sweep` runs against.
    pub(crate) fn sweep_target(&mut self) -> SweepTarget<'_, V, M> {
        SweepTarget {
            values: &mut self.values,
            halted: &mut self.halted,
            cur: &mut self.cur,
            nxt: &mut self.nxt,
            frontier: Some(&mut self.frontier),
        }
    }
}

/// Build the runtime state for every partition of `dg`.
pub fn init_runtimes<P: VertexProgram>(
    program: &P,
    dg: &DistGraph,
) -> Vec<PartitionRuntime<P::V, P::M>> {
    dg.parts.iter().map(|part| PartitionRuntime::new(program, part)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::context::VertexContext;
    use crate::graph::{generators, DistGraph};
    use crate::partition::hash_partition;

    struct Noop;
    impl VertexProgram for Noop {
        type V = u32;
        type M = u32;
        fn init(&self, v: crate::graph::VertexId, _d: u32) -> u32 {
            v * 2
        }
        fn compute(&self, _ctx: &mut VertexContext<'_, Self>) {}
    }

    #[test]
    fn init_assigns_program_values() {
        let g = generators::erdos_renyi(20, 40, 1);
        let a = hash_partition(&g, 3);
        let dg = DistGraph::new(&g, &a, 3);
        let rts = init_runtimes(&Noop, &dg);
        for (p, rt) in rts.iter().enumerate() {
            for (lv, &v) in rt.values.iter().enumerate() {
                assert_eq!(v, dg.parts[p].global_ids[lv] * 2);
            }
            assert!(rt.halted.iter().all(|&h| !h));
        }
    }

    #[test]
    fn frontier_dedup_and_swap() {
        let g = generators::erdos_renyi(5, 8, 2);
        let dg = DistGraph::new(&g, &vec![0; 5], 1);
        let mut rt = PartitionRuntime::new(&Noop, &dg.parts[0]);
        rt.schedule_next(2);
        rt.schedule_next(2);
        rt.schedule_next(4);
        let f = rt.begin_step();
        assert_eq!(f, vec![2, 4]);
        assert!(rt.frontier.is_empty());
        // messages pushed to nxt become cur after swap
        rt.nxt.push(1, 9);
        let _ = rt.begin_step();
        assert!(rt.cur.has_messages(1));
    }

    #[test]
    fn frontier_clear_allows_rescheduling() {
        let mut f = Frontier::new(4);
        f.schedule(1);
        f.schedule(3);
        f.clear();
        assert!(f.is_empty());
        f.schedule(1);
        assert_eq!(f.take(), vec![1]);
    }
}
