//! Per-partition runtime state shared by all push-based engines.
//!
//! [`PartitionRuntime`] is generic over the value/message types rather
//! than over a program trait so both the vertex-centric engines
//! ([`VertexProgram`]) and the graph-centric one
//! ([`super::giraphpp::PartitionProgram`]) execute over the same state —
//! one runtime per partition is exactly what a worker thread owns in the
//! parallel runtime (`super::worker`).
//!
//! # The step lifecycle
//!
//! A (pseudo-)superstep is an explicit transaction on the runtime:
//! [`begin_step`](PartitionRuntime::begin_step) swaps the inbox pair and
//! drains the frontier, [`commit_step`](PartitionRuntime::commit_step)
//! closes a step whose sweep ran, and
//! [`abort_step_carryover`](PartitionRuntime::abort_step_carryover)
//! rolls a *not-yet-swept* step back — un-swapping the inboxes and
//! re-scheduling the drained worklist — so an engine that hits a cap
//! (GraphHP's `max_pseudo_supersteps`) can stop mid-phase without
//! losing frontier entries or stranding mail in the wrong inbox. The
//! pre-lifecycle code broke out of the loop *after* the swap/drain and
//! silently dropped both (livelock until `max_iterations`).

use std::collections::VecDeque;

use crate::graph::{DistGraph, PartGraph};

use super::messages::MsgStore;
use super::program::VertexProgram;
use super::worker::{SweepTarget, Worklist};

/// A deduplicated "compute next (pseudo-)superstep" set: O(1) schedule
/// via a membership bitmap, drained in insertion order.
#[derive(Clone, Debug, Default)]
pub struct Frontier {
    // (`pub(crate)` for the debug sanitizers in `engine/invariants.rs`.)
    pub(crate) next: Vec<u32>,
    pub(crate) flagged: Vec<bool>,
}

impl Frontier {
    /// An empty frontier over `n` local vertices.
    pub fn new(n: usize) -> Self {
        Frontier { next: Vec::new(), flagged: vec![false; n] }
    }

    /// Mark `lv` to compute in the next (pseudo-)superstep.
    pub fn schedule(&mut self, lv: usize) {
        if !self.flagged[lv] {
            self.flagged[lv] = true;
            self.next.push(lv as u32);
        }
    }

    /// Take the scheduled set, leaving the frontier empty.
    pub fn take(&mut self) -> Vec<u32> {
        for &lv in &self.next {
            self.flagged[lv as usize] = false;
        }
        std::mem::take(&mut self.next)
    }

    /// Drain the scheduled set into a sweep worklist, keeping this
    /// frontier's buffer (unlike [`take`](Self::take), which surrenders
    /// it and reallocates on the next schedule) — the allocation-free
    /// path the engines' steady-state sweeps use.
    pub(crate) fn drain_into(&mut self, wl: &mut Worklist) {
        for &lv in &self.next {
            self.flagged[lv as usize] = false;
            wl.schedule(lv);
        }
        self.next.clear();
    }

    /// True when nothing is scheduled.
    pub fn is_empty(&self) -> bool {
        self.next.is_empty()
    }

    /// Number of scheduled vertices (telemetry: frontier occupancy).
    pub fn len(&self) -> usize {
        self.next.len()
    }

    /// Drop everything scheduled (checkpoint recovery).
    pub fn clear(&mut self) {
        for &lv in &self.next {
            self.flagged[lv as usize] = false;
        }
        self.next.clear();
    }

    /// Scheduled vertices in insertion order, non-draining
    /// (checkpointing).
    pub fn snapshot(&self) -> Vec<u32> {
        self.next.clone()
    }

    /// Rebuild a frontier of size `n` from a [`snapshot`](Self::snapshot).
    pub fn restore(n: usize, snap: &[u32]) -> Self {
        let mut f = Frontier::new(n);
        for &lv in snap {
            f.schedule(lv as usize);
        }
        f
    }
}

/// A deduplicating FIFO worklist — the GraphLab async scheduler:
/// scheduling an already-queued vertex is a no-op; popping a vertex
/// re-arms it for future scheduling.
#[derive(Clone, Debug, Default)]
pub struct FifoScheduler {
    // (`pub(crate)` for the debug sanitizers in `engine/invariants.rs`.)
    pub(crate) queue: VecDeque<u32>,
    pub(crate) queued: Vec<bool>,
}

impl FifoScheduler {
    /// An empty scheduler over `n` vertices.
    pub fn new(n: usize) -> Self {
        FifoScheduler { queue: VecDeque::new(), queued: vec![false; n] }
    }

    /// All of `0..n`, queued in id order.
    pub fn seeded(n: usize) -> Self {
        FifoScheduler { queue: (0..n as u32).collect(), queued: vec![true; n] }
    }

    /// Queue `v` unless it is already waiting.
    pub fn schedule(&mut self, v: u32) {
        if !self.queued[v as usize] {
            self.queued[v as usize] = true;
            self.queue.push_back(v);
        }
    }

    /// Dequeue the next vertex, re-arming it for future scheduling.
    pub fn pop(&mut self) -> Option<u32> {
        let v = self.queue.pop_front()?;
        self.queued[v as usize] = false;
        Some(v)
    }

    /// True when no vertex is queued.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }
}

/// Mutable state a worker keeps for one partition.
pub struct PartitionRuntime<V, M> {
    /// Vertex values (by local index).
    pub values: Vec<V>,
    /// voteToHalt flags.
    pub halted: Vec<bool>,
    /// Incoming messages for the current (pseudo-)superstep.
    pub cur: MsgStore<M>,
    /// Incoming messages for the next (pseudo-)superstep.
    pub nxt: MsgStore<M>,
    /// Vertices that must compute next (pseudo-)superstep (not halted,
    /// or received a message).
    pub frontier: Frontier,
    /// Step-lifecycle guard: a `begin_step` is open until `commit_step`
    /// or `abort_step_carryover` closes it.
    /// (`pub(crate)` for the debug sanitizers in `engine/invariants.rs`.)
    pub(crate) step_open: bool,
}

impl<V, M> PartitionRuntime<V, M> {
    /// Build from per-local-vertex initial values; all vertices start
    /// active (standard BSP).
    pub fn from_values(values: Vec<V>) -> Self {
        let n = values.len();
        PartitionRuntime {
            values,
            halted: vec![false; n],
            cur: MsgStore::new(n),
            nxt: MsgStore::new(n),
            frontier: Frontier::new(n),
            step_open: false,
        }
    }

    /// Initialize values via `program.init` for every owned vertex.
    pub fn new<P>(program: &P, part: &PartGraph) -> Self
    where
        P: VertexProgram<V = V, M = M>,
    {
        Self::from_values(
            (0..part.num_vertices())
                .map(|lv| program.init(part.global_ids[lv], part.out_degree[lv]))
                .collect(),
        )
    }

    /// Number of local vertices this runtime manages.
    pub fn num_vertices(&self) -> usize {
        self.values.len()
    }

    /// Mark `lv` to compute next (pseudo-)superstep.
    pub fn schedule_next(&mut self, lv: usize) {
        self.frontier.schedule(lv);
    }

    /// Open a step: swap the message stores and take the next frontier.
    /// Every `begin_step` must be paired with a
    /// [`commit_step`](Self::commit_step) (sweep ran) or an
    /// [`abort_step_carryover`](Self::abort_step_carryover) (sweep
    /// skipped).
    pub fn begin_step(&mut self) -> Vec<u32> {
        assert!(!self.step_open, "begin_step on an already-open step");
        self.step_open = true;
        std::mem::swap(&mut self.cur, &mut self.nxt);
        self.frontier.take()
    }

    /// [`begin_step`](Self::begin_step), pooled: swap the message stores
    /// and drain the frontier straight into `wl` (re-armed here for this
    /// partition), so opening a step allocates nothing at steady state.
    /// Pairs with `commit_step`/`abort_step_carryover` exactly like
    /// `begin_step`.
    pub(crate) fn begin_step_into(&mut self, wl: &mut Worklist) {
        assert!(!self.step_open, "begin_step on an already-open step");
        self.step_open = true;
        std::mem::swap(&mut self.cur, &mut self.nxt);
        wl.begin(self.num_vertices());
        self.frontier.drain_into(wl);
    }

    /// Close a step whose sweep executed.
    pub fn commit_step(&mut self) {
        assert!(self.step_open, "commit_step without begin_step");
        self.step_open = false;
    }

    /// Roll back a step that was begun but **not swept** (e.g. a
    /// pseudo-superstep cap): un-swap the message stores — the mail that
    /// was about to be read returns to `nxt`, where the *next* step's
    /// swap will find it — and re-schedule `worklist` (the drained
    /// frontier, possibly widened with mail-pending vertices; extra
    /// entries are harmless) so no scheduled vertex is lost.
    pub fn abort_step_carryover(&mut self, worklist: impl IntoIterator<Item = u32>) {
        assert!(self.step_open, "abort_step_carryover without begin_step");
        std::mem::swap(&mut self.cur, &mut self.nxt);
        for lv in worklist {
            self.frontier.schedule(lv as usize);
        }
        self.step_open = false;
    }

    /// A vertex is live if it has not halted or has pending messages.
    pub fn is_live(&self, lv: usize) -> bool {
        !self.halted[lv] || self.cur.has_messages(lv)
    }

    /// True when nothing remains to do in this partition:
    /// all halted and no undelivered messages.
    pub fn quiesced(&mut self) -> bool {
        self.frontier.is_empty() && self.nxt.is_empty() && self.cur.is_empty()
    }

    /// The split borrow a `super::worker::Sweep` runs against.
    pub(crate) fn sweep_target(&mut self) -> SweepTarget<'_, V, M> {
        SweepTarget {
            values: &mut self.values,
            halted: &mut self.halted,
            cur: &mut self.cur,
            nxt: &mut self.nxt,
            frontier: Some(&mut self.frontier),
        }
    }
}

/// Build the runtime state for every partition of `dg`.
pub fn init_runtimes<P: VertexProgram>(
    program: &P,
    dg: &DistGraph,
) -> Vec<PartitionRuntime<P::V, P::M>> {
    dg.parts.iter().map(|part| PartitionRuntime::new(program, part)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::context::VertexContext;
    use crate::graph::{generators, DistGraph};
    use crate::partition::hash_partition;

    struct Noop;
    impl VertexProgram for Noop {
        type V = u32;
        type M = u32;
        fn init(&self, v: crate::graph::VertexId, _d: u32) -> u32 {
            v * 2
        }
        fn compute(&self, _ctx: &mut VertexContext<'_, Self>) {}
    }

    #[test]
    fn init_assigns_program_values() {
        let g = generators::erdos_renyi(20, 40, 1);
        let a = hash_partition(&g, 3);
        let dg = DistGraph::new(&g, &a, 3);
        let rts = init_runtimes(&Noop, &dg);
        for (p, rt) in rts.iter().enumerate() {
            for (lv, &v) in rt.values.iter().enumerate() {
                assert_eq!(v, dg.parts[p].global_ids[lv] * 2);
            }
            assert!(rt.halted.iter().all(|&h| !h));
        }
    }

    #[test]
    fn frontier_dedup_and_swap() {
        let g = generators::erdos_renyi(5, 8, 2);
        let dg = DistGraph::new(&g, &vec![0; 5], 1);
        let mut rt = PartitionRuntime::new(&Noop, &dg.parts[0]);
        rt.schedule_next(2);
        rt.schedule_next(2);
        rt.schedule_next(4);
        let f = rt.begin_step();
        assert_eq!(f, vec![2, 4]);
        assert!(rt.frontier.is_empty());
        rt.commit_step();
        // messages pushed to nxt become cur after swap
        rt.nxt.push(1, 9);
        let _ = rt.begin_step();
        assert!(rt.cur.has_messages(1));
        rt.commit_step();
    }

    #[test]
    fn begin_step_into_drains_frontier_into_pooled_worklist() {
        let g = generators::erdos_renyi(6, 10, 4);
        let dg = DistGraph::new(&g, &vec![0; 6], 1);
        let mut rt = PartitionRuntime::new(&Noop, &dg.parts[0]);
        let mut wl = Worklist::default();
        rt.schedule_next(4);
        rt.schedule_next(1);
        rt.schedule_next(4);
        rt.nxt.push(1, 9);
        rt.begin_step_into(&mut wl);
        assert!(rt.frontier.is_empty());
        assert!(rt.cur.has_messages(1), "mail swapped in for this step");
        assert_eq!(wl.len(), 2);
        assert_eq!(wl.pop_first(), Some(1), "ascending drain");
        assert_eq!(wl.pop_first(), Some(4));
        rt.commit_step();
        // the pooled worklist re-arms for the next step
        rt.schedule_next(3);
        rt.begin_step_into(&mut wl);
        assert_eq!(wl.pop_first(), Some(3));
        assert_eq!(wl.pop_first(), None);
        rt.commit_step();
    }

    #[test]
    fn frontier_clear_allows_rescheduling() {
        let mut f = Frontier::new(4);
        f.schedule(1);
        f.schedule(3);
        f.clear();
        assert!(f.is_empty());
        f.schedule(1);
        assert_eq!(f.take(), vec![1]);
    }

    #[test]
    fn frontier_snapshot_restore_roundtrip() {
        let mut f = Frontier::new(5);
        f.schedule(3);
        f.schedule(0);
        f.schedule(4);
        let snap = f.snapshot();
        assert_eq!(snap, vec![3, 0, 4]);
        assert!(!f.is_empty(), "snapshot must not drain");
        let mut r = Frontier::restore(5, &snap);
        assert_eq!(r.take(), f.take(), "restored frontier preserves order");
    }

    #[test]
    fn abort_carryover_restores_frontier_and_mail() {
        let g = generators::erdos_renyi(6, 10, 3);
        let dg = DistGraph::new(&g, &vec![0; 6], 1);
        let mut rt = PartitionRuntime::new(&Noop, &dg.parts[0]);
        rt.schedule_next(2);
        rt.nxt.push(5, 99);
        rt.schedule_next(5);

        let taken = rt.begin_step();
        assert_eq!(taken, vec![2, 5]);
        assert!(rt.cur.has_messages(5), "mail swapped in for this step");

        // decide not to sweep (cap hit): everything must carry over
        rt.abort_step_carryover(taken);
        assert!(!rt.quiesced(), "carried-over work keeps the partition live");
        assert!(rt.nxt.has_messages(5), "mail back where the next swap finds it");

        let retaken = rt.begin_step();
        assert_eq!(retaken, vec![2, 5], "no frontier entry lost");
        assert!(rt.cur.has_messages(5), "no message lost");
        let mut buf = Vec::new();
        rt.cur.take_into(5, &mut buf);
        assert_eq!(buf, vec![99]);
        rt.commit_step();
    }

    #[test]
    #[should_panic(expected = "begin_step on an already-open step")]
    fn double_begin_step_panics() {
        let g = generators::erdos_renyi(4, 6, 1);
        let dg = DistGraph::new(&g, &vec![0; 4], 1);
        let mut rt = PartitionRuntime::new(&Noop, &dg.parts[0]);
        let _ = rt.begin_step();
        let _ = rt.begin_step();
    }

    #[test]
    #[should_panic(expected = "commit_step without begin_step")]
    fn commit_without_begin_panics() {
        let g = generators::erdos_renyi(4, 6, 1);
        let dg = DistGraph::new(&g, &vec![0; 4], 1);
        let mut rt = PartitionRuntime::new(&Noop, &dg.parts[0]);
        rt.commit_step();
    }

    #[test]
    fn fifo_scheduler_dedups_and_rearms() {
        let mut s = FifoScheduler::seeded(3);
        assert_eq!(s.pop(), Some(0));
        s.schedule(0); // re-arm after pop: accepted
        s.schedule(2); // still queued: no-op
        assert_eq!(s.pop(), Some(1));
        assert_eq!(s.pop(), Some(2));
        assert_eq!(s.pop(), Some(0));
        assert!(s.is_empty());
        assert_eq!(s.pop(), None);
    }
}
