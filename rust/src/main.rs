//! GraphHP launcher: generate workloads, partition graphs, and run any
//! algorithm on any engine with paper-style metric reporting.
//!
//! ```text
//! graphhp generate --kind road --rows 100 --cols 100 --seed 1 --out g.bin
//! graphhp partition --graph g.bin --parts 12 --method metis --out parts.txt
//! graphhp run --graph g.bin --algo sssp --engine graphhp --parts 12 [--source 0]
//! graphhp run --graph g.bin --algo pagerank --engine graphlab-sync --parts 12
//! graphhp run --graph g.bin --algo wcc --parts 12 --threads 4
//! graphhp run --graph g.bin --algo sssp --parts 12 --adaptive --trace out.json
//! graphhp info --graph g.bin
//! ```
//!
//! `--threads N` pins the worker parallelism (`0` = sequential; default:
//! one OS thread per core). Results are bit-for-bit identical across
//! thread counts — the knob only changes wall-clock. `--steal N` selects
//! the opt-in work-stealing mode instead (`Parallelism::WorkStealing`):
//! run-to-run deterministic, exact for min-fold programs, within
//! floating-point epsilon for sum-based ones (see
//! `docs/architecture.md`).
//!
//! `--adaptive` switches GraphHP to the telemetry-driven adaptive hybrid
//! scheduler (`HybridPolicy::Adaptive`); `--trace FILE` dumps the run's
//! per-superstep/per-partition telemetry (`RunTrace`) as JSON for
//! offline policy tuning.
//!
//! `--repartition [N]` enables telemetry-driven online repartitioning:
//! every N barriers (default 4) the engine folds the superstep trace
//! through the deterministic `MigrationPlanner` and may migrate
//! vertices off the most network-bound partition, bumping the routing
//! epoch. Ignored by `graphlab-async` (no barriers).
//!
//! `--chaos benign|stress` turns on seeded deterministic fault
//! injection on the barrier delivery path (`--chaos-seed N` picks the
//! replay seed, default 42; see `engine/chaos.rs`). Lossy schedules
//! need `--checkpoint N` (checkpoint every N iterations — honored by
//! every barrier engine) to recover — without it the run fails loudly
//! rather than converge on partial state. `--max-recoveries N` bounds
//! the rollback retry budget (default 64); exhausting it fails the run
//! loudly instead of retrying forever. `--chaos-trace FILE` dumps the
//! recorded `ChaosTrace` as JSON for replay. `graphlab-async` has no
//! barriers: chaos and migration are documented out of scope there,
//! and a configured `--checkpoint` is rejected loudly.
//!
//! Execution goes through the `Runner` session; `--engine` accepts every
//! `EngineKind` spelling (`hama|am-hama|graphhp|giraph++|graphlab-sync|
//! graphlab-async` — the GraphLab engines run the GAS algorithm forms).
//! (Hand-rolled argument parsing: the offline vendor set has no clap.)

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use graphhp::algorithms::{
    bipartite_matching::validate_matching, BipartiteMatching, GasPageRank, GasSssp, GasWcc,
    IncrementalPageRank, Sssp, Wcc,
};
use graphhp::engine::{
    ChaosPolicy, ChaosTrace, EngineKind, HybridPolicy, Metrics, Parallelism, Partitioner,
    RecoveryPolicy, RepartitionConfig, RunTrace, Runner,
};
use graphhp::graph::{generators, io, Graph};
use graphhp::partition::{hash_partition, metis_partition, MetisConfig, PartitionStats};

fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        let Some(key) = a.strip_prefix("--") else {
            bail!("unexpected argument: {a}");
        };
        let val = args.get(i + 1).cloned().unwrap_or_default();
        if val.starts_with("--") || val.is_empty() {
            flags.insert(key.to_string(), "true".to_string());
            i += 1;
        } else {
            flags.insert(key.to_string(), val);
            i += 2;
        }
    }
    Ok(flags)
}

fn get<'a>(flags: &'a HashMap<String, String>, key: &str) -> Result<&'a str> {
    flags.get(key).map(|s| s.as_str()).with_context(|| format!("missing --{key}"))
}

fn get_or<'a>(flags: &'a HashMap<String, String>, key: &str, default: &'a str) -> &'a str {
    flags.get(key).map(|s| s.as_str()).unwrap_or(default)
}

fn load_graph(path: &str) -> Result<Graph> {
    let p = Path::new(path);
    if path.ends_with(".bin") {
        io::read_binary(p)
    } else {
        io::read_edge_list(p)
    }
}

fn cmd_generate(flags: &HashMap<String, String>) -> Result<()> {
    let kind = get(flags, "kind")?;
    let seed: u64 = get_or(flags, "seed", "1").parse()?;
    let g = match kind {
        "road" => {
            let rows: usize = get_or(flags, "rows", "100").parse()?;
            let cols: usize = get_or(flags, "cols", "100").parse()?;
            generators::road(rows, cols, seed)
        }
        "powerlaw" | "web" => {
            let n: usize = get_or(flags, "n", "10000").parse()?;
            let deg: usize = get_or(flags, "deg", "5").parse()?;
            generators::powerlaw(n, deg, seed)
        }
        "bipartite" => {
            let nl: usize = get_or(flags, "left", "5000").parse()?;
            let nr: usize = get_or(flags, "right", "5000").parse()?;
            let deg: usize = get_or(flags, "deg", "3").parse()?;
            generators::bipartite(nl, nr, deg, seed)
        }
        "delaunay" => {
            let rows: usize = get_or(flags, "rows", "100").parse()?;
            let cols: usize = get_or(flags, "cols", "100").parse()?;
            generators::delaunay_like(rows, cols, seed)
        }
        "erdos" => {
            let n: usize = get_or(flags, "n", "10000").parse()?;
            let m: usize = get_or(flags, "m", "50000").parse()?;
            generators::erdos_renyi(n, m, seed)
        }
        other => bail!("unknown kind {other} (road|powerlaw|bipartite|delaunay|erdos)"),
    };
    let out = PathBuf::from(get(flags, "out")?);
    if out.extension().is_some_and(|e| e == "bin") {
        io::write_binary(&g, &out)?;
    } else {
        io::write_edge_list(&g, &out)?;
    }
    println!(
        "wrote {} vertices, {} edges to {}",
        g.num_vertices(),
        g.num_edges(),
        out.display()
    );
    Ok(())
}

fn make_partition(g: &Graph, flags: &HashMap<String, String>) -> Result<(Vec<u32>, usize)> {
    let k: usize = get_or(flags, "parts", "4").parse()?;
    let method = get_or(flags, "method", "metis");
    let assignment = match method {
        "hash" => hash_partition(g, k),
        "metis" => metis_partition(g, k, &MetisConfig::default()),
        other => bail!("unknown method {other} (hash|metis)"),
    };
    Ok((assignment, k))
}

fn cmd_partition(flags: &HashMap<String, String>) -> Result<()> {
    let g = load_graph(get(flags, "graph")?)?;
    let (assignment, k) = make_partition(&g, flags)?;
    let stats = PartitionStats::compute(&g, &assignment, k);
    println!("{stats}");
    if let Some(out) = flags.get("out") {
        let mut s = String::new();
        for a in &assignment {
            s.push_str(&a.to_string());
            s.push('\n');
        }
        std::fs::write(out, s)?;
        println!("wrote assignment to {out}");
    }
    Ok(())
}

fn report(engine: &str, m: &Metrics) {
    println!(
        "{engine:<14} I={:<8} M={:<12} localM={:<12} T={:.3}s  [compute {:.1}% | comm {:.1}% | sync {:.1}%]",
        m.global_iterations,
        m.network_messages,
        m.local_messages,
        m.elapsed.as_secs_f64(),
        100.0 * (1.0 - m.overhead_fraction()),
        100.0 * m.comm_fraction(),
        100.0 * m.sync_fraction(),
    );
}

/// Write the run's telemetry to the `--trace` file, if requested.
fn dump_trace(flags: &HashMap<String, String>, trace: &RunTrace) -> Result<()> {
    if let Some(path) = flags.get("trace") {
        std::fs::write(path, trace.to_json()).with_context(|| format!("write {path}"))?;
        println!("wrote trace to {path}");
    }
    Ok(())
}

/// Report injected chaos and write the recorded `ChaosTrace` to the
/// `--chaos-trace` file, if requested.
fn dump_chaos(flags: &HashMap<String, String>, chaos: &Option<ChaosTrace>) -> Result<()> {
    let Some(trace) = chaos else {
        return Ok(());
    };
    println!(
        "chaos: {} events injected ({} loss) under seed {}",
        trace.events.len(),
        trace.loss_events(),
        trace.seed
    );
    if let Some(path) = flags.get("chaos-trace") {
        std::fs::write(path, trace.to_json()).with_context(|| format!("write {path}"))?;
        println!("wrote chaos trace to {path}");
    }
    Ok(())
}

fn cmd_run(flags: &HashMap<String, String>) -> Result<()> {
    let g = load_graph(get(flags, "graph")?)?;
    let (assignment, k) = make_partition(&g, flags)?;
    let algo = get(flags, "algo")?;
    let engine = get_or(flags, "engine", "graphhp");
    let kind: EngineKind = engine.parse().map_err(|e: String| anyhow::anyhow!(e))?;
    let mut runner = Runner::new(&g)
        .partitions(k)
        .partitioner(Partitioner::Explicit(assignment))
        .engine(kind);
    if let Some(t) = flags.get("threads") {
        let n: usize = t.parse().with_context(|| format!("bad --threads {t}"))?;
        runner = runner.parallelism(if n == 0 {
            Parallelism::Sequential
        } else {
            Parallelism::Threads(n)
        });
    }
    if let Some(t) = flags.get("steal") {
        let n: usize = t.parse().with_context(|| format!("bad --steal {t}"))?;
        anyhow::ensure!(n > 0, "--steal needs a thread count > 0");
        runner = runner.parallelism(Parallelism::WorkStealing(n));
    }
    if flags.contains_key("adaptive") {
        runner = runner.hybrid_policy(HybridPolicy::adaptive());
    }
    if let Some(v) = flags.get("repartition") {
        let mut rc = RepartitionConfig::default();
        if v != "true" {
            rc.interval = v.parse().with_context(|| format!("bad --repartition {v}"))?;
            anyhow::ensure!(rc.interval > 0, "--repartition needs an interval > 0");
        }
        runner = runner.repartition(rc);
    }
    if let Some(v) = flags.get("checkpoint") {
        let n: u64 = v.parse().with_context(|| format!("bad --checkpoint {v}"))?;
        anyhow::ensure!(n > 0, "--checkpoint needs an interval > 0");
        runner = runner.checkpoint_interval(Some(n));
    }
    if let Some(v) = flags.get("max-recoveries") {
        let n: u64 = v.parse().with_context(|| format!("bad --max-recoveries {v}"))?;
        runner = runner.recovery(RecoveryPolicy { max_recoveries: n, ..Default::default() });
    }
    if let Some(v) = flags.get("chaos") {
        let seed: u64 = get_or(flags, "chaos-seed", "42")
            .parse()
            .with_context(|| "bad --chaos-seed")?;
        let policy = match v.as_str() {
            "benign" => ChaosPolicy::benign(seed),
            "stress" => ChaosPolicy::stress(seed),
            other => bail!("unknown chaos preset {other} (benign|stress)"),
        };
        runner = runner.chaos(policy);
    }

    match algo {
        "sssp" => {
            let source: u32 = get_or(flags, "source", "0").parse()?;
            let r = if kind.is_gas() {
                runner.run_gas(&GasSssp { source })
            } else {
                runner.run(&Sssp { source })
            };
            let reached =
                r.values.iter().filter(|&&d| d < graphhp::algorithms::sssp::INF).count();
            println!("sssp: {reached}/{} vertices reached", r.values.len());
            report(engine, &r.metrics);
            dump_trace(flags, &r.trace)?;
            dump_chaos(flags, &r.chaos)?;
        }
        "pagerank" => {
            let tol: f64 = get_or(flags, "tolerance", "1e-4").parse()?;
            let r = if kind.is_gas() {
                runner.run_gas(&GasPageRank { tolerance: tol })
            } else {
                runner.run(&IncrementalPageRank { tolerance: tol })
            };
            let mut top: Vec<(usize, f64)> =
                r.values.iter().copied().enumerate().collect();
            top.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            println!("pagerank top-5: {:?}", &top[..5.min(top.len())]);
            report(engine, &r.metrics);
            dump_trace(flags, &r.trace)?;
            dump_chaos(flags, &r.chaos)?;
        }
        "wcc" => {
            let r = if kind.is_gas() { runner.run_gas(&GasWcc) } else { runner.run(&Wcc) };
            let mut labels = r.values.clone();
            labels.sort_unstable();
            labels.dedup();
            println!("wcc: {} components", labels.len());
            report(engine, &r.metrics);
            dump_trace(flags, &r.trace)?;
            dump_chaos(flags, &r.chaos)?;
        }
        "bm" => {
            if kind.is_gas() {
                bail!("bipartite matching has no GAS form; pick a vertex-centric engine");
            }
            let nl: u32 = get(flags, "left")?.parse()?;
            let r = runner.run(&BipartiteMatching { num_left: nl });
            let size = validate_matching(&g, nl, &r.values)
                .map_err(|e| anyhow::anyhow!(e))?;
            println!("bm: maximal matching of size {size}");
            report(engine, &r.metrics);
            dump_trace(flags, &r.trace)?;
            dump_chaos(flags, &r.chaos)?;
        }
        other => bail!("unknown algo {other} (sssp|pagerank|wcc|bm)"),
    }
    Ok(())
}

fn cmd_info(flags: &HashMap<String, String>) -> Result<()> {
    let g = load_graph(get(flags, "graph")?)?;
    let ind = g.in_degrees();
    println!("vertices: {}", g.num_vertices());
    println!("edges:    {}", g.num_edges());
    println!(
        "max out-degree: {}",
        (0..g.num_vertices() as u32).map(|v| g.out_degree(v)).max().unwrap_or(0)
    );
    println!("max in-degree:  {}", ind.iter().max().copied().unwrap_or(0));
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprintln!("usage: graphhp <generate|partition|run|info> [--flags]");
        std::process::exit(2);
    };
    let flags = parse_flags(&args[1..])?;
    match cmd.as_str() {
        "generate" => cmd_generate(&flags),
        "partition" => cmd_partition(&flags),
        "run" => cmd_run(&flags),
        "info" => cmd_info(&flags),
        other => bail!("unknown command {other}"),
    }
}
