//! Single-source shortest paths (paper §6.1, Alg. 4).
//!
//! Superstep 0: the source takes value 0 and propagates `0 + w(u,v)`;
//! everyone else takes ∞. Later: a vertex adopts the minimum incoming
//! distance if it improves its value and relays `value + w` to its
//! neighbors. A min-combiner collapses messages per destination. Always
//! votes to halt — message arrivals reactivate.
//!
//! This is an *incremental* computation (paper §4.2): any subset of the
//! incoming messages can be applied safely, so boundary vertices can
//! participate in GraphHP local phases.

use crate::engine::graphlab::GasProgram;
use crate::engine::{SourceCombine, VertexContext, VertexProgram};
use crate::graph::VertexId;

/// Distance "infinity" — finite so additions stay representable,
/// matching the convention of the Pallas min-plus kernel
/// (`python/compile/kernels/minplus.py`).
pub const INF: f32 = 1e30;

/// SSSP vertex program.
pub struct Sssp {
    /// The source vertex (distance 0).
    pub source: VertexId,
}

impl VertexProgram for Sssp {
    type V = f32;
    type M = f32;

    fn init(&self, v: VertexId, _out_degree: u32) -> f32 {
        if v == self.source {
            0.0
        } else {
            INF
        }
    }

    fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
        if ctx.superstep() == 0 {
            if ctx.vertex_id() == self.source {
                ctx.send_along_edges(|e| Some(e.weight));
            }
        } else {
            let new = ctx.messages().iter().copied().fold(INF, f32::min);
            if new < *ctx.value() {
                ctx.set_value(new);
                ctx.send_along_edges(|e| Some(new + e.weight));
            }
        }
        ctx.vote_to_halt();
    }

    fn combiner(&self) -> Option<fn(f32, f32) -> f32> {
        Some(|a, b| a.min(b))
    }

    fn source_combine(&self) -> SourceCombine {
        SourceCombine::KeepLatest
    }
}

/// SSSP in GraphLab's pull (GAS) form for the GraphLab engines: each
/// vertex relaxes to the minimum of `dist(u) + w(u,v)` over its
/// in-neighbors — Bellman-Ford as a gather. Same fixed point as
/// [`Sssp`].
pub struct GasSssp {
    /// The source vertex (distance 0).
    pub source: VertexId,
}

impl GasProgram for GasSssp {
    type V = f32;
    type G = f32;

    fn init(&self, v: VertexId, _out_degree: u32) -> f32 {
        if v == self.source {
            0.0
        } else {
            INF
        }
    }

    fn gather(&self, src: &f32, _src_out_degree: u32, w: f32) -> f32 {
        src + w
    }

    fn merge(&self, a: f32, b: f32) -> f32 {
        a.min(b)
    }

    fn apply(&self, value: &mut f32, acc: Option<f32>) -> bool {
        let candidate = acc.unwrap_or(INF);
        if candidate < *value {
            *value = candidate;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::oracle;
    use crate::engine::{am_hama, graphhp, hama, EngineConfig};
    use crate::graph::{generators, DistGraph};
    use crate::partition::{hash_partition, metis_partition, MetisConfig};

    fn check_against_dijkstra(values: &[f32], g: &crate::graph::Graph, source: VertexId) {
        let want = oracle::dijkstra(g, source);
        assert_eq!(values.len(), want.len());
        for (i, (&got, &w)) in values.iter().zip(&want).enumerate() {
            if w.is_infinite() {
                assert!(got >= INF * 0.5, "v{i}: got {got}, want inf");
            } else {
                assert!((got - w as f32).abs() < 1e-3, "v{i}: got {got}, want {w}");
            }
        }
    }

    #[test]
    fn hama_matches_dijkstra() {
        let g = generators::connected(150, 80, 5);
        let dg = DistGraph::new(&g, &hash_partition(&g, 3), 3);
        let r = hama::run_hama(&Sssp { source: 0 }, &dg, &EngineConfig::default());
        check_against_dijkstra(&r.values, &g, 0);
    }

    #[test]
    fn graphhp_matches_dijkstra() {
        let g = generators::road(20, 25, 3);
        let a = metis_partition(&g, 4, &MetisConfig::default());
        let dg = DistGraph::new(&g, &a, 4);
        let r = graphhp::run_graphhp(&Sssp { source: 7 }, &dg, &EngineConfig::default());
        check_against_dijkstra(&r.values, &g, 7);
    }

    #[test]
    fn am_hama_matches_dijkstra() {
        let g = generators::road(15, 15, 9);
        let dg = DistGraph::new(&g, &hash_partition(&g, 3), 3);
        let r = am_hama::run_am_hama(&Sssp { source: 3 }, &dg, &EngineConfig::default());
        check_against_dijkstra(&r.values, &g, 3);
    }

    #[test]
    fn graphhp_needs_far_fewer_iterations_on_road() {
        let g = generators::road(30, 30, 1);
        let a = metis_partition(&g, 6, &MetisConfig::default());
        let dg = DistGraph::new(&g, &a, 6);
        let cfg = EngineConfig::default();
        let h = hama::run_hama(&Sssp { source: 0 }, &dg, &cfg);
        let hp = graphhp::run_graphhp(&Sssp { source: 0 }, &dg, &cfg);
        assert!(
            hp.metrics.global_iterations * 4 <= h.metrics.global_iterations,
            "graphhp {} vs hama {}",
            hp.metrics.global_iterations,
            h.metrics.global_iterations
        );
    }

    #[test]
    fn unreachable_vertices_stay_infinite() {
        // two disconnected edges
        let mut b = crate::graph::GraphBuilder::new(4);
        b.add_edge(0, 1, 1.0);
        b.add_edge(2, 3, 1.0);
        let g = b.build();
        let dg = DistGraph::new(&g, &hash_partition(&g, 2), 2);
        let r = hama::run_hama(&Sssp { source: 0 }, &dg, &EngineConfig::default());
        assert_eq!(r.values[0], 0.0);
        assert_eq!(r.values[1], 1.0);
        assert!(r.values[2] >= INF);
        assert!(r.values[3] >= INF);
    }
}
