//! Weakly connected components by min-label propagation. A confluent,
//! combiner-friendly workload used heavily by the equivalence test suite
//! (every engine must produce identical labels).

use crate::engine::graphlab::GasProgram;
use crate::engine::{SourceCombine, VertexContext, VertexProgram};
use crate::graph::VertexId;

/// WCC: every vertex converges to the minimum vertex id in its weakly
/// connected component. Assumes edges are symmetric (use
/// [`crate::graph::GraphBuilder::add_undirected`]-style graphs or
/// symmetrize first); on directed graphs it computes the "reach-down"
/// labeling instead.
pub struct Wcc;

impl VertexProgram for Wcc {
    type V = u32;
    type M = u32;

    fn init(&self, v: VertexId, _out_degree: u32) -> u32 {
        v
    }

    fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
        let mut label = *ctx.value();
        if ctx.superstep() == 0 {
            ctx.send_along_edges(|_| Some(label));
        } else if let Some(&m) = ctx.messages().iter().min() {
            if m < label {
                label = m;
                ctx.set_value(label);
                ctx.send_along_edges(|_| Some(label));
            }
        }
        ctx.vote_to_halt();
    }

    fn combiner(&self) -> Option<fn(u32, u32) -> u32> {
        Some(|a, b| a.min(b))
    }

    fn source_combine(&self) -> SourceCombine {
        SourceCombine::KeepLatest
    }
}

/// WCC in GraphLab's pull (GAS) form for the GraphLab engines: each
/// vertex adopts the minimum label among its in-neighbors. On symmetric
/// graphs this reaches the same fixed point as [`Wcc`]; on directed
/// graphs it computes the same "reach-down" labeling (labels flow along
/// edge direction in both formulations).
pub struct GasWcc;

impl GasProgram for GasWcc {
    type V = u32;
    type G = u32;

    fn init(&self, v: VertexId, _out_degree: u32) -> u32 {
        v
    }

    fn gather(&self, src: &u32, _src_out_degree: u32, _w: f32) -> u32 {
        *src
    }

    fn merge(&self, a: u32, b: u32) -> u32 {
        a.min(b)
    }

    fn apply(&self, value: &mut u32, acc: Option<u32>) -> bool {
        match acc {
            Some(m) if m < *value => {
                *value = m;
                true
            }
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::oracle;
    use crate::engine::{graphhp, hama, EngineConfig};
    use crate::graph::{generators, DistGraph, GraphBuilder};
    use crate::partition::hash_partition;

    #[test]
    fn labels_match_union_find() {
        // two separate undirected components
        let mut b = GraphBuilder::new(7);
        b.add_undirected(0, 1, 1.0);
        b.add_undirected(1, 2, 1.0);
        b.add_undirected(3, 4, 1.0);
        b.add_undirected(4, 5, 1.0);
        // 6 isolated
        let g = b.build();
        let dg = DistGraph::new(&g, &hash_partition(&g, 2), 2);
        let r = hama::run_hama(&Wcc, &dg, &EngineConfig::default());
        assert_eq!(r.values, vec![0, 0, 0, 3, 3, 3, 6]);
        let want = oracle::wcc_labels(&g);
        assert_eq!(r.values, want);
    }

    #[test]
    fn engines_agree_on_random_graph() {
        let g = generators::connected(250, 100, 31);
        let dg = DistGraph::new(&g, &hash_partition(&g, 5), 5);
        let cfg = EngineConfig::default();
        let h = hama::run_hama(&Wcc, &dg, &cfg);
        let hp = graphhp::run_graphhp(&Wcc, &dg, &cfg);
        assert_eq!(h.values, hp.values);
        assert!(h.values.iter().all(|&l| l == 0)); // connected graph
    }
}
