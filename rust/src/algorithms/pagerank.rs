//! PageRank (paper §6.2).
//!
//! [`IncrementalPageRank`] is the accumulative-update algorithm of
//! Alg. 5 ([36]): each vertex holds its accumulated rank; on receiving
//! delta messages it adds their (damped) sum to its value and relays the
//! increment to its out-neighbors, halting once the increment falls below
//! the tolerance Δ. A sum-combiner collapses deltas per destination. This
//! is the workload of Figures 4/5 and Table 4.
//!
//! [`ClassicPageRank`] is the straightforward Alg. 1 version: every
//! vertex stays active for a fixed number of supersteps, recomputing its
//! value from the full set of neighbor contributions — the workload of
//! the Figure 1 overhead study.
//!
//! [`GasPageRank`] is the same fixed point in GraphLab's pull form, and
//! [`GiraphPPPageRank`] the graph-centric form of §7.5.

use crate::engine::giraphpp::{PartitionContext, PartitionProgram};
use crate::engine::graphlab::GasProgram;
use crate::engine::{SourceCombine, VertexContext, VertexProgram};
use crate::graph::VertexId;

/// Damping factor used throughout (the paper's 0.85/0.15 split).
pub const DAMPING: f64 = 0.85;
/// Base rank injected at every vertex.
pub const BASE: f64 = 0.15;

/// Accumulative / incremental PageRank (Alg. 5).
pub struct IncrementalPageRank {
    /// Convergence tolerance Δ: a vertex stops propagating (and halts)
    /// when its pending update is below this.
    pub tolerance: f64,
}

impl VertexProgram for IncrementalPageRank {
    type V = f64;
    type M = f64;

    fn init(&self, _v: VertexId, _out_degree: u32) -> f64 {
        0.0
    }

    fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
        let update = if ctx.superstep() == 0 {
            BASE
        } else {
            ctx.messages().iter().sum::<f64>()
        };
        if update > 0.0 {
            ctx.set_value(ctx.value() + update);
            let deg = ctx.out_degree();
            if update > self.tolerance && deg > 0 {
                let share = DAMPING * update / deg as f64;
                ctx.send_along_edges(|_| Some(share));
            }
        }
        ctx.vote_to_halt();
    }

    fn combiner(&self) -> Option<fn(f64, f64) -> f64> {
        Some(|a, b| a + b)
    }

    fn source_combine(&self) -> SourceCombine {
        // deltas are additive: every message matters; the sum-combiner
        // above is what actually collapses them
        SourceCombine::KeepAll
    }
}

/// Straightforward PageRank (Alg. 1): fixed-superstep synchronous
/// iteration; every vertex stays active until `supersteps`.
pub struct ClassicPageRank {
    /// Fixed number of supersteps to run before halting.
    pub supersteps: u64,
}

impl VertexProgram for ClassicPageRank {
    type V = f64;
    type M = f64;

    fn init(&self, _v: VertexId, _out_degree: u32) -> f64 {
        1.0
    }

    fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
        if ctx.superstep() > 0 {
            let sum: f64 = ctx.messages().iter().sum();
            ctx.set_value(BASE + DAMPING * sum);
        }
        if ctx.superstep() < self.supersteps {
            let deg = ctx.out_degree();
            if deg > 0 {
                let share = *ctx.value() / deg as f64;
                ctx.send_along_edges(|_| Some(share));
            }
        } else {
            ctx.vote_to_halt();
        }
    }

    fn combiner(&self) -> Option<fn(f64, f64) -> f64> {
        Some(|a, b| a + b)
    }
}

/// GraphLab (GAS / pull) PageRank for the §7.5 comparison. Converges to
/// the same fixed point as [`IncrementalPageRank`]: `r = 0.15 + 0.85 ·
/// Σ_in r_u / deg_u`.
pub struct GasPageRank {
    /// Convergence tolerance: reschedule out-neighbors while the value
    /// change exceeds this.
    pub tolerance: f64,
}

impl GasProgram for GasPageRank {
    type V = f64;
    type G = f64;

    fn init(&self, _v: VertexId, _out_degree: u32) -> f64 {
        BASE
    }

    fn gather(&self, src: &f64, src_out_degree: u32, _w: f32) -> f64 {
        if src_out_degree == 0 {
            0.0
        } else {
            src / src_out_degree as f64
        }
    }

    fn merge(&self, a: f64, b: f64) -> f64 {
        a + b
    }

    fn apply(&self, value: &mut f64, acc: Option<f64>) -> bool {
        let new = BASE + DAMPING * acc.unwrap_or(0.0);
        let change = (new - *value).abs();
        *value = new;
        change > self.tolerance
    }
}

/// Graph-centric (Giraph++-style) incremental PageRank, after the
/// improvised `bsp()` implementation the paper benchmarks in §7.5: per
/// superstep, sequentially update each pending vertex once and
/// immediately push its damped delta to in-partition neighbors;
/// cross-partition deltas travel at the barrier.
pub struct GiraphPPPageRank {
    /// Convergence tolerance Δ: deltas below it stop propagating.
    pub tolerance: f64,
}

impl PartitionProgram for GiraphPPPageRank {
    type V = f64;
    type M = f64;

    fn init(&self, _vertex: VertexId, _out_degree: u32) -> f64 {
        0.0
    }

    fn compute_partition(&self, ctx: &mut PartitionContext<'_, Self>) {
        // the partition topology outlives the context borrow, so edge
        // iteration can interleave with `ctx.send` without copying the
        // edge list out per vertex
        let part = ctx.part;
        let n = part.num_vertices();
        // pending[lv]: accumulated undelivered delta for this superstep
        let mut pending = vec![0.0f64; n];
        if ctx.superstep == 0 {
            for d in pending.iter_mut() {
                *d = BASE;
            }
        } else {
            let mut buf = Vec::new();
            for lv in ctx.pending_vertices() {
                ctx.take_messages(lv as usize, &mut buf);
                pending[lv as usize] += buf.iter().sum::<f64>();
            }
        }
        let mut computations = 0u64;
        // one sequential sweep; in-partition deltas are applied
        // immediately to the receiver's pending slot (visible this sweep
        // if the receiver comes later in the order)
        for lv in 0..n {
            let delta = std::mem::take(&mut pending[lv]);
            if delta == 0.0 {
                ctx.halted[lv] = true;
                continue;
            }
            computations += 1;
            ctx.values[lv] += delta;
            let deg = part.out_degree[lv];
            if delta > self.tolerance && deg > 0 {
                let share = DAMPING * delta / deg as f64;
                for e in part.out_edges(lv) {
                    if e.target_part == part.part {
                        let tl = e.target_local as usize;
                        if tl > lv {
                            pending[tl] += share; // same-sweep visibility
                        } else {
                            ctx.send(e.target, share); // next superstep
                        }
                    } else {
                        ctx.send(e.target, share);
                    }
                }
            }
            ctx.halted[lv] = true;
        }
        ctx.count_computations(computations);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::oracle;
    use crate::engine::{giraphpp, graphhp, graphlab, hama, EngineConfig};
    use crate::graph::{generators, DistGraph};
    use crate::partition::hash_partition;

    fn l1_distance(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
    }

    #[test]
    fn incremental_converges_to_power_iteration() {
        let g = generators::powerlaw(300, 4, 7);
        let dg = DistGraph::new(&g, &hash_partition(&g, 3), 3);
        let r = hama::run_hama(
            &IncrementalPageRank { tolerance: 1e-9 },
            &dg,
            &EngineConfig::default(),
        );
        let want = oracle::pagerank(&g, 1e-12);
        let err = l1_distance(&r.values, &want) / want.len() as f64;
        assert!(err < 1e-6, "avg err {err}");
    }

    #[test]
    fn graphhp_matches_hama_values() {
        let g = generators::powerlaw(400, 4, 9);
        let dg = DistGraph::new(&g, &hash_partition(&g, 4), 4);
        let cfg = EngineConfig::default();
        let tol = 1e-8;
        let h = hama::run_hama(&IncrementalPageRank { tolerance: tol }, &dg, &cfg);
        let hp = graphhp::run_graphhp(&IncrementalPageRank { tolerance: tol }, &dg, &cfg);
        let err = l1_distance(&h.values, &hp.values) / h.values.len() as f64;
        // both within tolerance-bounded truncation of the same series
        assert!(err < 1e-5, "avg err {err}");
        assert!(hp.metrics.global_iterations < h.metrics.global_iterations);
    }

    #[test]
    fn classic_pagerank_fixed_supersteps() {
        let g = generators::powerlaw(200, 4, 3);
        let dg = DistGraph::new(&g, &hash_partition(&g, 2), 2);
        let r = hama::run_hama(&ClassicPageRank { supersteps: 30 }, &dg, &EngineConfig::default());
        assert_eq!(r.metrics.global_iterations, 31);
        let want = oracle::pagerank(&g, 1e-12);
        let err = l1_distance(&r.values, &want) / want.len() as f64;
        assert!(err < 1e-2, "avg err {err}");
    }

    #[test]
    fn gas_pagerank_same_fixed_point() {
        let g = generators::powerlaw(300, 4, 5);
        let a = hash_partition(&g, 3);
        let dg = DistGraph::new(&g, &a, 3);
        let r = graphlab::run_graphlab_sync(
            &GasPageRank { tolerance: 1e-9 },
            &dg,
            &EngineConfig::default(),
        );
        let want = oracle::pagerank(&g, 1e-12);
        let err = l1_distance(&r.values, &want) / want.len() as f64;
        assert!(err < 1e-5, "avg err {err}");
    }

    #[test]
    fn giraphpp_pagerank_same_fixed_point() {
        let g = generators::powerlaw(300, 4, 11);
        let a = hash_partition(&g, 3);
        let dg = DistGraph::new(&g, &a, 3);
        let r = giraphpp::run_giraphpp(
            &GiraphPPPageRank { tolerance: 1e-9 },
            &dg,
            &EngineConfig::default(),
        );
        let want = oracle::pagerank(&g, 1e-12);
        let err = l1_distance(&r.values, &want) / want.len() as f64;
        assert!(err < 1e-5, "avg err {err}");
    }
}
