//! Greedy graph coloring — one of the slow-convergence standard
//! algorithms called out by [28] as pathological for plain BSP. Used as
//! an extra stress workload for the engines.
//!
//! Jones–Plassmann, event-driven formulation: vertex priority = vertex
//! id. A vertex may color itself once every *higher*-priority neighbor
//! has announced its color; it then picks the smallest color unused among
//! those and announces to all neighbors. Vertices with no higher
//! neighbor color at superstep 0. No polling/re-announcement, so the
//! cascade composes with GraphHP's local phase (in-partition chains
//! resolve within one local phase; cross-partition dependencies advance
//! one global iteration at a time). Assumes symmetric edges.

use crate::engine::{VertexContext, VertexProgram};
use crate::graph::VertexId;
use crate::util::Codec;

/// Sentinel for "no color chosen yet".
pub const UNCOLORED: u32 = u32::MAX;

/// Vertex state: chosen color + colors seen from higher-priority
/// neighbors (by neighbor id, deduped).
#[derive(Clone, Debug, PartialEq)]
pub struct ColorState {
    /// Chosen color, or [`UNCOLORED`].
    pub color: u32,
    /// (neighbor id, color) announcements from higher-priority
    /// neighbors, deduplicated by neighbor.
    pub seen: Vec<(u32, u32)>,
}

impl Codec for ColorState {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.color.encode(buf);
        self.seen.encode(buf);
    }
    fn decode(r: &mut &[u8]) -> Option<Self> {
        Some(ColorState { color: u32::decode(r)?, seen: Vec::decode(r)? })
    }
}

/// Message: (sender id, sender's chosen color).
type ColorMsg = (u32, u32);

/// Greedy coloring vertex program.
pub struct Coloring;

impl Coloring {
    fn try_color(&self, ctx: &mut VertexContext<'_, Self>) {
        let me = ctx.vertex_id();
        // count higher-priority neighbors (dedup multi-edges)
        let mut higher: Vec<VertexId> =
            ctx.edges().iter().map(|e| e.target).filter(|&t| t > me).collect();
        higher.sort_unstable();
        higher.dedup();
        if ctx.value().seen.len() < higher.len() {
            return; // still waiting on some higher neighbor
        }
        let mut used: Vec<u32> = ctx.value().seen.iter().map(|&(_, c)| c).collect();
        used.sort_unstable();
        used.dedup();
        let mut c = 0u32;
        for &u in &used {
            if u == c {
                c += 1;
            } else if u > c {
                break;
            }
        }
        ctx.value_mut().color = c;
        ctx.send_along_edges(move |_| Some((me, c)));
    }
}

impl VertexProgram for Coloring {
    type V = ColorState;
    type M = ColorMsg;

    fn init(&self, _v: VertexId, _out_degree: u32) -> ColorState {
        ColorState { color: UNCOLORED, seen: Vec::new() }
    }

    fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
        let me = ctx.vertex_id();
        if ctx.value().color == UNCOLORED {
            // record announcements from higher-priority neighbors
            let incoming: Vec<ColorMsg> = ctx
                .messages()
                .iter()
                .copied()
                .filter(|&(nid, _)| nid > me)
                .collect();
            for (nid, c) in incoming {
                if !ctx.value().seen.iter().any(|&(n, _)| n == nid) {
                    ctx.value_mut().seen.push((nid, c));
                }
            }
            self.try_color(ctx);
        }
        ctx.vote_to_halt();
    }
}

/// Check a coloring is proper (no edge with equal endpoint colors, no
/// vertex uncolored).
pub fn is_proper_coloring(g: &crate::graph::Graph, colors: &[ColorState]) -> bool {
    for v in 0..g.num_vertices() as VertexId {
        if colors[v as usize].color == UNCOLORED {
            return false;
        }
        for &t in g.out_edges(v).0 {
            if t != v && colors[v as usize].color == colors[t as usize].color {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{am_hama, graphhp, hama, EngineConfig};
    use crate::graph::{generators, DistGraph};
    use crate::partition::hash_partition;

    #[test]
    fn hama_produces_proper_coloring() {
        let g = generators::delaunay_like(12, 12, 5);
        let dg = DistGraph::new(&g, &hash_partition(&g, 3), 3);
        let r = hama::run_hama(&Coloring, &dg, &EngineConfig::default());
        assert!(is_proper_coloring(&g, &r.values));
    }

    #[test]
    fn graphhp_produces_proper_coloring_in_fewer_iterations() {
        let g = generators::delaunay_like(12, 12, 5);
        let dg = DistGraph::new(&g, &hash_partition(&g, 3), 3);
        let cfg = EngineConfig::default();
        let h = hama::run_hama(&Coloring, &dg, &cfg);
        let hp = graphhp::run_graphhp(&Coloring, &dg, &cfg);
        assert!(is_proper_coloring(&g, &hp.values));
        assert!(hp.metrics.global_iterations <= h.metrics.global_iterations);
        let maxc = hp.values.iter().map(|c| c.color).max().unwrap();
        assert!(maxc < 12, "used {maxc} colors");
    }

    #[test]
    fn am_hama_produces_proper_coloring() {
        let g = generators::connected(120, 60, 8);
        let dg = DistGraph::new(&g, &hash_partition(&g, 4), 4);
        let r = am_hama::run_am_hama(&Coloring, &dg, &EngineConfig::default());
        assert!(is_proper_coloring(&g, &r.values));
    }
}
