//! Sequential reference implementations the test suite checks the
//! distributed engines against.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::graph::{Graph, VertexId};

/// Dijkstra single-source shortest paths (f64 accumulation).
pub fn dijkstra(g: &Graph, source: VertexId) -> Vec<f64> {
    let n = g.num_vertices();
    let mut dist = vec![f64::INFINITY; n];
    dist[source as usize] = 0.0;
    // (ordered distance bits, vertex) min-heap
    let mut heap: BinaryHeap<(Reverse<u64>, VertexId)> = BinaryHeap::new();
    heap.push((Reverse(0u64), source));
    while let Some((Reverse(dbits), v)) = heap.pop() {
        let d = f64::from_bits(dbits);
        if d > dist[v as usize] {
            continue;
        }
        let (ts, ws) = g.out_edges(v);
        for (&t, &w) in ts.iter().zip(ws) {
            let nd = d + w as f64;
            if nd < dist[t as usize] {
                dist[t as usize] = nd;
                heap.push((Reverse(nd.to_bits()), t));
            }
        }
    }
    dist
}

/// Power-iteration PageRank to the fixed point `r = 0.15 + 0.85·Σ r_u/d_u`
/// (the paper's unnormalized accumulative formulation).
pub fn pagerank(g: &Graph, tol: f64) -> Vec<f64> {
    let n = g.num_vertices();
    let deg: Vec<u32> = (0..n as VertexId).map(|v| g.out_degree(v) as u32).collect();
    let mut rank = vec![0.15f64; n];
    for _ in 0..100_000 {
        let mut next = vec![0.15f64; n];
        for v in 0..n as VertexId {
            if deg[v as usize] == 0 {
                continue;
            }
            let share = 0.85 * rank[v as usize] / deg[v as usize] as f64;
            for &t in g.out_edges(v).0 {
                next[t as usize] += share;
            }
        }
        let delta: f64 = rank.iter().zip(&next).map(|(a, b)| (a - b).abs()).sum();
        rank = next;
        if delta < tol {
            break;
        }
    }
    rank
}

/// Union-find weakly-connected-component labels (min vertex id per
/// component), treating edges as undirected.
pub fn wcc_labels(g: &Graph) -> Vec<u32> {
    let n = g.num_vertices();
    let mut parent: Vec<u32> = (0..n as u32).collect();
    fn find(parent: &mut [u32], mut x: u32) -> u32 {
        while parent[x as usize] != x {
            parent[x as usize] = parent[parent[x as usize] as usize];
            x = parent[x as usize];
        }
        x
    }
    for v in 0..n as VertexId {
        for &t in g.out_edges(v).0 {
            let (a, b) = (find(&mut parent, v), find(&mut parent, t));
            if a != b {
                let (lo, hi) = (a.min(b), a.max(b));
                parent[hi as usize] = lo;
            }
        }
    }
    (0..n as u32).map(|v| find(&mut parent, v)).collect()
}

/// Size of a greedy maximal matching (lower-bounds the maximum matching
/// within a factor of 2; any valid maximal matching is within [g/2, 2g]
/// of another).
pub fn greedy_matching_size(g: &Graph, num_left: u32) -> usize {
    let n = g.num_vertices();
    let mut matched = vec![false; n];
    let mut size = 0;
    for l in 0..num_left.min(n as u32) {
        if matched[l as usize] {
            continue;
        }
        for &r in g.out_edges(l).0 {
            if !matched[r as usize] {
                matched[l as usize] = true;
                matched[r as usize] = true;
                size += 1;
                break;
            }
        }
    }
    size
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, GraphBuilder};

    #[test]
    fn dijkstra_small() {
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1, 1.0);
        b.add_edge(1, 2, 2.0);
        b.add_edge(0, 2, 5.0);
        b.add_edge(2, 3, 1.0);
        let g = b.build();
        let d = dijkstra(&g, 0);
        assert_eq!(d, vec![0.0, 1.0, 3.0, 4.0]);
    }

    #[test]
    fn pagerank_sums_match_structure() {
        let g = generators::powerlaw(100, 3, 1);
        let r = pagerank(&g, 1e-12);
        // every rank >= base, hubs exceed it
        assert!(r.iter().all(|&x| x >= 0.15 - 1e-9));
        assert!(r.iter().any(|&x| x > 0.5));
    }

    #[test]
    fn wcc_two_components() {
        let mut b = GraphBuilder::new(5);
        b.add_undirected(0, 1, 1.0);
        b.add_undirected(2, 3, 1.0);
        b.add_undirected(3, 4, 1.0);
        let g = b.build();
        assert_eq!(wcc_labels(&g), vec![0, 0, 2, 2, 2]);
    }

    #[test]
    fn greedy_matching_on_bipartite() {
        let g = generators::bipartite(20, 20, 3, 2);
        let s = greedy_matching_size(&g, 20);
        assert!(s > 5 && s <= 20);
    }
}
