//! The paper's case-study vertex programs (§6) plus extra classics used
//! by the test suite:
//!
//! - [`sssp`] — single-source shortest paths (Alg. 4);
//! - [`pagerank`] — incremental/accumulative PageRank (Alg. 5) and the
//!   straightforward version (Alg. 1), plus the GAS form for the
//!   GraphLab engines and the graph-centric form for Giraph++;
//! - [`bipartite_matching`] — randomized maximal bipartite matching
//!   (Alg. 6);
//! - [`wcc`] — weakly connected components by min-label propagation;
//! - [`coloring`] — greedy graph coloring (a slow-convergence stress
//!   workload from [28]);
//! - [`oracle`] — sequential reference implementations (Dijkstra, power
//!   iteration, union-find, matching validation) used by tests.

pub mod bipartite_matching;
pub mod coloring;
pub mod oracle;
pub mod pagerank;
pub mod sssp;
pub mod wcc;

pub use bipartite_matching::BipartiteMatching;
pub use pagerank::{ClassicPageRank, GasPageRank, GiraphPPPageRank, IncrementalPageRank};
pub use sssp::{GasSssp, Sssp};
pub use wcc::{GasWcc, Wcc};
