//! Randomized maximal bipartite matching (paper §6.3, Alg. 6).
//!
//! The paper notes that GraphHP's hybrid/asynchronous execution "requires
//! a more stringent handshake mechanism" than the classic 4-stage Pregel
//! cycle. We implement exactly such a handshake, engine-agnostic and
//! livelock-free:
//!
//! - **left** vertices send one `Request` to every neighbor at superstep
//!   0, then react to events: on the first `Grant` they match, `Accept`
//!   the granter and `RejectGrant` every other granter; a `DenyMatched`
//!   marks that right vertex permanently unavailable.
//! - **right** vertices keep a queue of pending requesters. While
//!   `ungranted` they grant one pending requester (chosen uniformly at
//!   random with the per-vertex deterministic RNG) and hold the rest —
//!   *no busy-denial ping-pong*, which would livelock inside a GraphHP
//!   local phase. An `Accept` seals the match and sends `DenyMatched` to
//!   all still-pending requesters; a `RejectGrant` returns the right
//!   vertex to `ungranted`, and it grants the next pending requester.
//!
//! Every `Grant` is always answered (`Accept` or `RejectGrant`) and every
//! `Request` is eventually answered (`Grant` or `DenyMatched`), so the
//! protocol terminates with a maximal matching.
//!
//! Graphs must store bipartite edges in BOTH directions (see
//! [`crate::graph::generators::bipartite`]) so replies travel along edges
//! and Definition 1's boundary classification covers all message paths.

use crate::engine::{VertexContext, VertexProgram};
use crate::graph::VertexId;
use crate::util::Codec;

/// Left asks a right neighbor for a match.
pub const REQUEST: u8 = 0;
/// Right offers the match to one pending requester.
pub const GRANT: u8 = 1;
/// Left seals the match with the granter.
pub const ACCEPT: u8 = 2;
/// Left declines a grant (it already matched elsewhere).
pub const REJECT_GRANT: u8 = 3;
/// Right is permanently matched; requester must look elsewhere.
pub const DENY_MATCHED: u8 = 4;
/// Left withdraws its pending request (it matched elsewhere) — stops
/// rights from wasting a serial grant→reject round-trip on dead
/// requesters, which is what keeps GraphHP's iteration count low under
/// cross-partition contention.
pub const CANCEL: u8 = 5;

/// (kind, sender id).
pub type BmMsg = (u8, u32);

/// State shared by both sides (left uses `matched`; right uses
/// `matched`, `granted_to`, `pending`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BmState {
    /// Matched partner, if any.
    pub matched: Option<u32>,
    /// Right: the left vertex we granted and are waiting on.
    pub granted_to: Option<u32>,
    /// Right: requesters not yet answered.
    pub pending: Vec<u32>,
}

impl Codec for BmState {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.matched.encode(buf);
        self.granted_to.encode(buf);
        self.pending.encode(buf);
    }
    fn decode(r: &mut &[u8]) -> Option<Self> {
        Some(BmState {
            matched: Option::decode(r)?,
            granted_to: Option::decode(r)?,
            pending: Vec::decode(r)?,
        })
    }
}

/// The matching program. `num_left` splits the id space: ids `< num_left`
/// are left vertices.
pub struct BipartiteMatching {
    /// Ids below this are left vertices; the rest are right.
    pub num_left: u32,
}

impl BipartiteMatching {
    fn is_left(&self, v: VertexId) -> bool {
        v < self.num_left
    }

    fn compute_left(&self, ctx: &mut VertexContext<'_, Self>) {
        if ctx.superstep() == 0 {
            if *ctx.value() == BmState::default() && ctx.out_degree() > 0 {
                let me = ctx.vertex_id();
                ctx.send_along_edges(move |_| Some((REQUEST, me)));
            }
            ctx.vote_to_halt();
            return;
        }
        let me = ctx.vertex_id();
        let msgs: Vec<BmMsg> = ctx.messages().to_vec();
        for (kind, sender) in msgs {
            match kind {
                GRANT => {
                    if ctx.value().matched.is_none() {
                        ctx.value_mut().matched = Some(sender);
                        ctx.send(sender, (ACCEPT, me));
                        // withdraw every other outstanding request
                        let cancels: Vec<VertexId> = ctx
                            .edges()
                            .iter()
                            .map(|e| e.target)
                            .filter(|&t| t != sender)
                            .collect();
                        for t in cancels {
                            ctx.send(t, (CANCEL, me));
                        }
                    } else {
                        ctx.send(sender, (REJECT_GRANT, me));
                    }
                }
                DENY_MATCHED => { /* right permanently unavailable */ }
                _ => { /* lefts receive only grants/denials */ }
            }
        }
        ctx.vote_to_halt();
    }

    fn compute_right(&self, ctx: &mut VertexContext<'_, Self>) {
        let me = ctx.vertex_id();
        let msgs: Vec<BmMsg> = ctx.messages().to_vec();
        for (kind, sender) in msgs {
            match kind {
                REQUEST => {
                    if ctx.value().matched.is_some() {
                        ctx.send(sender, (DENY_MATCHED, me));
                    } else if !ctx.value().pending.contains(&sender) {
                        ctx.value_mut().pending.push(sender);
                    }
                }
                ACCEPT => {
                    // seal the match; release everyone still waiting
                    ctx.value_mut().matched = Some(sender);
                    ctx.value_mut().granted_to = None;
                    let pending = std::mem::take(&mut ctx.value_mut().pending);
                    for l in pending {
                        if l != sender {
                            ctx.send(l, (DENY_MATCHED, me));
                        }
                    }
                }
                REJECT_GRANT => {
                    if ctx.value().granted_to == Some(sender) {
                        ctx.value_mut().granted_to = None;
                    }
                }
                CANCEL => {
                    ctx.value_mut().pending.retain(|&l| l != sender);
                    if ctx.value().granted_to == Some(sender) {
                        ctx.value_mut().granted_to = None;
                    }
                }
                _ => {}
            }
        }
        // grant the next pending requester when free
        if ctx.value().matched.is_none() && ctx.value().granted_to.is_none() {
            let n = ctx.value().pending.len();
            if n > 0 {
                let pick = ctx.rng().index(n);
                let l = ctx.value_mut().pending.swap_remove(pick);
                ctx.value_mut().granted_to = Some(l);
                ctx.send(l, (GRANT, me));
            }
        }
        ctx.vote_to_halt();
    }
}

impl VertexProgram for BipartiteMatching {
    type V = BmState;
    type M = BmMsg;

    fn init(&self, _v: VertexId, _out_degree: u32) -> BmState {
        BmState::default()
    }

    fn compute(&self, ctx: &mut VertexContext<'_, Self>) {
        if self.is_left(ctx.vertex_id()) {
            self.compute_left(ctx);
        } else {
            self.compute_right(ctx);
        }
    }
    // No combiner: heterogeneous message kinds must all arrive (§6.4).
}

/// Validate a matching: consistency (partners agree, edges exist) and
/// maximality (no edge with both endpoints unmatched). Returns the
/// matching size.
pub fn validate_matching(
    g: &crate::graph::Graph,
    num_left: u32,
    values: &[BmState],
) -> Result<usize, String> {
    let mut size = 0usize;
    for v in 0..g.num_vertices() as VertexId {
        let s = &values[v as usize];
        if let Some(p) = s.matched {
            let ps = &values[p as usize];
            if ps.matched != Some(v) {
                return Err(format!("partner disagreement: {v} -> {p} -> {:?}", ps.matched));
            }
            if !g.out_edges(v).0.contains(&p) {
                return Err(format!("matched non-edge {v} -- {p}"));
            }
            if (v < num_left) != (p >= num_left) {
                return Err(format!("same-side match {v} -- {p}"));
            }
            if v < num_left {
                size += 1;
            }
        }
    }
    for v in 0..g.num_vertices() as VertexId {
        if values[v as usize].matched.is_none() {
            for &t in g.out_edges(v).0 {
                if values[t as usize].matched.is_none() {
                    return Err(format!("not maximal: edge {v} -- {t} both unmatched"));
                }
            }
        }
    }
    Ok(size)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{am_hama, graphhp, hama, EngineConfig};
    use crate::graph::{generators, DistGraph};
    use crate::partition::hash_partition;

    fn run_and_validate(
        engine: &str,
        g: &crate::graph::Graph,
        nl: u32,
        parts: usize,
    ) -> (usize, crate::engine::Metrics) {
        let dg = DistGraph::new(g, &hash_partition(g, parts), parts);
        let prog = BipartiteMatching { num_left: nl };
        let cfg = EngineConfig::default();
        let r = match engine {
            "hama" => hama::run_hama(&prog, &dg, &cfg),
            "am" => am_hama::run_am_hama(&prog, &dg, &cfg),
            "hp" => graphhp::run_graphhp(&prog, &dg, &cfg),
            _ => unreachable!(),
        };
        let size = validate_matching(g, nl, &r.values).expect(engine);
        (size, r.metrics)
    }

    #[test]
    fn all_engines_produce_valid_maximal_matchings() {
        let (nl, nr) = (60u32, 50u32);
        let g = generators::bipartite(nl as usize, nr as usize, 3, 13);
        let (s1, m1) = run_and_validate("hama", &g, nl, 4);
        let (s2, _m2) = run_and_validate("am", &g, nl, 4);
        let (s3, m3) = run_and_validate("hp", &g, nl, 4);
        assert!(s1 > 0 && s2 > 0 && s3 > 0);
        // maximal matchings are within 2x of each other (greedy bound)
        let lo = s1.min(s2).min(s3);
        let hi = s1.max(s2).max(s3);
        assert!(hi <= 2 * lo, "sizes {s1} {s2} {s3}");
        assert!(
            m3.global_iterations <= m1.global_iterations,
            "graphhp {} vs hama {}",
            m3.global_iterations,
            m1.global_iterations
        );
    }

    #[test]
    fn perfect_matching_on_disjoint_pairs() {
        // K_1,1 components: 0-2, 1-3 (nl=2)
        let mut b = crate::graph::GraphBuilder::new(4);
        b.add_undirected(0, 2, 1.0);
        b.add_undirected(1, 3, 1.0);
        let g = b.build();
        let dg = DistGraph::new(&g, &hash_partition(&g, 2), 2);
        let r = hama::run_hama(&BipartiteMatching { num_left: 2 }, &dg, &EngineConfig::default());
        assert_eq!(validate_matching(&g, 2, &r.values).unwrap(), 2);
    }

    #[test]
    fn contention_resolves_star() {
        // many lefts competing for one right
        let nl = 5u32;
        let mut b = crate::graph::GraphBuilder::new(6);
        for l in 0..5u32 {
            b.add_undirected(l, 5, 1.0);
        }
        let g = b.build();
        let dg = DistGraph::new(&g, &hash_partition(&g, 3), 3);
        let r = graphhp::run_graphhp(
            &BipartiteMatching { num_left: nl },
            &dg,
            &EngineConfig::default(),
        );
        assert_eq!(validate_matching(&g, nl, &r.values).unwrap(), 1);
    }
}
