//! Distributed (partitioned) view of a graph.
//!
//! [`DistGraph`] is built once from a [`Graph`] + a partition assignment
//! and is what every engine executes over: each [`PartGraph`] is the
//! read-only topology a worker owns, shared immutably across the
//! parallel worker threads (`Parallelism::Threads`) while all mutable
//! per-partition state lives in the engines' runtimes. It precomputes
//! exactly the metadata the paper's platform keeps per worker (§5.1):
//!
//! - each vertex's partition and partition-local index;
//! - per-edge location indicators (same-partition target + its local
//!   index, or remote partition);
//! - the local/boundary classification of Definition 1: a vertex is
//!   **boundary** iff it has at least one in-edge whose source lives in
//!   a different partition, else **local**. This is a static property of
//!   the partitioning — engines (including the adaptive scheduler's
//!   per-partition boundary decisions) consult it but never change it.

use super::csr::{Graph, VertexId};

/// One out-edge inside a partition, with the location indicator resolved.
#[derive(Clone, Copy, Debug)]
pub struct Edge {
    /// Global id of the target vertex.
    pub target: VertexId,
    /// Partition holding the target.
    pub target_part: u32,
    /// Target's index within its partition's vertex array.
    pub target_local: u32,
    /// Edge weight.
    pub weight: f32,
}

/// One partition of the distributed graph (the unit a worker owns).
#[derive(Clone, Debug)]
pub struct PartGraph {
    /// This partition's id.
    pub part: u32,
    /// Global ids of the vertices owned by this partition.
    pub global_ids: Vec<VertexId>,
    /// CSR offsets over `edges`, indexed by local vertex index.
    pub offsets: Vec<usize>,
    /// Out-edges of owned vertices with resolved locations.
    pub edges: Vec<Edge>,
    /// Definition 1 classification: `true` iff the vertex has an in-edge
    /// from another partition.
    pub is_boundary: Vec<bool>,
    /// Global out-degree of each owned vertex (same as local CSR degree,
    /// kept for O(1) access in vertex programs).
    pub out_degree: Vec<u32>,
}

impl PartGraph {
    /// Vertices owned by this partition.
    pub fn num_vertices(&self) -> usize {
        self.global_ids.len()
    }

    /// Out-edges of owned vertices (internal + cut).
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Out-edges of local vertex `lv`.
    pub fn out_edges(&self, lv: usize) -> &[Edge] {
        &self.edges[self.offsets[lv]..self.offsets[lv + 1]]
    }

    /// Number of boundary vertices.
    pub fn num_boundary(&self) -> usize {
        self.is_boundary.iter().filter(|&&b| b).count()
    }

    /// Number of internal (same-partition) edges.
    pub fn num_internal_edges(&self) -> usize {
        self.edges.iter().filter(|e| e.target_part == self.part).count()
    }
}

/// The fully-resolved distributed graph.
#[derive(Clone, Debug)]
pub struct DistGraph {
    /// Per-partition subgraphs, indexed by partition id — the read-only
    /// unit each parallel worker owns.
    pub parts: Vec<PartGraph>,
    /// Global vertex id -> (partition, local index).
    pub location: Vec<(u32, u32)>,
    /// Total vertex count.
    pub num_vertices: usize,
    /// Total edge count.
    pub num_edges: usize,
}

impl DistGraph {
    /// Partition `g` according to `assignment` (vertex -> partition id,
    /// all values < `num_parts`). Vertices keep their relative order
    /// within a partition.
    pub fn new(g: &Graph, assignment: &[u32], num_parts: usize) -> DistGraph {
        let nv = g.num_vertices();
        assert_eq!(assignment.len(), nv, "assignment length != num vertices");
        assert!(num_parts > 0);

        // location table
        let mut location = vec![(0u32, 0u32); nv];
        let mut counts = vec![0u32; num_parts];
        for v in 0..nv {
            let p = assignment[v] as usize;
            assert!(p < num_parts, "assignment[{v}]={p} >= num_parts");
            location[v] = (p as u32, counts[p]);
            counts[p] += 1;
        }

        let mut parts: Vec<PartGraph> = (0..num_parts)
            .map(|p| PartGraph {
                part: p as u32,
                global_ids: Vec::with_capacity(counts[p] as usize),
                offsets: vec![0],
                edges: Vec::new(),
                is_boundary: Vec::new(),
                out_degree: Vec::new(),
            })
            .collect();

        for v in 0..nv as VertexId {
            let (p, _) = location[v as usize];
            let part = &mut parts[p as usize];
            part.global_ids.push(v);
            let (ts, ws) = g.out_edges(v);
            for (&t, &w) in ts.iter().zip(ws) {
                let (tp, tl) = location[t as usize];
                part.edges.push(Edge { target: t, target_part: tp, target_local: tl, weight: w });
            }
            part.offsets.push(part.edges.len());
            part.out_degree.push(ts.len() as u32);
            part.is_boundary.push(false);
        }

        // Boundary classification: mark targets of cross-partition edges.
        // (A vertex with an in-edge from a remote partition is boundary.)
        let mut boundary = vec![false; nv];
        for part in &parts {
            for e in &part.edges {
                if e.target_part != part.part {
                    boundary[e.target as usize] = true;
                }
            }
        }
        for part in &mut parts {
            for (i, &gid) in part.global_ids.iter().enumerate() {
                part.is_boundary[i] = boundary[gid as usize];
            }
        }

        DistGraph { parts, location, num_vertices: nv, num_edges: g.num_edges() }
    }

    /// Number of partitions (= simulated workers).
    pub fn num_parts(&self) -> usize {
        self.parts.len()
    }

    /// Total number of cross-partition edges.
    pub fn edge_cut(&self) -> usize {
        self.parts
            .iter()
            .map(|p| p.edges.iter().filter(|e| e.target_part != p.part).count())
            .sum()
    }

    /// Total number of boundary vertices.
    pub fn num_boundary(&self) -> usize {
        self.parts.iter().map(|p| p.num_boundary()).sum()
    }

    /// Partition balance indicator: the largest partition's vertex count
    /// over the *mean* partition size (the METIS load-imbalance metric).
    /// 1.0 = perfectly balanced; k = all vertices in one of k partitions.
    /// Dividing by the mean rather than the smallest partition keeps the
    /// indicator finite when a partition is empty. Returns 1.0 for an
    /// empty graph.
    pub fn balance(&self) -> f64 {
        let sizes: Vec<usize> = self.parts.iter().map(|p| p.num_vertices()).collect();
        let max = *sizes.iter().max().unwrap_or(&0) as f64;
        let avg = self.num_vertices as f64 / self.num_parts() as f64;
        if avg == 0.0 {
            return 1.0;
        }
        max / avg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    fn path4() -> Graph {
        // 0 -> 1 -> 2 -> 3
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1, 1.0);
        b.add_edge(1, 2, 1.0);
        b.add_edge(2, 3, 1.0);
        b.build()
    }

    #[test]
    fn partitioning_preserves_structure() {
        let g = path4();
        let dg = DistGraph::new(&g, &[0, 0, 1, 1], 2);
        assert_eq!(dg.num_parts(), 2);
        assert_eq!(dg.parts[0].global_ids, vec![0, 1]);
        assert_eq!(dg.parts[1].global_ids, vec![2, 3]);
        assert_eq!(dg.num_edges, 3);
        assert_eq!(dg.edge_cut(), 1); // only 1 -> 2 crosses
    }

    #[test]
    fn location_indicators_resolved() {
        let g = path4();
        let dg = DistGraph::new(&g, &[0, 0, 1, 1], 2);
        let e = &dg.parts[0].out_edges(1)[0]; // edge 1 -> 2
        assert_eq!(e.target, 2);
        assert_eq!(e.target_part, 1);
        assert_eq!(e.target_local, 0);
        assert_eq!(dg.location[3], (1, 1));
    }

    #[test]
    fn boundary_classification_def1() {
        let g = path4();
        let dg = DistGraph::new(&g, &[0, 0, 1, 1], 2);
        // vertex 2 has in-edge from partition 0 => boundary; others local
        assert!(!dg.parts[0].is_boundary[0]); // v0: no in-edges
        assert!(!dg.parts[0].is_boundary[1]); // v1: in-edge from v0, same part
        assert!(dg.parts[1].is_boundary[0]); // v2: in-edge from remote v1
        assert!(!dg.parts[1].is_boundary[1]); // v3: in-edge from v2, same part
        assert_eq!(dg.num_boundary(), 1);
    }

    #[test]
    fn single_partition_has_no_boundary() {
        let g = path4();
        let dg = DistGraph::new(&g, &[0, 0, 0, 0], 1);
        assert_eq!(dg.num_boundary(), 0);
        assert_eq!(dg.edge_cut(), 0);
        assert_eq!(dg.balance(), 1.0);
    }

    #[test]
    fn balance_reflects_skew() {
        let g = path4();
        let dg = DistGraph::new(&g, &[0, 0, 0, 1], 2);
        assert_eq!(dg.balance(), 1.5); // max 3 / avg 2
    }

    #[test]
    fn balance_is_max_over_mean_and_finite_with_empty_partition() {
        let g = path4();
        // every vertex in partition 0 of 3: max 4 / mean (4/3) = 3.0 —
        // max/min would be infinite here, max/mean stays the partition
        // count (the documented worst case)
        let dg = DistGraph::new(&g, &[0, 0, 0, 0], 3);
        assert_eq!(dg.balance(), 3.0);
        assert!(dg.balance().is_finite());
    }
}
