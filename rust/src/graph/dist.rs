//! Distributed (partitioned) view of a graph.
//!
//! [`DistGraph`] is built once from a [`Graph`] + a partition assignment
//! and is what every engine executes over: each [`PartGraph`] is the
//! read-only topology a worker owns, shared immutably across the
//! parallel worker threads (`Parallelism::Threads`) while all mutable
//! per-partition state lives in the engines' runtimes. It precomputes
//! exactly the metadata the paper's platform keeps per worker (§5.1):
//!
//! - each vertex's partition and partition-local index;
//! - per-edge location indicators (same-partition target + its local
//!   index, or remote partition), packed as one-word [`EdgeRoute`]s;
//! - the local/boundary classification of Definition 1: a vertex is
//!   **boundary** iff it has at least one in-edge whose source lives in
//!   a different partition, else **local**. This is a static property of
//!   the partitioning — engines (including the adaptive scheduler's
//!   per-partition boundary decisions) consult it but never change it;
//! - the per-partition boundary-vertex and internal-edge counts, so the
//!   telemetry/stats queries on barrier paths are O(1) instead of
//!   rescanning the partition.
//!
//! # Edge storage: structure-of-arrays, optionally compressed
//!
//! A partition's out-edges live in three parallel arrays —
//! [`PartGraph::targets`], [`PartGraph::routes`], [`PartGraph::weights`]
//! — instead of one `Vec` of 16-byte edge records. The per-vertex sweep
//! loop is the platform's hottest code (it runs once per vertex per
//! pseudo-superstep), and its dominant consumers each touch only a
//! subset of the edge fields: `send_to_neighbors` streams routes alone,
//! weight-less programs (PageRank, WCC) never load `weights`, and the
//! partition-stats passes read only `routes`. The SoA split lets each
//! consumer stream exactly the words it needs. [`PartGraph::out_edges`]
//! still hands out an [`Edge`]-view iterator so edge-generic code reads
//! as before.
//!
//! With [`GraphLayout::compress_edges`], the `targets` + `routes`
//! columns (12 bytes/edge) are replaced by a per-vertex varint stream:
//! same-partition edges — the majority on a locality-aware partitioning,
//! which is GraphHP's whole premise — collapse to one zigzag-encoded
//! delta over local indices (typically 1–2 bytes), while cross-partition
//! edges keep their full route. The [`Edges`] view decodes the stream
//! on the fly, so `out_edges()` callers are unchanged; only code that
//! demanded the raw column slices had to move to the iterators.
//!
//! # Vertex layout
//!
//! Local indices within a partition are an *internal* naming: every
//! user-visible surface (vertex ids in programs, `gather_values`
//! output, the location table) speaks global ids. That freedom is used
//! by [`LayoutPolicy::DegreeSorted`]: local vertices are relabeled by
//! descending out-degree (ties broken by global id, so the permutation
//! is deterministic), stored as a [`VertexLayout`] on each partition.
//! High-degree vertices — the ones whose state and message slots are
//! touched most — become cache-adjacent at the front of every
//! per-vertex array. Because the location table, `EdgeRoute` columns
//! and `global_ids` are all written *through* the permutation, engines
//! and `gather_values` need no translation step: local indices are
//! simply born permuted.
//!
//! # Routing epochs
//!
//! All derived routing state — the global location table, the
//! per-partition cut-in tallies, and (rebuilt together with them) every
//! partition's `EdgeRoute` columns, boundary flags, precomputed counts
//! and `VertexLayout` permutation — is versioned by a [`RoutingEpoch`].
//! Epoch 0 is the build-time partitioning;
//! [`DistGraph::apply_migration`] consumes a [`MigrationPlan`]
//! (vertex → new partition) and produces the next epoch through the
//! same write-through construction path `with_layout` uses, sourcing
//! topology from the previous epoch's own partitions (a `DistGraph`
//! does not retain its source [`Graph`]). Engines treat an epoch as
//! immutable for the duration of a superstep and only swap epochs at a
//! barrier.

use super::csr::{Graph, VertexId};
use crate::util::codec::{read_varint, unzigzag, write_varint, zigzag, Codec};

/// Packed location indicator of an edge target (§5.1): the destination
/// partition in the high 32 bits, the destination's partition-local
/// index in the low 32. One aligned load resolves a message route with
/// no global-table lookup.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct EdgeRoute(u64);

impl EdgeRoute {
    /// Pack a `(partition, local index)` pair.
    #[inline]
    pub fn new(part: u32, local: u32) -> Self {
        EdgeRoute(((part as u64) << 32) | local as u64)
    }

    /// Destination partition.
    #[inline]
    pub fn part(self) -> u32 {
        (self.0 >> 32) as u32
    }

    /// Destination's index within its partition's vertex array.
    #[inline]
    pub fn local(self) -> u32 {
        self.0 as u32
    }

    /// Unpack into `(partition, local index)`.
    #[inline]
    pub fn unpack(self) -> (u32, u32) {
        (self.part(), self.local())
    }
}

/// How a [`DistGraph`] lays out each partition's local vertex indices.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum LayoutPolicy {
    /// Local indices follow ascending global id (the historical order).
    #[default]
    Identity,
    /// Local indices follow descending out-degree, ties broken by
    /// ascending global id — hot vertices become cache-adjacent at the
    /// front of every per-vertex array. Deterministic: a pure function
    /// of the graph + assignment.
    DegreeSorted,
}

/// Build-time layout configuration for [`DistGraph::with_layout`].
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub struct GraphLayout {
    /// Local-index naming policy.
    pub policy: LayoutPolicy,
    /// Replace the `targets`/`routes` SoA columns with per-vertex
    /// varint-delta streams (see the module docs). Weights and CSR
    /// offsets stay uncompressed.
    pub compress_edges: bool,
}

impl GraphLayout {
    /// Degree-sorted, uncompressed.
    pub fn degree_sorted() -> Self {
        GraphLayout { policy: LayoutPolicy::DegreeSorted, compress_edges: false }
    }

    /// Degree-sorted with compressed edge columns — the full
    /// bandwidth-bound configuration.
    pub fn packed() -> Self {
        GraphLayout { policy: LayoutPolicy::DegreeSorted, compress_edges: true }
    }
}

/// The local-index permutation of one partition.
///
/// "Natural rank" is a vertex's position in the ascending-global-id
/// enumeration of the partition's members (the [`LayoutPolicy::Identity`]
/// naming); "local" is the index actually used by every per-vertex
/// array. `fwd` maps natural rank -> local, `inv` maps local -> natural
/// rank. The identity permutation is represented by *empty* vectors so
/// the default layout costs no memory at web scale.
#[derive(Clone, Debug, Default)]
pub struct VertexLayout {
    /// natural rank -> local index (empty = identity).
    pub fwd: Vec<u32>,
    /// local index -> natural rank (empty = identity).
    pub inv: Vec<u32>,
}

impl VertexLayout {
    /// The identity permutation (any size).
    pub fn identity() -> Self {
        VertexLayout::default()
    }

    /// True when this is the (memory-free) identity permutation.
    pub fn is_identity(&self) -> bool {
        self.fwd.is_empty()
    }

    /// Local index of the vertex at `natural` rank.
    #[inline]
    pub fn to_local(&self, natural: u32) -> u32 {
        if self.fwd.is_empty() {
            natural
        } else {
            self.fwd[natural as usize]
        }
    }

    /// Natural rank of the vertex at `local` index.
    #[inline]
    pub fn to_natural(&self, local: u32) -> u32 {
        if self.inv.is_empty() {
            local
        } else {
            self.inv[local as usize]
        }
    }

    /// Descending-out-degree permutation over `gids` (a partition's
    /// members in ascending global-id order), ties broken by global id.
    /// Degrees come through an accessor so the construction core can
    /// source them from either a [`Graph`] or a previous routing epoch.
    fn degree_sorted(gids: &[VertexId], degree_of: impl Fn(VertexId) -> u32) -> Self {
        let n = gids.len();
        let mut inv: Vec<u32> = (0..n as u32).collect();
        inv.sort_unstable_by_key(|&r| {
            let gid = gids[r as usize];
            (std::cmp::Reverse(degree_of(gid)), gid)
        });
        let mut fwd = vec![0u32; n];
        for (local, &rank) in inv.iter().enumerate() {
            fwd[rank as usize] = local as u32;
        }
        VertexLayout { fwd, inv }
    }
}

/// One out-edge inside a partition, with the location indicator
/// resolved — the *view* type assembled on demand from the edge columns
/// by [`Edges`].
#[derive(Clone, Copy, Debug)]
pub struct Edge {
    /// Global id of the target vertex.
    pub target: VertexId,
    /// Partition holding the target.
    pub target_part: u32,
    /// Target's index within its partition's vertex array.
    pub target_local: u32,
    /// Edge weight.
    pub weight: f32,
}

impl Edge {
    /// The edge's packed location indicator.
    #[inline]
    pub fn route(&self) -> EdgeRoute {
        EdgeRoute::new(self.target_part, self.target_local)
    }
}

/// Borrowed view of one vertex's out-edges.
///
/// Over uncompressed storage this wraps the three SoA column slices;
/// over compressed storage it wraps the vertex's varint block and
/// decodes it streamingly. Iterates as [`Edge`] values
/// (`for e in part.out_edges(lv)` or `.iter()`);
/// [`route_iter`](Self::route_iter) streams the location indicators
/// alone (the `send_to_neighbors` hot path); the raw
/// [`targets`](Self::targets) / [`routes`](Self::routes) slices exist
/// only on uncompressed storage, [`weights`](Self::weights) on both.
#[derive(Clone, Copy, Debug)]
pub struct Edges<'a> {
    repr: EdgesRepr<'a>,
}

#[derive(Clone, Copy, Debug)]
enum EdgesRepr<'a> {
    Soa {
        targets: &'a [VertexId],
        routes: &'a [EdgeRoute],
        weights: &'a [f32],
    },
    Packed {
        /// This vertex's varint block.
        bytes: &'a [u8],
        /// Edge count (from the CSR offsets — not derivable from bytes).
        len: usize,
        /// Home partition id (same-partition deltas resolve against it).
        part: u32,
        /// The home partition's `global_ids` (local -> gid for
        /// same-partition targets).
        gids: &'a [VertexId],
        weights: &'a [f32],
    },
}

impl<'a> Edges<'a> {
    /// Number of edges in the view.
    #[inline]
    pub fn len(&self) -> usize {
        match self.repr {
            EdgesRepr::Soa { targets, .. } => targets.len(),
            EdgesRepr::Packed { len, .. } => len,
        }
    }

    /// True when the vertex has no out-edges.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Assemble the `i`-th edge view (panics if out of range). O(1) on
    /// SoA storage, O(i) on compressed storage (decodes the block up to
    /// `i`) — random access is a cold-path convenience; sweeps iterate.
    #[inline]
    pub fn get(&self, i: usize) -> Edge {
        match self.repr {
            EdgesRepr::Soa { targets, routes, weights } => {
                let r = routes[i];
                Edge {
                    target: targets[i],
                    target_part: r.part(),
                    target_local: r.local(),
                    weight: weights[i],
                }
            }
            EdgesRepr::Packed { .. } => {
                self.iter().nth(i).expect("edge index out of range")
            }
        }
    }

    /// Global target ids (the `targets` column). Only available on
    /// uncompressed storage — compressed callers stream
    /// [`iter`](Self::iter) instead.
    #[inline]
    pub fn targets(&self) -> &'a [VertexId] {
        match self.repr {
            EdgesRepr::Soa { targets, .. } => targets,
            EdgesRepr::Packed { .. } => {
                panic!("targets(): no raw column on compressed edge storage; iterate")
            }
        }
    }

    /// Packed location indicators (the `routes` column). Only available
    /// on uncompressed storage — compressed callers stream
    /// [`route_iter`](Self::route_iter) instead.
    #[inline]
    pub fn routes(&self) -> &'a [EdgeRoute] {
        match self.repr {
            EdgesRepr::Soa { routes, .. } => routes,
            EdgesRepr::Packed { .. } => {
                panic!("routes(): no raw column on compressed edge storage; route_iter")
            }
        }
    }

    /// Edge weights (kept uncompressed in both storage modes).
    #[inline]
    pub fn weights(&self) -> &'a [f32] {
        match self.repr {
            EdgesRepr::Soa { weights, .. } | EdgesRepr::Packed { weights, .. } => weights,
        }
    }

    /// Iterate the edges as assembled [`Edge`] views.
    #[inline]
    pub fn iter(&self) -> EdgesIter<'a> {
        match self.repr {
            EdgesRepr::Soa { targets, routes, weights } => EdgesIter {
                repr: EdgesIterRepr::Soa {
                    targets: targets.iter(),
                    routes: routes.iter(),
                    weights: weights.iter(),
                },
            },
            EdgesRepr::Packed { bytes, len, part, gids, weights } => EdgesIter {
                repr: EdgesIterRepr::Packed {
                    dec: PackedDecoder::new(bytes, len, part, gids),
                    weights: weights.iter(),
                },
            },
        }
    }

    /// Stream the location indicators alone — the `send_to_neighbors`
    /// hot path. On SoA storage this is the raw `routes` slice; on
    /// compressed storage it decodes routes without assembling edges.
    #[inline]
    pub fn route_iter(&self) -> RouteIter<'a> {
        match self.repr {
            EdgesRepr::Soa { routes, .. } => RouteIter { repr: RouteIterRepr::Soa(routes.iter()) },
            EdgesRepr::Packed { bytes, len, part, gids, .. } => {
                RouteIter { repr: RouteIterRepr::Packed(PackedDecoder::new(bytes, len, part, gids)) }
            }
        }
    }
}

impl<'a> IntoIterator for Edges<'a> {
    type Item = Edge;
    type IntoIter = EdgesIter<'a>;

    fn into_iter(self) -> EdgesIter<'a> {
        self.iter()
    }
}

/// Streaming decoder over one vertex's varint edge block (see
/// [`PartGraph::compress_edges`] for the format).
#[derive(Clone, Debug)]
struct PackedDecoder<'a> {
    bytes: &'a [u8],
    pos: usize,
    remaining: usize,
    /// Previous same-partition local index (delta base; 0 at block
    /// start).
    prev_local: u32,
    part: u32,
    gids: &'a [VertexId],
}

impl<'a> PackedDecoder<'a> {
    #[inline]
    fn new(bytes: &'a [u8], len: usize, part: u32, gids: &'a [VertexId]) -> Self {
        PackedDecoder { bytes, pos: 0, remaining: len, prev_local: 0, part, gids }
    }

    /// Decode the next `(route, target gid)` pair, or None at block end.
    #[inline]
    fn next_edge(&mut self) -> Option<(EdgeRoute, VertexId)> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let tag = read_varint(self.bytes, &mut self.pos);
        if tag & 1 == 0 {
            // same-partition edge: zigzag delta over local indices
            let local = (self.prev_local as i64 + unzigzag(tag >> 1)) as u32;
            self.prev_local = local;
            Some((EdgeRoute::new(self.part, local), self.gids[local as usize]))
        } else {
            // cross-partition edge: explicit (part, local, gid)
            let part = (tag >> 1) as u32;
            let local = read_varint(self.bytes, &mut self.pos) as u32;
            let gid = read_varint(self.bytes, &mut self.pos) as VertexId;
            Some((EdgeRoute::new(part, local), gid))
        }
    }
}

/// Iterator over an [`Edges`] view, yielding [`Edge`] values assembled
/// from the parallel columns (SoA) or decoded from the varint block
/// (compressed).
pub struct EdgesIter<'a> {
    repr: EdgesIterRepr<'a>,
}

enum EdgesIterRepr<'a> {
    Soa {
        targets: std::slice::Iter<'a, VertexId>,
        routes: std::slice::Iter<'a, EdgeRoute>,
        weights: std::slice::Iter<'a, f32>,
    },
    Packed {
        dec: PackedDecoder<'a>,
        weights: std::slice::Iter<'a, f32>,
    },
}

impl Iterator for EdgesIter<'_> {
    type Item = Edge;

    #[inline]
    fn next(&mut self) -> Option<Edge> {
        match &mut self.repr {
            EdgesIterRepr::Soa { targets, routes, weights } => {
                let &target = targets.next()?;
                let &route = routes.next().expect("routes column in sync");
                let &weight = weights.next().expect("weights column in sync");
                Some(Edge {
                    target,
                    target_part: route.part(),
                    target_local: route.local(),
                    weight,
                })
            }
            EdgesIterRepr::Packed { dec, weights } => {
                let (route, target) = dec.next_edge()?;
                let &weight = weights.next().expect("weights column in sync");
                Some(Edge {
                    target,
                    target_part: route.part(),
                    target_local: route.local(),
                    weight,
                })
            }
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        match &self.repr {
            EdgesIterRepr::Soa { targets, .. } => targets.size_hint(),
            EdgesIterRepr::Packed { dec, .. } => (dec.remaining, Some(dec.remaining)),
        }
    }
}

impl ExactSizeIterator for EdgesIter<'_> {}

/// Iterator over the location indicators of an [`Edges`] view alone —
/// no target/weight loads (SoA) or decodes beyond the route fields
/// (compressed).
pub struct RouteIter<'a> {
    repr: RouteIterRepr<'a>,
}

enum RouteIterRepr<'a> {
    Soa(std::slice::Iter<'a, EdgeRoute>),
    Packed(PackedDecoder<'a>),
}

impl Iterator for RouteIter<'_> {
    type Item = EdgeRoute;

    #[inline]
    fn next(&mut self) -> Option<EdgeRoute> {
        match &mut self.repr {
            RouteIterRepr::Soa(it) => it.next().copied(),
            RouteIterRepr::Packed(dec) => dec.next_edge().map(|(r, _)| r),
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        match &self.repr {
            RouteIterRepr::Soa(it) => it.size_hint(),
            RouteIterRepr::Packed(dec) => (dec.remaining, Some(dec.remaining)),
        }
    }
}

impl ExactSizeIterator for RouteIter<'_> {}

/// One partition of the distributed graph (the unit a worker owns).
#[derive(Clone, Debug)]
pub struct PartGraph {
    /// This partition's id.
    pub part: u32,
    /// Global ids of the vertices owned by this partition, in local
    /// index order (already permuted under a non-identity layout).
    pub global_ids: Vec<VertexId>,
    /// CSR offsets over the edge columns, indexed by local vertex index
    /// (edge *counts* — valid in both storage modes).
    pub offsets: Vec<usize>,
    /// Global target id of every out-edge (SoA column; empty when
    /// compressed).
    pub targets: Vec<VertexId>,
    /// Packed location indicator of every out-edge (SoA column; empty
    /// when compressed).
    pub routes: Vec<EdgeRoute>,
    /// Weight of every out-edge (kept uncompressed in both modes).
    pub weights: Vec<f32>,
    /// Definition 1 classification: `true` iff the vertex has an in-edge
    /// from another partition.
    pub is_boundary: Vec<bool>,
    /// Global out-degree of each owned vertex (same as local CSR degree,
    /// kept for O(1) access in vertex programs).
    pub out_degree: Vec<u32>,
    /// The local-index permutation this partition was built with.
    pub layout: VertexLayout,
    /// Varint-delta edge stream replacing `targets` + `routes` (empty
    /// when uncompressed). Per-vertex blocks delimited by
    /// `packed_offsets`.
    pub(crate) packed: Vec<u8>,
    /// Byte offsets of each vertex's block in `packed` (`nv + 1`
    /// entries; empty when uncompressed).
    pub(crate) packed_offsets: Vec<usize>,
    /// Precomputed count of `true` entries in `is_boundary`.
    boundary_vertices: usize,
    /// Precomputed count of edges whose target stays in this partition.
    internal_edges: usize,
}

impl PartGraph {
    /// Vertices owned by this partition.
    pub fn num_vertices(&self) -> usize {
        self.global_ids.len()
    }

    /// Out-edges of owned vertices (internal + cut).
    pub fn num_edges(&self) -> usize {
        self.weights.len()
    }

    /// True when the `targets`/`routes` columns live as varint blocks.
    pub fn is_compressed(&self) -> bool {
        !self.packed_offsets.is_empty()
    }

    /// Bytes held by the edge columns (targets + routes + weights +
    /// offsets, plus the varint stream when compressed) — the
    /// bytes-per-edge figure the bench report tracks.
    pub fn edge_column_bytes(&self) -> usize {
        self.targets.len() * std::mem::size_of::<VertexId>()
            + self.routes.len() * std::mem::size_of::<EdgeRoute>()
            + self.weights.len() * std::mem::size_of::<f32>()
            + self.offsets.len() * std::mem::size_of::<usize>()
            + self.packed.len()
            + self.packed_offsets.len() * std::mem::size_of::<usize>()
    }

    /// Out-edges of local vertex `lv` as a streaming view (SoA slices or
    /// varint block, transparently).
    #[inline]
    pub fn out_edges(&self, lv: usize) -> Edges<'_> {
        let (s, e) = (self.offsets[lv], self.offsets[lv + 1]);
        if self.packed_offsets.is_empty() {
            Edges {
                repr: EdgesRepr::Soa {
                    targets: &self.targets[s..e],
                    routes: &self.routes[s..e],
                    weights: &self.weights[s..e],
                },
            }
        } else {
            Edges {
                repr: EdgesRepr::Packed {
                    bytes: &self.packed[self.packed_offsets[lv]..self.packed_offsets[lv + 1]],
                    len: e - s,
                    part: self.part,
                    gids: &self.global_ids,
                    weights: &self.weights[s..e],
                },
            }
        }
    }

    /// Number of boundary vertices — precomputed at
    /// [`DistGraph::new`] time, O(1).
    pub fn num_boundary(&self) -> usize {
        self.boundary_vertices
    }

    /// Number of internal (same-partition) edges — precomputed at
    /// [`DistGraph::new`] time, O(1).
    pub fn num_internal_edges(&self) -> usize {
        self.internal_edges
    }

    /// Replace the `targets`/`routes` SoA columns with per-vertex varint
    /// blocks. Format, per edge, order-preserving:
    ///
    /// - same-partition: one varint `zigzag(local - prev_local) << 1`
    ///   (low bit 0), where `prev_local` starts at 0 per vertex block —
    ///   consecutive local targets cost 1 byte each;
    /// - cross-partition: varint `(part << 1) | 1`, then varint `local`,
    ///   then varint `gid`.
    ///
    /// Weights and CSR offsets are untouched; [`out_edges`] switches to
    /// the decoding view automatically.
    fn compress_edges(&mut self) {
        let nv = self.num_vertices();
        let mut packed = Vec::with_capacity(self.num_edges() * 2);
        let mut packed_offsets = Vec::with_capacity(nv + 1);
        packed_offsets.push(0);
        for lv in 0..nv {
            let (s, e) = (self.offsets[lv], self.offsets[lv + 1]);
            let mut prev = 0u32;
            for i in s..e {
                let r = self.routes[i];
                if r.part() == self.part {
                    write_varint(&mut packed, zigzag(r.local() as i64 - prev as i64) << 1);
                    prev = r.local();
                } else {
                    write_varint(&mut packed, ((r.part() as u64) << 1) | 1);
                    write_varint(&mut packed, r.local() as u64);
                    write_varint(&mut packed, self.targets[i] as u64);
                }
            }
            packed_offsets.push(packed.len());
        }
        self.packed = packed;
        self.packed_offsets = packed_offsets;
        self.targets = Vec::new();
        self.routes = Vec::new();
    }
}

/// The versioned routing state of a [`DistGraph`] (see the module docs).
///
/// Everything an engine needs to route a message — and everything a
/// migration must rewrite — hangs off one epoch: the global location
/// table here, plus the per-partition projections rebuilt in lockstep
/// with it (each [`PartGraph`]'s `EdgeRoute` columns — raw SoA or
/// packed varint — boundary flags, precomputed boundary/internal
/// counts, and `VertexLayout` permutation). The epoch number is bumped
/// exactly once per applied [`MigrationPlan`], at a barrier; within a
/// superstep the epoch is immutable and shared read-only across worker
/// threads.
#[derive(Clone, Debug)]
pub struct RoutingEpoch {
    /// Epoch counter: 0 at build time, +1 per applied migration.
    pub epoch: u64,
    /// Global vertex id -> (partition, local index).
    pub location: Vec<(u32, u32)>,
    /// Per-partition cut-in tallies: `cut_in[q]` = cross-partition edges
    /// whose target lives in partition `q`. Maintained with the epoch so
    /// `partition_localities` is O(parts) per barrier instead of a
    /// full-graph route rescan.
    pub cut_in: Vec<u64>,
}

/// A vertex-migration decision for one barrier: move each listed vertex
/// to a new owning partition, producing routing epoch `epoch`.
///
/// Plans are pure data — deterministic functions of trace counters —
/// so they can be checkpointed and replayed bit-for-bit on recovery
/// (the same contract as `PolicyCheckpoint`). `moves` is sorted by
/// global id and contains no duplicate vertices and no self-moves.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MigrationPlan {
    /// The epoch this plan produces (= previous epoch + 1).
    pub epoch: u64,
    /// `(vertex global id, new partition)`, sorted by global id.
    pub moves: Vec<(VertexId, u32)>,
}

impl MigrationPlan {
    /// Number of vertices the plan moves.
    pub fn len(&self) -> usize {
        self.moves.len()
    }

    /// True when the plan moves nothing.
    pub fn is_empty(&self) -> bool {
        self.moves.is_empty()
    }
}

impl Codec for MigrationPlan {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.epoch.encode(buf);
        self.moves.encode(buf);
    }

    fn decode(r: &mut &[u8]) -> Option<Self> {
        let epoch = u64::decode(r)?;
        let moves = Vec::<(VertexId, u32)>::decode(r)?;
        Some(MigrationPlan { epoch, moves })
    }
}

/// The fully-resolved distributed graph.
#[derive(Clone, Debug)]
pub struct DistGraph {
    /// Per-partition subgraphs, indexed by partition id — the read-only
    /// unit each parallel worker owns.
    pub parts: Vec<PartGraph>,
    /// The current routing epoch (location table + cut tallies; the
    /// per-partition route columns in `parts` are its projections).
    pub routing: RoutingEpoch,
    /// Total vertex count.
    pub num_vertices: usize,
    /// Total edge count.
    pub num_edges: usize,
    /// The layout configuration this graph was built with.
    pub layout: GraphLayout,
}

impl DistGraph {
    /// Partition `g` according to `assignment` (vertex -> partition id,
    /// all values < `num_parts`) with the default layout: local indices
    /// in ascending-global-id order, uncompressed SoA edge columns.
    pub fn new(g: &Graph, assignment: &[u32], num_parts: usize) -> DistGraph {
        Self::with_layout(g, assignment, num_parts, GraphLayout::default())
    }

    /// Partition `g` under an explicit [`GraphLayout`]. The layout only
    /// renames partition-local indices and re-encodes edge columns —
    /// every user-visible surface (global ids, `gather_values` output,
    /// boundary classification, edge cut) is identical across layouts.
    pub fn with_layout(
        g: &Graph,
        assignment: &[u32],
        num_parts: usize,
        layout: GraphLayout,
    ) -> DistGraph {
        Self::build(
            g.num_vertices(),
            g.num_edges(),
            assignment,
            num_parts,
            layout,
            0,
            |v| g.out_degree(v) as u32,
            |v, emit| {
                let (ts, ws) = g.out_edges(v);
                for (&t, &w) in ts.iter().zip(ws) {
                    emit(t, w);
                }
            },
        )
    }

    /// Shared construction core behind [`with_layout`](Self::with_layout)
    /// (epoch 0, topology from the source [`Graph`]) and
    /// [`apply_migration`](Self::apply_migration) (epoch n+1, topology
    /// from the previous epoch's own partitions — a `DistGraph` does not
    /// retain its source graph). Topology arrives through two accessors:
    /// `degree_of` (global out-degree, consulted only by the
    /// degree-sorted layout) and `for_each_edge` (streams each vertex's
    /// out-edges in order). Everything derived — location table, route
    /// columns, boundary flags, counts, permutations, cut-in tallies —
    /// is written through the permutation here and nowhere else.
    #[allow(clippy::too_many_arguments)]
    fn build(
        nv: usize,
        num_edges: usize,
        assignment: &[u32],
        num_parts: usize,
        layout: GraphLayout,
        epoch: u64,
        degree_of: impl Fn(VertexId) -> u32,
        for_each_edge: impl Fn(VertexId, &mut dyn FnMut(VertexId, f32)),
    ) -> DistGraph {
        assert_eq!(assignment.len(), nv, "assignment length != num vertices");
        assert!(num_parts > 0);

        // partition membership in ascending global-id order (the
        // "natural rank" enumeration the permutation is relative to)
        let mut members: Vec<Vec<VertexId>> = vec![Vec::new(); num_parts];
        for v in 0..nv {
            let p = assignment[v] as usize;
            assert!(p < num_parts, "assignment[{v}]={p} >= num_parts");
            members[p].push(v as VertexId);
        }

        let layouts: Vec<VertexLayout> = members
            .iter()
            .map(|gids| match layout.policy {
                LayoutPolicy::Identity => VertexLayout::identity(),
                LayoutPolicy::DegreeSorted => VertexLayout::degree_sorted(gids, &degree_of),
            })
            .collect();

        // location table, written through the permutation
        let mut location = vec![(0u32, 0u32); nv];
        for (p, gids) in members.iter().enumerate() {
            for (rank, &gid) in gids.iter().enumerate() {
                location[gid as usize] = (p as u32, layouts[p].to_local(rank as u32));
            }
        }

        let mut parts: Vec<PartGraph> = members
            .iter()
            .zip(layouts)
            .enumerate()
            .map(|(p, (gids, lay))| {
                let n = gids.len();
                let mut part = PartGraph {
                    part: p as u32,
                    global_ids: Vec::with_capacity(n),
                    offsets: Vec::with_capacity(n + 1),
                    targets: Vec::new(),
                    routes: Vec::new(),
                    weights: Vec::new(),
                    is_boundary: Vec::new(),
                    out_degree: Vec::with_capacity(n),
                    layout: lay,
                    packed: Vec::new(),
                    packed_offsets: Vec::new(),
                    boundary_vertices: 0,
                    internal_edges: 0,
                };
                part.offsets.push(0);
                for local in 0..n as u32 {
                    let gid = gids[part.layout.to_natural(local) as usize];
                    part.global_ids.push(gid);
                    let edges_before = part.weights.len();
                    for_each_edge(gid, &mut |t, w| {
                        let (tp, tl) = location[t as usize];
                        part.targets.push(t);
                        part.routes.push(EdgeRoute::new(tp, tl));
                        part.weights.push(w);
                        if tp == p as u32 {
                            part.internal_edges += 1;
                        }
                    });
                    part.offsets.push(part.targets.len());
                    part.out_degree.push((part.weights.len() - edges_before) as u32);
                    part.is_boundary.push(false);
                }
                part
            })
            .collect();

        // Boundary classification: mark targets of cross-partition edges.
        // (A vertex with an in-edge from a remote partition is boundary.)
        // The same streaming pass tallies the per-partition cut-in counts
        // the routing epoch carries for O(parts) locality stats.
        let mut boundary = vec![false; nv];
        let mut cut_in = vec![0u64; num_parts];
        for part in &parts {
            for (&t, r) in part.targets.iter().zip(&part.routes) {
                if r.part() != part.part {
                    boundary[t as usize] = true;
                    cut_in[r.part() as usize] += 1;
                }
            }
        }
        for part in &mut parts {
            for (i, &gid) in part.global_ids.iter().enumerate() {
                part.is_boundary[i] = boundary[gid as usize];
            }
            part.boundary_vertices = part.is_boundary.iter().filter(|&&b| b).count();
        }

        // Compression last: boundary/count passes above stream the SoA
        // columns one final time before they are dropped.
        if layout.compress_edges {
            for part in &mut parts {
                part.compress_edges();
            }
        }

        let dg = DistGraph {
            parts,
            routing: RoutingEpoch { epoch, location, cut_in },
            num_vertices: nv,
            num_edges,
            layout,
        };
        // debug sanitizer: edge views vs location table, CSR offsets,
        // permutation bijectivity, compressed-block decode, precomputed
        // counts — validated once per construction (no-op in release)
        crate::engine::invariants::check_edge_routes(&dg);
        dg
    }

    /// The current vertex -> partition assignment, derived from the
    /// routing epoch's location table.
    pub fn assignment(&self) -> Vec<u32> {
        self.routing.location.iter().map(|&(p, _)| p).collect()
    }

    /// Global out-degree of `v`, resolved through the location table.
    #[inline]
    pub fn out_degree_of(&self, v: VertexId) -> u32 {
        let (p, l) = self.routing.location[v as usize];
        self.parts[p as usize].out_degree[l as usize]
    }

    /// Apply a [`MigrationPlan`] atomically, producing the next routing
    /// epoch: every partition and all derived routing state — location
    /// table, `EdgeRoute` columns (raw SoA or packed varint), boundary
    /// flags, precomputed counts, cut-in tallies, and the `VertexLayout`
    /// permutations — is rebuilt through the same write-through
    /// construction path `with_layout` uses, under the moved assignment.
    /// Topology is reconstructed from this graph's own partitions.
    /// Debug builds validate the plan first (`check_migration_plan`) and
    /// re-run `check_edge_routes` on the result.
    ///
    /// Engines call this only at a barrier, then remap runtime state
    /// (values, mail, frontier) old-geometry -> new-geometry before the
    /// next superstep opens.
    pub fn apply_migration(&self, plan: &MigrationPlan) -> DistGraph {
        assert_eq!(
            plan.epoch,
            self.routing.epoch + 1,
            "migration plan targets epoch {} but the graph is at epoch {}",
            plan.epoch,
            self.routing.epoch
        );
        crate::engine::invariants::check_migration_plan(self, plan);
        let mut assignment = self.assignment();
        for &(gid, to) in &plan.moves {
            assignment[gid as usize] = to;
        }
        Self::build(
            self.num_vertices,
            self.num_edges,
            &assignment,
            self.num_parts(),
            self.layout,
            plan.epoch,
            |v| self.out_degree_of(v),
            |v, emit| {
                let (p, l) = self.routing.location[v as usize];
                for e in self.parts[p as usize].out_edges(l as usize) {
                    emit(e.target, e.weight);
                }
            },
        )
    }

    /// Number of partitions (= simulated workers).
    pub fn num_parts(&self) -> usize {
        self.parts.len()
    }

    /// Total number of cross-partition edges (O(parts): derived from the
    /// precomputed internal-edge counts).
    pub fn edge_cut(&self) -> usize {
        self.parts.iter().map(|p| p.num_edges() - p.num_internal_edges()).sum()
    }

    /// Total number of boundary vertices (O(parts)).
    pub fn num_boundary(&self) -> usize {
        self.parts.iter().map(|p| p.num_boundary()).sum()
    }

    /// Bytes held by all partitions' edge columns — divided by
    /// [`num_edges`](Self::num_edges) this is the bytes/edge figure the
    /// bench report tracks across storage modes.
    pub fn edge_column_bytes(&self) -> usize {
        self.parts.iter().map(|p| p.edge_column_bytes()).sum()
    }

    /// Partition balance indicator: the largest partition's vertex count
    /// over the *mean* partition size (the METIS load-imbalance metric).
    /// 1.0 = perfectly balanced; k = all vertices in one of k partitions.
    /// Dividing by the mean rather than the smallest partition keeps the
    /// indicator finite when a partition is empty. Returns 1.0 for an
    /// empty graph.
    pub fn balance(&self) -> f64 {
        let sizes: Vec<usize> = self.parts.iter().map(|p| p.num_vertices()).collect();
        let max = *sizes.iter().max().unwrap_or(&0) as f64;
        let avg = self.num_vertices as f64 / self.num_parts() as f64;
        if avg == 0.0 {
            return 1.0;
        }
        max / avg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    fn path4() -> Graph {
        // 0 -> 1 -> 2 -> 3
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1, 1.0);
        b.add_edge(1, 2, 1.0);
        b.add_edge(2, 3, 1.0);
        b.build()
    }

    /// Every layout configuration under test: identity/degree-sorted ×
    /// uncompressed/compressed.
    fn all_layouts() -> [GraphLayout; 4] {
        [
            GraphLayout::default(),
            GraphLayout { policy: LayoutPolicy::Identity, compress_edges: true },
            GraphLayout::degree_sorted(),
            GraphLayout::packed(),
        ]
    }

    #[test]
    fn edge_route_pack_roundtrip() {
        for (p, l) in [(0u32, 0u32), (1, 0), (0, 1), (7, 123_456), (u32::MAX, u32::MAX)] {
            let r = EdgeRoute::new(p, l);
            assert_eq!(r.part(), p);
            assert_eq!(r.local(), l);
            assert_eq!(r.unpack(), (p, l));
        }
    }

    #[test]
    fn partitioning_preserves_structure() {
        let g = path4();
        let dg = DistGraph::new(&g, &[0, 0, 1, 1], 2);
        assert_eq!(dg.num_parts(), 2);
        assert_eq!(dg.parts[0].global_ids, vec![0, 1]);
        assert_eq!(dg.parts[1].global_ids, vec![2, 3]);
        assert_eq!(dg.num_edges, 3);
        assert_eq!(dg.edge_cut(), 1); // only 1 -> 2 crosses
    }

    #[test]
    fn location_indicators_resolved() {
        let g = path4();
        let dg = DistGraph::new(&g, &[0, 0, 1, 1], 2);
        let edges = dg.parts[0].out_edges(1); // edge 1 -> 2
        assert_eq!(edges.len(), 1);
        let e = edges.get(0);
        assert_eq!(e.target, 2);
        assert_eq!(e.target_part, 1);
        assert_eq!(e.target_local, 0);
        assert_eq!(e.route(), EdgeRoute::new(1, 0));
        assert_eq!(dg.routing.location[3], (1, 1));
    }

    #[test]
    fn soa_columns_agree_with_edge_views() {
        let g = path4();
        let dg = DistGraph::new(&g, &[0, 1, 0, 1], 2);
        for part in &dg.parts {
            for lv in 0..part.num_vertices() {
                let edges = part.out_edges(lv);
                assert_eq!(edges.targets().len(), edges.len());
                assert_eq!(edges.routes().len(), edges.len());
                assert_eq!(edges.weights().len(), edges.len());
                for (i, e) in edges.iter().enumerate() {
                    assert_eq!(e.target, edges.targets()[i]);
                    assert_eq!(e.route(), edges.routes()[i]);
                    assert_eq!(e.weight, edges.weights()[i]);
                    assert_eq!(dg.routing.location[e.target as usize], e.route().unpack());
                }
            }
        }
    }

    #[test]
    fn boundary_classification_def1() {
        let g = path4();
        let dg = DistGraph::new(&g, &[0, 0, 1, 1], 2);
        // vertex 2 has in-edge from partition 0 => boundary; others local
        assert!(!dg.parts[0].is_boundary[0]); // v0: no in-edges
        assert!(!dg.parts[0].is_boundary[1]); // v1: in-edge from v0, same part
        assert!(dg.parts[1].is_boundary[0]); // v2: in-edge from remote v1
        assert!(!dg.parts[1].is_boundary[1]); // v3: in-edge from v2, same part
        assert_eq!(dg.num_boundary(), 1);
    }

    #[test]
    fn precomputed_counts_match_rescans() {
        let g = crate::graph::generators::powerlaw(300, 4, 17);
        let a = crate::partition::hash_partition(&g, 5);
        let dg = DistGraph::new(&g, &a, 5);
        for p in &dg.parts {
            assert_eq!(
                p.num_boundary(),
                p.is_boundary.iter().filter(|&&b| b).count(),
                "partition {}: boundary count",
                p.part
            );
            assert_eq!(
                p.num_internal_edges(),
                p.routes.iter().filter(|r| r.part() == p.part).count(),
                "partition {}: internal edges",
                p.part
            );
        }
        let brute_cut: usize = dg
            .parts
            .iter()
            .map(|p| p.routes.iter().filter(|r| r.part() != p.part).count())
            .sum();
        assert_eq!(dg.edge_cut(), brute_cut);
    }

    #[test]
    fn single_partition_has_no_boundary() {
        let g = path4();
        let dg = DistGraph::new(&g, &[0, 0, 0, 0], 1);
        assert_eq!(dg.num_boundary(), 0);
        assert_eq!(dg.edge_cut(), 0);
        assert_eq!(dg.balance(), 1.0);
    }

    #[test]
    fn balance_reflects_skew() {
        let g = path4();
        let dg = DistGraph::new(&g, &[0, 0, 0, 1], 2);
        assert_eq!(dg.balance(), 1.5); // max 3 / avg 2
    }

    #[test]
    fn balance_is_max_over_mean_and_finite_with_empty_partition() {
        let g = path4();
        // every vertex in partition 0 of 3: max 4 / mean (4/3) = 3.0 —
        // max/min would be infinite here, max/mean stays the partition
        // count (the documented worst case)
        let dg = DistGraph::new(&g, &[0, 0, 0, 0], 3);
        assert_eq!(dg.balance(), 3.0);
        assert!(dg.balance().is_finite());
    }

    // ---- vertex layout ----

    /// A small graph with distinct out-degrees so degree sorting is
    /// observable: 0 has degree 3, 1 has 2, 2 has 1, 3-5 have 0.
    fn skewed() -> Graph {
        let mut b = GraphBuilder::new(6);
        b.add_edge(0, 1, 1.0);
        b.add_edge(0, 2, 1.0);
        b.add_edge(0, 3, 1.0);
        b.add_edge(1, 2, 1.0);
        b.add_edge(1, 4, 1.0);
        b.add_edge(2, 5, 1.0);
        b.build()
    }

    #[test]
    fn degree_sorted_layout_orders_locals_by_descending_degree() {
        let g = skewed();
        let dg = DistGraph::with_layout(&g, &[0; 6], 1, GraphLayout::degree_sorted());
        let p = &dg.parts[0];
        // local order: degree 3 (v0), 2 (v1), 1 (v2), then degree-0
        // vertices by ascending gid
        assert_eq!(p.global_ids, vec![0, 1, 2, 3, 4, 5]);
        let dg = DistGraph::with_layout(&g, &[0, 0, 0, 1, 1, 1], 2, GraphLayout::degree_sorted());
        for p in &dg.parts {
            for w in p.out_degree.windows(2) {
                assert!(w[0] >= w[1], "out_degree must be descending: {:?}", p.out_degree);
            }
        }
    }

    #[test]
    fn degree_sorted_permutation_is_a_bijection_consistent_with_location() {
        let g = crate::graph::generators::powerlaw(500, 4, 7);
        let a = crate::partition::hash_partition(&g, 6);
        let dg = DistGraph::with_layout(&g, &a, 6, GraphLayout::degree_sorted());
        for p in &dg.parts {
            let n = p.num_vertices();
            assert_eq!(p.layout.fwd.len(), n);
            assert_eq!(p.layout.inv.len(), n);
            for local in 0..n as u32 {
                assert_eq!(p.layout.to_local(p.layout.to_natural(local)), local);
            }
            for (lv, &gid) in p.global_ids.iter().enumerate() {
                assert_eq!(dg.routing.location[gid as usize], (p.part, lv as u32));
            }
        }
    }

    #[test]
    fn identity_layout_costs_no_memory() {
        let g = path4();
        let dg = DistGraph::new(&g, &[0, 0, 1, 1], 2);
        for p in &dg.parts {
            assert!(p.layout.is_identity());
            assert!(p.layout.fwd.is_empty() && p.layout.inv.is_empty());
        }
    }

    /// The structural invariant every layout must satisfy: same vertex
    /// set, same per-gid out-degree/boundary flags, same multiset of
    /// (src gid, dst gid, weight) edges, same cut and counts.
    #[test]
    fn all_layouts_describe_the_same_graph() {
        let g = crate::graph::generators::powerlaw(400, 5, 23);
        let a = crate::partition::hash_partition(&g, 5);
        let base = DistGraph::new(&g, &a, 5);
        let mut base_edges: Vec<(VertexId, VertexId, f32)> = Vec::new();
        for p in &base.parts {
            for lv in 0..p.num_vertices() {
                for e in p.out_edges(lv) {
                    base_edges.push((p.global_ids[lv], e.target, e.weight));
                }
            }
        }
        base_edges.sort_by(|x, y| x.partial_cmp(y).unwrap());
        for layout in all_layouts() {
            let dg = DistGraph::with_layout(&g, &a, 5, layout);
            assert_eq!(dg.edge_cut(), base.edge_cut(), "{layout:?}");
            assert_eq!(dg.num_boundary(), base.num_boundary(), "{layout:?}");
            let mut edges: Vec<(VertexId, VertexId, f32)> = Vec::new();
            for p in &dg.parts {
                for lv in 0..p.num_vertices() {
                    let gid = p.global_ids[lv];
                    let (lp, ll) = dg.routing.location[gid as usize];
                    assert_eq!((lp, ll), (p.part, lv as u32), "{layout:?}");
                    for e in p.out_edges(lv) {
                        // routes resolve through the (permuted) location
                        // table in every layout
                        assert_eq!(
                            dg.routing.location[e.target as usize],
                            e.route().unpack(),
                            "{layout:?}"
                        );
                        edges.push((gid, e.target, e.weight));
                    }
                }
            }
            edges.sort_by(|x, y| x.partial_cmp(y).unwrap());
            assert_eq!(edges, base_edges, "{layout:?}");
        }
    }

    // ---- compressed edge columns ----

    #[test]
    fn compressed_decode_roundtrips_against_soa() {
        let g = crate::graph::generators::powerlaw(600, 6, 99);
        let a = crate::partition::hash_partition(&g, 7);
        for policy in [LayoutPolicy::Identity, LayoutPolicy::DegreeSorted] {
            let soa = DistGraph::with_layout(
                &g,
                &a,
                7,
                GraphLayout { policy, compress_edges: false },
            );
            let packed = DistGraph::with_layout(
                &g,
                &a,
                7,
                GraphLayout { policy, compress_edges: true },
            );
            for (ps, pp) in soa.parts.iter().zip(&packed.parts) {
                assert!(!ps.is_compressed());
                assert!(pp.is_compressed());
                assert!(pp.targets.is_empty() && pp.routes.is_empty());
                assert_eq!(ps.global_ids, pp.global_ids);
                assert_eq!(ps.num_edges(), pp.num_edges());
                for lv in 0..ps.num_vertices() {
                    let a = ps.out_edges(lv);
                    let b = pp.out_edges(lv);
                    assert_eq!(a.len(), b.len());
                    // full edge views decode identically, in order
                    let av: Vec<_> =
                        a.iter().map(|e| (e.target, e.route(), e.weight)).collect();
                    let bv: Vec<_> =
                        b.iter().map(|e| (e.target, e.route(), e.weight)).collect();
                    assert_eq!(av, bv, "part {} lv {lv}", ps.part);
                    // the route-only stream matches the route column
                    let ar: Vec<_> = a.route_iter().collect();
                    let br: Vec<_> = b.route_iter().collect();
                    assert_eq!(ar, br, "part {} lv {lv}", ps.part);
                    // random access decodes the same edges
                    if b.len() > 0 {
                        let e = b.get(b.len() - 1);
                        assert_eq!(e.target, av[av.len() - 1].0);
                    }
                }
            }
        }
    }

    #[test]
    fn compression_shrinks_local_heavy_partitions() {
        // single partition: every edge is same-partition, so each edge
        // costs a 1-2 byte varint instead of 12 bytes of SoA columns
        let g = crate::graph::generators::powerlaw(2_000, 8, 3);
        let soa = DistGraph::new(&g, &vec![0; 2_000], 1);
        let packed = DistGraph::with_layout(
            &g,
            &vec![0; 2_000],
            1,
            GraphLayout { policy: LayoutPolicy::Identity, compress_edges: true },
        );
        assert!(
            packed.edge_column_bytes() < soa.edge_column_bytes() / 2,
            "packed {} vs soa {}",
            packed.edge_column_bytes(),
            soa.edge_column_bytes()
        );
    }

    #[test]
    fn compressed_weights_stay_directly_addressable() {
        let g = skewed();
        let dg = DistGraph::with_layout(&g, &[0; 6], 1, GraphLayout::packed());
        let p = &dg.parts[0];
        for lv in 0..p.num_vertices() {
            let e = p.out_edges(lv);
            assert_eq!(e.weights().len(), e.len());
        }
    }

    // ---- routing epochs & migration ----

    /// Sorted (src gid, dst gid, weight) edge multiset of a DistGraph —
    /// the layout/epoch-independent description of the topology.
    fn edge_multiset(dg: &DistGraph) -> Vec<(VertexId, VertexId, f32)> {
        let mut es = Vec::new();
        for p in &dg.parts {
            for lv in 0..p.num_vertices() {
                for e in p.out_edges(lv) {
                    es.push((p.global_ids[lv], e.target, e.weight));
                }
            }
        }
        es.sort_by(|x, y| x.partial_cmp(y).unwrap());
        es
    }

    #[test]
    fn apply_migration_rebuilds_routing_and_bumps_epoch() {
        let g = crate::graph::generators::powerlaw(300, 4, 11);
        let a = crate::partition::hash_partition(&g, 4);
        for layout in all_layouts() {
            let dg = DistGraph::with_layout(&g, &a, 4, layout);
            assert_eq!(dg.routing.epoch, 0, "{layout:?}");
            // move the first 10 vertices of partition 0 to partition 1
            let mut moves: Vec<(VertexId, u32)> =
                dg.parts[0].global_ids.iter().take(10).map(|&gid| (gid, 1u32)).collect();
            moves.sort_unstable();
            let plan = MigrationPlan { epoch: 1, moves: moves.clone() };
            // apply_migration re-runs check_edge_routes internally, so a
            // successful return already validates the rebuilt routes
            let m = dg.apply_migration(&plan);
            assert_eq!(m.routing.epoch, 1, "{layout:?}");
            assert_eq!(m.num_vertices, dg.num_vertices);
            assert_eq!(m.num_edges, dg.num_edges);
            assert_eq!(m.parts[0].num_vertices(), dg.parts[0].num_vertices() - 10);
            for &(gid, to) in &moves {
                assert_eq!(m.routing.location[gid as usize].0, to, "{layout:?}");
            }
            let moved: std::collections::HashSet<VertexId> =
                moves.iter().map(|&(gid, _)| gid).collect();
            for v in 0..dg.num_vertices {
                if !moved.contains(&(v as VertexId)) {
                    assert_eq!(
                        m.routing.location[v].0,
                        dg.routing.location[v].0,
                        "unmoved vertex {v} changed partition ({layout:?})"
                    );
                }
            }
            // topology is preserved as an edge multiset
            assert_eq!(edge_multiset(&m), edge_multiset(&dg), "{layout:?}");
            // chained migration keeps bumping the epoch
            let back = MigrationPlan { epoch: 2, moves: moves.iter().map(|&(gid, _)| (gid, 0)).collect() };
            let m2 = m.apply_migration(&back);
            assert_eq!(m2.routing.epoch, 2, "{layout:?}");
            assert_eq!(m2.assignment(), dg.assignment(), "{layout:?}");
        }
    }

    #[test]
    fn cut_in_tallies_match_route_rescan() {
        let g = crate::graph::generators::powerlaw(400, 5, 31);
        let a = crate::partition::hash_partition(&g, 5);
        let dg = DistGraph::new(&g, &a, 5);
        let mut expect = vec![0u64; 5];
        for p in &dg.parts {
            for lv in 0..p.num_vertices() {
                for r in p.out_edges(lv).route_iter() {
                    if r.part() != p.part {
                        expect[r.part() as usize] += 1;
                    }
                }
            }
        }
        assert_eq!(dg.routing.cut_in, expect);
        assert_eq!(dg.routing.cut_in.iter().sum::<u64>() as usize, dg.edge_cut());
    }

    #[test]
    fn migration_plan_codec_roundtrips() {
        let plan = MigrationPlan { epoch: 3, moves: vec![(1, 2), (7, 0), (9, 4)] };
        let mut buf = Vec::new();
        plan.encode(&mut buf);
        assert_eq!(buf.len(), plan.encoded_len());
        let mut r = &buf[..];
        assert_eq!(MigrationPlan::decode(&mut r), Some(plan));
        assert!(r.is_empty());
        let mut r = &buf[..buf.len() - 1];
        assert_eq!(MigrationPlan::decode(&mut r), None);
    }

    #[test]
    #[should_panic(expected = "migration plan targets epoch")]
    fn apply_migration_rejects_wrong_epoch() {
        let g = path4();
        let dg = DistGraph::new(&g, &[0, 0, 1, 1], 2);
        let plan = MigrationPlan { epoch: 5, moves: vec![(0, 1)] };
        let _ = dg.apply_migration(&plan);
    }

    #[test]
    fn empty_migration_is_an_epoch_bump() {
        let g = path4();
        let dg = DistGraph::new(&g, &[0, 0, 1, 1], 2);
        let m = dg.apply_migration(&MigrationPlan { epoch: 1, moves: vec![] });
        assert_eq!(m.routing.epoch, 1);
        assert_eq!(m.routing.location, dg.routing.location);
        assert_eq!(m.routing.cut_in, dg.routing.cut_in);
        assert_eq!(m.edge_cut(), dg.edge_cut());
    }
}
