//! Distributed (partitioned) view of a graph.
//!
//! [`DistGraph`] is built once from a [`Graph`] + a partition assignment
//! and is what every engine executes over: each [`PartGraph`] is the
//! read-only topology a worker owns, shared immutably across the
//! parallel worker threads (`Parallelism::Threads`) while all mutable
//! per-partition state lives in the engines' runtimes. It precomputes
//! exactly the metadata the paper's platform keeps per worker (§5.1):
//!
//! - each vertex's partition and partition-local index;
//! - per-edge location indicators (same-partition target + its local
//!   index, or remote partition), packed as one-word [`EdgeRoute`]s;
//! - the local/boundary classification of Definition 1: a vertex is
//!   **boundary** iff it has at least one in-edge whose source lives in
//!   a different partition, else **local**. This is a static property of
//!   the partitioning — engines (including the adaptive scheduler's
//!   per-partition boundary decisions) consult it but never change it;
//! - the per-partition boundary-vertex and internal-edge counts, so the
//!   telemetry/stats queries on barrier paths are O(1) instead of
//!   rescanning the partition.
//!
//! # Edge storage: structure-of-arrays
//!
//! A partition's out-edges live in three parallel arrays —
//! [`PartGraph::targets`], [`PartGraph::routes`], [`PartGraph::weights`]
//! — instead of one `Vec` of 16-byte edge records. The per-vertex sweep
//! loop is the platform's hottest code (it runs once per vertex per
//! pseudo-superstep), and its dominant consumers each touch only a
//! subset of the edge fields: `send_to_neighbors` streams routes alone,
//! weight-less programs (PageRank, WCC) never load `weights`, and the
//! partition-stats passes read only `routes`. The SoA split lets each
//! consumer stream exactly the words it needs. [`PartGraph::out_edges`]
//! still hands out an [`Edge`]-view iterator so edge-generic code reads
//! as before.

use super::csr::{Graph, VertexId};

/// Packed location indicator of an edge target (§5.1): the destination
/// partition in the high 32 bits, the destination's partition-local
/// index in the low 32. One aligned load resolves a message route with
/// no global-table lookup.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct EdgeRoute(u64);

impl EdgeRoute {
    /// Pack a `(partition, local index)` pair.
    #[inline]
    pub fn new(part: u32, local: u32) -> Self {
        EdgeRoute(((part as u64) << 32) | local as u64)
    }

    /// Destination partition.
    #[inline]
    pub fn part(self) -> u32 {
        (self.0 >> 32) as u32
    }

    /// Destination's index within its partition's vertex array.
    #[inline]
    pub fn local(self) -> u32 {
        self.0 as u32
    }

    /// Unpack into `(partition, local index)`.
    #[inline]
    pub fn unpack(self) -> (u32, u32) {
        (self.part(), self.local())
    }
}

/// One out-edge inside a partition, with the location indicator
/// resolved — the *view* type assembled on demand from the SoA arrays
/// ([`PartGraph::targets`] / [`PartGraph::routes`] /
/// [`PartGraph::weights`]) by [`Edges`].
#[derive(Clone, Copy, Debug)]
pub struct Edge {
    /// Global id of the target vertex.
    pub target: VertexId,
    /// Partition holding the target.
    pub target_part: u32,
    /// Target's index within its partition's vertex array.
    pub target_local: u32,
    /// Edge weight.
    pub weight: f32,
}

impl Edge {
    /// The edge's packed location indicator.
    #[inline]
    pub fn route(&self) -> EdgeRoute {
        EdgeRoute::new(self.target_part, self.target_local)
    }
}

/// Borrowed view of one vertex's out-edges over the SoA arrays.
///
/// Iterates as [`Edge`] values (`for e in part.out_edges(lv)` or
/// `.iter()`); the raw [`targets`](Self::targets),
/// [`routes`](Self::routes) and [`weights`](Self::weights) slices are
/// exposed so hot paths can stream only the columns they touch.
#[derive(Clone, Copy, Debug)]
pub struct Edges<'a> {
    targets: &'a [VertexId],
    routes: &'a [EdgeRoute],
    weights: &'a [f32],
}

impl<'a> Edges<'a> {
    /// Number of edges in the view.
    #[inline]
    pub fn len(&self) -> usize {
        self.targets.len()
    }

    /// True when the vertex has no out-edges.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.targets.is_empty()
    }

    /// Assemble the `i`-th edge view (panics if out of range).
    #[inline]
    pub fn get(&self, i: usize) -> Edge {
        let r = self.routes[i];
        Edge {
            target: self.targets[i],
            target_part: r.part(),
            target_local: r.local(),
            weight: self.weights[i],
        }
    }

    /// Global target ids (the `targets` column).
    #[inline]
    pub fn targets(&self) -> &'a [VertexId] {
        self.targets
    }

    /// Packed location indicators (the `routes` column).
    #[inline]
    pub fn routes(&self) -> &'a [EdgeRoute] {
        self.routes
    }

    /// Edge weights (the `weights` column).
    #[inline]
    pub fn weights(&self) -> &'a [f32] {
        self.weights
    }

    /// Iterate the edges as assembled [`Edge`] views.
    #[inline]
    pub fn iter(&self) -> EdgesIter<'a> {
        EdgesIter {
            targets: self.targets.iter(),
            routes: self.routes.iter(),
            weights: self.weights.iter(),
        }
    }
}

impl<'a> IntoIterator for Edges<'a> {
    type Item = Edge;
    type IntoIter = EdgesIter<'a>;

    fn into_iter(self) -> EdgesIter<'a> {
        self.iter()
    }
}

/// Iterator over an [`Edges`] view, yielding [`Edge`] values assembled
/// from the parallel columns.
pub struct EdgesIter<'a> {
    targets: std::slice::Iter<'a, VertexId>,
    routes: std::slice::Iter<'a, EdgeRoute>,
    weights: std::slice::Iter<'a, f32>,
}

impl Iterator for EdgesIter<'_> {
    type Item = Edge;

    #[inline]
    fn next(&mut self) -> Option<Edge> {
        let &target = self.targets.next()?;
        let &route = self.routes.next().expect("routes column in sync");
        let &weight = self.weights.next().expect("weights column in sync");
        Some(Edge {
            target,
            target_part: route.part(),
            target_local: route.local(),
            weight,
        })
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        self.targets.size_hint()
    }
}

impl ExactSizeIterator for EdgesIter<'_> {}

/// One partition of the distributed graph (the unit a worker owns).
#[derive(Clone, Debug)]
pub struct PartGraph {
    /// This partition's id.
    pub part: u32,
    /// Global ids of the vertices owned by this partition.
    pub global_ids: Vec<VertexId>,
    /// CSR offsets over the edge columns, indexed by local vertex index.
    pub offsets: Vec<usize>,
    /// Global target id of every out-edge (SoA column).
    pub targets: Vec<VertexId>,
    /// Packed location indicator of every out-edge (SoA column).
    pub routes: Vec<EdgeRoute>,
    /// Weight of every out-edge (SoA column).
    pub weights: Vec<f32>,
    /// Definition 1 classification: `true` iff the vertex has an in-edge
    /// from another partition.
    pub is_boundary: Vec<bool>,
    /// Global out-degree of each owned vertex (same as local CSR degree,
    /// kept for O(1) access in vertex programs).
    pub out_degree: Vec<u32>,
    /// Precomputed count of `true` entries in `is_boundary`.
    boundary_vertices: usize,
    /// Precomputed count of edges whose target stays in this partition.
    internal_edges: usize,
}

impl PartGraph {
    /// Vertices owned by this partition.
    pub fn num_vertices(&self) -> usize {
        self.global_ids.len()
    }

    /// Out-edges of owned vertices (internal + cut).
    pub fn num_edges(&self) -> usize {
        self.targets.len()
    }

    /// Out-edges of local vertex `lv` as a SoA view.
    #[inline]
    pub fn out_edges(&self, lv: usize) -> Edges<'_> {
        let (s, e) = (self.offsets[lv], self.offsets[lv + 1]);
        Edges {
            targets: &self.targets[s..e],
            routes: &self.routes[s..e],
            weights: &self.weights[s..e],
        }
    }

    /// Number of boundary vertices — precomputed at
    /// [`DistGraph::new`] time, O(1).
    pub fn num_boundary(&self) -> usize {
        self.boundary_vertices
    }

    /// Number of internal (same-partition) edges — precomputed at
    /// [`DistGraph::new`] time, O(1).
    pub fn num_internal_edges(&self) -> usize {
        self.internal_edges
    }
}

/// The fully-resolved distributed graph.
#[derive(Clone, Debug)]
pub struct DistGraph {
    /// Per-partition subgraphs, indexed by partition id — the read-only
    /// unit each parallel worker owns.
    pub parts: Vec<PartGraph>,
    /// Global vertex id -> (partition, local index).
    pub location: Vec<(u32, u32)>,
    /// Total vertex count.
    pub num_vertices: usize,
    /// Total edge count.
    pub num_edges: usize,
}

impl DistGraph {
    /// Partition `g` according to `assignment` (vertex -> partition id,
    /// all values < `num_parts`). Vertices keep their relative order
    /// within a partition.
    pub fn new(g: &Graph, assignment: &[u32], num_parts: usize) -> DistGraph {
        let nv = g.num_vertices();
        assert_eq!(assignment.len(), nv, "assignment length != num vertices");
        assert!(num_parts > 0);

        // location table
        let mut location = vec![(0u32, 0u32); nv];
        let mut counts = vec![0u32; num_parts];
        for v in 0..nv {
            let p = assignment[v] as usize;
            assert!(p < num_parts, "assignment[{v}]={p} >= num_parts");
            location[v] = (p as u32, counts[p]);
            counts[p] += 1;
        }

        let mut parts: Vec<PartGraph> = (0..num_parts)
            .map(|p| PartGraph {
                part: p as u32,
                global_ids: Vec::with_capacity(counts[p] as usize),
                offsets: vec![0],
                targets: Vec::new(),
                routes: Vec::new(),
                weights: Vec::new(),
                is_boundary: Vec::new(),
                out_degree: Vec::new(),
                boundary_vertices: 0,
                internal_edges: 0,
            })
            .collect();

        for v in 0..nv as VertexId {
            let (p, _) = location[v as usize];
            let part = &mut parts[p as usize];
            part.global_ids.push(v);
            let (ts, ws) = g.out_edges(v);
            for (&t, &w) in ts.iter().zip(ws) {
                let (tp, tl) = location[t as usize];
                part.targets.push(t);
                part.routes.push(EdgeRoute::new(tp, tl));
                part.weights.push(w);
                if tp == p {
                    part.internal_edges += 1;
                }
            }
            part.offsets.push(part.targets.len());
            part.out_degree.push(ts.len() as u32);
            part.is_boundary.push(false);
        }

        // Boundary classification: mark targets of cross-partition edges.
        // (A vertex with an in-edge from a remote partition is boundary.)
        let mut boundary = vec![false; nv];
        for part in &parts {
            for (&t, r) in part.targets.iter().zip(&part.routes) {
                if r.part() != part.part {
                    boundary[t as usize] = true;
                }
            }
        }
        for part in &mut parts {
            for (i, &gid) in part.global_ids.iter().enumerate() {
                part.is_boundary[i] = boundary[gid as usize];
            }
            part.boundary_vertices = part.is_boundary.iter().filter(|&&b| b).count();
        }

        let dg = DistGraph { parts, location, num_vertices: nv, num_edges: g.num_edges() };
        // debug sanitizer: EdgeRoute columns vs location table, CSR
        // offsets, precomputed counts — validated once per construction
        // (no-op in release builds)
        crate::engine::invariants::check_edge_routes(&dg);
        dg
    }

    /// Number of partitions (= simulated workers).
    pub fn num_parts(&self) -> usize {
        self.parts.len()
    }

    /// Total number of cross-partition edges (O(parts): derived from the
    /// precomputed internal-edge counts).
    pub fn edge_cut(&self) -> usize {
        self.parts.iter().map(|p| p.num_edges() - p.num_internal_edges()).sum()
    }

    /// Total number of boundary vertices (O(parts)).
    pub fn num_boundary(&self) -> usize {
        self.parts.iter().map(|p| p.num_boundary()).sum()
    }

    /// Partition balance indicator: the largest partition's vertex count
    /// over the *mean* partition size (the METIS load-imbalance metric).
    /// 1.0 = perfectly balanced; k = all vertices in one of k partitions.
    /// Dividing by the mean rather than the smallest partition keeps the
    /// indicator finite when a partition is empty. Returns 1.0 for an
    /// empty graph.
    pub fn balance(&self) -> f64 {
        let sizes: Vec<usize> = self.parts.iter().map(|p| p.num_vertices()).collect();
        let max = *sizes.iter().max().unwrap_or(&0) as f64;
        let avg = self.num_vertices as f64 / self.num_parts() as f64;
        if avg == 0.0 {
            return 1.0;
        }
        max / avg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    fn path4() -> Graph {
        // 0 -> 1 -> 2 -> 3
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1, 1.0);
        b.add_edge(1, 2, 1.0);
        b.add_edge(2, 3, 1.0);
        b.build()
    }

    #[test]
    fn edge_route_pack_roundtrip() {
        for (p, l) in [(0u32, 0u32), (1, 0), (0, 1), (7, 123_456), (u32::MAX, u32::MAX)] {
            let r = EdgeRoute::new(p, l);
            assert_eq!(r.part(), p);
            assert_eq!(r.local(), l);
            assert_eq!(r.unpack(), (p, l));
        }
    }

    #[test]
    fn partitioning_preserves_structure() {
        let g = path4();
        let dg = DistGraph::new(&g, &[0, 0, 1, 1], 2);
        assert_eq!(dg.num_parts(), 2);
        assert_eq!(dg.parts[0].global_ids, vec![0, 1]);
        assert_eq!(dg.parts[1].global_ids, vec![2, 3]);
        assert_eq!(dg.num_edges, 3);
        assert_eq!(dg.edge_cut(), 1); // only 1 -> 2 crosses
    }

    #[test]
    fn location_indicators_resolved() {
        let g = path4();
        let dg = DistGraph::new(&g, &[0, 0, 1, 1], 2);
        let edges = dg.parts[0].out_edges(1); // edge 1 -> 2
        assert_eq!(edges.len(), 1);
        let e = edges.get(0);
        assert_eq!(e.target, 2);
        assert_eq!(e.target_part, 1);
        assert_eq!(e.target_local, 0);
        assert_eq!(e.route(), EdgeRoute::new(1, 0));
        assert_eq!(dg.location[3], (1, 1));
    }

    #[test]
    fn soa_columns_agree_with_edge_views() {
        let g = path4();
        let dg = DistGraph::new(&g, &[0, 1, 0, 1], 2);
        for part in &dg.parts {
            for lv in 0..part.num_vertices() {
                let edges = part.out_edges(lv);
                assert_eq!(edges.targets().len(), edges.len());
                assert_eq!(edges.routes().len(), edges.len());
                assert_eq!(edges.weights().len(), edges.len());
                for (i, e) in edges.iter().enumerate() {
                    assert_eq!(e.target, edges.targets()[i]);
                    assert_eq!(e.route(), edges.routes()[i]);
                    assert_eq!(e.weight, edges.weights()[i]);
                    assert_eq!(dg.location[e.target as usize], e.route().unpack());
                }
            }
        }
    }

    #[test]
    fn boundary_classification_def1() {
        let g = path4();
        let dg = DistGraph::new(&g, &[0, 0, 1, 1], 2);
        // vertex 2 has in-edge from partition 0 => boundary; others local
        assert!(!dg.parts[0].is_boundary[0]); // v0: no in-edges
        assert!(!dg.parts[0].is_boundary[1]); // v1: in-edge from v0, same part
        assert!(dg.parts[1].is_boundary[0]); // v2: in-edge from remote v1
        assert!(!dg.parts[1].is_boundary[1]); // v3: in-edge from v2, same part
        assert_eq!(dg.num_boundary(), 1);
    }

    #[test]
    fn precomputed_counts_match_rescans() {
        let g = crate::graph::generators::powerlaw(300, 4, 17);
        let a = crate::partition::hash_partition(&g, 5);
        let dg = DistGraph::new(&g, &a, 5);
        for p in &dg.parts {
            assert_eq!(
                p.num_boundary(),
                p.is_boundary.iter().filter(|&&b| b).count(),
                "partition {}: boundary count",
                p.part
            );
            assert_eq!(
                p.num_internal_edges(),
                p.routes.iter().filter(|r| r.part() == p.part).count(),
                "partition {}: internal edges",
                p.part
            );
        }
        let brute_cut: usize = dg
            .parts
            .iter()
            .map(|p| p.routes.iter().filter(|r| r.part() != p.part).count())
            .sum();
        assert_eq!(dg.edge_cut(), brute_cut);
    }

    #[test]
    fn single_partition_has_no_boundary() {
        let g = path4();
        let dg = DistGraph::new(&g, &[0, 0, 0, 0], 1);
        assert_eq!(dg.num_boundary(), 0);
        assert_eq!(dg.edge_cut(), 0);
        assert_eq!(dg.balance(), 1.0);
    }

    #[test]
    fn balance_reflects_skew() {
        let g = path4();
        let dg = DistGraph::new(&g, &[0, 0, 0, 1], 2);
        assert_eq!(dg.balance(), 1.5); // max 3 / avg 2
    }

    #[test]
    fn balance_is_max_over_mean_and_finite_with_empty_partition() {
        let g = path4();
        // every vertex in partition 0 of 3: max 4 / mean (4/3) = 3.0 —
        // max/min would be infinite here, max/mean stays the partition
        // count (the documented worst case)
        let dg = DistGraph::new(&g, &[0, 0, 0, 0], 3);
        assert_eq!(dg.balance(), 3.0);
        assert!(dg.balance().is_finite());
    }
}
