//! Compressed-sparse-row directed graph with f32 edge weights.
//!
//! This is the BSP data model of the paper §3: a directed graph whose
//! edges are associated with source vertices (adjacency lists of
//! out-edges). Vertex/edge *state* lives in the engines; this structure is
//! immutable topology.

/// Vertex identifier. The paper's datasets peak at ~24 M vertices; u32 is
/// plenty and halves the memory of adjacency storage.
pub type VertexId = u32;

/// Immutable directed graph in CSR form.
#[derive(Clone, Debug, PartialEq)]
pub struct Graph {
    /// `offsets[v]..offsets[v+1]` indexes `targets`/`weights` for v's
    /// out-edges. `offsets.len() == num_vertices() + 1`.
    pub offsets: Vec<usize>,
    /// Out-edge target vertices, grouped by source.
    pub targets: Vec<VertexId>,
    /// Out-edge weights, parallel to `targets`.
    pub weights: Vec<f32>,
}

impl Graph {
    /// Number of vertices.
    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of directed edges.
    pub fn num_edges(&self) -> usize {
        self.targets.len()
    }

    /// Out-degree of `v`.
    pub fn out_degree(&self, v: VertexId) -> usize {
        self.offsets[v as usize + 1] - self.offsets[v as usize]
    }

    /// Out-edges of `v` as parallel (targets, weights) slices.
    pub fn out_edges(&self, v: VertexId) -> (&[VertexId], &[f32]) {
        let (s, e) = (self.offsets[v as usize], self.offsets[v as usize + 1]);
        (&self.targets[s..e], &self.weights[s..e])
    }

    /// In-degrees of all vertices (one O(E) pass).
    pub fn in_degrees(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.num_vertices()];
        for &t in &self.targets {
            deg[t as usize] += 1;
        }
        deg
    }

    /// Reverse graph (all edges flipped), preserving weights.
    pub fn reversed(&self) -> Graph {
        let nv = self.num_vertices();
        let mut offsets = vec![0usize; nv + 1];
        for &t in &self.targets {
            offsets[t as usize + 1] += 1;
        }
        for i in 0..nv {
            offsets[i + 1] += offsets[i];
        }
        let mut pos = offsets.clone();
        let mut targets = vec![0 as VertexId; self.num_edges()];
        let mut weights = vec![0f32; self.num_edges()];
        for v in 0..nv as VertexId {
            let (ts, ws) = self.out_edges(v);
            for (&t, &w) in ts.iter().zip(ws) {
                let p = pos[t as usize];
                targets[p] = v;
                weights[p] = w;
                pos[t as usize] += 1;
            }
        }
        Graph { offsets, targets, weights }
    }

    /// Structural validation: monotone offsets, in-range targets.
    /// Used by tests and after deserialization.
    pub fn validate(&self) -> Result<(), String> {
        if self.offsets.is_empty() {
            return Err("empty offsets".into());
        }
        if self.offsets[0] != 0 {
            return Err("offsets[0] != 0".into());
        }
        if *self.offsets.last().unwrap() != self.targets.len() {
            return Err("last offset != num edges".into());
        }
        if self.targets.len() != self.weights.len() {
            return Err("targets/weights length mismatch".into());
        }
        for w in self.offsets.windows(2) {
            if w[0] > w[1] {
                return Err("offsets not monotone".into());
            }
        }
        let nv = self.num_vertices() as VertexId;
        for &t in &self.targets {
            if t >= nv {
                return Err(format!("target {t} out of range (nv={nv})"));
            }
        }
        Ok(())
    }

    /// Total weight of all edges.
    pub fn total_weight(&self) -> f64 {
        self.weights.iter().map(|&w| w as f64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    fn diamond() -> Graph {
        // 0 -> 1 (1.0), 0 -> 2 (2.0), 1 -> 3 (3.0), 2 -> 3 (4.0)
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1, 1.0);
        b.add_edge(0, 2, 2.0);
        b.add_edge(1, 3, 3.0);
        b.add_edge(2, 3, 4.0);
        b.build()
    }

    #[test]
    fn basic_accessors() {
        let g = diamond();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.out_degree(0), 2);
        assert_eq!(g.out_degree(3), 0);
        let (ts, ws) = g.out_edges(0);
        assert_eq!(ts, &[1, 2]);
        assert_eq!(ws, &[1.0, 2.0]);
        g.validate().unwrap();
    }

    #[test]
    fn in_degrees() {
        let g = diamond();
        assert_eq!(g.in_degrees(), vec![0, 1, 1, 2]);
    }

    #[test]
    fn reversed_roundtrip() {
        let g = diamond();
        let r = g.reversed();
        r.validate().unwrap();
        assert_eq!(r.num_edges(), g.num_edges());
        assert_eq!(r.in_degrees(), vec![2, 1, 1, 0]);
        // reversing twice restores the edge multiset
        let rr = r.reversed();
        let mut a: Vec<_> = (0..4u32)
            .flat_map(|v| {
                let (ts, ws) = g.out_edges(v);
                ts.iter().zip(ws).map(move |(&t, &w)| (v, t, w as u32)).collect::<Vec<_>>()
            })
            .collect();
        let mut b: Vec<_> = (0..4u32)
            .flat_map(|v| {
                let (ts, ws) = rr.out_edges(v);
                ts.iter().zip(ws).map(move |(&t, &w)| (v, t, w as u32)).collect::<Vec<_>>()
            })
            .collect();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn validate_catches_corruption() {
        let mut g = diamond();
        g.targets[0] = 99;
        assert!(g.validate().is_err());
        let mut g = diamond();
        g.offsets[1] = 10;
        assert!(g.validate().is_err());
    }
}
