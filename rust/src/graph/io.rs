//! Graph I/O: a text edge-list format (interoperable, debuggable) and a
//! compact binary format (fast reload for the larger bench graphs).

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::builder::GraphBuilder;
use super::csr::{Graph, VertexId};

/// Write `src dst weight` lines, preceded by a `# vertices edges` header.
pub fn write_edge_list(g: &Graph, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "# {} {}", g.num_vertices(), g.num_edges())?;
    for v in 0..g.num_vertices() as VertexId {
        let (ts, ws) = g.out_edges(v);
        for (&t, &wt) in ts.iter().zip(ws) {
            writeln!(w, "{v} {t} {wt}")?;
        }
    }
    Ok(())
}

/// Read the format written by [`write_edge_list`]. Also accepts headerless
/// files (vertex count inferred as max id + 1, weights default 1.0).
pub fn read_edge_list(path: &Path) -> Result<Graph> {
    let r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut edges: Vec<(VertexId, VertexId, f32)> = Vec::new();
    let mut declared_nv: Option<usize> = None;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            let mut it = rest.split_whitespace();
            if let (Some(nv), Some(_ne)) = (it.next(), it.next()) {
                declared_nv = Some(nv.parse().context("header vertex count")?);
            }
            continue;
        }
        let mut it = line.split_whitespace();
        let s: VertexId = match it.next() {
            Some(x) => x.parse().with_context(|| format!("line {}", lineno + 1))?,
            None => continue,
        };
        let t: VertexId = it
            .next()
            .with_context(|| format!("line {}: missing target", lineno + 1))?
            .parse()?;
        let w: f32 = match it.next() {
            Some(x) => x.parse()?,
            None => 1.0,
        };
        edges.push((s, t, w));
    }
    let nv = declared_nv.unwrap_or_else(|| {
        edges.iter().map(|&(s, t, _)| s.max(t) as usize + 1).max().unwrap_or(0)
    });
    let mut b = GraphBuilder::with_capacity(nv, edges.len());
    for (s, t, w) in edges {
        b.add_edge(s, t, w);
    }
    let g = b.build();
    g.validate().map_err(|e| anyhow::anyhow!(e))?;
    Ok(g)
}

const BIN_MAGIC: &[u8; 8] = b"GRAPHHP1";

/// Compact binary format: magic, counts, then raw LE arrays.
pub fn write_binary(g: &Graph, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&(g.num_vertices() as u64).to_le_bytes())?;
    w.write_all(&(g.num_edges() as u64).to_le_bytes())?;
    for &o in &g.offsets {
        w.write_all(&(o as u64).to_le_bytes())?;
    }
    for &t in &g.targets {
        w.write_all(&t.to_le_bytes())?;
    }
    for &wt in &g.weights {
        w.write_all(&wt.to_le_bytes())?;
    }
    Ok(())
}

/// Read the format written by [`write_binary`].
pub fn read_binary(path: &Path) -> Result<Graph> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != BIN_MAGIC {
        bail!("bad magic: not a graphhp binary graph");
    }
    let mut u64buf = [0u8; 8];
    r.read_exact(&mut u64buf)?;
    let nv = u64::from_le_bytes(u64buf) as usize;
    r.read_exact(&mut u64buf)?;
    let ne = u64::from_le_bytes(u64buf) as usize;
    let mut offsets = Vec::with_capacity(nv + 1);
    for _ in 0..=nv {
        r.read_exact(&mut u64buf)?;
        offsets.push(u64::from_le_bytes(u64buf) as usize);
    }
    let mut u32buf = [0u8; 4];
    let mut targets = Vec::with_capacity(ne);
    for _ in 0..ne {
        r.read_exact(&mut u32buf)?;
        targets.push(u32::from_le_bytes(u32buf));
    }
    let mut weights = Vec::with_capacity(ne);
    for _ in 0..ne {
        r.read_exact(&mut u32buf)?;
        weights.push(f32::from_le_bytes(u32buf));
    }
    let g = Graph { offsets, targets, weights };
    g.validate().map_err(|e| anyhow::anyhow!(e))?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn edge_list_roundtrip() {
        let g = generators::erdos_renyi(50, 200, 1);
        let dir = std::env::temp_dir().join("graphhp_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.txt");
        write_edge_list(&g, &p).unwrap();
        let g2 = read_edge_list(&p).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn binary_roundtrip() {
        let g = generators::powerlaw(300, 4, 2);
        let dir = std::env::temp_dir().join("graphhp_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.bin");
        write_binary(&g, &p).unwrap();
        let g2 = read_binary(&p).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn headerless_edge_list_parses() {
        let dir = std::env::temp_dir().join("graphhp_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("plain.txt");
        std::fs::write(&p, "0 1\n1 2 2.5\n\n2 0 1.5\n").unwrap();
        let g = read_edge_list(&p).unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.out_edges(0).1, &[1.0]);
        assert_eq!(g.out_edges(1).1, &[2.5]);
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("graphhp_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"NOTMAGIC garbage").unwrap();
        assert!(read_binary(&p).is_err());
    }
}
