//! Edge-list accumulator that sorts into CSR.

use super::csr::{Graph, VertexId};

/// Accumulates (src, dst, weight) triples and builds a [`Graph`].
pub struct GraphBuilder {
    num_vertices: usize,
    edges: Vec<(VertexId, VertexId, f32)>,
}

impl GraphBuilder {
    /// A builder for a graph of `num_vertices` vertices, no edges yet.
    pub fn new(num_vertices: usize) -> Self {
        GraphBuilder { num_vertices, edges: Vec::new() }
    }

    /// [`GraphBuilder::new`] with edge capacity pre-reserved.
    pub fn with_capacity(num_vertices: usize, num_edges: usize) -> Self {
        GraphBuilder { num_vertices, edges: Vec::with_capacity(num_edges) }
    }

    /// Add a directed edge. Panics if an endpoint is out of range.
    pub fn add_edge(&mut self, src: VertexId, dst: VertexId, weight: f32) {
        assert!((src as usize) < self.num_vertices, "src {src} out of range");
        assert!((dst as usize) < self.num_vertices, "dst {dst} out of range");
        self.edges.push((src, dst, weight));
    }

    /// Add both directions with the same weight.
    pub fn add_undirected(&mut self, a: VertexId, b: VertexId, weight: f32) {
        self.add_edge(a, b, weight);
        self.add_edge(b, a, weight);
    }

    /// Edges accumulated so far.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Drop exact duplicate (src, dst) pairs, keeping the first weight.
    pub fn dedup(&mut self) {
        self.edges.sort_by_key(|&(s, d, _)| (s, d));
        self.edges.dedup_by_key(|&mut (s, d, _)| (s, d));
    }

    /// Build the CSR graph (counting sort by source; stable for parallel
    /// edges).
    pub fn build(self) -> Graph {
        let nv = self.num_vertices;
        let mut offsets = vec![0usize; nv + 1];
        for &(s, _, _) in &self.edges {
            offsets[s as usize + 1] += 1;
        }
        for i in 0..nv {
            offsets[i + 1] += offsets[i];
        }
        let ne = self.edges.len();
        let mut pos = offsets.clone();
        let mut targets = vec![0 as VertexId; ne];
        let mut weights = vec![0f32; ne];
        for (s, d, w) in self.edges {
            let p = pos[s as usize];
            targets[p] = d;
            weights[p] = w;
            pos[s as usize] += 1;
        }
        Graph { offsets, targets, weights }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_groups_by_source() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(2, 0, 1.0);
        b.add_edge(0, 1, 2.0);
        b.add_edge(2, 1, 3.0);
        let g = b.build();
        g.validate().unwrap();
        assert_eq!(g.out_edges(0).0, &[1]);
        assert_eq!(g.out_edges(1).0, &[] as &[VertexId]);
        assert_eq!(g.out_edges(2).0, &[0, 1]);
    }

    #[test]
    fn undirected_adds_both() {
        let mut b = GraphBuilder::new(2);
        b.add_undirected(0, 1, 5.0);
        let g = b.build();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.out_edges(0).0, &[1]);
        assert_eq!(g.out_edges(1).0, &[0]);
    }

    #[test]
    fn dedup_removes_parallel_edges() {
        let mut b = GraphBuilder::new(2);
        b.add_edge(0, 1, 1.0);
        b.add_edge(0, 1, 9.0);
        b.dedup();
        let g = b.build();
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.out_edges(0).1, &[1.0]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn add_edge_bounds_checked() {
        let mut b = GraphBuilder::new(2);
        b.add_edge(0, 5, 1.0);
    }
}
