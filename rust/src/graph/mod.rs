//! Graph substrate: CSR graphs, builders, text/binary I/O, partitioned
//! distributed views, and the synthetic workload generators that stand in
//! for the paper's datasets (see DESIGN.md §2 for the substitution table).

pub mod builder;
pub mod csr;
pub mod dist;
pub mod generators;
pub mod io;

pub use builder::GraphBuilder;
pub use csr::{Graph, VertexId};
pub use dist::{
    DistGraph, Edge, EdgeRoute, Edges, EdgesIter, GraphLayout, LayoutPolicy, MigrationPlan,
    PartGraph, RouteIter, RoutingEpoch, VertexLayout,
};
