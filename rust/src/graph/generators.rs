//! Synthetic workload generators standing in for the paper's datasets.
//!
//! The paper evaluates on USA road networks (SSSP), web crawls
//! (PageRank), a patent citation network and a Delaunay triangulation
//! (bipartite matching). Those files are not available offline, so each
//! generator reproduces the *structural property that drives the
//! experiment* (DESIGN.md §2):
//!
//! - [`road`]: high diameter, low degree — BSP SSSP needs thousands of
//!   supersteps, the regime of paper Fig. 3 / Table 2;
//! - [`powerlaw`]: heavy-tail in-degrees — PageRank convergence behaviour
//!   of web-Google / uk-2002 (Fig. 4/5);
//! - [`bipartite`]: two-sided random graph for maximal matching (Table 3);
//! - [`delaunay_like`]: planar triangulation-style lattice, the
//!   delaunay_n24 stand-in (Table 3);
//! - [`erdos_renyi`]: plain G(n, m) used by tests and property harnesses.
//!
//! Every generator is a pure function of its arguments and seed
//! (deterministic [`Rng`]), so the same workload is bit-identical on
//! every host, every engine, and every `Parallelism` setting — the
//! benches and equivalence suites depend on that.

use super::builder::GraphBuilder;
use super::csr::{Graph, VertexId};
use crate::util::Rng;

/// Road-network-like graph: a `rows x cols` grid with 4-neighborhood,
/// random weights, a small fraction of missing links (rivers/dead ends)
/// and sparse long-range shortcuts (highways). Edges are bidirectional
/// (two directed edges), like the USA road datasets.
///
/// Diameter is Θ(rows + cols), which is what makes standard-BSP SSSP take
/// thousands of supersteps on it.
pub fn road(rows: usize, cols: usize, seed: u64) -> Graph {
    let n = rows * cols;
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::with_capacity(n, n * 4);
    let id = |r: usize, c: usize| (r * cols + c) as VertexId;
    for r in 0..rows {
        for c in 0..cols {
            // right neighbor
            if c + 1 < cols && !rng.chance(0.05) {
                let w = rng.f32_range(1.0, 10.0);
                b.add_undirected(id(r, c), id(r, c + 1), w);
            }
            // down neighbor
            if r + 1 < rows && !rng.chance(0.05) {
                let w = rng.f32_range(1.0, 10.0);
                b.add_undirected(id(r, c), id(r + 1, c), w);
            }
        }
    }
    // Sparse highways: ~0.1% of vertices get a long-range link. These cut
    // a few shortest paths but keep the diameter high.
    let highways = (n / 1000).max(1);
    for _ in 0..highways {
        let a = rng.index(n) as VertexId;
        let c = rng.index(n) as VertexId;
        if a != c {
            let w = rng.f32_range(20.0, 50.0);
            b.add_undirected(a, c, w);
        }
    }
    b.build()
}

/// Web-like directed graph: heavy-tail in-degrees via preferential
/// attachment PLUS host-level link locality. Real crawls (web-Google,
/// uk-2002) have both properties: a few global hubs, and the large
/// majority of links staying within a site/host neighborhood — which is
/// exactly what makes them partitionable (low metis edge-cut) and lets
/// GraphHP's local phase pay off. Vertex ids are crawl-ordered, so
/// nearby ids ≈ same host.
pub fn powerlaw(n: usize, avg_out: usize, seed: u64) -> Graph {
    powerlaw_with_locality(n, avg_out, 0.8, 256, seed)
}

/// [`powerlaw`] with explicit locality: each link stays within a
/// `window`-sized id neighborhood with probability `locality`, otherwise
/// it goes to a global preferentially-attached target (hubs).
pub fn powerlaw_with_locality(
    n: usize,
    avg_out: usize,
    locality: f64,
    window: usize,
    seed: u64,
) -> Graph {
    assert!(n >= 2);
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::with_capacity(n, n * avg_out);
    // Global preferential-attachment pool: each vertex once plus once per
    // received global link (heavy tail by repetition).
    let mut pool: Vec<VertexId> = Vec::with_capacity(n + n * avg_out / 4);
    pool.push(0);
    for v in 1..n as VertexId {
        let outs = 1 + rng.index(avg_out * 2); // mean ~ avg_out
        let mut targets: Vec<VertexId> = Vec::with_capacity(outs);
        for _ in 0..outs {
            let t = if rng.chance(locality) {
                // intra-host link: uniform in the trailing id window
                let lo = (v as usize).saturating_sub(window);
                (lo + rng.index((v as usize - lo).max(1))) as VertexId
            } else if rng.chance(0.8) {
                // global hub link, preferential
                pool[rng.index(pool.len())]
            } else {
                rng.index(v as usize) as VertexId
            };
            if t != v && !targets.contains(&t) {
                targets.push(t);
            }
        }
        for &t in &targets {
            b.add_edge(v, t, 1.0);
            if rng.chance(1.0 - locality) {
                pool.push(t);
            }
        }
        pool.push(v);
        // occasional local back-link so early vertices have out-edges too
        if rng.chance(0.5) {
            let lo = (v as usize).saturating_sub(window);
            let t = (lo + rng.index((v as usize - lo).max(1))) as VertexId;
            if t != v {
                b.add_edge(t, v, 1.0);
            }
        }
    }
    b.dedup();
    b.build()
}

/// Bipartite graph: `nl` left + `nr` right vertices (left ids
/// `0..nl`, right ids `nl..nl+nr`), each left vertex linked to ~`avg_deg`
/// random right vertices. Edges are stored in BOTH directions so request
/// and grant/deny/accept messages all travel along graph edges (which
/// keeps Definition 1's boundary classification sound for the matching
/// algorithm — see DESIGN.md §3).
pub fn bipartite(nl: usize, nr: usize, avg_deg: usize, seed: u64) -> Graph {
    let n = nl + nr;
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::with_capacity(n, nl * avg_deg * 2);
    for l in 0..nl as VertexId {
        let deg = 1 + rng.index(avg_deg * 2);
        for _ in 0..deg {
            let r = (nl + rng.index(nr)) as VertexId;
            b.add_undirected(l, r, 1.0);
        }
    }
    b.dedup();
    b.build()
}

/// Delaunay-like planar graph: a jittered `rows x cols` point lattice
/// triangulated with right/down/diagonal links — matching the local,
/// planar, bounded-degree structure of the delaunay_nXX family. Each
/// undirected edge is stored in both directions.
pub fn delaunay_like(rows: usize, cols: usize, seed: u64) -> Graph {
    let n = rows * cols;
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::with_capacity(n, n * 6);
    let id = |r: usize, c: usize| (r * cols + c) as VertexId;
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                b.add_undirected(id(r, c), id(r, c + 1), 1.0);
            }
            if r + 1 < rows {
                b.add_undirected(id(r, c), id(r + 1, c), 1.0);
            }
            // one of the two diagonals, at random — the triangulation edge
            if r + 1 < rows && c + 1 < cols {
                if rng.chance(0.5) {
                    b.add_undirected(id(r, c), id(r + 1, c + 1), 1.0);
                } else {
                    b.add_undirected(id(r, c + 1), id(r + 1, c), 1.0);
                }
            }
        }
    }
    b.build()
}

/// G(n, m): `m` uniformly random directed edges, no self-loops.
pub fn erdos_renyi(n: usize, m: usize, seed: u64) -> Graph {
    assert!(n >= 2);
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::with_capacity(n, m);
    for _ in 0..m {
        loop {
            let s = rng.index(n) as VertexId;
            let t = rng.index(n) as VertexId;
            if s != t {
                b.add_edge(s, t, rng.f32_range(0.5, 5.0));
                break;
            }
        }
    }
    b.build()
}

/// R-MAT recursive-quadrant graph (Chakrabarti et al. 2004) with the
/// Graph500 partition probabilities (a=0.57, b=0.19, c=0.19, d=0.05):
/// `2^scale` vertices, `edge_factor` directed edges per vertex before
/// deduplication. Each edge picks one of the four adjacency-matrix
/// quadrants per bit level, which yields the heavy-tail degree
/// distribution and community structure of real web/social graphs at
/// any size — `rmat(20, 16, seed)` is ~16M generated edges, the 10M+
/// regime the large bench scales use ([`crate::graph::GraphLayout`]
/// compression and `Parallelism::WorkStealing` are bandwidth
/// optimisations; they need graphs that exceed cache).
///
/// Self-loops are rerolled; duplicate edges are collapsed, so the built
/// edge count lands a few percent under `n * edge_factor`. Pure function
/// of `(scale, edge_factor, seed)` like every generator here.
pub fn rmat(scale: u32, edge_factor: usize, seed: u64) -> Graph {
    assert!((1..=30).contains(&scale), "rmat scale out of range");
    let n = 1usize << scale;
    let m = n * edge_factor;
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::with_capacity(n, m);
    const A: f64 = 0.57;
    const B: f64 = 0.19;
    const C: f64 = 0.19;
    for _ in 0..m {
        loop {
            let (mut src, mut dst) = (0usize, 0usize);
            for _ in 0..scale {
                src <<= 1;
                dst <<= 1;
                let r = rng.f64();
                if r < A {
                    // top-left quadrant: both bits 0
                } else if r < A + B {
                    dst |= 1;
                } else if r < A + B + C {
                    src |= 1;
                } else {
                    src |= 1;
                    dst |= 1;
                }
            }
            if src != dst {
                b.add_edge(src as VertexId, dst as VertexId, rng.f32_range(0.5, 5.0));
                break;
            }
        }
    }
    b.dedup();
    b.build()
}

/// Web-crawl stand-in at parametric scale: [`powerlaw_with_locality`]
/// with crawl-like defaults (80% of links within a 256-id host window).
/// `web(1 << 21, 8, seed)` is ~16M edges — the large bench scale.
pub fn web(n: usize, avg_out: usize, seed: u64) -> Graph {
    powerlaw_with_locality(n, avg_out, 0.8, 256, seed)
}

/// Random connected undirected graph: a random spanning tree plus `extra`
/// random undirected edges. Used by tests that need reachability.
pub fn connected(n: usize, extra: usize, seed: u64) -> Graph {
    assert!(n >= 1);
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::with_capacity(n, 2 * (n + extra));
    let mut order: Vec<VertexId> = (0..n as VertexId).collect();
    rng.shuffle(&mut order);
    for i in 1..n {
        let parent = order[rng.index(i)];
        let w = rng.f32_range(1.0, 10.0);
        b.add_undirected(order[i], parent, w);
    }
    for _ in 0..extra {
        let a = rng.index(n) as VertexId;
        let c = rng.index(n) as VertexId;
        if a != c {
            b.add_undirected(a, c, rng.f32_range(1.0, 10.0));
        }
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn road_shape_and_validity() {
        let g = road(20, 30, 1);
        g.validate().unwrap();
        assert_eq!(g.num_vertices(), 600);
        // grid with ~5% dropped links, bidirectional
        assert!(g.num_edges() > 1500 && g.num_edges() < 2600, "{}", g.num_edges());
        // max degree small (4-neighborhood + rare highways)
        let max_deg = (0..600u32).map(|v| g.out_degree(v)).max().unwrap();
        assert!(max_deg <= 8, "max_deg={max_deg}");
    }

    #[test]
    fn road_deterministic() {
        assert_eq!(road(10, 10, 7), road(10, 10, 7));
        assert_ne!(road(10, 10, 7), road(10, 10, 8));
    }

    #[test]
    fn powerlaw_has_heavy_tail() {
        let g = powerlaw(3000, 5, 2);
        g.validate().unwrap();
        let ind = g.in_degrees();
        let max_in = *ind.iter().max().unwrap();
        let avg_in = ind.iter().map(|&d| d as f64).sum::<f64>() / ind.len() as f64;
        // heavy tail: max in-degree far above the mean
        assert!(max_in as f64 > 5.0 * avg_in, "max={max_in} avg={avg_in}");
        // dropping locality concentrates the tail further
        let g = powerlaw_with_locality(3000, 5, 0.0, 256, 2);
        let ind = g.in_degrees();
        let max_in = *ind.iter().max().unwrap();
        let avg_in = ind.iter().map(|&d| d as f64).sum::<f64>() / ind.len() as f64;
        assert!(max_in as f64 > 15.0 * avg_in, "max={max_in} avg={avg_in}");
    }

    #[test]
    fn powerlaw_locality_gives_partitionable_structure() {
        let g = powerlaw(4000, 5, 9);
        let a = crate::partition::metis_partition(
            &g,
            8,
            &crate::partition::MetisConfig::default(),
        );
        let s = crate::partition::PartitionStats::compute(&g, &a, 8);
        // web-like locality => well below the random (1 - 1/k) ≈ 87% cut
        assert!(s.cut_fraction < 0.65, "{s}");
        let h = crate::partition::hash_partition(&g, 8);
        let sh = crate::partition::PartitionStats::compute(&g, &h, 8);
        assert!(s.edge_cut < sh.edge_cut, "metis {} vs hash {}", s.edge_cut, sh.edge_cut);
    }

    #[test]
    fn rmat_shape_heavy_tail_and_determinism() {
        let g = rmat(12, 8, 3);
        g.validate().unwrap();
        assert_eq!(g.num_vertices(), 1 << 12);
        // dedup + self-loop rerolls trim a few percent off n*edge_factor
        assert!(g.num_edges() > (1 << 12) * 6, "{}", g.num_edges());
        assert!(g.num_edges() <= (1 << 12) * 8);
        let max_out =
            (0..g.num_vertices() as VertexId).map(|v| g.out_degree(v)).max().unwrap();
        let avg = g.num_edges() as f64 / g.num_vertices() as f64;
        assert!(max_out as f64 > 5.0 * avg, "max={max_out} avg={avg}");
        assert_eq!(rmat(10, 4, 5), rmat(10, 4, 5));
        assert_ne!(rmat(10, 4, 5), rmat(10, 4, 6));
    }

    #[test]
    fn web_is_the_parametric_crawl_generator() {
        assert_eq!(web(2000, 5, 11), powerlaw_with_locality(2000, 5, 0.8, 256, 11));
        web(2000, 5, 11).validate().unwrap();
    }

    #[test]
    fn bipartite_sides_only_cross_link() {
        let (nl, nr) = (50, 40);
        let g = bipartite(nl, nr, 3, 3);
        g.validate().unwrap();
        for v in 0..(nl + nr) as VertexId {
            let left = (v as usize) < nl;
            for &t in g.out_edges(v).0 {
                let t_left = (t as usize) < nl;
                assert_ne!(left, t_left, "edge within one side: {v}->{t}");
            }
        }
    }

    #[test]
    fn bipartite_edges_are_symmetric() {
        let g = bipartite(30, 30, 4, 9);
        for v in 0..60u32 {
            for &t in g.out_edges(v).0 {
                assert!(g.out_edges(t).0.contains(&v), "missing reverse {t}->{v}");
            }
        }
    }

    #[test]
    fn delaunay_is_planarish_bounded_degree() {
        let g = delaunay_like(15, 15, 4);
        g.validate().unwrap();
        let max_deg = (0..g.num_vertices() as u32).map(|v| g.out_degree(v)).max().unwrap();
        assert!(max_deg <= 8, "max_deg={max_deg}");
        // Euler-ish density: |E_undirected| <= 3n - 6
        assert!(g.num_edges() / 2 <= 3 * g.num_vertices());
    }

    #[test]
    fn erdos_renyi_counts() {
        let g = erdos_renyi(100, 500, 5);
        g.validate().unwrap();
        assert_eq!(g.num_edges(), 500);
        // no self loops
        for v in 0..100u32 {
            assert!(!g.out_edges(v).0.contains(&v));
        }
    }

    #[test]
    fn connected_is_connected() {
        let g = connected(200, 50, 6);
        g.validate().unwrap();
        // BFS from 0 reaches everyone (undirected edges stored both ways)
        let mut seen = vec![false; 200];
        let mut stack = vec![0u32];
        seen[0] = true;
        while let Some(v) = stack.pop() {
            for &t in g.out_edges(v).0 {
                if !seen[t as usize] {
                    seen[t as usize] = true;
                    stack.push(t);
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
    }
}
