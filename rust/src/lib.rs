//! # GraphHP — a hybrid platform for iterative graph processing
//!
//! Reproduction of *GraphHP: A Hybrid Platform for Iterative Graph
//! Processing* (Chen, Bai, Li, Gou, Suo, Pan — NWPU, cs.DC 2017).
//!
//! GraphHP is a Pregel/Hama-style vertex-centric BSP platform whose
//! **hybrid execution model** splits every global iteration into a
//! *global phase* (boundary vertices, cross-partition messages) and a
//! *local phase* (in-memory pseudo-superstep iteration inside each
//! partition until it quiesces), so distributed synchronization and
//! communication happen once per global iteration instead of once per
//! superstep.
//!
//! The single entry point for executing programs is the
//! [`engine::Runner`] session, which partitions and distributes the
//! graph once and dispatches the same program to any
//! [`engine::EngineKind`]:
//!
//! ```no_run
//! use graphhp::algorithms::IncrementalPageRank;
//! use graphhp::engine::{EngineKind, Runner};
//! use graphhp::graph::generators;
//!
//! let g = generators::powerlaw(20_000, 5, 42);
//! let r = Runner::new(&g)
//!     .partitions(12)
//!     .engine(EngineKind::GraphHP)
//!     .run(&IncrementalPageRank { tolerance: 1e-4 });
//! println!("{}", r.metrics.summary());
//! ```
//!
//! The crate contains the complete platform plus everything the paper's
//! evaluation needs:
//!
//! - [`graph`] — CSR graphs, partitioned distributed views, synthetic
//!   workload generators standing in for the paper's datasets;
//! - [`partition`] — hash and from-scratch multilevel (METIS-like)
//!   partitioners, plus partition-quality and locality statistics;
//! - [`engine`] — the [`engine::Runner`] session, the vertex-centric
//!   programming interface ([`engine::VertexProgram`]), six execution
//!   engines (standard BSP (Hama), AM-Hama, **GraphHP**, a
//!   Giraph++-style graph-centric engine and GraphLab-style sync/async
//!   engines) over a simulated-cluster cost model, per-superstep
//!   telemetry ([`engine::RunTrace`]) and the telemetry-driven adaptive
//!   hybrid scheduler ([`engine::HybridPolicy::Adaptive`]);
//! - [`algorithms`] — SSSP, incremental & classic PageRank, bipartite
//!   matching, WCC, greedy coloring as vertex programs (plus GAS forms
//!   of PageRank/SSSP/WCC for the GraphLab engines);
//! - `runtime` (feature `xla`) — the XLA/PJRT runtime that loads the
//!   AOT-compiled JAX/Pallas local-phase artifacts (`artifacts/*.hlo.txt`)
//!   and the dense local-phase accelerator built on it. Gated because it
//!   binds to the `xla` crate, which must be vendored separately.
//!
//! See `docs/architecture.md` for the layer map, engine matrix and
//! migration table, `DESIGN.md` for the experiment index and
//! `EXPERIMENTS.md` for paper-vs-measured results.

// Every public item carries rustdoc; CI runs `cargo doc --no-deps` with
// `RUSTDOCFLAGS="-D warnings"`, so an undocumented addition fails the
// docs gate rather than rotting silently.
#![warn(missing_docs)]

pub mod algorithms;
pub mod bench_support;
pub mod engine;
pub mod graph;
pub mod lint;
pub mod partition;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod util;
