//! Minimal byte codec used for (a) network-byte accounting in the
//! simulated cluster and (b) checkpoint serialization.
//!
//! The vendored crate set has no serde, so this is a tiny hand-rolled
//! little-endian format. It is NOT a wire format for interop — it only has
//! to round-trip within this binary.

/// Encode/decode a value as little-endian bytes.
pub trait Codec: Sized {
    /// Append this value's encoding to `buf`.
    fn encode(&self, buf: &mut Vec<u8>);
    /// Decode from the front of `r`, advancing it. Returns None on
    /// truncated/malformed input.
    fn decode(r: &mut &[u8]) -> Option<Self>;
    /// Encoded size in bytes (used for simulated network accounting).
    fn encoded_len(&self) -> usize {
        let mut b = Vec::new();
        self.encode(&mut b);
        b.len()
    }
}

macro_rules! impl_codec_prim {
    ($t:ty, $n:expr) => {
        impl Codec for $t {
            fn encode(&self, buf: &mut Vec<u8>) {
                buf.extend_from_slice(&self.to_le_bytes());
            }
            fn decode(r: &mut &[u8]) -> Option<Self> {
                if r.len() < $n {
                    return None;
                }
                let (head, tail) = r.split_at($n);
                *r = tail;
                Some(<$t>::from_le_bytes(head.try_into().ok()?))
            }
            fn encoded_len(&self) -> usize {
                $n
            }
        }
    };
}

impl_codec_prim!(u8, 1);
impl_codec_prim!(u16, 2);
impl_codec_prim!(u32, 4);
impl_codec_prim!(u64, 8);
impl_codec_prim!(i32, 4);
impl_codec_prim!(i64, 8);
impl_codec_prim!(f32, 4);
impl_codec_prim!(f64, 8);

impl Codec for bool {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.push(*self as u8);
    }
    fn decode(r: &mut &[u8]) -> Option<Self> {
        u8::decode(r).map(|b| b != 0)
    }
    fn encoded_len(&self) -> usize {
        1
    }
}

impl Codec for usize {
    fn encode(&self, buf: &mut Vec<u8>) {
        (*self as u64).encode(buf)
    }
    fn decode(r: &mut &[u8]) -> Option<Self> {
        u64::decode(r).map(|v| v as usize)
    }
    fn encoded_len(&self) -> usize {
        8
    }
}

impl<A: Codec, B: Codec> Codec for (A, B) {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.0.encode(buf);
        self.1.encode(buf);
    }
    fn decode(r: &mut &[u8]) -> Option<Self> {
        Some((A::decode(r)?, B::decode(r)?))
    }
    fn encoded_len(&self) -> usize {
        self.0.encoded_len() + self.1.encoded_len()
    }
}

impl<T: Codec> Codec for Option<T> {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            None => buf.push(0),
            Some(v) => {
                buf.push(1);
                v.encode(buf);
            }
        }
    }
    fn decode(r: &mut &[u8]) -> Option<Self> {
        match u8::decode(r)? {
            0 => Some(None),
            1 => Some(Some(T::decode(r)?)),
            _ => None,
        }
    }
}

impl<T: Codec> Codec for Vec<T> {
    fn encode(&self, buf: &mut Vec<u8>) {
        (self.len() as u64).encode(buf);
        for v in self {
            v.encode(buf);
        }
    }
    fn decode(r: &mut &[u8]) -> Option<Self> {
        let n = u64::decode(r)? as usize;
        // Guard against corrupt length prefixes.
        if n > r.len() {
            return None;
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(T::decode(r)?);
        }
        Some(out)
    }
}

/// Append `v` as a LEB128-style varint: 7 payload bits per byte, the
/// high bit set on every byte except the last. Small values (the common
/// case for delta-encoded edge columns) take one byte.
#[inline]
pub fn write_varint(buf: &mut Vec<u8>, mut v: u64) {
    while v >= 0x80 {
        buf.push((v as u8) | 0x80);
        v >>= 7;
    }
    buf.push(v as u8);
}

/// Decode one varint from `bytes` starting at `*pos`, advancing `*pos`
/// past it. Panics on truncated input — the compressed edge columns are
/// built and consumed inside one process, so malformed bytes are a bug,
/// not an input condition.
#[inline]
pub fn read_varint(bytes: &[u8], pos: &mut usize) -> u64 {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = bytes[*pos];
        *pos += 1;
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return v;
        }
        shift += 7;
        debug_assert!(shift < 64, "varint longer than 64 bits");
    }
}

/// ZigZag-map a signed delta onto an unsigned varint payload so small
/// negative deltas stay short: 0 -> 0, -1 -> 1, 1 -> 2, -2 -> 3, ...
#[inline]
pub fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Inverse of [`zigzag`].
#[inline]
pub fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: Codec + PartialEq + std::fmt::Debug>(v: T) {
        let mut buf = Vec::new();
        v.encode(&mut buf);
        assert_eq!(buf.len(), v.encoded_len());
        let mut r = &buf[..];
        assert_eq!(T::decode(&mut r), Some(v));
        assert!(r.is_empty());
    }

    #[test]
    fn primitives_roundtrip() {
        roundtrip(42u32);
        roundtrip(-7i64);
        roundtrip(3.25f32);
        roundtrip(f64::NEG_INFINITY);
        roundtrip(true);
        roundtrip(usize::MAX);
    }

    #[test]
    fn composites_roundtrip() {
        roundtrip((1u32, 2.5f32));
        roundtrip(Some(9u64));
        roundtrip(Option::<u32>::None);
        roundtrip(vec![1u32, 2, 3]);
        roundtrip(vec![(1u32, 0.5f32), (2, 1.5)]);
    }

    #[test]
    fn truncated_decode_fails() {
        let mut buf = Vec::new();
        12345u64.encode(&mut buf);
        let mut r = &buf[..4];
        assert_eq!(u64::decode(&mut r), None);
    }

    #[test]
    fn corrupt_vec_length_fails_gracefully() {
        let mut buf = Vec::new();
        (u64::MAX).encode(&mut buf);
        let mut r = &buf[..];
        assert_eq!(Vec::<u32>::decode(&mut r), None);
    }

    #[test]
    fn varint_roundtrip_and_length() {
        let cases = [0u64, 1, 0x7f, 0x80, 0x3fff, 0x4000, 123_456_789, u64::MAX];
        for &v in &cases {
            let mut buf = Vec::new();
            write_varint(&mut buf, v);
            let mut pos = 0;
            assert_eq!(read_varint(&buf, &mut pos), v);
            assert_eq!(pos, buf.len());
        }
        let mut buf = Vec::new();
        write_varint(&mut buf, 0x7f);
        assert_eq!(buf.len(), 1);
        write_varint(&mut buf, 0x80);
        assert_eq!(buf.len(), 3);
    }

    #[test]
    fn varint_sequence_decodes_in_order() {
        let vals = [5u64, 0, 300, 1, u32::MAX as u64];
        let mut buf = Vec::new();
        for &v in &vals {
            write_varint(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &vals {
            assert_eq!(read_varint(&buf, &mut pos), v);
        }
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn zigzag_roundtrip_keeps_small_deltas_small() {
        for v in [-1_000_000i64, -2, -1, 0, 1, 2, 1_000_000, i64::MIN, i64::MAX] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        assert_eq!(zigzag(0), 0);
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
        // |delta| < 64 stays a one-byte varint either direction
        let mut buf = Vec::new();
        write_varint(&mut buf, zigzag(-63));
        write_varint(&mut buf, zigzag(63));
        assert_eq!(buf.len(), 2);
    }
}
