//! Small utilities shared across the crate: a deterministic PRNG (the
//! vendored crate set has no `rand`), a stopwatch, and byte codecs used by
//! message serialization accounting and checkpointing.

pub mod codec;
pub mod rng;
pub mod timer;

pub use codec::Codec;
pub use rng::Rng;
pub use timer::Stopwatch;
