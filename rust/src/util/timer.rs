//! Stopwatch used by the engines to attribute wall time to compute /
//! communication / synchronization buckets.

use std::time::{Duration, Instant};

/// A resettable stopwatch accumulating elapsed time across start/stop pairs.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    acc: Duration,
    started: Option<Instant>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    /// A stopped stopwatch at zero.
    pub fn new() -> Self {
        Stopwatch { acc: Duration::ZERO, started: None }
    }

    /// Start (or restart) the running segment.
    pub fn start(&mut self) {
        // detlint: allow(wall-clock) — the stopwatch exists to report
        // wall time; callers only feed its totals into metrics output.
        self.started = Some(Instant::now());
    }

    /// Stop the running segment, folding it into the accumulator.
    /// Returns the segment length.
    pub fn stop(&mut self) -> Duration {
        match self.started.take() {
            Some(t) => {
                let d = t.elapsed();
                self.acc += d;
                d
            }
            None => Duration::ZERO,
        }
    }

    /// Total accumulated time (not counting a currently running segment).
    pub fn total(&self) -> Duration {
        self.acc
    }

    /// Time a closure and fold it into the accumulator.
    pub fn time<R>(&mut self, f: impl FnOnce() -> R) -> R {
        self.start();
        let r = f();
        self.stop();
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_across_segments() {
        let mut sw = Stopwatch::new();
        sw.time(|| std::thread::sleep(Duration::from_millis(2)));
        sw.time(|| std::thread::sleep(Duration::from_millis(2)));
        assert!(sw.total() >= Duration::from_millis(4));
    }

    #[test]
    fn stop_without_start_is_zero() {
        let mut sw = Stopwatch::new();
        assert_eq!(sw.stop(), Duration::ZERO);
        assert_eq!(sw.total(), Duration::ZERO);
    }
}
