//! Deterministic xoshiro256** PRNG.
//!
//! The offline vendor set has no `rand` crate; everything in the repo that
//! needs randomness (graph generators, bipartite-matching tie-breaks,
//! property-test case generation) uses this implementation so runs are
//! bit-reproducible from a seed.

/// xoshiro256** with splitmix64 seeding (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. per vertex / per iteration).
    pub fn derive(&self, stream: u64) -> Self {
        let mut sm = self.s[0] ^ stream.wrapping_mul(0x2545F4914F6CDD1D);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Panics if `n == 0`.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        // Lemire's multiply-shift rejection method.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    pub fn index(&mut self, n: usize) -> usize {
        self.gen_range(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "astronomically unlikely");
    }

    #[test]
    fn derive_streams_are_independent() {
        let base = Rng::new(5);
        let mut a = base.derive(1);
        let mut b = base.derive(2);
        assert_ne!(a.next_u64(), b.next_u64());
        // deriving the same stream twice gives the same sequence
        let mut c = base.derive(1);
        let mut d = base.derive(1);
        for _ in 0..10 {
            assert_eq!(c.next_u64(), d.next_u64());
        }
    }
}
