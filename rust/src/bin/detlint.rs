//! `detlint` — run the determinism-contract lint over `rust/src`.
//!
//! ```text
//! cargo run --bin detlint              # human-readable report
//! cargo run --bin detlint -- --json    # machine-readable report
//! cargo run --bin detlint -- --root path/to/src
//! ```
//!
//! Exit status: 0 clean, 1 findings, 2 usage or I/O error. CI runs this
//! in the `lint` job; see `docs/architecture.md` ("Correctness
//! tooling") for the rules and the `allow` annotation syntax.

use std::path::PathBuf;
use std::process::ExitCode;

use graphhp::lint;

const USAGE: &str = "usage: detlint [--json] [--root DIR]\n\
  --json      machine-readable report on stdout\n\
  --root DIR  source tree to scan (default: this crate's src/)";

fn main() -> ExitCode {
    let mut json = false;
    let mut root: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => json = true,
            "--root" => match args.next() {
                Some(d) => root = Some(PathBuf::from(d)),
                None => {
                    eprintln!("detlint: --root needs a directory\n{USAGE}");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                println!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("detlint: unknown argument `{other}`\n{USAGE}");
                return ExitCode::from(2);
            }
        }
    }
    let root =
        root.unwrap_or_else(|| PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/src")));

    match lint::lint_tree(&root) {
        Err(e) => {
            eprintln!("detlint: {}: {e}", root.display());
            ExitCode::from(2)
        }
        Ok(findings) => {
            if json {
                println!("{}", lint::to_json(&findings));
            } else if findings.is_empty() {
                println!("detlint: clean ({})", root.display());
            } else {
                for f in &findings {
                    println!("{f}");
                }
                println!(
                    "detlint: {} finding(s) — suppress with \
                     `// detlint: allow(<rule>) — <reason>` on the offending line",
                    findings.len()
                );
            }
            if findings.is_empty() {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
    }
}
