//! `chaosjson` — machine-readable chaos stress report.
//!
//! Runs the recorded chaos matrix (engines × algorithms × schedules
//! from `tests/chaos_suite.rs`) and emits one schema-stable JSON
//! document per row: how many events the seeded schedule injected, how
//! many were loss, whether the run converged, whether the fixpoint
//! matched the clean baseline, and whether loss without checkpoints
//! failed loudly. The committed `STRESS_chaos_results.json` at the
//! repository root is this tool's output format (see its `provenance`
//! field for how it was produced).
//!
//! ```text
//! cargo run --release --bin chaosjson                 # JSON on stdout
//! cargo run --release --bin chaosjson -- --out c.json
//! cargo run --release --bin chaosjson -- --quick      # CI smoke scale
//! ```
//!
//! Schema (version 1) — field order is fixed; additions bump the
//! version:
//!
//! ```text
//! { schema_version, suite, provenance, measured, quick,
//!   graph: { name, vertices, edges, partitions },
//!   rows: [ { engine, algo, schedule, seed, events, loss_events,
//!             recoveries, converged, matched_clean, loud_failure,
//!             error } ] }
//! ```
//!
//! Every row is a pure function of its seed: two runs of this binary
//! produce byte-identical `rows` (the determinism the chaos suite
//! asserts), so the report doubles as a regression artifact.

use std::fmt::Write as _;
use std::process::ExitCode;

use graphhp::algorithms::{GasWcc, IncrementalPageRank, Sssp, Wcc};
use graphhp::bench_support::runner;
use graphhp::engine::{ChaosPolicy, ChaosSchedule, ChaosTrace, EngineKind, Runner};
use graphhp::graph::{generators, Graph};

const USAGE: &str = "usage: chaosjson [--out FILE] [--quick]\n\
  --out FILE  write the JSON document to FILE (default: stdout)\n\
  --quick     CI smoke scale: smaller grid, SSSP/WCC only";

struct ChaosRow {
    engine: String,
    algo: &'static str,
    schedule: &'static str,
    seed: u64,
    events: u64,
    loss_events: u64,
    recoveries: u64,
    converged: bool,
    matched_clean: bool,
    loud_failure: bool,
    error: String,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

fn trace_counts(t: &Option<ChaosTrace>) -> (u64, u64) {
    match t {
        Some(t) => (t.events.len() as u64, t.loss_events()),
        None => (0, 0),
    }
}

/// The kill-only schedule every engine must fail loudly on when no
/// checkpoints are configured (graphlab-async excepted, by contract).
fn kill_policy(seed: u64) -> ChaosPolicy {
    ChaosPolicy { seed, schedule: ChaosSchedule { kill_at: vec![1], ..Default::default() } }
}

/// benign / stress+checkpoint / kill-no-checkpoint rows for one push
/// engine and one algorithm. `matched` compares against the clean
/// baseline with the algorithm's own tolerance.
fn push_rows<P, F>(
    rows: &mut Vec<ChaosRow>,
    g: &Graph,
    kind: EngineKind,
    algo: &'static str,
    base_seed: u64,
    prog: &P,
    matched: F,
) where
    P: graphhp::engine::VertexProgram,
    F: Fn(&[P::V], &[P::V]) -> bool,
{
    let clean = runner(g, 4).engine(kind).run(prog);

    let benign = runner(g, 4).engine(kind).chaos(ChaosPolicy::benign(base_seed)).run(prog);
    let (events, loss) = trace_counts(&benign.chaos);
    rows.push(ChaosRow {
        engine: kind.to_string(),
        algo,
        schedule: "benign",
        seed: base_seed,
        events,
        loss_events: loss,
        recoveries: benign.metrics.recoveries,
        converged: true,
        matched_clean: matched(&clean.values, &benign.values),
        loud_failure: false,
        error: String::new(),
    });

    // checkpoint rollback is GraphHP's; the other push engines refuse
    // loss outright (covered by the kill row below)
    if matches!(kind, EngineKind::GraphHP) {
        let stress = runner(g, 4)
            .engine(kind)
            .checkpoint_interval(Some(2))
            .chaos(ChaosPolicy::stress(base_seed + 1))
            .run(prog);
        let (events, loss) = trace_counts(&stress.chaos);
        rows.push(ChaosRow {
            engine: kind.to_string(),
            algo,
            schedule: "stress+checkpoint",
            seed: base_seed + 1,
            events,
            loss_events: loss,
            recoveries: stress.metrics.recoveries,
            converged: true,
            matched_clean: matched(&clean.values, &stress.values),
            loud_failure: false,
            error: String::new(),
        });
    }

    let killed = runner(g, 4).engine(kind).chaos(kill_policy(base_seed + 2)).try_run(prog);
    let (loud, error) = match killed {
        Ok(_) => (false, "kill without checkpoints converged silently".to_string()),
        Err(e) => (e.starts_with("chaos:"), e),
    };
    rows.push(ChaosRow {
        engine: kind.to_string(),
        algo,
        schedule: "kill-no-checkpoint",
        seed: base_seed + 2,
        events: 0,
        loss_events: 0,
        recoveries: 0,
        converged: false,
        matched_clean: false,
        loud_failure: loud,
        error,
    });
}

/// The pull-engine rows: graphlab-sync must fail loudly on a kill and
/// record an empty trace under benign chaos; graphlab-async is
/// documented out of scope and runs chaos-free.
fn gas_rows(rows: &mut Vec<ChaosRow>, g: &Graph, base_seed: u64) {
    let sync = EngineKind::GraphLabSync;
    let clean = Runner::new(g).partitions(4).engine(sync).run_gas(&GasWcc);
    let benign = Runner::new(g)
        .partitions(4)
        .engine(sync)
        .chaos(ChaosPolicy::benign(base_seed))
        .run_gas(&GasWcc);
    let (events, loss) = trace_counts(&benign.chaos);
    rows.push(ChaosRow {
        engine: sync.to_string(),
        algo: "wcc",
        schedule: "benign",
        seed: base_seed,
        events,
        loss_events: loss,
        recoveries: benign.metrics.recoveries,
        converged: true,
        matched_clean: clean.values == benign.values,
        loud_failure: false,
        error: String::new(),
    });
    let killed = Runner::new(g)
        .partitions(4)
        .engine(sync)
        .chaos(kill_policy(base_seed + 1))
        .try_run_gas(&GasWcc);
    let (loud, error) = match killed {
        Ok(_) => (false, "kill without checkpoints converged silently".to_string()),
        Err(e) => (e.starts_with("chaos:"), e),
    };
    rows.push(ChaosRow {
        engine: sync.to_string(),
        algo: "wcc",
        schedule: "kill-no-checkpoint",
        seed: base_seed + 1,
        events: 0,
        loss_events: 0,
        recoveries: 0,
        converged: false,
        matched_clean: false,
        loud_failure: loud,
        error,
    });

    let kind = EngineKind::GraphLabAsync;
    let r = Runner::new(g)
        .partitions(4)
        .engine(kind)
        .chaos(kill_policy(base_seed + 2))
        .run_gas(&GasWcc);
    rows.push(ChaosRow {
        engine: kind.to_string(),
        algo: "wcc",
        schedule: "out-of-scope",
        seed: base_seed + 2,
        events: 0,
        loss_events: 0,
        recoveries: 0,
        converged: true,
        matched_clean: r.chaos.is_none() && clean.values == r.values,
        loud_failure: false,
        error: String::new(),
    });
}

fn main() -> ExitCode {
    let mut out_path: Option<String> = None;
    let mut quick = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--out" => match args.next() {
                Some(p) => out_path = Some(p),
                None => {
                    eprintln!("{USAGE}");
                    return ExitCode::from(2);
                }
            },
            "--quick" => quick = true,
            _ => {
                eprintln!("{USAGE}");
                return ExitCode::from(2);
            }
        }
    }

    // long-diameter grid: every run outlives the stress kill (barrier 5)
    let (gname, g) =
        if quick { ("road-12x12", generators::road(12, 12, 9)) } else { ("road-20x20", generators::road(20, 20, 9)) };
    let engines: Vec<EngineKind> = if quick {
        vec![EngineKind::Hama, EngineKind::GraphHP]
    } else {
        EngineKind::VERTEX_CENTRIC.to_vec()
    };

    let mut rows: Vec<ChaosRow> = Vec::new();
    for (ei, &kind) in engines.iter().enumerate() {
        let base = 100 * (ei as u64 + 1);
        eprintln!("chaosjson: {kind}");
        push_rows(&mut rows, &g, kind, "sssp", base, &Sssp { source: 0 }, |a, b| {
            a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
        });
        push_rows(&mut rows, &g, kind, "wcc", base + 10, &Wcc, |a, b| a == b);
        if !quick {
            push_rows(
                &mut rows,
                &g,
                kind,
                "pagerank",
                base + 20,
                &IncrementalPageRank { tolerance: 1e-6 },
                |a, b| a.iter().zip(b).all(|(x, y)| (x - y).abs() < 1e-6),
            );
        }
    }
    if !quick {
        eprintln!("chaosjson: graphlab");
        gas_rows(&mut rows, &g, 900);
    }

    let mut doc = String::new();
    doc.push_str("{\n");
    let _ = writeln!(doc, "  \"schema_version\": 1,");
    let _ = writeln!(doc, "  \"suite\": \"chaos_stress\",");
    let _ = writeln!(
        doc,
        "  \"provenance\": \"chaosjson v{} ({})\",",
        env!("CARGO_PKG_VERSION"),
        if quick { "quick" } else { "full" },
    );
    let _ = writeln!(doc, "  \"measured\": true,");
    let _ = writeln!(doc, "  \"quick\": {quick},");
    let _ = writeln!(
        doc,
        "  \"graph\": {{ \"name\": \"{}\", \"vertices\": {}, \"edges\": {}, \"partitions\": 4 }},",
        gname,
        g.num_vertices(),
        g.num_edges(),
    );
    doc.push_str("  \"rows\": [\n");
    for (ri, r) in rows.iter().enumerate() {
        let _ = writeln!(
            doc,
            "    {{ \"engine\": \"{}\", \"algo\": \"{}\", \"schedule\": \"{}\", \
             \"seed\": {}, \"events\": {}, \"loss_events\": {}, \"recoveries\": {}, \
             \"converged\": {}, \"matched_clean\": {}, \"loud_failure\": {}, \
             \"error\": \"{}\" }}{}",
            json_escape(&r.engine),
            r.algo,
            r.schedule,
            r.seed,
            r.events,
            r.loss_events,
            r.recoveries,
            r.converged,
            r.matched_clean,
            r.loud_failure,
            json_escape(&r.error),
            if ri + 1 < rows.len() { "," } else { "" },
        );
    }
    doc.push_str("  ]\n}\n");

    // the contract the chaos suite asserts, re-checked on the report
    let bad: Vec<&ChaosRow> = rows
        .iter()
        .filter(|r| match r.schedule {
            "kill-no-checkpoint" => !r.loud_failure,
            _ => !r.matched_clean,
        })
        .collect();
    for r in &bad {
        eprintln!(
            "chaosjson: CONTRACT VIOLATION {} {} {}: {}",
            r.engine, r.algo, r.schedule, r.error
        );
    }

    match out_path {
        Some(p) => {
            if let Err(e) = std::fs::write(&p, &doc) {
                eprintln!("chaosjson: write {p}: {e}");
                return ExitCode::from(2);
            }
            eprintln!("chaosjson: wrote {p}");
        }
        None => print!("{doc}"),
    }
    if bad.is_empty() { ExitCode::SUCCESS } else { ExitCode::from(1) }
}
